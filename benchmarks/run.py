"""Benchmark harness: one module per paper table/figure.

Prints ``name,value,notes`` CSV.  Modules:
  fig3     - pool characterization (Fig. 3, Table 1, Obs. 1-2)
  fig9     - 8 collectives vs IB + internal variants (Fig. 9)
  fig10    - scalability 3/6/12 nodes (Fig. 10)
  fig11    - slicing-factor sensitivity (Fig. 11)
  llm      - FSDP Llama-3-8B case study (Sec. 5.5)
  autotune - plan-driven backend='auto' vs fixed backends
  overlap  - bucketed+prefetched FSDP step vs per-leaf serialized
  fusion   - fused collective+compute kernels vs unfused composition
             (per-op modeled deltas, the plan's fused-cell audit,
             interpret-mode wall times)
  topology - hierarchical decomposition vs flat per-level recursion on
             a 3-level (pod/node/gpu) multi-fabric topology
  retune   - online re-tuning convergence under a 4x mis-calibrated
             pool oracle (measured-cost feedback + plan hot-swap)
  placement - placement planner vs hand-tuned / naive axis->level
             assignments, regular and irregular (4+2) topologies
  observability - tracing overhead on/off (< 5%) + degraded-link
             detection latency for an injected 4x-slow pool link
             (flight recorder + health monitor + calibration)
  resilience - chaos audit: rank death / link degrade / transient
             pool faults each driven through detect -> re-plan ->
             resume, with steps-lost and degraded-step-cost bounds
  serving  - continuous batching + CXL-pooled KV cache vs the static
             batch engine under Poisson arrivals (virtual clock over
             the real scheduler/block-manager/pool-store), prompt-
             reuse prefix sharing, tight-HBM eviction tiering
  pipeline - PP x TP x FSDP vs FSDP-only at fixed device count
             (stage handoff over tuned CXL/IB p2p cells), per-level
             p2p plan-cell coverage, 1F1B/interleaved bubble audit

``--smoke`` runs the fast CI path: coarse-grid plan generation + the
autotune and overlap audits (exercises the whole tuner + overlap stack
in seconds).  ``--json PATH`` additionally writes every emitted record
as JSON so CI can track the perf trajectory per-PR as an artifact.
"""
from __future__ import annotations

import argparse
import inspect
import json
import time

from benchmarks import (autotune, fig3_characterization, fig9_collectives,
                        fig10_scalability, fig11_chunks, fusion,
                        llm_case_study, observability, overlap, pipeline,
                        placement, resilience, retune, serving, topology)

MODULES = [
    ("fig3", fig3_characterization),
    ("fig9", fig9_collectives),
    ("fig10", fig10_scalability),
    ("fig11", fig11_chunks),
    ("llm", llm_case_study),
    ("autotune", autotune),
    ("overlap", overlap),
    ("fusion", fusion),
    ("topology", topology),
    ("retune", retune),
    ("placement", placement),
    ("observability", observability),
    ("resilience", resilience),
    ("serving", serving),
    ("pipeline", pipeline),
]

SMOKE_MODULES = ("fig3", "autotune", "overlap", "fusion", "topology",
                 "retune", "placement", "observability", "resilience",
                 "serving", "pipeline")


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("module", nargs="?", default=None,
                    help="run a single module (default: all)")
    ap.add_argument("--smoke", action="store_true",
                    help="fast CI path: coarse grids, subset of modules")
    ap.add_argument("--json", default=None,
                    help="also write emitted records to this JSON file")
    args = ap.parse_args()

    print("name,value,notes")
    records = []

    def emit(name, value, notes=""):
        v = f"{value:.4f}" if isinstance(value, float) else str(value)
        print(f"{name},{v},{notes}")
        records.append({"name": name, "value": value, "notes": notes})

    for key, mod in MODULES:
        if args.module and key != args.module:
            continue
        if args.smoke and not args.module and key not in SMOKE_MODULES:
            continue
        t0 = time.time()
        kwargs = {}
        if args.smoke and \
                "smoke" in inspect.signature(mod.run).parameters:
            kwargs["smoke"] = True
        mod.run(emit, **kwargs)
        emit(f"{key}_wall_s", time.time() - t0, "benchmark wall time")

    if args.json:
        with open(args.json, "w") as f:
            json.dump({"smoke": bool(args.smoke), "records": records},
                      f, indent=1)


if __name__ == "__main__":
    main()
