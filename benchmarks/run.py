"""Benchmark harness: one module per paper table/figure.

Prints ``name,value,notes`` CSV.  Modules:
  fig3  - pool characterization (Fig. 3, Table 1, Obs. 1-2)
  fig9  - 8 collectives vs IB + internal variants (Fig. 9)
  fig10 - scalability 3/6/12 nodes (Fig. 10)
  fig11 - slicing-factor sensitivity (Fig. 11)
  llm   - FSDP Llama-3-8B case study (Sec. 5.5)
"""
from __future__ import annotations

import sys
import time

from benchmarks import (fig3_characterization, fig9_collectives,
                        fig10_scalability, fig11_chunks, llm_case_study)

MODULES = [
    ("fig3", fig3_characterization),
    ("fig9", fig9_collectives),
    ("fig10", fig10_scalability),
    ("fig11", fig11_chunks),
    ("llm", llm_case_study),
]


def main() -> None:
    only = sys.argv[1] if len(sys.argv) > 1 else None
    print("name,value,notes")

    def emit(name, value, notes=""):
        v = f"{value:.4f}" if isinstance(value, float) else str(value)
        print(f"{name},{v},{notes}")

    for key, mod in MODULES:
        if only and key != only:
            continue
        t0 = time.time()
        mod.run(emit)
        emit(f"{key}_wall_s", time.time() - t0, "benchmark wall time")


if __name__ == "__main__":
    main()
