"""Resilience audit: inject -> detect -> re-plan -> resume, bounded.

Chaos engineering for the emulated cluster: every fault class the
fault plan can inject is driven through the full recovery loop
(``repro.resilience``) and the recovery cost is measured and gated.
All runs are seeded and deterministic.

1. **Monitoring is cheap enough to leave on.**  A synthetic step loop
   runs with the ``FailureMonitor`` (heartbeat pulses + health fold +
   liveness publication) off and on, interleaved; median-of-repeats
   wall times must differ by < ``OVERHEAD_BOUND_PCT``.
2. **Rank death.**  Rank 5 of a ``node:cxl:4+4`` pool level dies at
   ``FAULT_STEP``.  The heartbeat monitor must confirm within its
   timeout+patience budget, the controller re-plans the survivors onto
   the ragged ``4+3`` shape, and state rolls back to the newest
   pool-resident snapshot.  Steps lost (detection latency + rollback)
   is gated, and the survivor schedule must cost <=
   ``STEP_FACTOR_BOUND`` of the healthy one.
3. **Persistent link degrade.**  The pool link slows 4x
   (backend-qualified ``node@cxl``: the ring/IB alternative keeps its
   healthy speed).  The health monitor flags it, the controller fails
   the level over to its IB alternative, and the failed-over schedule
   must cost <= ``STEP_FACTOR_BOUND`` of healthy.
4. **Transient pool faults.**  A seeded window of pool-access errors
   hits every pool store (snapshots + heartbeats).  The retry layer
   absorbs all of them: zero snapshots fail, zero ranks are falsely
   confirmed dead, zero steps lost (strict zero-baseline gate).
5. **Re-convergence.**  After a transient 6x degrade window, the
   online tuner (EWMA decay toward the calibrated oracle +
   epsilon-greedy re-exploration) must walk its choice back to the
   original backend within ``RECONVERGE_BOUND`` refreshes - no
   restart, no operator.

Emitted metrics:
  resilience_monitor_overhead_pct   < OVERHEAD_BOUND_PCT (info-only
                                    for the gate: wall-clock noise,
                                    asserted in-bench instead)
  resilience_rankdeath_steps_lost   <= RANKDEATH_BOUND (gated lower)
  resilience_rankdeath_step_factor  <= STEP_FACTOR_BOUND (gated lower)
  resilience_linkdegrade_steps_lost <= DETECT_BOUND (gated lower)
  resilience_failover_step_factor   <= STEP_FACTOR_BOUND (gated lower)
  resilience_pool_steps_lost        == 0 (gated, strict zero baseline)
  resilience_pool_retries           > 0 (info: transients absorbed)
  resilience_reconverge_steps       <= RECONVERGE_BOUND (gated lower)
  resilience_reconverged            == 1 (asserted)
"""
from __future__ import annotations

import contextlib
import time

import numpy as np

from repro import tuner
from repro.core import ledger
from repro.core.hw import MiB
from repro.core.pool import PoolAccessError
from repro.core.topology import parse_topology, set_active_topology
from repro.obs import StepEmulator
from repro.resilience import (FailureMonitor, FaultPlan,
                              ResilienceController)
from repro.training.checkpoint import PoolCheckpointStore
from repro.tuner import runtime

OVERHEAD_BOUND_PCT = 2.0
OVERHEAD_STEPS = 40
OVERHEAD_REPEATS = 7

NRANKS = 8
FAULT_STEP = 12           # rank 5 dies here
SNAP_INTERVAL = 4         # pool snapshot cadence
RANKDEATH_BOUND = 8       # steps lost: detect latency + rollback
STEP_FACTOR_BOUND = 1.6   # degraded-mode step cost vs healthy

INJECT_STEP = 10          # pool link degrades 4x here (persistent)
DEGRADE_FACTOR = 4.0
DETECT_BOUND = 8          # flag + failover within this many steps
NOISE_STD = 0.03

POOL_ERROR_RATE = 0.5     # per-access failure prob in the window
POOL_RETRIES = 5

RECONV_DEGRADE = 6.0      # transient mis-pricing for the tuner
RECONVERGE_BOUND = 4      # refreshes to walk back after the heal


def _cleanup() -> None:
    """Reset every process-wide registry a section may have touched."""
    tuner.clear_active_plan()
    set_active_topology(None)
    runtime.clear_link_health()
    runtime.clear_rank_liveness()


def _monitor_overhead_pct() -> float:
    """Wall-time overhead (%) of the failure monitor on vs off:
    interleaved off/on repeats compared by median (machine-state drift
    cancels).  The monitored variant pulses every rank's heartbeat,
    folds link health, and settles verdicts each step - the full
    per-step detection path.  Its cost is a per-step *constant*
    (~20us: NRANKS pulses + staleness reads), so the synthetic step is
    sized like a real (smoke-train-scale, ~2ms) step - quoting a fixed
    per-step cost against a microsecond-scale step would measure a
    workload no trainer has."""
    work = np.random.default_rng(0).standard_normal((384, 384))

    def run_once(monitored: bool) -> float:
        mon = FailureMonitor(NRANKS) if monitored else None
        t0 = time.perf_counter()
        acc = 0.0
        for i in range(OVERHEAD_STEPS):
            acc += float(np.dot(work, work)[0, 0])   # the "step"
            acc += float(np.dot(work, work)[0, 0])
            if mon is not None:
                mon.pulse_all(i)
                mon.end_step(i)
        dt = time.perf_counter() - t0
        assert acc != 0.0
        if mon is not None:
            assert not mon.dead_ranks(), "false positive at idle"
        return dt

    run_once(False)                                  # warm caches
    run_once(True)
    offs, ons = [], []
    for _ in range(OVERHEAD_REPEATS):
        offs.append(run_once(False))
        ons.append(run_once(True))
    runtime.clear_rank_liveness()
    off = float(np.median(offs))
    on = float(np.median(ons))
    return max(0.0, (on - off) / off * 100.0)


def _step_cost(topo, axis: str) -> float:
    """Analytic cost of one representative training step's
    collectives on ``axis``: two FSDP gathers + one grad
    reduce-scatter at 16 MiB."""
    ag = tuner.predict_call_time(topo, axis, "all_gather", 16 * MiB)
    rs = tuner.predict_call_time(topo, axis, "reduce_scatter", 16 * MiB)
    return 2.0 * ag + rs


def _rank_death(emit) -> None:
    topo = parse_topology("pod:ib,node:cxl:4+4")
    mon = FailureMonitor(NRANKS)
    ctrl = ResilienceController(mon, topology=topo,
                                log=lambda *_: None)
    store = PoolCheckpointStore(capacity_bytes=1 << 20)
    state = {"w": np.arange(4096, dtype=np.float32),
             "b": np.zeros(64, dtype=np.float32)}
    fp = FaultPlan.parse(f"rank_death@{FAULT_STEP}:rank=5")
    confirm_step = rp = None
    with fp:
        for step in range(FAULT_STEP + 8):
            fp.begin_step(step)
            if step % SNAP_INTERVAL == 0:
                state["w"] = state["w"] + 1.0   # state evolves
                store.snapshot(step, state)
            got = ctrl.step(step)
            if got is not None:
                confirm_step, rp = step, got
                break
    assert rp is not None, "rank death never confirmed"
    assert confirm_step >= FAULT_STEP, (
        f"false positive: confirmed at {confirm_step} before the "
        f"fault at {FAULT_STEP}")
    assert mon.dead_ranks() == [5], mon.dead_ranks()
    lv = rp.topology.level_for("node")
    assert lv.shape == (4, 3), (
        f"survivor shape {lv.shape}, expected (4, 3)")

    # resume: the survivors restore the newest committed snapshot
    snap = store.latest()
    assert snap is not None and snap <= confirm_step
    restored, _meta = store.restore(state)
    np.testing.assert_allclose(restored["w"], state["w"])

    lost = ctrl.steps_lost(FAULT_STEP, confirm_step, snap)
    emit("resilience_rankdeath_steps_lost", lost,
         f"detect latency + rollback for a rank death at step "
         f"{FAULT_STEP}, snapshots every {SNAP_INTERVAL} "
         f"(bound {RANKDEATH_BOUND})")
    assert lost <= RANKDEATH_BOUND, (
        f"{lost} steps lost to a rank death (> {RANKDEATH_BOUND})")

    factor = _step_cost(rp.topology, "node") / _step_cost(topo, "node")
    emit("resilience_rankdeath_step_factor", factor,
         f"ragged 4+3 survivor step cost / healthy 4+4 "
         f"(bound {STEP_FACTOR_BOUND})")
    assert factor <= STEP_FACTOR_BOUND, (
        f"survivor schedule costs {factor:.2f}x healthy")
    _cleanup()


def _link_failover(emit) -> None:
    topo = parse_topology("pod:ib,node:cxl:4+4")
    profile = [
        {"primitive": "all_gather", "msg_bytes": 4 * MiB, "nranks": 8,
         "backend": "cxl", "slicing_factor": 4,
         "allreduce_mode": "two_phase", "level": "node",
         "fabric": "cxl", "calls": 2.0},
        {"primitive": "reduce_scatter", "msg_bytes": 4 * MiB,
         "nranks": 8, "backend": "cxl", "slicing_factor": 4,
         "allreduce_mode": "two_phase", "level": "node",
         "fabric": "cxl", "calls": 1.0},
        {"primitive": "all_reduce", "msg_bytes": 1 * MiB, "nranks": 2,
         "backend": "ring", "slicing_factor": 4,
         "allreduce_mode": "two_phase", "level": "pod", "fabric": "ib",
         "calls": 1.0},
    ]
    emu = StepEmulator(topology=topo, noise_std=NOISE_STD, seed=0)
    mon = FailureMonitor(NRANKS)
    ctrl = ResilienceController(mon, topology=topo,
                                log=lambda *_: None)
    fp = FaultPlan.parse(
        f"link_degrade@{INJECT_STEP}:link=node@cxl,"
        f"factor={DEGRADE_FACTOR}")
    confirm_step = rp = None
    with fp:
        for step in range(INJECT_STEP + DETECT_BOUND + 2):
            fp.begin_step(step, emulator=emu)
            samples = emu.step_timings(profile, book=False)
            got = ctrl.step(step, timings=samples)
            if got is not None:
                confirm_step, rp = step, got
                break
    assert rp is not None, "degraded pool link never failed over"
    assert confirm_step >= INJECT_STEP, (
        f"false positive: failover at {confirm_step} before the "
        f"injection at {INJECT_STEP}")
    lv = rp.topology.level_for("node")
    assert lv.fabric == "ib", (
        f"expected cxl->ib failover, got {lv.fabric}")
    assert lv.shape == (4, 4), "failover must keep every rank"

    latency = confirm_step - INJECT_STEP + 1
    emit("resilience_linkdegrade_steps_lost", latency,
         f"steps from {DEGRADE_FACTOR}x pool-link slowdown to the "
         f"failover re-plan (bound {DETECT_BOUND}; no rollback - "
         f"state is intact)")
    assert latency <= DETECT_BOUND, (
        f"failover took {latency} steps (> {DETECT_BOUND})")

    factor = _step_cost(rp.topology, "node") / _step_cost(topo, "node")
    emit("resilience_failover_step_factor", factor,
         f"IB-failover step cost / healthy cxl "
         f"(bound {STEP_FACTOR_BOUND})")
    assert factor <= STEP_FACTOR_BOUND, (
        f"failover schedule costs {factor:.2f}x healthy")
    _cleanup()


def _transient_pool(emit) -> None:
    store = PoolCheckpointStore(capacity_bytes=1 << 20,
                                retries=POOL_RETRIES)
    # timeout/patience sized so a short error window can never
    # confirm a live rank dead (a lost pulse is not a death)
    mon = FailureMonitor(4, heartbeat_timeout=2, patience=3)
    state = {"w": np.zeros(1024, dtype=np.float32)}
    fp = FaultPlan.parse(f"pool_error@5-8:rate={POOL_ERROR_RATE}",
                         seed=7)
    failed_snaps = 0
    with fp:
        for step in range(12):
            fp.begin_step(step)
            state["w"] = state["w"] + 1.0
            try:
                store.snapshot(step, state)
            except PoolAccessError:
                failed_snaps += 1
                mon.record_pool_error(step)
            mon.pulse_all(step)
            mon.end_step(step)
    assert not mon.dead_ranks(), (
        f"transient pool faults killed live ranks: "
        f"{mon.dead_ranks()}")
    assert store.latest() == 11, (
        f"newest committed snapshot {store.latest()}, expected 11")
    restored, _meta = store.restore(state)
    np.testing.assert_allclose(restored["w"], state["w"])

    emit("resilience_pool_steps_lost", failed_snaps,
         "snapshots lost to a 4-step transient pool-error window "
         "(retries absorb every fault; strict zero gate)")
    assert failed_snaps == 0, (
        f"{failed_snaps} snapshots failed past {POOL_RETRIES} retries")
    emit("resilience_pool_retries", store.retried,
         "transient pool faults absorbed by snapshot retries "
         "(info: proves the window actually hit the store)")
    assert store.retried > 0, (
        "the error window never touched a snapshot - the retry claim "
        "was not exercised")
    runtime.clear_rank_liveness()


def _reconvergence(emit) -> None:
    grid = tuner.TuneGrid(primitives=("all_gather",),
                          sizes=(4 * MiB,), nranks=(4,),
                          slicing_factors=(4,),
                          allreduce_modes=("two_phase",))
    plan = tuner.generate_plan(grid)
    cell = ("all_gather", 4 * MiB, 4)
    original = plan.lookup(*[cell[0], cell[1], cell[2]]).backend
    ot = tuner.OnlineTuner(plan, alpha=0.5, min_samples=2,
                           decay=0.3, explore_eps=0.35,
                           explore_seed=1)
    rng = np.random.default_rng(0)

    def true_time(ch) -> float:
        return tuner.predict_time(ch.backend, cell[0], cell[2],
                                  cell[1],
                                  slicing_factor=ch.slicing_factor,
                                  allreduce_mode=ch.allreduce_mode)

    def play_round(degraded: bool) -> str:
        """One refresh interval: 3 measured samples of the current
        choice at the world's current price, then a refresh."""
        ch = ot.plan.lookup(*cell)
        for _ in range(3):
            t = true_time(ch)
            if degraded and ch.backend == "cxl":
                t *= RECONV_DEGRADE
            t *= float(np.clip(rng.normal(1.0, NOISE_STD), 0.8, 1.2))
            ledger.record_timing(cell[0], cell[1], cell[2],
                                 ch.backend, t,
                                 slicing_factor=ch.slicing_factor,
                                 allreduce_mode=ch.allreduce_mode)
        ot.observe_timings(ledger.snapshot()["timings"])
        ledger.reset()
        # adopt the refreshed plan as the next round's base - the
        # launcher's hot-swap semantics, minus the global registry
        ot.plan = ot.refresh()
        return ot.plan.lookup(*cell).backend

    ledger.reset()
    assert original == "cxl", (
        f"expected the pool to win the healthy cell, got {original}")
    for _ in range(2):                       # healthy warmup
        assert play_round(degraded=False) == original, (
            "tuner abandoned a healthy winner")
    flipped = False
    for _ in range(4):                       # transient 6x window
        if play_round(degraded=True) != original:
            flipped = True
    assert flipped, (
        f"{RECONV_DEGRADE}x measured slowdown never flipped the "
        f"choice - the recovery demo has nothing to demonstrate")
    back_at = None                           # healed: walk back
    for r in range(RECONVERGE_BOUND + 2):
        if play_round(degraded=False) == original:
            back_at = r + 1
            break
    assert back_at is not None, (
        f"tuner never re-converged to {original} after the heal "
        f"(decay={ot.decay}, explore_eps={ot.explore_eps})")
    emit("resilience_reconverge_steps", back_at,
         f"refreshes to walk back to {original} after a transient "
         f"{RECONV_DEGRADE}x window (EWMA decay {ot.decay} + "
         f"eps-greedy {ot.explore_eps}; bound {RECONVERGE_BOUND})")
    assert back_at <= RECONVERGE_BOUND
    emit("resilience_reconverged", 1,
         "choice returned to the pre-fault backend without a restart")
    tuner.clear_active_plan()


def run(emit, smoke: bool = False) -> None:
    del smoke  # the audit is already CI-sized
    _cleanup()

    overhead = _monitor_overhead_pct()
    for _ in range(2):
        # A genuinely heavy monitor reads high on every trial; a
        # loaded machine does not.  Re-measure before failing.
        if overhead < OVERHEAD_BOUND_PCT:
            break
        overhead = min(overhead, _monitor_overhead_pct())
    emit("resilience_monitor_overhead_pct", overhead,
         f"failure monitor on vs off, median of {OVERHEAD_REPEATS} "
         f"interleaved repeats (bound {OVERHEAD_BOUND_PCT}%; "
         f"info-only for the gate)")
    assert overhead < OVERHEAD_BOUND_PCT, (
        f"monitor overhead {overhead:.2f}% exceeds "
        f"{OVERHEAD_BOUND_PCT}%")

    with contextlib.ExitStack() as stack:
        stack.callback(_cleanup)
        _rank_death(emit)
        _link_failover(emit)
        _transient_pool(emit)
        _reconvergence(emit)
