"""Communication/compute overlap benchmark (core.overlap).

Models one FSDP training step per zoo config under the calibrated cost
oracles and compares two schedules built from the *same* leaf/spec
enumeration the trainer uses (``model.abstract_params`` +
``sharding.param_specs`` + ``core.overlap.assign_buckets``):

* **per-leaf baseline** - one collective per parameter leaf (forward
  AllGather, remat re-AllGather, grad ReduceScatter per FSDP leaf; one
  AllReduce per replicated leaf), every collective serialized against
  the compute that consumes it - the pre-overlap hot path.
* **bucketed + prefetch** - leaves fused into size-capped flat buckets
  (one collective per bucket) and layer ``l+1``'s gathers priced
  against the roofline residency of layer ``l``'s compute
  (``exposed = max(0, comm - overlappable)``), matching the
  double-buffered carry in ``model._run_groups``.

Also audits an *overlap-aware* autotuning plan on the Fig. 9 sweep:
with every candidate (fixed baselines included) priced by exposed time,
``auto`` must never be slower than the best fixed choice
(``overlap_autotune_max_regret <= 1``), and wires a traced (1,1)-mesh
train step through the real ledger to show the per-step collective
*call* count drop and the exposed-vs-hidden byte split.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro import tuner
from repro.configs import get_config
from repro.core import ledger, overlap
from repro.core.hw import MiB
from repro.core.schedule import PRIMITIVES
from repro.models import blocks, model, sharding

NRANKS = 8                 # FSDP ranks (every zoo dim divides 8)
# Comm/compute balance point: small local batch keeps FSDP traffic
# comparable to the matmul time (llm_case_study.py documents the same
# H100 constants for the Sec. 5.5 reproduction).
TOKENS_PER_RANK = 2 * 4096
H100_FLOPS = 990e12
MFU = 0.40
BYTES_PER_PARAM = 2        # bf16 shards on the wire
GRAD_BYTES = 4             # fp32 grad accumulators (train_loop zeros_g)

ZOO = ("llama3-8b", "yi-6b", "phi3-medium-14b", "deepseek-coder-33b",
       "llama3.2-1b")
SMOKE_ZOO = ("llama3-8b", "yi-6b", "llama3.2-1b")
BUCKET_SWEEP_MB = (1, 4, 25, 100)

FIG9_SIZES = [1 * MiB, 4 * MiB, 16 * MiB, 64 * MiB, 256 * MiB,
              1024 * MiB, 4096 * MiB]
FIG9_SMOKE_SIZES = [1 * MiB, 16 * MiB, 256 * MiB]
OVERLAP_WINDOW_S = 2e-3    # per-collective compute window for the audit


# --------------------------------------------------------------------- #
# collective pricing (best fixed backend per call, like the tuner sees)
# --------------------------------------------------------------------- #

def _price(prim: str, full_bytes: int) -> float:
    msg = max(1, full_bytes // NRANKS) if prim == "all_gather" \
        else max(1, full_bytes)
    t_ring = tuner.predict_time("ring", prim, NRANKS, msg)
    t_cxl = tuner.predict_time("cxl", prim, NRANKS, msg,
                               slicing_factor=4,
                               allreduce_mode="two_phase")
    return min(t_ring, t_cxl)


def _leaf_entries(tree, specs, axis):
    """(fsdp_entries, sync_entries): (index, shape, dtype, group_key)
    rows ready for overlap.assign_buckets, plus per-leaf byte lists."""
    leaves, treedef = jax.tree.flatten(tree)
    spec_leaves = treedef.flatten_up_to(specs)
    fsdp, syncs = [], []
    for i, (x, spec) in enumerate(zip(leaves, spec_leaves)):
        if overlap._axis_dim(spec, axis) is not None:
            fsdp.append((i, tuple(x.shape), x.dtype, ()))
        elif axis not in overlap._spec_axes(spec):
            syncs.append((i, tuple(x.shape), x.dtype, ()))
    return fsdp, syncs


def _entry_bytes(e, per_param: int) -> int:
    size = 1
    for d in e[1]:
        size *= d
    return size * per_param


def _bucket_sizes(entries, cap_bytes, per_param: int) -> list:
    """Fused-buffer byte sizes under a cap (None -> fully fused,
    cap<=0 -> per-leaf)."""
    out = []
    for b in overlap.assign_buckets(entries, cap_bytes):
        out.append(sum(_entry_bytes((s.index, s.shape, None, None),
                                    per_param)
                       for s in b.slots))
    return out


def _row_structure(cfg):
    """Per scan-group: (count, fsdp gather entries, row params, sync
    entries) from the same abstract tree + specs the trainer builds."""
    sharding.set_mesh_sizes({"data": NRANKS, "model": 1})
    abstract = model.abstract_params(cfg, tp=1)
    pspecs = sharding.param_specs(abstract, cfg, model_axis="model",
                                  dp_axis="data", fsdp=True)
    rspecs = sharding.row_specs(pspecs)
    groups = blocks.scan_groups(cfg)
    rows = []
    for gi, g in enumerate(groups):
        key = "shared_a" if g.shared else f"g{gi}"
        row = abstract[key] if g.shared else jax.tree.map(
            lambda x: jax.ShapeDtypeStruct(x.shape[1:], x.dtype),
            abstract[key])
        fsdp, _ = _leaf_entries(row, rspecs[key], "data")
        row_params = sum(int(np.prod(x.shape))
                         for x in jax.tree.leaves(row))
        rows.append((g.count, g.shared, fsdp, row_params))
    fsdp_embed, _ = _leaf_entries(abstract["embed"], pspecs["embed"],
                                  "data")
    _, sync_entries = _leaf_entries(abstract, pspecs, "data")
    return rows, fsdp_embed, sync_entries


def _step_model(cfg, gather_cap, sync_cap, prefetch: bool) -> dict:
    """Modeled step time + per-step collective count for one schedule.

    ``gather_cap``/``sync_cap`` follow ``overlap.assign_buckets``:
    None = fully fused (row FlatParameter / one sync buffer), positive =
    NCCL-style size cap, <= 0 = per-leaf."""
    rows, fsdp_embed, sync_entries = _row_structure(cfg)
    compute_fn = lambda flops: tuner.roofline_compute_time(
        flops, peak_flops=H100_FLOPS * MFU)

    comm = exposed = 0.0
    count = 0
    total_params = 0
    for n_layers, shared, fsdp, row_params in rows:
        total_params += row_params * (1 if shared else n_layers)
        sizes = _bucket_sizes(fsdp, gather_cap, BYTES_PER_PARAM)
        ag = sum(_price("all_gather", s) for s in sizes)
        rs = sum(_price("reduce_scatter", s) for s in sizes)
        # fwd AllGather + remat re-AllGather + grad ReduceScatter per
        # layer; a shared (single-param-set) group under prefetch hoists
        # to ONE gather whose AD transpose is one fused ReduceScatter.
        hoisted = shared and prefetch
        n_ag = 1 if hoisted else 2 * n_layers
        n_rs = 1 if hoisted else n_layers
        layer_comm = ag * n_ag + rs * n_rs
        comm += layer_comm
        count += (n_ag + n_rs) * len(sizes)
        # fwd window = 2*N*t flops, bwd window = 4*N*t (remat replay
        # included in compute either way); prefetch hides each gather /
        # scatter behind the roofline residency of the adjacent layer.
        w_fwd = compute_fn(2.0 * row_params * TOKENS_PER_RANK)
        w_bwd = compute_fn(4.0 * row_params * TOKENS_PER_RANK)
        if prefetch:
            if hoisted:
                exposed += max(0.0, ag - w_fwd) + max(0.0, rs - w_bwd)
            else:
                # n_layers fwd gathers total: the prologue (row 0) is
                # fully exposed, the n_layers-1 prefetched ones hide
                # behind the previous layer's fwd compute; remat
                # re-gathers and grad scatters hide behind bwd compute.
                exposed += ag \
                    + (n_layers - 1) * max(0.0, ag - w_fwd) \
                    + n_layers * (max(0.0, ag - w_bwd)
                                  + max(0.0, rs - w_bwd))
        else:
            exposed += layer_comm

    emb_sizes = _bucket_sizes(fsdp_embed, gather_cap, BYTES_PER_PARAM)
    emb = sum(_price("all_gather", s) + _price("reduce_scatter", s)
              for s in emb_sizes)
    comm += emb
    exposed += emb            # gathered once up front: exposed prologue
    count += 2 * len(emb_sizes)

    sync_sizes = _bucket_sizes(sync_entries, sync_cap, GRAD_BYTES)
    sync = sum(_price("all_reduce", s) for s in sync_sizes)
    comm += sync
    exposed += sync           # step-tail sync: conservatively exposed
    count += len(sync_sizes)

    compute = compute_fn(6.0 * total_params * TOKENS_PER_RANK)
    step = compute + (exposed if prefetch else comm)
    return {"step": step, "comm": comm, "exposed": exposed,
            "compute": compute, "count": count,
            "params": total_params}


# --------------------------------------------------------------------- #
# traced ledger: the real train step on a (1,1) mesh
# --------------------------------------------------------------------- #

def _traced_calls(arch: str, bucket_mb: float, prefetch: int) -> dict:
    """Lower (trace only) the real sharded train step of the smoke
    config and snapshot the trace-time ledger."""
    from repro.optim import AdamWState
    from repro.training.train_loop import (TrainConfig,
                                           make_sharded_train_step)
    cfg = get_config(arch, smoke=True)
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    tcfg = TrainConfig(warmup=0, clip_norm=None, remat=False,
                       bucket_mb=bucket_mb, prefetch=prefetch)
    ledger.reset()
    step, pspecs, bspecs, pc = make_sharded_train_step(cfg, tcfg, mesh)
    B, L = 2, 16
    sds = lambda s, d: jax.ShapeDtypeStruct(s, d)
    abstract = model.abstract_params(cfg, tp=1)
    opt = AdamWState(
        step=sds((), jnp.int32),
        mu=jax.tree.map(lambda x: sds(x.shape, jnp.float32), abstract),
        nu=jax.tree.map(lambda x: sds(x.shape, jnp.float32), abstract))
    batch = {"tokens": sds((B, L), jnp.int32),
             "labels": sds((B, L), jnp.int32)}
    step.lower(abstract, opt, batch)
    snap = ledger.snapshot()
    ledger.reset()
    return snap


# --------------------------------------------------------------------- #
# overlap-aware autotuning audit (Fig. 9 sweep)
# --------------------------------------------------------------------- #

def _overlap_regret(emit, smoke: bool) -> None:
    sizes = FIG9_SMOKE_SIZES if smoke else FIG9_SIZES
    nranks = (3,) if smoke else (3, 6, 12)
    factors = (1, 4) if smoke else (1, 2, 4, 8, 16)
    grid = tuner.TuneGrid(sizes=tuple(sizes), nranks=nranks,
                          slicing_factors=factors)
    plan = tuner.generate_plan(grid, overlap_compute=OVERLAP_WINDOW_S)
    max_regret = 0.0
    hidden_cells = 0
    for prim in PRIMITIVES:
        for n in nranks:
            for size in sizes:
                ch = plan.lookup(prim, size, n)
                assert ch.overlap, "overlap-aware plan must mark cells"
                t_ring = tuner.predict_exposed_time(
                    "ring", prim, n, size,
                    overlappable_compute=OVERLAP_WINDOW_S)
                t_cxl = tuner.predict_exposed_time(
                    "cxl", prim, n, size,
                    overlappable_compute=OVERLAP_WINDOW_S,
                    slicing_factor=4, allreduce_mode="two_phase")
                best_fixed = min(t_ring, t_cxl)
                if ch.predicted_time == 0.0:
                    hidden_cells += 1
                if best_fixed > 0:
                    max_regret = max(max_regret,
                                     ch.predicted_time / best_fixed)
                else:
                    assert ch.predicted_time == 0.0, (prim, size, n)
    total = len(PRIMITIVES) * len(nranks) * len(sizes)
    emit("overlap_autotune_max_regret", max_regret,
         "auto exposed vs best fixed exposed; must be <= 1")
    emit("overlap_autotune_fully_hidden_fraction", hidden_cells / total,
         f"cells fully hidden behind {OVERLAP_WINDOW_S * 1e3:.0f}ms "
         "compute")
    assert max_regret <= 1.0 + 1e-9, (
        f"overlap-aware auto slower than a fixed backend: {max_regret}")


# --------------------------------------------------------------------- #
# entry point
# --------------------------------------------------------------------- #

def run(emit, smoke: bool = False) -> None:
    zoo = SMOKE_ZOO if smoke else ZOO
    sync_cap = overlap.DEFAULT_BUCKET_BYTES

    wins = 0
    for arch in zoo:
        cfg = get_config(arch)
        base = _step_model(cfg, gather_cap=0, sync_cap=0,
                           prefetch=False)
        fused = _step_model(cfg, gather_cap=None, sync_cap=sync_cap,
                            prefetch=True)
        speedup = base["step"] / fused["step"]
        count_ratio = base["count"] / fused["count"]
        wins += speedup >= 1.2
        emit(f"overlap_{arch}_step_speedup", speedup,
             "bucketed+prefetch vs per-leaf serialized")
        emit(f"overlap_{arch}_collective_count_ratio", count_ratio,
             f"per-leaf {base['count']} -> bucketed {fused['count']} "
             "per step")
        emit(f"overlap_{arch}_exposed_comm_frac",
             fused["exposed"] / fused["comm"] if fused["comm"] else 0.0,
             "fraction of comm time left exposed after prefetch")
    emit("overlap_zoo_wins_ge_1p2x", wins,
         f"configs with >= 1.2x modeled step speedup (of {len(zoo)})")
    assert wins >= 3, (
        f"bucketed+prefetch must dominate >= 1.2x on >= 3 zoo configs, "
        f"got {wins}")

    # llama3-8b-class collective-count criterion (>= 5x drop)
    base = _step_model(get_config("llama3-8b"), gather_cap=0,
                       sync_cap=0, prefetch=False)
    fused = _step_model(get_config("llama3-8b"), gather_cap=None,
                        sync_cap=sync_cap, prefetch=True)
    ratio = base["count"] / fused["count"]
    emit("overlap_llama3_8b_count_drop", ratio,
         "modeled per-step collectives, per-leaf / bucketed")
    assert ratio >= 5.0, f"collective count must drop >= 5x: {ratio}"

    # bucket-size sweep (EXPERIMENTS.md table): gather-bucket cap from
    # NCCL-small up to row-fused (None)
    for mb in BUCKET_SWEEP_MB:
        r = _step_model(get_config("llama3-8b"), gather_cap=mb * MiB,
                        sync_cap=sync_cap, prefetch=True)
        emit(f"overlap_llama3_8b_bucket{mb}mb_speedup",
             base["step"] / r["step"],
             f"{r['count']} collectives/step at {mb} MiB buckets")
    emit("overlap_llama3_8b_bucket_row_speedup",
         base["step"] / fused["step"],
         f"{fused['count']} collectives/step, row-fused buckets")

    # real traced step: ledger call counts + exposed/hidden byte split
    per_leaf = _traced_calls("llama3-8b", bucket_mb=0.0, prefetch=0)
    fused_tr = _traced_calls("llama3-8b", bucket_mb=25.0, prefetch=1)
    emit("overlap_traced_calls_per_leaf",
         per_leaf["total_collective_calls"],
         "ledger collective launches/step, smoke cfg, per-leaf")
    emit("overlap_traced_calls_bucketed",
         fused_tr["total_collective_calls"],
         "ledger collective launches/step, smoke cfg, bucketed+prefetch")
    assert fused_tr["total_collective_calls"] < \
        per_leaf["total_collective_calls"]

    _overlap_regret(emit, smoke)
