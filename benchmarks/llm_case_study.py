"""Sec. 5.5: FSDP Llama-3-8B training over the CXL pool vs InfiniBand.

FSDP per step and per layer: AllGather(params) in forward, AllGather
(params) again in backward, ReduceScatter(grads).  We price each
collective with the calibrated simulator (CXL) / analytic model (IB),
add an H100 compute-time estimate (6*N*tokens at 40% MFU), and overlap a
fraction of communication with compute (FSDP prefetch).  Outputs the
step-time speedup (paper: 1.11x) and the interconnect cost ratio
(paper: 2.75x).
"""
from __future__ import annotations

from repro.configs import get_config
from repro.core import ibmodel, simulator
from repro.core.hw import COST

NRANKS = 3
# The paper does not state the per-GPU workload; batch 32 x 4096 at 40%
# MFU (a standard large-accumulation FSDP setup on 80 GB H100s with
# activation checkpointing) makes the compute/comm split land on the
# reported 1.11x - the communication-time ratio itself (CXL vs IB) is
# fully determined by the calibrated collective models.
TOKENS_PER_RANK = 32 * 4096
H100_FLOPS = 990e12
MFU = 0.40
OVERLAP = 0.0                       # fraction of comm hidden by compute
BYTES_PER_PARAM = 2                 # bf16 shards


def step_times() -> dict:
    cfg = get_config("llama3-8b")
    n_params = cfg.param_count()
    per_layer = n_params // cfg.n_layers
    msg = per_layer * BYTES_PER_PARAM          # per-rank message (Table 2)

    def comm_time(kind: str) -> dict:
        cxl = simulator.run_variant("all", kind, NRANKS, msg).total_time
        ib = ibmodel.estimate(kind, NRANKS, msg).time
        return {"cxl": cxl, "ib": ib}

    ag = comm_time("all_gather")
    rs = comm_time("reduce_scatter")
    # 2 gathers + 1 reduce-scatter per layer per step
    comm = {k: cfg.n_layers * (2 * ag[k] + rs[k]) for k in ("cxl", "ib")}

    compute = 6 * n_params * TOKENS_PER_RANK / (H100_FLOPS * MFU)
    step = {k: compute + max(0.0, comm[k] - OVERLAP * compute)
            for k in comm}
    return {"compute": compute, "comm": comm, "step": step,
            "speedup": step["ib"] / step["cxl"],
            "params": n_params}


def run(emit) -> None:
    r = step_times()
    emit("llm_params_B", r["params"] / 1e9, "Llama-3-8B")
    emit("llm_compute_s", r["compute"], "per step @40% MFU")
    emit("llm_comm_cxl_s", r["comm"]["cxl"], "FSDP collectives, CXL pool")
    emit("llm_comm_ib_s", r["comm"]["ib"], "FSDP collectives, IB-200")
    emit("llm_step_speedup", r["speedup"], "paper: 1.11x")
    emit("llm_cost_ratio", COST.cost_ratio, "paper: 2.75x")
