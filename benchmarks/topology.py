"""Hierarchical-topology audit: level-decomposed collectives vs the flat
per-level recursion, on a 3-level (2 pods x 2 nodes x 2 gpus) cluster
with distinct per-level fabrics (pod: IB, node: CXL pool, gpu: ICI).

The whole tune -> plan -> auto path runs for real: a per-level plan is
generated against each level's own fabric config (and written to
``bench-topology-plan.json`` as a CI artifact), then AllReduce and
Broadcast are traced through ``Communicator(backend='auto')`` on an
abstract 2x2x2 mesh - no devices needed, the trace-time ledger records
the wire bytes each level's fabric actually carries.

The headline claim: under hierarchical decomposition each byte crosses
the slow pod-spanning fabric once (at 1/prod(inner) of the payload),
so cross-pool wire bytes drop by ~prod(inner sizes) = 4x vs recursing
the flat algorithm per level.  ``topology_*_crosspool_ratio`` must be
> 1 for AllReduce and Broadcast; the audit also sums the plan's
predicted per-level times for both schedules.
"""
from __future__ import annotations

import json
import os

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro import tuner
from repro.core import ledger
from repro.core.api import Communicator
from repro.core.hw import (MiB, CXLPoolConfig, ICIConfig,
                           InfiniBandConfig)
from repro.core.topology import Level, Topology

AXES = ("pod", "node", "gpu")
SHAPE = ((("pod", 2), ("node", 2), ("gpu", 2)))
PLAN_ARTIFACT = os.environ.get("BENCH_TOPO_PLAN",
                               "bench-topology-plan.json")

TOPOLOGY = Topology(levels=(
    Level("pod", "ib", ib=InfiniBandConfig(link_bw=12.5e9)),
    Level("node", "cxl", pool=CXLPoolConfig(device_bw=18e9)),
    Level("gpu", "ici", ici=ICIConfig(link_bw=45e9)),
))


def _abstract_mesh():
    """AbstractMesh across jax versions (no devices needed to trace)."""
    from jax.sharding import AbstractMesh
    try:
        return AbstractMesh(SHAPE)
    except TypeError:
        pass
    try:   # newer signature: (axis_sizes, axis_names)
        return AbstractMesh(tuple(s for _, s in SHAPE), AXES)
    except TypeError:
        return AbstractMesh({a: s for a, s in SHAPE})


def _trace(mesh, fn, nbytes: int) -> dict:
    """Trace one collective program and return the ledger snapshot."""
    ledger.reset()
    x = jax.ShapeDtypeStruct((nbytes // 4, 1), jnp.float32)
    jax.eval_shape(jax.shard_map(fn, mesh=mesh, in_specs=P(AXES),
                                 out_specs=P(AXES), check_vma=False), x)
    return ledger.snapshot()


def _crosspool(snap: dict) -> float:
    lvl = snap.get("level_wire_bytes") or {}
    return float(sum((lvl.get("pod/ib") or {}).values()))


def _predicted_s(snap: dict) -> float:
    return float(sum(c["predicted_time"]
                     for c in snap.get("auto_choices") or []))


def run(emit, smoke: bool = False) -> None:
    grid = tuner.TuneGrid(
        sizes=tuple(m * MiB for m in (1, 16, 64)),
        nranks=(2,), slicing_factors=(1, 4))
    plan = tuner.generate_plan(grid, topology=TOPOLOGY)
    tuner.save_plan(plan, PLAN_ARTIFACT)
    emit("topology_plan_cells", len(plan.entries),
         f"3-level plan -> {PLAN_ARTIFACT} (CI artifact)")
    for lv in TOPOLOGY.levels:
        lkey = TOPOLOGY.level_key(lv.axis)
        cells = [c for k, c in plan.entries.items() if k[3] == lkey]
        frac = sum(c.backend == "cxl" for c in cells) / len(cells)
        emit(f"topology_level_{lv.axis}_cxl_fraction", frac,
             f"{lv.fabric} fabric, fp {lv.fingerprint()}")

    mesh = _abstract_mesh()
    comm = Communicator(backend="auto", plan=plan, topology=TOPOLOGY)
    size = (16 if smoke else 64) * MiB

    # hierarchical vs flat per-level recursion, real traces
    hier_ar = _trace(mesh, lambda a: comm.all_reduce(a, AXES), size)

    def flat_ar(a):
        for ax in AXES:      # the legacy schedule: full payload per level
            a = comm.all_reduce(a, ax)
        return a
    flat_ar_snap = _trace(mesh, flat_ar, size)

    hier_bc = _trace(mesh, lambda a: comm.broadcast(a, AXES, root=0),
                     size)

    def flat_bc(a):
        for ax in AXES:      # per-level root chain, full payload
            a = comm.broadcast(a, ax, root=0)
        return a
    flat_bc_snap = _trace(mesh, flat_bc, size)

    for prim, hier, flat in (("all_reduce", hier_ar, flat_ar_snap),
                             ("broadcast", hier_bc, flat_bc_snap)):
        xh, xf = _crosspool(hier), _crosspool(flat)
        ratio = xf / xh if xh else float("inf")
        emit(f"topology_{prim}_crosspool_bytes_hier", xh,
             "pod/ib wire bytes per rank, hierarchical")
        emit(f"topology_{prim}_crosspool_bytes_flat", xf,
             "pod/ib wire bytes per rank, flat per-level recursion")
        emit(f"topology_{prim}_crosspool_ratio", ratio,
             "flat/hier; each byte crosses the pool fabric once")
        assert ratio > 1.0 + 1e-9, (
            f"hierarchical {prim} does not reduce cross-pool bytes: "
            f"{xh} vs {xf}")
        th, tf = _predicted_s(hier), _predicted_s(flat)
        if th > 0:
            emit(f"topology_{prim}_predicted_speedup", tf / th,
                 "sum of per-level plan-predicted times, flat/hier")

    # every traced byte is attributed to a level/fabric
    tagged = sum(sum(v.values())
                 for v in hier_ar["level_wire_bytes"].values())
    emit("topology_ledger_level_coverage",
         tagged / hier_ar["total_wire_bytes"],
         "fraction of hierarchical-AR bytes attributed per level")

    if os.path.exists(PLAN_ARTIFACT):
        with open(PLAN_ARTIFACT) as f:
            doc = json.load(f)
        assert doc["version"] == 6 and doc["meta"].get("topology")
