"""Observability audit: tracing overhead + degraded-link detection.

Two claims, both CI-sized and deterministic (seeded noise):

1. **Tracing is cheap enough to leave on.**  A synthetic step loop
   (fixed numpy work + one ledger timing sample per collective) runs
   with the flight recorder off and on, interleaved; median-of-repeats
   wall times must differ by < ``OVERHEAD_BOUND_PCT``.  The tracer's
   hot path is tuple appends with formatting deferred to dump - the
   bound has an order of magnitude of headroom in practice.

2. **A degraded link is flagged within ``DETECT_BOUND`` steps.**  A
   2-level (pod:ib / node:cxl) topology runs an emulated training loop
   (``obs.StepEmulator`` pricing each audited collective with the
   level's own oracle + 3% noise).  At ``INJECT_STEP`` the cxl pool
   link degrades 4x; the ``HealthMonitor`` inside ``ObsSession`` must
   flag ``node/cxl`` degraded within ``DETECT_BOUND`` steps (and never
   before the injection), trigger a flight-recorder dump, and - once
   the slowdown is lifted - clear the flag.  The same samples feed an
   ``OnlineTuner``, whose learned (backend, level) calibration scale
   must converge near the injected 4x and be reported by
   ``obs.calibration_drift`` as a placement-recheck recommendation.

Artifacts (CI uploads): the metrics JSON-lines stream
(``bench-obs-metrics.jsonl`` + ``.prom``) and the flight-recorder
Chrome trace (``bench-obs-trace.json``), both path-overridable via
``BENCH_OBS_METRICS`` / ``BENCH_OBS_TRACE``.

Emitted metrics (asserted):
  obs_overhead_pct          < OVERHEAD_BOUND_PCT (info-only for the
                            regression gate: wall-clock noise across
                            CI machines, asserted in-bench instead)
  obs_detect_latency_steps  <= DETECT_BOUND  (gated lower-is-better)
  obs_calibration_scale     ~= DEGRADE_FACTOR (asserted in [3, 5])
  obs_recovered             == 1 (flag clears after the slowdown ends)
"""
from __future__ import annotations

import contextlib
import json
import os
import time

import numpy as np

from repro import tuner
from repro.core import ledger
from repro.core.hw import MiB
from repro.core.topology import parse_topology
from repro.obs import (ObsSession, StepEmulator, calibration_drift,
                       disable_tracing, enable_tracing)

METRICS_ARTIFACT = os.environ.get("BENCH_OBS_METRICS",
                                  "bench-obs-metrics.jsonl")
TRACE_ARTIFACT = os.environ.get("BENCH_OBS_TRACE",
                                "bench-obs-trace.json")

OVERHEAD_BOUND_PCT = 5.0
OVERHEAD_STEPS = 40
OVERHEAD_SAMPLES = 16     # timing samples per synthetic step
OVERHEAD_REPEATS = 7

DEGRADE_FACTOR = 4.0
INJECT_STEP = 12          # cxl link degrades here...
RECOVER_STEP = 20         # ...and heals here
STEPS = 30
DETECT_BOUND = 5          # flag within this many steps of injection
NOISE_STD = 0.03


def _overhead_pct() -> float:
    """Wall-time overhead (%) of tracing on vs off: interleaved off/on
    repeats, compared by median so machine-state drift between phases
    (turbo, caches, a co-scheduled benchmark) cancels instead of
    landing entirely on one side.  Both runs book identical ledger
    samples; only the enabled tracer (ring buffer + timing hook)
    differs.  The synthetic step is sized like a real smoke-train step
    (~1ms of compute): the tracer's cost is per *sample* (~1us), so
    quoting it against a microsecond-scale step would measure a
    workload no trainer has."""
    work = np.random.default_rng(0).standard_normal((256, 256))

    def run_once(traced: bool) -> float:
        tr = enable_tracing(capacity_steps=16) if traced else None
        t0 = time.perf_counter()
        acc = 0.0
        for i in range(OVERHEAD_STEPS):
            cm = tr.step(i) if traced else contextlib.nullcontext()
            with cm:
                acc += float(np.dot(work, work)[0, 0])   # the "step"
                acc += float(np.dot(work, work)[0, 0])
                for _ in range(OVERHEAD_SAMPLES):
                    ledger.record_timing(
                        "all_reduce", 1 << 20, 8, "cxl", 1e-3,
                        slicing_factor=4, allreduce_mode="two_phase",
                        level="node", fabric="cxl")
            ledger.clear_timings()
        dt = time.perf_counter() - t0
        if traced:
            disable_tracing()
        assert acc != 0.0
        return dt

    run_once(False)                                      # warm caches
    run_once(True)
    offs, ons = [], []
    for _ in range(OVERHEAD_REPEATS):
        offs.append(run_once(False))
        ons.append(run_once(True))
    off = float(np.median(offs))
    on = float(np.median(ons))
    return max(0.0, (on - off) / off * 100.0)


def run(emit, smoke: bool = False) -> None:
    del smoke  # the audit is already CI-sized

    overhead = _overhead_pct()
    for _ in range(2):
        # A genuinely slow tracer reads high on every trial; a loaded
        # machine does not.  Re-measure before failing the bound.
        if overhead < OVERHEAD_BOUND_PCT:
            break
        overhead = min(overhead, _overhead_pct())
    emit("obs_overhead_pct", overhead,
         f"flight-recorder on vs off, median of {OVERHEAD_REPEATS} "
         f"interleaved repeats (bound {OVERHEAD_BOUND_PCT}%; info-only "
         f"for the gate)")
    assert overhead < OVERHEAD_BOUND_PCT, (
        f"tracing overhead {overhead:.2f}% exceeds "
        f"{OVERHEAD_BOUND_PCT}%")

    # -- degraded-link detection ------------------------------------------
    topo = parse_topology("pod:ib,node:cxl")
    plan = tuner.generate_plan(
        tuner.TuneGrid(primitives=("all_gather", "reduce_scatter"),
                       sizes=(1 * MiB, 4 * MiB), nranks=(4,),
                       slicing_factors=(4,),
                       allreduce_modes=("two_phase",)),
        topology=topo)
    # the per-step collective profile an auto-backend step would audit
    profile = [
        {"primitive": "all_gather", "msg_bytes": 4 * MiB, "nranks": 4,
         "backend": "cxl", "slicing_factor": 4,
         "allreduce_mode": "two_phase", "level": "node",
         "fabric": "cxl", "calls": 2.0},
        {"primitive": "reduce_scatter", "msg_bytes": 4 * MiB,
         "nranks": 4, "backend": "cxl", "slicing_factor": 4,
         "allreduce_mode": "two_phase", "level": "node",
         "fabric": "cxl", "calls": 1.0},
        {"primitive": "all_reduce", "msg_bytes": 1 * MiB, "nranks": 2,
         "backend": "ring", "slicing_factor": 4,
         "allreduce_mode": "two_phase", "level": "pod", "fabric": "ib",
         "calls": 1.0},
    ]
    emu = StepEmulator(topology=topo, noise_std=NOISE_STD, seed=0)
    ot = tuner.OnlineTuner(plan, alpha=0.5, min_samples=2)
    sess = ObsSession(metrics_out=METRICS_ARTIFACT,
                      trace_out=TRACE_ARTIFACT, trace_steps=12,
                      log=lambda *_: None)
    ledger.reset()
    detect_step = None
    recovered = False
    for step in range(STEPS):
        if step == INJECT_STEP:
            emu.set_degrade("node", DEGRADE_FACTOR)
        if step == RECOVER_STEP:
            emu.set_degrade("node", 1.0)
        with sess.step_span(step):
            samples = emu.step_timings(profile)   # books into ledger
            ot.observe_timings(samples)
        wall = sum(t["seconds"] * t["calls"] for t in samples) + 1e-3
        for ev in sess.on_step(step, wall, timings=samples):
            assert ev["link"] == "node/cxl", (
                f"wrong link flagged: {ev}")
            if ev["event"] == "degraded":
                assert detect_step is None, "flagged twice"
                detect_step = ev["step"]
            elif ev["event"] == "recovered":
                recovered = True
        ledger.clear_timings()
    summary = sess.finalize(snapshot=ledger.snapshot())
    tuner.clear_active_plan()

    assert detect_step is not None, "degraded link never flagged"
    assert detect_step >= INJECT_STEP, (
        f"false positive: flagged at step {detect_step}, before the "
        f"injection at {INJECT_STEP}")
    latency = detect_step - INJECT_STEP + 1
    emit("obs_detect_latency_steps", latency,
         f"steps from {DEGRADE_FACTOR}x cxl-link slowdown to the "
         f"degraded flag (bound {DETECT_BOUND})")
    assert latency <= DETECT_BOUND, (
        f"detection took {latency} steps (> {DETECT_BOUND})")
    emit("obs_recovered", int(recovered),
         "flag cleared after the slowdown was lifted")
    assert recovered, "link never recovered after the slowdown ended"
    assert summary["degraded_links"] == [], (
        f"links still flagged at exit: {summary['degraded_links']}")

    # the same samples taught the tuner a (backend, level) calibration
    # scale near the injected slowdown - while it was active, pricing
    # corrected the oracle everywhere on that fabric.  The EWMA decays
    # back toward 1.0 after recovery, so check the scale the tuner had
    # learned by the recovery boundary via the drift report from the
    # still-degraded window persisted in the refreshed plan.
    cal = ot.calibration_export()
    cxl_scales = [e for e in cal["levels"] if e["backend"] == "cxl"]
    assert cxl_scales, "no cxl calibration learned"

    # re-run the learning window only (deterministic) to read the
    # scale at its degraded peak
    emu2 = StepEmulator(topology=topo, noise_std=NOISE_STD, seed=0,
                        degrade={"node": DEGRADE_FACTOR})
    ot2 = tuner.OnlineTuner(plan, alpha=0.5, min_samples=2)
    for _ in range(8):
        ot2.observe_timings(emu2.step_timings(profile, book=False))
    peak = ot2.calibration_export()
    peak_cxl = [e for e in peak["levels"] if e["backend"] == "cxl"]
    scale = peak_cxl[0]["scale"]
    emit("obs_calibration_scale", scale,
         f"learned cxl measured/oracle scale under the "
         f"{DEGRADE_FACTOR}x slowdown")
    assert 3.0 <= scale <= 5.0, (
        f"calibration scale {scale:.2f} not near the injected "
        f"{DEGRADE_FACTOR}x")
    drift = calibration_drift(peak, threshold=1.5)
    assert any(d["backend"] == "cxl" for d in drift), (
        "calibration_drift did not recommend a placement re-check")
    tuner.clear_active_plan()

    # -- artifact sanity --------------------------------------------------
    with open(METRICS_ARTIFACT) as f:
        lines = [json.loads(ln) for ln in f if ln.strip()]
    kinds = {ln["kind"] for ln in lines}
    assert {"step", "health", "metric", "summary"} <= kinds, kinds
    emit("obs_metric_lines", len(lines),
         f"JSON-lines events in {METRICS_ARTIFACT} (CI artifact)")
    with open(TRACE_ARTIFACT) as f:
        doc = json.load(f)
    n_coll = sum(1 for e in doc["traceEvents"]
                 if e.get("cat") == "collective")
    assert doc["metadata"]["anomalies"], "no anomaly mark in the trace"
    assert n_coll > 0, "no collective slices in the flight recorder"
    emit("obs_trace_collectives", n_coll,
         f"collective slices in {TRACE_ARTIFACT} (CI artifact)")
