"""Autotuner audit: predicted speedup of ``backend='auto'`` over the
fixed backends across the Fig. 9 sweep.

For every (primitive, size) cell at 3 nodes (plus 6/12 in the full run)
we compare the plan's chosen configuration against fixed-``ring`` (the
NCCL-over-IB baseline) and fixed-``cxl`` at the Communicator's default
knobs (slicing_factor=4, two_phase).  Because the tuning grid contains
both fixed configurations as candidates, ``auto`` can never be slower
than the better of the two under the cost model - the emitted
``autotune_max_regret`` must be <= 1.
"""
from __future__ import annotations

import numpy as np

from repro.core import mesh_collectives as mc
from repro.core.hw import MiB
from repro.core.schedule import PRIMITIVES
from repro import tuner

SIZES = [1 * MiB, 4 * MiB, 16 * MiB, 64 * MiB, 256 * MiB, 1024 * MiB,
         4096 * MiB]
SMOKE_SIZES = [1 * MiB, 16 * MiB, 256 * MiB]


def run(emit, smoke: bool = False) -> None:
    sizes = SMOKE_SIZES if smoke else SIZES
    nranks = (3,) if smoke else (3, 6, 12)
    factors = (1, 4) if smoke else (1, 2, 4, 8, 16)
    grid = tuner.TuneGrid(sizes=tuple(sizes), nranks=nranks,
                          slicing_factors=factors)
    plan = tuner.generate_plan(grid)

    max_regret = 0.0
    cxl_cells = 0
    for prim in PRIMITIVES:
        sp_ring, sp_cxl, sp_best = [], [], []
        for n in nranks:
            for size in sizes:
                choice = plan.lookup(prim, size, n)
                t_auto = choice.predicted_time
                t_ring = tuner.predict_time("ring", prim, n, size)
                t_cxl = tuner.predict_time(
                    "cxl", prim, n, size,
                    slicing_factor=mc.DEFAULT_CHUNKS,
                    allreduce_mode="two_phase")
                if choice.backend == "cxl":
                    cxl_cells += 1
                sp_ring.append(t_ring / t_auto)
                sp_cxl.append(t_cxl / t_auto)
                best_fixed = min(t_ring, t_cxl)
                sp_best.append(best_fixed / t_auto)
                max_regret = max(max_regret, t_auto / best_fixed)
        emit(f"autotune_{prim}_speedup_vs_ring",
             float(np.mean(sp_ring)), "auto vs fixed-ring (IB)")
        emit(f"autotune_{prim}_speedup_vs_cxl",
             float(np.mean(sp_cxl)), "auto vs fixed-cxl (factor 4)")
        emit(f"autotune_{prim}_speedup_vs_best_fixed",
             float(np.mean(sp_best)), "auto vs per-cell best fixed")
    total = len(PRIMITIVES) * len(nranks) * len(sizes)
    emit("autotune_max_regret", max_regret,
         "max t_auto/best_fixed; must be <= 1")
    emit("autotune_cxl_cell_fraction", cxl_cells / total,
         "fraction of cells where the plan picks cxl")
    assert max_regret <= 1.0 + 1e-9, (
        f"auto slower than a fixed backend somewhere: {max_regret}")
