"""Pipeline-parallelism audit: does adding a pipeline axis (activations
hopping stages over tuned CXL/IB point-to-point) beat FSDP-only at a
fixed device count, and does the plan actually carry per-level ``p2p``
cells for the hops to resolve against?

Setup: a 32-device 3-level cluster - (pod: slow 2.5 GB/s IB) /
(node: CXL pool, modest 10 GB/s intra-node IB alternative) /
(gpu: fast ICI) - the DFabric-style hybrid where the rack-scale pool is
the fast path between nodes.  Two layouts at the same 32 devices:

* **FSDP-only**: one 32-way data axis split across all three levels;
  every layer's parameter AllGather + gradient ReduceScatter crosses
  the slow pod uplinks.
* **PP x TP x FSDP**: 4 stages x 4-way TP x 2-way FSDP.  A rank owns
  1/4 of the layer stack, so per-layer FSDP/TP traffic shrinks 4x and
  the only new cost is the stage handoff - ``2M`` microbatch-activation
  p2p hops priced by the tuned p2p cells - plus the 1F1B bubble
  ``(S-1)/(M+S-1)`` stretching compute.

Step time = roofline compute (bubble-stretched under PP) + the
placement planner's predicted exposed communication for the *best*
axis->level assignment of each layout, so both sides get their
strongest placement (``tuner.placement``, which prices the p2p axis
through ``predict_level_p2p_time``).

Claims audited:

* ``pipeline_arctic_speedup`` / ``pipeline_deepseek_speedup``: the
  PP x TP x FSDP step beats FSDP-only on arctic-480b (MoE) and
  deepseek-coder-33b (dense) at 32 devices.
* ``pipeline_p2p_cell_coverage``: a topology sweep yields a resolvable
  ``p2p`` plan cell for every (size bucket, level) the handoff can
  land on - and the choice is size/fabric-dependent (cxl pool-write
  wins the large buckets on the pool level, the direct ring hop keeps
  the latency-bound small ones: ``pipeline_p2p_cxl_cells`` > 0).
* ``pipeline_bubble_interleaved_gain``: the interleaved schedule's
  bubble fraction improves on 1F1B's by ~v at the benchmark shape.
"""
from __future__ import annotations

from repro.configs import get_config
from repro.core.hw import CXLPoolConfig, ICIConfig, InfiniBandConfig
from repro.core.topology import Level, Topology
from repro.training import pipeline as pp
from repro.tuner import costmodel
from repro.tuner import placement as pl
from repro.tuner import sweep

POD_IB = InfiniBandConfig(link_bw=2.5e9)
NODE_POOL = CXLPoolConfig(device_bw=18e9)
NODE_IB = InfiniBandConfig(link_bw=10e9)   # the pool's intra-node rival
GPU_ICI = ICIConfig(link_bw=45e9)

TOPO = Topology(levels=(
    Level("pod", "ib", ib=POD_IB, shape=(2,)),
    Level("node", "cxl", pool=NODE_POOL, ib=NODE_IB, shape=(4,)),
    Level("gpu", "ici", ici=GPU_ICI, shape=(4,)),
))

N_DEV = 32
SEQ = 4096
GLOBAL_BATCH = 64
STAGES, TP, FSDP = 4, 4, 2
MICROBATCHES = 16


def _step_time(cfg, axes: dict, *, pp_axis=None,
               microbatches: int = MICROBATCHES):
    """(compute_s, exposed_comm_s, total_s) for one layout.  Compute is
    the per-device roofline residency of the step's matmul FLOPs (equal
    for every layout at fixed device count), stretched by the 1F1B
    bubble when a pipeline axis is present; comm is the placement
    planner's exposed time for the layout's best axis->level
    assignment."""
    dp = axes.get("data", 1)
    bpr = max(1, GLOBAL_BATCH // max(1, dp))
    flops_dev = 6.0 * cfg.param_count() * GLOBAL_BATCH * SEQ / N_DEV
    compute = costmodel.roofline_compute_time(flops_dev)
    if pp_axis:
        bub = pp.bubble_fraction(axes[pp_axis], microbatches, "1f1b")
        compute = compute / (1.0 - bub)
    mix = pl.CollectiveMix.for_model(
        cfg, axes, seq=SEQ, batch_per_rank=bpr,
        pp_axis=pp_axis, microbatches=microbatches)
    plan = pl.plan_placement(mix, TOPO)
    comm = plan.best.predicted_exposed_s
    return compute, comm, compute + comm, plan.best


def run(emit, smoke: bool = False) -> None:
    # -- PP x TP x FSDP vs FSDP-only at 32 devices ------------------------
    for key, arch in (("arctic", "arctic-480b"),
                      ("deepseek", "deepseek-coder-33b")):
        cfg = get_config(arch)
        _, comm_f, fsdp_only, best_f = _step_time(cfg, {"data": N_DEV})
        comp_p, comm_p, pipe, best_p = _step_time(
            cfg, {"stage": STAGES, "model": TP, "data": FSDP},
            pp_axis="stage")
        emit(f"pipeline_{key}_fsdp_only_s", fsdp_only,
             f"32-way FSDP: {best_f.describe()} "
             f"(exposed comm {comm_f:.1f}s)")
        emit(f"pipeline_{key}_pp_tp_fsdp_s", pipe,
             f"{STAGES}pp x {TP}tp x {FSDP}dp, M={MICROBATCHES}: "
             f"{best_p.describe()} (exposed comm {comm_p:.1f}s, "
             f"bubble-stretched compute {comp_p:.1f}s)")
        emit(f"pipeline_{key}_speedup", fsdp_only / pipe,
             "FSDP-only step / PP x TP x FSDP step at 32 devices")
        assert pipe < fsdp_only, (arch, pipe, fsdp_only)

    # -- the p2p cells the handoff resolves against -----------------------
    grid = sweep.TuneGrid(sizes=(4096, 262144, 16 << 20),
                          nranks=(2, 4), slicing_factors=(1, 4, 8))
    plan = sweep.generate_plan(grid, topology=TOPO)
    total = resolved = cxl_cells = 0
    for level in TOPO.levels:
        lkey = TOPO.level_key(level.axis)
        n = sum(level.shape)
        for size in grid.sizes:
            total += 1
            ch = plan.lookup("p2p", size, n, level=lkey)
            if ch is None:
                continue
            resolved += 1
            if ch.backend == "cxl":
                cxl_cells += 1
    emit("pipeline_p2p_cell_coverage", resolved / total,
         f"{resolved}/{total} (size bucket, level) p2p lookups "
         f"resolve in the v{plan.to_json()['version']} plan")
    emit("pipeline_p2p_cxl_cells", float(cxl_cells),
         "p2p cells where the pool write + doorbell beats the "
         "direct ring hop (pool level, large buckets)")
    assert resolved == total, (resolved, total)
    assert cxl_cells > 0, "no p2p cell ever chose the cxl pool path"

    # -- schedule accounting ----------------------------------------------
    b1 = pp.bubble_fraction(STAGES, MICROBATCHES, "1f1b")
    b2 = pp.bubble_fraction(STAGES, MICROBATCHES, "interleaved",
                            n_chunks=2)
    emit("pipeline_bubble_interleaved_gain", b1 / b2,
         f"1F1B bubble {b1:.3f} / interleaved(v=2) {b2:.3f} at "
         f"S={STAGES}, M={MICROBATCHES}")
    assert b2 < b1
    span = pp.simulate(pp.make_schedule("1f1b", STAGES, MICROBATCHES))
    emit("pipeline_1f1b_span_ticks", float(span),
         f"greedy simulation matches the closed form "
         f"2M+2(S-1)={2 * MICROBATCHES + 2 * (STAGES - 1)}")
    assert span == 2 * MICROBATCHES + 2 * (STAGES - 1)
