"""Online re-tuning audit: convergence under a mis-calibrated oracle.

Setup: the pool oracle is deliberately wrong by 4x (device and server
bandwidth believed 4x higher than reality), so the offline plan routes
small scatter / all_gather / reduce_scatter cells to ``cxl`` where the
*true* winner is ``ring``.  The run then emulates a training loop: each
step executes every cell's currently-planned choice, the "hardware"
(the truthfully-calibrated oracle + deterministic noise) returns its
wall time, the sample lands in the ledger timing capture
(``ledger.record_timing``), and the ``OnlineTuner`` folds the samples
into the plan and hot-swaps it through the epoch-versioned registry at
every ``RETUNE_INTERVAL`` boundary.

The measured EWMA of the chosen candidate overrides the oracle once
``MIN_SAMPLES`` samples land, so a wrongly chosen backend is priced by
reality while the alternatives keep their (optimistic) oracle price -
the argmin walks through the optimistic candidates, measuring each,
until the measured-fastest survives.  Worst case that takes
(#candidates) retune intervals per cell; with ring + cxl@{1,4} that is
3 intervals, and the audit asserts full convergence by
``CONVERGE_BOUND`` steps.  The refined format-v4 plan is written to
``bench-retune-plan.json`` (uploaded as a CI artifact).

Emitted metrics (asserted):
A cell counts as *wrong* when its chosen candidate's true time exceeds
the true per-cell optimum by more than ``WRONG_MARGIN`` (2x the
measurement noise std): near-tie cells (e.g. reduce_scatter at 3 ranks
/ 1 MiB, where ring beats cxl by 1%) are genuinely indistinguishable
under noisy measurement, and either choice is within the noise floor
of optimal - converging "to the measured winner" means converging to
within measurement noise.

Emitted metrics (asserted):
  retune_wrong_cells_initial   > 0   (miscalibration flips choices)
  retune_wrong_cells_final     == 0  (feedback corrects all of them)
  retune_converged_step        <= CONVERGE_BOUND
  retune_regret_final_us       <= 20% of retune_regret_initial_us
                               (per-step true regret collapses)
"""
from __future__ import annotations

import dataclasses
import json
import os

import numpy as np

from repro import tuner
from repro.core import ledger
from repro.core.hw import CXL_POOL, MiB

PLAN_ARTIFACT = os.environ.get("BENCH_RETUNE_PLAN",
                               "bench-retune-plan.json")

# Cells chosen so the true winner is ring at small sizes (scatter,
# 2-rank all_gather, reduce_scatter) while a 4x-optimistic pool oracle
# prices cxl under ring everywhere.
GRID = tuner.TuneGrid(
    primitives=("scatter", "all_gather", "reduce_scatter"),
    sizes=(1 * MiB, 4 * MiB), nranks=(2, 3),
    slicing_factors=(1, 4), allreduce_modes=("two_phase",))

MISCAL_FACTOR = 4.0
RETUNE_INTERVAL = 5
MIN_SAMPLES = 3
EWMA_ALPHA = 0.5
STEPS = 60
# ring + cxl@{1,4} = 3 candidates; each needs one interval of samples
# before its measured cost can dethrone it, plus one settling interval.
CONVERGE_BOUND = (3 + 1) * RETUNE_INTERVAL
NOISE_STD = 0.03
WRONG_MARGIN = 2 * NOISE_STD   # within-noise choices are not "wrong"


def _true_time(prim: str, n: int, size: int, backend: str, factor: int,
               mode: str) -> float:
    """Ground truth: the honestly-calibrated oracle."""
    return tuner.predict_time(backend, prim, n, size,
                              slicing_factor=factor, allreduce_mode=mode)


def _true_best(prim: str, n: int, size: int) -> tuple:
    """(backend, factor, mode, time) of the true per-cell winner over
    the same candidate set the tuner sweeps."""
    best = None
    for f in GRID.slicing_factors:
        t = _true_time(prim, n, size, "cxl", f, "two_phase")
        if best is None or t < best[3]:
            best = ("cxl", f, "two_phase", t)
    t = _true_time(prim, n, size, "ring", 4, "two_phase")
    if t < best[3]:
        best = ("ring", 4, "two_phase", t)
    return best


def run(emit, smoke: bool = False) -> None:
    del smoke  # the audit is already CI-sized
    miscal = dataclasses.replace(
        CXL_POOL, device_bw=CXL_POOL.device_bw * MISCAL_FACTOR,
        server_bw=CXL_POOL.server_bw * MISCAL_FACTOR)
    plan = tuner.generate_plan(GRID, pool=miscal)
    cells = [(p, n, s) for p in GRID.primitives for n in GRID.nranks
             for s in GRID.sizes]
    truth = {c: _true_best(*c) for c in cells}

    def wrong_cells(p: tuner.Plan) -> int:
        wrong = 0
        for prim, n, size in cells:
            ch = p.lookup(prim, size, n)
            t = _true_time(prim, n, size, ch.backend,
                           ch.slicing_factor, ch.allreduce_mode)
            if t > truth[(prim, n, size)][3] * (1.0 + WRONG_MARGIN):
                wrong += 1
        return wrong

    wrong0 = wrong_cells(plan)
    emit("retune_wrong_cells_initial", wrong0,
         f"cells mis-routed by the {MISCAL_FACTOR}x-optimistic oracle "
         f"(of {len(cells)})")
    assert wrong0 > 0, "miscalibrated oracle flipped no cells - the " \
        "convergence demo has nothing to demonstrate"

    ot = tuner.OnlineTuner(plan, alpha=EWMA_ALPHA,
                           min_samples=MIN_SAMPLES,
                           retune_interval=RETUNE_INTERVAL, pool=miscal)
    epoch0 = tuner.plan_epoch()
    rng = np.random.default_rng(0)
    regret = []
    last_wrong_step = -1
    for step in range(STEPS):
        ledger.reset()
        step_regret = 0.0
        for prim, n, size in cells:
            ch = ot.plan.lookup(prim, size, n)
            t_true = _true_time(prim, n, size, ch.backend,
                                ch.slicing_factor, ch.allreduce_mode)
            measured = t_true * float(
                np.clip(rng.normal(1.0, NOISE_STD), 0.8, 1.2))
            # the ledger timing hook is the same capture path the
            # launchers use - observe via its samples, not directly
            ledger.record_timing(prim, size, n, ch.backend, measured,
                                 slicing_factor=ch.slicing_factor,
                                 allreduce_mode=ch.allreduce_mode)
            # regret of the *choice* (true times, noise-free): what the
            # plan costs per step vs the true per-cell optimum
            step_regret += t_true - truth[(prim, n, size)][3]
        ot.observe_timings(ledger.snapshot()["timings"])
        regret.append(step_regret)
        if wrong_cells(ot.plan) > 0:
            last_wrong_step = step
        ot.maybe_retune(step)
    epochs = tuner.plan_epoch() - epoch0
    tuner.clear_active_plan()

    converged_step = last_wrong_step + 1
    emit("retune_converged_step", converged_step,
         f"steps until auto matches the measured winner everywhere "
         f"(bound {CONVERGE_BOUND})")
    assert converged_step <= CONVERGE_BOUND, (
        f"online re-tuning did not converge within {CONVERGE_BOUND} "
        f"steps (last wrong at step {last_wrong_step})")
    wrong_final = wrong_cells(ot.plan)
    emit("retune_wrong_cells_final", wrong_final,
         "mis-routed cells after convergence")
    assert wrong_final == 0

    head = float(np.mean(regret[:RETUNE_INTERVAL]))
    tail = float(np.mean(regret[-RETUNE_INTERVAL:]))
    emit("retune_regret_initial_us", head * 1e6,
         "mean per-step true regret, first retune interval")
    emit("retune_regret_final_us", tail * 1e6,
         "mean per-step true regret, last retune interval")
    assert tail <= 0.2 * head, (
        f"regret did not collapse: first {head:.2e}s vs last "
        f"{tail:.2e}s")
    emit("retune_plan_epochs", epochs,
         "active-plan registry hot-swaps published during the run")

    refined = ot.plan
    tuner.save_plan(refined, PLAN_ARTIFACT)
    measured_cells = sum(c.sample_count >= MIN_SAMPLES
                         for c in refined.entries.values())
    emit("retune_measured_cells", measured_cells,
         f"v4 cells with >= {MIN_SAMPLES} samples -> {PLAN_ARTIFACT} "
         f"(CI artifact)")
    with open(PLAN_ARTIFACT) as f:
        doc = json.load(f)
    assert doc["version"] == 6
    assert any(e.get("sample_count", 0) >= MIN_SAMPLES
               for e in doc["entries"])
