"""Fused collective+compute kernel benchmark
(kernels.fused_collectives, EXPERIMENTS.md Sec. Fused kernels).

Three readouts:

* **Per-op modeled deltas** - for each fusable primitive
  (reduce_scatter with an rmsnorm/AdamW epilogue, all_gather feeding
  the consuming matmul) the unfused composition pays the collective,
  then the epilogue, then the epilogue's HBM round-trip on the payload;
  the fused kernel runs the epilogue in-register while the transfer
  streams, so its cost is ``max(wire, epilogue)``.  Both sides are
  priced by the same offline oracles the tuner uses
  (``costmodel.predict_time`` / ``roofline_compute_time``), so the
  speedups are deterministic and CI-gateable.
* **Plan audit** - a window-free smoke sweep must resolve every
  reduce_scatter/all_gather cell to its fused variant (the epilogue
  window strictly widens what the transfer can hide behind), and plan
  lookups must surface ``fused=True`` to ``backend='auto'``.
* **Interpret-mode wall times** - the real Pallas kernels against
  their unfused jnp compositions on tiny shapes, informational only
  (``*_wall_s``): CPU interpret mode measures dispatch overhead, not
  kernel quality, but catches gross pathologies.
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro import tuner
from repro.core.hw import MiB
from repro.kernels import ops, ref
from repro.tuner import costmodel

NRANKS = 8
# Same H100 constants as benchmarks/overlap.py / llm_case_study.py.
H100_FLOPS = 990e12
H100_HBM_BW = 3.35e12
MFU = 0.40
TOKENS_PER_RANK = 2 * 4096
SIZES_MB = (4, 64, 1024)
SMOKE_SIZES_MB = (4, 64)


def _wire(prim: str, msg_bytes: int) -> float:
    """Best fixed-backend oracle time, like the tuner's argmin sees."""
    t_ring = tuner.predict_time("ring", prim, NRANKS, msg_bytes)
    t_cxl = tuner.predict_time("cxl", prim, NRANKS, msg_bytes,
                               slicing_factor=4,
                               allreduce_mode="two_phase")
    return min(t_ring, t_cxl)


def _epilogue_time(prim: str, msg_bytes: int) -> float:
    """Roofline residency of the epilogue the fusion absorbs."""
    return costmodel.roofline_compute_time(
        costmodel.epilogue_flops(prim, msg_bytes),
        peak_flops=H100_FLOPS * MFU, hbm_bw=H100_HBM_BW)


def _hbm_round_trip(msg_bytes: int) -> float:
    """The unfused composition's extra HBM traffic: the collective
    writes its output and the epilogue reads it straight back."""
    return costmodel.roofline_compute_time(
        0.0, 2.0 * msg_bytes, peak_flops=H100_FLOPS * MFU,
        hbm_bw=H100_HBM_BW)


def _op_speedup(prim: str, msg_bytes: int) -> float:
    wire = _wire(prim, msg_bytes)
    epi = _epilogue_time(prim, msg_bytes)
    unfused = wire + epi + _hbm_round_trip(msg_bytes)
    fused = max(wire, epi)
    return unfused / fused if fused > 0 else 1.0


# --------------------------------------------------------------------- #
# plan audit: fusion as a tuner candidate
# --------------------------------------------------------------------- #

def _plan_audit(emit, smoke: bool) -> None:
    sizes = tuple(m * MiB for m in ((1, 16) if smoke else (1, 16, 256)))
    grid = tuner.TuneGrid(sizes=sizes, nranks=(2, 3),
                          slicing_factors=(1, 4))
    # window-free sweep: exposed == wire time, so the fused variant's
    # widened window strictly beats unfused in every RS/AG cell.  (A
    # large constant window can fully hide small cells, where fused
    # merely *ties* and the argmin keeps the unfused candidate.)
    plan = tuner.generate_plan(grid)
    fusable = total = 0
    for (prim, _b, _n), ch in plan.entries.items():
        if prim in ("reduce_scatter", "all_gather"):
            total += 1
            fusable += bool(ch.fused)
        else:
            assert not ch.fused, (prim, ch)
    emit("fusion_plan_fused_cell_fraction",
         fusable / total if total else 0.0,
         f"{fusable}/{total} RS/AG cells resolved fused")
    assert total and fusable == total, (
        "the fused variant must win every RS/AG cell: its window "
        f"strictly widens the unfused one ({fusable}/{total})")
    # lookups surface the verdict to backend='auto'
    ch = plan.lookup("reduce_scatter", 16 * MiB, 2)
    assert ch.fused, ch
    # v5 round-trip keeps it
    again = tuner.Plan.from_json(plan.to_json())
    assert again.lookup("reduce_scatter", 16 * MiB, 2).fused


# --------------------------------------------------------------------- #
# interpret-mode wall times (informational)
# --------------------------------------------------------------------- #

def _timed(fn, *args) -> float:
    jax.block_until_ready(fn(*args))          # compile + warm
    t0 = time.time()
    jax.block_until_ready(fn(*args))
    return time.time() - t0


def _measured(emit) -> None:
    rng = np.random.default_rng(0)
    n, t, d = 4, 128, 256
    shards = jnp.asarray(rng.normal(size=(n, t, d)), jnp.float32)
    scale = jnp.asarray(rng.normal(size=(d,)), jnp.float32)
    emit("fusion_rs_rmsnorm_fused_wall_s",
         _timed(jax.jit(lambda s, g: ops.reduce_scatter_rmsnorm(s, g)),
                shards, scale),
         f"pallas interpret, shards {n}x{t}x{d}")
    emit("fusion_rs_rmsnorm_unfused_wall_s",
         _timed(jax.jit(lambda s, g: ref.reduce_scatter_rmsnorm_ref(
             s, g)), shards, scale),
         "jnp reference composition, same shapes")

    x = jnp.asarray(rng.normal(size=(t, n * 64)), jnp.float32)
    w = jnp.asarray(rng.normal(size=(n, 64, d)), jnp.float32)
    emit("fusion_ag_matmul_fused_wall_s",
         _timed(jax.jit(lambda a, b: ops.all_gather_matmul(a, b)),
                x, w),
         f"pallas interpret, x {t}x{n * 64}, w {n}x64x{d}")
    emit("fusion_ag_matmul_unfused_wall_s",
         _timed(jax.jit(lambda a, b: ref.all_gather_matmul_ref(a, b)),
                x, w),
         "jnp reference composition, same shapes")


# --------------------------------------------------------------------- #
# entry point
# --------------------------------------------------------------------- #

def run(emit, smoke: bool = False) -> None:
    sizes = SMOKE_SIZES_MB if smoke else SIZES_MB
    for prim, tag in (("reduce_scatter", "rs"), ("all_gather", "ag")):
        for mb in sizes:
            sp = _op_speedup(prim, mb * MiB)
            emit(f"fusion_{tag}_{mb}mb_speedup", sp,
                 "fused kernel vs collective+epilogue+HBM round-trip, "
                 f"modeled, {NRANKS} ranks")
            assert sp >= 1.0, (prim, mb, sp)

    # end-to-end: one modeled llama3-8b FSDP step.  The AdamW update is
    # the grad ReduceScatter's epilogue; fusing it makes the optimizer
    # tail cost max(rs, adamw) instead of rs + adamw + round-trip, and
    # the gather-side fusion deletes the gathered-weights HBM bounce.
    from repro.configs import get_config
    from repro.models import model
    cfg = get_config("llama3-8b")
    params = float(sum(int(np.prod(x.shape)) for x in
                       jax.tree.leaves(model.abstract_params(cfg, tp=1))))
    ag_bytes = 2.0 * params                   # bf16 weights on the wire
    rs_bytes = 4.0 * params                   # f32 grads
    compute = costmodel.roofline_compute_time(
        6.0 * params * TOKENS_PER_RANK, peak_flops=H100_FLOPS * MFU)
    t_ag = _wire("all_gather", int(ag_bytes))
    t_rs = _wire("reduce_scatter", int(rs_bytes))
    epi = _epilogue_time("reduce_scatter", int(rs_bytes))
    base = compute + t_ag + _hbm_round_trip(int(ag_bytes)) \
        + t_rs + epi + _hbm_round_trip(int(rs_bytes))
    fused = compute + t_ag + max(t_rs, epi)
    emit("fusion_llama3_8b_step_speedup", base / fused,
         "modeled FSDP step: fused AG prologue + RS/AdamW epilogue "
         "vs unfused composition")
    assert base / fused >= 1.0

    _plan_audit(emit, smoke)
    _measured(emit)
