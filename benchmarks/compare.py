"""CI perf-regression gate: compare a benchmark JSON against the
committed baseline and fail when any gated metric regresses beyond
tolerance.

Usage:
  PYTHONPATH=src:. python benchmarks/compare.py \
      --baseline benchmarks/baselines/bench-smoke.json \
      --current bench-smoke.json [--tolerance 0.1]

Both files are ``benchmarks/run.py --json`` outputs.  Metrics are
classified by name:

* ``*_wall_s`` and other wall-clock metrics are machine-dependent:
  reported, never gated;
* higher-is-better metrics (``*speedup*``, ``*gain*``, ``*ratio*``,
  ``*coverage*``, ``*fraction*``) regress when the current value drops
  more than ``tolerance`` below baseline;
* lower-is-better metrics (``*regret*``, ``*_us``, ``*_bytes*``,
  ``*wrong*``, ``*step*``, ``*calls*``) regress when the current value
  rises more than ``tolerance`` above baseline;
* everything else is informational (printed, not gated) - a metric
  must opt in to a direction by its name.

A zero baseline makes relative deltas degenerate (+inf for any
nonzero current value), so zero-baseline lower-is-better metrics gate
on an *absolute* slack instead: ``ZERO_SLACK`` maps name patterns to
the absolute rise allowed from a 0 baseline (e.g. a converged regret
of 0 µs may drift up to 25 µs - measurement-noise scale - before the
gate trips; counters like ``*wrong*`` stay strict at 0).

A metric present in the baseline but missing from the current run is a
failure too (coverage regressions should not pass silently).  The
delta table is printed and, when ``$GITHUB_STEP_SUMMARY`` is set,
appended to the job summary as markdown.
"""
from __future__ import annotations

import argparse
import json
import math
import os
import sys

WALL = ("_wall_s",)
# first match wins across both lists, HIGHER checked first
HIGHER = ("speedup", "gain", "ratio", "coverage", "fraction",
          "measured_cells")
LOWER = ("regret", "_us", "_bytes", "wrong", "step", "calls", "epochs")
# plain "*_cells" counts (e.g. topology_plan_cells) are grid-size
# constants: informational, gated by neither list
# Absolute rise allowed above a 0.0 baseline (relative deltas are
# degenerate there), first matching pattern wins; unlisted names are
# strict (any rise from 0 fails).
ZERO_SLACK = (("_us", 25.0),)


def zero_slack(name: str) -> float:
    for pat, slack in ZERO_SLACK:
        if pat in name:
            return slack
    return 0.0


def direction(name: str) -> str:
    """'higher' | 'lower' | 'info' for a metric name."""
    if any(name.endswith(w) for w in WALL):
        return "info"
    if any(h in name for h in HIGHER):
        return "higher"
    if any(lo in name for lo in LOWER):
        return "lower"
    return "info"


def load_records(path: str) -> dict:
    with open(path) as f:
        doc = json.load(f)
    recs = doc["records"] if isinstance(doc, dict) else doc
    out = {}
    for r in recs:
        v = r["value"]
        if isinstance(v, (int, float)) and math.isfinite(v):
            out[r["name"]] = float(v)
    return out


def compare(baseline: dict, current: dict, tolerance: float) -> tuple:
    """Returns (rows, failures): one row per metric
    (name, base, cur, delta_frac, direction, status)."""
    rows = []
    failures = []
    for name in sorted(baseline):
        base = baseline[name]
        d = direction(name)
        if name not in current:
            if d != "info":
                failures.append(f"{name}: missing from current run "
                                f"(baseline {base:.4g})")
                rows.append((name, base, None, None, d, "MISSING"))
            continue
        cur = current[name]
        status = "ok"
        if base:
            delta = (cur - base) / abs(base)
            if d == "higher" and delta < -tolerance:
                status = "REGRESSED"
                failures.append(
                    f"{name}: {base:.4g} -> {cur:.4g} "
                    f"({delta * 100:+.1f}%, higher is better)")
            elif d == "lower" and delta > tolerance:
                status = "REGRESSED"
                failures.append(
                    f"{name}: {base:.4g} -> {cur:.4g} "
                    f"({delta * 100:+.1f}%, lower is better)")
        else:
            # zero baseline: relative deltas degenerate, gate on the
            # absolute slack instead
            delta = None
            if d == "lower" and cur > zero_slack(name):
                status = "REGRESSED"
                failures.append(
                    f"{name}: 0 -> {cur:.4g} (baseline is 0; allowed "
                    f"absolute rise {zero_slack(name):.4g})")
        rows.append((name, base, cur, delta, d, status))
    for name in sorted(set(current) - set(baseline)):
        rows.append((name, None, current[name], None,
                     direction(name), "new"))
    return rows, failures


def render(rows: list, tolerance: float) -> str:
    lines = ["| metric | baseline | current | delta | gate | status |",
             "|---|---:|---:|---:|---|---|"]
    for name, base, cur, delta, d, status in rows:
        fb = f"{base:.4g}" if base is not None else "-"
        fc = f"{cur:.4g}" if cur is not None else "-"
        fd = f"{delta * 100:+.1f}%" if delta is not None else "-"
        gate = {"higher": f">= -{tolerance:.0%}",
                "lower": f"<= +{tolerance:.0%}"}.get(d, "info")
        mark = {"REGRESSED": "**REGRESSED**",
                "MISSING": "**MISSING**"}.get(status, status)
        lines.append(f"| {name} | {fb} | {fc} | {fd} | {gate} | "
                     f"{mark} |")
    return "\n".join(lines)


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--baseline", required=True)
    ap.add_argument("--current", required=True)
    ap.add_argument("--tolerance", type=float, default=0.10,
                    help="allowed fractional regression per metric")
    args = ap.parse_args()

    baseline = load_records(args.baseline)
    current = load_records(args.current)
    rows, failures = compare(baseline, current, args.tolerance)
    table = render(rows, args.tolerance)
    gated = sum(r[4] in ("higher", "lower") and r[5] != "new"
                for r in rows)
    print(table)
    print(f"\n{gated} gated metrics vs {args.baseline} "
          f"(tolerance {args.tolerance:.0%}); "
          f"{len(failures)} regression(s)")

    summary = os.environ.get("GITHUB_STEP_SUMMARY")
    if summary:
        with open(summary, "a") as f:
            f.write("## Benchmark smoke vs baseline\n\n")
            f.write(table + "\n\n")
            if failures:
                f.write("**Regressions:**\n\n")
                for msg in failures:
                    f.write(f"- {msg}\n")

    if failures:
        print("\nPERF REGRESSION GATE FAILED:", file=sys.stderr)
        for msg in failures:
            print(f"  {msg}", file=sys.stderr)
        return 1
    print("perf gate ok")
    return 0


if __name__ == "__main__":
    sys.exit(main())
