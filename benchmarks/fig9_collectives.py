"""Fig. 9: the 8 collective primitives, CXL-CCL-{All,Aggregate,Naive} vs
NCCL-over-InfiniBand, 3 nodes, message sizes 1 MB - 4 GB.

Emits per-primitive mean speedups (the paper's headline numbers) and the
full per-size table.  The validation test (tests/test_paper_claims.py)
asserts the means sit within tolerance of Sec. 5.2.
"""
from __future__ import annotations

import numpy as np

from repro.core import ibmodel, simulator
from repro.core.hw import MiB

SIZES = [1 * MiB, 4 * MiB, 16 * MiB, 64 * MiB, 256 * MiB, 1024 * MiB,
         4096 * MiB]
NRANKS = 3

PAPER_MEANS = {
    "all_gather": 1.34, "broadcast": 1.84, "gather": 1.94,
    "scatter": 1.07, "all_reduce": 1.50, "reduce_scatter": 1.43,
    "reduce": 1.70, "all_to_all": 1.53,
}


def table(primitive: str) -> dict:
    rows = []
    for size in SIZES:
        t_all = simulator.run_variant("all", primitive, NRANKS,
                                      size).total_time
        t_agg = simulator.run_variant("aggregate", primitive, NRANKS,
                                      size).total_time
        t_nai = simulator.run_variant("naive", primitive, NRANKS,
                                      size).total_time
        t_ib = ibmodel.estimate(primitive, NRANKS, size).time
        rows.append(dict(size=size, all=t_all, aggregate=t_agg,
                         naive=t_nai, ib=t_ib, speedup=t_ib / t_all))
    return {"rows": rows,
            "mean_speedup": float(np.mean([r["speedup"] for r in rows])),
            "paper_mean": PAPER_MEANS[primitive]}


def run(emit) -> None:
    for prim, paper in PAPER_MEANS.items():
        t = table(prim)
        emit(f"fig9_{prim}_mean_speedup", t["mean_speedup"],
             f"vs IB, paper {paper}")
        emit(f"fig9_{prim}_naive_ratio_1GiB",
             t["rows"][5]["naive"] / t["rows"][5]["all"],
             "All speedup over Naive @1GiB")
