"""Roofline analysis over the dry-run records (EXPERIMENTS.md §Roofline).

Per (arch x shape x mesh):

  compute    = HLO_FLOPs_per_chip / 197 TF/s          (bf16 MXU peak)
  memory     = HLO_bytes_per_chip / 819 GB/s          (HBM)
  collective = wire_bytes_per_chip / (2 x 50 GB/s)    (one bidirectional
               ICI link pair serves a ring over one mesh axis)

plus MODEL_FLOPS (6*N*D training, 2*N*D inference; N_active for MoE) and
the useful-compute ratio MODEL_FLOPS / HLO_FLOPs, which surfaces
remat recompute, padding waste and redundant work.

Usage:
  PYTHONPATH=src:. python -m benchmarks.roofline [--dir experiments/dryrun]
      [--markdown]
"""
from __future__ import annotations

import argparse
import glob
import json
import os

from repro.configs import get_config
from repro.core.hw import TPU_V5E
from repro.launch.dryrun import SHAPES

LINK_BW = 2 * TPU_V5E.ici_bw      # both directions of one link pair


def mesh_info(mesh_name: str) -> tuple:
    """'pod16x16'/'pod2x16x16'/'pod<DP>x<TP>' -> (chips, tp)."""
    parts = mesh_name[3:].split("x")
    if len(parts) == 3:
        return 512, int(parts[2])
    dp, tp = int(parts[0]), int(parts[1])
    return dp * tp, tp


def model_flops_per_chip(rec: dict) -> float:
    cfg = get_config(rec["arch"])
    info = SHAPES[rec["shape"]]
    chips, _ = mesh_info(rec["mesh"])
    if info["kind"] == "train":
        tokens = info["seq_len"] * info["global_batch"]
        n = rec["active_params"]
        return 6.0 * n * tokens / chips
    if info["kind"] == "prefill":
        tokens = info["seq_len"] * info["global_batch"]
        return 2.0 * rec["active_params"] * tokens / chips
    # decode: one token per sequence
    tokens = info["global_batch"]
    return 2.0 * rec["active_params"] * tokens / chips


def terms(rec: dict) -> dict:
    """Roofline terms.  FLOPs/bytes come from the analytic cost model and
    collective bytes from the trace-time ledger - both are exact w.r.t.
    scan trip counts, which XLA's cost_analysis/HLO text count only once
    (the raw compiled-artifact numbers stay in the record and in
    EXPERIMENTS.md §Dry-run as evidence + cross-check)."""
    from benchmarks.analytic_cost import step_cost
    chips, tp = mesh_info(rec["mesh"])
    cost = step_cost(rec["arch"], SHAPES[rec["shape"]], chips, tp=tp)
    wire = rec.get("ledger", rec["collectives"])["total_wire_bytes"]
    t_c = cost.flops / TPU_V5E.peak_flops_bf16
    t_m = cost.bytes / TPU_V5E.hbm_bw
    t_x = wire / LINK_BW
    dom = max(("compute", t_c), ("memory", t_m),
              ("collective", t_x), key=lambda kv: kv[1])[0]
    mf = model_flops_per_chip(rec)
    return {"compute_s": t_c, "memory_s": t_m, "collective_s": t_x,
            "dominant": dom, "model_flops": mf,
            "useful_ratio": mf / cost.flops if cost.flops else 0.0,
            "bound_s": max(t_c, t_m, t_x),
            "hlo_flops": rec["cost"].get("flops", 0.0),
            "hlo_bytes": rec["cost"].get("bytes accessed", 0.0)}


SUGGESTION = {
    "compute": ("drop padded-head/expert waste or lower remat recompute "
                "(raise microbatch, selective checkpointing)"),
    "memory": ("fuse elementwise chains / keep activations bf16; for "
               "decode, shrink or quantize the KV cache reads"),
    "collective": ("shrink wire bytes: two_phase AllReduce, sequence-"
                   "parallel activations instead of full AllReduces, "
                   "overlap via chunked schedules"),
}


def load(dir_: str, backend: str = "ring") -> list[dict]:
    recs = []
    for f in sorted(glob.glob(os.path.join(dir_, f"*_{backend}.json"))):
        r = json.load(open(f))
        if r["status"] == "ok":
            r["terms"] = terms(r)
            recs.append(r)
    return recs


def markdown_table(recs: list[dict], mesh: str) -> str:
    lines = [
        "| arch | shape | compute s | memory s | collective s | "
        "bottleneck | useful FLOPs | next lever |",
        "|---|---|---|---|---|---|---|---|",
    ]
    for r in sorted(recs, key=lambda r: (r["arch"], r["shape"])):
        if r["mesh"] != mesh:
            continue
        t = r["terms"]
        lines.append(
            f"| {r['arch']} | {r['shape']} | {t['compute_s']:.2e} | "
            f"{t['memory_s']:.2e} | {t['collective_s']:.2e} | "
            f"**{t['dominant']}** | {t['useful_ratio']:.2f} | "
            f"{SUGGESTION[t['dominant']]} |")
    return "\n".join(lines)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--dir", default="experiments/dryrun")
    ap.add_argument("--backend", default="ring")
    ap.add_argument("--markdown", action="store_true")
    args = ap.parse_args()
    recs = load(args.dir, args.backend)
    if args.markdown:
        print(markdown_table(recs, "pod16x16"))
        return
    print(f"{'arch':22s} {'shape':12s} {'mesh':10s} {'compute':>9s} "
          f"{'memory':>9s} {'collectv':>9s} {'bound':>10s} {'useful':>7s}")
    for r in sorted(recs, key=lambda r: (r["arch"], r["shape"],
                                         r["mesh"])):
        t = r["terms"]
        print(f"{r['arch']:22s} {r['shape']:12s} {r['mesh']:10s} "
              f"{t['compute_s']:9.2e} {t['memory_s']:9.2e} "
              f"{t['collective_s']:9.2e} {t['dominant']:>10s} "
              f"{t['useful_ratio']:7.2f}")


if __name__ == "__main__":
    main()
