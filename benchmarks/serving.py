"""Serving benchmark: continuous batching + CXL-pooled KV cache vs
the static batch engine, on a virtual clock.

The simulation reuses the *real* serving control plane - the
``serving.scheduler.Scheduler`` (both modes), ``kvcache.BlockManager``
(paged HBM accounting with hash-shared blocks), and
``kvcache.PooledKVStore`` (doorbell-committed pooled prefixes and
eviction images) - and replaces only the jax numerics with their
cost-model residency: prefill/decode charge
``roofline_compute_time`` (decode is weight-read bound, so batching
more lanes under one weight sweep is where continuous batching's
throughput comes from), pool traffic charges the store's own
``predict_put_s``/``predict_get_s`` (the CXL constants the tuner
prices with).  Every placement decision still lands in the ledger via
``kvcache.resolve_kv_choice`` / ``kv_prefix`` cells, so the audit
trail is the production one.

Sections (all virtual-clock deterministic -> gateable):

1. **Continuous vs static** under the same Poisson arrivals
   (``LOAD``x the saturated service rate, zero prompt reuse, sharing
   off): continuous must win throughput and p99 latency.
2. **Prompt reuse** at ``REUSE`` through the pooled prefix store:
   sharing on vs off; pooled-prefix hits must replace prefill compute
   (speedup > 1) and the ``kv_prefix`` audit must show it.
3. **KV tiering** under a tight HBM budget (burst arrivals force
   preemption-by-eviction): the oracle must send evictions to the
   pool (cheaper than recompute at this model size), and a plan whose
   ``kv_block`` cell forces ``recompute`` must override it exactly
   (the ``launch/tune --kv-block-bytes`` contract).

Emitted metrics:
  serving_throughput_ratio         continuous/static req/s (gated up)
  serving_p99_gain_ratio           static p99 / continuous p99 (gated
                                   up; >= 1 means continuous no worse)
  serving_continuous_p99_us        continuous p99 latency (gated down)
  serving_prefix_hit_fraction      pooled prompt tokens / prompt
                                   tokens of reuse requests (gated up)
  serving_reuse_speedup            sharing-off wall / sharing-on wall
                                   at REUSE prompt reuse (gated up)
  serving_evict_pool_fraction      evictions placed in the pool by the
                                   oracle under a tight HBM budget
                                   (gated up)
  serving_plan_override_wrong      evictions that disobeyed a forced
                                   recompute kv_block plan cell
                                   (strict zero)
"""
from __future__ import annotations

import numpy as np

from repro.core import ledger
from repro.serving.kvcache import (BlockManager, PooledKVStore,
                                   chain_hashes, resolve_kv_choice)
from repro.serving.scheduler import (RUNNING, Request, Scheduler)
from repro.tuner.costmodel import roofline_compute_time
from repro.tuner.plan import Choice, Plan, hardware_fingerprint

PARAMS = 1.0e9                 # modeled active parameters
BYTES_PER_TOKEN = 64 * 1024    # modeled KV bytes per cached token
# 7 complete blocks + 1: a full pooled-prefix hit restores every
# complete block and teacher-forces a single token.  Teacher-forcing
# costs one decode round per token, so a long unique suffix can eat
# the prefill saving - reuse traffic is only worth pooling when the
# shared prefix covers almost the whole prompt (same trade-off the
# real engine faces).
PROMPT_LEN = 7 * 16 + 1
NEW_TOKENS = 64
BLOCK_TOKENS = 16
SLOTS = 8
REQUESTS = 48
LOAD = 1.25                    # offered load vs saturated service rate
REUSE = 0.75                   # shared-prefix fraction in section 2


def _prefill_s(ntok: int) -> float:
    """One prefill: MXU flops + one weight sweep."""
    return roofline_compute_time(2.0 * PARAMS * ntok, 2.0 * PARAMS)


def _decode_s(k: int) -> float:
    """One decode round over ``k`` lanes: token flops scale with the
    batch, the weight sweep does not - the physics that makes packed
    decode slots cheaper per token."""
    return roofline_compute_time(2.0 * PARAMS * max(1, k),
                                 2.0 * PARAMS)


class SimEngine:
    """ServeEngine's control flow with modeled time in place of jax.

    Mirrors ``serving.engine.ServeEngine._do_step`` decision-for-
    decision (admission via transactional reserve, newest-victim
    eviction priced through ``resolve_kv_choice``, pooled-prefix
    restore capped to keep one teacher-forced token, replay teacher-
    forcing); ``self.now`` is the virtual clock.
    """

    def __init__(self, *, mode: str = "continuous", slots: int = SLOTS,
                 hbm_blocks: "int | None" = None, pool=None,
                 prefix_sharing: bool = False, plan=None,
                 uid: str = "sim"):
        per_req = -(-(PROMPT_LEN + NEW_TOKENS) // BLOCK_TOKENS)
        self.blocks = BlockManager(
            slots * per_req if hbm_blocks is None else hbm_blocks,
            BLOCK_TOKENS)
        self.sched = Scheduler(slots, self.blocks, mode=mode)
        self.pool = pool if pool is not None else PooledKVStore(
            256 << 20, block_bytes=1 << 20)
        self.share = bool(prefix_sharing)
        self.plan = plan
        self.uid = uid
        self.now = 0.0
        self._sample_after: dict = {}
        self.counters = {"evictions": 0, "evict_pool": 0,
                         "restores": 0, "replays": 0,
                         "prefix_hits": 0, "prefix_hit_tokens": 0,
                         "prefills": 0}

    # -- modeled engine internals (same shape as ServeEngine) ----------

    def _reserve(self, st) -> bool:
        ntok = st.pos if st.preemptions else len(st.req.tokens)
        try:
            self.blocks.alloc(st.req.id, max(ntok, 1),
                              chain_hashes(st.req.tokens,
                                           BLOCK_TOKENS))
            return True
        except MemoryError:
            return False

    def _evict(self, st) -> None:
        nbytes = st.pos * BYTES_PER_TOKEN
        choice = resolve_kv_choice(
            "kv_block", nbytes, 2.0 * PARAMS * st.pos,
            plan=self.plan, block_bytes=self.pool.alloc.block_bytes)
        if choice.backend == "pool":
            key = ("evict", self.uid, st.req.id)
            if self.pool.put(key, bytes(nbytes)):
                self.now += self.pool.predict_put_s(nbytes)
                self.counters["evict_pool"] += 1
        self.blocks.free(st.req.id)
        self.sched.preempt(st)
        self.counters["evictions"] += 1

    def _ensure_capacity(self, st) -> bool:
        while True:
            try:
                self.blocks.append(st.req.id, 1)
                return True
            except MemoryError:
                victim = self.sched.pick_victim(exclude=(st,))
                if victim is None:
                    raise MemoryError("request cannot fit alone")
                self._evict(victim)

    def _try_prefix_restore(self, st) -> bool:
        if not self.share:
            return False
        hashes = chain_hashes(st.req.tokens, BLOCK_TOKENS)
        usable = min(len(hashes),
                     (len(st.req.tokens) - 1) // BLOCK_TOKENS)
        run = 0
        while run < usable and ("kvblk", hashes[run]) in self.pool:
            run += 1
        if run == 0:
            return False
        prefix = run * BLOCK_TOKENS
        nbytes = prefix * BYTES_PER_TOKEN
        for h in hashes[:run]:
            self.pool.get(("kvblk", h))
        self.now += self.pool.predict_get_s(nbytes)
        st.pos = prefix
        st.forced = tuple(st.req.tokens[prefix:])
        self._sample_after[st.req.id] = True
        self.counters["prefix_hits"] += 1
        self.counters["prefix_hit_tokens"] += prefix
        ledger.record_choice(
            "kv_prefix", max(1, nbytes), 1, "pool", 1, "kv_tier",
            predicted_time=self.pool.predict_get_s(nbytes),
            baseline_time=_prefill_s(prefix))
        return True

    def _publish_prefix(self, st) -> None:
        hashes = chain_hashes(st.req.tokens, BLOCK_TOKENS)
        blk = BLOCK_TOKENS * BYTES_PER_TOKEN
        for h in hashes:
            key = ("kvblk", h)
            if key in self.pool:
                continue
            if not self.pool.put(key, bytes(blk)):
                break
            self.now += self.pool.predict_put_s(blk)

    def _prefill(self, st) -> None:
        self.now += _prefill_s(len(st.req.tokens))
        self.counters["prefills"] += 1
        st.pos = len(st.req.tokens)
        if self.share:
            self._publish_prefix(st)
        st.generated.append(0)

    def _admit(self, st) -> None:
        if st.preemptions:
            key = ("evict", self.uid, st.req.id)
            img = self.pool.get(key)
            if img is not None:
                self.now += self.pool.predict_get_s(len(img))
                self.pool.remove(key)
                self.counters["restores"] += 1
                return
            # replay: re-prefill, teacher-force what was generated
            self.blocks.free(st.req.id)
            self.blocks.alloc(st.req.id, len(st.req.tokens),
                              chain_hashes(st.req.tokens,
                                           BLOCK_TOKENS))
            done = list(st.generated)
            self.now += _prefill_s(len(st.req.tokens))
            self.counters["prefills"] += 1
            self.counters["replays"] += 1
            st.pos = len(st.req.tokens)
            st.forced = tuple(done[:-1])
            self._sample_after[st.req.id] = False
            return
        if self._try_prefix_restore(st):
            return
        self._prefill(st)

    def round(self) -> list:
        """One engine round on the virtual clock; returns the request
        states that finished during it."""
        finished = []
        for adm in self.sched.admissions(self._reserve):
            self._admit(adm.state)
            if len(adm.state.generated) >= adm.state.req.max_new_tokens:
                self.blocks.free(adm.state.req.id)
                self.sched.finish(adm.state)
                finished.append(adm.state)
        stepping = []
        for st in list(self.sched.running.values()):
            if st.status == RUNNING and self._ensure_capacity(st):
                stepping.append(st)
        stepping = [st for st in stepping if st.status == RUNNING]
        if not stepping:
            return finished
        self.now += _decode_s(len(stepping))
        for st in stepping:
            st.pos += 1
            if st.forced:
                st.forced = st.forced[1:]
                if st.forced:
                    continue
                if not self._sample_after.pop(st.req.id, True):
                    continue
            st.generated.append(0)
            if len(st.generated) >= st.req.max_new_tokens:
                self.blocks.free(st.req.id)
                self.sched.finish(st)
                finished.append(st)
        return finished


def _trace(reuse: float, seed: int, *, rate: "float | None" = None,
           burst: bool = False) -> list:
    """Seeded request trace: ``(arrival_time, Request)`` with a
    ``reuse`` fraction of prompts drawn behind a shared prefix."""
    rng = np.random.default_rng(seed)
    per_req = _prefill_s(PROMPT_LEN) + NEW_TOKENS * _decode_s(
        SLOTS) / SLOTS
    if rate is None:
        rate = LOAD / per_req
    gaps = np.zeros(REQUESTS) if burst else rng.exponential(
        1.0 / rate, REQUESTS)
    arrivals = np.cumsum(gaps)
    prefix = tuple(rng.integers(1, 1000, PROMPT_LEN - 1))
    out = []
    for i in range(REQUESTS):
        if rng.random() < reuse:
            toks = prefix + tuple(rng.integers(
                1, 1000, PROMPT_LEN - len(prefix)))
        else:
            toks = tuple(rng.integers(1, 1000, PROMPT_LEN))
        out.append((float(arrivals[i]), Request(
            id=f"r{i}", tokens=toks, max_new_tokens=NEW_TOKENS)))
    return out


def _drive(eng: SimEngine, trace: list) -> dict:
    """Run the trace to completion; per-request latency in virtual
    seconds plus the total makespan."""
    born, done = {}, {}
    i = 0
    while i < len(trace) or not eng.sched.idle:
        if (eng.sched.idle and i < len(trace)
                and trace[i][0] > eng.now):
            eng.now = trace[i][0]
        while i < len(trace) and trace[i][0] <= eng.now:
            t, req = trace[i]
            eng.sched.submit(req)
            born[req.id] = t
            i += 1
        for st in eng.round():
            done[st.req.id] = eng.now
    assert len(done) == len(trace), (
        f"{len(trace) - len(done)} requests never finished")
    lats = sorted(done[r] - born[r] for r in done)
    return {"lats": lats, "makespan": eng.now,
            "req_per_s": len(done) / eng.now}


def _pct(vals: list, q: float) -> float:
    return vals[min(len(vals) - 1, int(q * (len(vals) - 1)))]


def run(emit, smoke: bool = False) -> None:
    del smoke   # virtual clock: already CI-sized

    # 1. continuous vs static, same arrivals, no reuse
    trace = _trace(0.0, seed=1)
    cont = _drive(SimEngine(mode="continuous"), trace)
    stat = _drive(SimEngine(mode="static"), trace)
    emit("serving_continuous_req_per_s", cont["req_per_s"],
         f"{REQUESTS} Poisson requests at {LOAD}x load, "
         f"{SLOTS} slots (virtual clock)")
    emit("serving_static_req_per_s", stat["req_per_s"],
         "batch-synchronous baseline, identical arrivals")
    ratio = cont["req_per_s"] / stat["req_per_s"]
    emit("serving_throughput_ratio", ratio,
         "continuous / static req/s (gated: must stay > 1)")
    assert ratio > 1.0, (
        f"continuous batching lost to static: {ratio:.3f}x")
    c99 = _pct(cont["lats"], 0.99)
    s99 = _pct(stat["lats"], 0.99)
    emit("serving_continuous_p99_us", c99 * 1e6,
         f"p50 {_pct(cont['lats'], 0.5) * 1e6:.0f}us")
    emit("serving_static_p99_us", s99 * 1e6,
         f"p50 {_pct(stat['lats'], 0.5) * 1e6:.0f}us")
    emit("serving_p99_gain_ratio", s99 / c99,
         "static p99 / continuous p99 (gated: >= 1 means "
         "continuous is no worse)")
    assert s99 >= c99, (
        f"continuous p99 {c99:.4f}s worse than static {s99:.4f}s")

    # 2. prompt reuse through the pooled prefix store
    ledger.reset()
    trace = _trace(REUSE, seed=2)
    eng = SimEngine(prefix_sharing=True)
    on = _drive(eng, trace)
    off = _drive(SimEngine(prefix_sharing=False), trace)
    reused = sum(1 for _, r in trace
                 if r.tokens[:BLOCK_TOKENS] == trace_prefix(trace))
    hit_frac = eng.counters["prefix_hit_tokens"] / float(
        reused * PROMPT_LEN)
    emit("serving_prefix_hit_fraction", hit_frac,
         f"pooled prompt tokens / prompt tokens of the {reused} "
         f"reuse requests at {REUSE} reuse "
         f"({eng.counters['prefix_hits']} hits)")
    assert hit_frac > 0.5, (
        f"pooled prefixes covered only {hit_frac:.2f} of reuse "
        f"prompts")
    speedup = off["makespan"] / on["makespan"]
    emit("serving_reuse_speedup", speedup,
         f"sharing-off wall / sharing-on wall at {REUSE} reuse "
         f"(pool get replaces prefill compute)")
    assert speedup > 1.0, (
        f"prefix sharing slowed serving down: {speedup:.3f}x")
    cells = [c for c in ledger.snapshot()["auto_choices"]
             if c["primitive"] == "kv_prefix"]
    assert cells and all(c["backend"] == "pool" for c in cells), (
        "pooled-prefix hits left no kv_prefix audit cells")

    # 3. tight-HBM tiering: oracle evictions + plan-cell override
    ledger.reset()
    per_req = -(-(PROMPT_LEN + NEW_TOKENS) // BLOCK_TOKENS)
    tight = SLOTS * per_req * 2 // 3
    trace = _trace(0.0, seed=3, burst=True)
    eng = SimEngine(hbm_blocks=tight, uid="tier")
    _drive(eng, trace)
    assert eng.counters["evictions"] > 0, (
        f"hbm_blocks={tight} never forced an eviction")
    frac = eng.counters["evict_pool"] / eng.counters["evictions"]
    emit("serving_evict_pool_fraction", frac,
         f"{eng.counters['evictions']} evictions under "
         f"hbm_blocks={tight} (restores "
         f"{eng.counters['restores']}, replays "
         f"{eng.counters['replays']}); oracle priced the pool "
         f"round-trip under recompute at {PARAMS:.0e} params")
    assert frac > 0.9, (
        f"oracle sent only {frac:.2f} of evictions to the pool")
    audited = [c for c in ledger.snapshot()["auto_choices"]
               if c["primitive"] == "kv_block"]
    assert len(audited) == eng.counters["evictions"], (
        "every eviction must land a kv_block audit cell")

    plan = Plan(fingerprint=hardware_fingerprint())
    forced = Choice(backend="recompute", slicing_factor=1,
                    allreduce_mode="kv_tier", predicted_time=1e-6,
                    baseline_time=2e-6)
    for tok in (32, 64, 128, 192):
        plan.add("kv_block", tok * BYTES_PER_TOKEN, 1, forced)
    eng = SimEngine(hbm_blocks=tight, plan=plan, uid="plan")
    _drive(eng, trace)
    wrong = eng.counters["evict_pool"]
    emit("serving_plan_override_wrong", wrong,
         f"evictions that disobeyed the forced-recompute kv_block "
         f"plan cell ({eng.counters['evictions']} evictions, "
         f"{eng.counters['replays']} replays; strict zero)")
    assert wrong == 0 and eng.counters["replays"] > 0
    ledger.reset()


def trace_prefix(trace: list) -> tuple:
    """First BLOCK_TOKENS of the trace's shared prefix (the reuse
    marker `_trace` built the prompts around)."""
    from collections import Counter
    heads = Counter(r.tokens[:BLOCK_TOKENS] for _, r in trace)
    return heads.most_common(1)[0][0]
