"""Placement-planner audit: does the planner recover the hand-tuned
mesh-axis -> fabric-level assignment, and does topology-aware placement
beat naive assignments - on a regular 3-level cluster and on an
irregular (mixed 4+2 fan-out) one?

Workload: Llama-3-8B's analytic collective mix (TP activation
AllReduces; FSDP parameter AllGathers with a roofline-derived overlap
window + gradient ReduceScatters), priced with the same per-level
oracles the tuner sweeps (``tuner.predict_level_time``).

Claims audited:

* **regular**: on (pod: slow IB) / (node: CXL pool) / (gpu: fast ICI),
  the planner's top-ranked assignment equals the hand-tuned one - the
  TP axis on the intra-node ring, the FSDP axis split across pod+node
  - and beats the naive swap (TP across pods) by
  ``placement_regular_naive_speedup``.
* **irregular**: with a ragged node level (one pod of 4 nodes, one of
  2) the planner still places FSDP on the pool level and ranks the
  TP-on-pool swap ``placement_irregular_naive_speedup`` slower; the
  grouped decomposition itself (within-pod rings + cross-group
  sub-roots over pod IB) beats the topology-blind flat ring over the
  cross-pod IB by ``placement_irregular_ar_ragged_speedup``.
* **relabeling is free**: the placed (axis-renamed) topology keeps the
  physical topology's fingerprint, so a tuned plan survives placement.
"""
from __future__ import annotations

from repro.configs import get_config
from repro.core.hw import CXLPoolConfig, ICIConfig, InfiniBandConfig
from repro.core.topology import Level, Topology
from repro.tuner import placement as pl

# cross-pod fabric: oversubscribed Ethernet-class uplinks (the
# DFabric-style hybrid: rack-scale CXL pools stitched over a slow
# inter-rack network) - the regime where matching traffic to fabric
# pays off
POD_IB = InfiniBandConfig(link_bw=2.5e9)
NODE_POOL = CXLPoolConfig(device_bw=18e9)
GPU_ICI = ICIConfig(link_bw=45e9)

REGULAR = Topology(levels=(
    Level("pod", "ib", ib=POD_IB, shape=(2,)),
    Level("node", "cxl", pool=NODE_POOL, shape=(2,)),
    Level("gpu", "ici", ici=GPU_ICI, shape=(4,)),
))

# one pod of 4 nodes and one of 2, stitched over the pod IB; the ICI
# level matches the 6-rank degree so both axes fit either level and
# the planner has a real decision to make
IRREGULAR = Topology(levels=(
    Level("pod", "ib", ib=POD_IB),
    Level("node", "cxl", pool=NODE_POOL, shape=(4, 2)),
    Level("gpu", "ici", ici=GPU_ICI, shape=(6,)),
))

HAND_REGULAR = {"data": ("pod", "node"), "model": "gpu"}
NAIVE_REGULAR = {"model": ("pod", "node"), "data": "gpu"}
HAND_IRREGULAR = {"data": "node", "model": "gpu"}
NAIVE_IRREGULAR = {"model": "node", "data": "gpu"}


def run(emit, smoke: bool = False) -> None:
    cfg = get_config("llama3-8b")

    # -- regular 2 x 2 x 4 ------------------------------------------------
    mix = pl.CollectiveMix.for_model(cfg, {"data": 4, "model": 4})
    plan = pl.plan_placement(mix, REGULAR)
    best = plan.best
    emit("placement_regular_candidates", len(plan.ranked),
         "feasible axis->level assignments enumerated")
    emit("placement_regular_best_exposed_s", best.predicted_exposed_s,
         f"chosen: {best.describe()}")
    hand = plan.find(HAND_REGULAR)
    naive = plan.find(NAIVE_REGULAR)
    assert hand is not None and naive is not None, \
        "reference assignments missing from the ranked plan"
    # acceptance: the planner matches-or-beats the hand-tuned layout
    assert best.predicted_exposed_s <= hand.predicted_exposed_s + 1e-12
    emit("placement_regular_matches_hand",
         float(best.assignment == hand.assignment),
         f"hand-tuned {hand.describe()} ranked "
         f"#{plan.ranked.index(hand)}")
    emit("placement_regular_naive_speedup",
         naive.predicted_exposed_s / best.predicted_exposed_s,
         f"vs {naive.describe()} (TP across pods)")
    assert naive.predicted_exposed_s >= best.predicted_exposed_s

    # -- irregular 4+2 ----------------------------------------------------
    mix_ir = pl.CollectiveMix.for_model(cfg, {"data": 6, "model": 6})
    plan_ir = pl.plan_placement(mix_ir, IRREGULAR)
    best_ir = plan_ir.best
    emit("placement_irregular_candidates", len(plan_ir.ranked),
         "feasible assignments on the ragged topology")
    emit("placement_irregular_best_exposed_s",
         best_ir.predicted_exposed_s,
         f"chosen: {best_ir.describe()} (node level is ragged 4+2)")
    hand_ir = plan_ir.find(HAND_IRREGULAR)
    naive_ir = plan_ir.find(NAIVE_IRREGULAR)
    assert hand_ir is not None and naive_ir is not None
    assert best_ir.predicted_exposed_s <= \
        hand_ir.predicted_exposed_s + 1e-12
    emit("placement_irregular_matches_hand",
         float(best_ir.assignment == hand_ir.assignment),
         f"hand-tuned {hand_ir.describe()}")
    emit("placement_irregular_naive_speedup",
         naive_ir.predicted_exposed_s / best_ir.predicted_exposed_s,
         f"vs {naive_ir.describe()} (TP on the ragged pool level)")
    assert naive_ir.predicted_exposed_s >= best_ir.predicted_exposed_s

    # the ragged decomposition itself: an AllReduce on the 4+2 level
    # (within-pod rings on the pool, sub-roots across IB) vs the
    # topology-blind flat ring over the cross-pod IB
    node = IRREGULAR.level_for("node")
    pod = IRREGULAR.level_for("pod")
    size = 64 * 2**20
    ragged = pl._ragged_call_time(node, pod, "all_reduce", size)
    flat = pl._best_level_time(pod, "all_reduce", 6, size)
    emit("placement_irregular_ar_ragged_speedup", flat / ragged,
         "64 MiB AllReduce: flat 6-rank ring on cross-pod IB / "
         "grouped 4+2 on the pool with IB sub-roots")
    assert flat > ragged, (flat, ragged)

    # -- relabeling keeps the plan fingerprint -----------------------------
    placed_topo = pl.placed_topology(best_ir, IRREGULAR)
    emit("placement_relabel_fingerprint_stable",
         float(placed_topo.fingerprint() == IRREGULAR.fingerprint()),
         "placed topology matches the tuned plan's fingerprint")
    assert placed_topo.fingerprint() == IRREGULAR.fingerprint()
    shape, names, aliases = pl.mesh_spec(best_ir, mix_ir, IRREGULAR)
    emit("placement_irregular_mesh", 0.0,
         f"mesh {dict(zip(names, shape))}, aliases {aliases}")
