"""Analytical per-chip FLOPs / HBM-bytes model for the roofline.

XLA's ``cost_analysis`` counts a ``lax.scan`` body once, so the compiled
artifact undercounts per-layer work by the trip count (documented in
EXPERIMENTS.md).  This module rebuilds the true per-step costs by walking
the architecture's layer pattern with the same sharding the dry-run uses
(TP over 16, dp over the rest, padded heads/experts, replicated KV where
not divisible) and the same execution plan (remat training: fwd + bwd +
one fwd replay = 4x forward FLOPs; inference: 1x).

Every matmul contributes ``2*m*k*n`` FLOPs and ``(m*k + k*n + m*n) * b``
bytes; flash attention contributes its streaming traffic; the SSM scan
its state traffic.  All values are per chip.
"""
from __future__ import annotations

import dataclasses
import math

from repro.configs import get_config
from repro.models.config import ModelConfig


# Use the Pallas ssm_scan kernel's streaming traffic for the scan (the
# deployable TPU path); False models the jnp associative-scan reference
# which materializes the full (t, d, n) state history in HBM.
SSM_KERNEL = True


@dataclasses.dataclass
class Cost:
    flops: float = 0.0
    bytes: float = 0.0

    def matmul(self, m, k, n, b_in=2, b_w=2, b_out=2):
        self.flops += 2.0 * m * k * n
        self.bytes += m * k * b_in + k * n * b_w + m * n * b_out

    def elementwise(self, elems, reads=2, writes=1, b=2, flops_per=1):
        self.flops += elems * flops_per
        self.bytes += elems * (reads + writes) * b


def _attention(c: Cost, cfg: ModelConfig, t: int, lk: int, tp: int,
               window):
    """t local query tokens attending to lk keys (per chip)."""
    d = cfg.d_model
    hd = cfg.head_dim
    nq_l = cfg.padded_heads(tp) // tp
    nkv_l = cfg.n_kv_heads // tp if cfg.kv_sharded(tp) else cfg.n_kv_heads
    c.matmul(t, d, nq_l * hd)                 # Q
    c.matmul(t, d, nkv_l * hd)                # K
    c.matmul(t, d, nkv_l * hd)                # V
    eff_lk = min(lk, window) if window else lk
    causal_frac = 0.5 if t == lk else 1.0     # causal prefill halves QK
    score = 2.0 * t * eff_lk * nq_l * hd * causal_frac
    c.flops += 2 * score                      # QK^T and PV
    # flash streaming: read q,k,v once, write o
    c.bytes += (t * nq_l * hd + 2 * eff_lk * nq_l * hd
                + t * nq_l * hd) * 2
    c.matmul(t, nq_l * hd, d)                 # output proj


def _ffn(c: Cost, d: int, ff_l: int, t: int):
    c.matmul(t, d, ff_l)          # gate
    c.matmul(t, d, ff_l)          # up
    c.elementwise(t * ff_l, flops_per=4)
    c.matmul(t, ff_l, d)          # down


def _moe(c: Cost, cfg: ModelConfig, t: int, tp: int):
    m = cfg.moe
    e_pad = m.padded_experts(tp)
    # token-sharded dispatch (moe_forward shard_tokens): each tp shard
    # routes a disjoint t/tp slice when tokens divide tp; otherwise the
    # replicated path dispatches everything from every shard
    t_route = t // tp if (tp > 1 and t % tp == 0 and t >= tp) else t
    c.matmul(t_route, cfg.d_model, e_pad, b_w=4)    # router (f32)
    routed = t_route * m.top_k * m.capacity_factor
    _ffn(c, cfg.d_model, m.expert_d_ff, int(routed))
    c.elementwise(t * cfg.d_model, reads=3, writes=1)  # combine+gather
    if m.dense_residual_d_ff:
        _ffn(c, cfg.d_model, m.dense_residual_d_ff // tp, t)


def _mamba(c: Cost, cfg: ModelConfig, t: int, tp: int, version: int):
    s = cfg.ssm
    d = cfg.d_model
    d_l = s.expand * d // tp
    n = s.d_state
    c.matmul(t, d, d_l)           # in_x
    c.matmul(t, d, d_l)           # in_z
    # Scan HBM traffic: the jnp associative-scan reference materializes
    # h_all (t, d_l, n) in f32 (4*t*d_l*n write + read); the Pallas
    # ssm_scan kernel keeps the state in VMEM and only streams
    # x/dt/B/C in + y out (§Perf H3 iteration 2).
    scan_bytes = (2.0 * t * d_l * 2 + 2.0 * t * n * 4) if SSM_KERNEL \
        else 8.0 * t * d_l * n
    if version == 1:
        r = s.dt_rank or math.ceil(d / 16)
        c.matmul(t, d_l, r + 2 * n)      # x_proj
        c.matmul(t, r, d_l)              # dt_proj
        # scan: h (d_l, n) updated per step: ~6 flops per (chan, state)
        c.flops += 6.0 * t * d_l * n
        c.bytes += scan_bytes
    else:
        nh_l = d_l // s.headdim
        c.matmul(t, d, 2 * n)            # in_bc
        c.matmul(t, d, nh_l)             # in_dt
        c.flops += 6.0 * t * d_l * n
        c.bytes += scan_bytes
    c.elementwise(t * d_l, flops_per=8)  # conv + silu + gate
    c.matmul(t, d_l, d)           # out_proj


def step_cost(arch: str, shape: dict, mesh_chips: int, tp: int = 16
              ) -> Cost:
    """Per-chip per-step cost for one (arch, input-shape) pair."""
    cfg = get_config(arch)
    kind = shape["kind"]
    seq, gbatch = shape["seq_len"], shape["global_batch"]
    dp = mesh_chips // tp
    window = cfg.sliding_window if (kind == "decode"
                                    and seq > 100_000
                                    and any(ch in "ae"
                                            for ch in cfg.layer_pattern)
                                    ) else None

    if kind == "train":
        t_local = seq * gbatch // dp          # tokens per chip per step
        lk = seq
        passes = 4.0                          # fwd + remat fwd + bwd(2x)
    elif kind == "prefill":
        t_local = seq * gbatch // dp
        lk = seq
        passes = 1.0
    else:
        t_local = max(1, gbatch // dp) if gbatch >= dp else gbatch
        lk = min(seq, window) if window else seq
        passes = 1.0

    c = Cost()
    d = cfg.d_model
    for ch in cfg.layer_pattern:
        if ch == "a":
            _attention(c, cfg, t_local, lk if kind != "train" else seq,
                       tp, window)
            _ffn(c, d, cfg.d_ff // tp, t_local)
        elif ch == "e":
            _attention(c, cfg, t_local, lk if kind != "train" else seq,
                       tp, window)
            _moe(c, cfg, t_local, tp)
        else:
            _mamba(c, cfg, t_local, tp, 1 if ch == "1" else 2)
        c.elementwise(t_local * d, reads=4, writes=2)   # norms+residual

    if cfg.encoder is not None:
        enc_t = cfg.encoder.source_len * gbatch // dp
        for _ in range(cfg.encoder.n_layers):
            _attention(c, cfg, enc_t, cfg.encoder.source_len, tp, None)
            _ffn(c, d, cfg.d_ff // tp, enc_t)
        # decoder cross-attention per row
        for _ in range(cfg.layer_pattern.count("a")):
            _attention(c, cfg, t_local, cfg.encoder.source_len, tp, None)

    # embedding + lm head (vocab sharded over tp)
    v_l = cfg.padded_vocab(tp) // tp
    c.bytes += t_local * d * 2                # embedding gather
    c.matmul(t_local, d, v_l, b_out=4)        # logits (f32 xent)

    c.flops *= passes
    c.bytes *= passes
    if kind == "train":
        # optimizer + grads traffic: 3 f32 reads + 2 writes per local
        # param element (adam m/v + grad) + bf16 param rw
        local_params = cfg.param_count(tp) / mesh_chips
        c.bytes += local_params * (5 * 4 + 2 * 2)
    else:
        # weights resident per chip are read once per token batch
        c.bytes += cfg.param_count(tp) / tp * 2
        if kind == "decode":
            # KV cache / state read per decode step
            c.bytes += _cache_bytes_per_chip(cfg, gbatch, lk, tp, dp)
    return c


def _cache_bytes_per_chip(cfg: ModelConfig, gbatch: int, lk: int,
                          tp: int, dp: int) -> float:
    b_local = max(1, gbatch // dp) if gbatch >= dp else gbatch
    total = 0.0
    for ch in cfg.layer_pattern:
        if ch in "ae":
            nkv = cfg.n_kv_heads
            total += 2 * b_local * (lk / tp) * nkv * cfg.head_dim * 2
        else:
            s = cfg.ssm
            d_l = s.expand * cfg.d_model // tp
            total += b_local * d_l * s.d_state * 4
    return total
