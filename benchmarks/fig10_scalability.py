"""Fig. 10: scalability 3 -> 6 -> 12 nodes, 128 MB - 4 GB, 6 CXL devices.

The paper's own scalability numbers come from an emulator with the same
assumptions as ours (even per-device sharing, independent devices).
Checks the qualitative claims: AllReduce degrades super-linearly
(2.1-3.0x at 6 nodes, 8.7-12.2x at 12), Broadcast grows mildly
(1.26-1.40x / ~2.5x), AllToAll stays nearly flat (1.11-1.43x /
1.44-1.83x).
"""
from __future__ import annotations

import numpy as np

from repro.core import simulator
from repro.core.hw import MiB

SIZES = [128 * MiB, 512 * MiB, 1024 * MiB, 4096 * MiB]
NODES = [3, 6, 12]
PRIMS = ["all_reduce", "broadcast", "all_gather", "all_to_all"]


def scaling(primitive: str) -> dict:
    out = {}
    for n in NODES:
        out[n] = [simulator.run_variant("all", primitive, n,
                                        s).total_time for s in SIZES]
    ratios6 = [b / a for a, b in zip(out[3], out[6])]
    ratios12 = [b / a for a, b in zip(out[3], out[12])]
    return {"times": out, "r6": ratios6, "r12": ratios12}


def run(emit) -> None:
    paper = {"all_reduce": ((2.1, 3.0), (8.7, 12.2)),
             "broadcast": ((1.26, 1.40), (2.2, 2.8)),
             "all_to_all": ((1.11, 1.43), (1.44, 1.83)),
             "all_gather": (None, None)}
    for prim in PRIMS:
        s = scaling(prim)
        lo6, hi6 = min(s["r6"]), max(s["r6"])
        lo12, hi12 = min(s["r12"]), max(s["r12"])
        p6, p12 = paper[prim]
        emit(f"fig10_{prim}_6node_slowdown", float(np.mean(s["r6"])),
             f"range {lo6:.2f}-{hi6:.2f}" +
             (f" (paper {p6[0]}-{p6[1]})" if p6 else ""))
        emit(f"fig10_{prim}_12node_slowdown", float(np.mean(s["r12"])),
             f"range {lo12:.2f}-{hi12:.2f}" +
             (f" (paper {p12[0]}-{p12[1]})" if p12 else ""))
