"""Fig. 3 / Table 1: CXL shared-memory-pool characterization.

Reproduces the microbenchmark *model* the paper measures: single-stream
bandwidth vs transfer size (Fig. 3a ramp into the ~20 GB/s device/DMA
ceiling), and concurrent multi-server reads/writes against one device
sharing bandwidth evenly (Fig. 3b/3c, Observation 2).  Latencies come
from Table 1 constants.
"""
from __future__ import annotations

from repro.core import schedule as sched
from repro.core import simulator
from repro.core.hw import CXL_POOL, MiB

SIZES = [1 * MiB, 4 * MiB, 16 * MiB, 64 * MiB, 256 * MiB, 1024 * MiB]


def single_stream_bw(size: int) -> float:
    """One server writing `size` bytes to one device (exclusive)."""
    t = CXL_POOL.memcpy_overhead + size / min(CXL_POOL.device_bw,
                                              CXL_POOL.server_bw)
    return size / t


def concurrent_bw(size: int, n_servers: int) -> float:
    """Per-server bandwidth when n servers hit the SAME device
    (Observation 2: even sharing)."""
    share = CXL_POOL.device_bw / n_servers
    t = CXL_POOL.memcpy_overhead + size / min(share, CXL_POOL.server_bw)
    return size / t


def run(emit) -> None:
    emit("fig3a_single_bw_1MiB", single_stream_bw(1 * MiB) / 1e9,
         "GB/s single-stream @1MiB")
    emit("fig3a_single_bw_1GiB", single_stream_bw(1024 * MiB) / 1e9,
         "GB/s single-stream @1GiB (paper ~20)")
    for n in (2, 3):
        emit(f"fig3bc_concurrent_bw_{n}srv_256MiB",
             concurrent_bw(256 * MiB, n) / 1e9,
             f"GB/s per server, {n} servers on one device "
             f"(paper: ~{20 / n:.1f})")
    emit("tab1_latency_ratio",
         CXL_POOL.access_latency / CXL_POOL.dram_latency,
         "pool/DRAM latency ratio (paper 3.1x)")
