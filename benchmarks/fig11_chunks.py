"""Fig. 11: sensitivity to the slicing factor (number of chunks),
AllGather, 1 GB messages, 3 nodes.

Paper findings: single-chunk is worst (no publish/retrieve overlap);
4-8 chunks best; total swing ~9%.
"""
from __future__ import annotations

from repro.core import simulator
from repro.core.hw import MiB

FACTORS = [1, 2, 4, 8, 16, 32]


def run(emit) -> None:
    times = {}
    for f in FACTORS:
        times[f] = simulator.run_variant(
            "all", "all_gather", 3, 1024 * MiB,
            slicing_factor=f).total_time
    best = min(times, key=times.get)
    emit("fig11_best_slicing_factor", best, "paper: 4-8")
    emit("fig11_worst_is_single_chunk",
         int(max(times, key=times.get) == 1), "paper: 1 chunk worst")
    emit("fig11_swing_pct",
         100 * (max(times.values()) - min(times.values()))
         / max(times.values()), "paper ~9%")
    for f in FACTORS:
        emit(f"fig11_time_f{f}_ms", times[f] * 1e3, "AllGather 1GiB")
