"""Docs CI: documented commands must not rot.

Two checks, run from the repo root (the CI ``docs`` job):

1. **Snippet execution** - every fenced ````bash`/`python` block in
   README.md and EXPERIMENTS.md is executed against the repo (the
   quickstart/workflow blocks are written with ``--smoke`` configs, so
   this is minutes, not hours).  Blocks whose fence uses any other
   info string (```` ``` ````, ```json, ```text) are prose, not
   contracts, and are skipped; a block annotated with an HTML comment
   ``<!-- docs-check: skip ... -->`` on the line above its fence is
   skipped too (used for the full tier-1 suite, which CI already runs
   as its own job).
2. **Link check** - every relative markdown link target in the repo's
   ``*.md`` files (top level + ``docs/``) must exist.  External
   ``http(s)``/``mailto`` links and pure anchors are not checked (no
   network in CI).

Usage::

    python tools/check_docs.py [--only-links] [--only-snippets] [-v]

Exit status 1 when any snippet fails or any link dangles.
"""
from __future__ import annotations

import argparse
import glob
import os
import re
import subprocess
import sys
import tempfile
import time

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
SNIPPET_FILES = ("README.md", "EXPERIMENTS.md")
LINK_GLOBS = ("*.md", "docs/*.md")
SKIP_MARK = "docs-check: skip"
TIMEOUT_S = 1800

_FENCE_RE = re.compile(r"^```(\w*)\s*$")
_LINK_RE = re.compile(r"\[[^\]]*\]\(([^)\s]+)\)")


def extract_snippets(path: str) -> list:
    """[(lang, first line number, code)] for runnable fenced blocks."""
    out = []
    with open(path) as f:
        lines = f.read().splitlines()
    i = 0
    while i < len(lines):
        m = _FENCE_RE.match(lines[i])
        if not m:
            i += 1
            continue
        lang = m.group(1).lower()
        body = []
        start = i + 1
        i += 1
        while i < len(lines) and not lines[i].startswith("```"):
            body.append(lines[i])
            i += 1
        i += 1  # closing fence
        if lang not in ("bash", "sh", "python"):
            continue
        # a skip marker on the (non-empty) line above the fence
        above = ""
        for j in range(start - 2, -1, -1):
            if lines[j].strip():
                above = lines[j]
                break
        if SKIP_MARK in above:
            continue
        out.append((lang, start, "\n".join(body)))
    return out


def run_snippet(lang: str, code: str, verbose: bool) -> tuple:
    """(ok, seconds, output tail)."""
    env = dict(os.environ)
    env.setdefault("PYTHONPATH", "src:.")
    t0 = time.time()
    try:
        if lang == "python":
            with tempfile.NamedTemporaryFile("w", suffix=".py",
                                             delete=False) as f:
                f.write(code)
                tmp = f.name
            try:
                proc = subprocess.run(
                    [sys.executable, tmp], cwd=ROOT, env=env,
                    capture_output=True, text=True, timeout=TIMEOUT_S)
            finally:
                os.unlink(tmp)
        else:
            proc = subprocess.run(
                ["bash", "-e", "-c", code], cwd=ROOT, env=env,
                capture_output=True, text=True, timeout=TIMEOUT_S)
        ok = proc.returncode == 0
        tail = ((proc.stdout or "") + (proc.stderr or ""))[-2000:]
    except subprocess.TimeoutExpired:
        ok, tail = False, f"timeout after {TIMEOUT_S}s"
    dt = time.time() - t0
    if verbose and tail:
        print(tail)
    return ok, dt, tail


def check_snippets(verbose: bool) -> int:
    failures = 0
    for name in SNIPPET_FILES:
        path = os.path.join(ROOT, name)
        if not os.path.exists(path):
            print(f"[docs] MISSING {name}")
            failures += 1
            continue
        for lang, line, code in extract_snippets(path):
            head = code.strip().splitlines()[0] if code.strip() else ""
            print(f"[docs] run {name}:{line} ({lang}) {head[:60]}")
            ok, dt, tail = run_snippet(lang, code, verbose)
            if ok:
                print(f"[docs]   ok ({dt:.1f}s)")
            else:
                failures += 1
                print(f"[docs]   FAIL ({dt:.1f}s)\n{tail}")
    return failures


def check_links() -> int:
    failures = 0
    md_files = []
    for pat in LINK_GLOBS:
        md_files.extend(sorted(glob.glob(os.path.join(ROOT, pat))))
    for path in md_files:
        base = os.path.dirname(path)
        rel = os.path.relpath(path, ROOT)
        with open(path) as f:
            text = f.read()
        for target in _LINK_RE.findall(text):
            if target.startswith(("http://", "https://", "mailto:",
                                  "#")):
                continue
            plain = target.split("#", 1)[0]
            if not plain:
                continue
            if not os.path.exists(os.path.join(base, plain)):
                failures += 1
                print(f"[docs] dangling link in {rel}: {target}")
    return failures


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only-links", action="store_true")
    ap.add_argument("--only-snippets", action="store_true")
    ap.add_argument("-v", "--verbose", action="store_true")
    args = ap.parse_args()
    failures = 0
    if not args.only_snippets:
        failures += check_links()
    if not args.only_links:
        failures += check_snippets(args.verbose)
    if failures:
        print(f"[docs] {failures} failure(s)")
        raise SystemExit(1)
    print("[docs] all snippets ran, all links resolve")


if __name__ == "__main__":
    main()
