"""Quickstart: the CXL-CCL core in three acts.

1. Run a collective through the functional pool emulation (the paper's
   Listing 2/3 data path, byte-for-byte).
2. Price the same collective with the calibrated performance simulator
   and compare against the NCCL-over-InfiniBand model (Fig. 9).
3. Run the deployable mesh backend (chunked ppermute schedules) inside
   shard_map on this host's devices.

Usage: PYTHONPATH=src python examples/quickstart.py
"""
import numpy as np

from repro.core import ibmodel, pool, simulator
from repro.core.hw import MiB


def main() -> None:
    # --- 1. functional pool emulation --------------------------------
    nranks = 3
    x = np.random.default_rng(0).standard_normal(
        (nranks, 6000)).astype(np.float32)
    out = pool.run_collective("all_gather", x)
    assert out.shape == (nranks, nranks * 6000)
    np.testing.assert_array_equal(out[0].reshape(nranks, -1), x)
    print("pool emulation: AllGather through the CXL pool is exact; "
          "no overlapping writes, no doorbell deadlocks")

    # --- 2. performance simulation vs InfiniBand ---------------------
    print(f"\n{'size':>8} {'CXL-All':>10} {'CXL-Naive':>10} "
          f"{'IB-200':>10} {'speedup':>8}")
    for size in (16 * MiB, 256 * MiB, 1024 * MiB):
        t_all = simulator.run_variant("all", "all_gather", nranks,
                                      size).total_time
        t_nai = simulator.run_variant("naive", "all_gather", nranks,
                                      size).total_time
        t_ib = ibmodel.estimate("all_gather", nranks, size).time
        print(f"{size // MiB:>6}MB {t_all * 1e3:>8.2f}ms "
              f"{t_nai * 1e3:>8.2f}ms {t_ib * 1e3:>8.2f}ms "
              f"{t_ib / t_all:>7.2f}x")

    # --- 3. the deployable mesh backend -------------------------------
    import jax
    from jax.sharding import PartitionSpec as P
    from repro.core.api import Communicator

    n = jax.device_count()
    if n > 1:
        mesh = jax.make_mesh((n,), ("x",))
        comm = Communicator(backend="cxl", slicing_factor=4)
        y = np.random.default_rng(1).standard_normal(
            (n * 8, 4)).astype(np.float32)
        f = jax.jit(jax.shard_map(
            lambda a: comm.all_reduce(a, "x"), mesh=mesh,
            in_specs=P("x"), out_specs=P("x"), check_vma=False))
        np.testing.assert_allclose(
            np.asarray(f(y)).reshape(n, 8, 4),
            np.tile(y.reshape(n, 8, 4).sum(0), (n, 1, 1)), rtol=1e-4)
        print(f"\nmesh backend: cxl-scheduled AllReduce exact on "
              f"{n} devices")
    else:
        print("\nmesh backend: single device visible - run with "
              "XLA_FLAGS=--xla_force_host_platform_device_count=8 "
              "to see the chunked ppermute schedules execute")


if __name__ == "__main__":
    main()
