"""The tune -> train/serve workflow on one machine.

1. Sweep the offline cost model into a persisted Plan (what
   ``python -m repro.launch.tune`` does).
2. Inspect a few of the plan's decisions.
3. Train with ``backend='auto'``: every collective in the step resolves
   against the plan at trace time, and the ledger audits each choice.

Usage:
  PYTHONPATH=src python examples/autotune_workflow.py
"""
import os
import tempfile

import jax

from repro import tuner
from repro.configs import get_config
from repro.core import ledger
from repro.core.hw import MiB
from repro.data.pipeline import SyntheticTokens
from repro.training.train_loop import TrainConfig, make_sharded_train_step


def main() -> None:
    # -- 1. offline tuning -----------------------------------------------
    plan = tuner.generate_plan(tuner.SMOKE_GRID)
    path = os.path.join(tempfile.mkdtemp(), "plan.json")
    tuner.save_plan(plan, path)
    print(f"tuned {len(plan.entries)} cells -> {path} "
          f"(fingerprint {plan.fingerprint})")

    # -- 2. what did the tuner decide? -----------------------------------
    for prim in ("all_gather", "all_reduce", "broadcast"):
        for size in (1 * MiB, 256 * MiB):
            c = plan.lookup(prim, size, 3)
            print(f"  {prim:12s} {size // MiB:>4d}MiB @3 ranks -> "
                  f"{c.backend:4s} factor={c.slicing_factor} "
                  f"({c.predicted_time * 1e3:.2f}ms, best fixed "
                  f"{c.baseline_time * 1e3:.2f}ms)")

    # -- 3. train with backend='auto' ------------------------------------
    cfg = get_config("llama3.2-1b", smoke=True)
    tcfg = TrainConfig(backend="auto", plan_path=path, clip_norm=None,
                      total_steps=2, warmup=0)
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    ledger.reset()
    step, pspecs, bspecs, pc = make_sharded_train_step(cfg, tcfg, mesh)

    from repro.models import model
    import jax.numpy as jnp
    from repro.optim import adamw_init
    params = model.init_params(jax.random.key(0), cfg, tp=1,
                               dtype=jnp.float32)
    data = iter(SyntheticTokens(cfg, batch=2, seq=16))
    batch = {k: jnp.asarray(v) for k, v in next(data).items()}
    params, opt, metrics = step(params, adamw_init(params), batch)
    print(f"auto-backend step ok, loss {float(metrics['loss']):.4f}")

    audit = ledger.snapshot()["auto_choices"]
    print(f"ledger audited {len(audit)} auto decisions, e.g.:")
    for a in audit[:4]:
        print(f"  {a['primitive']:14s} {a['msg_bytes']:>9d}B "
              f"n={a['nranks']} -> {a['backend']}")


if __name__ == "__main__":
    main()
