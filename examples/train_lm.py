"""End-to-end driver: train a ~100M-param llama-family model for a few
hundred steps on synthetic data, checkpointing as it goes.

The communication backend is selectable exactly like the production
launcher: with >1 visible device the step runs TP+FSDP inside shard_map
with every collective routed through CXL-CCL.

Usage:
  PYTHONPATH=src python examples/train_lm.py --steps 300
  XLA_FLAGS=--xla_force_host_platform_device_count=8 \
      PYTHONPATH=src python examples/train_lm.py --steps 50 \
      --backend cxl --tp 4 --dp 2
"""
import argparse
import time

import jax
import jax.numpy as jnp

from repro.data.pipeline import SyntheticTokens, make_batch_specs
from repro.models import model
from repro.models.config import ModelConfig, dense_pattern
from repro.optim import adamw_init
from repro.training import checkpoint
from repro.training.train_loop import (TrainConfig, make_sharded_train_step,
                                       train)

# ~100M params: 12 layers, d_model 768 (gpt2-small scale, llama anatomy)
CFG_100M = ModelConfig(
    name="llama-100m", family="dense", n_layers=12, d_model=768,
    n_heads=12, n_kv_heads=4, d_ff=2048, vocab_size=32000,
    layer_pattern=dense_pattern(12), source="examples/train_lm.py")


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--backend", choices=["ring", "cxl"], default="ring")
    ap.add_argument("--tp", type=int, default=1)
    ap.add_argument("--dp", type=int, default=1)
    ap.add_argument("--ckpt", default="/tmp/repro_ckpt")
    args = ap.parse_args()

    cfg = CFG_100M
    n_params = cfg.param_count()
    print(f"model: {cfg.name} ({n_params / 1e6:.1f}M params), "
          f"backend={args.backend}")
    tcfg = TrainConfig(lr=args.lr, warmup=20, total_steps=args.steps,
                      backend=args.backend)
    data = iter(SyntheticTokens(cfg, batch=args.batch, seq=args.seq))

    if args.tp * args.dp > 1:
        mesh = jax.make_mesh((args.dp, args.tp), ("data", "model"))
        step, pspecs, bspecs, pc = make_sharded_train_step(
            cfg, tcfg, mesh, dp_axis=("data",))
        params = model.init_params(jax.random.key(0), cfg, tp=args.tp,
                                   dtype=jnp.float32)
        opt = adamw_init(params)
        t0 = time.time()
        for i, batch in zip(range(args.steps), data):
            batch = {k: jnp.asarray(v) for k, v in batch.items()}
            params, opt, metrics = step(params, opt, batch)
            if i % 20 == 0 or i == args.steps - 1:
                print(f"step {i:4d} loss {float(metrics['loss']):.4f} "
                      f"({time.time() - t0:.1f}s)")
    else:
        params, opt, metrics = train(cfg, tcfg, data, steps=args.steps,
                                     log_every=20)
    checkpoint.save(args.ckpt, args.steps, {"params": params})
    print(f"checkpoint written to {args.ckpt}/step_{args.steps:08d}")


if __name__ == "__main__":
    main()
