"""Hierarchical-topology workflow: define a multi-fabric cluster, tune
a per-level plan, and watch the Communicator decompose collectives
against it - all offline (abstract mesh, no devices).

Run:
  PYTHONPATH=src python examples/topology_workflow.py
"""
import json
import tempfile

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro import tuner
from repro.core import ledger
from repro.core.api import Communicator
from repro.core.hw import MiB, CXLPoolConfig, InfiniBandConfig
from repro.core.topology import Level, Topology


def main() -> None:
    # 2 pods x 2 nodes x 2 gpus: IB across pods, a rack-scale CXL pool
    # within a pod, the chip ring within a node.
    topo = Topology(levels=(
        Level("pod", "ib", ib=InfiniBandConfig(link_bw=12.5e9)),
        Level("node", "cxl", pool=CXLPoolConfig(device_bw=18e9)),
        Level("gpu", "ici"),
    ))
    print("topology fingerprint:", topo.fingerprint())

    # offline: tune every level against its own fabric oracle
    grid = tuner.TuneGrid(sizes=tuple(m * MiB for m in (1, 16, 64)),
                          nranks=(2,), slicing_factors=(1, 4))
    plan = tuner.generate_plan(grid, topology=topo)
    path = tempfile.mktemp(suffix=".json")
    tuner.save_plan(plan, path)
    print(f"tuned {len(plan.entries)} level-keyed cells -> {path}")

    # online: one flag's worth of setup - the plan carries the topology
    plan = tuner.load_plan(path, topology=topo)
    comm = Communicator(backend="auto", plan=plan)
    mesh = jax.sharding.AbstractMesh((("pod", 2), ("node", 2),
                                      ("gpu", 2)))
    axes = ("pod", "node", "gpu")

    ledger.reset()
    jax.eval_shape(jax.shard_map(
        lambda g: comm.all_reduce(g, axes), mesh=mesh,
        in_specs=P(axes), out_specs=P(axes), check_vma=False),
        jax.ShapeDtypeStruct((16 * MiB // 4, 1), jnp.float32))
    snap = ledger.snapshot()
    print("per-level wire bytes (hierarchical AllReduce, 16 MiB):")
    print(json.dumps({k: sum(v.values())
                      for k, v in snap["level_wire_bytes"].items()},
                     indent=1))
    print("per-level choices:")
    for ch in snap["auto_choices"]:
        print(f"  {ch['primitive']:<15} level={ch['level']:<5} "
              f"fabric={ch['fabric']:<4} -> {ch['backend']}")


if __name__ == "__main__":
    main()
