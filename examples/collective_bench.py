"""Interactive collective explorer: sweep any primitive across message
sizes, node counts, slicing factors and implementation variants - the
tool we used for the Sec. 5.4-style sensitivity studies.

Usage:
  PYTHONPATH=src python examples/collective_bench.py \
      --primitive all_to_all --nodes 3 6 12 --sizes 64 256 1024
"""
import argparse

from repro.core import ibmodel, simulator
from repro.core.hw import MiB
from repro.core.schedule import PRIMITIVES


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--primitive", choices=PRIMITIVES,
                    default="all_gather")
    ap.add_argument("--nodes", type=int, nargs="+", default=[3])
    ap.add_argument("--sizes", type=int, nargs="+",
                    default=[16, 256, 1024], help="MiB")
    ap.add_argument("--slicing", type=int, default=4)
    args = ap.parse_args()

    print(f"{'nodes':>5} {'size':>7} {'all':>10} {'aggregate':>10} "
          f"{'naive':>10} {'IB-200':>10} {'speedup':>8}")
    for n in args.nodes:
        for mb in args.sizes:
            size = mb * MiB
            r = {v: simulator.run_variant(
                v, args.primitive, n, size,
                slicing_factor=args.slicing).total_time
                for v in ("all", "aggregate", "naive")}
            ib = ibmodel.estimate(args.primitive, n, size).time
            print(f"{n:>5} {mb:>5}MB "
                  f"{r['all'] * 1e3:>8.2f}ms {r['aggregate'] * 1e3:>8.2f}ms "
                  f"{r['naive'] * 1e3:>8.2f}ms {ib * 1e3:>8.2f}ms "
                  f"{ib / r['all']:>7.2f}x")


if __name__ == "__main__":
    main()
