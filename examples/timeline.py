"""Visualize the simulator's transfer timeline as an ASCII Gantt chart -
shows exactly the overlap structure of Fig. 7: with one chunk the
consumer idles until the producer finishes; with the slicing factor the
retrieve stream starts as soon as chunk 0's doorbell rings.

Usage:
  PYTHONPATH=src python examples/timeline.py \
      [--primitive broadcast] [--nranks 3] [--mib 64] [--chunks 1 4]
"""
import argparse

from repro.core import schedule as sched
from repro.core.hw import MiB
from repro.core.simulator import SimOptions, simulate

WIDTH = 72


def gantt(primitive: str, nranks: int, size: int, factor: int) -> None:
    s = sched.build(primitive, nranks, size, slicing_factor=factor)
    r = simulate(s, SimOptions(track_timeline=True))
    t_end = r.total_time
    print(f"\n== {primitive} {size // MiB} MiB x{nranks} ranks, "
          f"slicing={factor}: total {t_end * 1e3:.2f} ms ==")
    lanes = {}
    for rank, kind, key, t0, t1 in r.timeline:
        lanes.setdefault((rank, kind), []).append((t0, t1, key))
    for (rank, kind) in sorted(lanes):
        row = [" "] * WIDTH
        for t0, t1, key in lanes[(rank, kind)]:
            a = int(t0 / t_end * (WIDTH - 1))
            b = max(a + 1, int(t1 / t_end * (WIDTH - 1)))
            ch = "W" if kind == "write" else "R"
            for i in range(a, min(b, WIDTH)):
                row[i] = ch if row[i] == " " else "#"
        print(f"rank{rank} {kind:5s} |{''.join(row)}|")


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--primitive", default="broadcast")
    ap.add_argument("--nranks", type=int, default=3)
    ap.add_argument("--mib", type=int, default=64)
    ap.add_argument("--chunks", type=int, nargs="+", default=[1, 8])
    args = ap.parse_args()
    for f in args.chunks:
        gantt(args.primitive, args.nranks, args.mib * MiB, f)


if __name__ == "__main__":
    main()
