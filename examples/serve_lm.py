"""Serve a small model with batched requests: prefill a batch of
prompts, decode with greedy or temperature sampling, optionally with the
sliding-window long-context cache (the long_500k configuration).

Usage:
  PYTHONPATH=src python examples/serve_lm.py --arch llama3.2-1b --smoke
  PYTHONPATH=src python examples/serve_lm.py --arch falcon-mamba-7b \
      --smoke --window 64 --start-pos 524280
"""
import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import ARCH_IDS, get_config
from repro.models import model
from repro.serving import ServeConfig, ServeEngine


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ARCH_IDS, default="llama3.2-1b")
    ap.add_argument("--smoke", action="store_true",
                    help="use the reduced config (CPU friendly)")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--new-tokens", type=int, default=16)
    ap.add_argument("--temperature", type=float, default=0.0)
    ap.add_argument("--window", type=int, default=None)
    args = ap.parse_args()

    cfg = get_config(args.arch, smoke=args.smoke)
    params = model.init_params(jax.random.key(0), cfg, tp=1,
                               dtype=jnp.float32)
    eng = ServeEngine(cfg, params, ServeConfig(
        max_seq=args.prompt_len + args.new_tokens + 8,
        window=args.window, temperature=args.temperature))

    rng = np.random.default_rng(0)
    batch = {"tokens": jnp.asarray(rng.integers(
        0, cfg.vocab_size, (args.batch, args.prompt_len)))}
    if cfg.frontend == "vision_stub" and cfg.encoder is None:
        batch["frontend"] = jnp.asarray(rng.standard_normal(
            (args.batch, cfg.frontend_tokens, cfg.frontend_dim)),
            jnp.float32)
    if cfg.encoder is not None:
        batch["source"] = jnp.asarray(rng.standard_normal(
            (args.batch, cfg.encoder.source_len, cfg.frontend_dim)),
            jnp.float32)

    t0 = time.time()
    out = eng.generate(batch, max_new_tokens=args.new_tokens)
    dt = time.time() - t0
    print(f"arch={cfg.name} batch={args.batch} "
          f"prompt={args.prompt_len} new={args.new_tokens} "
          f"window={args.window}")
    print(f"generated {out.shape} in {dt:.2f}s "
          f"({args.batch * args.new_tokens / dt:.1f} tok/s)")
    print("first request:", out[0].tolist())


if __name__ == "__main__":
    main()
