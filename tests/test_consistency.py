"""Prefill -> decode continuation must equal full-sequence forward, per
architecture (exercises KV caches, ring buffers, SSM states, cross-attn
caches and the MoE drop-free decode path)."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ALL_IDS, get_config
from repro.models import model
from repro.models.pcontext import UNSHARDED

KEY = jax.random.key(0)
RNG = np.random.default_rng(0)
B, L = 2, 12


@pytest.mark.parametrize("arch", ALL_IDS)
def test_prefill_decode_consistency(arch):
    cfg = get_config(arch, smoke=True)
    if cfg.moe:  # drop-free routing for exactness
        cfg = dataclasses.replace(cfg, moe=dataclasses.replace(
            cfg.moe, capacity_factor=8.0))
    params = model.init_params(KEY, cfg, tp=1, dtype=jnp.float32)
    n_prefix = cfg.frontend_tokens if (cfg.frontend != "text"
                                       and cfg.encoder is None) else 0
    max_seq = n_prefix + L + 8
    toks = RNG.integers(0, cfg.vocab_size, (B, L + 1))
    extra = {}
    if cfg.frontend == "vision_stub" and cfg.encoder is None:
        extra["frontend"] = jnp.asarray(RNG.standard_normal(
            (B, cfg.frontend_tokens, cfg.frontend_dim)), jnp.float32)
    if cfg.encoder is not None:
        extra["source"] = jnp.asarray(RNG.standard_normal(
            (B, cfg.encoder.source_len, cfg.frontend_dim)), jnp.float32)

    ref_logits, _ = jax.jit(lambda p: model.prefill(
        p, {"tokens": jnp.asarray(toks)} | extra, cfg, UNSHARDED,
        max_seq=max_seq, cache_dtype=jnp.float32))(params)
    _, caches = jax.jit(lambda p: model.prefill(
        p, {"tokens": jnp.asarray(toks[:, :L])} | extra, cfg, UNSHARDED,
        max_seq=max_seq, cache_dtype=jnp.float32))(params)
    logits_d, _ = jax.jit(lambda p, c: model.decode_step(
        p, c, jnp.asarray(toks[:, L:L + 1]), jnp.int32(L + n_prefix),
        cfg, UNSHARDED))(params, caches)
    err = np.max(np.abs(np.asarray(ref_logits)[..., :cfg.vocab_size]
                        - np.asarray(logits_d)))
    assert err < 2e-3, f"{arch}: {err}"


def test_windowed_equals_full_within_window():
    """Sliding-window decode == full decode while pos < window."""
    cfg = get_config("llama3.2-1b", smoke=True)
    params = model.init_params(KEY, cfg, tp=1, dtype=jnp.float32)
    toks = RNG.integers(0, cfg.vocab_size, (B, 6))
    full = model.init_cache(cfg, UNSHARDED, B, 32,
                            cache_dtype=jnp.float32)
    win = model.init_cache(cfg, UNSHARDED, B, 1 << 20,
                           cache_dtype=jnp.float32, window=32)
    lf = lw = None
    for i in range(6):
        t = jnp.asarray(toks[:, i:i + 1])
        lf, full = model.decode_step(params, full, t, jnp.int32(i), cfg,
                                     UNSHARDED)
        lw, win = model.decode_step(params, win, t, jnp.int32(i), cfg,
                                    UNSHARDED, window=32)
    np.testing.assert_allclose(np.asarray(lf), np.asarray(lw),
                               rtol=1e-4, atol=1e-5)
