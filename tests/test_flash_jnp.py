"""The pure-JAX blocked attention (models/flash.py): forward + custom-VJP
backward vs plain softmax attention."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models.flash import flash_attention
from repro.kernels.ref import flash_attention_ref

RNG = np.random.default_rng(1)


def _ref4(q, k, v, causal, window):
    """(B, L, H, D) wrapper over the (BH, L, D) oracle."""
    b, l, h, d = q.shape
    fold = lambda x: x.transpose(0, 2, 1, 3).reshape(b * h, l, d)
    out = flash_attention_ref(fold(q), fold(k), fold(v), causal, window)
    return out.reshape(b, h, l, d).transpose(0, 2, 1, 3)


@pytest.mark.parametrize("l,blk,causal,window", [
    (128, 64, True, None), (200, 64, True, None), (256, 64, True, 96),
    (128, 32, False, None), (512, 128, True, 128)])
def test_forward(l, blk, causal, window):
    q = jnp.asarray(RNG.standard_normal((2, l, 4, 32)), jnp.float32)
    k = jnp.asarray(RNG.standard_normal((2, l, 4, 32)), jnp.float32)
    v = jnp.asarray(RNG.standard_normal((2, l, 4, 32)), jnp.float32)
    out = flash_attention(q, k, v, causal, window, 0, blk)
    ref = _ref4(q, k, v, causal, window)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=1e-5, atol=2e-6)


@pytest.mark.parametrize("causal,window", [(True, None), (True, 96)])
def test_backward(causal, window):
    l, blk = 192, 64
    q = jnp.asarray(RNG.standard_normal((1, l, 2, 32)), jnp.float32)
    k = jnp.asarray(RNG.standard_normal((1, l, 2, 32)), jnp.float32)
    v = jnp.asarray(RNG.standard_normal((1, l, 2, 32)), jnp.float32)
    f = lambda *a: jnp.sum(jnp.sin(flash_attention(*a, causal, window, 0,
                                                   blk)))
    g = lambda *a: jnp.sum(jnp.sin(_ref4(*a, causal, window)))
    gf = jax.grad(f, argnums=(0, 1, 2))(q, k, v)
    gr = jax.grad(g, argnums=(0, 1, 2))(q, k, v)
    for a, b in zip(gf, gr):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=1e-4, atol=1e-5)


def test_memory_is_blocked_not_quadratic():
    """Compiled forward must not materialize an (L, L) buffer: check via
    jaxpr that no intermediate reaches L*L floats."""
    l = 2048
    q = jax.ShapeDtypeStruct((1, l, 1, 64), jnp.float32)
    jaxpr = jax.make_jaxpr(
        lambda q, k, v: flash_attention(q, k, v, True, None, 0, 512))(
        q, q, q)
    worst = 0
    for eqn in jaxpr.jaxpr.eqns:
        for var in eqn.outvars:
            if hasattr(var, "aval") and hasattr(var.aval, "shape"):
                n = int(np.prod(var.aval.shape)) if var.aval.shape else 1
                worst = max(worst, n)
    assert worst < l * l, f"largest intermediate {worst} >= {l*l}"
