"""Fused collective+compute Pallas kernels vs their unfused jnp
oracles (kernels.fused_collectives / kernels.ref), plus the
differentiable ``fused_dense`` wrapper and the launcher-side
``--xla-overlap`` preset.

Tolerance rationale: all three kernels differ from the references only
in f32 summation/association order (the shard reduction and matmul
partials), so fp32 inputs get a 1-2 ulp allclose band, never a loose
one; bf16 inputs get the usual half-precision band.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref
from repro.kernels.fused_collectives import ROW_TILE, SEG_TILE

RNG = np.random.default_rng(0)


def _shards(n, t, d, dtype=jnp.float32):
    return jnp.asarray(RNG.normal(size=(n, t, d)), jnp.float32) \
        .astype(dtype)


# -- reduce_scatter + rmsnorm --------------------------------------------- #

@pytest.mark.parametrize("n,t,d", [
    (2, ROW_TILE, 64),        # exactly one row tile
    (4, 2 * ROW_TILE, 32),    # multi-tile
    (3, 37, 48),              # odd rows: padded grid, ragged shard count
    (8, 1, 16),               # single row
])
def test_rs_rmsnorm_matches_ref_fp32(n, t, d):
    shards = _shards(n, t, d)
    scale = jnp.asarray(RNG.normal(size=(d,)), jnp.float32)
    got = ops.reduce_scatter_rmsnorm(shards, scale)
    want = ref.reduce_scatter_rmsnorm_ref(shards, scale)
    assert got.shape == (t, d)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-6, atol=1e-7)


def test_rs_rmsnorm_bf16():
    shards = _shards(4, 96, 64, jnp.bfloat16)
    scale = jnp.asarray(RNG.normal(size=(64,)), jnp.bfloat16)
    got = ops.reduce_scatter_rmsnorm(shards, scale)
    want = ref.reduce_scatter_rmsnorm_ref(shards, scale)
    assert got.dtype == jnp.bfloat16
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32),
                               rtol=2e-2, atol=1e-2)


# -- reduce_scatter + AdamW ----------------------------------------------- #

def _adamw_inputs(n, length, dtype=jnp.float32):
    g = jnp.asarray(RNG.normal(size=(n, length)), jnp.float32)
    p = jnp.asarray(RNG.normal(size=(length,)), jnp.float32) \
        .astype(dtype)
    m = jnp.asarray(RNG.normal(size=(length,)) * 0.1, jnp.float32)
    v = jnp.asarray(RNG.random(size=(length,)) * 0.01, jnp.float32)
    return g, p, m, v


@pytest.mark.parametrize("n,length,wd", [
    (2, SEG_TILE, 0.0),           # one tile
    (4, 3 * SEG_TILE, 0.1),       # multi-tile + weight decay
    (3, 1000, 0.0),               # odd length: padded grid
    (6, 7, 0.01),                 # shorter than any tile
])
def test_rs_adamw_matches_ref(n, length, wd):
    g, p, m, v = _adamw_inputs(n, length)
    args = dict(lr=3e-3, bc1=1.0 - 0.9 ** 3, bc2=1.0 - 0.95 ** 3)
    got_p, got_m, got_v = ops.reduce_scatter_adamw(
        g, p, m, v, args["lr"], args["bc1"], args["bc2"],
        weight_decay=wd)
    want_p, want_m, want_v = ref.reduce_scatter_adamw_ref(
        g, p, m, v, args["lr"], args["bc1"], args["bc2"],
        weight_decay=wd)
    # same f32 math, shard sum may associate differently: 1-2 ulp
    for got, want in ((got_m, want_m), (got_v, want_v),
                      (got_p, want_p)):
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   rtol=1e-6, atol=1e-7)


def test_rs_adamw_padding_leaves_tail_untouched():
    """The padded grid cells must not leak into the returned segment:
    moments past ``length`` would corrupt the next step if sliced
    wrong."""
    g, p, m, v = _adamw_inputs(2, SEG_TILE + 17)
    got_p, got_m, got_v = ops.reduce_scatter_adamw(
        g, p, m, v, 1e-3, 0.1, 0.05)
    assert got_p.shape == got_m.shape == got_v.shape \
        == (SEG_TILE + 17,)


# -- all_gather + matmul -------------------------------------------------- #

@pytest.mark.parametrize("n,t,ks,nout", [
    (2, ROW_TILE, 32, 48),    # one row tile
    (4, 200, 16, 64),         # odd rows: padded grid
    (8, 64, 8, 128),          # many shards
])
def test_ag_matmul_matches_ref(n, t, ks, nout):
    x = jnp.asarray(RNG.normal(size=(t, n * ks)), jnp.float32)
    w = jnp.asarray(RNG.normal(size=(n, ks, nout)), jnp.float32)
    got = ops.all_gather_matmul(x, w)
    want = ref.all_gather_matmul_ref(x, w)
    assert got.shape == (t, nout)
    # same f32 accumulation, different summation order (per-shard
    # partials vs one dot): tight allclose, not bitwise
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-5, atol=1e-5)


def test_ag_matmul_rejects_contraction_mismatch():
    x = jnp.zeros((8, 48), jnp.float32)
    w = jnp.zeros((4, 16, 8), jnp.float32)    # 4*16 != 48
    with pytest.raises(ValueError, match="contraction mismatch"):
        ops.all_gather_matmul(x, w)


def test_fused_dense_forward_and_grads():
    """``fused_dense`` must match the reference matmul in value and in
    both gradients (its VJP is the plain-jnp transpose), including
    collapsed leading batch dims."""
    n, ks, nout = 4, 16, 24
    x = jnp.asarray(RNG.normal(size=(2, 5, n * ks)), jnp.float32)
    w = jnp.asarray(RNG.normal(size=(n, ks, nout)), jnp.float32)

    def fused(x, w):
        return jnp.sum(jnp.sin(ops.fused_dense(x, w)))

    def unfused(x, w):
        return jnp.sum(jnp.sin(x @ w.reshape(n * ks, nout)))

    np.testing.assert_allclose(float(fused(x, w)),
                               float(unfused(x, w)), rtol=1e-5)
    gx_f, gw_f = jax.grad(fused, argnums=(0, 1))(x, w)
    gx_u, gw_u = jax.grad(unfused, argnums=(0, 1))(x, w)
    assert gx_f.shape == x.shape and gw_f.shape == w.shape
    np.testing.assert_allclose(np.asarray(gx_f), np.asarray(gx_u),
                               rtol=1e-4, atol=1e-5)
    np.testing.assert_allclose(np.asarray(gw_f),
                               np.asarray(gw_u).reshape(n, ks, nout),
                               rtol=1e-4, atol=1e-5)


def test_dense_helper_dispatches_on_stacked_shards():
    """``models.layers.dense`` routes StackedShards through the fused
    kernel and plain arrays through ``@`` - same numbers either way."""
    from repro.core.overlap import StackedShards
    from repro.models.layers import dense
    n, ks, nout = 2, 8, 12
    x = jnp.asarray(RNG.normal(size=(3, n * ks)), jnp.float32)
    w = jnp.asarray(RNG.normal(size=(n, ks, nout)), jnp.float32)
    flat = w.reshape(n * ks, nout)
    got = dense(x, StackedShards(w))
    np.testing.assert_allclose(np.asarray(got), np.asarray(x @ flat),
                               rtol=1e-5, atol=1e-5)
    np.testing.assert_array_equal(np.asarray(dense(x, flat)),
                                  np.asarray(x @ flat))


def test_stacked_shards_is_a_pytree():
    from repro.core.overlap import StackedShards
    s = StackedShards(jnp.ones((2, 3, 4)))
    leaves = jax.tree.leaves(s)
    assert len(leaves) == 1 and leaves[0].shape == (2, 3, 4)
    mapped = jax.tree.map(lambda a: a * 2, s)
    assert isinstance(mapped, StackedShards)
    np.testing.assert_array_equal(np.asarray(mapped.shards), 2.0)


# -- ledger fused split --------------------------------------------------- #

def test_ledger_fused_context_and_fallback_audit():
    from repro.core import ledger
    ledger.reset()
    ledger.record("all_gather", 1000.0)
    with ledger.fused():
        ledger.record("all_gather", 500.0)
    ledger.record("reduce_scatter", 300.0, fused=True)
    ledger.record_fallback("all_to_all", level="node", fabric="cxl")
    snap = ledger.snapshot()
    assert snap["fused_bytes"] == {"all_gather": 500.0,
                                   "reduce_scatter": 300.0}
    assert snap["total_fused_bytes"] == 800.0
    assert snap["wire_bytes"]["all_gather"] == 1500.0
    fb = snap["fallbacks"]
    assert len(fb) == 1 and fb[0]["primitive"] == "all_to_all"
    assert fb[0]["reason"] == "flat_on_ragged"
    ledger.reset()
    assert ledger.snapshot()["fallbacks"] == []
    assert ledger.snapshot()["total_fused_bytes"] == 0.0


# -- launcher --xla-overlap preset ---------------------------------------- #

def test_xla_overlap_preset(monkeypatch):
    from repro.launch import xla
    monkeypatch.delenv("XLA_FLAGS", raising=False)
    # absent flag: no-op
    assert not xla.apply_overlap_preset([])
    assert "XLA_FLAGS" not in __import__("os").environ
    # applied (forced past the CUDA-jaxlib gate): all flags land
    assert xla.apply_overlap_preset(["--xla-overlap"], force=True)
    flags = __import__("os").environ["XLA_FLAGS"].split()
    assert all(f in flags for f in xla.OVERLAP_FLAGS)
    # an env-pinned flag wins over the preset, with a warning
    monkeypatch.setenv(
        "XLA_FLAGS", "--xla_gpu_enable_latency_hiding_scheduler=false")
    with pytest.warns(UserWarning, match="keeping it"):
        xla.apply_overlap_preset(["--xla-overlap"], force=True)
    flags = __import__("os").environ["XLA_FLAGS"].split()
    assert "--xla_gpu_enable_latency_hiding_scheduler=false" in flags
    assert "--xla_gpu_enable_latency_hiding_scheduler=true" not in flags


def test_xla_overlap_preset_skips_without_cuda(monkeypatch):
    from repro.launch import xla
    monkeypatch.delenv("XLA_FLAGS", raising=False)
    monkeypatch.setattr(xla, "_gpu_jaxlib", lambda: False)
    with pytest.warns(UserWarning, match="no CUDA jaxlib"):
        assert not xla.apply_overlap_preset(["--xla-overlap"])
    assert "XLA_FLAGS" not in __import__("os").environ
