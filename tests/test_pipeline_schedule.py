"""Property tests for the pipeline schedules (unit + hypothesis).

Three families, over arbitrary (stages, microbatches[, chunks]):
deadlock-freedom with the closed-form span under greedy dataflow
execution, forward-precedes-backward per (stage, microbatch, chunk),
and the bubble closed forms reconciling with the simulated span.
Falls back to ``_hypothesis_shim`` when hypothesis is not installed.
"""
try:
    import hypothesis as hp
    import hypothesis.strategies as st
except ImportError:              # optional dep: use the local shim
    import _hypothesis_shim as hp
    import _hypothesis_shim as st
import pytest

from repro.training.pipeline import (Op, PipelineDeadlock, bubble_count,
                                     bubble_fraction, make_schedule,
                                     schedule_1f1b, schedule_interleaved,
                                     simulate)


# --------------------------------------------------------------------- #
# 1F1B
# --------------------------------------------------------------------- #

@hp.given(st.integers(1, 6), st.integers(1, 12))
def test_1f1b_deadlock_free_with_closed_form_span(S, M):
    span = simulate(schedule_1f1b(S, M))
    assert span == 2 * M + 2 * (S - 1)


@hp.given(st.integers(1, 6), st.integers(1, 12))
def test_1f1b_each_stage_runs_every_microbatch_once(S, M):
    for ops in schedule_1f1b(S, M):
        assert len(ops) == 2 * M
        assert sorted(o.microbatch for o in ops if o.kind == "F") == \
            list(range(M))
        assert sorted(o.microbatch for o in ops if o.kind == "B") == \
            list(range(M))
        assert all(o.chunk == 0 for o in ops)


@hp.given(st.integers(1, 6), st.integers(1, 12))
def test_1f1b_forward_precedes_backward(S, M):
    for ops in schedule_1f1b(S, M):
        seen_f = set()
        for o in ops:
            if o.kind == "F":
                seen_f.add(o.microbatch)
            else:
                assert o.microbatch in seen_f, (o, ops)


@hp.given(st.integers(1, 6), st.integers(1, 12))
def test_1f1b_bubble_reconciles_with_span(S, M):
    # per-stage idle ticks = span minus the stage's own 2M busy ticks
    span = simulate(schedule_1f1b(S, M))
    assert span - 2 * M == bubble_count(S, M, "1f1b")
    assert bubble_fraction(S, M, "1f1b") == pytest.approx(
        (span - 2 * M) / span)


@hp.given(st.integers(1, 6), st.integers(1, 12))
def test_1f1b_warmup_depth_bounds_live_activations(S, M):
    # stage s holds at most min(S-s, M) forward activations at once:
    # the PipeDream-flush memory bound (GPipe would hold M)
    for s, ops in enumerate(schedule_1f1b(S, M)):
        live = peak = 0
        for o in ops:
            live += 1 if o.kind == "F" else -1
            peak = max(peak, live)
        assert peak == min(S - s, M), (s, peak)


# --------------------------------------------------------------------- #
# interleaved (Megatron-style looping pipeline)
# --------------------------------------------------------------------- #

@hp.given(st.integers(1, 4), st.integers(1, 3), st.integers(2, 4))
def test_interleaved_deadlock_free_with_closed_form_span(S, k, v):
    M = k * S
    span = simulate(schedule_interleaved(S, M, n_chunks=v), n_chunks=v)
    assert span == 2 * M * v + 2 * (S - 1)


@hp.given(st.integers(1, 4), st.integers(1, 3), st.integers(2, 4))
def test_interleaved_forward_precedes_backward_per_chunk(S, k, v):
    M = k * S
    for ops in schedule_interleaved(S, M, n_chunks=v):
        assert len(ops) == 2 * M * v
        seen = set()
        for o in ops:
            assert 0 <= o.chunk < v
            if o.kind == "F":
                assert (o.microbatch, o.chunk) not in seen
                seen.add((o.microbatch, o.chunk))
            else:
                assert (o.microbatch, o.chunk) in seen, (o, ops)


@hp.given(st.integers(1, 4), st.integers(1, 3), st.integers(2, 4))
def test_interleaved_bubble_shrinks_by_chunk_count(S, k, v):
    M = k * S
    span = simulate(schedule_interleaved(S, M, n_chunks=v), n_chunks=v)
    # same 2(S-1) idle ticks as 1F1B, but the tick is a chunk op
    # (1/v of a stage op): the Megatron 1/v bubble shrink
    assert span - 2 * M * v == bubble_count(S, M, "interleaved", v)
    assert bubble_fraction(S, M, "interleaved", v) == pytest.approx(
        (span - 2 * M * v) / span)
    if S > 1:
        assert bubble_fraction(S, M, "interleaved", v) < \
            bubble_fraction(S, M, "1f1b")


def test_interleaved_requires_divisible_microbatches():
    with pytest.raises(ValueError, match="microbatches % stages"):
        schedule_interleaved(3, 4, n_chunks=2)


def test_interleaved_single_chunk_is_1f1b():
    assert schedule_interleaved(3, 6, n_chunks=1) == schedule_1f1b(3, 6)


# --------------------------------------------------------------------- #
# dispatcher + simulator
# --------------------------------------------------------------------- #

def test_make_schedule_dispatch_and_validation():
    assert make_schedule("1f1b", 2, 4) == schedule_1f1b(2, 4)
    assert make_schedule("interleaved", 2, 4, n_chunks=2) == \
        schedule_interleaved(2, 4, n_chunks=2)
    with pytest.raises(ValueError, match="unknown schedule"):
        make_schedule("gpipe", 2, 4)
    with pytest.raises(ValueError):
        schedule_1f1b(0, 4)
    with pytest.raises(ValueError):
        schedule_1f1b(2, 0)
    with pytest.raises(ValueError):
        bubble_count(2, 4, "gpipe")


def test_simulate_detects_deadlock():
    # a backward scheduled before its own forward can never start
    with pytest.raises(PipelineDeadlock, match="wedged"):
        simulate([[Op("B", 0), Op("F", 0)]])
    # ... and a cross-stage wedge: last stage drains backward-first
    # while stage 0 never forwards microbatch 1 ahead of B(1)
    bad = [[Op("F", 0), Op("B", 1), Op("F", 1), Op("B", 0)],
           [Op("F", 0), Op("B", 0), Op("F", 1), Op("B", 1)]]
    with pytest.raises(PipelineDeadlock):
        simulate(bad)


def test_simulate_degenerate_single_stage():
    # S=1: no pipeline, no bubble - span is just the 2M sequential ops
    assert simulate(schedule_1f1b(1, 5)) == 10
    assert bubble_count(1, 5) == 0
    assert bubble_fraction(1, 5) == 0.0
