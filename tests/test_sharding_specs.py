"""Parameter-sharding spec rules: Megatron TP dims, FSDP overlay,
stacked-group handling, and divisibility of every sharded dim for every
architecture on the production mesh."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P
from jax.tree_util import tree_flatten_with_path, DictKey

from repro.configs import ARCH_IDS, get_config
from repro.models import model, sharding


def _specs(arch, tp=16, dp=("pod", "data"), fsdp=True):
    cfg = get_config(arch)
    sharding.set_mesh_sizes({"pod": 2, "data": 16, "model": tp})
    abstract = model.abstract_params(cfg, tp=tp, dtype=jnp.bfloat16)
    return cfg, abstract, sharding.param_specs(
        abstract, cfg, dp_axis=dp, fsdp=fsdp)


def test_megatron_rules_dense():
    cfg, params, specs = _specs("llama3-8b")
    g0 = specs["g0"]
    assert g0["attn"]["wq"][2] == "model"     # (L, d, H*hd) column
    assert g0["attn"]["wo"][1] == "model"     # (L, H*hd, d) row
    assert g0["ffn"]["wg"][2] == "model"
    assert g0["ffn"]["wd"][1] == "model"
    assert specs["embed"]["tok"][0] == "model"   # vocab sharded
    # llama3-8b kv=8 < 16 -> replicated over model
    assert "model" not in tuple(g0["attn"]["wk"])


def test_moe_expert_parallel_dim():
    cfg, params, specs = _specs("arctic-480b")
    g0 = specs["g0"]
    assert g0["moe"]["wg"][1] == "model"      # (L, E, d, ff): expert dim
    assert g0["moe"]["wd"][1] == "model"
    assert "model" not in tuple(g0["moe"]["router"])  # replicated


def test_mamba_channel_parallel():
    cfg, params, specs = _specs("falcon-mamba-7b")
    g0 = specs["g0"]["mamba"]
    assert g0["in_x"][2] == "model"
    assert g0["x_proj"][1] == "model"         # row-parallel input dim
    assert g0["out_proj"][1] == "model"
    assert g0["A_log"][1] == "model"


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_all_sharded_dims_divide_production_mesh(arch):
    """Every sharded dim of every param must divide its mesh axes on the
    2x16x16 mesh - the invariant the dry-run depends on."""
    sizes = {"pod": 2, "data": 16, "model": 16}
    cfg, params, specs = _specs(arch)
    flat_p, _ = tree_flatten_with_path(params)
    flat_s, _ = tree_flatten_with_path(specs)
    for (path, leaf), (_, spec) in zip(flat_p, flat_s):
        for dim, ax in enumerate(spec):
            if ax is None:
                continue
            axes = ax if isinstance(ax, tuple) else (ax,)
            n = int(np.prod([sizes[a] for a in axes]))
            assert leaf.shape[dim] % n == 0, \
                (arch, [getattr(k, 'key', k) for k in path], dim,
                 leaf.shape, spec)


def test_row_specs_drop_layer_dim():
    cfg, params, specs = _specs("yi-6b")
    rows = sharding.row_specs(specs)
    assert len(rows["g0"]["attn"]["wq"]) == \
        len(specs["g0"]["attn"]["wq"]) - 1
    # unstacked leaves unchanged
    assert rows["embed"]["tok"] == specs["embed"]["tok"]


def test_fsdp_skips_small_and_frontend():
    cfg, params, specs = _specs("whisper-tiny")
    flat_p, _ = tree_flatten_with_path(params)
    flat_s, _ = tree_flatten_with_path(specs)
    for (path, leaf), (_, spec) in zip(flat_p, flat_s):
        names = [k.key for k in path if isinstance(k, DictKey)]
        dp_used = any(isinstance(s, tuple) or s in ("pod", "data")
                      for s in spec if s is not None)
        if "encoder" in names or "enc_proj" in names:
            assert not dp_used, names
        if leaf.size < sharding.FSDP_MIN_SIZE:
            assert not dp_used, (names, leaf.size)
