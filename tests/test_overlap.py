"""Overlap subsystem: bucket assignment, pack/unpack, exposed-time
costing, overlap-aware plans, ledger hidden/exposed accounting, and
single-device equivalence of the bucketed+prefetched train step."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import tuner
from repro.core import ledger, overlap
from repro.core.api import Communicator
from repro.core.hw import MiB


def setup_function(_):
    ledger.reset()


# -- bucket assignment ----------------------------------------------------

def _entries(shapes, dtype=jnp.float32, key=()):
    return [(i, s, dtype, key) for i, s in enumerate(shapes)]


def test_assign_buckets_cap_and_determinism():
    shapes = [(64, 64), (64, 64), (64, 64)]        # 16 KiB each (f32)
    buckets = overlap.assign_buckets(_entries(shapes), cap_bytes=33000)
    assert [len(b.slots) for b in buckets] == [2, 1]
    assert buckets[0].elems == 2 * 64 * 64
    # deterministic: same entries -> identical assignment
    again = overlap.assign_buckets(_entries(shapes), cap_bytes=33000)
    assert buckets == again
    # slots preserve leaf order with cumulative offsets
    assert [s.offset for s in buckets[0].slots] == [0, 64 * 64]


def test_assign_buckets_modes():
    shapes = [(8,), (8,), (8,)]
    per_leaf = overlap.assign_buckets(_entries(shapes), cap_bytes=0)
    assert len(per_leaf) == 3
    fused = overlap.assign_buckets(_entries(shapes), cap_bytes=None)
    assert len(fused) == 1 and fused[0].elems == 24
    # a leaf larger than the cap still gets (its own) bucket
    big = overlap.assign_buckets(_entries([(1024, 1024), (8,)]),
                                 cap_bytes=1024)
    assert [len(b.slots) for b in big] == [1, 1]


def test_assign_buckets_groups_by_dtype_and_key():
    entries = [(0, (8,), jnp.float32, ("data",)),
               (1, (8,), jnp.bfloat16, ("data",)),
               (2, (8,), jnp.float32, ("model",)),
               (3, (8,), jnp.float32, ("data",))]
    buckets = overlap.assign_buckets(entries, cap_bytes=None)
    keys = [b.key for b in buckets]
    assert len(buckets) == 3
    assert (("data",), "float32") in keys
    # same-key leaves fused despite the interleaved other groups
    fused = next(b for b in buckets if b.key == (("data",), "float32"))
    assert [s.index for s in fused.slots] == [0, 3]


def test_pack_unpack_roundtrip():
    rng = np.random.default_rng(0)
    leaves = [jnp.asarray(rng.standard_normal((4, 3)), jnp.float32),
              jnp.asarray(rng.standard_normal((2, 5)), jnp.float32)]
    (bucket,) = overlap.assign_buckets(
        [(i, x.shape, x.dtype, ()) for i, x in enumerate(leaves)],
        cap_bytes=None)
    flat = overlap.pack(bucket, leaves)
    assert flat.shape == (22,)
    restored = dict(overlap.unpack(bucket, flat))
    for i, x in enumerate(leaves):
        np.testing.assert_array_equal(np.asarray(restored[i]),
                                      np.asarray(x))


# -- overlap-aware costing ------------------------------------------------

def test_exposed_time_model():
    t = tuner.predict_time("ring", "all_gather", 3, 4 * MiB)
    assert tuner.predict_exposed_time(
        "ring", "all_gather", 3, 4 * MiB,
        overlappable_compute=0.0) == pytest.approx(t)
    assert tuner.predict_exposed_time(
        "ring", "all_gather", 3, 4 * MiB,
        overlappable_compute=t / 2) == pytest.approx(t / 2)
    assert tuner.predict_exposed_time(
        "ring", "all_gather", 3, 4 * MiB,
        overlappable_compute=10 * t) == 0.0


def test_roofline_compute_time():
    t = tuner.roofline_compute_time(1e12, 1e9, peak_flops=1e12,
                                    hbm_bw=1e9)
    assert t == pytest.approx(1.0)
    assert tuner.roofline_compute_time(
        1e12, 0.0, peak_flops=2e12, hbm_bw=1e9) == pytest.approx(0.5)
    with pytest.raises(ValueError):
        tuner.roofline_compute_time(-1.0)


TINY = tuner.TuneGrid(primitives=("all_gather", "all_reduce"),
                      sizes=(1 * MiB, 16 * MiB), nranks=(2, 3),
                      slicing_factors=(1, 4))


def test_overlap_plan_marks_cells_and_keeps_guarantee():
    plan = tuner.generate_plan(TINY, overlap_compute=1e-3)
    for (prim, bucket, n), ch in plan.entries.items():
        assert ch.overlap
        assert ch.hidden_time >= 0.0
        size = 1 << bucket
        if prim == "p2p":
            # the handoff's baselines window the same way: exposed =
            # max(0, wire - overlappable compute)
            t_ring = max(0.0, tuner.predict_p2p_time("ring", size)
                         - 1e-3)
            t_cxl = max(0.0, tuner.predict_p2p_time(
                "cxl", size, slicing_factor=4) - 1e-3)
        else:
            t_ring = tuner.predict_exposed_time(
                "ring", prim, n, size, overlappable_compute=1e-3)
            t_cxl = tuner.predict_exposed_time(
                "cxl", prim, n, size, overlappable_compute=1e-3,
                slicing_factor=4, allreduce_mode="two_phase")
        assert ch.predicted_time <= min(t_ring, t_cxl) * (1 + 1e-9)
    assert plan.meta["overlap_compute_s"] == pytest.approx(1e-3)


def test_overlap_plan_per_cell_callable():
    window = lambda prim, size, n: 1e-3 if size >= 16 * MiB else 0.0
    plan = tuner.generate_plan(TINY, overlap_compute=window)
    # all_reduce has no fused variant: its cells track the caller's
    # window exactly
    small = plan.lookup("all_reduce", 1 * MiB, 3)
    large = plan.lookup("all_reduce", 16 * MiB, 3)
    assert not small.overlap and large.overlap
    # all_gather cells carry a window even where the caller gave none:
    # the fused variant folds its epilogue's roofline residency in and
    # strictly wins the window-free cell
    small_ag = plan.lookup("all_gather", 1 * MiB, 3)
    assert small_ag.fused and small_ag.overlap
    assert small_ag.hidden_time > 0.0
    assert plan.meta["overlap_compute_s"] == "per-cell"


def test_overlap_plan_roundtrip_and_v1_compat(tmp_path):
    plan = tuner.generate_plan(TINY, overlap_compute=1e-3)
    path = str(tmp_path / "plan.json")
    tuner.save_plan(plan, path)
    loaded = tuner.load_plan(path)
    assert loaded.entries == plan.entries
    # a v1 plan document (no overlap fields) still loads, cost-in-isolation
    import json
    doc = json.load(open(path))
    doc["version"] = 1
    for e in doc["entries"]:
        e.pop("overlap")
        e.pop("hidden_time")
    json.dump(doc, open(path, "w"))
    v1 = tuner.load_plan(path)
    assert all(not c.overlap and c.hidden_time == 0.0
               for c in v1.entries.values())


# -- ledger hidden/exposed + scaled call counts ---------------------------

def test_ledger_hidden_and_calls():
    ledger.record("all_gather", 100)
    with ledger.hidden():
        assert ledger.in_hidden_region()
        with ledger.scale(3):
            ledger.record("all_gather", 10)
    ledger.record("all_reduce", 5, hidden=True)
    snap = ledger.snapshot()
    assert snap["exposed_bytes"]["all_gather"] == 100
    assert snap["hidden_bytes"]["all_gather"] == 30
    assert snap["total_hidden_bytes"] == 35
    assert snap["total_wire_bytes"] == 135
    # counts = call sites; collective_calls = trip-count-scaled launches
    assert snap["counts"]["all_gather"] == 2
    assert snap["collective_calls"]["all_gather"] == 4.0
    assert snap["total_collective_calls"] == 5.0


def test_auto_books_overlap_cells_as_hidden():
    plan = tuner.generate_plan(TINY, overlap_compute=1e-3)
    comm = Communicator(backend="auto", plan=plan)
    from jax.sharding import PartitionSpec as P
    mesh = jax.make_mesh((1,), ("x",))
    f = jax.jit(jax.shard_map(lambda a: comm.all_gather(a, "x"),
                              mesh=mesh, in_specs=P("x"), out_specs=P(),
                              check_vma=False))
    f.lower(jax.ShapeDtypeStruct((8, 4), jnp.float32))
    snap = ledger.snapshot()
    assert snap["auto_choices"][0]["overlap"] is True
    # n=1 wire bytes are 0 either way, but the call must book hidden
    assert snap["collective_calls"]["all_gather"] == 1.0
    assert snap["exposed_bytes"].get("all_gather", 0.0) == 0.0


# -- single-device end-to-end: bucketed+prefetch == per-leaf --------------

@pytest.mark.parametrize("arch", ["llama3-8b"])
def test_bucketed_prefetch_step_matches_per_leaf(arch):
    """The full sharded train step on a (1, 1) mesh: bucketing +
    double-buffered prefetch must reproduce the per-leaf serialized
    schedule's numerics, with strictly fewer collective launches."""
    from repro.configs import get_config
    from repro.models import model
    from repro.optim import adamw_init
    from repro.training.train_loop import (TrainConfig,
                                           make_sharded_train_step)

    cfg = get_config(arch, smoke=True)
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    rng = np.random.default_rng(0)
    B, L = 2, 16
    batch = {"tokens": jnp.asarray(rng.integers(0, cfg.vocab_size,
                                                (B, L))),
             "labels": jnp.asarray(rng.integers(0, cfg.vocab_size,
                                                (B, L)))}
    params = model.init_params(jax.random.key(0), cfg, tp=1,
                               dtype=jnp.float32)

    results = {}
    for name, kw in (("fused", {}),
                     ("per_leaf", dict(bucket_mb=0.0, prefetch=0))):
        tcfg = TrainConfig(lr=1e-3, warmup=0, clip_norm=None,
                           remat=False, **kw)
        ledger.reset()
        step, _, _, _ = make_sharded_train_step(cfg, tcfg, mesh)
        p, _, m = step(params, adamw_init(params), batch)
        results[name] = (p, float(m["loss"]),
                         ledger.snapshot()["total_collective_calls"])
        ledger.reset()

    p_f, loss_f, calls_f = results["fused"]
    p_l, loss_l, calls_l = results["per_leaf"]
    assert loss_f == pytest.approx(loss_l, abs=1e-5)
    worst = max(jax.tree.leaves(jax.tree.map(
        lambda a, b: float(jnp.max(jnp.abs(a - b))), p_f, p_l)))
    assert worst < 1e-3, worst
    assert calls_f < calls_l, (calls_f, calls_l)
