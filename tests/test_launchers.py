"""Launcher CLIs run end-to-end on a small forced-device mesh."""
import os
import subprocess
import sys

import pytest

ROOT = os.path.dirname(os.path.dirname(__file__))


def _env(devices=None):
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(ROOT, "src") + os.pathsep + \
        env.get("PYTHONPATH", "")
    if devices:
        env["XLA_FLAGS"] = \
            f"--xla_force_host_platform_device_count={devices}"
    return env


@pytest.mark.slow
def test_train_launcher_sharded(tmp_path):
    proc = subprocess.run(
        [sys.executable, "-m", "repro.launch.train", "--arch",
         "llama3.2-1b", "--smoke", "--steps", "6", "--batch", "4",
         "--seq", "32", "--mesh", "2x4", "--backend", "cxl",
         "--ckpt", str(tmp_path)],
        env=_env(8), capture_output=True, text=True, timeout=1200,
        cwd=ROOT)
    assert proc.returncode == 0, proc.stderr[-2000:]
    assert "loss" in proc.stdout
    assert os.path.isdir(os.path.join(tmp_path, "step_00000006"))


@pytest.mark.slow
def test_train_launcher_online_retune(tmp_path):
    """--online-retune end to end: measured step times fold into the
    plan, hot-swaps publish through the registry, and --plan-out
    persists a format-v4 refined plan."""
    import json
    env = _env(4)
    env["REPRO_PLAN_CACHE"] = str(tmp_path / "cache")
    out = tmp_path / "refined.json"
    proc = subprocess.run(
        [sys.executable, "-m", "repro.launch.train", "--arch",
         "llama3.2-1b", "--smoke", "--steps", "12", "--batch", "4",
         "--seq", "32", "--mesh", "2x2", "--backend", "auto",
         "--online-retune", "--retune-interval", "5",
         "--plan-out", str(out)],
        env=env, capture_output=True, text=True, timeout=1200,
        cwd=ROOT)
    assert proc.returncode == 0, proc.stderr[-2000:]
    assert "online re-tuning" in proc.stdout
    assert "saved refined plan" in proc.stdout
    doc = json.load(open(out))
    assert doc["version"] == 6
    # the refined plan carries measured feedback somewhere
    assert any(e.get("sample_count", 0) > 0 for e in doc["entries"])


@pytest.mark.slow
def test_train_launcher_observability(tmp_path):
    """--metrics-out/--trace-out/--timing-source emulator end to end:
    per-collective emulated times feed the online tuner, the JSON-lines
    stream + Prometheus rendering + flight-recorder trace land on disk,
    and the report CLI summarizes them."""
    import json
    metrics = tmp_path / "run.jsonl"
    trace = tmp_path / "run.trace.json"
    proc = subprocess.run(
        [sys.executable, "-m", "repro.launch.train", "--arch",
         "llama3.2-1b", "--smoke", "--steps", "8", "--batch", "4",
         "--seq", "32", "--mesh", "2x2", "--backend", "auto",
         "--online-retune", "--retune-interval", "4",
         "--timing-source", "emulator",
         "--metrics-out", str(metrics), "--trace-out", str(trace),
         "--trace-steps", "4"],
        env=_env(4), capture_output=True, text=True, timeout=1200,
        cwd=ROOT)
    assert proc.returncode == 0, proc.stderr[-2000:]
    events = [json.loads(ln) for ln in open(metrics) if ln.strip()]
    kinds = {e["kind"] for e in events}
    assert {"step", "retune", "metric", "summary"} <= kinds, kinds
    steps = [e for e in events if e["kind"] == "step"]
    assert len(steps) == 8
    assert any(e.get("timing_samples", 0) > 0 for e in steps)
    assert (tmp_path / "run.prom").exists()
    doc = json.load(open(trace))
    assert doc["metadata"]["steps_retained"] == [4, 5, 6, 7]
    assert any(e.get("cat") == "collective" for e in doc["traceEvents"])
    rep = subprocess.run(
        [sys.executable, "-m", "repro.launch.report", str(metrics),
         "--trace", str(trace)],
        env=_env(), capture_output=True, text=True, timeout=300,
        cwd=ROOT)
    assert rep.returncode == 0, rep.stderr[-2000:]
    assert "steps: 8" in rep.stdout
    assert "collective time by cell" in rep.stdout
    assert "flight recorder" in rep.stdout


@pytest.mark.slow
def test_train_launcher_resilience():
    """--resilience/--fault-plan/--pool-ckpt-interval end to end: the
    injected rank death goes stale on its heartbeat, the monitor
    confirms it at timeout+patience, the survivor re-plan hot-swaps,
    and the loop resumes from the newest pool-resident snapshot."""
    proc = subprocess.run(
        [sys.executable, "-m", "repro.launch.train", "--arch",
         "llama3.2-1b", "--smoke", "--steps", "12", "--batch", "4",
         "--seq", "32", "--mesh", "2x4", "--backend", "auto",
         "--topology", "pod:ib,node:cxl:4+4",
         "--timing-source", "emulator", "--resilience",
         "--fault-plan", "rank_death@6:rank=5",
         "--pool-ckpt-interval", "2"],
        env=_env(8), capture_output=True, text=True, timeout=1200,
        cwd=ROOT)
    assert proc.returncode == 0, proc.stderr[-2000:]
    assert "fault plan: rank_death@6:rank=5" in proc.stdout
    assert "step     6 fault injected" in proc.stdout
    # die@6 + heartbeat timeout 1 + patience 2 -> confirmed at step 8
    assert ("[resilience] step 8: re-plan [survivors on node: -[5] "
            "-> 4+3]" in proc.stdout)
    assert "resume: rolled back to pool snapshot" in proc.stdout
    assert "resilience: 1 re-plan(s), dead ranks [5]" in proc.stdout
    # training carried on after the recovery
    assert "step    11 loss" in proc.stdout


@pytest.mark.slow
def test_serve_launcher():
    proc = subprocess.run(
        [sys.executable, "-m", "repro.launch.serve", "--arch", "yi-6b",
         "--smoke", "--batch", "2", "--prompt-len", "8",
         "--new-tokens", "4"],
        env=_env(), capture_output=True, text=True, timeout=1200,
        cwd=ROOT)
    assert proc.returncode == 0, proc.stderr[-2000:]
    assert "tok/s" in proc.stdout


@pytest.mark.slow
def test_serve_launcher_poisson_trace(tmp_path):
    """Request-trace mode end to end: Poisson arrivals through
    submit/step/poll, pooled prefix hits at nonzero prompt reuse, and
    the serving gauges in the metrics stream."""
    import json
    metrics = str(tmp_path / "serve.jsonl")
    proc = subprocess.run(
        [sys.executable, "-m", "repro.launch.serve", "--arch",
         "llama3.2-1b", "--smoke", "--trace", "poisson",
         "--requests", "12", "--arrival-rate", "0.5",
         "--prompt-reuse", "0.6", "--prompt-len", "24",
         "--kv-block-tokens", "8", "--new-tokens", "4",
         "--decode-slots", "2", "--metrics-out", metrics],
        env=_env(), capture_output=True, text=True, timeout=1200,
        cwd=ROOT)
    assert proc.returncode == 0, proc.stderr[-2000:]
    assert "trace poisson" in proc.stdout
    assert "req/s" in proc.stdout
    assert "prefix hits" in proc.stdout
    events = [json.loads(l) for l in open(metrics) if l.strip()]
    summary = next(e for e in events if e.get("kind") == "summary")
    assert summary["requests"] == 12
    assert summary["req_per_s"] > 0
    hits = next(e for e in events
                if e.get("kind") == "metric"
                and e["name"] == "repro_serve_prefix_hits_total")
    assert hits["value"] > 0
