"""Per-architecture smoke tests (the required reduced-config checks):
one forward/train step on CPU asserting output shapes and no NaNs, plus
one decode step per arch including the long-context windowed path."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ALL_IDS, ARCH_IDS, get_config
from repro.models import model
from repro.models.pcontext import UNSHARDED

KEY = jax.random.key(0)
RNG = np.random.default_rng(0)
B, L = 2, 32


def make_batch(cfg):
    batch = {
        "tokens": jnp.asarray(RNG.integers(0, cfg.vocab_size, (B, L))),
        "labels": jnp.asarray(RNG.integers(0, cfg.vocab_size, (B, L))),
    }
    if cfg.frontend == "vision_stub" and cfg.encoder is None:
        batch["frontend"] = jnp.asarray(RNG.standard_normal(
            (B, cfg.frontend_tokens, cfg.frontend_dim)), jnp.float32)
    if cfg.encoder is not None:
        batch["source"] = jnp.asarray(RNG.standard_normal(
            (B, cfg.encoder.source_len, cfg.frontend_dim)), jnp.float32)
    return batch


@pytest.mark.parametrize("arch", ALL_IDS)
def test_forward_and_train_step(arch):
    cfg = get_config(arch, smoke=True)
    assert cfg.n_layers <= 4 and cfg.d_model <= 512
    if cfg.moe:
        assert cfg.moe.num_experts <= 4
    params = model.init_params(KEY, cfg, tp=1, dtype=jnp.float32)
    batch = make_batch(cfg)
    loss, aux = jax.jit(lambda p, b: model.loss_fn(
        p, b, cfg, UNSHARDED, remat=False))(params, batch)
    assert loss.shape == ()
    assert np.isfinite(float(loss))
    assert np.isfinite(float(aux["xent"]))
    # one optimizer step moves the loss
    from repro.training.train_loop import TrainConfig, make_train_step
    from repro.optim import adamw_init
    step = jax.jit(make_train_step(cfg, TrainConfig(lr=1e-3, warmup=0,
                                                    remat=False)))
    p2, opt, metrics = step(params, adamw_init(params), batch)
    assert np.isfinite(float(metrics["loss"]))
    # params actually changed
    d = jax.tree.map(lambda a, b: float(jnp.max(jnp.abs(a - b))),
                     params, p2)
    assert max(jax.tree.leaves(d)) > 0


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_decode_step_shapes_no_nan(arch):
    cfg = get_config(arch, smoke=True)
    params = model.init_params(KEY, cfg, tp=1, dtype=jnp.float32)
    caches = model.init_cache(cfg, UNSHARDED, B, 64,
                              cache_dtype=jnp.float32)
    tok = jnp.asarray(RNG.integers(0, cfg.vocab_size, (B, 1)))
    logits, caches = jax.jit(
        lambda p, c: model.decode_step(p, c, tok, jnp.int32(0), cfg,
                                       UNSHARDED))(params, caches)
    assert logits.shape == (B, 1, cfg.vocab_size)
    assert np.all(np.isfinite(np.asarray(logits)))


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_long_context_windowed_decode(arch):
    """long_500k path: position 524287, ring-buffer window cache."""
    cfg = get_config(arch, smoke=True)
    params = model.init_params(KEY, cfg, tp=1, dtype=jnp.float32)
    caches = model.init_cache(cfg, UNSHARDED, 1, 1 << 20,
                              cache_dtype=jnp.float32, window=16)
    tok = jnp.asarray(RNG.integers(0, cfg.vocab_size, (1, 1)))
    logits, _ = jax.jit(
        lambda p, c: model.decode_step(p, c, tok, jnp.int32(524287), cfg,
                                       UNSHARDED, window=16))(
        params, caches)
    assert np.all(np.isfinite(np.asarray(logits)))


def test_param_counts_match_published_scale():
    """Full configs land near the advertised parameter counts."""
    expect = {"llama3.2-1b": (1.0e9, 1.7e9),
              "yi-6b": (5.5e9, 6.5e9),
              "llama3-8b": (7.5e9, 8.6e9),
              "phi3-medium-14b": (13e9, 15e9),
              "deepseek-coder-33b": (31e9, 35e9),
              "falcon-mamba-7b": (6.5e9, 8e9),
              "arctic-480b": (430e9, 500e9)}
    for arch, (lo, hi) in expect.items():
        n = get_config(arch).param_count()
        assert lo <= n <= hi, f"{arch}: {n/1e9:.2f}B not in [{lo},{hi}]"
