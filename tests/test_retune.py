"""Online re-tuning: plan format v4 compat, ledger timing capture,
EWMA aggregation/convergence under noisy samples, measured-over-oracle
re-resolution, workload-bucket cell growth, the epoch-versioned
active-plan registry, and measurement folding (the mid-run bitwise
hot-swap equivalence runs on the 8-device mesh in _mesh_runner.py)."""
import dataclasses
import json

import numpy as np
import pytest

from repro import tuner
from repro.core import ledger
from repro.core.api import Communicator
from repro.core.hw import CXL_POOL, MiB

TINY = tuner.TuneGrid(
    primitives=("all_gather", "scatter"),
    sizes=(1 * MiB, 16 * MiB), nranks=(2, 3),
    slicing_factors=(1, 4), allreduce_modes=("two_phase",))

# 4x-optimistic pool oracle: believes the pool twice as fast per
# direction on both the device and server caps than reality
MISCAL = dataclasses.replace(CXL_POOL, device_bw=CXL_POOL.device_bw * 4,
                             server_bw=CXL_POOL.server_bw * 4)


@pytest.fixture(scope="module")
def tiny_plan():
    return tuner.generate_plan(TINY)


@pytest.fixture(scope="module")
def miscal_plan():
    return tuner.generate_plan(TINY, pool=MISCAL)


# -- plan format v4 -------------------------------------------------------

def _entry(**kw):
    base = {"primitive": "all_gather", "bucket": 20, "nranks": 3,
            "backend": "cxl", "slicing_factor": 4,
            "allreduce_mode": "two_phase",
            "predicted_time": 1e-3, "baseline_time": 2e-3}
    base.update(kw)
    return base


def test_plan_v1_to_v6_compat_chain():
    """The same entries doc loads under every readable version, with
    the fields each version lacks defaulting: v1 has no overlap
    fields, v1/v2 no level keys, v1-v3 no measured feedback, v1-v4 no
    fused knob, v1-v5 no p2p cells."""
    for version in (1, 2, 3):
        p = tuner.Plan.from_json(
            {"version": version, "fingerprint": "f", "meta": {},
             "entries": [_entry()]})
        ch = p.entries[("all_gather", 20, 3)]
        assert ch.measured_us == 0.0 and ch.sample_count == 0
        assert ch.ewma_alpha == 0.0
        assert ch.fused is False
        # pre-v4 cells cost by the oracle regardless of min_samples
        assert ch.effective_time(1) == ch.predicted_time
    v4 = {"version": 4, "fingerprint": "f", "meta": {},
          "entries": [_entry(level="1:abc", measured_us=1500.0,
                             sample_count=5, ewma_alpha=0.3)]}
    p4 = tuner.Plan.from_json(v4)
    ch = p4.entries[("all_gather", 20, 3, "1:abc")]
    assert ch.measured_us == 1500.0 and ch.sample_count == 5
    assert ch.fused is False        # pre-v5 cells are unfused
    # measured overrides the oracle once min_samples is met...
    assert ch.effective_time(3) == pytest.approx(1.5e-3)
    # ...but not before
    assert ch.effective_time(9) == ch.predicted_time
    again = tuner.Plan.from_json(p4.to_json())
    assert again.entries == p4.entries
    # v5: the fused knob round-trips
    v5 = {"version": 5, "fingerprint": "f", "meta": {},
          "entries": [_entry(fused=True)]}
    p5 = tuner.Plan.from_json(v5)
    assert p5.entries[("all_gather", 20, 3)].fused is True
    again5 = tuner.Plan.from_json(p5.to_json())
    assert again5.entries == p5.entries
    # a v5 doc re-serializes at the current version
    assert p5.to_json()["version"] == 6
    # v6: point-to-point (pipeline stage handoff) cells round-trip,
    # flat and level-tagged
    v6 = {"version": 6, "fingerprint": "f", "meta": {},
          "entries": [_entry(primitive="p2p"),
                      _entry(primitive="p2p", level="0:ib",
                             backend="ring", slicing_factor=1)]}
    p6 = tuner.Plan.from_json(v6)
    assert p6.entries[("p2p", 20, 3)].backend == "cxl"
    assert p6.entries[("p2p", 20, 3, "0:ib")].backend == "ring"
    assert p6.lookup("p2p", 1 << 20, 3, level="0:ib").backend == "ring"
    again6 = tuner.Plan.from_json(p6.to_json())
    assert again6.entries == p6.entries
    assert p6.to_json()["version"] == 6


def test_plan_v7_raises_version_error(tmp_path):
    doc = {"version": 7, "fingerprint": "x", "entries": []}
    path = tmp_path / "plan.json"
    path.write_text(json.dumps(doc))
    with pytest.raises(tuner.PlanVersionError) as ei:
        tuner.load_plan(str(path))
    assert "7" in str(ei.value) and "(1, 2, 3, 4, 5, 6)" in str(ei.value)


def test_saved_plan_roundtrips_measured_fields(tiny_plan, tmp_path):
    ot = tuner.OnlineTuner(tiny_plan, min_samples=1)
    # a measurement fast enough to win the cell outright, so the
    # winner carries the persisted measured fields
    ot.observe("all_gather", 1 * MiB, 3, "ring", 1e-5)
    refined = ot.refresh()
    ch = refined.lookup("all_gather", 1 * MiB, 3)
    assert ch.backend == "ring" and ch.sample_count == 1
    path = str(tmp_path / "plan.json")
    tuner.save_plan(refined, path)
    loaded = tuner.load_plan(path)
    assert loaded.entries == refined.entries
    # the refreshed plan warm-starts a fresh tuner's EWMAs
    ot2 = tuner.OnlineTuner(loaded, min_samples=1)
    key = tuner.online.cell_key("all_gather", 1 * MiB, 3)
    st = ot2.stats[(key, ("ring", 4, "two_phase"))]
    assert st.samples == 1
    assert st.ewma_seconds == pytest.approx(1e-5)


# -- ledger timing capture ------------------------------------------------

def test_ledger_timing_capture_and_cells():
    ledger.reset()
    ledger.record_timing("all_gather", 1 * MiB, 3, "cxl", 1e-3,
                         slicing_factor=2, allreduce_mode="two_phase")
    with ledger.timed("all_reduce", 2 * MiB, 4, "ring"):
        pass
    snap = ledger.snapshot()
    assert len(snap["timings"]) == 2
    t0 = snap["timings"][0]
    assert t0["backend"] == "cxl" and t0["slicing_factor"] == 2
    assert t0["calls"] == 1.0
    assert snap["timings"][1]["seconds"] >= 0.0
    cells = snap["timing_cells"]
    k = "all_gather/b20/n3@cxl:2:two_phase"
    assert cells[k]["samples"] == 1
    assert cells[k]["mean_seconds"] == pytest.approx(1e-3)
    # knobs the caller does not know key explicitly as '?' - they must
    # never pool into a tuned candidate's mean
    assert "all_reduce/b21/n4@ring:?:?" in cells
    ledger.reset()
    assert ledger.snapshot()["timings"] == []


def test_ledger_timing_stamps_ambient_scale():
    """A timing captured inside ledger.scale() carries its true trip
    count, so scanned-region samples weight EWMAs correctly."""
    ledger.reset()
    with ledger.scale(3):
        with ledger.scale(2):
            ledger.record_timing("all_gather", 1 * MiB, 3, "ring", 1e-3)
    ledger.record_timing("all_gather", 1 * MiB, 3, "ring", 1e-3,
                         calls=7.0)   # explicit override wins
    snap = ledger.snapshot()
    assert snap["timings"][0]["calls"] == 6.0
    assert snap["timings"][1]["calls"] == 7.0
    ledger.reset()


# -- EWMA aggregation + convergence under noise ---------------------------

def test_ewma_update_sequence(tiny_plan):
    ot = tuner.OnlineTuner(tiny_plan, alpha=0.5, min_samples=2)
    for s in (1.0, 2.0, 3.0):
        ot.observe("all_gather", 1 * MiB, 2, "ring", s)
    key = tuner.online.cell_key("all_gather", 1 * MiB, 2)
    st = ot.stats[(key, ("ring", 4, "two_phase"))]
    # 1.0 -> .5*2+.5*1=1.5 -> .5*3+.5*1.5=2.25
    assert st.ewma_seconds == pytest.approx(2.25)
    assert st.samples == 3


def test_ewma_converges_under_noisy_samples(tiny_plan):
    """The EWMA of noisy samples lands within the noise scale of the
    true mean, for every (alpha, truth) combination tried."""
    rng = np.random.default_rng(0)
    for alpha, true_s in ((0.1, 5e-4), (0.3, 2e-3), (0.5, 1e-2)):
        ot = tuner.OnlineTuner(tiny_plan, alpha=alpha, min_samples=3)
        for _ in range(200):
            ot.observe("scatter", 1 * MiB, 3, "ring",
                       true_s * rng.normal(1.0, 0.1))
        key = tuner.online.cell_key("scatter", 1 * MiB, 3)
        st = ot.stats[(key, ("ring", 4, "two_phase"))]
        # EWMA std ~= noise_std * sqrt(alpha / (2 - alpha))
        tol = 4 * 0.1 * (alpha / (2 - alpha)) ** 0.5
        assert abs(st.ewma_seconds - true_s) <= tol * true_s, \
            (alpha, true_s, st.ewma_seconds)


def test_refresh_flips_to_measured_winner(miscal_plan):
    """Scatter at 1 MiB / 2 ranks: ring truly wins, but the
    4x-optimistic pool oracle routes it to cxl.  Feeding the truth
    back flips the cell; candidates walk until the measured winner
    survives (at most one interval per candidate)."""
    assert miscal_plan.lookup("scatter", 1 * MiB, 2).backend == "cxl"
    ot = tuner.OnlineTuner(miscal_plan, min_samples=2, pool=MISCAL,
                           retune_interval=2)
    plan = miscal_plan
    for step in range(12):
        ch = plan.lookup("scatter", 1 * MiB, 2)
        truth = tuner.predict_time(ch.backend, "scatter", 2, 1 * MiB,
                                   slicing_factor=ch.slicing_factor,
                                   allreduce_mode=ch.allreduce_mode)
        ot.observe("scatter", 1 * MiB, 2, ch.backend, truth,
                   slicing_factor=ch.slicing_factor,
                   allreduce_mode=ch.allreduce_mode)
        new = ot.maybe_retune(step)
        if new is not None:
            plan = new
    tuner.clear_active_plan()
    final = plan.lookup("scatter", 1 * MiB, 2)
    assert final.backend == "ring"
    assert final.sample_count >= 2
    assert final.measured_us == pytest.approx(
        tuner.predict_time("ring", "scatter", 2, 1 * MiB) * 1e6)


def test_refresh_grows_cells_at_measured_buckets(tiny_plan):
    """A measurement at a bucket the grid never tuned grows an exact
    cell, so runtime lookup stops falling back to a neighbor."""
    ot = tuner.OnlineTuner(tiny_plan, min_samples=1)
    assert ("all_gather", 10, 2) not in tiny_plan.entries
    ot.observe("all_gather", 1024, 2, "ring", 3e-3)
    refined = ot.refresh()
    assert ("all_gather", 10, 2) in refined.entries
    # the grown cell's lookup is exact (same bucket), not nearest
    got = refined.lookup("all_gather", 1024, 2)
    assert got is refined.entries[("all_gather", 10, 2)]
    # base cells all survive
    assert set(tiny_plan.entries) <= set(refined.entries)


def test_refresh_keeps_overlap_objective():
    """A measurement-free refresh of an overlap-tuned plan must not
    flip choices: the constant window is re-applied to oracle prices
    (same exposed-time objective as the sweep), and per-cell windows -
    which are not serialized - freeze unmeasured cells outright."""
    const = tuner.generate_plan(TINY, overlap_compute=150e-6)
    ot = tuner.OnlineTuner(const)
    assert ot.overlap_window == pytest.approx(150e-6)
    refreshed = ot.refresh()
    assert not tuner.choices_changed(const, refreshed)
    for k in const.entries:
        assert refreshed.entries[k].overlap == const.entries[k].overlap
        assert refreshed.entries[k].predicted_time == pytest.approx(
            const.entries[k].predicted_time)
    percell = tuner.generate_plan(
        TINY, overlap_compute=lambda p, s, n: 150e-6)
    assert percell.meta["overlap_compute_s"] == "per-cell"
    ot2 = tuner.OnlineTuner(percell)
    assert ot2.window_unknown
    frozen = ot2.refresh()
    assert frozen.entries == percell.entries
    # measured cells still re-resolve even under unknown windows
    ch = percell.lookup("scatter", 1 * MiB, 2)
    ot2.observe("scatter", 1 * MiB, 2, ch.backend, 10.0,
                slicing_factor=ch.slicing_factor,
                allreduce_mode=ch.allreduce_mode)
    ot2.min_samples = 1
    moved = ot2.refresh()
    assert moved.lookup("scatter", 1 * MiB, 2).backend != ch.backend \
        or moved.lookup("scatter", 1 * MiB,
                        2).slicing_factor != ch.slicing_factor


def test_flat_plan_under_active_topology_maps_levels(tiny_plan):
    """A flat plan driven under an active topology audits level tags by
    axis name; the tuner must map them through the *active* topology's
    level keys, or every measurement lands in cells runtime lookup
    never queries."""
    from repro.core.topology import (Level, Topology,
                                     clear_active_topology,
                                     set_active_topology)
    topo = Topology(levels=(Level("pod", "ib"), Level("data", "cxl")))
    set_active_topology(topo)
    try:
        ot = tuner.OnlineTuner(tiny_plan, min_samples=1)
        ot.observe("all_gather", 1 * MiB, 2, "ring", 1e-9,
                   level="data")     # axis name, as the ledger tags it
        lkey = topo.level_key("data")
        key = ("all_gather", tuner.size_bucket(1 * MiB), 2, lkey)
        assert (key, ("ring", 4, "two_phase")) in ot.stats
        refined = ot.refresh()
        assert key in refined.entries
        # runtime lookup with the level key resolves the grown cell
        got = refined.lookup("all_gather", 1 * MiB, 2, level=lkey)
        assert got is refined.entries[key]
    finally:
        clear_active_topology()
    # no topology in scope: an unmappable axis name aggregates
    # level-agnostically instead of creating unreachable cells
    ot2 = tuner.OnlineTuner(tiny_plan, min_samples=1)
    ot2.observe("all_gather", 1 * MiB, 2, "ring", 1e-9, level="data")
    key3 = ("all_gather", tuner.size_bucket(1 * MiB), 2)
    assert (key3, ("ring", 4, "two_phase")) in ot2.stats
    # a raw "<idx>:<fp>" key from a persisted record passes through
    ot2.observe("all_gather", 1 * MiB, 2, "ring", 1e-9,
                level="1:0123456789ab")
    key4 = key3 + ("1:0123456789ab",)
    assert (key4, ("ring", 4, "two_phase")) in ot2.stats


def test_observe_step_apportions_by_predicted_share(tiny_plan):
    ot = tuner.OnlineTuner(tiny_plan, min_samples=1)
    choices = [
        {"primitive": "all_gather", "msg_bytes": 1 * MiB, "nranks": 2,
         "backend": "ring", "slicing_factor": 4,
         "allreduce_mode": "two_phase", "predicted_time": 3e-3,
         "calls": 2.0},
        {"primitive": "scatter", "msg_bytes": 1 * MiB, "nranks": 2,
         "backend": "cxl", "slicing_factor": 4,
         "allreduce_mode": "two_phase", "predicted_time": 1e-3,
         "calls": 4.0},
    ]
    # total predicted = 3e-3*2 + 1e-3*4 = 1e-2; step measured 2e-2
    assert ot.observe_step(2e-2, choices) == 2
    k_ag = tuner.online.cell_key("all_gather", 1 * MiB, 2)
    k_sc = tuner.online.cell_key("scatter", 1 * MiB, 2)
    ag = ot.stats[(k_ag, ("ring", 4, "two_phase"))]
    sc = ot.stats[(k_sc, ("cxl", 4, "two_phase"))]
    # per-launch: 2e-2 * (6e-3/1e-2) / 2 = 6e-3 ; 2e-2 * (4e-3/1e-2)/4
    assert ag.ewma_seconds == pytest.approx(6e-3)
    assert sc.ewma_seconds == pytest.approx(2e-3)
    # zero or missing predicted time: nothing to apportion
    assert ot.observe_step(1.0, [{"primitive": "reduce",
                                  "msg_bytes": 1, "nranks": 2,
                                  "backend": "ring",
                                  "predicted_time": 0.0}]) == 0


# -- epoch-versioned registry + hot-swap plumbing -------------------------

def test_registry_epoch_bumps_and_stamps_audit(tiny_plan):
    tuner.clear_active_plan()
    e0 = tuner.plan_epoch()
    tuner.set_active_plan(tiny_plan)
    try:
        assert tuner.plan_epoch() == e0 + 1
        assert tuner.get_active_plan_versioned() == (tiny_plan, e0 + 1)
        ledger.reset()
        comm = Communicator(backend="auto")   # registry resolution
        comm._choice("all_gather", 1 * MiB, 3)
        audit = ledger.snapshot()["auto_choices"]
        assert audit[0]["plan_epoch"] == e0 + 1
        # an explicitly attached plan is not registry-versioned
        ledger.reset()
        Communicator(backend="auto", plan=tiny_plan)._choice(
            "all_gather", 1 * MiB, 3)
        assert ledger.snapshot()["auto_choices"][0]["plan_epoch"] is None
    finally:
        tuner.clear_active_plan()
        ledger.reset()


def test_refresh_and_activate_publishes(miscal_plan):
    ot = tuner.OnlineTuner(miscal_plan, min_samples=1, pool=MISCAL)
    tuner.clear_active_plan()
    e0 = tuner.plan_epoch()
    try:
        plan = ot.refresh_and_activate()
        assert tuner.get_active_plan() is plan
        assert tuner.plan_epoch() == e0 + 1
        assert ot.plan is plan     # next refresh builds on this one
    finally:
        tuner.clear_active_plan()


def test_choices_changed(tiny_plan):
    ot = tuner.OnlineTuner(tiny_plan, min_samples=1)
    same = ot.refresh()
    assert not tuner.choices_changed(tiny_plan, same)
    ch = tiny_plan.lookup("scatter", 1 * MiB, 2)
    ot.observe("scatter", 1 * MiB, 2, ch.backend, 10.0,
               slicing_factor=ch.slicing_factor,
               allreduce_mode=ch.allreduce_mode)
    flipped = ot.refresh()
    assert tuner.choices_changed(tiny_plan, flipped)


def test_choices_changed_ignores_same_resolution_growth(tiny_plan):
    """A cell grown at a measured bucket that resolves exactly like the
    nearest-bucket cell it replaces must NOT count as changed - the
    compiled step would be identical, so re-tracing is pure waste."""
    served = tiny_plan.lookup("all_gather", 1024, 2)
    ot = tuner.OnlineTuner(tiny_plan, min_samples=1)
    # measure the served candidate fast enough to win its grown cell
    # outright: the exact-bucket cell then resolves identically
    ot.observe("all_gather", 1024, 2, served.backend, 1e-9,
               slicing_factor=served.slicing_factor,
               allreduce_mode=served.allreduce_mode)
    grown = ot.refresh()
    key = ("all_gather", tuner.size_bucket(1024), 2)
    assert key in grown.entries
    g = grown.entries[key]
    assert (g.backend, g.slicing_factor, g.allreduce_mode) == (
        served.backend, served.slicing_factor, served.allreduce_mode)
    assert not tuner.choices_changed(tiny_plan, grown)


def test_fold_measurements_via_ledger(tiny_plan):
    """End-to-end tune --measurements path: ledger timing records in,
    refreshed v6 plan out."""
    ledger.reset()
    ch = tiny_plan.lookup("all_gather", 16 * MiB, 3)
    for _ in range(3):
        ledger.record_timing("all_gather", 16 * MiB, 3, ch.backend,
                             0.5, slicing_factor=ch.slicing_factor,
                             allreduce_mode=ch.allreduce_mode)
    refined = tuner.fold_measurements(
        tiny_plan, ledger.snapshot()["timings"], min_samples=3)
    ledger.reset()
    new = refined.lookup("all_gather", 16 * MiB, 3)
    # half a second measured: every oracle candidate beats it
    assert (new.backend, new.slicing_factor) != \
        (ch.backend, ch.slicing_factor)
    assert refined.to_json()["version"] == 6


def test_online_tuner_validates_args(tiny_plan):
    with pytest.raises(ValueError):
        tuner.OnlineTuner(tiny_plan, alpha=0.0)
    with pytest.raises(ValueError):
        tuner.OnlineTuner(tiny_plan, alpha=1.5)
    with pytest.raises(ValueError):
        tuner.OnlineTuner(tiny_plan, retune_interval=0)
    # <= 1 rank or negative duration: silently ignored, not recorded
    ot = tuner.OnlineTuner(tiny_plan)
    ot.observe("all_gather", 1 * MiB, 1, "ring", 1e-3)
    ot.observe("all_gather", 1 * MiB, 3, "ring", -1.0)
    assert not ot.stats
