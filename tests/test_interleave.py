"""Eq. 1-4 placement-math properties (unit + hypothesis)."""
try:
    import hypothesis as hp
    import hypothesis.strategies as st
except ImportError:              # optional dep: use the local shim
    import _hypothesis_shim as hp
    import _hypothesis_shim as st
import pytest

from repro.core.interleave import (PoolLayout, publish_order,
                                   rank_partitioned, round_robin)

LAYOUT = PoolLayout(num_devices=6, device_capacity=1 << 20,
                    doorbell_region=4096, block_size=1024)


def test_round_robin_strides_devices():
    devs = [round_robin(LAYOUT, i).device_index for i in range(12)]
    assert devs == [0, 1, 2, 3, 4, 5] * 2


def test_round_robin_block_ids():
    assert round_robin(LAYOUT, 0).device_block_id == 0
    assert round_robin(LAYOUT, 6).device_block_id == 1
    assert round_robin(LAYOUT, 13).device_block_id == 2


def test_eq3_location_decomposition():
    p = round_robin(LAYOUT, 8)   # device 2, block 1
    assert p.device_location == (LAYOUT.doorbell_region
                                 + 1 * LAYOUT.block_size
                                 + 2 * LAYOUT.device_capacity)


@hp.given(st.integers(0, 500), st.integers(0, 500))
def test_round_robin_no_collisions(i, j):
    hp.assume(i != j)
    a, b = round_robin(LAYOUT, i), round_robin(LAYOUT, j)
    assert a.device_location != b.device_location


@hp.given(st.integers(1, 12), st.integers(0, 11), st.integers(0, 50))
def test_rank_partitioned_in_bounds(nranks, rank, data_id):
    hp.assume(rank < nranks)
    p = rank_partitioned(LAYOUT, rank, nranks, data_id)
    assert 0 <= p.device_index < LAYOUT.num_devices
    start = p.device_index * LAYOUT.device_capacity
    assert start + LAYOUT.doorbell_region <= p.device_location
    assert p.device_location + LAYOUT.block_size <= \
        start + LAYOUT.device_capacity


@hp.given(st.integers(2, 6))
def test_rank_partitions_disjoint_devices(nranks):
    """When nranks <= ND each rank's devices are mutually exclusive
    (Eq. 4's stated goal)."""
    per_rank = {}
    for r in range(nranks):
        per_rank[r] = {rank_partitioned(LAYOUT, r, nranks, d).device_index
                       for d in range(20)}
    for a in range(nranks):
        for b in range(a + 1, nranks):
            assert not (per_rank[a] & per_rank[b])


@hp.given(st.integers(2, 16), st.integers(0, 15), st.integers(0, 15),
          st.integers(0, 99), st.integers(0, 99))
def test_rank_partitioned_no_cross_rank_collisions(nranks, r1, r2, d1, d2):
    hp.assume(r1 < nranks and r2 < nranks)
    hp.assume((r1, d1) != (r2, d2))
    a = rank_partitioned(LAYOUT, r1, nranks, d1)
    b = rank_partitioned(LAYOUT, r2, nranks, d2)
    assert a.device_location != b.device_location


@hp.given(st.integers(1, 32), st.integers(0, 31))
def test_publish_order_is_rotation(nranks, rank):
    hp.assume(rank < nranks)
    order = publish_order(rank, nranks)
    assert sorted(order) == list(range(nranks))
    assert order[0] == (rank + 1) % nranks


def test_layout_validation():
    with pytest.raises(ValueError):
        PoolLayout(0, 100, 0, 10)
    with pytest.raises(ValueError):
        PoolLayout(6, 100, 200, 10)  # doorbells exceed capacity
