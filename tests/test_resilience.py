"""Unit coverage for the recovery loop: inject -> detect -> re-plan
-> resume (repro.resilience + the fault shim, heartbeats, pool
checkpoint store, and tuner-recovery knobs it composes)."""
import numpy as np
import pytest

from repro.core import pool as pool_mod
from repro.core.doorbell import HeartbeatRegion
from repro.core.hw import InfiniBandConfig
from repro.core.topology import (Level, Topology, get_active_topology,
                                 set_active_topology)
from repro.resilience import (Failure, FailureMonitor, FaultEvent,
                              FaultPlan, ResilienceController,
                              failover_topology, health_penalties,
                              replan, survivor_topology)
from repro.training import checkpoint
from repro.tuner import runtime
from repro.tuner.placement import (AxisTraffic, CollectiveCall,
                                   CollectiveMix, _link_penalty,
                                   plan_placement)


@pytest.fixture(autouse=True)
def _clean_global_state():
    """Every test leaves the fault hook and runtime registries as it
    found them - the resilience layer is all about global seams."""
    yield
    pool_mod.clear_fault_hook()
    runtime.clear_active_plan()
    runtime.clear_link_health()
    runtime.clear_rank_liveness()
    set_active_topology(None)


def _topo(shape=(4, 4)):
    # pod is absorbed as the grouped node level's cross-group parent;
    # gpu gives the placement tests a home for the small dp axis
    return Topology(levels=(
        Level(axis="pod", fabric="ib"),
        Level(axis="node", fabric="cxl", shape=shape),
        Level(axis="gpu", fabric="ici", shape=(2,))))


# -- core.pool fault shim -------------------------------------------------

def test_fault_hook_install_and_clear():
    seen = []

    def hook(op, info):
        seen.append((op, info))
        if info.get("rank") == 1:
            raise pool_mod.PoolAccessError("injected")

    assert pool_mod.get_fault_hook() is None
    pool_mod.check_fault("write", rank=1)   # no hook: no-op
    pool_mod.set_fault_hook(hook)
    pool_mod.check_fault("write", rank=0)
    with pytest.raises(pool_mod.PoolAccessError):
        pool_mod.check_fault("write", rank=1)
    assert seen == [("write", {"rank": 0}), ("write", {"rank": 1})]
    pool_mod.clear_fault_hook()
    pool_mod.check_fault("write", rank=1)   # cleared: no-op again
    assert len(seen) == 2


def test_with_retries_absorbs_transients():
    calls = {"n": 0}
    notes = []

    def flaky():
        calls["n"] += 1
        if calls["n"] <= 2:
            raise pool_mod.PoolAccessError("transient")
        return "ok"

    out = pool_mod.with_retries(flaky, retries=3,
                                on_retry=lambda a, e: notes.append(a))
    assert out == "ok"
    assert calls["n"] == 3
    assert notes == [1, 2]


def test_with_retries_exhausts_and_reraises():
    calls = {"n": 0}

    def dead():
        calls["n"] += 1
        raise pool_mod.PoolAccessError("persistent")

    with pytest.raises(pool_mod.PoolAccessError):
        pool_mod.with_retries(dead, retries=3)
    assert calls["n"] == 4      # 1 try + 3 retries


def test_with_retries_exponential_backoff_injectable_sleep():
    slept = []

    def dead():
        raise pool_mod.PoolAccessError("persistent")

    with pytest.raises(pool_mod.PoolAccessError):
        pool_mod.with_retries(dead, retries=3, backoff_s=0.1,
                              sleep=slept.append)
    assert slept == pytest.approx([0.1, 0.2, 0.4])


# -- heartbeats -----------------------------------------------------------

def test_heartbeat_pulse_read_stale():
    hb = HeartbeatRegion(4)
    assert hb.read_all() == (-1, -1, -1, -1)
    assert hb.stale_ranks(1, timeout_steps=1) == [0, 1, 2, 3]
    for r in range(4):
        hb.pulse(r, 5)
    assert hb.read_all() == (5, 5, 5, 5)
    hb.pulse(0, 6)
    hb.pulse(1, 6)
    assert hb.stale_ranks(7, timeout_steps=1) == [2, 3]
    assert hb.stale_ranks(6, timeout_steps=1) == []
    with pytest.raises(IndexError):
        hb.pulse(4, 0)
    assert hb.address(3) == 3 * hb.address(1)


def test_heartbeat_pulse_routes_through_fault_hook():
    hb = HeartbeatRegion(2)

    def hook(op, info):
        if op == "heartbeat" and info["rank"] == 1:
            raise pool_mod.PoolAccessError("rank 1 dead")

    pool_mod.set_fault_hook(hook)
    hb.pulse(0, 3)
    with pytest.raises(pool_mod.PoolAccessError):
        hb.pulse(1, 3)
    assert hb.read(0) == 3
    assert hb.read(1) == -1     # the failed store never landed


# -- fault plan -----------------------------------------------------------

def test_fault_plan_parse_round_trip():
    fp = FaultPlan.parse(
        "link_degrade@10-18:link=node@cxl,factor=4;"
        "rank_death@12:rank=3;pool_error@5-7:rate=0.5")
    kinds = [e.kind for e in fp.events]
    assert kinds == ["pool_error", "link_degrade", "rank_death"]
    assert fp.describe() == ("pool_error@5-7:rate=0.5; "
                             "link_degrade@10-18:link=node@cxl,x4.0; "
                             "rank_death@12:rank=3")


@pytest.mark.parametrize("spec", [
    "nonsense",
    "rank_death@12",                   # needs rank=
    "link_degrade@3:factor=2",         # needs link=
    "exorcism@3:rank=1",               # unknown kind
    "pool_error@7-7:rate=1",           # until must be > step
])
def test_fault_plan_rejects_bad_specs(spec):
    with pytest.raises(ValueError):
        FaultPlan.parse(spec)


def test_fault_event_active_windows():
    transient = FaultEvent(kind="link_degrade", step=5, link="node",
                           until_step=8)
    assert [transient.active(s) for s in (4, 5, 7, 8)] == \
        [False, True, True, False]
    death = FaultEvent(kind="rank_death", step=5, rank=1, until_step=8)
    assert death.active(100)            # death ignores until_step


def test_fault_plan_begin_step_drives_emulator_degrades():
    calls = []

    class FakeEmu:
        def set_degrade(self, link, factor):
            calls.append((link, factor))

    fp = FaultPlan.parse("link_degrade@2-4:link=node@cxl,factor=4")
    emu = FakeEmu()
    for s in range(6):
        fresh = fp.begin_step(s, emulator=emu)
        assert bool(fresh) == (s == 2)
    assert calls == [("node@cxl", 4.0), ("node@cxl", 1.0)]
    assert fp.injected == [(2, "link_degrade@2-4:link=node@cxl,x4.0")]


def test_fault_plan_pool_hook_dead_rank_and_seeded_errors():
    def run(seed):
        fp = FaultPlan.parse("rank_death@2:rank=1;pool_error@4-6:rate=0.5",
                             seed=seed)
        outcomes = []
        with fp:
            for s in range(8):
                fp.begin_step(s)
                for r in range(3):
                    try:
                        pool_mod.check_fault("write", rank=r)
                        outcomes.append((s, r, "ok"))
                    except pool_mod.PoolAccessError:
                        outcomes.append((s, r, "fail"))
        assert pool_mod.get_fault_hook() is None    # context uninstalls
        return outcomes

    a, b = run(seed=3), run(seed=3)
    assert a == b                       # seeded: exactly reproducible
    # rank 1 fails every access from its death on, no exceptions
    assert all(o == "fail" for s, r, o in a if r == 1 and s >= 2)
    assert all(o == "ok" for s, r, o in a if r == 1 and s < 2)
    # the error window hit somebody besides the dead rank
    window = [o for s, r, o in a if 4 <= s < 7 and r != 1]
    assert "fail" in window and "ok" in window
    # outside the window live ranks never fail
    assert all(o == "ok" for s, r, o in a if r != 1 and not 4 <= s < 7)


def test_fault_plan_uninstall_leaves_foreign_hook():
    other = lambda op, info: None       # noqa: E731
    fp = FaultPlan.parse("rank_death@0:rank=0")
    fp.install()
    pool_mod.set_fault_hook(other)      # someone else took the seam
    fp.uninstall()                      # must not clobber it
    assert pool_mod.get_fault_hook() is other


# -- failure monitor ------------------------------------------------------

def _drive(mon, steps, dead=(), die_at=0):
    """Pulse + end_step for ``steps`` steps, skipping pulses for
    ``dead`` ranks from ``die_at`` on; returns {step: verdicts}."""
    out = {}
    for s in range(steps):
        for r in range(mon.nranks):
            if r in dead and s >= die_at:
                continue
            mon.heartbeats.pulse(r, s)
        out[s] = mon.end_step(s)
    return out


def test_monitor_confirms_death_at_timeout_plus_patience():
    mon = FailureMonitor(4, heartbeat_timeout=1, patience=2,
                         publish=False)
    verdicts = _drive(mon, 8, dead={2}, die_at=3)
    confirmed = {s: [f.kind for f in v] for s, v in verdicts.items() if v}
    assert confirmed == {5: ["rank_death"]}     # 3 + timeout 1 + patience 2 - 1
    assert verdicts[5][0].rank == 2
    assert verdicts[5][0].detail["last_beat"] == 2
    assert mon.dead_ranks() == [2]
    # a confirmed rank is never re-confirmed
    assert not any(verdicts[s] for s in (6, 7))


def test_monitor_readmits_transient_silence():
    mon = FailureMonitor(4, heartbeat_timeout=1, patience=2,
                         publish=False)
    for s in range(8):
        for r in range(4):
            if r == 1 and s == 3:       # one dropped pulse
                continue
            mon.heartbeats.pulse(r, s)
        assert mon.end_step(s) == []
    assert mon.dead_ranks() == []


def test_monitor_publishes_liveness_transitions_only():
    mon = FailureMonitor(2, heartbeat_timeout=1, patience=2)
    _drive(mon, 7, dead={1}, die_at=2)
    st = runtime.get_rank_liveness(1)
    assert st["alive"] is False and st["suspect"] is True
    assert st["last_beat_step"] == 1
    # the confirmed verdict published once, at the confirmation step,
    # not re-stamped every following step (event-driven registry)
    assert st["step"] == 4
    assert runtime.get_rank_liveness(0)["alive"] is True
    assert runtime.dead_ranks() == [1]


def test_monitor_pool_error_streak_patience():
    mon = FailureMonitor(2, pool_error_patience=3, publish=False)
    kinds = []
    for s in range(10):
        for r in range(2):
            mon.heartbeats.pulse(r, s)
        if s in (1, 4, 5, 6, 7):        # isolated blip, then a streak
            mon.record_pool_error(s)
        kinds.append([f.kind for f in mon.end_step(s)])
    # the isolated error at step 1 never confirms; the streak starting
    # at step 4 confirms once its 3rd consecutive erroring step closes
    assert kinds == [[], [], [], [], [], [], ["pool_errors"], [], [], []]


def test_monitor_pulse_all_skips_confirmed_dead():
    mon = FailureMonitor(4, publish=False)
    assert mon.pulse_all(0) == 4
    mon.confirmed_dead.add(3)
    assert mon.pulse_all(1) == 3
    assert mon.heartbeats.read(3) == 0


def test_monitor_link_penalties_empty_when_healthy():
    mon = FailureMonitor(2, publish=False)
    _drive(mon, 3)
    assert mon.link_penalties() == {}
    assert mon.persistent_links(2) == []


# -- topology surgery -----------------------------------------------------

def test_survivor_topology_shrinks_owning_group():
    topo = survivor_topology(_topo((4, 4)), "node", [5])
    assert topo.level_for("node").shape == (4, 3)
    assert topo.level_for("node").fabric == "cxl"
    assert topo.level_for("pod").fabric == "ib"     # untouched
    topo = survivor_topology(_topo((4, 4)), "node", [0, 1, 7])
    assert topo.level_for("node").shape == (2, 3)


def test_survivor_topology_drops_emptied_group():
    topo = survivor_topology(_topo((2, 4)), "node", [0, 1])
    assert topo.level_for("node").shape == (4,)


def test_survivor_topology_edge_cases():
    with pytest.raises(ValueError, match="no survivors"):
        survivor_topology(_topo((2,)), "node", [0, 1])
    with pytest.raises(ValueError, match="out of range"):
        survivor_topology(_topo((4, 4)), "node", [8])
    with pytest.raises(KeyError):
        survivor_topology(_topo(), "rack", [0])
    # a shape-less level needs the mesh degree passed in
    bare = Topology(levels=(Level(axis="node", fabric="cxl"),))
    with pytest.raises(ValueError, match="pass size="):
        survivor_topology(bare, "node", [1])
    topo = survivor_topology(bare, "node", [1], size=4)
    assert topo.level_for("node").shape == (3,)


def test_failover_topology_flips_cxl_to_ib():
    ib = InfiniBandConfig(link_bw=7.5e9)
    base = Topology(levels=(
        Level(axis="pod", fabric="ib"),
        Level(axis="node", fabric="cxl", ib=ib, shape=(4, 4))))
    topo = failover_topology(base, "node")
    lv = topo.level_for("node")
    assert lv.fabric == "ib"
    assert lv.shape == (4, 4)           # same ranks, new transport
    assert lv.ib is ib                  # the priced-against alternative
    with pytest.raises(ValueError, match="only a cxl level"):
        failover_topology(topo, "node")     # already ib
    with pytest.raises(KeyError):
        failover_topology(base, "rack")


# -- re-planning ----------------------------------------------------------

def _mix(node_size=8):
    call = CollectiveCall(primitive="all_gather", msg_bytes=1 << 20)
    return CollectiveMix(axes=(
        AxisTraffic(axis="dp", size=2, calls=(call,)),
        AxisTraffic(axis="fsdp", size=node_size, calls=(call,))))


def test_replan_rank_death_shrinks_and_rescales_mix():
    failures = [Failure(kind="rank_death", step=8, rank=5)]
    rp = replan(failures, _topo((4, 4)), mix=_mix(node_size=8))
    assert rp.topology.level_for("fsdp").shape == (4, 3)
    assert "survivors on node: -[5] -> 4+3" in rp.reason
    # the mix axis sized like the shrunk level follows the survivors
    assert rp.placement.meta["axes"]["fsdp"] == 7
    assert rp.chosen is not None
    assert rp.plan.entries                      # re-tuned for the topo
    assert "re-plan [" in rp.describe()


def test_replan_persistent_cxl_degrade_fails_over():
    failures = [Failure(kind="link_degraded", step=6, link="node/cxl")]
    rp = replan(failures, _topo((4, 4)),
                link_penalties={"node/cxl": 4.0})
    assert rp.topology.level_for("node").fabric == "ib"
    assert "failover node/cxl -> ib" in rp.reason


def test_replan_requires_actionable_failures():
    with pytest.raises(ValueError, match="no actionable"):
        replan([Failure(kind="pool_errors", step=3)], _topo())
    with pytest.raises(ValueError, match="no actionable"):
        # a degrade on an unknown axis is nothing to act on
        replan([Failure(kind="link_degraded", step=3, link="rack/ib")],
               _topo())


def test_recovery_plan_apply_publishes():
    rp = replan([Failure(kind="rank_death", step=8, rank=5)],
                _topo((4, 4)))
    epoch = runtime.plan_epoch()
    rp.apply()
    assert get_active_topology() is rp.topology
    assert runtime.get_active_plan() is rp.plan
    assert runtime.plan_epoch() == epoch + 1    # hot-swap is versioned


def test_health_penalties_from_registry_shape():
    lh = {"node/cxl": {"degraded": True, "slowdown": 3.7},
          "pod/ib": {"degraded": False, "slowdown": 2.0},
          "gpu/ici": {"degraded": True}}
    assert health_penalties(lh) == {"node/cxl": 3.7, "gpu/ici": 1.0}


# -- penalized placement --------------------------------------------------

def test_link_penalty_exempts_ring_on_cxl():
    lv = Level(axis="node", fabric="cxl")
    pen = {"node/cxl": 8.0}
    assert _link_penalty(lv, "cxl", pen) == 8.0
    assert _link_penalty(lv, "ring", pen) == 1.0    # rides the IB alt
    assert _link_penalty(lv, "cxl", {"cxl": 5.0}) == 5.0  # bare fabric
    assert _link_penalty(lv, "cxl", None) == 1.0


def test_plan_placement_reranks_under_penalty():
    mix = _mix(node_size=8)
    topo = _topo((4, 4))
    healthy = plan_placement(mix, topo)
    hurt = plan_placement(mix, topo, link_penalties={"node/cxl": 64.0})
    assert hurt.meta["link_penalties"] == {"node/cxl": 64.0}
    hit = hurt.best.predicted_exposed_s
    base = healthy.best.predicted_exposed_s
    assert hit >= base                  # the fault can only cost time
    # the same assignment prices worse under the penalty than healthy
    same = [p for p in hurt.ranked
            if p.assignment == healthy.best.assignment]
    assert same and same[0].predicted_exposed_s > base


# -- atomic disk checkpoints ----------------------------------------------

def test_save_is_atomic_and_tmp_is_invisible(tmp_path):
    d = str(tmp_path)
    tree = {"w": np.arange(6, dtype=np.float32).reshape(2, 3)}
    checkpoint.save(d, 4, tree, meta={"loss": 1.5})
    assert checkpoint.latest_step(d) == 4
    # an interrupted save leaves step_<n>.tmp: never a checkpoint
    (tmp_path / "step_00000009.tmp").mkdir()
    assert checkpoint.latest_step(d) == 4
    with pytest.raises(FileNotFoundError, match="interrupted"):
        checkpoint.restore(d, 9, tree)
    # a stale tmp from a died rank doesn't block a re-save
    checkpoint.save(d, 9, tree)
    assert checkpoint.latest_step(d) == 9
    got = checkpoint.restore(d, 9, tree)
    np.testing.assert_array_equal(got["w"], tree["w"])
    assert checkpoint.load_meta(d, 4)["loss"] == 1.5


# -- pool checkpoint store ------------------------------------------------

def _tree(v=0.0):
    return {"params": np.full((4, 4), v, dtype=np.float32),
            "step_count": np.array(int(v), dtype=np.int32)}


def test_pool_store_snapshot_restore_round_trip():
    store = checkpoint.PoolCheckpointStore(capacity_bytes=1 << 16)
    rep = store.snapshot(3, _tree(3.0), meta={"loss": 0.25})
    assert rep["step"] == 3 and rep["retries"] == 0
    assert rep["predicted_write_s"] > 0.0
    tree, meta = store.restore(_tree())
    np.testing.assert_array_equal(tree["params"], _tree(3.0)["params"])
    assert tree["step_count"].item() == 3
    assert meta == {"loss": 0.25}


def test_pool_store_double_buffer_keeps_previous_committed():
    store = checkpoint.PoolCheckpointStore(capacity_bytes=1 << 16)
    a = store.snapshot(1, _tree(1.0))
    b = store.snapshot(2, _tree(2.0))
    assert {a["slot"], b["slot"]} == {0, 1}     # alternating slots
    assert store.latest() == 2
    # snapshot 3 overwrites slot holding step 1, never step 2
    c = store.snapshot(3, _tree(3.0))
    assert c["slot"] == a["slot"]
    tree, _ = store.restore(_tree(), step=2)
    assert float(tree["params"][0, 0]) == 2.0


def test_pool_store_midwrite_death_leaves_restorable_snapshot():
    store = checkpoint.PoolCheckpointStore(capacity_bytes=1 << 16,
                                           retries=2)
    store.snapshot(5, _tree(5.0))

    pool_mod.set_fault_hook(lambda op, info: (_ for _ in ()).throw(
        pool_mod.PoolAccessError("pool down"))
        if op == "ckpt_write" else None)
    with pytest.raises(pool_mod.PoolAccessError):
        store.snapshot(6, _tree(6.0))
    pool_mod.clear_fault_hook()
    # the in-flight slot is STALE, the committed one untouched
    assert store.latest() == 5
    tree, _ = store.restore(_tree())
    assert float(tree["params"][0, 0]) == 5.0


def test_pool_store_retries_absorb_transients():
    fails = {"n": 2}

    def hook(op, info):
        if op == "ckpt_write" and fails["n"] > 0:
            fails["n"] -= 1
            raise pool_mod.PoolAccessError("transient")

    store = checkpoint.PoolCheckpointStore(capacity_bytes=1 << 16,
                                           retries=3)
    pool_mod.set_fault_hook(hook)
    rep = store.snapshot(1, _tree(1.0))
    assert rep["retries"] == 2
    assert store.retried == 2
    assert store.latest() == 1


def test_pool_store_capacity_and_slot_validation():
    with pytest.raises(ValueError, match="slot capacity"):
        checkpoint.PoolCheckpointStore(capacity_bytes=4096).snapshot(
            0, {"w": np.zeros((1024, 1024), dtype=np.float32)})
    with pytest.raises(ValueError, match=">= 2 slots"):
        checkpoint.PoolCheckpointStore(slots=1)


# -- online-tuner recovery knobs ------------------------------------------

def _flat_tuner(**kw):
    from repro import tuner
    grid = tuner.TuneGrid(primitives=("all_gather",),
                          sizes=(4 << 20,), nranks=(4,),
                          slicing_factors=(4,),
                          allreduce_modes=("two_phase",))
    plan = tuner.generate_plan(grid)
    return tuner.OnlineTuner(plan, alpha=0.5, min_samples=2, **kw)


def _feed(ot, seconds, n=3):
    for _ in range(n):
        ot.observe("all_gather", 4 << 20, 4, "cxl", seconds,
                   slicing_factor=4, allreduce_mode="two_phase")


def test_online_tuner_validates_recovery_knobs():
    from repro import tuner
    plan = _flat_tuner().plan
    for bad in ({"decay": 1.0}, {"decay": -0.1},
                {"explore_eps": 1.0}, {"explore_eps": -0.5}):
        with pytest.raises(ValueError):
            tuner.OnlineTuner(plan, **bad)


def test_online_tuner_defaults_keep_refresh_stable():
    ot = _flat_tuner()                  # decay=0, explore_eps=0
    cell = ("all_gather", 4 << 20, 4)
    before = ot.plan.lookup(*cell)
    _feed(ot, before and 1e-5)
    a = ot.refresh()
    b = ot.refresh()
    assert a.lookup(*cell).backend == b.lookup(*cell).backend
    assert ot.explored == []
    assert "decay" not in a.meta["online"]


def test_online_tuner_decay_unlearns_healed_fault():
    ot = _flat_tuner(decay=0.5)
    cell = ("all_gather", 4 << 20, 4)
    original = ot.plan.lookup(*cell).backend
    assert original == "cxl"
    # enough evidence that the first post-decay refresh still trusts
    # the measurement (samples stay past min_samples once decayed)
    _feed(ot, 0.5, n=8)                 # pool measured catastrophically
    ot.plan = ot.refresh()
    assert ot.plan.lookup(*cell).backend != original
    # fault heals, no new samples: stale evidence fades and the
    # calibrated oracle reclaims the cell within a few refreshes
    for _ in range(12):
        ot.plan = ot.refresh()
        if ot.plan.lookup(*cell).backend == original:
            break
    assert ot.plan.lookup(*cell).backend == original


def test_online_tuner_no_decay_never_forgets():
    ot = _flat_tuner()                  # decay=0: verdicts are forever
    cell = ("all_gather", 4 << 20, 4)
    original = ot.plan.lookup(*cell).backend
    _feed(ot, 0.5)
    for _ in range(12):
        ot.plan = ot.refresh()
    assert ot.plan.lookup(*cell).backend != original


def test_online_tuner_exploration_is_seeded():
    def explored_with(seed):
        ot = _flat_tuner(explore_eps=0.9, explore_seed=seed)
        _feed(ot, 1e-4)
        for _ in range(4):
            ot.plan = ot.refresh()
        return [(rc, cand) for rc, _k, cand in ot.explored]

    assert explored_with(7) == explored_with(7)     # reproducible
    assert explored_with(7)                         # and non-empty


# -- the controller's closed loop -----------------------------------------

def test_controller_death_to_hotswap():
    mon = FailureMonitor(8, heartbeat_timeout=1, patience=2,
                         publish=False)
    logs = []
    ctl = ResilienceController(mon, topology=_topo((4, 4)),
                               mix=_mix(node_size=8),
                               axis_sizes={"node": 8}, log=logs.append)
    rps = {}
    for s in range(10):
        for r in range(8):
            if r == 5 and s >= 6:
                continue
            mon.heartbeats.pulse(r, s)
        rp = ctl.step(s, pulse=False)
        if rp is not None:
            rps[s] = rp
    assert list(rps) == [8]             # die@6 + timeout 1 + patience 2
    rp = rps[8]
    assert rp.topology.level_for("fsdp").shape == (4, 3)
    assert ctl.replans == 1
    assert ctl.topology is rp.topology  # controller follows the swap
    assert get_active_topology() is rp.topology
    assert runtime.get_active_plan() is rp.plan
    assert ctl.recoveries[0]["step"] == 8
    assert any("re-plan" in m for m in logs)
    assert ctl.report()["monitor"]["dead_ranks"] == [5]


def test_controller_ignores_unactionable_verdicts():
    mon = FailureMonitor(4, pool_error_patience=2, publish=False)
    logs = []
    ctl = ResilienceController(mon, topology=_topo((2, 2)),
                               log=logs.append)
    for s in range(4):
        mon.pulse_all(s)
        mon.record_pool_error(s)
        assert ctl.step(s, pulse=False) is None
    assert ctl.replans == 0
    assert any("no re-plan" in m for m in logs)


def test_controller_replans_back_on_recovery():
    mon = FailureMonitor(4, publish=False)
    base = _topo((4, 4))
    ctl = ResilienceController(mon, topology=base, log=lambda _m: None)
    failed = ctl._replan(
        6, [Failure(kind="link_degraded", step=6, link="node/cxl")])
    assert failed.topology.level_for("node").fabric == "ib"
    assert ctl.failed_over == {"node/cxl"}
    back = ctl._replan_back(
        11, [Failure(kind="link_recovered", step=11, link="node/cxl")])
    assert back is not None
    assert back.topology is base        # the pool won its level back
    assert ctl.failed_over == set()
    assert get_active_topology() is base
    assert ctl.replans == 2
    # an unrelated recovery is a no-op
    assert ctl._replan_back(
        12, [Failure(kind="link_recovered", step=12, link="pod/ib")]) \
        is None


def test_controller_steps_lost_accounting():
    ctl = ResilienceController(FailureMonitor(2, publish=False),
                               topology=_topo((1, 1)),
                               log=lambda _m: None)
    # detect (6..8 inclusive = 3) + rollback (8 - 4 = 4)
    assert ctl.steps_lost(6, 8, 4) == 7
    assert ctl.steps_lost(6, 8, None) == 3      # no snapshot: detect only
    assert ctl.steps_lost(6, 8, 8) == 3
