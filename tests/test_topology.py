"""core.topology + the per-level tuner path: level fingerprints, spec
parsing, topology-keyed plans (format v3), per-level cost oracles, the
plan version compat chain, and the dry-run helpers (plan report,
roofline-derived overlap windows)."""
import dataclasses
import json

import pytest

from repro import tuner
from repro.core.hw import (CXL_POOL, ICI, INFINIBAND, MiB, CXLPoolConfig,
                           ICIConfig, InfiniBandConfig)
from repro.core.topology import (Level, Topology, clear_active_topology,
                                 default_topology, get_active_topology,
                                 parse_topology, save_topology,
                                 set_active_topology)

TOPO = Topology(levels=(
    Level("pod", "ib", ib=InfiniBandConfig(link_bw=12.5e9)),
    Level("node", "cxl", pool=CXLPoolConfig(device_bw=18e9)),
    Level("gpu", "ici", ici=ICIConfig(link_bw=45e9)),
))

TINY = tuner.TuneGrid(
    primitives=("all_reduce", "all_gather", "broadcast"),
    sizes=(1 * MiB, 16 * MiB), nranks=(2, 4), slicing_factors=(1, 4))


@pytest.fixture(scope="module")
def topo_plan():
    return tuner.generate_plan(TINY, topology=TOPO)


# -- topology mechanics ---------------------------------------------------

def test_level_validation_and_defaults():
    with pytest.raises(ValueError):
        Level("pod", "nvlink")
    lv = Level("node")
    assert lv.fabric == "cxl"
    assert lv.pool_cfg is CXL_POOL and lv.ib_cfg is INFINIBAND
    assert Level("gpu", "ici").ici_cfg is ICI
    assert Level("node", "cxl").backends() == ("ring", "cxl")
    assert Level("pod", "ib").backends() == ("ring",)


def test_topology_validation():
    with pytest.raises(ValueError):
        Topology(levels=())
    with pytest.raises(ValueError):
        Topology(levels=(Level("a"), Level("a")))
    assert TOPO.axes == ("pod", "node", "gpu")
    assert TOPO.level_for("node").fabric == "cxl"
    assert TOPO.level_for("nope") is None
    assert TOPO.covers(("pod", "gpu")) and not TOPO.covers(("pod", "x"))
    assert TOPO.index_of("gpu") == 2


def test_fingerprints_track_fabric_config():
    base = Level("node", "cxl")
    tweaked = Level("node", "cxl",
                    pool=dataclasses.replace(CXL_POOL, device_bw=1e9))
    assert base.fingerprint() != tweaked.fingerprint()
    # same config, different position -> different level key
    t = Topology(levels=(Level("a", "ib"), Level("b", "ib")))
    ka, kb = t.level_key("a"), t.level_key("b")
    assert ka.split(":")[1] == kb.split(":")[1]   # same fabric fp
    assert ka != kb                               # different index
    assert TOPO.fingerprint() != Topology(
        levels=TOPO.levels[:2]).fingerprint()


def test_parse_and_roundtrip(tmp_path):
    t = parse_topology("pod:ib, node:cxl, gpu:ici")
    assert t.axes == ("pod", "node", "gpu")
    assert [lv.fabric for lv in t.levels] == ["ib", "cxl", "ici"]
    # JSON file round-trip preserves per-level config overrides
    path = str(tmp_path / "topo.json")
    save_topology(TOPO, path)
    t2 = parse_topology(path)
    assert t2 == TOPO
    assert t2.fingerprint() == TOPO.fingerprint()
    assert t2.level_for("node").pool.device_bw == 18e9


def test_default_topology():
    t3 = default_topology(("pod", "data", "model"))
    assert [lv.fabric for lv in t3.levels] == ["ib", "cxl", "ici"]
    t2 = default_topology(("data", "model"))
    assert [lv.fabric for lv in t2.levels] == ["cxl", "ici"]
    assert default_topology(("x",)).levels[0].fabric == "cxl"


def test_active_topology_registry():
    clear_active_topology()
    assert get_active_topology() is None
    set_active_topology(TOPO)
    try:
        assert get_active_topology() is TOPO
    finally:
        clear_active_topology()


# -- per-level cost oracle ------------------------------------------------

def test_predict_level_time_prices_each_fabric():
    size, n = 64 * MiB, 4
    t_ib = tuner.predict_level_time(TOPO.levels[0], "all_gather", n, size)
    t_ici = tuner.predict_level_time(TOPO.levels[2], "all_gather", n,
                                     size)
    # the 12.5 GB/s pod IB must be slower than the 45 GB/s ICI ring
    assert t_ib > t_ici > 0
    # cxl level: ring prices the IB alternative, cxl runs the simulator
    lv = TOPO.levels[1]
    t_ring = tuner.predict_level_time(lv, "all_gather", n, size)
    t_cxl = tuner.predict_level_time(lv, "all_gather", n, size,
                                     backend="cxl")
    assert t_ring > 0 and t_cxl > 0 and t_ring != t_cxl
    # the pool schedule does not exist off the pool
    import math
    assert math.isinf(tuner.predict_level_time(
        TOPO.levels[0], "all_gather", n, size, backend="cxl"))
    assert tuner.predict_level_time(lv, "all_gather", 1, size) == 0.0
    with pytest.raises(ValueError):
        tuner.predict_level_time(lv, "all_gather", n, size,
                                 backend="nccl")


# -- topology plans -------------------------------------------------------

def test_topology_plan_cells_are_level_keyed(topo_plan):
    assert topo_plan.fingerprint == TOPO.fingerprint()
    assert topo_plan.topology() == TOPO
    lkeys = topo_plan.levels()
    assert set(lkeys) == {TOPO.level_key(a) for a in TOPO.axes}
    # every cell is level-keyed; only the cxl level may pick 'cxl'
    for k, c in topo_plan.entries.items():
        assert len(k) == 4
        if k[3] != TOPO.level_key("node"):
            assert c.backend == "ring", k
    node_backends = {c.backend for k, c in topo_plan.entries.items()
                     if k[3] == TOPO.level_key("node")}
    assert "cxl" in node_backends


def test_topology_plan_lookup_levels(topo_plan):
    node = topo_plan.lookup("all_reduce", 1 * MiB, 4,
                            level=TOPO.level_key("node"))
    pod = topo_plan.lookup("all_reduce", 1 * MiB, 4,
                           level=TOPO.level_key("pod"))
    assert node is not None and pod is not None and node != pod
    # unknown level with no flat cells -> None (Communicator rings)
    assert topo_plan.lookup("all_reduce", 1 * MiB, 4,
                            level="9:deadbeef") is None
    # flat plans ignore the level arg via the level-agnostic fallback
    flat = tuner.generate_plan(TINY)
    assert flat.lookup("all_reduce", 1 * MiB, 4,
                       level=TOPO.level_key("node")) is not None


def test_topology_plan_roundtrip_and_fingerprint_check(topo_plan,
                                                       tmp_path):
    path = str(tmp_path / "plan.json")
    tuner.save_plan(topo_plan, path)
    loaded = tuner.load_plan(path, topology=TOPO)
    assert loaded.entries == topo_plan.entries
    # the flat pool/ib fingerprint check must not reject topology plans
    loaded2 = tuner.load_plan(path, pool=CXL_POOL, ib=INFINIBAND)
    assert loaded2.fingerprint == TOPO.fingerprint()
    with pytest.raises(ValueError):
        tuner.load_plan(path, topology=Topology(levels=TOPO.levels[:2]))


def test_activate_plan_file_activates_topology(topo_plan, tmp_path):
    path = str(tmp_path / "plan.json")
    tuner.save_plan(topo_plan, path)
    clear_active_topology()
    tuner.clear_active_plan()
    try:
        plan = tuner.activate_plan_file(path)
        assert tuner.get_active_plan() is plan
        assert get_active_topology() == TOPO
    finally:
        tuner.clear_active_plan()
        clear_active_topology()


def test_activate_plan_file_keeps_explicit_topology(topo_plan,
                                                    tmp_path):
    """An explicitly activated topology wins over the plan's embedded
    one; a fingerprint mismatch warns instead of silently ringing."""
    path = str(tmp_path / "plan.json")
    tuner.save_plan(topo_plan, path)
    other = Topology(levels=TOPO.levels[:2])
    tuner.clear_active_plan()
    set_active_topology(other)
    try:
        with pytest.warns(UserWarning, match="topology conflict") as rec:
            tuner.activate_plan_file(path)
        # the warning must name BOTH fingerprints - with only one in
        # the logs a conflict cannot be attributed to either side
        msg = str(rec[0].message)
        assert other.fingerprint() in msg
        assert TOPO.fingerprint() in msg
        assert get_active_topology() is other
    finally:
        tuner.clear_active_plan()
        clear_active_topology()


def test_warn_uncovered_mesh_axes():
    """Topology axis names that don't match the mesh must be surfaced,
    not silently fall back to the untuned flat path."""
    import jax

    from repro.core.topology import warn_uncovered
    mesh = jax.sharding.AbstractMesh((("data", 2), ("model", 4)))
    wrong = parse_topology("node:cxl,gpu:ici")
    with pytest.warns(UserWarning, match="data.*model"):
        assert warn_uncovered(wrong, mesh) == ("data", "model")
    right = parse_topology("data:cxl,model:ici")
    assert warn_uncovered(right, mesh) == ()
    # size-1 axes need no level (nothing to communicate over)
    mesh1 = jax.sharding.AbstractMesh((("pod", 1), ("data", 2)))
    assert warn_uncovered(parse_topology("data:cxl"), mesh1) == ()


def test_never_slower_than_fixed_per_level(topo_plan):
    """The regret guarantee holds per level against that level's own
    fabric oracle."""
    for (prim, bucket, n, lkey), ch in topo_plan.entries.items():
        level = TOPO.levels[int(lkey.split(":")[0])]
        size = 1 << bucket
        if prim == "p2p":
            # the stage handoff's ring baseline is one direct hop
            t_ring = tuner.predict_level_p2p_time(level, size)
        else:
            t_ring = tuner.predict_level_time(level, prim, n, size)
        assert ch.predicted_time <= t_ring * (1 + 1e-9), (prim, lkey, ch)


# -- plan format versioning (satellite) -----------------------------------

def test_unknown_version_raises_plan_version_error(tmp_path):
    doc = {"version": 99, "fingerprint": "x", "entries": []}
    path = tmp_path / "plan.json"
    path.write_text(json.dumps(doc))
    with pytest.raises(tuner.PlanVersionError) as ei:
        tuner.load_plan(str(path))
    msg = str(ei.value)
    assert "99" in msg and "(1, 2, 3, 4, 5, 6)" in msg
    # PlanVersionError is a ValueError: existing catch sites still work
    assert isinstance(ei.value, ValueError)
    with pytest.raises(tuner.PlanVersionError):
        tuner.Plan.from_json({"entries": []})   # missing version


def test_plan_version_compat_chain(tmp_path):
    """v1 -> v2 -> v3 load compatibility: the same entries doc loads
    under every readable version, with the fields each version lacks
    defaulting (v1: no overlap fields; v1/v2: no level keys)."""
    base_entry = {"primitive": "all_gather", "bucket": 20, "nranks": 3,
                  "backend": "cxl", "slicing_factor": 4,
                  "allreduce_mode": "two_phase",
                  "predicted_time": 1e-3, "baseline_time": 2e-3}
    v1 = {"version": 1, "fingerprint": "f", "meta": {},
          "entries": [dict(base_entry)]}
    p1 = tuner.Plan.from_json(v1)
    ch = p1.entries[("all_gather", 20, 3)]
    assert ch.overlap is False and ch.hidden_time == 0.0
    v2 = {"version": 2, "fingerprint": "f", "meta": {},
          "entries": [dict(base_entry, overlap=True, hidden_time=5e-4)]}
    p2 = tuner.Plan.from_json(v2)
    assert p2.entries[("all_gather", 20, 3)].overlap is True
    v3 = {"version": 3, "fingerprint": "f", "meta": {},
          "entries": [dict(base_entry, overlap=True, hidden_time=5e-4,
                           level="1:abc")]}
    p3 = tuner.Plan.from_json(v3)
    assert ("all_gather", 20, 3, "1:abc") in p3.entries
    # a v3 plan saved today re-loads identically (self round-trip)
    for p in (p1, p2, p3):
        again = tuner.Plan.from_json(p.to_json())
        assert again.entries == p.entries


# -- roofline-derived overlap windows (satellite) -------------------------

def _fake_record(flops, wire, calls):
    return {"status": "ok", "cost": {"flops": flops,
                                     "bytes accessed": 0.0},
            "ledger": {"wire_bytes": wire, "collective_calls": calls}}


def test_overlap_windows_from_dryrun():
    rec = _fake_record(
        flops=197e12,  # exactly 1 s of roofline compute on TPU_V5E
        wire={"all_gather": 3e9, "all_reduce": 1e9},
        calls={"all_gather": 30.0, "all_reduce": 5.0})
    win = tuner.overlap_windows_from_dryrun([rec])
    # compute apportioned by byte share / per-primitive launch count
    assert win("all_gather", 1, 2) == pytest.approx(0.75 / 30)
    assert win("all_reduce", 1, 2) == pytest.approx(0.25 / 5)
    assert win("broadcast", 1, 2) == 0.0     # unseen primitive
    # failed / empty records are skipped
    win2 = tuner.overlap_windows_from_dryrun(
        [{"status": "error"}, _fake_record(0.0, {}, {})])
    assert win2("all_gather", 1, 2) == 0.0


def test_generate_plan_with_derived_windows_marks_overlap():
    rec = _fake_record(flops=197e12, wire={"all_gather": 1e9},
                       calls={"all_gather": 2.0})
    win = tuner.overlap_windows_from_dryrun([rec])
    plan = tuner.generate_plan(
        tuner.TuneGrid(primitives=("all_gather", "broadcast"),
                       sizes=(1 * MiB,), nranks=(3,),
                       slicing_factors=(4,)),
        overlap_compute=win)
    ag = plan.lookup("all_gather", 1 * MiB, 3)
    bc = plan.lookup("broadcast", 1 * MiB, 3)
    assert ag.overlap and ag.hidden_time > 0.0
    assert not bc.overlap                    # zero window for broadcast
    assert plan.meta["overlap_compute_s"] == "per-cell"


# -- Communicator topology resolution -------------------------------------

def test_communicator_topology_resolution(topo_plan):
    from repro.core.api import Communicator
    c = Communicator(backend="cxl", topology=TOPO)
    assert c._topo() is TOPO
    clear_active_topology()
    try:
        assert Communicator(backend="cxl")._topo() is None
        set_active_topology(TOPO)
        assert Communicator(backend="cxl")._topo() is TOPO
        clear_active_topology()
        # auto + topology plan: topology rides in via the plan meta
        c2 = Communicator(backend="auto", plan=topo_plan)
        assert c2._topo() == TOPO
    finally:
        clear_active_topology()


def test_communicator_choice_is_level_aware(topo_plan):
    from repro.core import ledger
    from repro.core.api import Communicator
    comm = Communicator(backend="auto", plan=topo_plan, topology=TOPO)
    ledger.reset()
    # the cxl pool level may resolve to the pool schedule; the ib pod
    # level must ring
    comm._choice("all_reduce", 16 * MiB, 4, TOPO, "node")
    comm._choice("all_reduce", 16 * MiB, 4, TOPO, "pod")
    audit = ledger.snapshot()["auto_choices"]
    assert [a["level"] for a in audit] == ["node", "pod"]
    assert [a["fabric"] for a in audit] == ["cxl", "ib"]
    assert audit[1]["backend"] == "ring"
    want = topo_plan.lookup("all_reduce", 16 * MiB, 4,
                            level=TOPO.level_key("node"))
    assert audit[0]["backend"] == want.backend
    assert audit[0]["predicted_time"] == want.predicted_time
    ledger.reset()


def test_flat_fallback_never_drives_non_pool_fabric():
    """A flat (level-agnostic) plan cell reached through the lookup
    fallback must not drive an ib/ici level with the pool schedule:
    the Communicator coerces it to ring."""
    from repro.core import ledger
    from repro.core.api import Communicator
    flat = tuner.Plan(fingerprint="x")
    flat.add("all_gather", 16 * MiB, 4,
             tuner.Choice(backend="cxl", slicing_factor=8))
    comm = Communicator(backend="auto", plan=flat, topology=TOPO)
    ledger.reset()
    be_pod, _, _, _, _ = comm._choice("all_gather", 16 * MiB, 4, TOPO,
                                      "pod")
    be_gpu, _, _, _, _ = comm._choice("all_gather", 16 * MiB, 4, TOPO,
                                      "gpu")
    be_node, _, _, _, _ = comm._choice("all_gather", 16 * MiB, 4, TOPO,
                                       "node")
    assert (be_pod, be_gpu) == ("ring", "ring")
    assert be_node == "cxl"           # the pool level may keep it
    audit = ledger.snapshot()["auto_choices"]
    assert [a["backend"] for a in audit] == ["ring", "ring", "cxl"]
    ledger.reset()


def test_ledger_level_split():
    from repro.core import ledger
    ledger.reset()
    ledger.record("all_gather", 100.0, level="node", fabric="cxl")
    ledger.record("all_gather", 10.0, level="pod", fabric="ib")
    ledger.record("all_gather", 1.0)   # untagged: flat total only
    snap = ledger.snapshot()
    assert snap["level_wire_bytes"] == {
        "node/cxl": {"all_gather": 100.0},
        "pod/ib": {"all_gather": 10.0}}
    assert snap["total_wire_bytes"] == 111.0
    ledger.reset()
    assert ledger.snapshot()["level_wire_bytes"] == {}
