"""Tiny stand-in for the optional ``hypothesis`` dependency.

The property tests only use ``@hp.settings``, ``@hp.given``,
``hp.assume`` and ``st.integers``; when hypothesis is not installed the
test modules fall back to this shim, which drives each property with the
strategy bounds plus a deterministic pseudo-random sample.  Import it as
both ``hp`` and ``st``::

    try:
        import hypothesis as hp
        import hypothesis.strategies as st
    except ImportError:
        import _hypothesis_shim as hp
        import _hypothesis_shim as st
"""
from __future__ import annotations

import functools
import random

DEFAULT_EXAMPLES = 20


class _Unsatisfied(Exception):
    pass


def assume(condition) -> bool:
    if not condition:
        raise _Unsatisfied
    return True


class integers:
    def __init__(self, min_value: int, max_value: int):
        self.lo = min_value
        self.hi = max_value

    def sample(self, rng: random.Random) -> int:
        return rng.randint(self.lo, self.hi)


def given(*strategies):
    def deco(fn):
        @functools.wraps(fn)
        def wrapper(*args, **kwargs):
            n = getattr(wrapper, "_max_examples", DEFAULT_EXAMPLES)
            rng = random.Random(fn.__qualname__)  # deterministic per test
            examples = [tuple(s.lo for s in strategies),
                        tuple(s.hi for s in strategies)]
            while len(examples) < n + 2:
                examples.append(tuple(s.sample(rng) for s in strategies))
            ran = 0
            for ex in examples:
                try:
                    fn(*args, *ex, **kwargs)
                    ran += 1
                except _Unsatisfied:
                    continue
            if not ran:     # mirror hypothesis's Unsatisfied error
                raise RuntimeError(
                    f"{fn.__qualname__}: assume() rejected every "
                    f"generated example")

        # pytest must see a zero-arg test, not the property's params
        # (inspect.signature follows __wrapped__ and would report them
        # as missing fixtures otherwise).
        del wrapper.__wrapped__
        wrapper._hypothesis_shim = True
        return wrapper
    return deco


def settings(deadline=None, max_examples: int = DEFAULT_EXAMPLES, **_kw):
    def deco(fn):
        fn._max_examples = max_examples
        return fn
    return deco
