"""EXPERIMENTS.md validation: the simulated system must reproduce the
paper's headline numbers (Sec. 5.2, 5.3, 5.4, 5.5) within tolerance."""
import numpy as np
import pytest

from benchmarks import fig9_collectives, fig10_scalability, fig11_chunks
from benchmarks.llm_case_study import step_times
from repro.core.hw import COST


@pytest.mark.parametrize("prim,paper",
                         list(fig9_collectives.PAPER_MEANS.items()))
def test_fig9_mean_speedups(prim, paper):
    t = fig9_collectives.table(prim)
    assert t["mean_speedup"] == pytest.approx(paper, rel=0.10), \
        f"{prim}: simulated {t['mean_speedup']:.2f} vs paper {paper}"


def test_fig9_small_message_losses():
    """Paper: ReduceScatter/Scatter/AllToAll lose to IB at 1 MB."""
    for prim in ("reduce_scatter", "scatter", "all_to_all"):
        t = fig9_collectives.table(prim)
        assert t["rows"][0]["speedup"] < 1.0, prim


def test_fig9_allreduce_parity_at_large():
    """Paper: ~1.05x beyond 256 MB."""
    t = fig9_collectives.table("all_reduce")
    large = [r["speedup"] for r in t["rows"][-3:]]
    assert all(0.9 < s < 1.35 for s in large), large


def test_fig10_allreduce_scaling():
    s = fig10_scalability.scaling("all_reduce")
    assert 2.0 <= float(np.mean(s["r6"])) <= 3.2    # paper 2.1-3.0
    assert 8.0 <= float(np.mean(s["r12"])) <= 13.0  # paper 8.7-12.2


def test_fig10_broadcast_scales_mildly():
    s = fig10_scalability.scaling("broadcast")
    assert float(np.mean(s["r6"])) < 1.6            # paper 1.26-1.40
    assert float(np.mean(s["r12"])) < 3.0           # paper ~2.5


def test_fig11_single_chunk_worst():
    times = {f: fig11_chunks.simulator.run_variant(
        "all", "all_gather", 3, 1024 * fig11_chunks.MiB,
        slicing_factor=f).total_time for f in (1, 4, 8)}
    assert times[1] == max(times.values())
    assert times[4] < times[1] and times[8] < times[1]


def test_llm_case_study():
    r = step_times()
    assert r["speedup"] == pytest.approx(1.11, abs=0.03)
    assert COST.cost_ratio == pytest.approx(2.75, abs=0.05)
