"""Observability subsystem: tracer ring buffer + span nesting, metrics
registry export (JSON-lines / Prometheus), profiler-trace matching and
the device-free step emulator, link-health EWMA detection + recovery,
tuner calibration learn/persist/warm-start, ObsSession end-to-end
artifacts, and the report CLI summary."""
import gzip
import json
import os

import numpy as np
import pytest

from repro import tuner
from repro.core import ledger
from repro.core.hw import MiB
from repro.core.topology import parse_topology
from repro.launch import report
from repro.obs import (HealthMonitor, MetricsRegistry, ObsSession,
                       StepEmulator, calibration_drift, disable_tracing,
                       enable_tracing, from_ledger, profiled_timings,
                       trace_timings)
from repro.obs import metrics as obs_metrics
from repro.obs import profile as obs_profile
from repro.obs.trace import Tracer
from repro.tuner import costmodel, runtime

TOPO = parse_topology("pod:ib,node:cxl")


@pytest.fixture(autouse=True)
def _clean_obs_state():
    """Tracing hooks, the ledger, and the link-health registry are
    process-global: every test starts and ends detached/empty."""
    disable_tracing()
    ledger.reset()
    runtime.clear_link_health()
    yield
    disable_tracing()
    ledger.reset()
    runtime.clear_link_health()


def _book(seconds=1e-3, *, primitive="all_gather", backend="cxl",
          level="node", fabric="cxl", calls=1.0):
    ledger.record_timing(primitive, 1 * MiB, 4, backend, seconds,
                         slicing_factor=4, allreduce_mode="two_phase",
                         level=level, fabric=fabric, calls=calls)


def _sample(seconds, *, primitive="all_gather", backend="cxl",
            level="node", fabric="cxl", calls=1.0, msg_bytes=1 * MiB,
            nranks=4):
    return {"primitive": primitive, "msg_bytes": msg_bytes,
            "nranks": nranks, "backend": backend, "slicing_factor": 4,
            "allreduce_mode": "two_phase", "level": level,
            "fabric": fabric, "seconds": float(seconds),
            "calls": float(calls)}


# -- tracer / flight recorder ---------------------------------------------

def test_tracer_ring_buffer_keeps_last_steps():
    tr = Tracer(capacity_steps=4)
    tr.enabled = True
    for i in range(10):
        with tr.step(i):
            tr.instant("tick")
    assert tr.steps_retained() == [6, 7, 8, 9]
    doc = tr.dump()
    steps = [e for e in doc["traceEvents"]
             if e.get("cat") == "step"]
    assert [e["args"]["step"] for e in steps] == [6, 7, 8, 9]
    assert doc["metadata"]["capacity_steps"] == 4
    assert doc["metadata"]["steps_retained"] == [6, 7, 8, 9]


def test_tracer_span_nesting_and_containment():
    tr = Tracer()
    tr.enabled = True
    with tr.step(0):
        with tr.span("gather", phase="fwd"):
            with tr.span("inner"):
                pass
    doc = tr.dump()
    by_name = {e["name"]: e for e in doc["traceEvents"]
               if e.get("ph") == "X"}
    step, outer, inner = (by_name["step 0"], by_name["gather"],
                          by_name["inner"])
    assert outer["args"] == {"phase": "fwd"}
    # timestamp containment: step spans the phases, phases nest
    for parent, child in ((step, outer), (outer, inner)):
        assert parent["ts"] <= child["ts"]
        assert (child["ts"] + child["dur"]
                <= parent["ts"] + parent["dur"] + 1e-6)


def test_tracer_ledger_hook_bridges_collectives(tmp_path):
    tr = enable_tracing(capacity_steps=8)
    with tr.step(3):
        _book(2e-3, calls=2.0)
    doc = tr.dump()
    coll = [e for e in doc["traceEvents"]
            if e.get("cat") == "collective"]
    assert len(coll) == 1
    ev = coll[0]
    assert ev["name"] == "all_gather@cxl [node]"
    assert ev["tid"] == 1 and ev["dur"] == pytest.approx(2e3)
    assert ev["args"]["calls"] == 2.0
    assert ev["args"]["step"] == 3
    # disabled tracer stops receiving (hook detached)
    disable_tracing()
    _book()
    assert sum(1 for e in tr.dump()["traceEvents"]
               if e.get("cat") == "collective") == 1


def test_enable_tracing_twice_does_not_duplicate_hook():
    enable_tracing()
    tr2 = enable_tracing()          # replaces, must unhook the first
    with tr2.step(0):
        _book()
    coll = [e for e in tr2.dump()["traceEvents"]
            if e.get("cat") == "collective"]
    assert len(coll) == 1


def test_tracer_trigger_dumps_anomaly(tmp_path):
    tr = enable_tracing(capacity_steps=4)
    with tr.step(0):
        pass
    out = str(tmp_path / "flight.json")
    tr.trigger("link node/cxl degraded", out)
    assert tr.dumps == 1
    doc = json.load(open(out))
    assert doc["metadata"]["anomalies"][0]["reason"] == \
        "link node/cxl degraded"
    marks = [e for e in doc["traceEvents"] if e.get("cat") == "anomaly"]
    assert marks and marks[0]["ph"] == "i"


# -- metrics registry ------------------------------------------------------

def test_counter_gauge_histogram_basics():
    reg = MetricsRegistry()
    c = reg.counter("repro_steps_total", "steps")
    c.inc()
    c.inc(2.0, phase="fwd")
    assert reg.value("repro_steps_total") == 1.0
    assert reg.value("repro_steps_total", phase="fwd") == 2.0
    with pytest.raises(ValueError):
        c.inc(-1.0)
    g = reg.gauge("repro_plan_epoch")
    g.set(3)
    g.add(2)
    assert reg.value("repro_plan_epoch") == 5.0
    h = reg.histogram("repro_step_seconds", buckets=(0.1, 1.0))
    for v in (0.05, 0.5, 5.0):
        h.observe(v)
    samples = dict(((n, k), v) for n, k, v in h.samples())
    assert samples[("repro_step_seconds_bucket",
                    (("le", "0.1"),))] == 1
    assert samples[("repro_step_seconds_bucket",
                    (("le", "1"),))] == 2          # cumulative
    assert samples[("repro_step_seconds_bucket",
                    (("le", "+Inf"),))] == 3
    assert samples[("repro_step_seconds_count", ())] == 3
    assert samples[("repro_step_seconds_sum", ())] == \
        pytest.approx(5.55)
    # same name, different type: refuse
    with pytest.raises(TypeError):
        reg.gauge("repro_steps_total")
    # idempotent re-registration returns the same family
    assert reg.counter("repro_steps_total") is c


def test_prometheus_and_jsonl_export():
    reg = MetricsRegistry()
    reg.counter("repro_x_total", "help text").inc(3, kind="ag")
    reg.histogram("repro_t_seconds", buckets=(1.0,)).observe(0.5)
    text = reg.to_prometheus()
    assert "# HELP repro_x_total help text" in text
    assert "# TYPE repro_x_total counter" in text
    assert 'repro_x_total{kind="ag"} 3' in text
    assert 'repro_t_seconds_bucket{le="+Inf"} 1' in text
    assert "repro_t_seconds_sum 0.5" in text
    lines = [json.loads(ln) for ln in reg.to_jsonl().splitlines()]
    assert {"name": "repro_x_total", "type": "counter",
            "labels": {"kind": "ag"}, "value": 3.0} in lines


def test_from_ledger_reconciles_with_snapshot():
    snap = {
        "wire_bytes": {"all_gather": 1024.0, "all_reduce": 2048.0},
        "exposed_bytes": {"all_gather": 256.0},
        "hidden_bytes": {"all_gather": 768.0},
        "collective_calls": {"all_gather": 4.0},
        "level_wire_bytes": {"node/cxl": {"all_gather": 1024.0}},
    }
    reg = MetricsRegistry()
    from_ledger(reg, snap)
    assert reg.value("repro_wire_bytes", kind="all_gather") == 1024.0
    assert reg.value("repro_wire_bytes", kind="all_reduce") == 2048.0
    assert reg.value("repro_exposed_bytes", kind="all_gather") == 256.0
    assert reg.value("repro_hidden_bytes", kind="all_gather") == 768.0
    assert reg.value("repro_collective_launches",
                     kind="all_gather") == 4.0
    assert reg.value("repro_level_wire_bytes", level="node",
                     fabric="cxl", kind="all_gather") == 1024.0
    # re-export after a re-trace overwrites (gauges, not counters)
    from_ledger(reg, snap)
    assert reg.value("repro_wire_bytes", kind="all_gather") == 1024.0


def test_observe_timings_histogram_and_busy_counter():
    reg = MetricsRegistry()
    n = obs_metrics.observe_timings(reg, [
        _sample(1e-3, calls=2.0),
        _sample(2e-3, primitive="all_reduce", backend="ring",
                level="pod", fabric="ib"),
    ])
    assert n == 2
    assert reg.value("repro_level_busy_seconds_total", level="node",
                     fabric="cxl") == pytest.approx(2e-3)   # 1e-3 x 2
    assert reg.value("repro_level_busy_seconds_total", level="pod",
                     fabric="ib") == pytest.approx(2e-3)
    hist = reg.histogram("repro_collective_seconds")
    counts = {k: v for name, k, v in hist.samples()
              if name.endswith("_count")}
    key = (("backend", "cxl"), ("level", "node"),
           ("primitive", "all_gather"))
    assert counts[key] == 1


# -- profiler-trace parsing + emulator -------------------------------------

def test_classify_hlo_names():
    assert obs_profile.classify("all-reduce.3") == (True, "all_reduce")
    assert obs_profile.classify("AllGather_7") == (True, "all_gather")
    assert obs_profile.classify("reduce-scatter.0") == \
        (True, "reduce_scatter")
    assert obs_profile.classify("all-to-all.1") == (True, "all_to_all")
    # one cxl collective is a chain of permutes: collective, unmatchable
    assert obs_profile.classify("collective-permute.5") == (True, None)
    assert obs_profile.classify("fusion.12") == (False, None)


def _choices():
    return [
        {"primitive": "all_gather", "msg_bytes": 4 * MiB, "nranks": 4,
         "backend": "cxl", "slicing_factor": 4,
         "allreduce_mode": "two_phase", "level": "node",
         "fabric": "cxl", "calls": 2.0},
        {"primitive": "all_gather", "msg_bytes": 1 * MiB, "nranks": 2,
         "backend": "ring", "slicing_factor": 1,
         "allreduce_mode": "two_phase", "level": "pod", "fabric": "ib",
         "calls": 1.0},
        {"primitive": "all_reduce", "msg_bytes": 1 * MiB, "nranks": 4,
         "backend": "cxl", "slicing_factor": 4,
         "allreduce_mode": "two_phase", "level": "node",
         "fabric": "cxl", "calls": 1.0},
    ]


def test_match_events_walks_expanded_schedule():
    # 3 all_gather launches expected per step: cxl, cxl, ring (calls
    # 2+1); 4 events = one step + cyclic wrap back to the first slot
    events = [{"name": f"all-gather.{i}", "primitive": "all_gather",
               "ts_us": 10.0 * i, "dur_us": 5.0 + i}
              for i in range(4)]
    events.append({"name": "all-reduce.0", "primitive": "all_reduce",
                   "ts_us": 100.0, "dur_us": 7.0})
    events.append({"name": "collective-permute.0", "primitive": None,
                   "ts_us": 200.0, "dur_us": 9.0})
    out = obs_profile.match_events(events, _choices())
    assert len(out) == 5                      # permute chain skipped
    ag = [t for t in out if t["primitive"] == "all_gather"]
    assert [t["msg_bytes"] for t in ag] == \
        [4 * MiB, 4 * MiB, 1 * MiB, 4 * MiB]
    assert [t["backend"] for t in ag] == ["cxl", "cxl", "ring", "cxl"]
    assert all(t["calls"] == 1.0 for t in out)  # one launch per event
    assert ag[0]["seconds"] == pytest.approx(5e-6)
    ar = [t for t in out if t["primitive"] == "all_reduce"]
    assert ar[0]["level"] == "node" and ar[0]["fabric"] == "cxl"


def test_trace_timings_from_gzipped_chrome_trace(tmp_path):
    doc = {"traceEvents": [
        {"ph": "X", "name": "all-reduce.1", "ts": 3.0, "dur": 11.0},
        {"ph": "X", "name": "fusion.2", "ts": 1.0, "dur": 50.0},
        {"ph": "M", "name": "process_name"},
        {"ph": "X", "name": "all-gather.0", "ts": 0.5, "dur": 2.0},
    ]}
    path = str(tmp_path / "t.trace.json.gz")
    with gzip.open(path, "wt") as f:
        json.dump(doc, f)
    out = trace_timings(path, _choices())
    # sorted by ts: the all_gather event lands on the first cxl slot
    assert [t["primitive"] for t in out] == ["all_gather", "all_reduce"]
    assert out[0]["backend"] == "cxl"
    assert out[1]["seconds"] == pytest.approx(11e-6)


def test_profiled_timings_picks_newest_and_books(tmp_path):
    logdir = tmp_path / "prof"
    nested = logdir / "plugins" / "profile" / "run1"
    nested.mkdir(parents=True)
    with open(nested / "host.trace.json", "w") as f:
        json.dump({"traceEvents": [
            {"ph": "X", "name": "all-reduce.0", "ts": 0.0, "dur": 4.0},
        ]}, f)
    out = profiled_timings(str(logdir), _choices(), book=True)
    assert len(out) == 1
    booked = ledger.snapshot()["timings"]
    assert len(booked) == 1
    assert booked[0]["primitive"] == "all_reduce"
    assert booked[0]["seconds"] == pytest.approx(4e-6)
    # empty logdir -> [] (caller falls back to step apportioning)
    assert profiled_timings(str(tmp_path / "nope"), _choices()) == []


def test_step_emulator_prices_with_level_oracle():
    emu = StepEmulator(topology=TOPO, noise_std=0.0, seed=0)
    c = _choices()[0]
    want = costmodel.predict_level_time(
        TOPO.level_for("node"), "all_gather", 4, 4 * MiB,
        backend="cxl", slicing_factor=4, allreduce_mode="two_phase")
    assert emu.time_choice(c) == pytest.approx(want)
    # degrade factors multiply: level axis x fabric kind x wildcard
    emu.set_degrade("node", 4.0)
    emu.set_degrade("cxl", 2.0)
    emu.set_degrade("*", 0.5)
    assert emu.time_choice(c) == pytest.approx(want * 4.0)
    emu.set_degrade("node", 1.0)          # factor 1.0 clears the key
    assert "node" not in emu.degrade
    samples = emu.step_timings(_choices())        # books by default
    assert [t["calls"] for t in samples] == [2.0, 1.0, 1.0]
    assert len(ledger.snapshot()["timings"]) == 3


def test_step_emulator_noise_is_seeded():
    a = StepEmulator(topology=TOPO, noise_std=0.1, seed=7)
    b = StepEmulator(topology=TOPO, noise_std=0.1, seed=7)
    ta = [a.time_choice(c) for c in _choices()]
    tb = [b.time_choice(c) for c in _choices()]
    assert ta == tb
    base = StepEmulator(topology=TOPO).time_choice(_choices()[0])
    assert ta[0] != pytest.approx(base)


# -- link health -----------------------------------------------------------

def test_health_monitor_flags_and_recovers():
    mon = HealthMonitor(threshold=2.0, patience=2, warmup_steps=2,
                        publish=False)
    events = []
    for step in range(20):
        slow = 8 <= step < 12
        t = [_sample(4e-3 if slow else 1e-3),
             _sample(1e-3, primitive="all_reduce", backend="ring",
                     level="pod", fabric="ib")]
        events += mon.observe_step(t, step)
    kinds = [(e["event"], e["link"], e["step"]) for e in events]
    assert ("degraded", "node/cxl", 9) in kinds     # patience=2 -> step 9
    assert any(e[0] == "recovered" and e[1] == "node/cxl"
               for e in kinds)
    assert all(e[1] == "node/cxl" for e in kinds)   # ib never flagged
    deg = next(e for e in events if e["event"] == "degraded")
    assert deg["since_step"] == 8
    assert deg["slowdown"] > 2.0
    assert mon.degraded_links() == []               # recovered by end
    assert mon.report()["node/cxl"]["degraded"] is False


def test_health_baseline_frozen_while_outlying():
    """A persistent slowdown must not launder itself into the baseline:
    with the degradation never lifted, the link stays flagged."""
    mon = HealthMonitor(threshold=2.0, patience=2, warmup_steps=2,
                        publish=False)
    for step in range(30):
        mon.observe_step([_sample(1e-3 if step < 5 else 5e-3)], step)
    assert mon.degraded_links() == ["node/cxl"]
    assert mon.report()["node/cxl"]["slowdown"] > 2.0


def test_health_exports_gauges_and_registry():
    reg = MetricsRegistry()
    mon = HealthMonitor(threshold=2.0, patience=1, warmup_steps=1,
                        registry=reg)
    for step in range(6):
        mon.observe_step([_sample(1e-3 if step < 4 else 9e-3)], step)
    assert reg.value("repro_link_health", level="node",
                     fabric="cxl") == 0.0
    assert reg.value("repro_link_slowdown_ratio", level="node",
                     fabric="cxl") > 2.0
    # published into the plan registry for planners / dry-run reports
    assert runtime.degraded_links() == ["node/cxl"]
    assert runtime.get_link_health("node/cxl")["degraded"] is True


def test_health_ignores_idle_links_and_warmup():
    mon = HealthMonitor(threshold=2.0, patience=1, warmup_steps=3,
                        publish=False)
    # huge jump inside warmup: never flagged
    ev = mon.observe_step([_sample(1e-3)], 0)
    ev += mon.observe_step([_sample(50e-3)], 1)
    assert ev == []
    assert mon.observe_step([], 2) == []            # idle step is a no-op


def test_calibration_drift_flags_both_directions():
    cal = {"levels": [
        {"backend": "cxl", "level": "1:abc", "scale": 4.0,
         "samples": 12.0},
        {"backend": "ring", "level": "0:def", "scale": 1.1,
         "samples": 9.0},
        {"backend": "ring", "level": None, "scale": 0.5,
         "samples": 4.0},
    ]}
    hits = calibration_drift(cal, threshold=1.5)
    assert [(h["backend"], h["scale"]) for h in hits] == \
        [("cxl", 4.0), ("ring", 0.5)]
    assert all("placement" in h["recommendation"] for h in hits)
    assert calibration_drift({}, threshold=1.5) == []
    with pytest.raises(ValueError):
        calibration_drift(cal, threshold=1.0)


# -- tuner calibration: learn -> persist -> warm-start ---------------------

def test_calibration_learns_persists_and_warm_starts():
    plan = tuner.generate_plan(tuner.TuneGrid(
        primitives=("all_gather",), sizes=(1 * MiB,), nranks=(4,),
        slicing_factors=(4,), allreduce_modes=("two_phase",)))
    ch = plan.lookup("all_gather", 1 * MiB, 4)
    oracle = costmodel.predict_time(
        ch.backend, "all_gather", 4, 1 * MiB,
        slicing_factor=ch.slicing_factor,
        allreduce_mode=ch.allreduce_mode)
    ot = tuner.OnlineTuner(plan, min_samples=2)
    ot.observe("all_gather", 1 * MiB, 4, ch.backend, 4.0 * oracle,
               slicing_factor=ch.slicing_factor,
               allreduce_mode=ch.allreduce_mode)
    # below cal_min_samples the scale stays neutral
    assert ot.cal_scale(ch.backend, None, "all_gather") == 1.0
    ot.observe("all_gather", 1 * MiB, 4, ch.backend, 4.0 * oracle,
               slicing_factor=ch.slicing_factor,
               allreduce_mode=ch.allreduce_mode)
    assert ot.cal_scale(ch.backend, None, "all_gather") == \
        pytest.approx(4.0, rel=1e-6)
    exp = ot.calibration_export()
    assert exp["scales"][0]["scale"] == pytest.approx(4.0, rel=1e-6)
    assert exp["levels"][0]["backend"] == ch.backend
    refreshed = ot.refresh()
    assert refreshed.meta["calibration"]["scales"]
    # a fresh tuner over the refreshed plan starts corrected
    ot2 = tuner.OnlineTuner(refreshed, min_samples=2)
    assert ot2.cal_scale(ch.backend, None, "all_gather") == \
        pytest.approx(4.0, rel=1e-6)


# -- ObsSession end-to-end -------------------------------------------------

def test_obs_session_end_to_end(tmp_path):
    metrics_out = str(tmp_path / "run.jsonl")
    trace_out = str(tmp_path / "run.trace.json")
    sess = ObsSession(metrics_out=metrics_out, trace_out=trace_out,
                      trace_steps=8, threshold=2.0, patience=1,
                      warmup_steps=2, log=lambda *_: None)
    for step in range(8):
        slow = step >= 6
        with sess.step_span(step):
            with sess.span("sync", phase="bwd"):
                _book(8e-3 if slow else 1e-3)
        timings = ledger.snapshot()["timings"]
        sess.on_step(step, 0.01, timings=timings,
                     extra={"loss": 2.5})
        ledger.clear_timings()
    sess.on_retune(epoch=2, swapped=True, regret_s=1.5e-4,
                   measured_cells=3)
    summary = sess.finalize(snapshot=ledger.snapshot(),
                            extra={"steps": 8})
    assert summary["degraded_links"] == ["node/cxl"]
    assert summary["steps"] == 8
    assert sess.finalize() == {}                    # idempotent

    events = report.load_events(metrics_out)
    kinds = {e["kind"] for e in events}
    assert {"step", "retune", "health", "metric", "summary"} <= kinds
    steps = [e for e in events if e["kind"] == "step"]
    assert len(steps) == 8 and steps[0]["loss"] == 2.5
    assert steps[0]["timing_samples"] == 1
    health = [e for e in events if e["kind"] == "health"]
    assert health[0]["link"] == "node/cxl"
    assert health[0]["event"] == "degraded"
    retune = next(e for e in events if e["kind"] == "retune")
    assert retune == {"kind": "retune", "epoch": 2, "swapped": True,
                      "regret_s": 1.5e-4, "measured_cells": 3}
    metric = {(e["name"], tuple(sorted(e["labels"].items())))
              : e["value"] for e in events if e["kind"] == "metric"}
    assert metric[("repro_steps_total", ())] == 8.0
    assert metric[("repro_retune_swaps_total", ())] == 1.0
    assert metric[("repro_plan_epoch", ())] == 2.0

    # Prometheus rendering lands next to the jsonl
    prom = open(str(tmp_path / "run.prom")).read()
    assert "repro_steps_total 8" in prom
    assert "# TYPE repro_step_seconds histogram" in prom

    # the degradation triggered an immediate flight-recorder dump, and
    # finalize wrote the final trace
    doc = json.load(open(trace_out))
    assert doc["metadata"]["anomalies"]
    assert "degraded" in doc["metadata"]["anomalies"][0]["reason"]
    assert any(e.get("cat") == "collective"
               for e in doc["traceEvents"])


def test_obs_session_disabled_is_inert(tmp_path):
    sess = ObsSession(log=lambda *_: None)
    assert not sess.enabled
    with sess.step_span(0):
        with sess.span("x"):
            pass
    assert sess.on_step(0, 0.1, timings=[_sample(1.0)]) == []
    sess.on_retune(epoch=1, swapped=False)
    assert sess.finalize() == {}
    assert list(tmp_path.iterdir()) == []


# -- report CLI ------------------------------------------------------------

def test_report_summarize(tmp_path):
    metrics_out = str(tmp_path / "run.jsonl")
    sess = ObsSession(metrics_out=metrics_out, threshold=2.0,
                      patience=1, warmup_steps=2, log=lambda *_: None)
    for step in range(6):
        t = [_sample(6e-3 if step >= 4 else 1e-3, calls=2.0)]
        sess.on_step(step, 0.5 if step == 0 else 0.01, timings=t)
    sess.finalize(snapshot={"wire_bytes": {"all_gather": 4096.0}})
    text = report.summarize(report.load_events(metrics_out))
    assert "steps: 6" in text
    assert "(first step 0.50s, incl. compile)" in text
    assert "all_gather@cxl [node]" in text
    assert "node/cxl" in text
    assert "health: link node/cxl degraded" in text
    assert "degraded links at exit: ['node/cxl']" in text
    assert "trace-time wire bytes/step" in text


def test_obs_session_diag_routes_to_report(tmp_path):
    """A launcher diagnostic routed through ``diag`` is counted,
    persisted as a kind=diag event, and surfaced by the report."""
    metrics_out = str(tmp_path / "run.jsonl")
    logged = []
    sess = ObsSession(metrics_out=metrics_out, health=False,
                      log=logged.append)
    sess.diag("serve", "plan loaded but the engine is unsharded")
    sess.finalize()
    assert logged[0].startswith("[serve] plan loaded")
    events = report.load_events(metrics_out)
    diag = next(e for e in events if e.get("kind") == "diag")
    assert diag["source"] == "serve"
    counted = next(e for e in events
                   if e.get("kind") == "metric"
                   and e["name"] == "repro_diag_total")
    assert counted["value"] == 1
    text = report.summarize(events)
    assert "diagnostics: 1" in text
    assert "[serve] plan loaded but the engine is unsharded" in text


def test_report_summarize_trace(tmp_path):
    tr = enable_tracing(capacity_steps=4)
    with tr.step(0):
        _book()
    tr.trigger("test anomaly")
    path = str(tmp_path / "t.json")
    tr.dump(path)
    text = report.summarize_trace(path)
    assert "steps retained [0]" in text
    assert "1 collective slices" in text
    assert "test anomaly" in text
