"""Slicing-factor chunking + doorbell state machine."""
try:
    import hypothesis as hp
    import hypothesis.strategies as st
except ImportError:              # optional dep: use the local shim
    import _hypothesis_shim as hp
    import _hypothesis_shim as st
import pytest

from repro.core import chunking
from repro.core.doorbell import DOORBELL_BYTES, DoorbellRegion


@hp.given(st.integers(1, 1 << 22), st.integers(1, 64))
def test_split_covers_exactly(total, factor):
    chunks = chunking.split(total, factor)
    assert sum(c.size for c in chunks) == total
    assert chunks[0].offset == 0
    for a, b in zip(chunks, chunks[1:]):
        assert b.offset == a.offset + a.size


@hp.given(st.integers(1, 1 << 20), st.integers(1, 32))
def test_split_granularity(total, factor):
    total4 = total * 4
    chunks = chunking.split(total4, factor, granularity=4)
    for c in chunks[:-1]:
        assert c.offset % 4 == 0 and c.size % 4 == 0


def test_min_chunk_clamp():
    chunks = chunking.split(100_000, 32)  # 32 chunks would be ~3 KB each
    assert len(chunks) <= 100_000 // chunking.MIN_CHUNK_BYTES + 1


def test_granularity_mismatch_raises():
    with pytest.raises(ValueError):
        chunking.split(10, 4, granularity=4)


def test_doorbell_protocol():
    db = DoorbellRegion(8)
    assert not db.is_ready(3)
    db.ring(3)
    assert db.is_ready(3)
    db.reset(3)
    assert not db.is_ready(3)
    assert db.rings == 1 and db.polls == 3
    assert db.flushes == db.rings + db.polls  # every op touches the line


def test_doorbell_addresses_are_index_math():
    db = DoorbellRegion(16)
    for i in range(16):
        assert db.address(i) == i * DOORBELL_BYTES
    with pytest.raises(IndexError):
        db.address(16)
    assert db.region_bytes == 16 * DOORBELL_BYTES
