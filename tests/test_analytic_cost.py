"""Analytic roofline cost model: consistency with 6*N*D model FLOPs and
basic monotonicity."""
import pytest

from benchmarks.analytic_cost import step_cost
from repro.configs import get_config
from repro.launch.dryrun import SHAPES


def test_dense_train_flops_near_model_flops():
    """Analytic FLOPs for a dense arch should sit between 6*N*D (no
    remat, no attention) and ~2x that (remat 4/3 + attention + padding),
    per chip."""
    for arch in ("llama3-8b", "yi-6b", "deepseek-coder-33b"):
        cfg = get_config(arch)
        shape = SHAPES["train_4k"]
        chips, tp = 256, 16
        c = step_cost(arch, shape, chips, tp=tp)
        tokens = shape["seq_len"] * shape["global_batch"]
        model_flops = 6.0 * cfg.param_count() * tokens / chips
        ratio = c.flops / model_flops
        assert 1.0 < ratio < 3.5, (arch, ratio)


def test_moe_token_sharding_reduces_flops():
    shape = SHAPES["train_4k"]
    c16 = step_cost("arctic-480b", shape, 256, tp=16)
    # same chips, replicated dispatch modelled by tp=1 routing factor:
    # compare against granite where tokens always divide tp
    assert c16.flops > 0


def test_decode_cheaper_than_prefill():
    for arch in ("yi-6b", "falcon-mamba-7b", "granite-moe-3b-a800m"):
        pre = step_cost(arch, SHAPES["prefill_32k"], 256)
        dec = step_cost(arch, SHAPES["decode_32k"], 256)
        assert dec.flops < pre.flops / 100, arch


def test_window_caps_long_context_decode():
    dense_long = step_cost("yi-6b", SHAPES["long_500k"], 256)
    dense_32k = step_cost("yi-6b", SHAPES["decode_32k"], 256)
    # batch 1 vs 128 but window 8k vs full 32k cache: per-step flops for
    # long_500k must be far below a linear 16x extrapolation
    assert dense_long.flops < dense_32k.flops


def test_roofline_terms_positive_for_all_records():
    import glob
    import json
    from benchmarks.roofline import terms
    files = glob.glob("experiments/dryrun/*_ring.json")
    if not files:
        pytest.skip("no dry-run records present")
    for f in files[:20]:
        r = json.load(open(f))
        if r["status"] != "ok":
            continue
        t = terms(r)
        assert t["compute_s"] > 0 and t["memory_s"] > 0
        assert t["dominant"] in ("compute", "memory", "collective")
        assert 0 <= t["useful_ratio"] < 4
