"""Unit coverage of the pipeline p2p plumbing outside shard_map: the
point-to-point cost oracles (flat + per-level dispatch), the sweep's
format-v6 ``p2p`` plan cells, plan lookup over level tags, and the
placement mix's pipeline terms (``pp_axis`` handoff traffic + the 1/p
per-layer shrink on the other axes)."""
import math

import pytest

from repro import tuner
from repro.configs import get_config
from repro.core.hw import CXLPoolConfig, ICIConfig, InfiniBandConfig
from repro.core.topology import Level, Topology
from repro.tuner import costmodel
from repro.tuner.placement import CollectiveMix, plan_placement

MiB = 1 << 20


# --------------------------------------------------------------------- #
# cost oracles
# --------------------------------------------------------------------- #

def test_p2p_oracle_basics():
    assert costmodel.predict_p2p_time("ring", 0) == 0.0
    assert costmodel.predict_p2p_time("cxl", 0) == 0.0
    assert costmodel.predict_p2p_time("ring", MiB) > 0.0
    assert costmodel.predict_p2p_time("cxl", MiB) > 0.0
    with pytest.raises(ValueError):
        costmodel.predict_p2p_time("nvlink", 4096)


def test_p2p_oracle_monotone_in_size():
    for backend in ("ring", "cxl"):
        ts = [costmodel.predict_p2p_time(backend, s)
              for s in (4096, 1 << 16, MiB, 16 * MiB)]
        assert ts == sorted(ts), (backend, ts)


def test_p2p_slicing_tradeoff():
    # on the pool, chunking pipelines the consumer read behind the
    # producer write; each chunk pays a doorbell ring + poll, so the
    # win shows on large payloads
    big = 64 * MiB
    assert costmodel.predict_p2p_time("cxl", big, slicing_factor=8) < \
        costmodel.predict_p2p_time("cxl", big, slicing_factor=1)
    # a ring hop has nothing to pipeline against: chunking only adds
    # per-message overhead
    assert costmodel.predict_p2p_time("ring", big, slicing_factor=1) <= \
        costmodel.predict_p2p_time("ring", big, slicing_factor=8)


def test_level_p2p_dispatch():
    cxl = Level("node", "cxl", pool=CXLPoolConfig(device_bw=18e9),
                ib=InfiniBandConfig(link_bw=10e9))
    ib = Level("pod", "ib", ib=InfiniBandConfig(link_bw=2.5e9))
    ici = Level("gpu", "ici", ici=ICIConfig(link_bw=45e9))
    s = MiB
    # cxl level: both backends exist (pool handoff vs the rival IB)
    assert math.isfinite(
        costmodel.predict_level_p2p_time(cxl, s, backend="cxl"))
    assert math.isfinite(
        costmodel.predict_level_p2p_time(cxl, s, backend="ring"))
    # off the pool there is no pool handoff
    assert costmodel.predict_level_p2p_time(ib, s, backend="cxl") \
        == math.inf
    assert costmodel.predict_level_p2p_time(ici, s, backend="cxl") \
        == math.inf
    # the fast ICI hop beats the slow inter-node IB hop
    assert costmodel.predict_level_p2p_time(ici, s) < \
        costmodel.predict_level_p2p_time(ib, s)
    with pytest.raises(ValueError):
        costmodel.predict_level_p2p_time(cxl, s, backend="nvlink")


# --------------------------------------------------------------------- #
# sweep cells + plan lookup
# --------------------------------------------------------------------- #

GRID = tuner.TuneGrid(sizes=(4096, 16 * MiB), nranks=(2, 4),
                      slicing_factors=(1, 4, 8))


def test_sweep_emits_flat_p2p_cells():
    plan = tuner.generate_plan(GRID)
    assert plan.to_json()["version"] == 6
    for size in GRID.sizes:
        for n in GRID.nranks:
            ch = plan.lookup("p2p", size, n)
            assert ch is not None, (size, n)
            assert ch.backend in ("ring", "cxl")
            if ch.backend == "ring":
                # a single hop: nothing to pipeline against
                assert ch.slicing_factor == 1
    # nearest-bucket + nearest-nranks fallback applies to p2p too
    assert plan.lookup("p2p", 5000, 3) is not None


def test_sweep_emits_per_level_p2p_cells():
    topo = Topology(levels=(
        Level("stage", "ib", ib=InfiniBandConfig(link_bw=2.5e9)),
        Level("node", "cxl", pool=CXLPoolConfig(device_bw=18e9),
              ib=InfiniBandConfig(link_bw=10e9)),
    ))
    plan = tuner.generate_plan(GRID, topology=topo)
    ib_key = topo.level_key("stage")
    node_key = topo.level_key("node")
    for lkey in (ib_key, node_key):
        for size in GRID.sizes:
            assert plan.lookup("p2p", size, 2, level=lkey) is not None
    # the ib level has no pool: every p2p cell there must ride ring
    for key, ch in plan.entries.items():
        if key[0] == "p2p" and len(key) == 4 and key[3] == ib_key:
            assert ch.backend == "ring", (key, ch)
    # on the pool level the 16MiB bucket beats the 10GB/s IB rival
    big = plan.lookup("p2p", 16 * MiB, 2, level=node_key)
    assert big.backend == "cxl", big
    # and the round trip preserves the level-tagged cells
    again = tuner.Plan.from_json(plan.to_json())
    assert again.entries == plan.entries


def test_online_refresh_preserves_unmeasured_p2p_cells():
    # no observations: the refresh reprices every cell against the
    # same candidate set the sweep used, so nothing may flip
    plan = tuner.generate_plan(GRID)
    ot = tuner.OnlineTuner(plan, min_samples=1)
    assert not tuner.choices_changed(plan, ot.refresh())


# --------------------------------------------------------------------- #
# placement mix
# --------------------------------------------------------------------- #

def test_for_model_pipeline_terms():
    cfg = get_config("deepseek-coder-33b")
    mix = CollectiveMix.for_model(cfg, {"stage": 4, "model": 4,
                                        "data": 2},
                                  pp_axis="stage", microbatches=8)
    stage = mix.axis("stage")
    assert [c.primitive for c in stage.calls] == ["p2p"]
    # forward activations + backward cotangents: 2 hops per microbatch
    assert stage.calls[0].calls == 16.0
    # pipelining shrinks the other axes' per-layer traffic by 1/p
    base = CollectiveMix.for_model(cfg, {"model": 4, "data": 2})
    assert mix.axis("model").bytes_per_step == pytest.approx(
        base.axis("model").bytes_per_step / 4)
    assert mix.axis("data").bytes_per_step == pytest.approx(
        base.axis("data").bytes_per_step / 4)


def test_placement_prices_pipeline_mix():
    topo = Topology(levels=(
        Level("pod", "ib", ib=InfiniBandConfig(link_bw=2.5e9),
              shape=(2,)),
        Level("node", "cxl", pool=CXLPoolConfig(device_bw=18e9),
              ib=InfiniBandConfig(link_bw=10e9), shape=(4,)),
        Level("gpu", "ici", ici=ICIConfig(link_bw=45e9), shape=(4,)),
    ))
    cfg = get_config("deepseek-coder-33b")
    mix = CollectiveMix.for_model(cfg, {"stage": 4, "model": 4,
                                        "data": 2},
                                  pp_axis="stage", microbatches=8)
    plan = plan_placement(mix, topo)
    assert plan.ranked
    assert math.isfinite(plan.best.predicted_exposed_s)
    assert plan.best.predicted_exposed_s > 0.0


def test_from_dryrun_keeps_p2p_level_attribution():
    rec = {"ledger": {"auto_choices": [
        {"primitive": "p2p", "msg_bytes": 65536, "nranks": 2,
         "backend": "cxl", "slicing_factor": 4,
         "allreduce_mode": "two_phase", "level": "stage",
         "calls": 8.0},
        {"primitive": "all_reduce", "msg_bytes": 4096, "nranks": 4,
         "backend": "ring", "slicing_factor": 1,
         "allreduce_mode": "two_phase", "level": None, "calls": 2.0},
    ]}}
    mix = CollectiveMix.from_dryrun(rec, {"data": 4})
    stage = mix.axis("stage")
    assert stage.calls[0].primitive == "p2p"
    assert stage.calls[0].calls == 8.0
    assert stage.size == 2            # inferred from the audit
    assert mix.axis("data").size == 4
