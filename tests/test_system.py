"""End-to-end behaviour: train a small model until the loss drops,
checkpoint, resume, and serve from the trained weights."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.data.pipeline import SyntheticTokens
from repro.models import model
from repro.models.pcontext import UNSHARDED
from repro.optim import adamw_init
from repro.serving import ServeConfig, ServeEngine
from repro.training import checkpoint
from repro.training.train_loop import TrainConfig, make_train_step, train


@pytest.mark.slow
def test_train_loss_decreases_checkpoint_resume_serve(tmp_path):
    cfg = get_config("llama3.2-1b", smoke=True)
    data = iter(SyntheticTokens(cfg, batch=8, seq=32, seed=0))
    tcfg = TrainConfig(lr=3e-3, warmup=5, total_steps=60, remat=False)

    losses = []
    params, opt_state, metrics = train(
        cfg, tcfg, data, steps=60, log_every=1000,
        log_fn=lambda s: losses.append(s))
    last = float(metrics["loss"])
    # retrace initial loss with a fresh model for the comparison
    p0 = model.init_params(jax.random.key(0), cfg, tp=1,
                           dtype=jnp.float32)
    batch = {k: jnp.asarray(v) for k, v in next(data).items()}
    first, _ = jax.jit(lambda p, b: model.loss_fn(
        p, b, cfg, UNSHARDED, remat=False))(p0, batch)
    assert last < float(first) - 0.3, (float(first), last)

    # checkpoint + byte-exact resume
    checkpoint.save(str(tmp_path), 60, {"params": params})
    like = {"params": jax.tree.map(jnp.zeros_like, params)}
    restored = checkpoint.restore(str(tmp_path), 60, like)["params"]
    step = jax.jit(make_train_step(cfg, tcfg))
    p1, _, m1 = step(params, adamw_init(params), batch)
    p2, _, m2 = step(restored, adamw_init(restored), batch)
    assert float(m1["loss"]) == pytest.approx(float(m2["loss"]),
                                              abs=1e-6)

    # serve from trained weights
    eng = ServeEngine(cfg, params, ServeConfig(max_seq=64))
    out = eng.generate({"tokens": jnp.asarray(
        np.arange(8, dtype=np.int32)[None].repeat(2, 0))},
        max_new_tokens=4)
    assert out.shape == (2, 4) and out.max() < cfg.vocab_size


def test_microbatch_accumulation_matches_full_batch():
    """Grad accumulation (the dry-run's memory lever) must match the
    single-batch step."""
    cfg = get_config("llama3-8b", smoke=True)
    params = model.init_params(jax.random.key(0), cfg, tp=1,
                               dtype=jnp.float32)
    rng = np.random.default_rng(0)
    batch = {"tokens": jnp.asarray(rng.integers(0, cfg.vocab_size,
                                                (8, 16))),
             "labels": jnp.asarray(rng.integers(0, cfg.vocab_size,
                                                (8, 16)))}
    one = jax.jit(make_train_step(cfg, TrainConfig(
        lr=1e-3, warmup=0, clip_norm=None, remat=False, microbatches=1)))
    four = jax.jit(make_train_step(cfg, TrainConfig(
        lr=1e-3, warmup=0, clip_norm=None, remat=False, microbatches=4)))
    p1, _, m1 = one(params, adamw_init(params), batch)
    p4, _, m4 = four(params, adamw_init(params), batch)
    assert float(m1["loss"]) == pytest.approx(float(m4["loss"]),
                                              rel=1e-5)
    # Adam normalizes grad/sqrt(v), so fp summation-order noise in the
    # accumulated grads can move a low-|v| param by O(lr); bound by 2*lr.
    worst = max(jax.tree.leaves(jax.tree.map(
        lambda a, b: float(jnp.max(jnp.abs(a - b))), p1, p4)))
    assert worst < 2e-3, worst
