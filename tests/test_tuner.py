"""Autotuning plan subsystem: serialization round-trip, bucketing +
fallback lookup, fingerprint keying, the never-slower guarantee, and
Communicator(backend='auto') dispatch + ledger audit."""
import dataclasses
import json
import os

import numpy as np
import pytest

from repro import tuner
from repro.core import ledger
from repro.core.api import Communicator, make_communicator
from repro.core.hw import CXL_POOL, INFINIBAND, MiB
from repro.tuner import costmodel

TINY = tuner.TuneGrid(
    primitives=("all_gather", "all_reduce", "broadcast"),
    sizes=(1 * MiB, 16 * MiB), nranks=(2, 3), slicing_factors=(1, 4))


@pytest.fixture(scope="module")
def tiny_plan():
    return tuner.generate_plan(TINY)


# -- plan mechanics -------------------------------------------------------

def test_size_bucket():
    assert tuner.size_bucket(1) == 0
    assert tuner.size_bucket(1024) == 10
    assert tuner.size_bucket(1025) == 10
    assert tuner.size_bucket(2048) == 11
    with pytest.raises(ValueError):
        tuner.size_bucket(0)


def test_roundtrip(tiny_plan, tmp_path):
    path = str(tmp_path / "plan.json")
    tuner.save_plan(tiny_plan, path)
    loaded = tuner.load_plan(path)
    assert loaded.fingerprint == tiny_plan.fingerprint
    assert loaded.entries == tiny_plan.entries
    assert loaded.meta["grid"]["nranks"] == [2, 3]


def test_fingerprint_tracks_hardware(tiny_plan, tmp_path):
    pool2 = dataclasses.replace(CXL_POOL, device_bw=10e9)
    assert tuner.hardware_fingerprint(pool2) != \
        tuner.hardware_fingerprint(CXL_POOL)
    path = str(tmp_path / "plan.json")
    tuner.save_plan(tiny_plan, path)
    # verified load: matching hw ok, mismatched hw refused
    tuner.load_plan(path, pool=CXL_POOL, ib=INFINIBAND)
    with pytest.raises(ValueError):
        tuner.load_plan(path, pool=pool2)


def test_rejects_unknown_version(tiny_plan, tmp_path):
    path = str(tmp_path / "plan.json")
    tuner.save_plan(tiny_plan, path)
    doc = json.load(open(path))
    doc["version"] = 999
    json.dump(doc, open(path, "w"))
    with pytest.raises(ValueError):
        tuner.load_plan(path)


def test_lookup_exact_and_fallback(tiny_plan):
    # exact cell
    ch = tiny_plan.lookup("all_gather", 16 * MiB, 3)
    assert ch is tiny_plan.entries[("all_gather",
                                    tuner.size_bucket(16 * MiB), 3)]
    # size between buckets 20 (1 MiB) and 24 (16 MiB): 5 MiB -> bucket 22,
    # equidistant, ties to the smaller bucket
    ch = tiny_plan.lookup("all_gather", 5 * MiB, 3)
    assert ch is tiny_plan.entries[("all_gather",
                                    tuner.size_bucket(1 * MiB), 3)]
    # unseen nranks -> nearest tuned nranks (8 -> 3)
    ch = tiny_plan.lookup("all_gather", 1 * MiB, 8)
    assert ch is tiny_plan.entries[("all_gather",
                                    tuner.size_bucket(1 * MiB), 3)]
    # untuned primitive -> None
    assert tiny_plan.lookup("scatter", 1 * MiB, 3) is None


def test_auto_never_slower_than_fixed(tiny_plan):
    """The tentpole guarantee: every plan entry's predicted time is <=
    both fixed-ring and fixed-cxl (default knobs) for its cell."""
    for (prim, bucket, n), ch in tiny_plan.entries.items():
        size = 1 << bucket
        if prim == "p2p":
            # the stage handoff's fixed baselines: one direct hop vs
            # the pool write at the default chunking
            t_ring = tuner.predict_p2p_time("ring", size)
            t_cxl = tuner.predict_p2p_time("cxl", size,
                                           slicing_factor=4)
        else:
            t_ring = tuner.predict_time("ring", prim, n, size)
            t_cxl = tuner.predict_time("cxl", prim, n, size,
                                       slicing_factor=4,
                                       allreduce_mode="two_phase")
        best_fixed = min(t_ring, t_cxl)
        assert ch.predicted_time <= best_fixed * (1 + 1e-9), \
            (prim, bucket, n, ch)
        assert ch.baseline_time == pytest.approx(best_fixed, rel=1e-12)


def test_costmodel_two_phase_is_composition():
    t2 = tuner.predict_time("cxl", "all_reduce", 3, 4 * MiB,
                            slicing_factor=4,
                            allreduce_mode="two_phase")
    rs = costmodel._sim_time("reduce_scatter", 3, 4 * MiB, 4, CXL_POOL)
    ag = costmodel._sim_time("all_gather", 3, (4 * MiB) // 3, 4, CXL_POOL)
    assert t2 == pytest.approx(rs + ag)
    assert tuner.predict_time("ring", "all_gather", 1, MiB) == 0.0
    with pytest.raises(ValueError):
        tuner.predict_time("nccl", "all_gather", 3, MiB)


# -- runtime registry + persisted default plan ----------------------------

def test_runtime_cache_persists(tmp_path, monkeypatch):
    monkeypatch.setenv("REPRO_PLAN_CACHE", str(tmp_path))
    tuner.clear_active_plan()
    try:
        plan = tuner.ensure_default_plan(grid=TINY)
        path = tuner.default_plan_path()
        assert os.path.exists(path)
        # a fresh process state must load the persisted plan, not retune
        tuner.clear_active_plan()
        again = tuner.ensure_default_plan(grid=TINY)
        assert again.entries == plan.entries
        assert tuner.get_active_plan() is again
    finally:
        tuner.clear_active_plan()


# -- Communicator(backend='auto') -----------------------------------------

def test_communicator_slicing_factor_validation():
    for bad in (0, -3, 2.5, True):
        with pytest.raises(ValueError):
            Communicator(slicing_factor=bad)
    assert Communicator(slicing_factor=1).slicing_factor == 1


def test_communicator_accepts_auto(tiny_plan):
    c = make_communicator("auto", plan=tiny_plan)
    assert c.backend == "auto" and c.plan is tiny_plan
    # plan is advisory state: excluded from equality
    assert c == make_communicator("auto")


def test_auto_choice_follows_plan_and_audits(tiny_plan):
    comm = Communicator(backend="auto", plan=tiny_plan)
    ledger.reset()
    be, factor, mode, ov, fz = comm._choice("all_gather", 16 * MiB, 3)
    want = tiny_plan.lookup("all_gather", 16 * MiB, 3)
    assert (be, factor, mode, ov, fz) == (
        want.backend, want.slicing_factor, want.allreduce_mode,
        want.overlap, want.fused)
    # untuned primitive falls back to ring with the communicator knobs
    be2, _, _, _, _ = comm._choice("scatter", 1 * MiB, 3)
    assert be2 == "ring"
    audit = ledger.snapshot()["auto_choices"]
    assert [a["primitive"] for a in audit] == ["all_gather", "scatter"]
    assert audit[0]["backend"] == want.backend
    assert audit[0]["nranks"] == 3
    ledger.reset()
    assert ledger.snapshot()["auto_choices"] == []


def test_auto_fixed_backends_do_not_audit():
    ledger.reset()
    comm = Communicator(backend="cxl", slicing_factor=8)
    assert comm._choice("all_gather", MiB, 4) == (
        "cxl", 8, "two_phase", False, False)
    assert ledger.snapshot()["auto_choices"] == []


def test_auto_traces_through_shard_map(tiny_plan):
    """End-to-end: an auto Communicator inside jit/shard_map resolves its
    plan at trace time and still computes the right collective."""
    import jax
    from jax.sharding import PartitionSpec as P

    comm = Communicator(backend="auto", plan=tiny_plan)
    mesh = jax.make_mesh((1,), ("x",))
    ledger.reset()
    f = jax.jit(jax.shard_map(
        lambda a: comm.all_reduce(comm.all_gather(a, "x"), "x"),
        mesh=mesh, in_specs=P("x"), out_specs=P(), check_vma=False))
    x = np.arange(32, dtype=np.float32).reshape(8, 4)
    np.testing.assert_allclose(np.asarray(f(x)), x, rtol=1e-6)
    audit = ledger.snapshot()["auto_choices"]
    assert [a["primitive"] for a in audit] == ["all_gather",
                                               "all_reduce"]
