"""Pallas kernels vs pure-jnp oracles (interpret mode), shape/dtype
sweeps + hypothesis properties."""
try:
    import hypothesis as hp
    import hypothesis.strategies as st
except ImportError:              # optional dep: use the local shim
    import _hypothesis_shim as hp
    import _hypothesis_shim as st
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref

RNG = np.random.default_rng(0)


@pytest.mark.parametrize("n_src", [2, 6, 16])
@pytest.mark.parametrize("length", [512, 4096, 9999])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_chunked_reduce(n_src, length, dtype):
    x = jnp.asarray(RNG.standard_normal((n_src, length)), dtype)
    out = ops.chunked_reduce(x, tile=512)
    want = ref.chunked_reduce_ref(x)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(want, np.float32),
                               rtol=2e-2 if dtype == jnp.bfloat16
                               else 1e-6, atol=1e-2)


@hp.settings(deadline=None, max_examples=15)
@hp.given(st.integers(1, 8), st.integers(1, 100))
def test_chunked_reduce_property(n_src, length):
    x = jnp.asarray(RNG.standard_normal((n_src, length * 8)), jnp.float32)
    out = ops.chunked_reduce(x, tile=256)
    np.testing.assert_allclose(np.asarray(out), np.asarray(x).sum(0),
                               rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("bh,l,d", [(4, 256, 64), (2, 512, 128),
                                    (1, 384, 64)])
@pytest.mark.parametrize("causal,window", [(True, None), (True, 128),
                                           (False, None)])
def test_flash_kernel(bh, l, d, causal, window):
    q = jnp.asarray(RNG.standard_normal((bh, l, d)), jnp.float32)
    k = jnp.asarray(RNG.standard_normal((bh, l, d)), jnp.float32)
    v = jnp.asarray(RNG.standard_normal((bh, l, d)), jnp.float32)
    out = ops.flash_attention(q, k, v, causal=causal, window=window)
    want = ref.flash_attention_ref(q, k, v, causal=causal, window=window)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                               rtol=1e-4, atol=2e-5)


def test_flash_kernel_bf16():
    q = jnp.asarray(RNG.standard_normal((2, 256, 64)), jnp.bfloat16)
    k = jnp.asarray(RNG.standard_normal((2, 256, 64)), jnp.bfloat16)
    v = jnp.asarray(RNG.standard_normal((2, 256, 64)), jnp.bfloat16)
    out = ops.flash_attention(q, k, v)
    want = ref.flash_attention_ref(q, k, v)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(want, np.float32),
                               rtol=5e-2, atol=5e-2)


@pytest.mark.parametrize("b,l,d,n", [(2, 256, 256, 16), (1, 128, 512, 8),
                                     (2, 64, 128, 4)])
def test_ssm_scan_kernel(b, l, d, n):
    x = jnp.asarray(RNG.standard_normal((b, l, d)), jnp.float32)
    dt = jnp.asarray(np.abs(RNG.standard_normal((b, l, d))) * 0.1,
                     jnp.float32)
    a = -jnp.asarray(np.abs(RNG.standard_normal((d, n))), jnp.float32)
    bs = jnp.asarray(RNG.standard_normal((b, l, n)), jnp.float32)
    cs = jnp.asarray(RNG.standard_normal((b, l, n)), jnp.float32)
    dres = jnp.asarray(RNG.standard_normal((d,)), jnp.float32)
    out = ops.ssm_scan(x, dt, a, bs, cs, dres, block_d=128, block_l=64)
    want = ref.ssm_scan_ref(x, dt, a, bs, cs, dres)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                               rtol=1e-4, atol=1e-4)


def test_ssm_scan_state_carries_across_blocks():
    """Splitting L into multiple grid blocks must not reset the state."""
    b, l, d, n = 1, 128, 128, 8
    x = jnp.asarray(RNG.standard_normal((b, l, d)), jnp.float32)
    dt = jnp.asarray(np.abs(RNG.standard_normal((b, l, d))) * 0.1,
                     jnp.float32)
    a = -jnp.ones((d, n), jnp.float32)
    bs = jnp.ones((b, l, n), jnp.float32)
    cs = jnp.ones((b, l, n), jnp.float32)
    dres = jnp.zeros((d,), jnp.float32)
    one_block = ops.ssm_scan(x, dt, a, bs, cs, dres, block_d=128,
                             block_l=128)
    four_blocks = ops.ssm_scan(x, dt, a, bs, cs, dres, block_d=128,
                               block_l=32)
    np.testing.assert_allclose(np.asarray(one_block),
                               np.asarray(four_blocks), rtol=1e-5,
                               atol=1e-5)


@pytest.mark.parametrize("t,d", [(64, 128), (300, 256), (1000, 384)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_rms_norm_kernel(t, d, dtype):
    x = jnp.asarray(RNG.standard_normal((t, d)), dtype)
    scale = jnp.asarray(RNG.standard_normal((d,)), jnp.float32)
    out = ops.rms_norm(x, scale, rows=128)
    want = ref.rms_norm_ref(x, scale)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(want, np.float32),
                               rtol=2e-2 if dtype == jnp.bfloat16
                               else 1e-5, atol=1e-2)
