"""Serving engine: batched generation, greedy determinism, windowed
long-context sessions."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.models import model
from repro.models.pcontext import UNSHARDED
from repro.serving import ServeConfig, ServeEngine

KEY = jax.random.key(0)
RNG = np.random.default_rng(0)


def _engine(arch="llama3.2-1b", **kw):
    cfg = get_config(arch, smoke=True)
    params = model.init_params(KEY, cfg, tp=1, dtype=jnp.float32)
    return cfg, ServeEngine(cfg, params,
                            ServeConfig(max_seq=64, **kw))


def test_greedy_generation_deterministic():
    cfg, eng = _engine()
    prompts = {"tokens": jnp.asarray(
        RNG.integers(0, cfg.vocab_size, (3, 8)))}
    a = eng.generate(prompts, max_new_tokens=6)
    b = eng.generate(prompts, max_new_tokens=6)
    assert a.shape == (3, 6)
    np.testing.assert_array_equal(a, b)
    assert a.max() < cfg.vocab_size


def test_sampled_generation_valid():
    cfg, eng = _engine(temperature=0.8)
    prompts = {"tokens": jnp.asarray(
        RNG.integers(0, cfg.vocab_size, (2, 8)))}
    out = eng.generate(prompts, max_new_tokens=5, seed=3)
    assert out.shape == (2, 5)
    assert out.max() < cfg.vocab_size


def test_ssm_engine_generates():
    cfg, eng = _engine("falcon-mamba-7b")
    prompts = {"tokens": jnp.asarray(
        RNG.integers(0, cfg.vocab_size, (2, 8)))}
    out = eng.generate(prompts, max_new_tokens=4)
    assert out.shape == (2, 4)


def test_windowed_engine_matches_full_early():
    """While the context fits the window, the windowed engine must make
    the same greedy choices as the full-cache engine."""
    cfg, full = _engine()
    _, win = _engine(window=64)
    prompts = {"tokens": jnp.asarray(
        RNG.integers(0, cfg.vocab_size, (2, 8)))}
    np.testing.assert_array_equal(full.generate(prompts, 6),
                                  win.generate(prompts, 6))
