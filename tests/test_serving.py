"""Serving engine: the request-level API (submit/step/poll),
continuous-batching lifecycle (preempt / evict-to-pool / restore /
replay), paged block accounting with shared prefixes, the pooled
prefix cache across engines, and the ``generate()`` compat wrapper's
bitwise equivalence to the old batch API."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.core import ledger
from repro.models import model
from repro.models.pcontext import UNSHARDED
from repro.serving import (BlockManager, PooledKVStore, Request,
                           SamplingParams, Scheduler, ServeConfig,
                           ServeEngine, chain_hashes)

KEY = jax.random.key(0)
RNG = np.random.default_rng(0)


def _engine(arch="llama3.2-1b", **kw):
    cfg = get_config(arch, smoke=True)
    params = model.init_params(KEY, cfg, tp=1, dtype=jnp.float32)
    return cfg, ServeEngine(cfg, params,
                            ServeConfig(max_seq=64, **kw))


def test_greedy_generation_deterministic():
    cfg, eng = _engine()
    prompts = {"tokens": jnp.asarray(
        RNG.integers(0, cfg.vocab_size, (3, 8)))}
    a = eng.generate(prompts, max_new_tokens=6)
    b = eng.generate(prompts, max_new_tokens=6)
    assert a.shape == (3, 6)
    np.testing.assert_array_equal(a, b)
    assert a.max() < cfg.vocab_size


def test_sampled_generation_valid():
    cfg, eng = _engine(temperature=0.8)
    prompts = {"tokens": jnp.asarray(
        RNG.integers(0, cfg.vocab_size, (2, 8)))}
    out = eng.generate(prompts, max_new_tokens=5, seed=3)
    assert out.shape == (2, 5)
    assert out.max() < cfg.vocab_size


def test_ssm_engine_generates():
    cfg, eng = _engine("falcon-mamba-7b")
    prompts = {"tokens": jnp.asarray(
        RNG.integers(0, cfg.vocab_size, (2, 8)))}
    out = eng.generate(prompts, max_new_tokens=4)
    assert out.shape == (2, 4)


def test_windowed_engine_matches_full_early():
    """While the context fits the window, the windowed engine must make
    the same greedy choices as the full-cache engine."""
    cfg, full = _engine()
    _, win = _engine(window=64)
    prompts = {"tokens": jnp.asarray(
        RNG.integers(0, cfg.vocab_size, (2, 8)))}
    np.testing.assert_array_equal(full.generate(prompts, 6),
                                  win.generate(prompts, 6))


# -- scheduler / block-manager policy (no model, no jit) -------------------


def test_block_manager_shared_prefix_refcounts():
    bm = BlockManager(8, 4)
    h = chain_hashes(tuple(range(8)), 4)     # two complete blocks
    a = bm.alloc("a", 8, h)
    b = bm.alloc("b", 8, h)
    assert a == b                            # hash-shared prompt blocks
    assert bm.used_blocks == 2
    assert bm.shared_block_hits == 2
    assert all(bm.refcount(blk) == 2 for blk in a)
    # growth past the hashed prefix is private
    bm.append("b", 1)
    assert bm.used_blocks == 3
    assert bm.refcount(bm.table("b")[-1]) == 1
    bm.free("a")
    assert bm.used_blocks == 3               # b still holds the prefix
    bm.free("b")
    assert bm.used_blocks == 0


def test_scheduler_continuous_policy():
    s = Scheduler(2, BlockManager(100, 4))
    r = [s.submit(Request(id=f"r{i}", tokens=(1, 2, 3)))
         for i in range(3)]
    assert [a.state.req.id
            for a in s.admissions(lambda st: True)] == ["r0", "r1"]
    # newest running request is the eviction victim, and a preempted
    # request resumes before fresh waiting work
    assert s.pick_victim().req.id == "r1"
    assert s.pick_victim(exclude=(r[1],)).req.id == "r0"
    s.preempt(r[1])
    assert r[1].status == "preempted" and r[1].preemptions == 1
    assert [a.state.req.id
            for a in s.admissions(lambda st: True)] == ["r1"]
    s.finish(r[0])
    assert [a.state.req.id
            for a in s.admissions(lambda st: True)] == ["r2"]
    s.finish(r[1])
    s.finish(r[2])
    assert s.idle


def test_scheduler_transactional_reserve():
    """A failing reserve leaves the candidate queued (no slot leak)."""
    s = Scheduler(2, BlockManager(100, 4))
    s.submit(Request(id="r0", tokens=(1,)))
    assert s.admissions(lambda st: False) == []
    assert len(s.waiting) == 1 and len(s._free_slots) == 2
    assert [a.state.req.id
            for a in s.admissions(lambda st: True)] == ["r0"]


def test_scheduler_static_gates_admission():
    s = Scheduler(2, BlockManager(100, 4), mode="static")
    for i in range(4):
        s.submit(Request(id=f"r{i}", tokens=(1,)))
    batch = s.admissions(lambda st: True)
    assert len(batch) == 2
    assert s.admissions(lambda st: True) == []   # not drained yet
    s.finish(batch[0].state)
    assert s.admissions(lambda st: True) == []   # still one running
    s.finish(batch[1].state)
    assert len(s.admissions(lambda st: True)) == 2


# -- request-level API -----------------------------------------------------


def test_request_api_streaming():
    cfg, eng = _engine()
    toks = RNG.integers(0, cfg.vocab_size, 8)
    rid = eng.submit(Request(id="s0", tokens=toks, max_new_tokens=5))
    status, fresh = eng.poll(rid)
    assert status == "waiting" and fresh == []
    with pytest.raises(ValueError):
        eng.submit(Request(id="s0", tokens=toks))   # duplicate id
    seen = []
    busy = True
    while busy:
        busy = eng.step()
        status, fresh = eng.poll(rid)
        seen += fresh
    assert status == "finished" and len(seen) == 5
    assert max(seen) < cfg.vocab_size
    with pytest.raises(KeyError):
        eng.poll(rid)            # drained requests drop out of poll


def test_generate_is_thin_wrapper_greedy():
    cfg, eng = _engine()
    _, ref = _engine()
    toks = RNG.integers(0, cfg.vocab_size, (3, 8))
    out = ref.generate({"tokens": jnp.asarray(toks)}, max_new_tokens=6)
    sp = SamplingParams(temperature=0.0, seed=0)
    for b in range(3):
        eng.submit(Request(id=f"m{b}", tokens=toks[b], sampling=sp,
                           max_new_tokens=6))
    while eng.step():
        pass
    rows = [eng.poll(f"m{b}")[1] for b in range(3)]
    np.testing.assert_array_equal(out, np.asarray(rows))


def test_generate_is_thin_wrapper_sampled():
    cfg, eng = _engine(temperature=0.8)
    _, ref = _engine(temperature=0.8)
    toks = RNG.integers(0, cfg.vocab_size, (2, 8))
    out = ref.generate({"tokens": jnp.asarray(toks)},
                       max_new_tokens=5, seed=3)
    sp = SamplingParams(temperature=0.8, seed=3)
    for b in range(2):
        eng.submit(Request(id=f"m{b}", tokens=toks[b], sampling=sp,
                           max_new_tokens=5))
    while eng.step():
        pass
    rows = [eng.poll(f"m{b}")[1] for b in range(2)]
    np.testing.assert_array_equal(out, np.asarray(rows))


# -- KV tiering: preemption-by-eviction ------------------------------------

_TIGHT = dict(decode_slots=2, kv_block_tokens=4, hbm_budget_blocks=6)


def test_eviction_to_pool_restores_bitwise():
    cfg, eng = _engine(kv_placement="pool", **_TIGHT)
    toks = RNG.integers(0, cfg.vocab_size, (3, 8))
    out = eng.generate({"tokens": jnp.asarray(toks)}, 6)
    assert eng.counters["evictions"] > 0
    assert eng.counters["restores"] > 0
    assert eng.counters["replays"] == 0
    _, ref = _engine(decode_slots=2, kv_block_tokens=4)  # roomy HBM
    exp = ref.generate({"tokens": jnp.asarray(toks)}, 6)
    assert ref.counters["evictions"] == 0
    np.testing.assert_array_equal(out, exp)


def test_eviction_recompute_replays_bitwise():
    cfg, eng = _engine(kv_placement="recompute", **_TIGHT)
    toks = RNG.integers(0, cfg.vocab_size, (3, 8))
    out = eng.generate({"tokens": jnp.asarray(toks)}, 6)
    assert eng.counters["evictions"] > 0
    assert eng.counters["replays"] > 0
    assert eng.counters["restores"] == 0
    _, ref = _engine(decode_slots=2, kv_block_tokens=4)
    exp = ref.generate({"tokens": jnp.asarray(toks)}, 6)
    np.testing.assert_array_equal(out, exp)


def test_ssm_whole_image_eviction_bitwise():
    """SSM state has no seq axis: eviction serializes the whole image
    and must still restore bitwise."""
    cfg, eng = _engine("falcon-mamba-7b", kv_placement="pool", **_TIGHT)
    toks = RNG.integers(0, cfg.vocab_size, (3, 8))
    out = eng.generate({"tokens": jnp.asarray(toks)}, 6)
    assert eng.counters["evictions"] > 0
    _, ref = _engine("falcon-mamba-7b", decode_slots=2,
                     kv_block_tokens=4)
    exp = ref.generate({"tokens": jnp.asarray(toks)}, 6)
    np.testing.assert_array_equal(out, exp)


def test_static_scheduler_matches_continuous():
    cfg, eng = _engine(scheduler="static", decode_slots=2)
    _, ref = _engine(decode_slots=2)
    toks = {"tokens": jnp.asarray(
        RNG.integers(0, cfg.vocab_size, (3, 8)))}
    np.testing.assert_array_equal(eng.generate(toks, 5),
                                  ref.generate(toks, 5))


def test_budget_too_small_raises():
    cfg, eng = _engine(decode_slots=2, kv_block_tokens=4,
                       hbm_budget_blocks=1)
    eng.submit(Request(id="big",
                       tokens=RNG.integers(0, cfg.vocab_size, 8)))
    with pytest.raises(MemoryError):
        eng.step()


def test_kv_block_plan_cell_overrides_oracle(tmp_path):
    """A kv_block cell written by ``tune --kv-block-bytes`` must win
    over the live oracle (the plan->serve contract)."""
    from repro.tuner import save_plan
    from repro.tuner.plan import Choice, Plan, hardware_fingerprint
    plan = Plan(fingerprint=hardware_fingerprint())
    plan.add("kv_block", 1 << 16, 1,
             Choice(backend="recompute", slicing_factor=1,
                    allreduce_mode="kv_tier"))
    path = str(tmp_path / "plan.json")
    save_plan(plan, path)
    cfg, eng = _engine(plan_path=path, **_TIGHT)
    toks = RNG.integers(0, cfg.vocab_size, (3, 8))
    ledger.reset()
    eng.generate({"tokens": jnp.asarray(toks)}, 6)
    assert eng.counters["evictions"] > 0
    assert eng.counters["replays"] > 0      # plan forced recompute
    assert eng.counters["restores"] == 0
    cells = [c for c in ledger.snapshot()["auto_choices"]
             if c["primitive"] == "kv_block"]
    assert cells and all(c["backend"] == "recompute" for c in cells)


# -- pooled prefix sharing -------------------------------------------------


def test_pooled_prefix_sharing_across_engines():
    """Engine A publishes its prompt's blocks; engine B (sharing the
    pool) restores them instead of prefilling, bit-identically."""
    cfg = get_config("llama3.2-1b", smoke=True)
    params = model.init_params(KEY, cfg, tp=1, dtype=jnp.float32)
    scfg = ServeConfig(max_seq=64, decode_slots=2, kv_block_tokens=8,
                       prefix_sharing=True)
    a = ServeEngine(cfg, params, scfg)
    toks = RNG.integers(0, cfg.vocab_size, (1, 32))
    exp = a.generate({"tokens": jnp.asarray(toks)}, 4)
    assert a.counters["prefix_publishes"] == 4   # 32 tok / 8-tok blocks
    assert a.counters["prefix_hits"] == 0
    b = ServeEngine(cfg, params, scfg, pool=a.pool)
    ledger.reset()
    got = b.generate({"tokens": jnp.asarray(toks)}, 4)
    # restore is capped at 3 blocks: >= 1 prompt token must be
    # teacher-forced to produce the logits the first sample needs
    assert b.counters["prefix_hits"] == 1
    assert b.counters["prefix_hit_tokens"] == 24
    assert b.counters["prefills"] == 0
    np.testing.assert_array_equal(got, exp)
    cells = [c for c in ledger.snapshot()["auto_choices"]
             if c["primitive"] == "kv_prefix"]
    assert len(cells) == 1 and cells[0]["backend"] == "pool"


def test_prefix_store_doorbell_and_refcount_protocol():
    """put commits via the doorbell; pinned entries survive reclaim."""
    pool = PooledKVStore(4 << 16, block_bytes=1 << 16, max_entries=4)
    assert pool.put("a", bytes(1 << 16))
    assert pool.put("b", bytes(1 << 16))
    pool.acquire("a")
    # filling the budget reclaims LRU *unpinned* entries only
    assert pool.put("c", bytes(1 << 16))
    assert pool.put("d", bytes(1 << 16))
    assert pool.put("e", bytes(1 << 16))
    assert "a" in pool and pool.get("a") == bytes(1 << 16)
    assert "b" not in pool                   # LRU, unpinned: reclaimed
    with pytest.raises(ValueError):
        pool.remove("a")                     # still referenced
    pool.release("a")
    pool.remove("a")
    assert "a" not in pool
