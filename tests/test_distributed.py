"""Multi-device tests run in a subprocess so the forced host-device
count never leaks into the main pytest process."""
import os
import subprocess
import sys

import pytest

HERE = os.path.dirname(__file__)
ROOT = os.path.dirname(HERE)


@pytest.mark.slow
def test_mesh_runner():
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["PYTHONPATH"] = os.path.join(ROOT, "src") + os.pathsep + \
        env.get("PYTHONPATH", "")
    proc = subprocess.run(
        [sys.executable, os.path.join(HERE, "_mesh_runner.py")],
        env=env, capture_output=True, text=True, timeout=3600)
    sys.stdout.write(proc.stdout[-4000:])
    sys.stderr.write(proc.stderr[-4000:])
    assert proc.returncode == 0
    assert "MESH RUNNER: ALL OK" in proc.stdout


@pytest.mark.slow
def test_dryrun_reduced_mesh():
    """End-to-end dry-run plumbing on a reduced mesh: one arch per
    family, every shape, both mesh topologies."""
    env = dict(os.environ)
    env["REPRO_DRYRUN_DEVICES"] = "8"
    env["PYTHONPATH"] = os.path.join(ROOT, "src") + os.pathsep + \
        env.get("PYTHONPATH", "")
    for arch in ("yi-6b", "granite-moe-3b-a800m", "falcon-mamba-7b"):
        proc = subprocess.run(
            [sys.executable, "-m", "repro.launch.dryrun", "--arch", arch,
             "--shape", "all", "--both-meshes", "--out",
             "/tmp/dryrun_pytest"],
            env=env, capture_output=True, text=True, timeout=3600,
            cwd=ROOT)
        assert proc.returncode == 0, proc.stdout[-3000:]
