"""Trace-time wire-byte ledger: scale nesting, per-primitive formulas,
and integration with a traced Communicator program."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import ledger
from repro.core.api import Communicator


def setup_function(_):
    ledger.reset()


def test_scale_nesting():
    ledger.record("x", 10)
    with ledger.scale(3):
        ledger.record("x", 10)
        with ledger.scale(2):
            ledger.record("x", 10)
    ledger.record("x", 10)
    snap = ledger.snapshot()
    assert snap["wire_bytes"]["x"] == 10 + 30 + 60 + 10
    assert snap["counts"]["x"] == 4


def test_scale_restores_on_exception():
    try:
        with ledger.scale(5):
            raise RuntimeError
    except RuntimeError:
        pass
    ledger.record("x", 1)
    assert ledger.snapshot()["wire_bytes"]["x"] == 1


def test_nbytes():
    assert ledger.nbytes(jnp.zeros((4, 8), jnp.bfloat16)) == 64
    assert ledger.nbytes(jax.ShapeDtypeStruct((3,), jnp.float32)) == 12


def test_communicator_records_ring_formulas():
    """Trace (not run) a shard_map program; check the ledger totals match
    the ring wire formulas for an 8-way axis."""
    import os
    if jax.device_count() < 8:
        pytest.skip("needs forced host devices; covered by mesh runner")
    from jax.sharding import PartitionSpec as P
    mesh = jax.make_mesh((8,), ("x",))
    comm = Communicator()

    def f(a):
        b = comm.all_reduce(a, "x")              # 2*s*(7/8)
        c = comm.all_gather(a, "x")              # s*7
        d = comm.reduce_scatter(a, "x")          # s*(7/8)
        return b, c, d

    ledger.reset()
    jax.jit(jax.shard_map(f, mesh=mesh, in_specs=P("x"),
                          out_specs=(P("x"), P(), P("x")),
                          check_vma=False)).lower(
        jax.ShapeDtypeStruct((64, 4), jnp.float32))
    s = 8 * 4 * 4  # local shard bytes: (8,4) f32
    snap = ledger.snapshot()["wire_bytes"]
    assert snap["all_reduce"] == pytest.approx(2 * s * 7 / 8)
    assert snap["all_gather"] == pytest.approx(s * 7)
    assert snap["reduce_scatter"] == pytest.approx(s * 7 / 8)
