"""Functional pool emulation vs numpy oracles: all 8 primitives, nranks
sweeps, slicing factors, plus hypothesis property tests.  Also checks the
structural invariants (no overlapping pool writes - enforced inside
execute; doorbell deadlock freedom)."""
try:
    import hypothesis as hp
    import hypothesis.strategies as st
except ImportError:              # optional dep: use the local shim
    import _hypothesis_shim as hp
    import _hypothesis_shim as st
import numpy as np
import pytest

from repro.core import pool, schedule as sched

TOL = dict(rtol=1e-4, atol=1e-5)
RNG = np.random.default_rng(0)


def _x(n, e):
    return RNG.standard_normal((n, e)).astype(np.float32)


@pytest.mark.parametrize("nranks", [2, 3, 4, 6, 8, 12])
@pytest.mark.parametrize("factor", [1, 4, 8])
def test_all_primitives(nranks, factor):
    e = 480
    x = _x(nranks, e)
    np.testing.assert_allclose(
        pool.run_collective("all_reduce", x, slicing_factor=factor),
        np.tile(x.sum(0), (nranks, 1)), **TOL)
    np.testing.assert_allclose(
        pool.run_collective("reduce_scatter", x, slicing_factor=factor),
        x.sum(0).reshape(nranks, -1), **TOL)
    out = pool.run_collective("all_gather", x, slicing_factor=factor)
    for r in range(nranks):
        np.testing.assert_array_equal(out[r].reshape(nranks, e), x)
    out = pool.run_collective("all_to_all", x, slicing_factor=factor)
    ref = x.reshape(nranks, nranks, e // nranks).transpose(
        1, 0, 2).reshape(nranks, e)
    np.testing.assert_array_equal(out, ref)
    np.testing.assert_allclose(
        pool.run_collective("reduce", x, root=nranks - 1,
                            slicing_factor=factor)[nranks - 1],
        x.sum(0), **TOL)
    out = pool.run_collective("gather", x, root=0,
                              slicing_factor=factor)
    np.testing.assert_array_equal(out[0].reshape(nranks, e), x)
    out = pool.run_collective("broadcast", x, root=0,
                              slicing_factor=factor)
    np.testing.assert_array_equal(out, np.tile(x[0], (nranks, 1)))
    z = _x(nranks, nranks * e)
    np.testing.assert_array_equal(
        pool.run_collective("scatter", z, root=0,
                            slicing_factor=factor),
        z[0].reshape(nranks, -1))


@hp.settings(deadline=None, max_examples=25)
@hp.given(st.integers(2, 8), st.integers(1, 40), st.integers(1, 8),
          st.integers(0, 7))
def test_property_allreduce_and_gather(nranks, width, factor, root):
    hp.assume(root < nranks)
    e = width * nranks * 4  # divisible for segmented primitives
    x = RNG.standard_normal((nranks, e)).astype(np.float32)
    np.testing.assert_allclose(
        pool.run_collective("all_reduce", x, slicing_factor=factor),
        np.tile(x.sum(0), (nranks, 1)), **TOL)
    out = pool.run_collective("gather", x, root=root,
                              slicing_factor=factor)
    np.testing.assert_array_equal(out[root].reshape(nranks, e), x)


def test_rooted_type_uses_round_robin_striping():
    # message large enough that the min-chunk clamp keeps 6 chunks
    s = sched.build("broadcast", 3, 6 * 64 * 1024, num_devices=6,
                    device_capacity=1 << 22, slicing_factor=6,
                    granularity=1)
    devs = [op.device for op in s.writes[0]]
    assert devs == [0, 1, 2, 3, 4, 5]


def test_n_to_n_respects_rank_partitions():
    s = sched.build("all_gather", 3, 6 * 1024, num_devices=6,
                    device_capacity=1 << 20, slicing_factor=4)
    for r in range(3):
        my_devs = {op.device for op in s.writes[r]}
        assert my_devs <= {2 * r, 2 * r + 1}   # 2 devices per rank


def test_read_rotation_starts_at_next_rank():
    s = sched.build("all_gather", 4, 4 * 1024, num_devices=6,
                    device_capacity=1 << 20, slicing_factor=1)
    for r in range(4):
        producers = [op.producer for op in s.reads[r]]
        assert producers[0] == (r + 1) % 4


def test_naive_placement_hotspots_device0():
    s = sched.build("all_gather", 3, 64 * 1024, num_devices=6,
                    device_capacity=1 << 30, slicing_factor=1,
                    placement="naive")
    assert {op.device for op in s.all_writes()} == {0}
