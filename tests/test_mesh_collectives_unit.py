"""Single-device unit behaviour of the mesh-collective helpers (the
multi-device semantics are covered by tests/_mesh_runner.py)."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import mesh_collectives as mc
from repro.core.api import Communicator, make_communicator


def test_split_chunks_divisible():
    x = jnp.arange(12.0).reshape(12, 1)
    chunks = mc._split_chunks(x, 4)
    assert len(chunks) == 4
    np.testing.assert_array_equal(
        np.concatenate([np.asarray(c) for c in chunks]), np.asarray(x))


def test_split_chunks_non_divisible_falls_back():
    x = jnp.arange(10.0)
    assert len(mc._split_chunks(x, 4)) == 1   # 10 % 4 != 0


def test_split_chunks_scalar_and_single():
    assert len(mc._split_chunks(jnp.float32(1.0), 4)) == 1
    assert len(mc._split_chunks(jnp.arange(8.0), 1)) == 1


def test_ring_perm():
    assert mc._ring_perm(4) == [(0, 1), (1, 2), (2, 3), (3, 0)]
    assert mc._ring_perm(4, shift=2) == [(0, 2), (1, 3), (2, 0), (3, 1)]


def test_communicator_validation():
    with pytest.raises(ValueError):
        Communicator(backend="nccl")
    with pytest.raises(ValueError):
        Communicator(allreduce_mode="ring")
    c = make_communicator("cxl", slicing_factor=8,
                          allreduce_mode="faithful")
    assert c.backend == "cxl" and c.slicing_factor == 8


def test_axis_size_one_is_identity():
    """All collectives must be exact no-ops over a size-1 axis (the
    single-pod 'pod' dimension)."""
    import jax
    from jax.sharding import PartitionSpec as P
    mesh = jax.make_mesh((1,), ("solo",))
    comm = Communicator(backend="cxl")
    x = jnp.arange(16.0).reshape(8, 2)
    for fn in (lambda a: comm.all_reduce(a, "solo"),
               lambda a: comm.all_gather(a, "solo"),
               lambda a: comm.reduce_scatter(a, "solo"),
               lambda a: comm.all_to_all(a, "solo"),
               lambda a: comm.broadcast(a, "solo")):
        out = jax.jit(jax.shard_map(fn, mesh=mesh, in_specs=P(),
                                    out_specs=P(),
                                    check_vma=False))(x)
        np.testing.assert_array_equal(np.asarray(out), np.asarray(x))
