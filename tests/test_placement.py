"""tuner.placement: the topology-aware placement planner, irregular
(shape-vector) levels, and the axis-alias indirection that applies a
placement without touching model code."""
import json

import pytest

from repro import tuner
from repro.core.hw import MiB, CXLPoolConfig, ICIConfig, InfiniBandConfig
from repro.core.topology import (Level, Topology, clear_active_topology,
                                 parse_topology)
from repro.models import sharding
from repro.tuner import placement as pl

SLOW_IB = InfiniBandConfig(link_bw=2.5e9)
POOL = CXLPoolConfig(device_bw=18e9)
FAST_ICI = ICIConfig(link_bw=45e9)

TOPO = Topology(levels=(
    Level("pod", "ib", ib=SLOW_IB, shape=(2,)),
    Level("node", "cxl", pool=POOL, shape=(2,)),
    Level("gpu", "ici", ici=FAST_ICI, shape=(4,)),
))

RAGGED = Topology(levels=(
    Level("pod", "ib", ib=SLOW_IB),
    Level("node", "cxl", pool=POOL, shape=(4, 2)),
    Level("gpu", "ici", ici=FAST_ICI, shape=(6,)),
))


def heavy_tp_mix(tp=4, dp=4):
    """A mix whose TP axis dominates: the planner must put it on the
    fastest level under any sane oracle."""
    return pl.CollectiveMix(axes=(
        pl.AxisTraffic("model", tp, (
            pl.CollectiveCall("all_reduce", 64 * MiB, calls=100.0),)),
        pl.AxisTraffic("data", dp, (
            pl.CollectiveCall("all_gather", 4 * MiB, calls=4.0),)),
    ))


# -- shape-vector levels ---------------------------------------------------

def test_level_shape_validation_and_props():
    lv = Level("node", "cxl", shape=(4, 2))
    assert lv.size == 6 and lv.grouped and lv.irregular
    assert Level("gpu", "ici", shape=(8,)).size == 8
    assert not Level("gpu", "ici", shape=(8,)).grouped
    assert Level("n", "cxl", shape=(3, 3)).grouped
    assert not Level("n", "cxl", shape=(3, 3)).irregular
    assert Level("n", "cxl").size is None
    with pytest.raises(ValueError):
        Level("n", "cxl", shape=())
    with pytest.raises(ValueError):
        Level("n", "cxl", shape=(4, 0))


def test_shape_in_fingerprint_and_parse():
    base = Level("n", "cxl")
    assert base.fingerprint() != Level("n", "cxl",
                                       shape=(4, 2)).fingerprint()
    assert Level("n", "cxl", shape=(4, 2)).fingerprint() != \
        Level("n", "cxl", shape=(3, 3)).fingerprint()
    t = parse_topology("pod:ib,node:cxl:4+2,gpu:ici:8")
    assert t.level_for("node").shape == (4, 2)
    assert t.level_for("gpu").shape == (8,)
    assert t.level_for("pod").shape is None
    assert t.parent_of("node").axis == "pod"
    assert t.parent_of("pod") is None


def test_topology_fingerprint_ignores_axis_names():
    """Placement relabels levels with logical axis names; the
    fingerprint must survive so tuned plans keep matching."""
    a = Topology(levels=(Level("pod", "ib"), Level("node", "cxl")))
    b = Topology(levels=(Level("data", "ib"), Level("model", "cxl")))
    assert a.fingerprint() == b.fingerprint()
    # order still matters
    c = Topology(levels=(Level("x", "cxl"), Level("y", "ib")))
    assert a.fingerprint() != c.fingerprint()


def test_irregular_level_roundtrip_through_plan_save_load(tmp_path):
    """A ragged topology embedded in a tuned plan survives
    save -> load with its shape vector and fingerprint intact."""
    grid = tuner.TuneGrid(primitives=("all_reduce",), sizes=(1 * MiB,),
                          nranks=(3,), slicing_factors=(4,))
    plan = tuner.generate_plan(grid, topology=RAGGED)
    path = str(tmp_path / "ragged.json")
    tuner.save_plan(plan, path)
    loaded = tuner.load_plan(path, topology=RAGGED)
    topo = loaded.topology()
    assert topo.level_for("node").shape == (4, 2)
    assert topo.level_for("node").irregular
    assert topo.fingerprint() == RAGGED.fingerprint()
    # the sweep tuned the ragged level at its real group sizes and the
    # parent at the group count (sub-root exchange)
    node_n = {k[2] for k in loaded.entries
              if k[3] == RAGGED.level_key("node")}
    pod_n = {k[2] for k in loaded.entries
             if k[3] == RAGGED.level_key("pod")}
    assert {2, 4} <= node_n
    assert 2 in pod_n


# -- the planner -----------------------------------------------------------

def test_planner_picks_known_best_under_skewed_oracle():
    """With TP traffic dominating, the planner must land the TP axis
    on the fast ICI level and the FSDP axis on the pod+node split -
    and rank the swapped (naive) assignment strictly worse."""
    plan = pl.plan_placement(heavy_tp_mix(), TOPO)
    best = plan.best
    assert best.levels_for("model") == ("gpu",)
    assert best.levels_for("data") == ("pod", "node")
    naive = plan.find({"model": ("pod", "node"), "data": "gpu"})
    assert naive is not None
    assert naive.predicted_exposed_s > best.predicted_exposed_s
    assert best is plan.best_with_unsplit(("model",))
    assert "data" in best.split_axes and "model" not in best.split_axes


def test_planner_infeasible_and_size_checks():
    mix = pl.CollectiveMix(axes=(
        pl.AxisTraffic("model", 5, (
            pl.CollectiveCall("all_reduce", MiB),)),))
    with pytest.raises(ValueError, match="no feasible"):
        pl.plan_placement(mix, TOPO)    # no level of size 5
    # undeclared level sizes accept any degree
    topo = Topology(levels=(Level("a", "ib"), Level("b", "ici")))
    plan = pl.plan_placement(mix, topo)
    assert plan.best.levels_for("model") in (("a",), ("b",))


def test_planner_ragged_pricing_prefers_pool_over_flat_ib():
    """On the ragged topology the grouped decomposition must price the
    big AllReduce below the flat cross-pod IB ring, steering TP away
    from the ragged level only when the alternative is faster."""
    node, pod = RAGGED.level_for("node"), RAGGED.level_for("pod")
    ragged = pl._ragged_call_time(node, pod, "all_reduce", 64 * MiB)
    flat = pl._best_level_time(pod, "all_reduce", 6, 64 * MiB)
    assert 0 < ragged < flat
    plan = pl.plan_placement(heavy_tp_mix(tp=6, dp=6), RAGGED)
    assert plan.best.levels_for("model") == ("gpu",)
    # the absorbed pod level (parent of the ragged node) never takes
    # an axis of its own
    for p in plan.ranked:
        for _, levels in p.assignment:
            assert "pod" not in levels


def test_best_with_unsplit_raises_when_only_splits_fit():
    """A placement whose TP axis spans two levels cannot be applied
    (the mesh would lack the model axis): best_with_unsplit must
    refuse loudly instead of handing back a split assignment."""
    topo = Topology(levels=(Level("pod", "ib", shape=(2,)),
                            Level("node", "cxl", shape=(2,))))
    mix = pl.CollectiveMix(axes=(
        pl.AxisTraffic("model", 4, (
            pl.CollectiveCall("all_reduce", MiB),)),))
    plan = pl.plan_placement(mix, topo)   # only pod+node fits model=4
    assert plan.best.levels_for("model") == ("pod", "node")
    with pytest.raises(ValueError, match="splits"):
        plan.best_with_unsplit(("model",))
    # report marks the actually-applied candidate, not always rank #0
    rep = pl.format_report(plan, chosen=plan.best)
    assert "chosen" in rep


def test_overlap_window_reduces_exposed_time():
    call = pl.CollectiveCall("all_gather", 16 * MiB, calls=2.0,
                             overlap_s=1e9)  # absurdly large window
    mix = pl.CollectiveMix(axes=(
        pl.AxisTraffic("data", 4, (call,)),
        pl.AxisTraffic("model", 4, (
            pl.CollectiveCall("all_reduce", MiB),)),))
    plan = pl.plan_placement(mix, TOPO)
    assert dict(plan.best.per_axis_s)["data"] == 0.0


def test_placement_plan_json_roundtrip(tmp_path):
    plan = pl.plan_placement(heavy_tp_mix(), TOPO)
    path = str(tmp_path / "placement.json")
    pl.save_placement(plan, path)
    again = pl.load_placement(path)
    assert again.best.assignment == plan.best.assignment
    assert again.best.predicted_exposed_s == pytest.approx(
        plan.best.predicted_exposed_s)
    assert again.topology.fingerprint() == TOPO.fingerprint()
    # the doc is plain JSON (CI artifacts, plan meta embedding)
    json.dumps(plan.to_json())


def test_placement_embeds_in_plan_meta():
    grid = tuner.TuneGrid(primitives=("all_reduce",), sizes=(1 * MiB,),
                          nranks=(2,), slicing_factors=(4,))
    plan = tuner.generate_plan(grid, topology=TOPO)
    assert plan.placement() is None
    pplan = pl.plan_placement(heavy_tp_mix(), TOPO)
    plan.meta["placement"] = pplan.to_json()
    again = tuner.Plan.from_json(plan.to_json())
    assert again.placement().best.assignment == pplan.best.assignment


def test_mix_for_model_shapes():
    from repro.configs import get_config
    cfg = get_config("llama3-8b")
    mix = pl.CollectiveMix.for_model(cfg, {"data": 4, "model": 8})
    data, model = mix.axis("data"), mix.axis("model")
    assert {c.primitive for c in model.calls} == {"all_reduce"}
    assert {c.primitive for c in data.calls} == {"all_gather",
                                                 "reduce_scatter"}
    # gathers are overlappable (prefetch), grad RS is not
    ag = next(c for c in data.calls if c.primitive == "all_gather")
    rs = next(c for c in data.calls if c.primitive == "reduce_scatter")
    assert ag.overlap_s > 0.0 and rs.overlap_s == 0.0
    assert data.bytes_per_step > 0 and model.bytes_per_step > 0
    # size-1 axes ride along traffic-free
    mix1 = pl.CollectiveMix.for_model(cfg, {"data": 4, "model": 1})
    assert mix1.axis("model").calls == ()
    with pytest.raises(ValueError):
        pl.CollectiveMix.for_model(cfg, {"data": 1, "model": 1})


def test_mix_from_dryrun_record():
    rec = {"ledger": {"auto_choices": [
        {"primitive": "all_reduce", "msg_bytes": 1024, "nranks": 4,
         "calls": 32.0, "level": "model"},
        {"primitive": "all_gather", "msg_bytes": 2048, "nranks": 2,
         "calls": 8.0, "level": None},
    ]}}
    mix = pl.CollectiveMix.from_dryrun(rec, axis_sizes={"data": 2,
                                                        "model": 4})
    assert mix.axis("model").calls[0].calls == 32.0
    assert mix.axis("data").calls[0].primitive == "all_gather"
    with pytest.raises(ValueError):
        pl.CollectiveMix.from_dryrun({"ledger": {}})


# -- applying a placement --------------------------------------------------

def test_placed_topology_and_mesh_spec():
    mix = heavy_tp_mix()
    plan = pl.plan_placement(mix, TOPO)
    best = plan.best            # data->pod+node, model->gpu
    placed = pl.placed_topology(best, TOPO)
    # single-level run renamed to the logical axis; split keeps the
    # physical level names; fingerprint survives relabeling
    assert placed.axes == ("pod", "node", "model")
    assert placed.fingerprint() == TOPO.fingerprint()
    shape, names, aliases = pl.mesh_spec(best, mix, TOPO)
    assert names == ("pod", "node", "model")
    assert shape == (2, 2, 4)
    assert aliases == {"data": ("pod", "node")}


def test_mesh_spec_appends_size1_axes():
    from repro.configs import get_config
    cfg = get_config("llama3-8b")
    mix = pl.CollectiveMix.for_model(cfg, {"data": 4, "model": 1})
    topo = Topology(levels=(Level("pod", "ib", shape=(2,)),
                            Level("node", "cxl", shape=(2,))))
    plan = pl.plan_placement(mix, topo)
    shape, names, aliases = pl.mesh_spec(plan.best, mix, topo)
    assert names[-1] == "model" and shape[-1] == 1
    assert set(names) == {"pod", "node", "model"}


def test_sharding_axis_aliases():
    try:
        sharding.set_axis_aliases({"data": ("pod", "node")})
        assert sharding.resolve_axis("data") == ("pod", "node")
        assert sharding.resolve_axis("model") == "model"
        assert sharding.resolve_axis(("pod", "data")) == \
            ("pod", "pod", "node")  # tuples flatten through aliases
        assert sharding.resolve_axis(None) is None
        sharding.set_mesh_sizes({"pod": 2, "node": 2, "model": 4})
        import jax.numpy as jnp

        class _Cfg:
            @staticmethod
            def kv_sharded(tp):
                return True
        params = {"big": jnp.zeros((256, 512), jnp.float32)}
        specs = sharding.param_specs(params, _Cfg, dp_axis="data",
                                     fsdp=True)
        assert sharding._has_axis(specs["big"],
                                  ("pod", "node")) is not None
    finally:
        sharding.clear_axis_aliases()
        clear_active_topology()


def test_format_report_names_the_winner():
    plan = pl.plan_placement(heavy_tp_mix(), TOPO)
    rep = pl.format_report(plan)
    assert "chosen" in rep and plan.best.describe() in rep
