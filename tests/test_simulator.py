"""Event-driven simulator invariants + paper-claim validation."""
import numpy as np
import pytest

from repro.core import ibmodel, simulator
from repro.core.hw import MiB

PRIMS = ["all_reduce", "all_gather", "reduce_scatter", "all_to_all",
         "broadcast", "reduce", "gather", "scatter"]


@pytest.mark.parametrize("prim", PRIMS)
def test_monotone_in_message_size(prim):
    t = [simulator.run_variant("all", prim, 3, s).total_time
         for s in (4 * MiB, 64 * MiB, 1024 * MiB)]
    assert t[0] < t[1] < t[2]


@pytest.mark.parametrize("prim", PRIMS)
def test_variant_ordering(prim):
    """CXL-CCL-All <= Aggregate <= Naive (Sec. 5.2)."""
    s = 256 * MiB
    t_all = simulator.run_variant("all", prim, 3, s).total_time
    t_agg = simulator.run_variant("aggregate", prim, 3, s).total_time
    t_nai = simulator.run_variant("naive", prim, 3, s).total_time
    assert t_all <= t_agg * 1.001
    assert t_agg <= t_nai * 1.001


@pytest.mark.parametrize("prim", PRIMS)
def test_no_deadlock_and_bytes_accounted(prim):
    r = simulator.run_variant("all", prim, 4, 16 * MiB)
    assert r.total_time > 0
    assert r.bytes_moved > 0
    assert all(v >= 0 for v in r.rank_finish.values())


def test_interleaving_beats_hotspot():
    """Bandwidth aggregation: interleaved AllGather >> naive (device-0
    hot spot) at large sizes."""
    t_all = simulator.run_variant("all", "all_gather", 3,
                                  1024 * MiB).total_time
    t_nai = simulator.run_variant("naive", "all_gather", 3,
                                  1024 * MiB).total_time
    assert t_nai / t_all > 2.0


def test_overlap_beats_barrier():
    """Chunked overlap (Sec. 4.4): slicing 8 beats slicing 1."""
    t8 = simulator.run_variant("all", "broadcast", 3, 1024 * MiB,
                               slicing_factor=8).total_time
    t1 = simulator.run_variant("all", "broadcast", 3, 1024 * MiB,
                               slicing_factor=1).total_time
    assert t8 < t1


def test_ib_model_basics():
    t_small = ibmodel.estimate("all_reduce", 3, 1 * MiB).time
    t_big = ibmodel.estimate("all_reduce", 3, 1024 * MiB).time
    assert t_small < t_big
    assert ibmodel.estimate("all_reduce", 1, MiB).time == 0.0
