"""Standalone multi-device validation, run in a subprocess with
XLA_FLAGS=--xla_force_host_platform_device_count=8 (tests must not leak
the forced device count into other test processes).

Validates, for ring and cxl backends:
  1. every Communicator collective vs its jax.lax oracle (single axis);
  2. hierarchical (pod, data)-style axes;
  3. TP+FSDP sharded loss == unsharded loss;
  4. one sharded AdamW train step produces the SAME updated params as
     the unsharded step (grads + replicated-grad sync + optimizer) -
     through the bucketed gather + prefetch production path;
  5. bucketed sync_grads / fused FSDP gather numerics vs the per-leaf
     reference across TP x FSDP mesh shapes (bitwise for fp32 ring,
     allclose for cxl and bf16), including sub-FSDP_MIN_SIZE leaves;
  6. obs metrics export reconciles exactly with ledger.snapshot();
  7. elastic reconfiguration: a rank death mid-run -> confirmed by the
     heartbeat monitor -> ragged survivor re-plan + mesh rebuild +
     pool-snapshot rollback, allclose vs a flat 7-rank reference;
  8. fused collective+compute kernels: the padding-free ragged
     reduce_scatter vs the flat reference (no fallback events), and
     ``fuse_kernels`` train steps vs the unfused bucketed path on
     regular and ragged (4+2) dp meshes, with the ledger's fused-byte
     split flipping on and off with the flag;
  9. flat-fallback audit: all_to_all / scatter on a grouped (4+2)
     level book one explicit flat-on-ragged event per call while
     still matching the flat-schedule numerics;
 10. pipeline parallelism: a 2-stage x 4-dp pipelined train step
     (1F1B microbatch loop, Communicator.send stage handoff over the
     tuned p2p plan cells) matches the FSDP-only 8-rank step, with
     the p2p wire bytes attributed to the stage level.
"""
import os

assert os.environ.get("XLA_FLAGS", "").endswith("device_count=8"), \
    "run with XLA_FLAGS=--xla_force_host_platform_device_count=8"

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from repro.configs import get_config
from repro.core import overlap
from repro.core.api import Communicator
from repro.models import model, sharding
from repro.models.pcontext import ParallelContext, UNSHARDED
from repro.optim import adamw_init
from repro.training.train_loop import TrainConfig, make_train_step

RNG = np.random.default_rng(0)
KEY = jax.random.key(0)


def check_collectives(backend: str, rng=None) -> None:
    rng = RNG if rng is None else rng
    mesh = jax.make_mesh((8,), ("x",))
    comm = Communicator(backend=backend, slicing_factor=4)
    x = rng.standard_normal((8 * 16, 4)).astype(np.float32)
    y = rng.standard_normal((8, 32, 4)).astype(np.float32)

    def smap(f, ins, outs):
        return jax.jit(jax.shard_map(f, mesh=mesh, in_specs=ins,
                                     out_specs=outs, check_vma=False))

    out = smap(lambda a: comm.all_gather(a, "x"), P("x"), P())(x)
    np.testing.assert_allclose(out, x, rtol=1e-6)
    out = smap(lambda a: comm.reduce_scatter(a, "x"), P("x"),
               P("x"))(y.reshape(256, 4))
    np.testing.assert_allclose(np.asarray(out), y.sum(0), rtol=1e-4,
                               atol=1e-5)
    for mode in ("faithful", "two_phase"):
        c = Communicator(backend=backend, allreduce_mode=mode)
        out = smap(lambda a: c.all_reduce(a, "x"), P("x"),
                   P("x"))(y.reshape(256, 4))
        np.testing.assert_allclose(np.asarray(out).reshape(8, 32, 4),
                                   np.tile(y.sum(0), (8, 1, 1)),
                                   rtol=1e-4, atol=1e-5)
    z = rng.standard_normal((8, 16, 3)).astype(np.float32)
    out = smap(lambda a: comm.all_to_all(a, "x"), P("x"),
               P("x"))(z.reshape(128, 3))
    np.testing.assert_allclose(
        np.asarray(out).reshape(8, 8, 2, 3),
        z.reshape(8, 8, 2, 3).transpose(1, 0, 2, 3), rtol=1e-6)
    out = smap(lambda a: comm.broadcast(a, "x", root=3), P("x"),
               P("x"))(x)
    np.testing.assert_allclose(
        np.asarray(out).reshape(8, 16, 4),
        np.tile(x.reshape(8, 16, 4)[3], (8, 1, 1)), rtol=1e-6)
    out = smap(lambda a: comm.reduce(a, "x", root=2), P("x"),
               P("x"))(y.reshape(256, 4))
    o = np.asarray(out).reshape(8, 32, 4)
    np.testing.assert_allclose(o[2], y.sum(0), rtol=1e-4, atol=1e-5)
    assert np.allclose(o[3], 0)
    out = smap(lambda a: comm.gather(a, "x", root=1), P("x"),
               P("x"))(x)
    np.testing.assert_allclose(np.asarray(out).reshape(8, 128, 4)[1], x,
                               rtol=1e-6)
    out = smap(lambda a: comm.scatter(a, "x", root=0), P("x"),
               P("x"))(x)
    np.testing.assert_allclose(np.asarray(out).reshape(8, 2, 4),
                               x.reshape(8, 16, 4)[0].reshape(8, 2, 4),
                               rtol=1e-6)
    print(f"  collectives[{backend}] ok")


def check_hierarchical(backend: str, rng=None) -> None:
    rng = RNG if rng is None else rng
    mesh = jax.make_mesh((2, 4), ("p", "d"))
    comm = Communicator(backend=backend)
    w = rng.standard_normal((48, 5)).astype(np.float32)
    f = jax.jit(jax.shard_map(
        lambda a: comm.all_gather(a, ("p", "d")), mesh=mesh,
        in_specs=P(("p", "d")), out_specs=P(), check_vma=False))
    np.testing.assert_allclose(f(w), w, rtol=1e-6)
    v = rng.standard_normal((8, 16, 5)).astype(np.float32)
    g = jax.jit(jax.shard_map(
        lambda a: comm.all_gather(comm.reduce_scatter(a, ("p", "d")),
                                  ("p", "d")), mesh=mesh,
        in_specs=P(("p", "d")), out_specs=P(("p", "d")),
        check_vma=False))
    np.testing.assert_allclose(
        np.asarray(g(v.reshape(128, 5))).reshape(8, 16, 5),
        np.tile(v.sum(0), (8, 1, 1)), rtol=1e-4, atol=1e-5)
    print(f"  hierarchical[{backend}] ok")


def check_rank_major_layout(backend: str, rng=None) -> None:
    """Tuple-axis (outer, inner) all_gather / reduce_scatter must produce
    exactly the layout of the same collective over one flat axis whose
    rank order is outer-major (rank = p * |d| + d)."""
    rng = RNG if rng is None else rng
    mesh2 = jax.make_mesh((2, 4), ("p", "d"))
    mesh1 = jax.make_mesh((8,), ("x",))
    comm = Communicator(backend=backend)
    x = rng.standard_normal((8 * 8, 5)).astype(np.float32)

    def run(mesh, spec, f):
        return np.asarray(jax.jit(jax.shard_map(
            f, mesh=mesh, in_specs=P(spec), out_specs=P(spec),
            check_vma=False))(x))

    ag2 = run(mesh2, ("p", "d"), lambda a: comm.all_gather(a, ("p", "d")))
    ag1 = run(mesh1, "x", lambda a: comm.all_gather(a, "x"))
    np.testing.assert_allclose(ag2, ag1, rtol=1e-6)
    # oracle: every rank holds the full rank-major array
    np.testing.assert_allclose(ag2.reshape(8, 64, 5),
                               np.tile(x, (8, 1, 1)), rtol=1e-6)

    rs2 = run(mesh2, ("p", "d"),
              lambda a: comm.reduce_scatter(a, ("p", "d")))
    rs1 = run(mesh1, "x", lambda a: comm.reduce_scatter(a, "x"))
    np.testing.assert_allclose(rs2, rs1, rtol=1e-4, atol=1e-5)
    # oracle: assembled output is the cross-rank sum of the shards
    np.testing.assert_allclose(rs2, x.reshape(8, 8, 5).sum(0),
                               rtol=1e-4, atol=1e-5)
    print(f"  rank-major-layout[{backend}] ok")


def check_bucketed_sync_grads(backend: str) -> None:
    """Bucketed sync_grads vs the per-leaf reference across TP x FSDP
    mesh shapes: bitwise-equal for fp32 under ring (same per-element
    rank-summation order), allclose for cxl and for bf16.  The tree
    mixes a big FSDP leaf, a sub-FSDP_MIN_SIZE replicated leaf, a
    TP-sharded leaf and a norm vector, so every sync group (missing tp,
    missing dp, missing both) is exercised."""
    rng = np.random.default_rng(99)
    for dp, tp in ((2, 4), (4, 2)):
        mesh = jax.make_mesh((dp, tp), ("data", "model"))
        sharding.set_mesh_sizes({"data": dp, "model": tp})
        comm = Communicator(backend=backend)
        pc = ParallelContext(tp_axis="model", dp_axis="data", tp=tp,
                             comm=comm)
        params = {
            "big": jnp.zeros((256, 512), jnp.float32),   # FSDP-sharded
            "small": jnp.zeros((64, 32), jnp.float32),   # < FSDP_MIN_SIZE
            "wq": jnp.zeros((128, 8 * 16), jnp.float32),  # TP-sharded
            "norm1": jnp.zeros((128,), jnp.float32),
        }

        class _Cfg:  # minimal stand-in for spec construction
            @staticmethod
            def kv_sharded(tp):
                return True
        pspecs = sharding.param_specs(params, _Cfg, dp_axis="data",
                                      fsdp=True)
        assert sharding._has_axis(pspecs["big"], "data") is not None
        assert sharding._has_axis(pspecs["small"], "data") is None

        for dtype, tol in ((jnp.float32, 0.0), (jnp.bfloat16, 2e-2)):
            grads = {k: jnp.asarray(
                rng.standard_normal(v.shape), jnp.float32).astype(dtype)
                for k, v in params.items()}

            def run(fn):
                f = jax.jit(jax.shard_map(
                    fn, mesh=mesh, in_specs=(pspecs,), out_specs=pspecs,
                    check_vma=False))
                return jax.tree.map(np.asarray, f(grads))

            ref = run(lambda g: sharding.sync_grads(g, pspecs, pc,
                                                    "data"))
            for cap in (None, 3000):   # fully fused + multi-bucket
                got = run(lambda g: overlap.bucketed_sync_grads(
                    g, pspecs, pc, "data", bucket_bytes=cap))
                for k in params:
                    if backend == "ring" and dtype == jnp.float32:
                        assert np.array_equal(ref[k], got[k]), \
                            (dp, tp, k, cap)
                    else:
                        np.testing.assert_allclose(
                            np.asarray(ref[k], np.float32),
                            np.asarray(got[k], np.float32),
                            rtol=tol or 1e-5, atol=tol or 1e-6,
                            err_msg=f"{dp}x{tp} {k} cap={cap}")
    print(f"  bucketed-sync[{backend}] ok")


def check_bucketed_gather(backend: str) -> None:
    """Fused (bucketed) FSDP AllGather vs the per-leaf gather over a
    hierarchical (pod, data) axis: pure data movement, so the result
    must be bitwise identical - including dtype-split buckets and
    pass-through of sub-threshold leaves."""
    rng = np.random.default_rng(7)
    mesh = jax.make_mesh((2, 4), ("pod", "data"))
    comm = Communicator(backend=backend)
    pc = ParallelContext(tp_axis=None, dp_axis=("pod", "data"), tp=1,
                         comm=comm)
    row = {
        "w1": jnp.asarray(rng.standard_normal((64, 48)), jnp.float32),
        "w2": jnp.asarray(rng.standard_normal((32, 64)), jnp.float32),
        "wb": jnp.asarray(rng.standard_normal((64, 16)),
                          jnp.float32).astype(jnp.bfloat16),
        "tiny": jnp.asarray(rng.standard_normal((8,)), jnp.float32),
    }
    specs = {"w1": P(("pod", "data"), None),
             "w2": P(None, ("pod", "data")),
             "wb": P(("pod", "data"), None),
             "tiny": P(None)}
    in_specs = (specs,)
    out_specs = {k: P() for k in row}

    def run(fn):
        f = jax.jit(jax.shard_map(
            lambda p: fn("row", p), mesh=mesh, in_specs=in_specs,
            out_specs=out_specs, check_vma=False))
        return jax.tree.map(np.asarray, f(row))

    ref = run(sharding.fsdp_gather_fn({"row": specs}, pc,
                                      ("pod", "data")))
    for cap in (None, 8192):
        got = run(overlap.make_gather_fn({"row": specs}, pc,
                                         ("pod", "data"),
                                         bucket_bytes=cap))
        for k in row:
            assert got[k].dtype == ref[k].dtype, k
            assert np.array_equal(ref[k], got[k]), (k, cap)
    # oracle: gathered leaves reproduce the full (unsharded) array
    np.testing.assert_array_equal(ref["w1"], np.asarray(row["w1"]))
    np.testing.assert_array_equal(ref["tiny"], np.asarray(row["tiny"]))
    print(f"  bucketed-gather[{backend}] ok")


def check_train_equivalence(backend: str, arch: str) -> None:
    mesh = jax.make_mesh((2, 4), ("data", "model"))
    cfg = get_config(arch, smoke=True)
    if cfg.moe:
        cfg = dataclasses.replace(cfg, moe=dataclasses.replace(
            cfg.moe, capacity_factor=16.0, router_aux_weight=0.0))
    params = model.init_params(KEY, cfg, tp=4, dtype=jnp.float32)
    B, L = 4, 16
    batch = {"tokens": jnp.asarray(RNG.integers(0, cfg.vocab_size,
                                                (B, L))),
             "labels": jnp.asarray(RNG.integers(0, cfg.vocab_size,
                                                (B, L)))}
    bspecs = {"tokens": P("data"), "labels": P("data")}
    if cfg.frontend == "vision_stub" and cfg.encoder is None:
        batch["frontend"] = jnp.asarray(RNG.standard_normal(
            (B, cfg.frontend_tokens, cfg.frontend_dim)), jnp.float32)
        bspecs["frontend"] = P("data")
    if cfg.encoder is not None:
        batch["source"] = jnp.asarray(RNG.standard_normal(
            (B, cfg.encoder.source_len, cfg.frontend_dim)), jnp.float32)
        bspecs["source"] = P("data")

    tcfg = TrainConfig(lr=1e-3, warmup=0, clip_norm=None, remat=False)
    ref_step = jax.jit(make_train_step(cfg, tcfg))
    p_ref, _, m_ref = ref_step(params, adamw_init(params), batch)

    sharding.set_mesh_sizes({"model": 4, "data": 2})
    comm = Communicator(backend=backend)
    pc = ParallelContext(tp_axis="model", dp_axis="data", tp=4, comm=comm)
    pspecs = sharding.param_specs(params, cfg, dp_axis="data", fsdp=True)
    rspecs = sharding.row_specs(pspecs)
    # production path: row-fused FSDP gathers + bucketed grad sync +
    # double-buffered prefetch (TrainConfig defaults)
    gather = overlap.make_gather_fn(rspecs, pc, "data", bucket_bytes=None)
    inner = make_train_step(cfg, tcfg, pc, gather_fn=gather,
                            param_spec_tree=pspecs, dp_axis="data")
    from repro.optim import AdamWState
    ospecs = AdamWState(step=P(), mu=pspecs, nu=pspecs)
    mspecs = {"loss": P(), "lr": P(), "grad_norm": P(), "xent": P(),
              "aux": P()}
    step = jax.jit(jax.shard_map(
        inner, mesh=mesh, in_specs=(pspecs, ospecs, bspecs),
        out_specs=(pspecs, ospecs, mspecs), check_vma=False))
    p_sh, _, m_sh = step(params, adamw_init(params), batch)

    # zamba2 stacks 38 recurrent (exp-decay) layers: the row-parallel
    # psum reassociation amplifies chaotically, so it gets a wider band
    # (observed deltas up to ~5e-2 on CPU jax 0.4.x).
    tol = 8e-2 if arch.startswith("zamba2") else 5e-3
    assert abs(float(m_sh["loss"]) - float(m_ref["loss"])) < tol, \
        (arch, float(m_sh["loss"]), float(m_ref["loss"]))
    errs = jax.tree.map(
        lambda a, b: float(jnp.max(jnp.abs(a.astype(jnp.float32)
                                           - b.astype(jnp.float32)))),
        p_ref, p_sh)
    worst = max(jax.tree.leaves(errs))
    assert worst < tol, f"{arch} {backend}: param delta {worst}"
    print(f"  train-equiv[{backend}/{arch}] ok "
          f"(loss {float(m_sh['loss']):.4f}, worst dp {worst:.1e})")


def check_topology_hierarchical() -> None:
    """Acceptance: a 3-level ("pod", "node", "gpu") topology with
    distinct per-level fabric configs round-trips through
    tune -> save -> load -> Communicator(backend='auto'), the plan cells
    carry (level, fabric fingerprint) keys, the ledger splits wire bytes
    per level/fabric, and the hierarchical decomposition matches the
    flat single-axis reference: bitwise for fp32 (integer-valued data,
    so cross-order summation is exact) under ring, allclose for cxl and
    bf16.  Uneven level sizes (2x4, 4x2) are covered too."""
    import tempfile

    from repro import tuner
    from repro.core import ledger
    from repro.core.hw import CXLPoolConfig, ICIConfig, InfiniBandConfig
    from repro.core.topology import Level, Topology

    rng = np.random.default_rng(42)
    topo = Topology(levels=(
        Level("pod", "ib", ib=InfiniBandConfig(link_bw=12.5e9)),
        Level("node", "cxl", pool=CXLPoolConfig(device_bw=18e9)),
        Level("gpu", "ici", ici=ICIConfig(link_bw=45e9)),
    ))
    grid = tuner.TuneGrid(sizes=(256, 4096, 65536), nranks=(2, 4, 8),
                          slicing_factors=(1, 4))
    plan = tuner.generate_plan(grid, topology=topo)
    # round-trip through disk, exactly as tune -> train would
    with tempfile.TemporaryDirectory() as td:
        path = td + "/topo_plan.json"
        tuner.save_plan(plan, path)
        plan = tuner.load_plan(path, topology=topo)
    assert plan.topology().fingerprint() == topo.fingerprint()
    lkeys = plan.levels()
    assert len(lkeys) == 3, lkeys
    for i, lv in enumerate(topo.levels):
        assert topo.level_key(lv.axis) in lkeys, (lv.axis, lkeys)
        assert topo.level_key(lv.axis).startswith(f"{i}:")
    # distinct fabrics -> distinct fingerprints
    assert len({k.split(":")[1] for k in lkeys}) == 3

    mesh3 = jax.make_mesh((2, 2, 2), ("pod", "node", "gpu"))
    mesh1 = jax.make_mesh((8,), ("x",))
    axes3 = ("pod", "node", "gpu")
    xi = rng.integers(-8, 8, (64, 5)).astype(np.float32)

    def run(mesh, spec, comm_fn, x):
        return np.asarray(jax.jit(jax.shard_map(
            comm_fn, mesh=mesh, in_specs=P(spec), out_specs=P(spec),
            check_vma=False))(x))

    for backend in ("ring", "cxl", "auto"):
        comm = Communicator(backend=backend, plan=plan, topology=topo)
        flat = Communicator(backend=backend, plan=plan)
        ledger.reset()
        ar3 = run(mesh3, axes3, lambda a: comm.all_reduce(a, axes3), xi)
        snap = ledger.snapshot()
        # hierarchical AR decomposes into per-level RS/AR/AG and the
        # ledger attributes every byte to its level/fabric; the outer
        # (pod-spanning) fabric carries 1/prod(inner) of the payload
        lvl = {k: sum(v.values())
               for k, v in snap["level_wire_bytes"].items()}
        assert set(lvl) == {"pod/ib", "node/cxl", "gpu/ici"}, lvl
        assert lvl["pod/ib"] < lvl["gpu/ici"], lvl
        if backend == "auto":
            audit = snap["auto_choices"]
            assert {a["level"] for a in audit} == set(axes3)
            assert {a["fabric"] for a in audit} == {"ib", "cxl", "ici"}
            # the pool schedule only exists on the cxl level
            for a in audit:
                if a["fabric"] != "cxl":
                    assert a["backend"] == "ring", a
        ar1 = run(mesh1, "x", lambda a: flat.all_reduce(a, "x"), xi)
        assert np.array_equal(ar3, ar1), backend
        ag3 = run(mesh3, axes3, lambda a: comm.all_gather(a, axes3), xi)
        ag1 = run(mesh1, "x", lambda a: flat.all_gather(a, "x"), xi)
        assert np.array_equal(ag3, ag1), backend
        bc3 = run(mesh3, axes3,
                  lambda a: comm.broadcast(a, axes3, root=5), xi)
        bc1 = run(mesh1, "x",
                  lambda a: flat.broadcast(a, "x", root=5), xi)
        assert np.array_equal(bc3, bc1), backend
        rs3 = run(mesh3, axes3,
                  lambda a: comm.reduce_scatter(a, axes3), xi)
        rs1 = run(mesh1, "x", lambda a: flat.reduce_scatter(a, "x"), xi)
        assert np.array_equal(rs3, rs1), backend
        # bf16: same decomposition, allclose band
        xb = jnp.asarray(xi + 0.25 * rng.standard_normal(xi.shape),
                         jnp.bfloat16)
        arb3 = run(mesh3, axes3, lambda a: comm.all_reduce(a, axes3), xb)
        arb1 = run(mesh1, "x", lambda a: flat.all_reduce(a, "x"), xb)
        np.testing.assert_allclose(
            np.asarray(arb3, np.float32), np.asarray(arb1, np.float32),
            rtol=3e-2, atol=3e-1, err_msg=backend)
    # uneven level sizes: 2x4 and 4x2 two-level topologies
    topo_pn = Topology(levels=topo.levels[:2])
    for shape in ((2, 4), (4, 2)):
        mesh2 = jax.make_mesh(shape, ("pod", "node"))
        for backend in ("ring", "cxl"):
            comm = Communicator(backend=backend, topology=topo_pn)
            flat = Communicator(backend=backend)
            a2 = run(mesh2, ("pod", "node"),
                     lambda a: comm.all_reduce(a, ("pod", "node")), xi)
            a1 = run(mesh1, "x", lambda a: flat.all_reduce(a, "x"), xi)
            assert np.array_equal(a2, a1), (shape, backend)
            b2 = run(mesh2, ("pod", "node"),
                     lambda a: comm.broadcast(a, ("pod", "node"),
                                              root=5), xi)
            b1 = run(mesh1, "x",
                     lambda a: flat.broadcast(a, "x", root=5), xi)
            assert np.array_equal(b2, b1), (shape, backend)
    print("  topology-hierarchical ok")


def check_irregular_ragged() -> None:
    """Irregular (4+2) hierarchical collectives vs the flat single-axis
    reference: a topology level with a mixed-fan-out shape vector lives
    on one flat 6-rank axis, decomposes into within-pod rings + an IB
    sub-root exchange, and must stay allclose to the flat result (the
    grouped decomposition changes the summation order).  The ledger
    must attribute the cross-group bytes to the parent (pod) fabric."""
    from repro import tuner
    from repro.core import ledger
    from repro.core.hw import CXLPoolConfig, InfiniBandConfig
    from repro.core.topology import Level, Topology

    rng = np.random.default_rng(11)
    topo = Topology(levels=(
        Level("pod", "ib", ib=InfiniBandConfig(link_bw=2.5e9)),
        Level("node", "cxl", pool=CXLPoolConfig(device_bw=18e9),
              shape=(4, 2)),
    ))
    plan = tuner.generate_plan(
        tuner.TuneGrid(sizes=(4096, 65536), nranks=(2, 4),
                       slicing_factors=(1, 4)), topology=topo)
    mesh6 = jax.sharding.Mesh(np.asarray(jax.devices()[:6]), ("node",))
    mesh1 = jax.sharding.Mesh(np.asarray(jax.devices()[:6]), ("x",))
    x = rng.standard_normal((6 * 8, 5)).astype(np.float32)

    def run(mesh, spec, f, arr, out_spec=None):
        return np.asarray(jax.jit(jax.shard_map(
            f, mesh=mesh, in_specs=P(spec),
            out_specs=P(out_spec if out_spec is not None else spec),
            check_vma=False))(arr))

    for backend in ("ring", "cxl", "auto"):
        comm = Communicator(backend=backend, plan=plan, topology=topo)
        flat = Communicator(backend=backend, plan=plan)
        ledger.reset()
        ar6 = run(mesh6, "node", lambda a: comm.all_reduce(a, "node"), x)
        snap = ledger.snapshot()
        lvl = {k: sum(v.values())
               for k, v in snap["level_wire_bytes"].items()}
        assert set(lvl) == {"node/cxl", "pod/ib"}, lvl
        assert lvl["pod/ib"] < lvl["node/cxl"], lvl
        ar1 = run(mesh1, "x", lambda a: flat.all_reduce(a, "x"), x)
        np.testing.assert_allclose(ar6, ar1, rtol=1e-4, atol=1e-5,
                                   err_msg=backend)
        ag6 = run(mesh6, "node", lambda a: comm.all_gather(a, "node"),
                  x, out_spec=())
        np.testing.assert_allclose(ag6, x, rtol=1e-6, err_msg=backend)
        g6 = run(mesh6, "node",
                 lambda a: comm.gather(a, "node", root=4), x)
        g6 = g6.reshape(6, 48, 5)
        np.testing.assert_allclose(g6[4], x, rtol=1e-6, err_msg=backend)
        assert np.allclose(g6[0], 0.0), backend
        if backend == "auto":
            audit = snap["auto_choices"]
            assert {a["level"] for a in audit} == {"node", "pod"}
            # the sub-root exchange runs at the group count on the
            # parent level, the within-pod schedule at the max group
            ns = {(a["level"], a["nranks"]) for a in audit}
            assert ("pod", 2) in ns and ("node", 4) in ns, ns
    print("  irregular-ragged ok (4+2 vs flat, per-level ledger)")


def check_survivor_reconfig() -> None:
    """Elastic reconfiguration on real devices: an 8-rank
    ``node:cxl:4+4`` data-parallel loop loses rank 5 mid-run.  The
    heartbeat monitor confirms the death, ``resilience.replan``
    produces the ragged ``4+3`` survivor topology (hot-swapped through
    the registry), the mesh is rebuilt over the 7 surviving devices,
    and state rolls back to the newest pool-resident snapshot.  The
    continued (ragged, hierarchical) run must stay allclose to a fresh
    flat single-axis 7-rank run from the same restored state, and the
    post-failure ledger must attribute bytes to the survivor
    topology's levels (within-group cxl + cross-group ib sub-root)."""
    from repro import tuner
    from repro.core import ledger
    from repro.core.hw import CXLPoolConfig, InfiniBandConfig
    from repro.core.topology import (Level, Topology,
                                     set_active_topology)
    from repro.resilience import (FailureMonitor, FaultPlan,
                                  ResilienceController)
    from repro.training.checkpoint import PoolCheckpointStore
    from repro.tuner import runtime as tuner_runtime

    # detached stream: the chaotic train-equivalence checks depend on
    # the module RNG's draw order
    rng = np.random.default_rng(31)
    base_plan = tuner.get_active_plan()
    topo8 = Topology(levels=(
        Level("pod", "ib", ib=InfiniBandConfig(link_bw=2.5e9)),
        Level("node", "cxl", pool=CXLPoolConfig(device_bw=18e9),
              shape=(4, 4)),
    ))

    def make_step(mesh, axis, comm):
        def step(p, x):
            g = comm.all_reduce(x * p, axis)
            piece = comm.reduce_scatter(g, axis)
            return p - 0.1 * comm.all_gather(piece, axis)
        return jax.jit(jax.shard_map(step, mesh=mesh,
                                     in_specs=(P(), P(axis)),
                                     out_specs=P(), check_vma=False))

    mesh8 = jax.sharding.Mesh(np.asarray(jax.devices()[:8]), ("node",))
    comm8 = Communicator(backend="cxl", topology=topo8)
    step8 = make_step(mesh8, "node", comm8)
    p = jnp.asarray(rng.standard_normal((56, 4)).astype(np.float32)
                    * 1e-3)
    store = PoolCheckpointStore(capacity_bytes=1 << 20)
    mon = FailureMonitor(8)
    ctrl = ResilienceController(mon, topology=topo8,
                                log=lambda *_: None)
    fp = FaultPlan.parse("rank_death@6:rank=5")
    confirm_step = rp = None
    with fp:
        for i in range(12):
            fp.begin_step(i)
            x = rng.standard_normal((8 * 56, 4)).astype(np.float32)
            p = step8(p, x)
            if i % 2 == 0:
                store.snapshot(i, {"p": p})
            got = ctrl.step(i)
            if got is not None:
                confirm_step, rp = i, got
                break
    assert rp is not None, "rank death never confirmed"
    lv = rp.topology.level_for("node")
    assert lv.shape == (4, 3), lv.shape
    snap = store.latest()
    lost = ctrl.steps_lost(6, confirm_step, snap)
    assert lost <= 8, (confirm_step, snap, lost)

    # resume: survivors restore the snapshot and continue on a 7-rank
    # mesh under the re-planned ragged topology (registry-resolved)
    restored, _ = store.restore({"p": p})
    p7 = jnp.asarray(restored["p"])
    mesh7 = jax.sharding.Mesh(np.asarray(jax.devices()[:7]), ("node",))
    comm7 = Communicator(backend="auto")    # recovery plan + topology
    step7 = make_step(mesh7, "node", comm7)
    ledger.reset()
    xs = [rng.standard_normal((7 * 56, 4)).astype(np.float32)
          for _ in range(3)]
    p_ragged = p7
    for x in xs:
        p_ragged = step7(p_ragged, x)
    snap7 = ledger.snapshot()
    lvl = {k: sum(v.values())
           for k, v in snap7["level_wire_bytes"].items()}
    assert set(lvl) == {"node/cxl", "pod/ib"}, lvl
    assert lvl["pod/ib"] < lvl["node/cxl"], lvl
    audit = snap7["auto_choices"]
    ns = {(a["level"], a["nranks"]) for a in audit}
    # ragged 4+3: within-group schedules at the max group (4), the
    # cross-group sub-root exchange at the group count (2)
    assert ("node", 4) in ns and ("pod", 2) in ns, ns

    # reference: fresh flat single-axis 7-rank run, same state + data
    mesh7f = jax.sharding.Mesh(np.asarray(jax.devices()[:7]), ("x",))
    flat = Communicator(backend="cxl")
    stepf = make_step(mesh7f, "x", flat)
    p_flat = p7
    for x in xs:
        p_flat = stepf(p_flat, x)
    np.testing.assert_allclose(np.asarray(p_ragged),
                               np.asarray(p_flat),
                               rtol=1e-4, atol=1e-6)

    # restore process-wide state for the checks that follow
    tuner.set_active_plan(base_plan)
    set_active_topology(None)
    tuner_runtime.clear_rank_liveness()
    print(f"  survivor-reconfig ok (confirm@{confirm_step}, "
          f"rollback to {snap}, {lost} steps lost, ragged 4+3 "
          f"allclose vs flat 7-rank)")


def check_online_retune_hotswap() -> None:
    """Hot-swapping a measurement-refreshed plan mid-run must keep the
    numerics bitwise-identical to running the whole loop under the
    fixed plan.  Two swap flavors are exercised mid-loop:

    1. a refresh whose measurements *confirm* the oracle (EWMA-only
       update: the workload's resolved choices cannot move);
    2. a refresh whose measurements flip a cell the workload never
       touches (choices_changed is True, the step re-traces against
       the bumped registry epoch).

    Either way the collectives the step actually runs are identical,
    so the retraced program must produce bit-identical parameters.
    """
    from repro import tuner
    from repro.core import ledger

    mesh = jax.make_mesh((8,), ("x",))
    base = tuner.get_active_plan()
    assert base is not None

    def make_step():
        comm = Communicator(backend="auto")  # registry resolution
        def step(p, x):
            g = comm.all_reduce(x * p, "x")
            piece = comm.reduce_scatter(g, "x")
            return p - 0.1 * comm.all_gather(piece, "x")
        return jax.jit(jax.shard_map(step, mesh=mesh,
                                     in_specs=(P(), P("x")),
                                     out_specs=P(), check_vma=False))

    rng = np.random.default_rng(7)
    p0 = rng.standard_normal((16, 4)).astype(np.float32)
    xs = [rng.standard_normal((128, 4)).astype(np.float32)
          for _ in range(6)]

    # reference: 6 steps under the fixed base plan
    tuner.set_active_plan(base)
    ledger.reset()
    step = make_step()
    p_ref = jnp.asarray(p0)
    for x in xs:
        p_ref = step(p_ref, x)
    profile = ledger.snapshot()["auto_choices"]
    assert profile, "auto resolution recorded no choices"

    # hot-swap run: swap at step 3 with oracle-confirming measurements,
    # then at step 5 with a flip in an untouched broadcast cell
    tuner.set_active_plan(base)
    step = make_step()
    p_hot = jnp.asarray(p0)
    ot = tuner.OnlineTuner(base, min_samples=2)
    for i, x in enumerate(xs):
        if i == 3:
            for c in profile:
                # only feed cells the plan already holds: a sample at
                # an untuned bucket would legitimately grow an
                # exact-bucket cell and re-resolve it at its own size
                key = (c["primitive"],
                       tuner.size_bucket(c["msg_bytes"]), c["nranks"])
                if key not in base.entries:
                    continue
                for _ in range(2):   # measured == predicted: confirm
                    ot.observe(c["primitive"], c["msg_bytes"],
                               c["nranks"], c["backend"],
                               c["predicted_time"],
                               slicing_factor=c["slicing_factor"],
                               allreduce_mode=c["allreduce_mode"])
            refreshed = ot.refresh_and_activate()
            for c in profile:   # workload cells resolve identically
                want = base.lookup(c["primitive"], c["msg_bytes"],
                                   c["nranks"])
                got = refreshed.lookup(c["primitive"], c["msg_bytes"],
                                       c["nranks"])
                assert (got.backend, got.slicing_factor,
                        got.allreduce_mode) == \
                    (want.backend, want.slicing_factor,
                     want.allreduce_mode), (c, want, got)
            step = make_step()   # re-trace against the new epoch
        if i == 5:
            # flip an untouched broadcast cell: its *chosen* candidate
            # measures terribly, so the argmin must move off it
            bch = base.lookup("broadcast", 4096, 4)
            for _ in range(2):
                ot.observe("broadcast", 4096, 4, bch.backend, 10.0,
                           slicing_factor=bch.slicing_factor,
                           allreduce_mode=bch.allreduce_mode)
            refreshed = ot.refresh_and_activate()
            assert tuner.choices_changed(base, refreshed)
            step = make_step()
        p_hot = step(p_hot, x)

    assert np.array_equal(np.asarray(p_ref), np.asarray(p_hot)), \
        "hot-swap perturbed the numerics"
    tuner.set_active_plan(base)
    print("  online-retune-hotswap ok (bitwise vs fixed plan)")


def check_obs_metrics() -> None:
    """Every gauge ``obs.from_ledger`` exports must reconcile exactly
    with the ``ledger.snapshot()`` it was built from - per collective
    kind, per (level, fabric) attribution, and in total - and survive a
    JSON-lines round trip.  Run against a real 2-level hierarchical
    AllReduce so the snapshot carries multi-fabric attribution."""
    from repro.core import ledger
    from repro.core.hw import CXLPoolConfig, InfiniBandConfig
    from repro.core.topology import Level, Topology
    from repro.obs import MetricsRegistry, from_ledger

    topo = Topology(levels=(
        Level("pod", "ib", ib=InfiniBandConfig(link_bw=12.5e9)),
        Level("node", "cxl", pool=CXLPoolConfig(device_bw=18e9)),
    ))
    mesh = jax.make_mesh((2, 4), ("pod", "node"))
    comm = Communicator(backend="cxl", topology=topo)
    # detached stream: the chaotic train-equivalence checks depend on
    # the module RNG's draw order
    x = np.random.default_rng(23).standard_normal(
        (64, 5)).astype(np.float32)
    ledger.reset()
    jax.jit(jax.shard_map(
        lambda a: comm.all_gather(comm.all_reduce(a, ("pod", "node")),
                                  ("pod", "node")),
        mesh=mesh, in_specs=P(("pod", "node")), out_specs=P(),
        check_vma=False)).lower(x)
    snap = ledger.snapshot()
    assert snap["wire_bytes"] and snap["level_wire_bytes"], snap

    reg = MetricsRegistry()
    from_ledger(reg, snap)
    for kind, b in snap["wire_bytes"].items():
        assert reg.value("repro_wire_bytes", kind=kind) == b, kind
    for kind, c in snap["collective_calls"].items():
        assert reg.value("repro_collective_launches",
                         kind=kind) == c, kind
    for lk, kinds in snap["level_wire_bytes"].items():
        level, _, fabric = lk.partition("/")
        for kind, b in kinds.items():
            assert reg.value("repro_level_wire_bytes", level=level,
                             fabric=fabric, kind=kind) == b, (lk, kind)
    # per-level attribution partitions the wire total
    lvl_total = sum(b for kinds in snap["level_wire_bytes"].values()
                    for b in kinds.values())
    assert abs(lvl_total - snap["total_wire_bytes"]) < 1e-6, \
        (lvl_total, snap["total_wire_bytes"])
    # the JSON-lines artifact round-trips to the same values
    import json as _json
    seen = {}
    for line in reg.to_jsonl().splitlines():
        rec = _json.loads(line)
        seen[(rec["name"], tuple(sorted(rec["labels"].items())))] = \
            rec["value"]
    for kind, b in snap["wire_bytes"].items():
        assert seen[("repro_wire_bytes", (("kind", kind),))] == b
    print(f"  obs-metrics ok ({len(seen)} samples reconcile with the "
          f"ledger)")


def check_ledger_vs_hlo():
    """For an unscanned program the trace-time ledger and the compiled-HLO
    parse must agree on collective wire bytes (the scan undercount is the
    only reason the two differ - see EXPERIMENTS.md §Dry-run)."""
    from repro.core import ledger
    from repro.launch.dryrun import parse_collectives
    mesh = jax.make_mesh((8,), ("x",))
    comm = Communicator()

    def f(a):
        return comm.all_reduce(comm.all_gather(a, "x"), "x")

    ledger.reset()
    lowered = jax.jit(jax.shard_map(
        f, mesh=mesh, in_specs=P("x"), out_specs=P("x"),
        check_vma=False)).lower(
        jax.ShapeDtypeStruct((64, 128), jnp.float32))
    led = ledger.snapshot()["total_wire_bytes"]
    hlo = parse_collectives(lowered.compile().as_text())
    parsed = hlo["total_wire_bytes"]
    ratio = parsed / led if led else 0.0
    # XLA may fuse/convert ops (e.g. AR -> AG or RS+AG) so allow 2x band
    assert 0.4 < ratio < 2.5, (led, parsed, hlo)
    print(f"  ledger-vs-hlo ok (ledger {led/1e3:.1f}KB, "
          f"hlo {parsed/1e3:.1f}KB)")


def check_ragged_reduce_scatter() -> None:
    """Padding-free ragged reduce_scatter: a 4+2 grouped level on one
    flat 6-rank axis must return the same rank-major segments as the
    flat single-axis schedule (allclose - the grouped decomposition
    reassociates the sum), attribute the within-group bytes to the cxl
    level and the sub-root exchange to the parent ib fabric, and record
    NO flat-on-ragged fallback event: the ragged schedule is the real
    path, not a padded or flattened escape hatch."""
    from repro import tuner
    from repro.core import ledger
    from repro.core.hw import CXLPoolConfig, InfiniBandConfig
    from repro.core.topology import Level, Topology

    rng = np.random.default_rng(23)
    topo = Topology(levels=(
        Level("pod", "ib", ib=InfiniBandConfig(link_bw=2.5e9)),
        Level("node", "cxl", pool=CXLPoolConfig(device_bw=18e9),
              shape=(4, 2)),
    ))
    plan = tuner.generate_plan(
        tuner.TuneGrid(sizes=(4096, 65536), nranks=(2, 4),
                       slicing_factors=(1, 4)), topology=topo)
    mesh6 = jax.sharding.Mesh(np.asarray(jax.devices()[:6]), ("node",))
    mesh1 = jax.sharding.Mesh(np.asarray(jax.devices()[:6]), ("x",))
    # per-rank lead 12 divides the 6-rank axis; seg = 2 rows
    x = rng.standard_normal((6 * 12, 5)).astype(np.float32)

    def run(mesh, spec, f, arr):
        return np.asarray(jax.jit(jax.shard_map(
            f, mesh=mesh, in_specs=P(spec), out_specs=P(spec),
            check_vma=False))(arr))

    for backend in ("ring", "cxl", "auto"):
        comm = Communicator(backend=backend, plan=plan, topology=topo)
        flat = Communicator(backend=backend, plan=plan)
        ledger.reset()
        rs6 = run(mesh6, "node",
                  lambda a: comm.reduce_scatter(a, "node"), x)
        snap = ledger.snapshot()
        assert snap["fallbacks"] == [], (backend, snap["fallbacks"])
        lvl = {k: sum(v.values())
               for k, v in snap["level_wire_bytes"].items()}
        assert set(lvl) == {"node/cxl", "pod/ib"}, lvl
        assert lvl["pod/ib"] < lvl["node/cxl"], lvl
        rs1 = run(mesh1, "x", lambda a: flat.reduce_scatter(a, "x"), x)
        np.testing.assert_allclose(rs6, rs1, rtol=1e-5, atol=1e-6,
                                   err_msg=backend)
        if backend == "auto":
            ns = {(a["level"], a["nranks"])
                  for a in snap["auto_choices"]
                  if a["primitive"] == "reduce_scatter"}
            # within-group rings at the max group, sub-root exchange
            # at the group count on the parent level
            assert ("node", 4) in ns and ("pod", 2) in ns, ns
    print("  ragged-reduce-scatter ok (4+2 vs flat, no fallback)")


def check_fused_train(ragged: bool) -> None:
    """``TrainConfig.fuse_kernels`` routes the FSDP AllGather into the
    consuming matmuls (kernels.fused_collectives via StackedShards) -
    one sharded AdamW step must match the unfused bucketed path on the
    same mesh, and the ledger must book the gathered weight bytes into
    the fused split (and book nothing there when the flag is off).
    ``ragged=True`` re-runs the comparison on a 6-rank 4+2 grouped dp
    axis, where the gather's AD transpose lowers to the padding-free
    ragged reduce_scatter - no fallback events allowed."""
    from repro.core import ledger
    from repro.models.config import ModelConfig, dense_pattern
    from repro.optim import AdamWState
    from repro.training.train_loop import make_gather_fn as mk_gather

    rng = np.random.default_rng(77)
    if ragged:
        from repro.core.hw import CXLPoolConfig, InfiniBandConfig
        from repro.core.topology import Level, Topology
        topo = Topology(levels=(
            Level("pod", "ib", ib=InfiniBandConfig(link_bw=2.5e9)),
            Level("data", "cxl", pool=CXLPoolConfig(device_bw=18e9),
                  shape=(4, 2)),
        ))
        mesh = jax.sharding.Mesh(
            np.asarray(jax.devices()[:6]).reshape(6, 1),
            ("data", "model"))
        # d_model divisible by the ragged dp=6 and past FSDP_MIN_SIZE
        # (384*384 elements), so the matmul weights actually shard
        cfg = ModelConfig(name="tiny-fsdp6", family="dense",
                          n_layers=2, d_model=384, n_heads=6,
                          n_kv_heads=2, d_ff=768, vocab_size=512,
                          layer_pattern=dense_pattern(2))
        dp, tp = 6, 1
        comm = Communicator(backend="cxl", topology=topo)
    else:
        mesh = jax.make_mesh((2, 2), ("data", "model"))
        cfg = get_config("llama3-8b", smoke=True)
        dp, tp = 2, 2
        comm = Communicator(backend="ring")
    params = model.init_params(jax.random.key(7), cfg, tp=tp,
                               dtype=jnp.float32)
    B, L = dp, 16
    batch = {"tokens": jnp.asarray(rng.integers(0, cfg.vocab_size,
                                                (B, L))),
             "labels": jnp.asarray(rng.integers(0, cfg.vocab_size,
                                                (B, L)))}
    bspecs = {"tokens": P("data"), "labels": P("data")}

    sharding.set_mesh_sizes({"model": tp, "data": dp})
    pc = ParallelContext(tp_axis="model", dp_axis="data", tp=tp,
                         comm=comm)
    pspecs = sharding.param_specs(params, cfg, dp_axis="data",
                                  fsdp=True)
    rspecs = sharding.row_specs(pspecs)
    ospecs = AdamWState(step=P(), mu=pspecs, nu=pspecs)
    mspecs = {"loss": P(), "lr": P(), "grad_norm": P(), "xent": P(),
              "aux": P()}

    out = {}
    for fuse in (False, True):
        tcfg = TrainConfig(lr=1e-3, warmup=0, clip_norm=None,
                           remat=False, fuse_kernels=fuse)
        gather = mk_gather(tcfg, rspecs, pc, "data")
        inner = make_train_step(cfg, tcfg, pc, gather_fn=gather,
                                param_spec_tree=pspecs, dp_axis="data")
        step = jax.jit(jax.shard_map(
            inner, mesh=mesh, in_specs=(pspecs, ospecs, bspecs),
            out_specs=(pspecs, ospecs, mspecs), check_vma=False))
        ledger.reset()
        p2, _, m2 = step(params, adamw_init(params), batch)
        out[fuse] = (p2, m2, ledger.snapshot())
    (p_u, m_u, snap_u), (p_f, m_f, snap_f) = out[False], out[True]

    # the flag alone flips the fused split on and off
    assert snap_u["total_fused_bytes"] == 0.0, snap_u["fused_bytes"]
    assert snap_f["fused_bytes"].get("all_gather", 0.0) > 0.0, \
        snap_f["fused_bytes"]
    if ragged:
        assert snap_f["fallbacks"] == [], snap_f["fallbacks"]
        assert snap_u["fallbacks"] == [], snap_u["fallbacks"]
    assert abs(float(m_f["loss"]) - float(m_u["loss"])) < 1e-5, \
        (float(m_f["loss"]), float(m_u["loss"]))
    errs = jax.tree.map(
        lambda a, b: float(jnp.max(jnp.abs(a - b))), p_u, p_f)
    worst = max(jax.tree.leaves(errs))
    # the kernels differ from the unfused path only in f32 matmul
    # summation order, but AdamW's first step normalizes to
    # ~sign(g)*lr, so near-zero grad elements amplify that ulp-level
    # noise toward lr=1e-3; observed worst deltas are ~2e-4
    assert worst < 5e-4, f"fused-vs-unfused param delta {worst}"
    print(f"  fused-train[{'ragged 4+2' if ragged else '2x2'}] ok "
          f"(loss {float(m_f['loss']):.4f}, worst delta {worst:.1e}, "
          f"fused AG {snap_f['fused_bytes']['all_gather']/1e6:.2f}MB)")


def check_fallback_audit() -> None:
    """all_to_all / scatter have no grouped schedule: on a grouped
    (4+2) level they run the flat single-axis program and must book one
    explicit flat-on-ragged fallback event per call - with the level
    and fabric they degraded on - while still computing the flat
    schedule's exact answer.  The inverse of check_ragged_reduce_scatter
    (which asserts the ragged path books NO events)."""
    from repro.core import ledger
    from repro.core.hw import CXLPoolConfig, InfiniBandConfig
    from repro.core.topology import Level, Topology

    rng = np.random.default_rng(31)
    topo = Topology(levels=(
        Level("pod", "ib", ib=InfiniBandConfig(link_bw=2.5e9)),
        Level("node", "cxl", pool=CXLPoolConfig(device_bw=18e9),
              shape=(4, 2)),
    ))
    mesh6 = jax.sharding.Mesh(np.asarray(jax.devices()[:6]), ("node",))
    # per-rank lead 12 divides the 6-rank axis; a2a block / scatter
    # segment = 2 rows
    x = rng.standard_normal((6 * 12, 5)).astype(np.float32)

    def run(f, arr):
        return np.asarray(jax.jit(jax.shard_map(
            f, mesh=mesh6, in_specs=P("node"), out_specs=P("node"),
            check_vma=False))(arr))

    for backend in ("ring", "cxl"):
        comm = Communicator(backend=backend, topology=topo,
                            slicing_factor=4)
        ledger.reset()
        a2a = run(lambda a: comm.all_to_all(a, "node"), x)
        sc = run(lambda a: comm.scatter(a, "node", root=1), x)
        snap = ledger.snapshot()
        prims = sorted(e["primitive"] for e in snap["fallbacks"])
        assert prims == ["all_to_all", "scatter"], \
            (backend, snap["fallbacks"])
        for e in snap["fallbacks"]:
            assert (e["level"], e["fabric"], e["reason"]) == \
                ("node", "cxl", "flat_on_ragged"), e
            assert e["calls"] == 1.0, e
        # the degraded calls still attribute wire bytes to the level
        lvl = snap["level_wire_bytes"]["node/cxl"]
        assert lvl.get("all_to_all", 0.0) > 0.0, lvl
        assert lvl.get("scatter", 0.0) > 0.0, lvl
        # numerics vs the flat oracle
        z = x.reshape(6, 12, 5)
        np.testing.assert_allclose(
            a2a.reshape(6, 6, 2, 5),
            z.reshape(6, 6, 2, 5).transpose(1, 0, 2, 3), rtol=1e-6,
            err_msg=backend)
        np.testing.assert_allclose(
            sc.reshape(6, 2, 5), z[1].reshape(6, 2, 5), rtol=1e-6,
            err_msg=backend)
    print("  fallback-audit ok (all_to_all/scatter on 4+2 book "
          "flat_on_ragged)")


def check_pipeline_train() -> None:
    """Pipeline parallelism end to end on real devices: a 2-stage x
    4-dp pipelined AdamW step (1F1B microbatch loop, stage handoff via
    ``Communicator.send`` resolved from the plan's tuned p2p cells)
    must produce the same loss and updated params as the FSDP-only
    8-rank step on the same global batch, and the ledger must attribute
    the activation/cotangent handoff bytes to the stage level's fabric
    as ``p2p`` - not to any collective kind."""
    from repro import tuner
    from repro.core import ledger
    from repro.core.hw import CXLPoolConfig, InfiniBandConfig
    from repro.core.topology import Level, Topology, set_active_topology
    from repro.models.config import ModelConfig, dense_pattern
    from repro.training.pipeline import (bubble_fraction,
                                         make_sharded_pipeline_step)
    from repro.training.train_loop import make_sharded_train_step

    rng = np.random.default_rng(7)
    cfg = ModelConfig(name="tiny-pp", family="dense", n_layers=4,
                      d_model=64, n_heads=4, n_kv_heads=2, d_ff=128,
                      vocab_size=96, layer_pattern=dense_pattern(4))
    B, L, M = 16, 16, 4
    batch = {"tokens": jnp.asarray(
                 rng.integers(0, cfg.vocab_size, (B, L))),
             "labels": jnp.asarray(
                 rng.integers(0, cfg.vocab_size, (B, L)))}
    params = model.init_params(jax.random.key(1), cfg, tp=1,
                               dtype=jnp.float32)
    tcfg = TrainConfig(lr=1e-3, warmup=0, clip_norm=None, remat=False,
                       backend="ring")

    # FSDP-only reference: the same 8 devices as one data axis
    mesh_ref = jax.make_mesh((8, 1), ("data", "model"))
    sharding.set_mesh_sizes({"data": 8, "model": 1})
    step_ref, _, _, _ = make_sharded_train_step(cfg, tcfg, mesh_ref)
    p_ref, _, m_ref = step_ref(params, adamw_init(params), batch)

    # pipelined run: IB between stages, the CXL pool under the data
    # axis - the plan's per-level p2p cells steer the stage handoff
    base_plan = tuner.get_active_plan()
    topo = Topology(levels=(
        Level("stage", "ib", ib=InfiniBandConfig(link_bw=2.5e9)),
        Level("data", "cxl", pool=CXLPoolConfig(device_bw=18e9),
              shape=(4,)),
    ))
    plan = tuner.generate_plan(
        tuner.TuneGrid(sizes=(256, 4096, 65536), nranks=(2, 4, 8),
                       slicing_factors=(1, 4)), topology=topo)
    tuner.set_active_plan(plan)
    set_active_topology(topo)
    try:
        mesh = jax.make_mesh((2, 4), ("stage", "data"))
        tcfg_pp = dataclasses.replace(tcfg, backend="auto")
        step_pp, _, _, _ = make_sharded_pipeline_step(
            cfg, tcfg_pp, mesh, n_microbatches=M)
        ledger.reset()
        p_pp, _, m_pp = step_pp(params, adamw_init(params), batch)
        snap = ledger.snapshot()
    finally:
        tuner.set_active_plan(base_plan)
        set_active_topology(None)

    assert abs(float(m_pp["loss"]) - float(m_ref["loss"])) < 1e-5, \
        (float(m_pp["loss"]), float(m_ref["loss"]))
    errs = jax.tree.map(lambda a, b: float(jnp.max(jnp.abs(a - b))),
                        p_ref, p_pp)
    worst = max(jax.tree.leaves(errs))
    # same AdamW-first-step amplification band as check_fused_train:
    # the two paths differ only in f32 reduction order
    assert worst < 5e-4, f"pipeline-vs-fsdp param delta {worst}"
    lvl = snap["level_wire_bytes"]
    assert lvl.get("stage/ib", {}).get("p2p", 0.0) > 0.0, lvl
    assert "p2p" not in lvl.get("data/cxl", {}), lvl
    assert lvl.get("data/cxl", {}).get("all_reduce", 0.0) > 0.0, lvl
    p2p_audit = [a for a in snap["auto_choices"]
                 if a["primitive"] == "p2p"]
    assert p2p_audit and \
        all(a["level"] == "stage" for a in p2p_audit), p2p_audit
    assert abs(float(m_pp["bubble_fraction"])
               - bubble_fraction(2, M)) < 1e-6
    print(f"  pipeline-train ok (loss {float(m_pp['loss']):.4f} vs "
          f"fsdp {float(m_ref['loss']):.4f}, worst delta {worst:.1e}, "
          f"p2p {lvl['stage/ib']['p2p']/1e3:.1f}KB on stage/ib)")


if __name__ == "__main__":
    # backend='auto' resolves from the process-wide plan: tune a tiny
    # grid spanning the message sizes/axis sizes these checks use.
    from repro import tuner
    tuner.set_active_plan(tuner.generate_plan(tuner.TuneGrid(
        sizes=(256, 4096, 65536), nranks=(2, 4, 8),
        slicing_factors=(1, 4))))

    check_ledger_vs_hlo()
    check_obs_metrics()
    check_online_retune_hotswap()
    check_topology_hierarchical()
    check_irregular_ragged()
    check_ragged_reduce_scatter()
    check_survivor_reconfig()
    check_fused_train(ragged=False)
    check_fused_train(ragged=True)
    check_fallback_audit()
    check_pipeline_train()
    # ring/cxl draw from the module RNG in the original order (the
    # chaotic train-equivalence checks below are sensitive to the global
    # draw sequence); the added checks use a detached stream.
    for backend in ("ring", "cxl"):
        check_collectives(backend)
        check_hierarchical(backend)
    aux = np.random.default_rng(1234)
    for backend in ("ring", "cxl", "auto"):
        check_rank_major_layout(backend, rng=aux)
    check_collectives("auto", rng=aux)
    check_hierarchical("auto", rng=aux)
    for backend in ("ring", "cxl", "auto"):
        check_bucketed_sync_grads(backend)
        check_bucketed_gather(backend)
    for backend in ("ring", "cxl"):
        for arch in ("llama3-8b", "arctic-480b", "falcon-mamba-7b",
                     "zamba2-1.2b"):
            check_train_equivalence(backend, arch)
    print("MESH RUNNER: ALL OK")
