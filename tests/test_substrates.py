"""Optimizer, schedules, checkpointing, data pipeline."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.data.pipeline import (MemmapCorpus, SyntheticTokens, batch_for,
                                 write_corpus)
from repro.optim import (adamw_init, adamw_update, clip_by_global_norm,
                         cosine_schedule, linear_warmup_cosine)
from repro.training import checkpoint


def test_adamw_converges_on_quadratic():
    params = {"w": jnp.asarray([5.0, -3.0]), "b": jnp.asarray(2.0)}
    opt = adamw_init(params)

    def loss(p):
        return jnp.sum(p["w"] ** 2) + p["b"] ** 2

    for _ in range(200):
        g = jax.grad(loss)(params)
        params, opt = adamw_update(params, g, opt, lr=0.05)
    assert float(loss(params)) < 1e-2
    assert int(opt.step) == 200


def test_clip_by_global_norm():
    g = {"a": jnp.full((4,), 10.0)}
    clipped, norm = clip_by_global_norm(g, 1.0)
    assert norm == pytest.approx(20.0)
    assert float(jnp.linalg.norm(clipped["a"])) == pytest.approx(1.0,
                                                                 rel=1e-5)


def test_schedules():
    lr = linear_warmup_cosine(1e-3, warmup=10, total_steps=100)
    assert float(lr(jnp.int32(0))) == 0.0
    assert float(lr(jnp.int32(10))) == pytest.approx(1e-3, rel=1e-5)
    assert float(lr(jnp.int32(100))) < 3e-4
    cos = cosine_schedule(1.0, 100)
    assert float(cos(jnp.int32(0))) == pytest.approx(1.0)


def test_checkpoint_roundtrip(tmp_path):
    tree = {"a": jnp.arange(6).reshape(2, 3),
            "nested": {"b": jnp.ones((4,), jnp.float32)},
            "lst": [jnp.zeros((2,)), jnp.full((3,), 7.0)]}
    checkpoint.save(str(tmp_path), 42, tree, meta={"note": "x"})
    assert checkpoint.latest_step(str(tmp_path)) == 42
    like = jax.tree.map(lambda x: jnp.zeros_like(x), tree)
    restored = checkpoint.restore(str(tmp_path), 42, like)
    for a, b in zip(jax.tree.leaves(tree), jax.tree.leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    assert checkpoint.load_meta(str(tmp_path), 42)["note"] == "x"


def test_checkpoint_structure_mismatch_raises(tmp_path):
    checkpoint.save(str(tmp_path), 0, {"a": jnp.zeros((2,))})
    with pytest.raises(ValueError):
        checkpoint.restore(str(tmp_path), 0, {"a": jnp.zeros((3,))})
    with pytest.raises(ValueError):
        checkpoint.restore(str(tmp_path), 0, {"b": jnp.zeros((2,))})


def test_synthetic_tokens():
    cfg = get_config("llama3.2-1b", smoke=True)
    it = iter(SyntheticTokens(cfg, batch=4, seq=16, seed=1))
    b = next(it)
    assert b["tokens"].shape == (4, 16)
    assert b["labels"].shape == (4, 16)
    assert b["tokens"].max() < cfg.vocab_size
    # next-token alignment comes from the same (L+1) window
    b2 = next(iter(SyntheticTokens(cfg, batch=4, seq=16, seed=1)))
    np.testing.assert_array_equal(b["tokens"], b2["tokens"])  # determinism


def test_memmap_corpus(tmp_path):
    cfg = get_config("llama3.2-1b", smoke=True)
    path = os.path.join(tmp_path, "corpus.bin")
    write_corpus(path, np.arange(10_000) % cfg.vocab_size)
    it = iter(MemmapCorpus(cfg, path, batch=2, seq=32))
    b = next(it)
    assert b["tokens"].shape == (2, 32)
    # labels are the shifted window
    np.testing.assert_array_equal(b["tokens"][:, 1:], b["labels"][:, :-1])


def test_frontend_batches():
    cfg = get_config("phi-3-vision-4.2b", smoke=True)
    b = batch_for(cfg, np.zeros((2, 17), np.int64))
    assert b["frontend"].shape == (2, cfg.frontend_tokens,
                                   cfg.frontend_dim)
    cfg = get_config("whisper-tiny", smoke=True)
    b = batch_for(cfg, np.zeros((2, 17), np.int64))
    assert b["source"].shape == (2, cfg.encoder.source_len,
                                 cfg.frontend_dim)
