"""Token data pipeline.

Two sources:

* ``SyntheticTokens`` - deterministic PRNG LM batches (zipf-ish marginal
  so losses are non-degenerate); used by the examples and benchmarks.
* ``MemmapCorpus`` - a flat binary token file sampled in windows, the
  standard "one big .bin" pretraining layout.

Batches are host-built numpy and sharded onto the mesh by the launcher
(``jax.device_put`` with a ``NamedSharding`` over the dp axis).  Each
batch dict matches ``model.loss_fn``: tokens, labels (next-token shifted)
and the modality extras demanded by the architecture's frontend.
"""
from __future__ import annotations

import dataclasses
from typing import Iterator, Optional

import numpy as np
from jax.sharding import PartitionSpec as P

from repro.models.config import ModelConfig


def make_batch_specs(cfg: ModelConfig, dp_axis) -> dict:
    specs = {"tokens": P(dp_axis), "labels": P(dp_axis)}
    if cfg.frontend == "vision_stub" and cfg.encoder is None:
        specs["frontend"] = P(dp_axis)
    if cfg.encoder is not None:
        specs["source"] = P(dp_axis)
    return specs


def batch_for(cfg: ModelConfig, tokens: np.ndarray,
              rng: Optional[np.random.Generator] = None) -> dict:
    """tokens (B, L+1) -> training batch with next-token labels and the
    frontend extras (random stub embeddings)."""
    rng = rng or np.random.default_rng(0)
    b = {"tokens": tokens[:, :-1].astype(np.int32),
         "labels": tokens[:, 1:].astype(np.int32)}
    n = tokens.shape[0]
    if cfg.frontend == "vision_stub" and cfg.encoder is None:
        b["frontend"] = rng.standard_normal(
            (n, cfg.frontend_tokens, cfg.frontend_dim)).astype(np.float32)
    if cfg.encoder is not None:
        b["source"] = rng.standard_normal(
            (n, cfg.encoder.source_len,
             cfg.frontend_dim or cfg.d_model)).astype(np.float32)
    return b


@dataclasses.dataclass
class SyntheticTokens:
    cfg: ModelConfig
    batch: int
    seq: int
    seed: int = 0

    def __iter__(self) -> Iterator[dict]:
        rng = np.random.default_rng(self.seed)
        zipf_p = 1.0 / np.arange(1, self.cfg.vocab_size + 1) ** 1.1
        zipf_p /= zipf_p.sum()
        while True:
            toks = rng.choice(self.cfg.vocab_size,
                              size=(self.batch, self.seq + 1), p=zipf_p)
            yield batch_for(self.cfg, toks, rng)


@dataclasses.dataclass
class MemmapCorpus:
    cfg: ModelConfig
    path: str
    batch: int
    seq: int
    dtype: str = "uint16"
    seed: int = 0

    def __post_init__(self):
        self.tokens = np.memmap(self.path, dtype=self.dtype, mode="r")
        if len(self.tokens) < self.seq + 1:
            raise ValueError("corpus shorter than one sample window")

    def __iter__(self) -> Iterator[dict]:
        rng = np.random.default_rng(self.seed)
        n = len(self.tokens) - self.seq - 1
        while True:
            starts = rng.integers(0, n, size=self.batch)
            toks = np.stack([np.asarray(
                self.tokens[s:s + self.seq + 1]) for s in starts])
            toks = np.minimum(toks.astype(np.int64),
                              self.cfg.vocab_size - 1)
            yield batch_for(self.cfg, toks, rng)


def write_corpus(path: str, tokens: np.ndarray,
                 dtype: str = "uint16") -> None:
    np.asarray(tokens, dtype=dtype).tofile(path)
