from repro.data.pipeline import (SyntheticTokens, MemmapCorpus,
                                 make_batch_specs, batch_for)

__all__ = ["SyntheticTokens", "MemmapCorpus", "make_batch_specs",
           "batch_for"]
