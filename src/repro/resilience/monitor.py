"""Failure detection: heartbeats + link health -> confirmed failures.

``FailureMonitor`` composes three raw signals into *confirmed*
``Failure`` events with explicit timeout/patience semantics:

* **Rank liveness** from pool-side heartbeats
  (``core.doorbell.HeartbeatRegion``): each live rank writes its step
  into its liveness word once per step; a rank whose word falls more
  than ``heartbeat_timeout`` steps behind is *suspect*, and stays so
  for ``patience`` further steps before the monitor confirms it dead
  (a rank that resumes pulsing in that window is re-admitted with no
  event).  Confirmed verdicts publish to
  ``tuner.runtime.set_rank_liveness`` - the planner-facing registry.
* **Link degradation** from the ``obs.health.HealthMonitor`` EWMAs
  (which carry their own warmup/threshold/patience): its
  degraded/recovered transitions pass through as failures, and
  ``persistent_links`` tells the re-planner which degrades have
  outlived ``failover_patience`` and warrant failover rather than
  waiting.
* **Pool errors**: ``record_pool_error`` counts ``PoolAccessError``s
  that survived retry; ``pool_error_patience`` consecutive erroring
  steps confirm a pool fault (isolated transients never do - the
  retry layer already absorbed their cost).
"""
from __future__ import annotations

import dataclasses
from typing import Optional

from repro.core import pool as pool_mod
from repro.core.doorbell import HeartbeatRegion
from repro.obs.health import HealthMonitor
from repro.tuner import runtime


@dataclasses.dataclass(frozen=True)
class Failure:
    """One confirmed failure (or recovery) verdict."""

    kind: str          # "rank_death" | "link_degraded" |
    #                    "link_recovered" | "pool_errors"
    step: int          # the step the verdict was confirmed at
    rank: Optional[int] = None
    link: Optional[str] = None
    detail: dict = dataclasses.field(default_factory=dict)

    def describe(self) -> str:
        what = (f"rank {self.rank}" if self.rank is not None
                else f"link {self.link}" if self.link is not None
                else "pool")
        return f"{self.kind}({what}) confirmed at step {self.step}"


class FailureMonitor:
    """Timeout/patience promotion of raw health signals to verdicts.

    Timeline for a rank that stops pulsing after step ``s``: it reads
    as *suspect* once ``step - last_beat > heartbeat_timeout`` and is
    confirmed dead ``patience`` steps later, i.e. at
    ``s + heartbeat_timeout + patience`` - tight enough to bound steps
    lost, loose enough that one dropped pulse (a transient pool fault)
    never kills a live rank.
    """

    def __init__(self, nranks: int, *, heartbeat_timeout: int = 1,
                 patience: int = 2, pool_error_patience: int = 3,
                 failover_patience: int = 2,
                 health: Optional[HealthMonitor] = None,
                 publish: bool = True):
        self.nranks = int(nranks)
        self.heartbeat_timeout = max(1, int(heartbeat_timeout))
        self.patience = max(1, int(patience))
        self.pool_error_patience = max(1, int(pool_error_patience))
        self.failover_patience = max(1, int(failover_patience))
        self.health = health if health is not None else HealthMonitor(
            publish=publish)
        self.publish = publish
        self.heartbeats = HeartbeatRegion(self.nranks)
        self.confirmed_dead: set = set()
        self.pool_errors_step = 0           # errors recorded this step
        self._pool_error_streak = 0
        self._pool_confirmed = False
        self._published: dict = {}          # rank -> last liveness
        self.failures: list = []            # every verdict, in order

    # -- per-step inputs --------------------------------------------------
    def pulse_all(self, step: int) -> int:
        """Pulse every not-confirmed-dead rank's heartbeat (what the
        emulated step loop does on the ranks' behalf).  A pulse the
        fault hook rejects is simply lost - exactly a dead or faulted
        rank's behavior; a rejected pulse by a live rank also counts a
        pool error.  Returns the number of pulses that landed."""
        landed = 0
        for r in range(self.nranks):
            if r in self.confirmed_dead:
                continue
            try:
                self.heartbeats.pulse(r, step)
                landed += 1
            except pool_mod.PoolAccessError:
                self.record_pool_error(step)
        return landed

    def record_pool_error(self, step: int) -> None:
        """Count one pool access that failed past its retry budget."""
        del step
        self.pool_errors_step += 1

    def observe_timings(self, timings: list) -> None:
        self.health.observe_timings(timings)

    # -- the verdict ------------------------------------------------------
    def end_step(self, step: int, timings: Optional[list] = None
                 ) -> list:
        """Close the step: fold link-health samples, poll heartbeats,
        settle pool-error streaks.  Returns the ``Failure`` verdicts
        confirmed at this step."""
        out: list = []
        if timings:
            self.health.observe_timings(timings)
        for ev in self.health.end_step(step):
            kind = ("link_degraded" if ev["event"] == "degraded"
                    else "link_recovered")
            out.append(Failure(kind=kind, step=int(step),
                               link=ev["link"], detail=dict(ev)))

        # heartbeat staleness -> suspect -> confirmed dead
        for r in range(self.nranks):
            if r in self.confirmed_dead:
                continue
            behind = step - self.heartbeats.read(r)
            suspect_for = behind - self.heartbeat_timeout
            if suspect_for >= self.patience:
                self.confirmed_dead.add(r)
                out.append(Failure(
                    kind="rank_death", step=int(step), rank=r,
                    detail={"last_beat": self.heartbeats.read(r),
                            "behind_steps": behind}))
            if self.publish:
                # event-driven: the registry holds state, so only a
                # *changed* verdict (alive/suspect transition) is
                # republished - the per-step monitor cost stays flat
                # when everything is healthy
                state = (r not in self.confirmed_dead,
                         suspect_for > 0)
                if self._published.get(r) != state:
                    self._published[r] = state
                    runtime.set_rank_liveness(r, {
                        "alive": state[0],
                        "last_beat_step": self.heartbeats.read(r),
                        "suspect": state[1], "step": int(step)})

        # pool-error streaks: only sustained windows confirm
        if self.pool_errors_step > 0:
            self._pool_error_streak += 1
            if (self._pool_error_streak >= self.pool_error_patience
                    and not self._pool_confirmed):
                self._pool_confirmed = True
                out.append(Failure(
                    kind="pool_errors", step=int(step),
                    detail={"streak": self._pool_error_streak,
                            "errors": self.pool_errors_step}))
        else:
            self._pool_error_streak = 0
            self._pool_confirmed = False
        self.pool_errors_step = 0

        self.failures.extend(out)
        return out

    # -- promotion queries ------------------------------------------------
    def dead_ranks(self) -> list:
        return sorted(self.confirmed_dead)

    def persistent_links(self, step: int) -> list:
        """Degraded links that have outlived ``failover_patience`` -
        the ones a re-planner should fail over rather than wait out."""
        return self.health.persistent_links(step, self.failover_patience)

    def link_penalties(self) -> dict:
        """Measured slowdown multipliers for currently degraded links,
        shaped for ``tuner.placement.plan_placement(link_penalties=)``."""
        return {k: max(1.0, st.slowdown())
                for k, st in self.health.links.items() if st.degraded}

    def report(self) -> dict:
        return {"dead_ranks": self.dead_ranks(),
                "degraded_links": self.health.degraded_links(),
                "heartbeat_timeout": self.heartbeat_timeout,
                "patience": self.patience,
                "failures": [f.describe() for f in self.failures]}
