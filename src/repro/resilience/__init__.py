"""Fault tolerance and elastic reconfiguration.

The recovery loop, end to end and operator-free:

* inject  — ``FaultPlan`` (seeded rank deaths / link degrades / pool
  errors) through the emulator degrade hooks and the ``core.pool``
  fault shim;
* detect  — ``FailureMonitor``: pool-side heartbeats + link-health
  EWMAs + pool-error streaks, promoted to confirmed ``Failure``s
  under explicit timeout/patience;
* re-plan — ``replan``/``survivor_topology``/``failover_topology``:
  ragged survivor shapes, cxl->ib level failover, placement re-ranked
  under measured link penalties, hot-swapped through the
  epoch-versioned registry;
* resume  — pool-resident checkpoints
  (``training.checkpoint.PoolCheckpointStore``) roll the survivors
  back warm; ``ResilienceController`` sequences all of it from inside
  a step loop.

See ``docs/RESILIENCE.md`` for the failure model and knobs.
"""
from repro.resilience.controller import ResilienceController
from repro.resilience.faults import FaultEvent, FaultPlan
from repro.resilience.monitor import Failure, FailureMonitor
from repro.resilience.replan import (RecoveryPlan, failover_topology,
                                     health_penalties, replan,
                                     survivor_topology)

__all__ = [
    "FaultEvent",
    "FaultPlan",
    "Failure",
    "FailureMonitor",
    "RecoveryPlan",
    "ResilienceController",
    "failover_topology",
    "health_penalties",
    "replan",
    "survivor_topology",
]
