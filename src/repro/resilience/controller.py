"""The closed loop: detect -> re-plan -> resume, without an operator.

``ResilienceController`` owns one ``FailureMonitor`` and drives the
whole recovery path from inside a step loop:

1. each step the loop forwards its timing samples and pulses the
   heartbeats (``step``);
2. when the monitor confirms an *actionable* failure - a rank death,
   or a cxl link degraded past ``failover_patience`` - the controller
   calls ``resilience.replan`` over the active topology, applies the
   ``RecoveryPlan`` (epoch-versioned hot-swap + topology activation),
   and hands the plan back so the launcher can re-trace its step,
   rebuild its mesh over the survivors, and roll state back to the
   newest pool-resident snapshot;
3. a later ``link_recovered`` on a failed-over level triggers a
   re-plan *back* onto the original topology (the pool won its level
   back), closing the transient-degrade loop without a restart.

Steps-lost accounting: the controller stamps each recovery with the
confirmation step and the restored snapshot step; ``steps_lost`` for
a rank death is (confirm - snapshot) rollback plus the detection
latency the monitor's timeout/patience impose - the quantity
``benchmarks/resilience.py`` commits bounds on.
"""
from __future__ import annotations

from typing import Callable, Optional

from repro.core.topology import Topology, get_active_topology
from repro.resilience.monitor import Failure, FailureMonitor
from repro.resilience.replan import RecoveryPlan, replan
from repro.tuner.placement import CollectiveMix
from repro.tuner.sweep import TuneGrid


class ResilienceController:
    """Detect/re-plan/resume policy around a ``FailureMonitor``."""

    def __init__(self, monitor: FailureMonitor, *,
                 topology: Optional[Topology] = None,
                 mix: Optional[CollectiveMix] = None,
                 grid: Optional[TuneGrid] = None,
                 unsplit: tuple = (),
                 axis_sizes: Optional[dict] = None,
                 auto_apply: bool = True,
                 on_replan: Optional[Callable[[RecoveryPlan], None]]
                 = None,
                 log: Callable[[str], None] = print):
        self.monitor = monitor
        self._topology = topology
        self.original_topology = (topology if topology is not None
                                  else get_active_topology())
        self.mix = mix
        self.grid = grid
        self.unsplit = tuple(unsplit)
        self.axis_sizes = dict(axis_sizes or {})
        self.auto_apply = auto_apply
        self.on_replan = on_replan
        self.log = log
        self.recoveries: list = []          # applied RecoveryPlans
        self.failed_over: set = set()       # links currently on IB
        self.replans = 0

    @property
    def topology(self) -> Optional[Topology]:
        return (self._topology if self._topology is not None
                else get_active_topology())

    # -- the per-step hook ------------------------------------------------
    def step(self, step: int, timings: Optional[list] = None, *,
             pulse: bool = True) -> Optional[RecoveryPlan]:
        """Run one detection round; returns the applied
        ``RecoveryPlan`` when this step confirmed something
        actionable, else None."""
        if pulse:
            self.monitor.pulse_all(step)
        failures = self.monitor.end_step(step, timings=timings)
        if not failures:
            return None
        actionable = []
        recovered = []
        topo = self.topology
        for f in failures:
            if f.kind == "rank_death":
                actionable.append(f)
            elif f.kind == "link_degraded" and topo is not None:
                axis = f.link.split("/", 1)[0]
                lv = topo.level_for(axis)
                if lv is not None and lv.fabric == "cxl":
                    actionable.append(f)
            elif f.kind == "link_recovered":
                recovered.append(f)
        if recovered and not actionable:
            rp = self._replan_back(step, recovered)
            if rp is not None:
                return rp
        if not actionable:
            for f in failures:
                self.log(f"[resilience] {f.describe()} (no re-plan)")
            return None
        return self._replan(step, actionable)

    # -- re-planning ------------------------------------------------------
    def _replan(self, step: int,
                failures: list) -> Optional[RecoveryPlan]:
        topo = self.topology
        if topo is None:
            self.log("[resilience] confirmed failure but no active "
                     "topology to re-plan; resume-only recovery")
            return None
        rp = replan(failures, topo, mix=self.mix, grid=self.grid,
                    link_penalties=self.monitor.link_penalties(),
                    unsplit=self.unsplit, axis_sizes=self.axis_sizes)
        self._finish(step, rp, failures)
        for f in failures:
            if f.kind == "link_degraded":
                self.failed_over.add(f.link)
        return rp

    def _replan_back(self, step: int,
                     recovered: list) -> Optional[RecoveryPlan]:
        """A recovered link whose level we failed over: re-plan onto
        the original topology - the pool wins its level back."""
        hits = [f for f in recovered if f.link in self.failed_over]
        if not hits or self.original_topology is None:
            return None
        from repro.tuner.sweep import SMOKE_GRID, generate_plan
        topo = self.original_topology
        plan = generate_plan(self.grid if self.grid is not None
                             else SMOKE_GRID, topology=topo)
        rp = RecoveryPlan(
            topology=topo, plan=plan,
            reason="recovered: " + ", ".join(f.link for f in hits),
            failures=tuple(hits))
        self._finish(step, rp, hits)
        for f in hits:
            self.failed_over.discard(f.link)
        return rp

    def _finish(self, step: int, rp: RecoveryPlan,
                failures: list) -> None:
        self.replans += 1
        if self.auto_apply:
            rp.apply()
            if self._topology is not None:
                self._topology = rp.topology
        self.recoveries.append({"step": int(step), "plan": rp,
                                "failures": [f.describe()
                                             for f in failures]})
        self.log(f"[resilience] step {step}: {rp.describe()}")
        if self.on_replan is not None:
            self.on_replan(rp)

    # -- accounting -------------------------------------------------------
    def steps_lost(self, fault_step: int, confirm_step: int,
                   snapshot_step: Optional[int]) -> int:
        """Steps of training lost to one failure: detection latency
        (fault -> confirmation, inclusive) plus the rollback from the
        confirmation point to the newest committed snapshot."""
        detect = max(0, int(confirm_step) - int(fault_step) + 1)
        rollback = (max(0, int(confirm_step) - int(snapshot_step))
                    if snapshot_step is not None else 0)
        return detect + rollback

    def report(self) -> dict:
        return {"replans": self.replans,
                "failed_over": sorted(self.failed_over),
                "recoveries": [{"step": r["step"],
                                "reason": r["plan"].reason,
                                "failures": r["failures"]}
                               for r in self.recoveries],
                "monitor": self.monitor.report()}
