"""Seeded fault injection: the chaos half of the recovery loop.

A ``FaultPlan`` is a deterministic schedule of ``FaultEvent``s - rank
deaths, persistent or transient link degradations, windows of
pool-access failures - driven through the two seams the rest of the
repo already has:

* **link degrades** multiply the ``obs.StepEmulator`` per-level
  slowdown factors (``set_degrade``).  A pool-side degrade uses the
  backend-qualified key (``"node@cxl"``) so the level's ring/IB
  alternative keeps its healthy speed - that is what makes failover
  worth anything.
* **rank deaths and pool errors** install as the ``core.pool`` fault
  hook: every emulated pool access (collective write/read, heartbeat
  pulse, pool-checkpoint store) consults it, and the hook raises
  ``PoolAccessError`` for accesses by a dead rank or inside an active
  pool-error window (Bernoulli at ``error_rate``, seeded).

Determinism: the schedule is explicit and the pool-error coin flips
come from a ``numpy`` generator seeded at construction, so a fault run
is exactly reproducible - benchmarks commit bounds against it.
"""
from __future__ import annotations

import dataclasses
import re
from typing import Optional

import numpy as np

from repro.core import pool as pool_mod

_KINDS = ("rank_death", "link_degrade", "pool_error")


@dataclasses.dataclass(frozen=True)
class FaultEvent:
    """One scheduled fault.

    ``step`` is the first step the fault is active; ``until_step``
    (exclusive) ends a *transient* fault, ``None`` makes it
    persistent.  Field use by kind:

    * ``rank_death``: ``rank`` dies at ``step`` (pool stores fail,
      heartbeat goes stale).  Always persistent.
    * ``link_degrade``: emulator degrade key ``link`` (axis, fabric,
      ``"axis@backend"``, or ``"*"``) slows by ``factor`` while
      active.
    * ``pool_error``: while active, any pool access fails with
      probability ``error_rate`` (1.0 = every access).
    """

    kind: str
    step: int
    rank: Optional[int] = None
    link: Optional[str] = None
    factor: float = 4.0
    until_step: Optional[int] = None
    error_rate: float = 1.0

    def __post_init__(self) -> None:
        if self.kind not in _KINDS:
            raise ValueError(f"unknown fault kind {self.kind!r} "
                             f"(one of {_KINDS})")
        if self.kind == "rank_death" and self.rank is None:
            raise ValueError("rank_death needs rank=")
        if self.kind == "link_degrade" and self.link is None:
            raise ValueError("link_degrade needs link=")
        if self.until_step is not None and self.until_step <= self.step:
            raise ValueError("until_step must be > step")

    def active(self, step: int) -> bool:
        if step < self.step:
            return False
        if self.kind == "rank_death":
            return True                     # death is forever
        return self.until_step is None or step < self.until_step

    def describe(self) -> str:
        span = (f"@{self.step}" if self.until_step is None
                else f"@{self.step}-{self.until_step}")
        if self.kind == "rank_death":
            return f"rank_death{span}:rank={self.rank}"
        if self.kind == "link_degrade":
            return f"link_degrade{span}:link={self.link},x{self.factor}"
        return f"pool_error{span}:rate={self.error_rate}"


_SPEC_RE = re.compile(
    r"(?P<kind>\w+)@(?P<step>\d+)(?:-(?P<until>\d+))?"
    r"(?::(?P<kv>[^;]*))?")


def _parse_one(part: str) -> FaultEvent:
    m = _SPEC_RE.fullmatch(part.strip())
    if m is None:
        raise ValueError(
            f"bad fault spec {part!r}; expected "
            f"kind@step[-until][:k=v,...], e.g. rank_death@12:rank=3")
    kv = {}
    for item in (m.group("kv") or "").split(","):
        if not item:
            continue
        k, _, v = item.partition("=")
        kv[k.strip()] = v.strip()
    kw: dict = {"kind": m.group("kind"), "step": int(m.group("step"))}
    if m.group("until") is not None:
        kw["until_step"] = int(m.group("until"))
    if "rank" in kv:
        kw["rank"] = int(kv["rank"])
    if "link" in kv:
        kw["link"] = kv["link"]
    if "factor" in kv:
        kw["factor"] = float(kv["factor"])
    if "rate" in kv:
        kw["error_rate"] = float(kv["rate"])
    return FaultEvent(**kw)


class FaultPlan:
    """A seeded, step-indexed schedule of faults.

    Drive it from the step loop::

        fp = FaultPlan.parse("rank_death@12:rank=5", seed=0)
        fp.install()                  # pool fault hook
        for step in range(steps):
            fp.begin_step(step, emulator=emu)   # link degrades
            ...
        fp.uninstall()

    ``begin_step`` applies/clears emulator degrades at activation and
    healing boundaries and returns the events newly activated this
    step; the installed hook covers rank deaths and pool-error
    windows continuously.
    """

    def __init__(self, events: "list[FaultEvent] | tuple" = (), *,
                 seed: int = 0):
        self.events = tuple(sorted(events, key=lambda e: (e.step,
                                                          e.kind)))
        self._rng = np.random.default_rng(seed)
        self.step = -1
        self.injected: list = []            # (step, describe()) log

    @classmethod
    def parse(cls, spec: str, *, seed: int = 0) -> "FaultPlan":
        """Parse ``"kind@step[-until][:k=v,...];..."``, e.g.
        ``"link_degrade@10-18:link=node@cxl,factor=4;``
        ``rank_death@12:rank=3;pool_error@5-7:rate=0.5"``."""
        parts = [p for p in spec.split(";") if p.strip()]
        return cls([_parse_one(p) for p in parts], seed=seed)

    # -- schedule state ---------------------------------------------------
    def dead_ranks(self, step: Optional[int] = None) -> set:
        s = self.step if step is None else step
        return {e.rank for e in self.events
                if e.kind == "rank_death" and e.active(s)}

    def active_events(self, step: Optional[int] = None) -> list:
        s = self.step if step is None else step
        return [e for e in self.events if e.active(s)]

    def begin_step(self, step: int, emulator=None) -> list:
        """Advance the schedule to ``step``: apply newly-active link
        degrades to ``emulator`` (and lift healed ones).  Returns the
        events that became active this step."""
        prev = self.step
        self.step = int(step)
        fresh = [e for e in self.events
                 if e.active(step) and not e.active(prev)]
        if emulator is not None:
            for e in self.events:
                if e.kind != "link_degrade":
                    continue
                if e.active(step) and not e.active(prev):
                    emulator.set_degrade(e.link, e.factor)
                elif e.active(prev) and not e.active(step):
                    emulator.set_degrade(e.link, 1.0)
        for e in fresh:
            self.injected.append((int(step), e.describe()))
        return fresh

    # -- the pool fault hook ----------------------------------------------
    def pool_hook(self, op: str, info: dict) -> None:
        """``core.pool`` fault hook: fail accesses by dead ranks, and
        any access inside an active pool-error window (seeded
        Bernoulli at the event's ``error_rate``)."""
        rank = info.get("rank")
        if rank is not None and rank in self.dead_ranks():
            raise pool_mod.PoolAccessError(
                f"rank {rank} is dead (op={op}, step={self.step})")
        for e in self.events:
            if e.kind == "pool_error" and e.active(self.step):
                if self._rng.random() < e.error_rate:
                    raise pool_mod.PoolAccessError(
                        f"transient pool fault (op={op}, "
                        f"step={self.step}, rate={e.error_rate})")

    def install(self) -> None:
        pool_mod.set_fault_hook(self.pool_hook)

    def uninstall(self) -> None:
        if pool_mod.get_fault_hook() == self.pool_hook:
            pool_mod.clear_fault_hook()

    def __enter__(self) -> "FaultPlan":
        self.install()
        return self

    def __exit__(self, *exc) -> None:
        self.uninstall()

    def describe(self) -> str:
        return "; ".join(e.describe() for e in self.events) or "(none)"
