"""Survivor re-planning: confirmed failures -> a hot-swappable plan.

Two topology surgeries, both pure functions of the current topology:

* ``survivor_topology`` removes confirmed-dead ranks from a level:
  the level's shape vector loses one slot per dead rank in the
  owning group (``(4, 4)`` minus rank 5 -> ``(4, 3)``), turning the
  level ragged - PR 5's grouped/ragged schedules execute such shapes
  natively, so the survivors keep the hierarchy instead of falling
  flat.
* ``failover_topology`` retires a dead CXL level onto its
  *alternative IB transport*: the level's fabric flips cxl -> ib
  carried by the very ``ib_cfg`` the tuner has been pricing cxl
  against all along (DFabric's hybrid-fabric move) - the pool is
  gone, the ranks are not.

``replan`` composes them from a failure list into a ``RecoveryPlan``:
survivor/failover topology + (optionally) a placement re-ranking under
the monitor's measured link penalties + a plan re-tuned for the new
topology, with ``apply()`` publishing through the epoch-versioned
registry - the same hot-swap path online re-tuning already uses, so
the next re-trace of the step picks everything up.  Rebuilding the
jax mesh itself stays with the launcher, which owns the devices.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

from repro.core.topology import Level, Topology, set_active_topology
from repro.tuner import runtime
from repro.tuner.placement import (AxisTraffic, CollectiveMix,
                                   Placement, PlacementPlan,
                                   placed_topology, plan_placement)
from repro.tuner.plan import Plan
from repro.tuner.sweep import SMOKE_GRID, TuneGrid, generate_plan


def survivor_topology(topology: Topology, axis: str, dead_ranks,
                      *, size: Optional[int] = None) -> Topology:
    """Remove ``dead_ranks`` (flat indices on ``axis``) from the
    axis's level: the owning group of each dead rank shrinks by one in
    the shape vector; emptied groups drop out.  An undeclared-shape
    level needs ``size`` (the mesh axis degree) to seed the vector."""
    lv = topology.level_for(axis)
    if lv is None:
        raise KeyError(f"no level for axis {axis!r}")
    shape = lv.shape
    if shape is None:
        if size is None:
            raise ValueError(
                f"level {axis!r} declares no shape; pass size= (the "
                f"mesh axis degree) to derive the survivor vector")
        shape = (int(size),)
    total = sum(shape)
    dead = sorted(set(int(r) for r in dead_ranks))
    if any(r < 0 or r >= total for r in dead):
        raise ValueError(f"dead ranks {dead} out of range for level "
                         f"{axis!r} of {total} ranks")
    groups = list(shape)
    bounds = []
    acc = 0
    for g in groups:
        bounds.append((acc, acc + g))
        acc += g
    for r in dead:
        for gi, (lo, hi) in enumerate(bounds):
            if lo <= r < hi:
                groups[gi] -= 1
                break
    new_shape = tuple(g for g in groups if g > 0)
    if not new_shape:
        raise ValueError(f"no survivors on level {axis!r}")
    levels = tuple(dataclasses.replace(l, shape=new_shape)
                   if l.axis == lv.axis else l
                   for l in topology.levels)
    return Topology(levels=levels)


def failover_topology(topology: Topology, axis: str) -> Topology:
    """Retire ``axis``'s CXL level onto its alternative IB transport:
    same axis, same shape, fabric cxl -> ib carried by the level's own
    ``ib_cfg`` - the transport the tuner was already pricing the pool
    against."""
    lv = topology.level_for(axis)
    if lv is None:
        raise KeyError(f"no level for axis {axis!r}")
    if lv.fabric != "cxl":
        raise ValueError(
            f"level {axis!r} is {lv.fabric}; only a cxl level has an "
            f"IB alternative to fail over to")
    fo = Level(axis=lv.axis, fabric="ib", ib=lv.ib_cfg, shape=lv.shape)
    levels = tuple(fo if l.axis == lv.axis else l
                   for l in topology.levels)
    return Topology(levels=levels)


def health_penalties(link_health: Optional[dict] = None) -> dict:
    """Placement penalties from the runtime link-health registry (or
    an explicit registry copy): degraded links contribute their
    measured slowdown."""
    lh = (runtime.get_link_health() if link_health is None
          else link_health)
    return {k: max(1.0, float(v.get("slowdown", 1.0)))
            for k, v in lh.items() if v.get("degraded")}


@dataclasses.dataclass
class RecoveryPlan:
    """A re-plan ready to hot-swap: the post-failure topology, the
    re-tuned plan for it, and (when a collective mix was supplied) the
    placement re-ranking that chose it."""

    topology: Topology
    plan: Plan
    reason: str
    placement: Optional[PlacementPlan] = None
    chosen: Optional[Placement] = None
    failures: tuple = ()

    def apply(self) -> Plan:
        """Publish: activate the new topology and push the re-tuned
        plan through the epoch-versioned registry.  The caller
        re-traces its step (and rebuilds its mesh over the survivors)
        - identical mechanics to an online-retune hot-swap."""
        set_active_topology(self.topology)
        runtime.set_active_plan(self.plan)
        return self.plan

    def describe(self) -> str:
        lv = ", ".join(f"{l.axis}:{l.fabric}"
                       + (f":{'+'.join(map(str, l.shape))}"
                          if l.shape else "")
                       for l in self.topology.levels)
        return f"re-plan [{self.reason}] -> topology ({lv})"


def _axis_of_link(link: str, topology: Topology) -> Optional[str]:
    """Map a health-registry "axis/fabric" key back to its axis."""
    axis = link.split("/", 1)[0]
    return axis if topology.level_for(axis) is not None else None


def replan(failures, topology: Topology, *,
           mix: Optional[CollectiveMix] = None,
           grid: Optional[TuneGrid] = None,
           link_penalties: Optional[dict] = None,
           unsplit: tuple = (),
           axis_sizes: Optional[dict] = None) -> RecoveryPlan:
    """Derive the recovery from confirmed ``Failure``s.

    * every ``rank_death`` on a level shrinks that level's shape
      vector (``survivor_topology``; dead ranks are attributed to the
      innermost pool level unless the failure's detail names an axis);
    * every persistent ``link_degraded`` on a cxl level fails the
      level over to IB (``failover_topology``);
    * with a ``mix``, placement re-ranks axis->level over the new
      topology under the measured ``link_penalties``; the plan is then
      re-tuned (``generate_plan``) for the placed topology.

    Raises ``ValueError`` when the failures demand nothing (the caller
    gates on confirmed, actionable failures).
    """
    failures = tuple(failures)
    topo = topology
    reasons = []

    def _default_axis() -> Optional[str]:
        # dead ranks live on the innermost cxl (pool) level by
        # default: that is where heartbeat words live
        for lv in reversed(topo.levels):
            if lv.fabric == "cxl":
                return lv.axis
        return topo.levels[-1].axis if topo.levels else None

    dead_by_axis: dict = {}
    for f in failures:
        if f.kind == "rank_death":
            axis = f.detail.get("axis") or _default_axis()
            if axis is None:
                raise ValueError("rank death with no level to shrink")
            dead_by_axis.setdefault(axis, set()).add(f.rank)
        elif f.kind == "link_degraded":
            axis = _axis_of_link(f.link, topo)
            if axis is None:
                continue
            lv = topo.level_for(axis)
            if lv is not None and lv.fabric == "cxl":
                topo = failover_topology(topo, axis)
                reasons.append(f"failover {f.link} -> ib")
    shrunk: dict = {}                       # old size -> new size
    for axis, dead in sorted(dead_by_axis.items()):
        size = (axis_sizes or {}).get(axis)
        before = topo.level_for(axis).size or size
        topo = survivor_topology(topo, axis, dead, size=size)
        if before is not None:
            shrunk[int(before)] = topo.level_for(axis).size
        reasons.append(
            f"survivors on {axis}: -{sorted(dead)} -> "
            f"{'+'.join(map(str, topo.level_for(axis).shape))}")
    if not reasons:
        raise ValueError(
            "no actionable failure (rank_death or cxl link_degraded) "
            f"in {[f.kind for f in failures]}")

    placement = chosen = None
    if mix is not None:
        if shrunk:
            # the workload's logical axes shrink with their level: a
            # mix axis sized like a shrunk level carries the survivor
            # degree now (the launcher's mesh rebuild does the same)
            mix = CollectiveMix(axes=tuple(
                dataclasses.replace(a, size=shrunk[a.size])
                if a.size in shrunk else a for a in mix.axes))
        placement = plan_placement(mix, topo,
                                   link_penalties=link_penalties)
        chosen = (placement.best_with_unsplit(unsplit) if unsplit
                  else placement.best)
        topo = placed_topology(chosen, topo)
    plan = generate_plan(grid if grid is not None else SMOKE_GRID,
                         topology=topo)
    return RecoveryPlan(topology=topo, plan=plan,
                        reason="; ".join(reasons),
                        placement=placement, chosen=chosen,
                        failures=failures)
