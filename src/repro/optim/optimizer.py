"""AdamW + schedules, pytree-native (no optax dependency).

The update is purely elementwise, so it is shard-transparent: applied to
FSDP/TP param shards inside shard_map it computes exactly what it would
compute on the full arrays.  Global-norm clipping is NOT shard-transparent
(it needs a cross-shard reduction and de-duplication of replicated
params), so it is only applied on the unsharded path; the sharded trainer
uses per-shard clipping off by default (documented in DESIGN.md).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, NamedTuple

import jax
import jax.numpy as jnp


class AdamWState(NamedTuple):
    step: jnp.ndarray
    mu: Any        # first moment (pytree like params)
    nu: Any        # second moment


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1


def adamw_init(params: Any) -> AdamWState:
    zeros = lambda t: jax.tree.map(
        lambda x: jnp.zeros(x.shape, jnp.float32), t)
    return AdamWState(step=jnp.zeros((), jnp.int32), mu=zeros(params),
                      nu=zeros(params))


def adamw_update(params: Any, grads: Any, state: AdamWState, lr,
                 cfg: AdamWConfig = AdamWConfig()):
    """Returns (new_params, new_state).  ``lr`` is a scalar (possibly from
    a schedule evaluated at state.step)."""
    step = state.step + 1
    b1, b2 = cfg.b1, cfg.b2
    bc1 = 1.0 - b1 ** step.astype(jnp.float32)
    bc2 = 1.0 - b2 ** step.astype(jnp.float32)

    def upd(p, g, m, v):
        g32 = g.astype(jnp.float32)
        m = b1 * m + (1 - b1) * g32
        v = b2 * v + (1 - b2) * jnp.square(g32)
        mhat = m / bc1
        vhat = v / bc2
        delta = mhat / (jnp.sqrt(vhat) + cfg.eps)
        if cfg.weight_decay and p.ndim >= 2:   # decay matrices only
            delta = delta + cfg.weight_decay * p.astype(jnp.float32)
        return (p.astype(jnp.float32) - lr * delta).astype(p.dtype), m, v

    flat_p, treedef = jax.tree.flatten(params)
    flat_g = treedef.flatten_up_to(grads)
    flat_m = treedef.flatten_up_to(state.mu)
    flat_v = treedef.flatten_up_to(state.nu)
    out = [upd(p, g, m, v) for p, g, m, v in
           zip(flat_p, flat_g, flat_m, flat_v)]
    new_p = treedef.unflatten([o[0] for o in out])
    new_m = treedef.unflatten([o[1] for o in out])
    new_v = treedef.unflatten([o[2] for o in out])
    return new_p, AdamWState(step=step, mu=new_m, nu=new_v)


def clip_by_global_norm(grads: Any, max_norm: float):
    """Unsharded-path global-norm clip.  Returns (clipped, norm)."""
    sq = sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
             for g in jax.tree.leaves(grads))
    norm = jnp.sqrt(sq)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(norm, 1e-12))
    return jax.tree.map(lambda g: (g * scale).astype(g.dtype), grads), norm


def cosine_schedule(base_lr: float, total_steps: int,
                    final_frac: float = 0.1) -> Callable:
    def lr(step):
        t = jnp.clip(step.astype(jnp.float32) / total_steps, 0.0, 1.0)
        cos = 0.5 * (1 + jnp.cos(jnp.pi * t))
        return base_lr * (final_frac + (1 - final_frac) * cos)
    return lr


def linear_warmup_cosine(base_lr: float, warmup: int,
                         total_steps: int) -> Callable:
    cos = cosine_schedule(base_lr, max(1, total_steps - warmup))
    def lr(step):
        w = jnp.minimum(1.0, step.astype(jnp.float32) / max(1, warmup))
        return jnp.where(step < warmup, base_lr * w, cos(step - warmup))
    return lr
