"""Serving engine: batched prefill + decode with KV-cache management.

Decode attention follows the flash-decoding layout (cache sequence dim
sharded over tp, partial-softmax combine via two tp AllReduces through
the CXL-CCL Communicator).  ``window`` switches to the ring-buffer
sliding-window cache used by the ``long_500k`` shape for attention
architectures; SSM rows always carry O(1) state.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import model
from repro.models.config import ModelConfig
from repro.models.pcontext import ParallelContext, UNSHARDED


@dataclasses.dataclass(frozen=True)
class ServeConfig:
    max_seq: int = 2048
    window: Optional[int] = None          # sliding-window cache size
    temperature: float = 0.0              # 0 = greedy
    cache_dtype: str = "float32"
    # Autotuning plan (repro.launch.tune output).  When set, the engine's
    # Communicator switches to backend='auto' driven by this plan.
    plan_path: Optional[str] = None


class ServeEngine:
    def __init__(self, cfg: ModelConfig, params, scfg: ServeConfig,
                 pc: ParallelContext = UNSHARDED):
        self.cfg = cfg
        self.params = params
        self.scfg = scfg
        if scfg.plan_path is not None:
            from repro.core.hw import CXL_POOL, INFINIBAND
            from repro.tuner import load_plan
            pc = dataclasses.replace(
                pc, comm=dataclasses.replace(
                    pc.comm, backend="auto",
                    plan=load_plan(scfg.plan_path, pool=CXL_POOL,
                                   ib=INFINIBAND)))
            if pc.tp_axis is None or pc.tp == 1:
                print("[serve] plan loaded but the engine is unsharded "
                      "(tp=1): no collectives to autotune")
        self.pc = pc
        cd = jnp.dtype(scfg.cache_dtype)
        self._prefill = jax.jit(
            lambda p, b: model.prefill(p, b, cfg, pc, scfg.max_seq,
                                       cache_dtype=cd,
                                       window=scfg.window))
        self._decode = jax.jit(
            lambda p, c, t, pos: model.decode_step(p, c, t, pos, cfg, pc,
                                                   window=scfg.window))

    def _sample(self, logits: jnp.ndarray, key) -> jnp.ndarray:
        logits = logits[:, -1, :self.cfg.vocab_size]
        if self.scfg.temperature == 0.0:
            return jnp.argmax(logits, axis=-1).astype(jnp.int32)
        return jax.random.categorical(
            key, logits / self.scfg.temperature).astype(jnp.int32)

    def generate(self, batch: dict, max_new_tokens: int,
                 seed: int = 0) -> np.ndarray:
        """Greedy/temperature generation for a batch of prompts.
        ``batch['tokens']`` is (B, L_prompt) right-aligned (no padding
        support needed for the examples).  Returns (B, max_new_tokens)."""
        key = jax.random.key(seed)
        logits, caches = self._prefill(self.params, batch)
        prompt_len = batch["tokens"].shape[1]
        n_prefix = self.cfg.frontend_tokens if (
            self.cfg.frontend != "text" and self.cfg.encoder is None) \
            else 0
        pos = prompt_len + n_prefix
        out = []
        key, k = jax.random.split(key)
        tok = self._sample(logits, k)
        out.append(tok)
        for i in range(max_new_tokens - 1):
            logits, caches = self._decode(self.params, caches,
                                          tok[:, None],
                                          jnp.int32(pos + i))
            key, k = jax.random.split(key)
            tok = self._sample(logits, k)
            out.append(tok)
        return np.stack([np.asarray(t) for t in out], axis=1)
