"""Request-level serving engine: continuous batching over a pooled,
paged KV cache.

The engine's numeric state is one dense slot-major cache pytree
(``ServeConfig.decode_slots`` batch lanes) driven by a single jitted
step whose position argument is a per-slot vector
(``model.decode_step`` with ``pos: (B,)``), so slots at different
depths decode together.  Around it:

* admission / preemption / slot packing live in
  ``serving.scheduler.Scheduler`` (``continuous`` or the
  batch-synchronous ``static`` baseline);
* HBM is accounted in fixed token blocks
  (``serving.kvcache.BlockManager``), and when a growing sequence
  cannot get a block the newest running request is *evicted to the
  pool*: its slot's cache image is serialized through
  ``core.pool.PoolBlockAllocator`` (doorbell-committed) and restored
  bitwise-exactly when a slot frees up - or, when the placement
  oracle prices recompute cheaper than the pool round-trip, dropped
  and re-prefixed by teacher-forcing (the ``kv_block`` plan cell
  decides, audited in the ledger like any collective);
* with ``prefix_sharing`` on, complete prompt blocks are published to
  a hash-addressed :class:`~repro.serving.kvcache.PooledKVStore`; a
  later request (this engine or any engine *sharing the store*)
  restores the longest pooled prefix instead of prefilling it, and
  teacher-forces only the remainder.

API: ``submit(Request) -> id``, ``step() -> bool`` (one scheduler
round + one decode step), ``poll() -> finished-token streaming``.
``generate()`` remains as a thin compat wrapper (submit-all +
step-until-drained) over the same machinery.  Sampling is
per-request: the key is ``fold_in(key(seed), token_index)``, so a
request's token stream is invariant to how it was scheduled,
preempted, or restored.
"""
from __future__ import annotations

import dataclasses
import itertools
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import ledger
from repro.models import model
from repro.models.config import ModelConfig
from repro.models.pcontext import ParallelContext, UNSHARDED
from repro.serving import kvcache
from repro.serving.scheduler import (FINISHED, RUNNING, Request,
                                     RequestState, SamplingParams,
                                     Scheduler)
from repro.tuner.costmodel import roofline_compute_time

_ENGINE_IDS = itertools.count()


@dataclasses.dataclass(frozen=True)
class ServeConfig:
    """Engine-level serving configuration.

    Per-request knobs (temperature, seed) moved to
    ``serving.scheduler.SamplingParams`` - the ``temperature`` field
    here survives only as the default the ``generate()`` compat
    wrapper folds into its requests' ``SamplingParams`` (see
    docs/API.md for the migration).
    """

    max_seq: int = 2048
    window: Optional[int] = None          # sliding-window cache size
    temperature: float = 0.0              # compat default for generate()
    cache_dtype: str = "float32"
    # Autotuning plan (repro.launch.tune output).  When set, the engine's
    # Communicator switches to backend='auto' driven by this plan, and
    # kv_block cache-placement cells in it override the live oracle.
    plan_path: Optional[str] = None
    # KV tiering (PR 9): decode lanes, HBM block budget, pool budget.
    decode_slots: int = 4
    kv_block_tokens: int = 16
    hbm_budget_blocks: Optional[int] = None   # None: slots*ceil(seq/bt)
    pool_budget_bytes: int = 64 << 20
    pool_block_bytes: int = 1 << 16
    scheduler: str = "continuous"             # or 'static' (baseline)
    # Eviction placement: 'auto' prices pool-round-trip vs recompute
    # through the kv_block plan cell / live oracle; 'pool' and
    # 'recompute' force one arm (tests, A/B benchmarks).
    kv_placement: str = "auto"
    # Cross-request pooled-prefix sharing.  Off by default: a pooled
    # prefix is restored bitwise, but the *suffix* is then teacher-
    # forced through the decode path, whose float reduction order can
    # differ from prefill's - repeated identical prompts would no
    # longer be bit-identical to the first.  The Poisson benchmark and
    # ``serve --prompt-reuse`` turn it on.
    prefix_sharing: bool = False


class ServeEngine:
    def __init__(self, cfg: ModelConfig, params, scfg: ServeConfig,
                 pc: ParallelContext = UNSHARDED, *,
                 pool: Optional[kvcache.PooledKVStore] = None,
                 obs=None):
        self.cfg = cfg
        self.params = params
        self.scfg = scfg
        self.obs = obs
        self._kv_plan = None
        if scfg.plan_path is not None:
            from repro.core.hw import CXL_POOL, INFINIBAND
            from repro.tuner import load_plan
            plan = load_plan(scfg.plan_path, pool=CXL_POOL,
                             ib=INFINIBAND)
            self._kv_plan = plan
            pc = dataclasses.replace(
                pc, comm=dataclasses.replace(pc.comm, backend="auto",
                                             plan=plan))
            if pc.tp_axis is None or pc.tp == 1:
                self._diag("plan loaded but the engine is unsharded "
                           "(tp=1): no collectives to autotune")
        self.pc = pc
        self._uid = f"eng{next(_ENGINE_IDS)}"
        cd = jnp.dtype(scfg.cache_dtype)
        self._cd = cd
        self._n_prefix = cfg.frontend_tokens if (
            cfg.frontend != "text" and cfg.encoder is None) else 0

        # Dense slot cache + its structural layout.
        self.layout = kvcache.CacheLayout(
            cfg, pc, scfg.decode_slots, scfg.max_seq, cd,
            window=scfg.window)
        self.caches = model.init_cache(cfg, pc, scfg.decode_slots,
                                       scfg.max_seq, cache_dtype=cd,
                                       window=scfg.window)

        # Paged HBM accounting + scheduler + pool tier.
        bt = scfg.kv_block_tokens
        n_hbm = scfg.hbm_budget_blocks
        if n_hbm is None:
            n_hbm = scfg.decode_slots * (-(-scfg.max_seq // bt))
        self.blocks = kvcache.BlockManager(n_hbm, bt)
        self.sched = Scheduler(scfg.decode_slots, self.blocks,
                               mode=scfg.scheduler)
        self.pool = pool if pool is not None else kvcache.PooledKVStore(
            scfg.pool_budget_bytes, block_bytes=scfg.pool_block_bytes)
        self._share = bool(scfg.prefix_sharing
                           and self.layout.block_sharable)

        self._states: dict = {}          # request id -> RequestState
        self._sample_after: dict = {}    # id -> sample when forced drains
        self._gen = itertools.count()
        # Serving counters (exported through obs, read by stats()).
        self.counters = {"finished": 0, "evictions": 0, "restores": 0,
                         "replays": 0, "prefix_hits": 0,
                         "prefix_hit_tokens": 0, "prefix_publishes": 0,
                         "decode_steps": 0, "prefills": 0}

        self._prefill = jax.jit(
            lambda p, b: model.prefill(p, b, cfg, pc, scfg.max_seq,
                                       cache_dtype=cd,
                                       window=scfg.window))

        def step_impl(p, c, tok, pos, active):
            logits, nc = model.decode_step(p, c, tok, pos, cfg, pc,
                                           window=scfg.window)
            return logits, self.layout.where_slots(active, nc, c)

        self._decode = jax.jit(step_impl)

    # -- diagnostics / metrics --------------------------------------------

    def _diag(self, msg: str) -> None:
        if self.obs is not None:
            self.obs.diag("serve", msg)
        else:
            print(f"[serve] {msg}")

    def stats(self) -> dict:
        return {"inflight": self.sched.inflight,
                "running": len(self.sched.running),
                "waiting": len(self.sched.waiting),
                "preempted_queued": len(self.sched.preempted),
                "hbm_blocks_used": self.blocks.used_blocks,
                "hbm_shared_hits": self.blocks.shared_block_hits,
                "pool": self.pool.stats, **self.counters}

    def _export_metrics(self) -> None:
        if self.obs is None or not self.obs.enabled:
            return
        g = self.obs.registry.gauge
        g("repro_serve_inflight",
          "requests in flight").set(self.sched.inflight)
        g("repro_serve_hbm_blocks_used",
          "HBM KV blocks held").set(self.blocks.used_blocks)
        g("repro_serve_pool_blocks_used",
          "pool KV blocks held").set(self.pool.alloc.used_blocks)
        for k in ("finished", "evictions", "restores", "replays",
                  "prefix_hits", "prefix_publishes"):
            g(f"repro_serve_{k}_total", f"serving {k}").set(
                self.counters[k])
        g("repro_serve_pool_hits_total",
          "pooled KV store hits").set(self.pool.hits)

    # -- request API -------------------------------------------------------

    def submit(self, req: Request) -> str:
        """Queue a request; returns its id (``poll`` key)."""
        if req.id in self._states:
            raise ValueError(f"request id {req.id!r} already submitted")
        self._states[req.id] = self.sched.submit(req)
        return req.id

    def poll(self, req_id: Optional[str] = None):
        """Finished-token streaming.  ``poll(id)`` returns
        ``(status, new_tokens)`` - the tokens generated since the last
        poll.  ``poll()`` returns ``{id: (status, new_tokens)}`` for
        every tracked request and drops fully-delivered finished
        requests from tracking."""
        if req_id is not None:
            st = self._states[req_id]
            fresh = [int(t) for t in st.generated[st.delivered:]]
            st.delivered = len(st.generated)
            if st.status == FINISHED and st.delivered == len(
                    st.generated):
                del self._states[req_id]
            return st.status, fresh
        out = {}
        for rid in list(self._states):
            out[rid] = self.poll(rid)
        return out

    def step(self) -> bool:
        """One engine round: admit what fits, secure block capacity
        (evicting to the pool when HBM runs out), run one jitted
        decode step over every running slot, sample/advance each
        request.  Returns True while work remains."""
        span = self.obs.span("serve_step") if self.obs is not None \
            else None
        if span is not None:
            span.__enter__()
        try:
            self._do_step()
        finally:
            if span is not None:
                span.__exit__(None, None, None)
        self._export_metrics()
        return not self.sched.idle

    # -- compat wrapper ----------------------------------------------------

    def generate(self, batch: dict, max_new_tokens: int,
                 seed: int = 0) -> np.ndarray:
        """Pre-PR-9 batch API, now a thin wrapper: one request per
        batch row (temperature from ``ServeConfig``), stepped until
        drained.  Returns (B, max_new_tokens)."""
        toks = np.asarray(batch["tokens"])
        sp = SamplingParams(temperature=self.scfg.temperature,
                            seed=seed)
        ids = []
        for b in range(toks.shape[0]):
            extras = {k: np.asarray(v)[b] for k, v in batch.items()
                      if k != "tokens"} or None
            ids.append(self.submit(Request(
                id=f"gen{next(self._gen)}", tokens=toks[b],
                sampling=sp, max_new_tokens=max_new_tokens,
                extras=extras)))
        while self.step():
            pass
        rows = []
        for rid in ids:
            _status, fresh = self.poll(rid)
            rows.append(fresh)
        return np.asarray(rows, np.int32)

    # -- internals ---------------------------------------------------------

    def _prompt_ntok(self, st: RequestState) -> int:
        return self._n_prefix + len(st.req.tokens)

    def _hashes(self, st: RequestState) -> list:
        """Chain hashes of the prompt's complete blocks (content
        addressing is text-only: conditioned requests don't share)."""
        if st.req.extras is not None or self._n_prefix:
            return []
        return kvcache.chain_hashes(st.req.tokens,
                                    self.blocks.block_tokens)

    def _reserve(self, st: RequestState) -> bool:
        """Transactionally claim the blocks an admission needs (the
        scheduler's ``reserve`` callback)."""
        ntok = st.pos if st.preemptions else self._prompt_ntok(st)
        try:
            self.blocks.alloc(st.req.id, max(ntok, 1),
                              self._hashes(st))
            return True
        except MemoryError:
            return False

    def _replay_flops(self, ntok: int) -> float:
        """Roofline FLOPs of recomputing ``ntok`` tokens of cache
        (~2 * active params per token, forward only)."""
        return 2.0 * self.cfg.active_param_count() * max(1, ntok)

    def _evict(self, st: RequestState) -> None:
        """Preemption-by-eviction: spill ``st``'s slot to the pool (or
        drop it for recompute when the oracle prices that cheaper)."""
        nbytes = self.layout.bytes_for(st.pos)
        if self.scfg.kv_placement == "auto":
            choice = kvcache.resolve_kv_choice(
                "kv_block", nbytes, self._replay_flops(st.pos),
                plan=self._kv_plan,
                block_bytes=self.pool.alloc.block_bytes)
            backend = choice.backend
        else:
            backend = self.scfg.kv_placement
            ledger.record_choice("kv_block", max(1, nbytes), 1,
                                 backend, 1, "kv_tier")
        slot = st.slot
        if backend == "pool":
            img = self.layout.extract_slot(self.caches, slot, st.pos)
            if not self.pool.put(("evict", self._uid, st.req.id), img):
                self._diag(f"pool budget full: eviction of "
                           f"{st.req.id!r} falls back to recompute")
        self.blocks.free(st.req.id)
        self.sched.preempt(st)
        self.counters["evictions"] += 1

    def _ensure_capacity(self, st: RequestState) -> bool:
        """Secure the next token's HBM block, evicting newer requests
        as needed.  False when ``st`` itself got preempted."""
        while True:
            try:
                self.blocks.append(st.req.id, 1)
                return True
            except MemoryError:
                victim = self.sched.pick_victim(exclude=(st,))
                if victim is None:
                    raise MemoryError(
                        f"hbm_budget_blocks={self.blocks.num_blocks} "
                        f"cannot hold request {st.req.id!r} alone "
                        f"({self.blocks.used_blocks} blocks at "
                        f"{st.pos} tokens)")
                self._evict(victim)

    def _sample_one(self, row, sp: SamplingParams, index: int) -> int:
        row = row[:self.cfg.vocab_size]
        if sp.temperature == 0.0:
            return int(jnp.argmax(row))
        key = jax.random.fold_in(jax.random.key(sp.seed), index)
        return int(jax.random.categorical(key, row / sp.temperature))

    def _finish(self, st: RequestState) -> None:
        self.blocks.free(st.req.id)
        self.sched.finish(st)
        self.counters["finished"] += 1

    def _prefill_request(self, st: RequestState) -> None:
        """Materialize a fresh prompt: full prefill into the slot via
        the canonical byte image, then sample the first token."""
        b = {"tokens": jnp.asarray(
            np.asarray(st.req.tokens, np.int32)[None])}
        if st.req.extras is not None:
            for k, v in st.req.extras.items():
                b[k] = jnp.asarray(np.asarray(v)[None])
        logits, c1 = self._prefill(self.params, b)
        self.counters["prefills"] += 1
        st.n_prefix = self._n_prefix
        st.pos = self._prompt_ntok(st)
        lay1 = self._lay1
        img = lay1.extract_slot(c1, 0, st.pos)
        self.caches = self.layout.insert_slot(self.caches, st.slot,
                                              st.pos, img)
        if self._share:
            self._publish_prefix(st)
        tok = self._sample_one(np.asarray(logits)[0, -1],
                               st.req.sampling, 0)
        st.generated.append(tok)
        st.last_token = tok

    @property
    def _lay1(self) -> kvcache.CacheLayout:
        """Layout of a batch-1 prefill cache (same leaves, one slot)."""
        if not hasattr(self, "_lay1_cached"):
            self._lay1_cached = kvcache.CacheLayout(
                self.cfg, self.pc, 1, self.scfg.max_seq, self._cd,
                window=self.scfg.window)
        return self._lay1_cached

    def _publish_prefix(self, st: RequestState) -> None:
        """Push the prompt's complete blocks to the pooled prefix
        store (hash-addressed; write -> refcount -> doorbell ring)."""
        hashes = self._hashes(st)
        bt = self.blocks.block_tokens
        for i, h in enumerate(hashes):
            key = ("kvblk", h)
            if key in self.pool:
                continue
            img = self.layout.extract_token_range(
                self.caches, st.slot, i * bt, (i + 1) * bt)
            if not self.pool.put(key, img):
                break               # pool full of pinned entries
            self.counters["prefix_publishes"] += 1

    def _try_prefix_restore(self, st: RequestState) -> bool:
        """Restore the longest pooled prefix and queue the rest of the
        prompt for teacher-forcing.  False on miss (caller prefills)."""
        if not self._share:
            return False
        hashes = self._hashes(st)
        bt = self.blocks.block_tokens
        prompt_len = len(st.req.tokens)
        # Cap so at least one prompt token is teacher-forced: its
        # decode step yields the logits the first sample needs.
        usable = min(len(hashes), (prompt_len - 1) // bt)
        run = 0
        while run < usable and ("kvblk", hashes[run]) in self.pool:
            run += 1
        if run == 0:
            return False
        imgs = []
        keys = [("kvblk", h) for h in hashes[:run]]
        for key in keys:
            self.pool.acquire(key)      # pin against reclaim mid-read
        try:
            for key in keys:
                img = self.pool.get(key)
                if img is None:         # lost a race with reclaim
                    return False
                imgs.append(img)
        finally:
            for key in keys:
                self.pool.release(key)
        for i, img in enumerate(imgs):
            self.caches = self.layout.insert_token_range(
                self.caches, st.slot, i * bt, (i + 1) * bt, img)
        prefix = run * bt
        st.pos = prefix
        st.forced = tuple(st.req.tokens[prefix:])
        self._sample_after[st.req.id] = True
        st.prefix_hit_tokens = prefix
        self.counters["prefix_hits"] += 1
        self.counters["prefix_hit_tokens"] += prefix
        # Audit: pooled prefix replaced prefill compute over `prefix`
        # tokens - a kv_prefix cell, recorded like any collective.
        nbytes = self.layout.bytes_for_range(0, prefix)
        ledger.record_choice(
            "kv_prefix", max(1, nbytes), 1, "pool", 1, "kv_tier",
            predicted_time=self.pool.predict_get_s(nbytes),
            baseline_time=roofline_compute_time(
                self._replay_flops(prefix)))
        return True

    def _admit(self, st: RequestState, slot: int) -> None:
        if st.preemptions:
            key = ("evict", self._uid, st.req.id)
            img = self.pool.get(key)
            if img is not None:
                # Bitwise restore of the evicted image (blocks were
                # reserved at admission).
                self.caches = self.layout.insert_slot(
                    self.caches, slot, st.pos, img)
                self.pool.remove(key)
                self.counters["restores"] += 1
                return
            # Recompute path: re-prefill the prompt, then teacher-
            # force the tokens already sampled (minus the last, which
            # is the next step's input).  The sample stream is index-
            # keyed, so the continuation is unchanged.
            self._replay(st)
            return
        if self._try_prefix_restore(st):
            return
        self._prefill_request(st)
        if st.done:
            self._finish(st)

    def _replay(self, st: RequestState) -> None:
        done_tokens = list(st.generated)
        st.pos = 0
        st.forced = ()
        # Re-size the admission reservation (made at the preempted
        # pos) down to the prompt; forced steps grow it back.
        self.blocks.free(st.req.id)
        self.blocks.alloc(st.req.id, self._prompt_ntok(st),
                          self._hashes(st))
        self._prefill_request_replay(st, done_tokens)
        self.counters["replays"] += 1

    def _prefill_request_replay(self, st: RequestState,
                                done_tokens: list) -> None:
        b = {"tokens": jnp.asarray(
            np.asarray(st.req.tokens, np.int32)[None])}
        if st.req.extras is not None:
            for k, v in st.req.extras.items():
                b[k] = jnp.asarray(np.asarray(v)[None])
        _logits, c1 = self._prefill(self.params, b)
        self.counters["prefills"] += 1
        st.pos = self._prompt_ntok(st)
        img = self._lay1.extract_slot(c1, 0, st.pos)
        self.caches = self.layout.insert_slot(self.caches, st.slot,
                                              st.pos, img)
        st.generated = done_tokens
        # Feed back everything but the last sampled token; sampling
        # must not rerun when the forced queue drains.
        st.forced = tuple(done_tokens[:-1])
        self._sample_after[st.req.id] = False
        st.last_token = done_tokens[-1]

    def _do_step(self) -> None:
        for adm in self.sched.admissions(self._reserve):
            self._admit(adm.state, adm.slot)
        # Secure one token of growth per running request; evictions
        # here shrink `running` for this round.
        stepping = []
        for st in list(self.sched.running.values()):
            if st.status == RUNNING and self._ensure_capacity(st):
                stepping.append(st)
        # An eviction later in the loop may have preempted an earlier
        # entrant; only still-running slots step.
        stepping = [st for st in stepping if st.status == RUNNING]
        if not stepping:
            if self.sched.inflight and not self.sched.running:
                head = (self.sched.preempted or self.sched.waiting)[0]
                raise MemoryError(
                    f"engine cannot make progress: request "
                    f"{head.req.id!r} does not fit an empty "
                    f"hbm_budget_blocks={self.blocks.num_blocks}")
            return
        n = self.scfg.decode_slots
        tok = np.zeros((n, 1), np.int32)
        pos = np.zeros((n,), np.int32)
        active = np.zeros((n,), bool)
        for st in stepping:
            feed = st.forced[0] if st.forced else st.last_token
            tok[st.slot, 0] = feed
            pos[st.slot] = st.pos
            active[st.slot] = True
        logits, self.caches = self._decode(
            self.params, self.caches, jnp.asarray(tok),
            jnp.asarray(pos), jnp.asarray(active))
        self.counters["decode_steps"] += 1
        rows = np.asarray(logits)[:, 0]
        for st in stepping:
            st.pos += 1
            if st.forced:
                st.forced = st.forced[1:]
                if st.forced:
                    continue
                if not self._sample_after.pop(st.req.id, True):
                    continue        # replay rejoin: last_token is set
            tokv = self._sample_one(rows[st.slot], st.req.sampling,
                                    len(st.generated))
            st.generated.append(tokv)
            st.last_token = tokv
            if st.done:
                self._finish(st)
