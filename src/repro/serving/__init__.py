from repro.serving.engine import ServeEngine, ServeConfig

__all__ = ["ServeEngine", "ServeConfig"]
