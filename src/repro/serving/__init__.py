from repro.serving.engine import ServeConfig, ServeEngine
from repro.serving.kvcache import (BlockManager, CacheLayout,
                                   PooledKVStore, chain_hashes)
from repro.serving.scheduler import (Request, RequestState,
                                     SamplingParams, Scheduler)

__all__ = ["ServeEngine", "ServeConfig", "Request", "SamplingParams",
           "RequestState", "Scheduler", "BlockManager", "CacheLayout",
           "PooledKVStore", "chain_hashes"]
