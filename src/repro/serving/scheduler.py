"""Continuous-batching request scheduler (policy only, no numerics).

The scheduler owns the request lifecycle

    WAITING -> RUNNING -> FINISHED
                 |  ^
                 v  |            (preemption-by-eviction: the engine
              PREEMPTED           spills the slot's cache to the pool)

and the two placement resources the engine cannot see from inside a
jitted step: decode slots (the dense cache's batch lanes) and HBM
blocks (the :class:`~repro.serving.kvcache.BlockManager` budget).  It
is deliberately free of jax / pool I/O so the policy is unit-testable
and the virtual-clock benchmark can drive the *real* scheduler without
touching a model.

Two modes:

* ``continuous`` - per-request admission: any free slot whose blocks
  fit is filled immediately, preempted requests are resumed first
  (they hold progress), and when a growing sequence cannot get a block
  the *newest* running request is evicted (vLLM's policy: the oldest
  request never starves).
* ``static`` - the PR-8-era batch-synchronous engine as a policy: a
  batch is admitted only when the engine is idle, and the next batch
  waits until every member finished.  This is the serving benchmark's
  baseline, running through the identical engine machinery.
"""
from __future__ import annotations

import dataclasses
from collections import deque
from typing import Optional

from repro.serving.kvcache import BlockManager

WAITING = "waiting"
RUNNING = "running"
PREEMPTED = "preempted"
FINISHED = "finished"


@dataclasses.dataclass(frozen=True)
class SamplingParams:
    """Per-request sampling knobs (the request-level half of the old
    ``ServeConfig``): ``temperature == 0`` is greedy; ``seed`` feeds a
    per-request key folded with the token index, so a request's sample
    stream does not depend on how it was scheduled."""

    temperature: float = 0.0
    seed: int = 0


@dataclasses.dataclass(frozen=True)
class Request:
    """One generation request: prompt tokens in, sampled tokens out."""

    id: str
    tokens: tuple                       # prompt token ids
    sampling: SamplingParams = SamplingParams()
    max_new_tokens: int = 16
    # Non-text conditioning (vision frontend / encoder source) for the
    # compat path; keyed per request, batch dim stripped.
    extras: Optional[dict] = None

    def __post_init__(self):
        object.__setattr__(self, "tokens",
                           tuple(int(t) for t in self.tokens))
        if not self.tokens:
            raise ValueError(f"request {self.id!r} has an empty prompt")
        if self.max_new_tokens < 1:
            raise ValueError(f"request {self.id!r}: max_new_tokens "
                             f"must be >= 1")


@dataclasses.dataclass
class RequestState:
    """Mutable per-request record the scheduler and engine share."""

    req: Request
    arrival: int                        # admission-order tiebreaker
    status: str = WAITING
    slot: int = -1
    pos: int = 0            # cache positions filled (incl. any prefix)
    n_prefix: int = 0       # non-text conditioning tokens before text
    forced: tuple = ()      # prompt tokens still to teacher-force
    generated: list = dataclasses.field(default_factory=list)
    delivered: int = 0      # tokens already handed out via poll()
    last_token: int = -1    # input token for the next decode step
    preemptions: int = 0
    prefix_hit_tokens: int = 0

    @property
    def done(self) -> bool:
        return len(self.generated) >= self.req.max_new_tokens

    @property
    def total_tokens(self) -> int:
        """Tokens the request's cache must hold right now."""
        return self.pos


@dataclasses.dataclass(frozen=True)
class Preemption:
    """Engine order: spill this running request's slot to the pool."""

    state: RequestState


@dataclasses.dataclass(frozen=True)
class Admission:
    """Engine order: materialize this request's cache in ``slot``
    (fresh prefill, pooled-prefix restore, or eviction-image restore
    - the engine decides which, the scheduler only placed it)."""

    state: RequestState
    slot: int


class Scheduler:
    def __init__(self, n_slots: int, blocks: BlockManager, *,
                 mode: str = "continuous"):
        if mode not in ("continuous", "static"):
            raise ValueError(f"unknown scheduler mode {mode!r}")
        if n_slots <= 0:
            raise ValueError("need at least one decode slot")
        self.mode = mode
        self.n_slots = int(n_slots)
        self.blocks = blocks
        self.waiting: deque = deque()
        self.preempted: deque = deque()
        self.running: dict = {}          # slot -> RequestState
        self._free_slots = list(range(self.n_slots - 1, -1, -1))
        self._arrivals = 0
        self.preemption_count = 0

    # -- queue state -------------------------------------------------------

    @property
    def inflight(self) -> int:
        return (len(self.waiting) + len(self.preempted)
                + len(self.running))

    @property
    def idle(self) -> bool:
        return self.inflight == 0

    def submit(self, req: Request) -> RequestState:
        st = RequestState(req=req, arrival=self._arrivals)
        self._arrivals += 1
        self.waiting.append(st)
        return st

    # -- admission ---------------------------------------------------------

    def admissions(self, reserve) -> list:
        """Requests to place this step, in priority order (resume
        preempted work before admitting fresh prompts).

        ``reserve(state) -> bool`` must *transactionally* claim the
        candidate's HBM blocks (the engine binds it to
        ``BlockManager.alloc``): a candidate is only taken off its
        queue once its blocks are actually held, so one round's
        admissions can never over-commit the budget.  In ``static``
        mode nothing is admitted until the engine drained completely.
        """
        if self.mode == "static" and self.running:
            return []
        out = []
        for queue in (self.preempted, self.waiting):
            while queue and self._free_slots:
                st = queue[0]
                if not reserve(st):
                    break
                queue.popleft()
                slot = self._free_slots.pop()
                st.slot = slot
                st.status = RUNNING
                self.running[slot] = st
                out.append(Admission(state=st, slot=slot))
        return out

    # -- preemption --------------------------------------------------------

    def pick_victim(self, *, exclude=()) -> Optional[RequestState]:
        """Newest-arrival running request not in ``exclude`` (the
        oldest request never starves)."""
        candidates = [st for st in self.running.values()
                      if st not in exclude]
        if not candidates:
            return None
        return max(candidates, key=lambda st: st.arrival)

    def preempt(self, st: RequestState) -> Preemption:
        """Take ``st`` off its slot; it re-queues at the *front* so it
        resumes before fresh admissions."""
        if st.status != RUNNING:
            raise ValueError(f"cannot preempt {st.req.id!r} in state "
                             f"{st.status}")
        del self.running[st.slot]
        self._free_slots.append(st.slot)
        st.slot = -1
        st.status = PREEMPTED
        st.preemptions += 1
        self.preemption_count += 1
        self.preempted.appendleft(st)
        return Preemption(state=st)

    # -- completion --------------------------------------------------------

    def finish(self, st: RequestState) -> None:
        if st.status != RUNNING:
            raise ValueError(f"cannot finish {st.req.id!r} in state "
                             f"{st.status}")
        del self.running[st.slot]
        self._free_slots.append(st.slot)
        st.slot = -1
        st.status = FINISHED
