"""Paged KV-cache management: HBM block accounting + pool-resident tier.

The serving engine keeps its numeric decode state as one dense
slot-major cache pytree (batch axis = decode slots), because the jitted
step function needs static shapes.  Everything *around* that state is
paged:

* :class:`BlockManager` accounts HBM in fixed ``block_tokens`` blocks -
  per-request block tables, refcounted hash-chained prefix blocks (two
  requests with the same prompt prefix count those blocks once), and
  admission/growth failures that drive preemption;
* :class:`CacheLayout` maps between one slot of the dense pytree and a
  canonical byte image (derived structurally from
  ``model.init_cache`` shapes, so it works for attention, SSM and
  hybrid caches alike) - the serialization used for eviction to the
  pool and for bitwise-exact restore;
* :class:`PooledKVStore` is the pool-resident tier: payloads live in a
  ``core.pool.PoolBlockAllocator`` region, each entry is committed by
  ringing a ``DoorbellRegion`` doorbell after its payload blocks land,
  and cross-engine sharing is tracked in ``RefcountRegion`` words - the
  paper's index-calculated doorbell protocol, reused for KV pages.
  Several engines can hold the *same* store, which is exactly the
  cross-replica pooled-prefix play (Beluga): engine B's lookup of a
  hash-addressed prefix block hits what engine A published.

Placement is priced like wire traffic: :func:`price_kv_block` compares
the pool round-trip (write + read through the CXL cost model) against
recomputing the tokens (prefill roofline), yielding a tuner ``Choice``
with backend ``"pool"`` or ``"recompute"`` that is recorded in the
ledger and can live in the plan as a ``kv_block`` cell like any
collective.
"""
from __future__ import annotations

import dataclasses
import hashlib
from collections import OrderedDict
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import ledger
from repro.core.doorbell import DoorbellRegion, RefcountRegion
from repro.core.hw import CXL_POOL, CXLPoolConfig
from repro.core.pool import PoolBlockAllocator
from repro.tuner.costmodel import roofline_compute_time
from repro.tuner.plan import Choice, Plan


# -- dense-slot <-> bytes mapping ------------------------------------------

@dataclasses.dataclass(frozen=True)
class LeafSpec:
    """One cache-pytree leaf: where its batch/seq axes live."""

    shape: tuple
    dtype: np.dtype
    batch_axis: int
    seq_axis: Optional[int]     # None: no per-token extent (SSM state,
                                # cross-attention cache, ring buffers)


def _diff_axis(a, b) -> Optional[int]:
    axes = [i for i, (x, y) in enumerate(zip(a, b)) if x != y]
    if not axes:
        return None
    if len(axes) > 1:
        raise ValueError(f"ambiguous axis between {a} and {b}")
    return axes[0]


class CacheLayout:
    """Structural map of the engine's cache pytree.

    Axes are derived by probing ``model.init_cache`` shapes (via
    ``jax.eval_shape``, no allocation) at two batch sizes and two
    ``max_seq`` values: the axis that scales with batch is the slot
    axis, the axis that scales with ``max_seq`` is the token axis.
    Leaves that scale with neither (mamba state, conv state,
    cross-attention caches, and every leaf under a sliding ``window``)
    carry no per-token extent and are serialized whole.
    """

    def __init__(self, cfg, pc, batch: int, max_seq: int, cache_dtype,
                 window: Optional[int] = None):
        from repro.models import model
        self.batch = int(batch)
        self.max_seq = int(max_seq)
        self.window = window
        self.eff_seq = min(max_seq, window) if window else max_seq

        def probe(b, s):
            return jax.eval_shape(lambda: model.init_cache(
                cfg, pc, b, s, cache_dtype=cache_dtype, window=window))

        real = probe(batch, max_seq)
        alt_b = probe(batch + 1, max_seq)
        s2 = max_seq * 2
        alt_s = probe(batch, s2)
        self.treedef = jax.tree.structure(real)
        self.leaves: list[LeafSpec] = []
        for lr, lb, ls in zip(jax.tree.leaves(real),
                              jax.tree.leaves(alt_b),
                              jax.tree.leaves(alt_s)):
            b_ax = _diff_axis(lr.shape, lb.shape)
            if b_ax is None:
                raise ValueError(f"cache leaf {lr.shape} has no batch "
                                 f"axis")
            self.leaves.append(LeafSpec(
                shape=tuple(lr.shape), dtype=np.dtype(lr.dtype),
                batch_axis=b_ax,
                seq_axis=_diff_axis(lr.shape, ls.shape)))

    # A cache is block-sharable when *every* leaf has a token axis:
    # then a [t0, t1) token range is a complete, self-contained slice
    # of decode state.  Recurrent state (SSM) and ring-buffer windows
    # break that, so those engines fall back to whole-image pooling.
    @property
    def block_sharable(self) -> bool:
        return all(sp.seq_axis is not None for sp in self.leaves)

    def _ntok(self, ntok: int) -> int:
        return min(int(ntok), self.eff_seq)

    def bytes_for(self, ntok: int) -> int:
        """Image size of one slot holding ``ntok`` tokens."""
        n = self._ntok(ntok)
        total = 0
        for sp in self.leaves:
            shape = list(sp.shape)
            del shape[sp.batch_axis]
            if sp.seq_axis is not None:
                sa = sp.seq_axis - (1 if sp.batch_axis < sp.seq_axis
                                    else 0)
                shape[sa] = n
            total += int(np.prod(shape, dtype=np.int64)) \
                * sp.dtype.itemsize
        return total

    def bytes_for_range(self, t0: int, t1: int) -> int:
        """Image size of a [t0, t1) token range (block-sharable only)."""
        total = 0
        for sp in self.leaves:
            shape = list(sp.shape)
            del shape[sp.batch_axis]
            sa = sp.seq_axis - (1 if sp.batch_axis < sp.seq_axis else 0)
            shape[sa] = t1 - t0
            total += int(np.prod(shape, dtype=np.int64)) \
                * sp.dtype.itemsize
        return total

    # -- extraction / insertion (host-side, canonical byte order) ---------

    def extract_slot(self, caches, slot: int, ntok: int) -> bytes:
        """Serialize slot ``slot``'s first ``ntok`` tokens of state."""
        n = self._ntok(ntok)
        parts = []
        for leaf, sp in zip(jax.tree.leaves(caches), self.leaves):
            arr = np.asarray(leaf)
            idx = [slice(None)] * arr.ndim
            idx[sp.batch_axis] = slot
            if sp.seq_axis is not None:
                idx[sp.seq_axis] = slice(0, n)
            parts.append(np.ascontiguousarray(arr[tuple(idx)]).tobytes())
        return b"".join(parts)

    def insert_slot(self, caches, slot: int, ntok: int, data: bytes):
        """Inverse of :meth:`extract_slot`: returns a new cache pytree
        with slot ``slot`` holding exactly the image (positions beyond
        ``ntok`` zeroed, so a restored slot is canonical)."""
        n = self._ntok(ntok)
        if len(data) != self.bytes_for(ntok):
            raise ValueError(f"cache image is {len(data)} bytes, slot "
                             f"at {ntok} tokens needs "
                             f"{self.bytes_for(ntok)}")
        leaves = list(jax.tree.leaves(caches))
        off = 0
        out = []
        for leaf, sp in zip(leaves, self.leaves):
            slot_shape = list(sp.shape)
            del slot_shape[sp.batch_axis]
            chunk_shape = list(slot_shape)
            if sp.seq_axis is not None:
                sa = sp.seq_axis - (1 if sp.batch_axis < sp.seq_axis
                                    else 0)
                chunk_shape[sa] = n
            nb = int(np.prod(chunk_shape, dtype=np.int64)) \
                * sp.dtype.itemsize
            chunk = np.frombuffer(data, sp.dtype, count=nb
                                  // sp.dtype.itemsize,
                                  offset=off).reshape(chunk_shape)
            off += nb
            target = np.zeros(slot_shape, sp.dtype)
            if sp.seq_axis is not None:
                tidx = [slice(None)] * len(slot_shape)
                tidx[sa] = slice(0, n)
                target[tuple(tidx)] = chunk
            else:
                target[...] = chunk
            bidx = [slice(None)] * len(sp.shape)
            bidx[sp.batch_axis] = slot
            out.append(leaf.at[tuple(bidx)].set(
                jnp.asarray(target, leaf.dtype)))
        return self.treedef.unflatten(out)

    def extract_token_range(self, caches, slot: int, t0: int,
                            t1: int) -> bytes:
        """Serialize a token range of one slot (block-sharable only)."""
        if not self.block_sharable:
            raise ValueError("cache layout has token-free leaves; "
                             "ranges are not self-contained")
        parts = []
        for leaf, sp in zip(jax.tree.leaves(caches), self.leaves):
            arr = np.asarray(leaf)
            idx = [slice(None)] * arr.ndim
            idx[sp.batch_axis] = slot
            idx[sp.seq_axis] = slice(t0, t1)
            parts.append(np.ascontiguousarray(arr[tuple(idx)]).tobytes())
        return b"".join(parts)

    def insert_token_range(self, caches, slot: int, t0: int, t1: int,
                           data: bytes):
        if not self.block_sharable:
            raise ValueError("cache layout has token-free leaves; "
                             "ranges are not self-contained")
        if len(data) != self.bytes_for_range(t0, t1):
            raise ValueError("token-range image size mismatch")
        leaves = list(jax.tree.leaves(caches))
        off = 0
        out = []
        for leaf, sp in zip(leaves, self.leaves):
            chunk_shape = list(sp.shape)
            del chunk_shape[sp.batch_axis]
            sa = sp.seq_axis - (1 if sp.batch_axis < sp.seq_axis else 0)
            chunk_shape[sa] = t1 - t0
            nb = int(np.prod(chunk_shape, dtype=np.int64)) \
                * sp.dtype.itemsize
            chunk = np.frombuffer(data, sp.dtype, count=nb
                                  // sp.dtype.itemsize,
                                  offset=off).reshape(chunk_shape)
            off += nb
            idx = [slice(None)] * len(sp.shape)
            idx[sp.batch_axis] = slot
            idx[sp.seq_axis] = slice(t0, t1)
            out.append(leaf.at[tuple(idx)].set(
                jnp.asarray(chunk, leaf.dtype)))
        return self.treedef.unflatten(out)

    def reset_slot(self, caches, slot: int):
        out = []
        for leaf, sp in zip(jax.tree.leaves(caches), self.leaves):
            idx = [slice(None)] * len(sp.shape)
            idx[sp.batch_axis] = slot
            out.append(leaf.at[tuple(idx)].set(0))
        return self.treedef.unflatten(out)

    def where_slots(self, active, new, old):
        """jit-safe per-slot select: keep ``new`` where ``active`` else
        ``old`` (discards the step's writes to inactive slots)."""
        new_leaves = jax.tree.leaves(new)
        old_leaves = jax.tree.leaves(old)
        out = []
        for ln, lo, sp in zip(new_leaves, old_leaves, self.leaves):
            shape = [1] * ln.ndim
            shape[sp.batch_axis] = -1
            out.append(jnp.where(active.reshape(shape), ln, lo))
        return self.treedef.unflatten(out)


# -- HBM block accounting --------------------------------------------------

def chain_hashes(tokens, block_tokens: int) -> list:
    """Rolling content hash per complete token block: block i's hash
    covers tokens [0, (i+1)*block_tokens), so equal hashes mean equal
    *prefixes*, which is what makes them pool-addressable."""
    out = []
    h = b""
    toks = list(int(t) for t in tokens)
    for i in range(len(toks) // block_tokens):
        blk = toks[i * block_tokens:(i + 1) * block_tokens]
        h = hashlib.sha256(
            h + np.asarray(blk, np.int64).tobytes()).hexdigest()
        out.append(h)
        h = h.encode()
    return out


class BlockManager:
    """HBM accounting in fixed-size token blocks with refcounted,
    hash-chained prefix sharing.

    Purely host-side bookkeeping (the numeric state lives in the dense
    slot cache): per-request block tables, a free list, and a
    hash -> block directory so two requests with the same prompt prefix
    pin the same logical blocks (refcount 2) instead of two copies.
    ``alloc``/``append`` raise ``MemoryError`` when the budget is
    exhausted - the scheduler turns that into preemption.
    """

    def __init__(self, num_blocks: int, block_tokens: int):
        if num_blocks <= 0 or block_tokens <= 0:
            raise ValueError("num_blocks/block_tokens must be positive")
        self.num_blocks = int(num_blocks)
        self.block_tokens = int(block_tokens)
        self._free = list(range(self.num_blocks - 1, -1, -1))
        self._ref: dict = {}        # block id -> refcount
        self._by_hash: dict = {}    # chain hash -> block id
        self._hash_of: dict = {}    # block id -> chain hash
        self._tables: dict = {}     # request key -> [block ids]
        self._ntok: dict = {}       # request key -> tokens held
        self.shared_block_hits = 0

    @property
    def free_blocks(self) -> int:
        return len(self._free)

    @property
    def used_blocks(self) -> int:
        return self.num_blocks - len(self._free)

    def blocks_for(self, ntok: int) -> int:
        return -(-int(ntok) // self.block_tokens)

    def refcount(self, block: int) -> int:
        return self._ref.get(block, 0)

    def table(self, key) -> list:
        return list(self._tables[key])

    def tokens_held(self, key) -> int:
        return self._ntok[key]

    def holders(self) -> list:
        return list(self._tables)

    def can_fit(self, ntok: int, hashes=()) -> bool:
        need = self.blocks_for(ntok)
        reused = sum(1 for h in hashes if h in self._by_hash)
        return need - min(reused, need) <= len(self._free)

    def alloc(self, key, ntok: int, hashes=()) -> list:
        """Allocate ``key``'s table for ``ntok`` tokens.  ``hashes`` is
        the prompt's chain-hash list (complete blocks only): matching
        blocks are shared (refcount bump) instead of allocated."""
        if key in self._tables:
            raise ValueError(f"request {key!r} already holds blocks")
        need = self.blocks_for(ntok)
        table = []
        try:
            for i in range(need):
                h = hashes[i] if i < len(hashes) else None
                if h is not None and h in self._by_hash:
                    b = self._by_hash[h]
                    self._ref[b] += 1
                    self.shared_block_hits += 1
                else:
                    if not self._free:
                        raise MemoryError(
                            f"HBM block budget exhausted "
                            f"({self.used_blocks}/{self.num_blocks} "
                            f"used)")
                    b = self._free.pop()
                    self._ref[b] = 1
                    if h is not None:
                        self._by_hash[h] = b
                        self._hash_of[b] = h
                table.append(b)
        except MemoryError:
            self._release(table)
            raise
        self._tables[key] = table
        self._ntok[key] = int(ntok)
        return list(table)

    def append(self, key, n: int = 1) -> None:
        """Grow ``key`` by ``n`` decode tokens (new blocks unhashed)."""
        table = self._tables[key]
        ntok = self._ntok[key] + int(n)
        grown = []
        try:
            while len(table) < self.blocks_for(ntok):
                if not self._free:
                    raise MemoryError(
                        f"HBM block budget exhausted growing "
                        f"{key!r} ({self.used_blocks}/"
                        f"{self.num_blocks} used)")
                b = self._free.pop()
                self._ref[b] = 1
                table.append(b)
                grown.append(b)
        except MemoryError:
            for b in grown:
                table.remove(b)
            self._release(grown)
            raise
        self._ntok[key] = ntok

    def free(self, key) -> None:
        self._release(self._tables.pop(key))
        del self._ntok[key]

    def _release(self, blocks) -> None:
        for b in blocks:
            self._ref[b] -= 1
            if self._ref[b] == 0:
                del self._ref[b]
                h = self._hash_of.pop(b, None)
                if h is not None:
                    del self._by_hash[h]
                self._free.append(b)


# -- pool-resident tier ----------------------------------------------------

@dataclasses.dataclass
class _PoolEntry:
    index: int          # doorbell / refcount word index
    blocks: list        # pool block ids, payload order
    nbytes: int


class PooledKVStore:
    """Hash-addressed KV pages in pool memory, doorbell-committed.

    Payload bytes live in a :class:`PoolBlockAllocator` region (every
    access through the pool fault shim with bounded retries); entry
    ``i``'s commit doorbell and cross-engine refcount are the words at
    index-calculated addresses ``i * DOORBELL_BYTES`` in their regions.
    The publish protocol is write-payload -> set-refcount -> ring:
    a reader that finds the doorbell STALE treats the entry as absent,
    so a half-written entry is never served.  When the region fills,
    the least-recently-used entry with a zero refcount word is
    reclaimed; pinned (acquired) entries never are.

    One store instance shared by several engines *is* the
    cross-replica prefix cache: keys are content-derived (chain
    hashes), so identical system prompts collide on purpose.
    """

    def __init__(self, budget_bytes: int, *, block_bytes: int = 1 << 16,
                 max_entries: int = 512,
                 cfg: Optional[CXLPoolConfig] = None):
        self.alloc = PoolBlockAllocator(budget_bytes, block_bytes,
                                        cfg or CXL_POOL)
        self.doorbells = DoorbellRegion(max_entries)
        self.refs = RefcountRegion(max_entries)
        self.max_entries = int(max_entries)
        self._dir: "OrderedDict[object, _PoolEntry]" = OrderedDict()
        self._free_idx = list(range(max_entries - 1, -1, -1))
        # Telemetry + modeled cost (the virtual-clock benchmark and the
        # obs gauges both read these).
        self.puts = 0
        self.hits = 0
        self.misses = 0
        self.dropped = 0
        self.reclaimed = 0
        self.predicted_write_s = 0.0
        self.predicted_read_s = 0.0

    def __contains__(self, key) -> bool:
        e = self._dir.get(key)
        return e is not None and self.doorbells.is_ready(e.index)

    def keys(self) -> list:
        return list(self._dir)

    def predict_put_s(self, nbytes: int) -> float:
        bb = self.alloc.block_bytes
        whole, rem = divmod(int(nbytes), bb)
        return whole * self.alloc.predict_write_s(bb) + (
            self.alloc.predict_write_s(rem) if rem else 0.0)

    def predict_get_s(self, nbytes: int) -> float:
        bb = self.alloc.block_bytes
        whole, rem = divmod(int(nbytes), bb)
        return whole * self.alloc.predict_read_s(bb) + (
            self.alloc.predict_read_s(rem) if rem else 0.0)

    def put(self, key, payload: bytes, *, rank: int = 0) -> bool:
        """Publish ``payload`` under ``key``.  Returns False when the
        pool budget cannot hold it even after reclaiming unpinned
        entries (callers fall back to recompute)."""
        if key in self._dir:
            self._dir.move_to_end(key)
            return True
        nblocks = max(1, -(-len(payload) // self.alloc.block_bytes))
        while (not self._free_idx
               or self.alloc.free_blocks < nblocks):
            if not self._reclaim_one():
                self.dropped += 1
                return False
        index = self._free_idx.pop()
        blocks = self.alloc.alloc(nblocks)
        bb = self.alloc.block_bytes
        for i, b in enumerate(blocks):
            self.alloc.write_block(b, payload[i * bb:(i + 1) * bb],
                                   rank=rank)
        self.refs.reset(index)
        self.doorbells.ring(index)   # commit point
        self._dir[key] = _PoolEntry(index, blocks, len(payload))
        self.puts += 1
        self.predicted_write_s += self.predict_put_s(len(payload))
        return True

    def get(self, key, *, rank: int = 0) -> Optional[bytes]:
        """Fetch a committed entry's payload (None on miss or when the
        doorbell has not rung - a half-published entry is a miss)."""
        e = self._dir.get(key)
        if e is None or not self.doorbells.is_ready(e.index):
            self.misses += 1
            return None
        self._dir.move_to_end(key)
        out = b"".join(self.alloc.read_block(b, rank=rank)
                       for b in e.blocks)[:e.nbytes]
        self.hits += 1
        self.predicted_read_s += self.predict_get_s(e.nbytes)
        return out

    def acquire(self, key, *, rank: int = 0) -> int:
        return self.refs.acquire(self._dir[key].index, rank=rank)

    def release(self, key, *, rank: int = 0) -> int:
        return self.refs.release(self._dir[key].index, rank=rank)

    def refcount(self, key) -> int:
        return self.refs.read(self._dir[key].index)

    def remove(self, key) -> None:
        """Drop an entry outright (one-shot eviction images)."""
        e = self._dir.pop(key)
        if self.refs.read(e.index) > 0:
            self._dir[key] = e
            self._dir.move_to_end(key, last=False)
            raise ValueError(f"pooled entry {key!r} still referenced")
        self._reclaim(e)

    def _reclaim_one(self) -> bool:
        for key, e in self._dir.items():
            if self.refs.read(e.index) == 0:
                del self._dir[key]
                self._reclaim(e)
                self.reclaimed += 1
                return True
        return False

    def _reclaim(self, e: _PoolEntry) -> None:
        self.alloc.free(e.blocks)
        self.doorbells.reset(e.index)
        self.refs.reset(e.index)
        self._free_idx.append(e.index)

    @property
    def stats(self) -> dict:
        return {"entries": len(self._dir), "puts": self.puts,
                "hits": self.hits, "misses": self.misses,
                "dropped": self.dropped, "reclaimed": self.reclaimed,
                "pool_blocks_used": self.alloc.used_blocks,
                "pool_blocks_free": self.alloc.free_blocks,
                "pool_retried": self.alloc.retried,
                "predicted_write_s": self.predicted_write_s,
                "predicted_read_s": self.predicted_read_s}


# -- placement pricing (the tuner's oracle, applied to cache pages) --------

def price_kv_block(nbytes: int, recompute_flops: float, *,
                   pool_cfg: Optional[CXLPoolConfig] = None,
                   block_bytes: int = 1 << 16) -> Choice:
    """Evict-to-pool vs recompute, priced with the same models the
    tuner uses for wire traffic: the pool round-trip is a block write
    plus a block read through the CXL cost constants, recompute is the
    roofline residency of re-running prefill over the covered tokens.
    Returns a plan ``Choice`` (backend ``"pool"`` | ``"recompute"``)
    whose predicted/baseline times are the two candidates.
    """
    cfg = pool_cfg or CXL_POOL
    nblocks = max(1, -(-int(nbytes) // block_bytes))
    per_w = cfg.memcpy_overhead + block_bytes / cfg.server_bw
    per_r = per_w + cfg.access_latency
    pool_s = nblocks * (per_w + per_r)
    rec_s = roofline_compute_time(max(0.0, recompute_flops))
    pick_pool = pool_s <= rec_s
    return Choice(backend="pool" if pick_pool else "recompute",
                  slicing_factor=1, allreduce_mode="kv_tier",
                  predicted_time=min(pool_s, rec_s),
                  baseline_time=max(pool_s, rec_s))


def resolve_kv_choice(primitive: str, nbytes: int,
                      recompute_flops: float, *,
                      plan: Optional[Plan] = None,
                      pool_cfg: Optional[CXLPoolConfig] = None,
                      block_bytes: int = 1 << 16) -> Choice:
    """Resolve a cache-placement cell: a tuned plan cell wins (the
    sweep in ``launch/tune --kv-block-bytes`` writes them), otherwise
    the live oracle prices it.  Either way the decision lands in the
    ledger's auto-choice audit exactly like a collective's."""
    choice = plan.lookup(primitive, max(1, nbytes), 1) \
        if plan is not None else None
    if choice is None:
        choice = price_kv_block(nbytes, recompute_flops,
                                pool_cfg=pool_cfg,
                                block_bytes=block_bytes)
    ledger.record_choice(primitive, max(1, nbytes), 1, choice.backend,
                         choice.slicing_factor, choice.allreduce_mode,
                         predicted_time=choice.predicted_time,
                         baseline_time=choice.baseline_time)
    return choice
