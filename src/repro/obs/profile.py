"""Per-collective timing capture: profiler-trace parsing + emulator.

The online tuner wants *per-collective* measured times keyed to plan
cells.  Inside ``jax.jit`` nothing can call ``ledger.timed`` around an
individual collective - the only per-op timing signal for a jitted step
is a profiler trace.  This module turns either of two sources into
ledger timing samples carrying full plan-cell identity:

* **Profiler path** (``trace_timings`` / ``profiled_timings``): parse
  the Chrome trace-event JSON that ``jax.profiler.trace`` emits (plain
  or gzipped), keep the device-side collective ops, and match them to
  the trace-time ``auto_choices`` audit by primitive in recorded order
  - choice k's ``calls`` launches are expected before choice k+1's, so
  events map onto the expanded schedule cyclically.  Best-effort by
  design: profile availability varies across jax builds (some emit
  only ``xplane.pb``), so callers fall back to step-time apportioning
  when no events parse.  ``collective-permute`` ops are surfaced but
  not matched: the cxl backend lowers one logical collective into a
  *chain* of permutes, so a 1:1 event->cell mapping does not exist for
  them.

* **Emulator path** (``StepEmulator``): a device-free stand-in that
  prices each audited choice with the cost oracle for its own topology
  level, applies configurable per-level degrade factors (a 4x-slow CXL
  link, a flaky IB stage) plus seeded multiplicative noise, and books
  the result through ``ledger.record_timing``.  This exercises the
  whole feedback loop - flight recorder, health monitor, calibration,
  retune - deterministically on CI machines with no accelerator.
"""
from __future__ import annotations

import glob
import gzip
import json
import os
import re

import numpy as np

from repro.core import ledger
from repro.tuner import costmodel

# XLA/HLO op-name fragments -> ledger primitive.  ``collective-permute``
# maps to None: recognized as collective time, but one logical cxl
# collective is a chain of permutes, so per-cell matching is undefined.
PRIM_PATTERNS = (
    (re.compile(r"all[-_]?reduce", re.I), "all_reduce"),
    (re.compile(r"reduce[-_]?scatter", re.I), "reduce_scatter"),
    (re.compile(r"all[-_]?gather", re.I), "all_gather"),
    (re.compile(r"all[-_]?to[-_]?all", re.I), "all_to_all"),
    (re.compile(r"collective[-_]?permute|ppermute", re.I), None),
)


def classify(name: str) -> "tuple[bool, str | None]":
    """``(is_collective, primitive-or-None)`` for one trace-event name."""
    for pat, prim in PRIM_PATTERNS:
        if pat.search(name):
            return True, prim
    return False, None


def load_trace(path: str) -> dict:
    """Load a Chrome trace-event document (``.json`` or ``.json.gz``)."""
    opener = gzip.open if path.endswith(".gz") else open
    with opener(path, "rt") as f:
        doc = json.load(f)
    return doc if isinstance(doc, dict) else {"traceEvents": doc}


def collective_events(doc: dict) -> list:
    """Complete (``ph: X``) collective events, sorted by timestamp:
    ``{"name", "primitive", "ts_us", "dur_us"}``."""
    out = []
    for ev in doc.get("traceEvents", []):
        if not isinstance(ev, dict) or ev.get("ph") != "X":
            continue
        name = ev.get("name", "")
        is_coll, prim = classify(name)
        if not is_coll or float(ev.get("dur", 0.0)) <= 0.0:
            continue
        out.append({"name": name, "primitive": prim,
                    "ts_us": float(ev.get("ts", 0.0)),
                    "dur_us": float(ev.get("dur", 0.0))})
    out.sort(key=lambda e: e["ts_us"])
    return out


def _sample_from_choice(choice: dict, seconds: float,
                        calls: float = 1.0) -> dict:
    return {"primitive": choice["primitive"],
            "msg_bytes": int(choice["msg_bytes"]),
            "nranks": int(choice["nranks"]),
            "backend": choice["backend"],
            "slicing_factor": int(choice["slicing_factor"]),
            "allreduce_mode": choice["allreduce_mode"],
            "level": choice.get("level"),
            "fabric": choice.get("fabric"),
            "seconds": float(seconds), "calls": float(calls)}


def match_events(events: list, choices: list) -> list:
    """Assign profiler collective events to audited ``auto_choices`` and
    return ledger-shaped timing samples (one per matched event,
    ``calls=1.0`` since each event is one launch).

    Per primitive, the audit's call sites in recorded order - each
    expanded by its trip count - form the expected launch schedule; the
    primitive's events, in time order, walk that schedule cyclically
    (a profile may cover several steps).  Events whose primitive has no
    audited site (or is unmatchable, e.g. ``collective-permute``) are
    skipped.
    """
    sched: dict = {}
    for c in choices:
        sched.setdefault(c["primitive"], []).extend(
            [c] * max(1, int(round(c.get("calls", 1.0)))))
    cursor: dict = {p: 0 for p in sched}
    out = []
    for ev in events:
        prim = ev["primitive"]
        slots = sched.get(prim)
        if not slots:
            continue
        c = slots[cursor[prim] % len(slots)]
        cursor[prim] += 1
        out.append(_sample_from_choice(c, ev["dur_us"] * 1e-6))
    return out


def trace_timings(path: str, choices: list) -> list:
    """Parse one profiler trace file into matched timing samples."""
    return match_events(collective_events(load_trace(path)), choices)


def profiled_timings(logdir: str, choices: list, *,
                     book: bool = False) -> list:
    """Find the newest ``*.trace.json[.gz]`` under a
    ``jax.profiler.trace`` logdir and match it against the audit.
    Returns ``[]`` when no parseable trace exists (some jax builds only
    emit ``xplane.pb``) - the caller should fall back to step timing.
    When ``book`` is set, samples are also recorded into the ledger
    (feeding the flight recorder via the timing hook)."""
    paths = sorted(
        glob.glob(os.path.join(logdir, "**", "*.trace.json"),
                  recursive=True)
        + glob.glob(os.path.join(logdir, "**", "*.trace.json.gz"),
                    recursive=True),
        key=os.path.getmtime)
    if not paths:
        return []
    try:
        samples = trace_timings(paths[-1], choices)
    except (OSError, ValueError, KeyError):
        return []
    if book:
        for t in samples:
            ledger.record_timing(**t)
    return samples


class StepEmulator:
    """Device-free per-collective timing source.

    Prices every audited plan choice with the cost oracle for its own
    topology level, times a configurable per-level slowdown, times
    seeded multiplicative noise - i.e. "what a profiler would have
    measured on hardware that matches the oracle, except where we say
    it doesn't".  ``degrade`` keys are level axis names (``"node"``),
    fabric kinds (``"cxl"``), or ``"*"``; factors multiply.  A
    backend-qualified key (``"node@cxl"``, ``"cxl@cxl"``) hits only
    choices *executing* that backend on the level/fabric - the shape
    of a pool-side fault, which slows the pool transport but not the
    ring alternative riding the level's IB config.
    """

    def __init__(self, *, topology=None, noise_std: float = 0.0,
                 seed: int = 0, degrade: "dict | None" = None):
        self.topology = topology
        self.noise_std = float(noise_std)
        self._rng = np.random.default_rng(seed)
        self.degrade = dict(degrade or {})

    def set_degrade(self, key: str, factor: float) -> None:
        """Inject (or clear, with factor 1.0) a slowdown mid-run."""
        if factor == 1.0:
            self.degrade.pop(key, None)
        else:
            self.degrade[key] = float(factor)

    def _factor(self, level: "str | None", fabric: "str | None",
                backend: "str | None" = None) -> float:
        f = self.degrade.get("*", 1.0)
        if level is not None:
            f *= self.degrade.get(level, 1.0)
        if fabric is not None:
            f *= self.degrade.get(fabric, 1.0)
        if backend is not None:
            for base in (level, fabric):
                if base is not None:
                    f *= self.degrade.get(f"{base}@{backend}", 1.0)
        return f

    def time_choice(self, choice: dict) -> float:
        """Oracle time for one audited choice on its own level's fabric,
        degraded + noised."""
        axis = choice.get("level")
        lv = self.topology.level_for(axis) if (
            self.topology is not None and axis is not None) else None
        if choice["primitive"] == "p2p":
            # stage-handoff cells price through the dedicated p2p
            # oracles (the collective models don't know the primitive)
            if lv is not None:
                t = costmodel.predict_level_p2p_time(
                    lv, int(choice["msg_bytes"]),
                    backend=choice["backend"],
                    slicing_factor=int(choice["slicing_factor"]))
            else:
                t = costmodel.predict_p2p_time(
                    choice["backend"], int(choice["msg_bytes"]),
                    slicing_factor=int(choice["slicing_factor"]))
        elif lv is not None:
            t = costmodel.predict_level_time(
                lv, choice["primitive"], int(choice["nranks"]),
                int(choice["msg_bytes"]), backend=choice["backend"],
                slicing_factor=int(choice["slicing_factor"]),
                allreduce_mode=choice["allreduce_mode"])
        else:
            t = costmodel.predict_time(
                choice["backend"], choice["primitive"],
                int(choice["nranks"]), int(choice["msg_bytes"]),
                slicing_factor=int(choice["slicing_factor"]),
                allreduce_mode=choice["allreduce_mode"])
        t *= self._factor(axis, choice.get("fabric"),
                          choice.get("backend"))
        if self.noise_std > 0.0:
            t *= float(np.clip(self._rng.normal(1.0, self.noise_std),
                               0.5, 2.0))
        return t

    def step_timings(self, choices: list, *, book: bool = True) -> list:
        """One emulated step: a timing sample per audited choice,
        weighted by its trip count.  ``book`` records each sample into
        the ledger (default - that is what drives the flight recorder
        and any registered timing hooks)."""
        samples = [_sample_from_choice(c, self.time_choice(c),
                                       calls=c.get("calls", 1.0))
                   for c in choices]
        if book:
            for t in samples:
                ledger.record_timing(**t)
        return samples
