"""Span-based structured tracing + flight recorder.

``Tracer`` records a hierarchy of spans - step > phase > collective -
each tagged with the plan-cell identity of the work it covers (via the
``ledger.add_timing_hook`` bridge, every measured collective sample
lands in the trace with its primitive / backend / knobs / level /
fabric / plan-epoch args).  The hot path is deliberately cheap: an
event is a tuple appended to a Python list (no dict building, no
string formatting, no clock math beyond one ``perf_counter`` read per
span edge); all formatting is deferred to ``dump()``.  The
``benchmarks/observability.py`` smoke asserts the resulting overhead
stays under 5% of step time.

The **flight recorder** keeps only the last ``capacity_steps`` steps in
a ring buffer (``collections.deque(maxlen=...)``), so tracing can stay
on for a whole run at O(capacity) memory.  ``trigger(reason)`` marks an
anomaly (the health monitor calls it when a link degrades) and - when a
dump path is configured - snapshots the ring to disk immediately, so
the trace that *led up to* the anomaly survives even if the run dies.

``dump()`` writes the standard Chrome trace-event JSON (``ph: "X"``
complete events), loadable in Perfetto / ``chrome://tracing``: steps
and phases nest on one track by timestamp containment, measured
collectives render on a second track.
"""
from __future__ import annotations

import collections
import contextlib
import json
import time

from repro.core import ledger

# Event tuples (hot path; formatted only at dump time):
#   ("X", kind, name, t0, dur, tags)   span (step/phase/...)
#   ("i", kind, name, ts, tags)        instant marker
#   ("T", sample_dict, ts_end, step)   measured collective (ledger hook)
DEFAULT_CAPACITY = 32


class Tracer:
    """Structured tracer with a bounded step ring buffer."""

    def __init__(self, capacity_steps: int = DEFAULT_CAPACITY):
        self.capacity = max(1, int(capacity_steps))
        # Ring of (step_index, events): the flight recorder.
        self._steps = collections.deque(maxlen=self.capacity)
        self._events: list = []        # current step (or pre-step preamble)
        self._step_index = None
        self._t0 = time.perf_counter()
        self.enabled = False
        self.anomalies: list = []      # (ts, reason)
        self.dumps = 0

    # -- recording --------------------------------------------------------

    def _now(self) -> float:
        return time.perf_counter() - self._t0

    @contextlib.contextmanager
    def step(self, index: int):
        """One training/serving step: the ring-buffer unit."""
        if not self.enabled:
            yield
            return
        prev_events, prev_index = self._events, self._step_index
        self._events, self._step_index = [], int(index)
        t0 = self._now()
        try:
            yield
        finally:
            dur = self._now() - t0
            events = self._events
            events.insert(0, ("X", "step", f"step {index}", t0, dur,
                              (("step", int(index)),)))
            self._steps.append((int(index), events))
            self._events, self._step_index = prev_events, prev_index

    @contextlib.contextmanager
    def span(self, name: str, kind: str = "phase", **tags):
        """A named sub-region of the current step (phase, retune, ...)."""
        if not self.enabled:
            yield
            return
        t0 = self._now()
        try:
            yield
        finally:
            self._events.append(("X", kind, name, t0, self._now() - t0,
                                 tuple(tags.items())))

    def instant(self, name: str, kind: str = "mark", **tags) -> None:
        if self.enabled:
            self._events.append(("i", kind, name, self._now(),
                                 tuple(tags.items())))

    def record_collective(self, sample: dict) -> None:
        """Ledger timing hook: one measured collective sample.  The dict
        is stored by reference; formatting waits for ``dump()``."""
        if self.enabled:
            self._events.append(("T", sample, self._now(),
                                 self._step_index))

    # -- anomaly / dump ---------------------------------------------------

    def trigger(self, reason: str, path: "str | None" = None) -> None:
        """Mark an anomaly; dump the flight recorder now if ``path``."""
        self.anomalies.append((self._now(), str(reason)))
        self.instant(f"anomaly: {reason}", kind="anomaly")
        if path:
            self.dump(path)

    def _format(self, events, out: list) -> None:
        for ev in events:
            if ev[0] == "X":
                _, kind, name, t0, dur, tags = ev
                out.append({"name": name, "cat": kind, "ph": "X",
                            "ts": t0 * 1e6, "dur": dur * 1e6,
                            "pid": 0, "tid": 0, "args": dict(tags)})
            elif ev[0] == "i":
                _, kind, name, ts, tags = ev
                out.append({"name": name, "cat": kind, "ph": "i",
                            "ts": ts * 1e6, "s": "p",
                            "pid": 0, "tid": 0, "args": dict(tags)})
            else:                       # ("T", sample, ts_end, step)
                _, t, ts_end, step = ev
                dur = float(t["seconds"])
                args = {k: v for k, v in t.items() if v is not None}
                if step is not None:
                    args.setdefault("step", step)
                lvl = t.get("level")
                name = f"{t['primitive']}@{t['backend']}" + (
                    f" [{lvl}]" if lvl else "")
                # Measured duration, anchored so the slice *ends* at the
                # moment the sample was booked.  Emulated times may
                # exceed real wall gaps; the collectives track is a
                # per-sample timeline, not a wall-clock gantt.
                out.append({"name": name, "cat": "collective", "ph": "X",
                            "ts": max(0.0, ts_end - dur) * 1e6,
                            "dur": dur * 1e6,
                            "pid": 0, "tid": 1, "args": args})

    def dump(self, path: "str | None" = None) -> dict:
        """Render the flight recorder (ring + in-flight step) as a
        Chrome trace-event document; write JSON to ``path`` if given."""
        events: list = [
            {"name": "process_name", "ph": "M", "pid": 0,
             "args": {"name": "repro"}},
            {"name": "thread_name", "ph": "M", "pid": 0, "tid": 0,
             "args": {"name": "steps/phases"}},
            {"name": "thread_name", "ph": "M", "pid": 0, "tid": 1,
             "args": {"name": "collectives (measured)"}},
        ]
        for _idx, evs in self._steps:
            self._format(evs, events)
        if self._events:
            self._format(self._events, events)
        doc = {"traceEvents": events, "displayTimeUnit": "ms",
               "metadata": {
                   "capacity_steps": self.capacity,
                   "steps_retained": [i for i, _ in self._steps],
                   "anomalies": [{"ts": ts, "reason": r}
                                 for ts, r in self.anomalies]}}
        if path:
            with open(path, "w") as f:
                json.dump(doc, f)
            self.dumps += 1
        return doc

    def steps_retained(self) -> list:
        return [i for i, _ in self._steps]


# -- module-level singleton (what launchers and the ledger hook use) -------

_TRACER = Tracer()


def get_tracer() -> Tracer:
    return _TRACER


def enable_tracing(capacity_steps: int = DEFAULT_CAPACITY) -> Tracer:
    """Turn on the global tracer (fresh ring buffer) and bridge the
    ledger's timing stream into it."""
    global _TRACER
    ledger.remove_timing_hook(_TRACER.record_collective)
    _TRACER = Tracer(capacity_steps)
    _TRACER.enabled = True
    ledger.add_timing_hook(_TRACER.record_collective)
    return _TRACER


def disable_tracing() -> None:
    _TRACER.enabled = False
    ledger.remove_timing_hook(_TRACER.record_collective)
