"""Link-health monitoring: EWMA baselines + persistent-outlier flags.

``HealthMonitor`` watches the measured per-collective timing stream
(the same samples the online tuner consumes) and maintains, per
(level axis, fabric) link, two EWMAs of the link's *per-step busy
seconds* (sum of measured seconds x trip count):

* a **slow baseline** (``alpha_slow``) - what this link normally
  costs per step;
* a **fast tracker** (``alpha_fast``) - what it costs right now.

A step is an outlier for a link when ``fast > threshold x baseline``;
``patience`` *consecutive* outlier steps flag the link degraded (one
noisy step never trips it), and the baseline is frozen while outlying
so a real degradation cannot launder itself into the new normal.
Recovery is symmetric: ``patience`` consecutive in-band steps clear
the flag.  Busy seconds rather than a measured/oracle ratio keeps the
detector independent of the cost model (and usable on samples whose
knobs - hence oracle - are unknown); the *calibration* scales in
``tuner.online`` are the oracle-anchored complement.

Flags propagate three ways: gauges in the metrics registry
(``repro_link_health`` / ``repro_link_slowdown_ratio``), the plan
registry (``tuner.runtime.set_link_health``) for planners and dry-run
reports, and an ``on_degraded`` callback that ``ObsSession`` wires to
the flight recorder's anomaly trigger.

``calibration_drift`` is the retune-boundary companion: it reads the
per-(backend, level) aggregate calibration scales persisted in plan
meta and recommends a placement re-plan when a fabric's measured/oracle
ratio has drifted past a threshold - the plan is then optimal for
hardware that no longer exists.
"""
from __future__ import annotations

import dataclasses

from repro.tuner import runtime


@dataclasses.dataclass
class LinkState:
    """Health-tracking state for one (level axis, fabric) link."""

    baseline: float = 0.0      # slow EWMA of per-step busy seconds
    fast: float = 0.0          # fast EWMA of per-step busy seconds
    steps: int = 0             # steps with traffic on this link
    streak: int = 0            # consecutive outlier steps
    ok_streak: int = 0         # consecutive in-band steps (recovery)
    degraded: bool = False
    since_step: "int | None" = None

    def slowdown(self) -> float:
        return self.fast / self.baseline if self.baseline > 0.0 else 1.0

    def degraded_for(self, step: int) -> int:
        """Steps this link has been flagged degraded as of ``step``
        (0 when healthy) - the persistence signal the resilience layer
        uses to tell a transient wobble from a dead fabric."""
        if not self.degraded or self.since_step is None:
            return 0
        return max(0, int(step) - int(self.since_step) + 1)

    def report(self) -> dict:
        return {"degraded": self.degraded,
                "slowdown": round(self.slowdown(), 4),
                "baseline_busy_s": self.baseline,
                "fast_busy_s": self.fast,
                "steps": self.steps, "streak": self.streak,
                "since_step": self.since_step}


class HealthMonitor:
    """Per-(level, fabric) degradation detector over timing samples."""

    def __init__(self, *, alpha_fast: float = 0.5,
                 alpha_slow: float = 0.05, threshold: float = 2.0,
                 patience: int = 3, warmup_steps: int = 3,
                 min_busy_s: float = 1e-9, registry=None,
                 on_degraded=None, on_recovered=None,
                 publish: bool = True):
        self.alpha_fast = float(alpha_fast)
        self.alpha_slow = float(alpha_slow)
        self.threshold = float(threshold)
        self.patience = max(1, int(patience))
        self.warmup_steps = max(1, int(warmup_steps))
        self.min_busy_s = float(min_busy_s)    # ignore ~idle links
        self.registry = registry
        self.on_degraded = on_degraded
        self.on_recovered = on_recovered
        self.publish = publish
        self.links: dict = {}                  # "axis/fabric" -> LinkState
        self._step_busy: dict = {}             # accumulates within a step

    @staticmethod
    def _key(sample: dict) -> str:
        return f"{sample.get('level') or '-'}/{sample.get('fabric') or '-'}"

    def observe_timings(self, timings: list) -> None:
        """Accumulate measured samples into the current step's per-link
        busy seconds.  Call any number of times per step, then
        ``end_step``."""
        for t in timings:
            busy = float(t["seconds"]) * max(1.0, float(t.get("calls",
                                                               1.0)))
            k = self._key(t)
            self._step_busy[k] = self._step_busy.get(k, 0.0) + busy

    def end_step(self, step: int) -> list:
        """Close the step: fold busy totals into the EWMAs, update
        streaks, fire transitions.  Returns the transition events
        (``{"event": "degraded"|"recovered", "link": ..., ...}``)."""
        events = []
        for k, busy in self._step_busy.items():
            if busy < self.min_busy_s:
                continue
            st = self.links.setdefault(k, LinkState())
            st.steps += 1
            if st.steps == 1:
                st.fast = st.baseline = busy
            else:
                st.fast += self.alpha_fast * (busy - st.fast)
            outlier = (st.steps > self.warmup_steps
                       and st.fast > self.threshold * st.baseline)
            if not outlier:
                # Baseline learns only from in-band steps: a persistent
                # slowdown must keep reading as one, not become normal.
                st.baseline += self.alpha_slow * (busy - st.baseline)
            if st.steps <= self.warmup_steps:
                continue
            if outlier:
                st.streak += 1
                st.ok_streak = 0
                if not st.degraded and st.streak >= self.patience:
                    st.degraded = True
                    st.since_step = int(step) - self.patience + 1
                    events.append({"event": "degraded", "link": k,
                                   "step": int(step), **st.report()})
            else:
                st.streak = 0
                st.ok_streak += 1
                if st.degraded and st.ok_streak >= self.patience:
                    st.degraded = False
                    st.since_step = None
                    events.append({"event": "recovered", "link": k,
                                   "step": int(step), **st.report()})
        self._step_busy.clear()
        self._export(int(step))
        for ev in events:
            cb = (self.on_degraded if ev["event"] == "degraded"
                  else self.on_recovered)
            if cb is not None:
                cb(ev)
        return events

    def observe_step(self, timings: list, step: int) -> list:
        self.observe_timings(timings)
        return self.end_step(step)

    def _export(self, step: int) -> None:
        if self.registry is not None:
            healthy = self.registry.gauge(
                "repro_link_health",
                "1 = link within baseline, 0 = flagged degraded")
            ratio = self.registry.gauge(
                "repro_link_slowdown_ratio",
                "fast-EWMA busy seconds over slow baseline")
            for k, st in self.links.items():
                level, _, fabric = k.partition("/")
                healthy.set(0.0 if st.degraded else 1.0,
                            level=level, fabric=fabric)
                ratio.set(st.slowdown(), level=level, fabric=fabric)
        if self.publish:
            for k, st in self.links.items():
                runtime.set_link_health(k, {**st.report(),
                                            "step": step})

    def report(self) -> dict:
        return {k: st.report() for k, st in sorted(self.links.items())}

    def degraded_links(self) -> list:
        return sorted(k for k, st in self.links.items() if st.degraded)

    def link(self, key: str) -> "LinkState | None":
        """The tracked state for one "axis/fabric" link, if any."""
        return self.links.get(key)

    def persistent_links(self, step: int, min_steps: int) -> list:
        """Links degraded for at least ``min_steps`` consecutive steps
        as of ``step`` - the promotion threshold at which the
        resilience layer stops waiting for recovery and fails over."""
        return sorted(k for k, st in self.links.items()
                      if st.degraded_for(step) >= max(1, int(min_steps)))


def calibration_drift(calibration: dict, *,
                      threshold: float = 1.5) -> list:
    """Scan a plan's persisted calibration aggregate
    (``plan.calibration()["levels"]``) for (backend, level) fabrics
    whose measured/oracle scale has drifted by more than ``threshold``
    in either direction.  Each hit is a recommendation to re-check
    placement: the plan (and any placement derived from the oracle) was
    optimized for a fabric that measures differently now."""
    if threshold <= 1.0:
        raise ValueError("threshold must be > 1")
    out = []
    for e in (calibration or {}).get("levels", []):
        scale = float(e.get("scale", 1.0))
        if scale > threshold or (scale > 0 and scale < 1.0 / threshold):
            out.append({"backend": e.get("backend"),
                        "level": e.get("level"),
                        "scale": round(scale, 4),
                        "samples": e.get("samples", 0.0),
                        "recommendation": "re-run placement/tune for "
                                          "this fabric"})
    return out
