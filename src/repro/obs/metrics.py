"""Metrics registry + export (counters / gauges / histograms).

A tiny dependency-free registry in the Prometheus data model: every
metric is a named family of labeled series, and the whole registry
exports as

* **JSON-lines** (``to_jsonl``): one sample per line,
  ``{"name", "type", "labels", "value"}`` (histograms expand into
  ``_bucket``/``_sum``/``_count`` samples, like the text format), the
  machine-readable artifact CI uploads; and
* **Prometheus text exposition format** (``to_prometheus``): what a
  node exporter / pushgateway sidecar would scrape.

``from_ledger`` populates the standard gauge set from a trace-time
ledger snapshot (wire bytes, exposed-vs-hidden split, per-(level,
fabric) attribution, launch counts) so every exported value reconciles
with ``ledger.snapshot()`` by construction - the ``_mesh_runner``
``obs-metrics`` check asserts exactly that.  Run-time series (step
wall times, measured collective seconds, retune swaps, plan-cell
regret, link health) are maintained by ``obs.ObsSession`` /
``obs.health.HealthMonitor``.

Metric names follow Prometheus conventions (``repro_`` prefix, unit
suffix); see docs/OBSERVABILITY.md for the full catalog.
"""
from __future__ import annotations

import json
import math

# Log-spaced wall-time buckets (seconds): collectives span ~1us..10s.
TIME_BUCKETS = (1e-5, 1e-4, 1e-3, 1e-2, 1e-1, 1.0, 10.0)


def _labels_key(labels: dict) -> tuple:
    return tuple(sorted((str(k), str(v)) for k, v in labels.items()))


def _fmt_labels(key: tuple) -> str:
    if not key:
        return ""
    parts = []
    for k, v in key:
        v = v.replace("\\", r"\\").replace('"', r"\"").replace("\n", r"\n")
        parts.append(f'{k}="{v}"')
    return "{" + ",".join(parts) + "}"


def _fmt_value(v: float) -> str:
    if v == math.inf:
        return "+Inf"
    f = float(v)
    return str(int(f)) if f == int(f) and abs(f) < 1e15 else repr(f)


class Metric:
    """One metric family: a name plus labeled series."""

    kind = "untyped"

    def __init__(self, name: str, help: str = ""):
        self.name = name
        self.help = help
        self.series: dict = {}      # labels key -> value (or hist state)

    def value(self, **labels) -> float:
        return self.series.get(_labels_key(labels), 0.0)

    def samples(self) -> list:
        return [(self.name, key, v) for key, v in self.series.items()]


class Counter(Metric):
    kind = "counter"

    def inc(self, value: float = 1.0, **labels) -> None:
        if value < 0.0:
            raise ValueError(f"counter {self.name} cannot decrease")
        key = _labels_key(labels)
        self.series[key] = self.series.get(key, 0.0) + float(value)


class Gauge(Metric):
    kind = "gauge"

    def set(self, value: float, **labels) -> None:
        self.series[_labels_key(labels)] = float(value)

    def add(self, value: float, **labels) -> None:
        key = _labels_key(labels)
        self.series[key] = self.series.get(key, 0.0) + float(value)


class Histogram(Metric):
    kind = "histogram"

    def __init__(self, name: str, help: str = "",
                 buckets: tuple = TIME_BUCKETS):
        super().__init__(name, help)
        self.buckets = tuple(sorted(float(b) for b in buckets))

    def observe(self, value: float, **labels) -> None:
        key = _labels_key(labels)
        st = self.series.get(key)
        if st is None:
            st = {"counts": [0] * (len(self.buckets) + 1),
                  "sum": 0.0, "count": 0}
            self.series[key] = st
        i = 0
        while i < len(self.buckets) and value > self.buckets[i]:
            i += 1
        st["counts"][i] += 1
        st["sum"] += float(value)
        st["count"] += 1

    def samples(self) -> list:
        out = []
        for key, st in self.series.items():
            cum = 0
            for le, n in zip(self.buckets + (math.inf,), st["counts"]):
                cum += n
                out.append((f"{self.name}_bucket",
                            key + (("le", _fmt_value(le)),), cum))
            out.append((f"{self.name}_sum", key, st["sum"]))
            out.append((f"{self.name}_count", key, st["count"]))
        return out


class MetricsRegistry:
    """Ordered collection of metric families; the export surface."""

    def __init__(self):
        self._metrics: dict = {}

    def _get(self, cls, name: str, help: str, **kw):
        m = self._metrics.get(name)
        if m is None:
            m = cls(name, help, **kw)
            self._metrics[name] = m
        elif not isinstance(m, cls):
            raise TypeError(f"metric {name} already registered as "
                            f"{m.kind}, not {cls.kind}")
        return m

    def counter(self, name: str, help: str = "") -> Counter:
        return self._get(Counter, name, help)

    def gauge(self, name: str, help: str = "") -> Gauge:
        return self._get(Gauge, name, help)

    def histogram(self, name: str, help: str = "",
                  buckets: tuple = TIME_BUCKETS) -> Histogram:
        return self._get(Histogram, name, help, buckets=buckets)

    def metrics(self) -> list:
        return list(self._metrics.values())

    def value(self, name: str, **labels) -> float:
        m = self._metrics.get(name)
        return 0.0 if m is None else m.value(**labels)

    # -- export -----------------------------------------------------------

    def to_jsonl(self) -> str:
        """One JSON object per line per sample (stable order): the
        CI-artifact form of the registry."""
        lines = []
        for m in self._metrics.values():
            for name, key, v in m.samples():
                lines.append(json.dumps(
                    {"name": name, "type": m.kind,
                     "labels": dict(key), "value": v},
                    sort_keys=True))
        return "\n".join(lines) + ("\n" if lines else "")

    def to_prometheus(self) -> str:
        """Prometheus text exposition format (version 0.0.4)."""
        out = []
        for m in self._metrics.values():
            if m.help:
                out.append(f"# HELP {m.name} {m.help}")
            out.append(f"# TYPE {m.name} {m.kind}")
            for name, key, v in m.samples():
                out.append(f"{name}{_fmt_labels(key)} {_fmt_value(v)}")
        return "\n".join(out) + ("\n" if out else "")


def from_ledger(registry: MetricsRegistry, snapshot: dict) -> None:
    """Populate the standard trace-time gauge set from a
    ``ledger.snapshot()``.  Gauges (not counters) because a snapshot is
    already a total: re-exporting after a re-trace must overwrite, not
    double-count.  Every value reconciles with the snapshot exactly."""
    wire = registry.gauge("repro_wire_bytes",
                          "per-step collective wire bytes per chip")
    for kind, b in snapshot.get("wire_bytes", {}).items():
        wire.set(b, kind=kind)
    exp = registry.gauge("repro_exposed_bytes",
                         "wire bytes not hidden behind compute")
    for kind, b in snapshot.get("exposed_bytes", {}).items():
        exp.set(b, kind=kind)
    hid = registry.gauge("repro_hidden_bytes",
                         "wire bytes overlap-hidden behind compute")
    for kind, b in snapshot.get("hidden_bytes", {}).items():
        hid.set(b, kind=kind)
    calls = registry.gauge("repro_collective_launches",
                           "collective launches per step (trip-count "
                           "scaled)")
    for kind, c in snapshot.get("collective_calls", {}).items():
        calls.set(c, kind=kind)
    lvl = registry.gauge("repro_level_wire_bytes",
                         "wire bytes attributed to the topology level "
                         "(fabric) that carries them")
    for lk, kinds in snapshot.get("level_wire_bytes", {}).items():
        level, _, fabric = lk.partition("/")
        for kind, b in kinds.items():
            lvl.set(b, level=level, fabric=fabric, kind=kind)


def observe_timings(registry: MetricsRegistry, timings: list) -> int:
    """Fold measured per-collective samples into the run-time series:
    the ``repro_collective_seconds`` histogram plus per-(level, fabric)
    busy-time counters.  Returns the number of samples folded."""
    hist = registry.histogram("repro_collective_seconds",
                              "measured per-collective wall time")
    busy = registry.counter("repro_level_busy_seconds_total",
                            "cumulative measured collective seconds "
                            "per (level, fabric)")
    n = 0
    for t in timings:
        hist.observe(t["seconds"], primitive=t["primitive"],
                     backend=t["backend"],
                     level=t.get("level") or "-")
        busy.inc(t["seconds"] * max(1.0, t.get("calls", 1.0)),
                 level=t.get("level") or "-",
                 fabric=t.get("fabric") or "-")
        n += 1
    return n
