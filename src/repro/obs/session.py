"""One-stop observability wiring for the launchers.

``ObsSession`` bundles the three obs components behind the two CLI
flags every launcher exposes (``--metrics-out``, ``--trace-out``):

* a ``MetricsRegistry`` populated with run-time series (steps, step
  wall time, measured collective seconds, retune swaps, plan epoch,
  measured plan-cell regret) and, at ``finalize``, the trace-time
  ledger gauges;
* the flight-recorder tracer (enabled only when ``--trace-out`` is
  given - tracing off means zero hooks registered, zero overhead);
* a ``HealthMonitor`` whose degradation flags trigger an immediate
  flight-recorder dump, so the trace that led up to the anomaly is on
  disk even if the run dies next step.

Output layout: ``--metrics-out`` is a JSON-lines stream - one
``{"kind": "step"|"retune"|"health"|"metric"|"summary", ...}`` object
per line, written incrementally (step/retune/health events as they
happen, the full metric dump at finalize) - plus a Prometheus text
rendering of the final registry next to it (``<base>.prom``).
``--trace-out`` is a Chrome trace-event JSON openable in Perfetto.
``launch/report.py`` turns the JSON-lines file back into a human
step-time breakdown.
"""
from __future__ import annotations

import contextlib
import json
import os

from repro.obs import metrics as obs_metrics
from repro.obs import trace as obs_trace
from repro.obs.health import HealthMonitor


class ObsSession:
    """Launcher-facing facade over tracer + metrics + health monitor."""

    def __init__(self, *, metrics_out: "str | None" = None,
                 trace_out: "str | None" = None, trace_steps: int = 32,
                 health: bool = True, threshold: float = 2.0,
                 patience: int = 3, warmup_steps: int = 3,
                 log=print):
        self.metrics_out = metrics_out
        self.trace_out = trace_out
        self.enabled = bool(metrics_out or trace_out)
        self.log = log or (lambda *_: None)
        self.registry = obs_metrics.MetricsRegistry()
        if trace_out:
            self.tracer = obs_trace.enable_tracing(trace_steps)
        else:
            self.tracer = None
        self.monitor = HealthMonitor(
            registry=self.registry, threshold=threshold,
            patience=patience, warmup_steps=warmup_steps,
            on_degraded=self._on_health_event,
            on_recovered=self._on_health_event,
        ) if (self.enabled and health) else None
        self._jsonl = open(metrics_out, "w") if metrics_out else None
        self._finalized = False

    # -- tracing passthrough ---------------------------------------------

    def step_span(self, index: int):
        """Context manager bounding one step in the flight recorder."""
        if self.tracer is not None:
            return self.tracer.step(index)
        return contextlib.nullcontext()

    def span(self, name: str, **tags):
        if self.tracer is not None:
            return self.tracer.span(name, **tags)
        return contextlib.nullcontext()

    # -- event stream -----------------------------------------------------

    def _emit(self, obj: dict) -> None:
        if self._jsonl is not None:
            self._jsonl.write(json.dumps(obj, sort_keys=True) + "\n")
            self._jsonl.flush()

    def on_step(self, index: int, wall_s: float,
                timings: "list | None" = None,
                extra: "dict | None" = None) -> list:
        """Book one finished step: counters/histograms, the health
        monitor's step boundary, and a JSON-lines event.  ``timings``
        is the step's measured per-collective samples (pass ``None``
        when the run has no run-time timing source).  Returns the
        health transition events fired by this step."""
        if not self.enabled:
            return []
        self.registry.counter("repro_steps_total",
                              "steps completed").inc()
        self.registry.histogram("repro_step_seconds",
                                "step wall time").observe(float(wall_s))
        self.registry.gauge("repro_last_step_seconds",
                            "most recent step wall time").set(
                                float(wall_s))
        events: list = []
        if timings:
            obs_metrics.observe_timings(self.registry, timings)
        if self.monitor is not None and timings is not None:
            events = self.monitor.observe_step(timings, index)
        self._emit({"kind": "step", "step": int(index),
                    "wall_s": float(wall_s),
                    "timing_samples": len(timings or ()),
                    **(extra or {})})
        return events

    def diag(self, source: str, msg: str) -> None:
        """Book a diagnostic line: counted in the registry, persisted
        as a ``kind: diag`` event (``launch/report.py`` surfaces them),
        and echoed through the session log - the structured replacement
        for a launcher's bare ``print``."""
        self.log(f"[{source}] {msg}")
        if not self.enabled:
            return
        self.registry.counter(
            "repro_diag_total",
            "diagnostic lines emitted").inc(source=source)
        self._emit({"kind": "diag", "source": source, "msg": msg})

    def on_retune(self, *, epoch: int, swapped: bool,
                  regret_s: "float | None" = None,
                  measured_cells: "int | None" = None) -> None:
        """Book a retune boundary (whether or not the plan swapped)."""
        if not self.enabled:
            return
        self.registry.gauge("repro_plan_epoch",
                            "active-plan registry epoch").set(int(epoch))
        if swapped:
            self.registry.counter("repro_retune_swaps_total",
                                  "hot plan swaps applied").inc()
        if regret_s is not None:
            self.registry.gauge(
                "repro_plan_cell_regret_seconds",
                "sum over measured cells of chosen-minus-best "
                "measured EWMA time").set(float(regret_s))
        ev = {"kind": "retune", "epoch": int(epoch),
              "swapped": bool(swapped)}
        if regret_s is not None:
            ev["regret_s"] = float(regret_s)
        if measured_cells is not None:
            ev["measured_cells"] = int(measured_cells)
        self._emit(ev)

    def _on_health_event(self, ev: dict) -> None:
        self._emit({"kind": "health", **ev})
        self.log(f"[obs] link {ev['link']} {ev['event']} at step "
                 f"{ev['step']} (slowdown {ev['slowdown']:.2f}x)")
        if ev["event"] == "degraded" and self.tracer is not None:
            # Snapshot the flight recorder NOW: the trace leading up to
            # the degradation must survive even if the run dies.
            self.tracer.trigger(f"link {ev['link']} degraded "
                                f"{ev['slowdown']:.2f}x", self.trace_out)

    # -- teardown ---------------------------------------------------------

    def finalize(self, snapshot: "dict | None" = None,
                 extra: "dict | None" = None) -> dict:
        """Flush everything: fold the ledger snapshot into the gauges,
        dump the metric samples (JSON-lines + ``.prom``) and the flight
        recorder, detach hooks.  Idempotent."""
        if self._finalized:
            return {}
        self._finalized = True
        if not self.enabled:
            return {}
        if snapshot is not None:
            obs_metrics.from_ledger(self.registry, snapshot)
        summary = {"kind": "summary",
                   "degraded_links": (self.monitor.degraded_links()
                                      if self.monitor else []),
                   **(extra or {})}
        self._emit(summary)
        for m in self.registry.metrics():
            for name, key, v in m.samples():
                self._emit({"kind": "metric", "name": name,
                            "type": m.kind, "labels": dict(key),
                            "value": v})
        if self._jsonl is not None:
            self._jsonl.close()
            self._jsonl = None
            prom = os.path.splitext(self.metrics_out)[0] + ".prom"
            with open(prom, "w") as f:
                f.write(self.registry.to_prometheus())
            self.log(f"[obs] metrics: {self.metrics_out} (+ {prom})")
        if self.tracer is not None:
            self.tracer.dump(self.trace_out)
            obs_trace.disable_tracing()
            self.log(f"[obs] flight recorder: {self.trace_out} "
                     f"(steps {self.tracer.steps_retained()!r}, "
                     f"open in Perfetto)")
        return summary
