"""Runtime observability: tracing, profiling, metrics, link health.

The trace-time ledger (``core.ledger``) answers "what *should* this
step cost"; this package watches what it *does* cost and closes the
loop:

* ``obs.trace``   - span tracer + flight recorder (Chrome trace JSON);
* ``obs.profile`` - per-collective wall times from a ``jax.profiler``
  trace or the device-free ``StepEmulator``, keyed to plan cells;
* ``obs.metrics`` - counters/gauges/histograms exported as JSON-lines
  and Prometheus text;
* ``obs.health``  - per-(level, fabric) EWMA baselines flagging
  persistently slow links into metrics + the plan registry;
* ``obs.session`` - ``ObsSession``, the launcher facade behind
  ``--metrics-out`` / ``--trace-out``.

See docs/OBSERVABILITY.md for schemas and the degraded-link
walkthrough.
"""
from repro.obs.health import HealthMonitor, calibration_drift
from repro.obs.metrics import (Counter, Gauge, Histogram,
                               MetricsRegistry, from_ledger)
from repro.obs.profile import StepEmulator, profiled_timings, trace_timings
from repro.obs.session import ObsSession
from repro.obs.trace import (Tracer, disable_tracing, enable_tracing,
                             get_tracer)

__all__ = [
    "Counter", "Gauge", "Histogram", "MetricsRegistry", "from_ledger",
    "Tracer", "enable_tracing", "disable_tracing", "get_tracer",
    "StepEmulator", "profiled_timings", "trace_timings",
    "HealthMonitor", "calibration_drift",
    "ObsSession",
]
