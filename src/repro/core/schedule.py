"""Collective -> transfer-schedule compiler (paper Sec. 4.3-4.5).

Every collective primitive is compiled into an explicit, ordered list of
pool *writes* (the publish phase) and pool *reads* (the retrieve phase) per
rank.  The placement of each block follows the interleaving math of
Sec. 4.3; the issue order follows the rotation rule ("start from
``(rank_id+1) % nranks``"); each chunk carries a doorbell index.

The same schedule drives three consumers:

* ``core.collectives`` executes it functionally against an in-memory pool
  (correctness oracle for the address math);
* ``core.simulator`` timestamps it under the pool's bandwidth/latency model
  (reproduces the paper's throughput numbers);
* ``core.mesh_collectives`` realizes the equivalent read rotation as chunked
  ``lax.ppermute`` rounds on a TPU mesh (the deployable path).
"""
from __future__ import annotations

import dataclasses
import enum
from typing import Optional

from repro.core import chunking
from repro.core.doorbell import DOORBELL_BYTES
from repro.core.interleave import (PoolLayout, Placement, publish_order,
                                   rank_partitioned, round_robin)

PRIMITIVES = ("broadcast", "scatter", "gather", "reduce", "all_gather",
              "reduce_scatter", "all_reduce", "all_to_all")

# Paper Table 2 taxonomy: type (1) rooted collectives use round-robin
# striping over ALL devices; type (2) N->N collectives use rank-partitioned
# device ownership (Eq. 4).
ROOTED = ("broadcast", "scatter", "gather", "reduce")
N_TO_N = ("all_gather", "reduce_scatter", "all_reduce", "all_to_all")


class OpKind(enum.Enum):
    WRITE = "write"
    READ = "read"


@dataclasses.dataclass(frozen=True)
class TransferOp:
    """One pool transfer (a single cudaMemcpyAsync in the paper's terms)."""

    kind: OpKind
    rank: int                     # issuing rank
    device: int                   # CXL device touched
    pool_offset: int              # byte address within the unified pool space
    buf_offset: int               # byte offset within the local send/recv buf
    size: int                     # bytes
    doorbell: int                 # doorbell index guarding this chunk
    data_key: tuple               # (producer, segment, chunk) identity
    producer: int                 # rank that publishes this data
    reduce: bool = False          # read feeds a reduction (+=) not a copy


@dataclasses.dataclass
class Schedule:
    primitive: str
    nranks: int
    msg_bytes: int                # N in Table 2 (bytes per rank)
    layout: PoolLayout
    root: Optional[int]
    slicing_factor: int
    writes: dict[int, list[TransferOp]]   # rank -> ordered writeStream ops
    reads: dict[int, list[TransferOp]]    # rank -> ordered readStream ops
    num_doorbells: int

    def all_writes(self) -> list[TransferOp]:
        return [op for r in sorted(self.writes) for op in self.writes[r]]

    def all_reads(self) -> list[TransferOp]:
        return [op for r in sorted(self.reads) for op in self.reads[r]]

    def write_for(self, data_key: tuple) -> TransferOp:
        for ops in self.writes.values():
            for op in ops:
                if op.data_key == data_key:
                    return op
        raise KeyError(data_key)


class _Builder:
    """Accumulates ops while tracking per-rank write-issue counters (the
    counter doubles as ``data_id`` so consecutive publications round-robin
    across the rank's devices, cf. Fig. 6).

    ``placement='naive'`` models the CXL-CCL-Naive baseline (Sec. 5.1):
    memory is allocated sequentially from the bottom of the pool, so all
    traffic converges on device 0 (the hot-spot the interleaving removes).
    """

    def __init__(self, primitive: str, nranks: int, msg_bytes: int,
                 layout: PoolLayout, root: Optional[int],
                 slicing_factor: int, placement: str = "interleaved"):
        self.placement = placement
        self._naive_cursor = 0
        self.primitive = primitive
        self.nranks = nranks
        self.msg_bytes = msg_bytes
        self.layout = layout
        self.root = root
        self.slicing_factor = slicing_factor
        self.writes: dict[int, list[TransferOp]] = {r: [] for r in
                                                    range(nranks)}
        self.reads: dict[int, list[TransferOp]] = {r: [] for r in
                                                   range(nranks)}
        self._write_counter: dict[int, int] = {r: 0 for r in range(nranks)}
        self._placements: dict[tuple, Placement] = {}
        # Static per-rank write bound: at most one (segment, chunk) pair per
        # peer; used to stripe doorbell slots disjointly across ranks.
        self.max_writes_per_rank = 0  # set by build() before op emission

    def place(self, writer: int, rooted: bool,
              data_id: int | None = None,
              size: int | None = None) -> Placement:
        if data_id is None:
            data_id = self._write_counter[writer]
        self._write_counter[writer] += 1
        if self.placement == "naive":
            # Sequential allocation from the bottom of the pool: ignores
            # devices entirely, exactly what hardware would do without an
            # explicit placement mechanism (Sec. 4.2 challenge 1).
            off = self.layout.doorbell_region + self._naive_cursor
            self._naive_cursor += size if size is not None else \
                self.layout.block_size
            dev = off // self.layout.device_capacity
            return Placement(dev, data_id, off, doorbell_index=data_id)
        if rooted:
            return round_robin(self.layout, data_id)
        return rank_partitioned(self.layout, writer, self.nranks, data_id)

    def write(self, writer: int, buf_offset: int, size: int,
              data_key: tuple, rooted: bool,
              data_id: int | None = None) -> None:
        pl = self.place(writer, rooted, data_id, size=size)
        # Compact, statically computable doorbell slot: the builder knows
        # the per-rank write bound, so rooted placements use the global
        # data_id and partitioned ones get a per-rank stripe.
        if rooted:
            doorbell = pl.doorbell_index
        else:
            doorbell = writer * self.max_writes_per_rank + pl.doorbell_index
        pl = dataclasses.replace(pl, doorbell_index=doorbell)
        self._placements[data_key] = pl
        self.writes[writer].append(TransferOp(
            kind=OpKind.WRITE, rank=writer, device=pl.device_index,
            pool_offset=pl.device_location, buf_offset=buf_offset,
            size=size, doorbell=pl.doorbell_index, data_key=data_key,
            producer=writer))

    def read(self, reader: int, data_key: tuple, buf_offset: int,
             reduce: bool = False) -> None:
        pl = self._placements[data_key]
        producer = data_key[0]
        self.reads[reader].append(TransferOp(
            kind=OpKind.READ, rank=reader, device=pl.device_index,
            pool_offset=pl.device_location, buf_offset=buf_offset,
            size=self._size_of(data_key), doorbell=pl.doorbell_index,
            data_key=data_key, producer=producer, reduce=reduce))

    def _size_of(self, data_key: tuple) -> int:
        for ops in self.writes.values():
            for op in ops:
                if op.data_key == data_key:
                    return op.size
        raise KeyError(data_key)

    def finish(self) -> Schedule:
        dbs = max((op.doorbell for ops in self.writes.values()
                   for op in ops), default=0) + 1
        return Schedule(self.primitive, self.nranks, self.msg_bytes,
                        self.layout, self.root, self.slicing_factor,
                        self.writes, self.reads, num_doorbells=dbs)


def make_layout(num_devices: int, device_capacity: int, block_size: int,
                num_doorbells: int) -> PoolLayout:
    db_region = num_doorbells * DOORBELL_BYTES
    # Align the data region start to the block size for tidy addresses.
    db_region = (db_region + block_size - 1) // block_size * block_size
    return PoolLayout(num_devices=num_devices,
                      device_capacity=device_capacity,
                      doorbell_region=db_region, block_size=block_size)


def build(primitive: str, nranks: int, msg_bytes: int, *,
          num_devices: int = 6, device_capacity: int = 128 * 1024**3,
          slicing_factor: int = chunking.DEFAULT_SLICING_FACTOR,
          root: int = 0, granularity: int = 1,
          clamp_chunks: bool = True,
          placement: str = "interleaved") -> Schedule:
    """Compile ``primitive`` into a pool transfer schedule.

    ``msg_bytes`` follows Table 2's ``N``: the per-rank send size for all
    primitives except scatter, where the root holds ``N * nranks`` and each
    rank receives ``N``.
    """
    if primitive not in PRIMITIVES:
        raise ValueError(f"unknown primitive {primitive!r}")
    if nranks < 1:
        raise ValueError("nranks must be >= 1")
    if msg_bytes <= 0:
        raise ValueError("msg_bytes must be positive")

    rooted = primitive in ROOTED
    seg_bytes = msg_bytes // nranks if primitive in (
        "reduce_scatter", "all_to_all") else msg_bytes
    if primitive in ("reduce_scatter", "all_to_all"):
        if msg_bytes % nranks:
            raise ValueError(
                f"{primitive} needs msg_bytes divisible by nranks")
    chunks = chunking.split(seg_bytes, slicing_factor, clamp=clamp_chunks,
                            granularity=granularity)
    block_size = max(c.size for c in chunks)

    # Upper bound on doorbells: every (rank, segment, chunk) written.
    max_writes = nranks * nranks * len(chunks)
    layout = make_layout(num_devices, device_capacity, block_size,
                         num_doorbells=max_writes)
    b = _Builder(primitive, nranks, msg_bytes, layout, root if rooted else
                 None, slicing_factor, placement=placement)
    b.max_writes_per_rank = nranks * len(chunks)

    if primitive == "broadcast":
        _broadcast(b, chunks, root)
    elif primitive == "scatter":
        _scatter(b, chunks, root)
    elif primitive in ("gather", "reduce"):
        _gather(b, chunks, root, reduce=(primitive == "reduce"))
    elif primitive in ("all_gather", "all_reduce"):
        _all_gather(b, chunks, reduce=(primitive == "all_reduce"))
    elif primitive in ("reduce_scatter", "all_to_all"):
        _segmented_n_to_n(b, chunks,
                          reduce=(primitive == "reduce_scatter"))
    return b.finish()


def _broadcast(b: _Builder, chunks, root: int) -> None:
    """Root stripes its buffer over all devices (Eq. 1-3); every other rank
    reads all chunks, rotating its start offset so concurrent readers hit
    disjoint devices."""
    for c in chunks:
        b.write(root, c.offset, c.size, (root, 0, c.index), rooted=True)
    n = len(chunks)
    for r in range(b.nranks):
        if r == root:
            continue
        for i in range(n):
            c = chunks[(r + i) % n]
            b.read(r, (root, 0, c.index), c.offset)


def _scatter(b: _Builder, chunks, root: int) -> None:
    """Root writes one segment per destination rank, segments striped
    round-robin; rank i reads only segment i."""
    seg = b.msg_bytes
    order = publish_order(root, b.nranks)  # rotate segment publication
    for dest in order:
        if dest == root:
            continue  # root's own segment never travels through the pool
        for c in chunks:
            b.write(root, dest * seg + c.offset, c.size,
                    (root, dest, c.index), rooted=True)
    for r in range(b.nranks):
        if r == root:
            continue
        for c in chunks:
            b.read(r, (root, r, c.index), c.offset)
    # Root's own segment never travels through the pool (local copy).


def _gather(b: _Builder, chunks, root: int, reduce: bool) -> None:
    """Each non-root rank publishes its buffer; the root reads producers in
    rotated order.  For reduce, reads accumulate into the root's buffer.

    N->1 has many concurrent writers even though it is a "rooted" type, so
    the logical ``data_id`` is globalized as ``rank*F + chunk``: producers
    land on distinct devices (Eq. 1) instead of colliding on device 0."""
    nf = len(chunks)
    for r in range(b.nranks):
        if r == root:
            continue
        for c in chunks:
            b.write(r, c.offset, c.size, (r, 0, c.index), rooted=True,
                    data_id=r * nf + c.index)
    for p in publish_order(root, b.nranks):
        if p == root:
            continue
        for c in chunks:
            dst = c.offset if reduce else p * b.msg_bytes + c.offset
            b.read(root, (p, 0, c.index), dst, reduce=reduce)


def _all_gather(b: _Builder, chunks, reduce: bool) -> None:
    """N->N full-buffer exchange.  Writers stay inside their own device
    partition (Eq. 4); reader r pulls producers in ``publish_order(r)`` so
    reads rotate away from concurrent writes (Fig. 6).  ``reduce=True``
    turns this into the paper's AllReduce: every rank reduces everything
    locally (no partial-result reuse - Sec. 5.2)."""
    for r in range(b.nranks):
        for c in chunks:
            b.write(r, c.offset, c.size, (r, 0, c.index), rooted=False)
    for r in range(b.nranks):
        for p in publish_order(r, b.nranks):
            if p == r:
                continue
            for c in chunks:
                dst = c.offset if reduce else p * b.msg_bytes + c.offset
                b.read(r, (p, 0, c.index), dst, reduce=reduce)


def _segmented_n_to_n(b: _Builder, chunks, reduce: bool) -> None:
    """ReduceScatter / AllToAll: rank r publishes segment ``dest`` of its
    send buffer for every other rank, starting from ``(r+1) % nranks``
    (Fig. 6); then reads its own segment from every producer."""
    seg = b.msg_bytes // b.nranks
    for r in range(b.nranks):
        for dest in publish_order(r, b.nranks):
            if dest == r:
                continue  # own segment stays local
            for c in chunks:
                b.write(r, dest * seg + c.offset, c.size,
                        (r, dest, c.index), rooted=False)
    for r in range(b.nranks):
        for p in publish_order(r, b.nranks):
            if p == r:
                continue
            for c in chunks:
                dst = c.offset if reduce else p * seg + c.offset
                b.read(r, (p, r, c.index), dst, reduce=reduce)
