"""First-class cluster topology: ordered axis levels, one fabric each.

The paper expects a single CXL pool to span only a handful of nodes
(Sec. 5.3), so a production deployment is necessarily hierarchical:
rack-scale CXL pools stitched together over IB/Ethernet, with an
intra-node ring (ICI/NVLink-class) underneath.  A ``Topology`` makes
that explicit instead of letting one global ``CXLPoolConfig`` price
every level:

* each mesh axis binds to a ``Level`` with a fabric kind - ``cxl``
  (its own ``CXLPoolConfig`` + the IB config of the alternative
  transport the tuner compares against), ``ib`` (its own
  ``InfiniBandConfig``) or ``ici`` (its own ``ICIConfig``);
* levels are ordered outermost first, matching the repo's tuple-axis
  convention (``("pod", "node", "gpu")`` = rank-major, pod most
  significant);
* every level has a stable ``fingerprint()`` (hash of fabric kind +
  config + shape) so tuner plan cells can be keyed by (level, fabric)
  and a plan tuned for one fabric never silently drives another;
* a level may carry a **shape vector** instead of a single radix
  (``shape=(4, 2)``: the first outer group spans 4 ranks, the second
  2).  Irregular (mixed fan-out) levels cannot be a regular mesh axis
  of their own; they live on one *flat* mesh axis of ``sum(shape)``
  ranks, and the Communicator decomposes collectives over that axis
  into within-group schedules on this level's fabric plus a sub-root
  exchange on the *parent* level's fabric
  (``core.mesh_collectives`` grouped/ragged schedules).

Spec formats (CLI ``--topology`` accepts either):

* compact string: ``"pod:ib,node:cxl,gpu:ici"``; an optional third
  field declares the level shape - ``"node:cxl:4+2"`` (irregular
  fan-out) or ``"gpu:ici:8"`` (declared size, single group);
* JSON file: ``{"levels": [{"axis": "pod", "fabric": "ib",
  "ib": {"link_bw": 5e10}}, {"axis": "node", "fabric": "cxl",
  "shape": [4, 2]}, ...]}`` where the per-fabric objects
  override individual ``hw`` dataclass fields.

The process-wide active topology (``set_active_topology``) is what a
``Communicator`` without an explicit ``topology=`` falls back to, so
launchers can activate one once and every collective in the traced
program decomposes against it.
"""
from __future__ import annotations

import dataclasses
import hashlib
import json
import os
import warnings
from typing import Optional, Sequence

from repro.core.hw import (CXL_POOL, ICI, INFINIBAND, CXLPoolConfig,
                           ICIConfig, InfiniBandConfig)

FABRICS = ("cxl", "ib", "ici")


def _cfg_fingerprint(tag: str, cfg) -> str:
    blob = json.dumps({tag: dataclasses.asdict(cfg)}, sort_keys=True)
    return hashlib.sha256(blob.encode()).hexdigest()


@dataclasses.dataclass(frozen=True)
class Level:
    """One axis of the hierarchy bound to the fabric that carries it."""

    axis: str
    fabric: str = "cxl"
    # Per-level hardware overrides; None = the repo-wide defaults.
    pool: Optional[CXLPoolConfig] = None   # cxl levels
    ib: Optional[InfiniBandConfig] = None  # ib levels; also the
    #                                        alternative transport the
    #                                        tuner prices cxl against
    ici: Optional[ICIConfig] = None        # ici levels
    # Shape vector: per-outer-group fan-out of this level.  None means
    # the level is regular with its size taken from the mesh axis;
    # ``(6,)`` declares the size (one group of 6); ``(4, 2)`` declares
    # an irregular level - two groups under the parent level, one of 4
    # ranks and one of 2, carried by a single flat mesh axis of 6.
    shape: Optional[tuple] = None

    def __post_init__(self):
        if self.fabric not in FABRICS:
            raise ValueError(
                f"level {self.axis!r}: fabric must be one of {FABRICS}, "
                f"got {self.fabric!r}")
        if self.shape is not None:
            shape = tuple(int(g) for g in self.shape)
            if not shape or any(g < 1 for g in shape):
                raise ValueError(
                    f"level {self.axis!r}: shape must be a non-empty "
                    f"vector of positive group sizes, got {self.shape!r}")
            object.__setattr__(self, "shape", shape)

    @property
    def size(self) -> Optional[int]:
        """Total ranks this level spans (None when undeclared)."""
        return sum(self.shape) if self.shape is not None else None

    @property
    def grouped(self) -> bool:
        """True when the level decomposes a flat mesh axis into more
        than one rank group (the ragged/hierarchical-on-one-axis case)."""
        return self.shape is not None and len(self.shape) > 1

    @property
    def irregular(self) -> bool:
        """True when the level's groups have mixed fan-out."""
        return self.grouped and len(set(self.shape)) > 1

    @property
    def pool_cfg(self) -> CXLPoolConfig:
        return self.pool if self.pool is not None else CXL_POOL

    @property
    def ib_cfg(self) -> InfiniBandConfig:
        return self.ib if self.ib is not None else INFINIBAND

    @property
    def ici_cfg(self) -> ICIConfig:
        return self.ici if self.ici is not None else ICI

    def backends(self) -> tuple:
        """Backends executable on this fabric: the pool schedule only
        exists where there is a pool."""
        return ("ring", "cxl") if self.fabric == "cxl" else ("ring",)

    def fingerprint(self) -> str:
        if self.fabric == "cxl":
            blob = (_cfg_fingerprint("pool", self.pool_cfg)
                    + _cfg_fingerprint("ib", self.ib_cfg))
        elif self.fabric == "ib":
            blob = _cfg_fingerprint("ib", self.ib_cfg)
        else:
            blob = _cfg_fingerprint("ici", self.ici_cfg)
        # The shape vector is part of the hardware identity: plan cells
        # tuned for a 4+2 level must not drive a 3+3 one.  Shapeless
        # levels keep their pre-shape fingerprints (old plans load).
        tag = self.fabric
        if self.shape is not None:
            tag += "[" + "+".join(str(g) for g in self.shape) + "]"
        return hashlib.sha256(
            (tag + ":" + blob).encode()).hexdigest()[:12]

    # -- serialization ----------------------------------------------------

    def to_json(self) -> dict:
        doc: dict = {"axis": self.axis, "fabric": self.fabric}
        if self.shape is not None:
            doc["shape"] = list(self.shape)
        for name in ("pool", "ib", "ici"):
            cfg = getattr(self, name)
            if cfg is not None:
                doc[name] = dataclasses.asdict(cfg)
        return doc

    @classmethod
    def from_json(cls, doc: dict) -> "Level":
        kw: dict = {}
        if doc.get("shape") is not None:
            kw["shape"] = tuple(int(g) for g in doc["shape"])
        for name, klass in (("pool", CXLPoolConfig),
                            ("ib", InfiniBandConfig), ("ici", ICIConfig)):
            if doc.get(name) is not None:
                kw[name] = klass(**doc[name])
        return cls(axis=doc["axis"], fabric=doc.get("fabric", "cxl"),
                   **kw)


@dataclasses.dataclass(frozen=True)
class Topology:
    """Ordered (outermost first) levels of the cluster hierarchy."""

    levels: tuple = ()

    def __post_init__(self):
        if not self.levels:
            raise ValueError("a Topology needs at least one level")
        object.__setattr__(self, "levels", tuple(self.levels))
        axes = [lv.axis for lv in self.levels]
        if len(set(axes)) != len(axes):
            raise ValueError(f"duplicate axes in topology: {axes}")

    @property
    def axes(self) -> tuple:
        return tuple(lv.axis for lv in self.levels)

    def level_for(self, axis: str) -> Optional[Level]:
        for lv in self.levels:
            if lv.axis == axis:
                return lv
        return None

    def index_of(self, axis: str) -> int:
        for i, lv in enumerate(self.levels):
            if lv.axis == axis:
                return i
        raise KeyError(axis)

    def parent_of(self, axis: str) -> Optional[Level]:
        """The level immediately outside ``axis`` (None at the
        outermost).  For a grouped level this is the fabric the
        cross-group sub-root exchange rides - e.g. a ``node`` level
        with ``shape=(4, 2)`` under a ``pod:ib`` level sends its two
        pod sums across IB."""
        i = self.index_of(axis)
        return self.levels[i - 1] if i > 0 else None

    def covers(self, axes: Sequence[str]) -> bool:
        return all(self.level_for(a) is not None for a in axes)

    def level_key(self, axis: str) -> str:
        """Stable plan-cell key for a level: ``"<index>:<fabric fp>"``.
        The index pins the position in the hierarchy, the fingerprint
        pins the fabric hardware."""
        i = self.index_of(axis)
        return f"{i}:{self.levels[i].fingerprint()}"

    def fingerprint(self) -> str:
        """Hash of the ordered level fingerprints.  Deliberately
        *excludes* axis names: a placement relabels levels with the
        logical mesh axes it assigned to them (``tuner.placement``),
        and a plan tuned against the physical topology must keep
        matching the relabeled one - the hardware did not change.
        (Pre-PR-5 fingerprints hashed the axis names too, so plans
        cached by the old scheme regenerate once.)"""
        blob = "|".join(f"{i}={lv.fingerprint()}"
                        for i, lv in enumerate(self.levels))
        return hashlib.sha256(blob.encode()).hexdigest()[:16]

    # -- serialization ----------------------------------------------------

    def to_json(self) -> dict:
        return {"levels": [lv.to_json() for lv in self.levels]}

    @classmethod
    def from_json(cls, doc: dict) -> "Topology":
        return cls(levels=tuple(Level.from_json(d)
                                for d in doc["levels"]))


def parse_topology(spec: str) -> Topology:
    """Parse a CLI topology spec: a JSON file path or the compact
    ``"axis:fabric[:shape],..."`` string (outermost level first).
    The optional shape field is ``+``-separated group sizes:
    ``"node:cxl:4+2"`` declares an irregular level of two groups
    (4 and 2 ranks), ``"gpu:ici:8"`` just declares the size."""
    if os.path.exists(spec) or spec.endswith(".json"):
        with open(spec) as f:
            return Topology.from_json(json.load(f))
    levels = []
    for part in spec.split(","):
        part = part.strip()
        if not part:
            continue
        fields = [p.strip() for p in part.split(":")]
        axis = fields[0]
        fabric = fields[1] if len(fields) > 1 and fields[1] else "cxl"
        shape = None
        if len(fields) > 2 and fields[2]:
            shape = tuple(int(g) for g in fields[2].split("+"))
        levels.append(Level(axis=axis, fabric=fabric, shape=shape))
    return Topology(levels=tuple(levels))


def save_topology(topo: Topology, path: str) -> None:
    d = os.path.dirname(os.path.abspath(path))
    os.makedirs(d, exist_ok=True)
    with open(path, "w") as f:
        json.dump(topo.to_json(), f, indent=1, sort_keys=True)


def default_topology(axis_names: Sequence[str]) -> Topology:
    """The production default for a mesh: the outermost axis rides IB
    across pods, intermediate axes ride rack-scale CXL pools, and the
    innermost axis is the intra-node ring."""
    names = list(axis_names)
    levels = []
    for i, a in enumerate(names):
        if len(names) == 1:
            fabric = "cxl"                     # the paper's base case
        elif i == len(names) - 1:
            fabric = "ici"
        elif i == 0 and len(names) >= 3:
            fabric = "ib"
        else:
            fabric = "cxl"
        levels.append(Level(axis=a, fabric=fabric))
    return Topology(levels=tuple(levels))


def warn_uncovered(topo: Topology, mesh) -> tuple:
    """Warn when mesh axes (of size > 1) have no topology level: their
    collectives silently resolve untuned (ring baseline), which is
    almost always a topology spec whose axis names don't match the
    mesh (e.g. tuning ``node``/``gpu`` while the mesh says
    ``data``/``model``).  Returns the uncovered axes."""
    missing = tuple(a for a in mesh.axis_names
                    if mesh.shape[a] > 1 and topo.level_for(a) is None)
    if missing:
        warnings.warn(
            f"mesh axes {missing} are not covered by the active "
            f"topology (levels: {topo.axes}); collectives over them "
            f"fall back to the untuned flat path - check the "
            f"--topology axis names against the mesh")
    return missing


# -- process-wide active topology -----------------------------------------
# Mirrors the tuner's active-plan registry: launchers activate one, and
# any Communicator without an explicit ``topology=`` decomposes against
# it at trace time.

_ACTIVE: list = [None]


def set_active_topology(topo: Optional[Topology]) -> None:
    _ACTIVE[0] = topo


def get_active_topology() -> Optional[Topology]:
    return _ACTIVE[0]


def clear_active_topology() -> None:
    _ACTIVE[0] = None
