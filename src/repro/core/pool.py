"""Functional CXL-pool emulation (correctness path).

Executes a ``core.schedule.Schedule`` against an in-memory byte pool,
enforcing the doorbell protocol: a read may only proceed once the producer
has rung the chunk's doorbell.  Streams are interleaved round-robin one op
at a time, which models the concurrent publish/retrieve overlap of
Sec. 4.4 and catches ordering bugs (a read whose doorbell never rings is a
deadlock and raises).

This is the oracle for the placement math: tests assert (a) no two writes
overlap in the pool address space, (b) N->N writers never touch another
rank's device partition, and (c) the collective's result matches the pure
``jax.lax``/numpy reference.
"""
from __future__ import annotations

import time
from typing import Any, Callable, Optional

import numpy as np

from repro.core import schedule as sched
from repro.core.doorbell import DoorbellRegion
from repro.core.interleave import PoolLayout


class PoolAccessError(RuntimeError):
    """A pool-side load/store (or doorbell/heartbeat word) failed.

    Raised by an installed fault hook to model the unhappy path of the
    pooled fabric: a dead rank whose writes never land, a CXL port
    returning poisoned reads, a transient timeout.  Collective and
    checkpoint paths decide per-site whether the error is retryable
    (``with_retries``) or a confirmed failure for the monitor.
    """


# Module-level fault hook: ``hook(op, info)`` is consulted before every
# emulated pool access and raises PoolAccessError to inject a failure.
# One slot (not a list of hooks): fault injection composes inside a
# FaultPlan, not by stacking hooks.
_FAULT_HOOK: list[Optional[Callable[[str, dict], None]]] = [None]


def set_fault_hook(hook: Callable[[str, dict], None]) -> None:
    """Install ``hook(op, info)``; it raises ``PoolAccessError`` to
    inject a failure at that access.  ``op`` names the access kind
    ("write" / "read" / "heartbeat" / "ckpt_write" / ...), ``info``
    carries at least the acting ``rank`` where known."""
    _FAULT_HOOK[0] = hook


def clear_fault_hook() -> None:
    _FAULT_HOOK[0] = None


def get_fault_hook() -> Optional[Callable[[str, dict], None]]:
    return _FAULT_HOOK[0]


def check_fault(op: str, **info: Any) -> None:
    """Consult the installed fault hook (no-op when none is set)."""
    hook = _FAULT_HOOK[0]
    if hook is not None:
        hook(op, info)


def with_retries(fn: Callable[[], Any], *, retries: int = 3,
                 backoff_s: float = 0.0,
                 sleep: Callable[[float], None] = time.sleep,
                 on_retry: Optional[Callable[[int, Exception], None]] = None,
                 ) -> Any:
    """Run ``fn`` with bounded retry-with-exponential-backoff on
    ``PoolAccessError``.  Transient pool faults (the kind a real fabric
    shrugs off with a replayed transaction) are absorbed here; a fault
    that persists past ``retries`` attempts re-raises for the failure
    monitor to confirm.  ``sleep`` is injectable so tests and the
    emulated step loop never actually block."""
    attempt = 0
    while True:
        try:
            return fn()
        except PoolAccessError as exc:
            attempt += 1
            if attempt > retries:
                raise
            if on_retry is not None:
                on_retry(attempt, exc)
            if backoff_s > 0.0:
                sleep(backoff_s * (2 ** (attempt - 1)))


class PoolBlockAllocator:
    """Fixed-size block allocator over a region of pool memory.

    The serving KV-cache tier (``repro.serving.kvcache``) stores evicted
    and prefix-shared cache pages here: ``capacity_bytes`` of emulated
    pool memory split into equal ``block_bytes`` blocks, handed out from
    a free list by pure index calculation (no metadata in the pool, in
    the spirit of the paper's allocator-free doorbell addressing).
    Block payload I/O goes through the module fault shim
    (``check_fault``) with bounded retry-with-backoff, exactly like
    ``training.checkpoint.PoolCheckpointStore``, so injected pool
    faults surface where a real CXL load/store would fail.

    ``predict_write_s``/``predict_read_s`` price one block transfer with
    the pool cost model (per-copy software overhead + bytes over the
    pool server link) - the same numbers the tuner's oracles use for
    wire traffic - so cache-placement decisions can be costed against
    recompute before any byte moves.
    """

    def __init__(self, capacity_bytes: int, block_bytes: int,
                 cfg: Optional["CXLPoolConfig"] = None, *,
                 retries: int = 3, backoff_s: float = 0.0,
                 sleep: Callable[[float], None] = lambda _s: None):
        from repro.core.hw import CXL_POOL
        if block_bytes <= 0:
            raise ValueError("block_bytes must be positive")
        self.block_bytes = int(block_bytes)
        self.num_blocks = int(capacity_bytes) // self.block_bytes
        if self.num_blocks <= 0:
            raise ValueError(
                f"pool capacity {capacity_bytes} holds no "
                f"{block_bytes}-byte block")
        self.cfg = cfg or CXL_POOL
        self.retries = retries
        self.backoff_s = backoff_s
        self.sleep = sleep
        self._mem = np.zeros(self.num_blocks * self.block_bytes,
                             dtype=np.uint8)
        self._free: list[int] = list(range(self.num_blocks - 1, -1, -1))
        # Telemetry for tests / metrics export.
        self.writes = 0
        self.reads = 0
        self.retried = 0

    # -- addressing (pure index calculation) ------------------------------
    def offset(self, block: int) -> int:
        self._check(block)
        return block * self.block_bytes

    @property
    def free_blocks(self) -> int:
        return len(self._free)

    @property
    def used_blocks(self) -> int:
        return self.num_blocks - len(self._free)

    def alloc(self, n: int = 1) -> list[int]:
        """Allocate ``n`` blocks; raises ``MemoryError`` when the pool
        budget is exhausted (callers decide whether to evict or fail)."""
        if n > len(self._free):
            raise MemoryError(
                f"pool block budget exhausted: want {n}, "
                f"{len(self._free)}/{self.num_blocks} free")
        return [self._free.pop() for _ in range(n)]

    def free(self, blocks: list[int]) -> None:
        for b in blocks:
            self._check(b)
            if b in self._free:
                raise ValueError(f"double free of pool block {b}")
            self._free.append(b)

    # -- payload I/O through the fault shim -------------------------------
    def write_block(self, block: int, data: bytes, *,
                    rank: int = 0) -> None:
        if len(data) > self.block_bytes:
            raise ValueError(
                f"payload {len(data)} bytes > block {self.block_bytes}")
        off = self.offset(block)

        def attempt() -> None:
            check_fault("kv_write", rank=rank, offset=off,
                        size=len(data))
            self._mem[off:off + len(data)] = np.frombuffer(
                data, dtype=np.uint8)

        def note(_attempt: int, _exc: Exception) -> None:
            self.retried += 1

        with_retries(attempt, retries=self.retries,
                     backoff_s=self.backoff_s, sleep=self.sleep,
                     on_retry=note)
        self.writes += 1

    def read_block(self, block: int, nbytes: Optional[int] = None, *,
                   rank: int = 0) -> bytes:
        nbytes = self.block_bytes if nbytes is None else int(nbytes)
        off = self.offset(block)

        def attempt() -> bytes:
            check_fault("kv_read", rank=rank, offset=off, size=nbytes)
            return bytes(self._mem[off:off + nbytes])

        def note(_attempt: int, _exc: Exception) -> None:
            self.retried += 1

        out = with_retries(attempt, retries=self.retries,
                           backoff_s=self.backoff_s, sleep=self.sleep,
                           on_retry=note)
        self.reads += 1
        return out

    # -- cost model -------------------------------------------------------
    def predict_write_s(self, nbytes: Optional[int] = None) -> float:
        """One block write: per-copy software overhead + bytes over the
        pool server link (same constants as the tuner's pool oracle)."""
        n = self.block_bytes if nbytes is None else int(nbytes)
        return self.cfg.memcpy_overhead + n / self.cfg.server_bw

    def predict_read_s(self, nbytes: Optional[int] = None) -> float:
        n = self.block_bytes if nbytes is None else int(nbytes)
        return (self.cfg.memcpy_overhead + n / self.cfg.server_bw
                + self.cfg.access_latency)

    def _check(self, block: int) -> None:
        if not 0 <= block < self.num_blocks:
            raise IndexError(
                f"pool block {block} out of range [0, {self.num_blocks})")


class PoolEmulator:
    """A byte-addressable emulation of the unified pool address space."""

    def __init__(self, layout: PoolLayout, num_doorbells: int):
        self.layout = layout
        total = layout.num_devices * layout.device_capacity
        self.pool = np.zeros(total, dtype=np.uint8)
        self.doorbells = DoorbellRegion(num_doorbells)
        # (offset, size) of every write, for overlap auditing.
        self.write_log: list[tuple[int, int, int]] = []  # (rank, off, size)

    def device_of(self, pool_offset: int) -> int:
        return pool_offset // self.layout.device_capacity

    def write(self, op: sched.TransferOp, src: np.ndarray) -> None:
        assert op.kind is sched.OpKind.WRITE
        check_fault("write", rank=op.rank, offset=op.pool_offset,
                    size=op.size)
        data = src[op.buf_offset:op.buf_offset + op.size]
        if self.device_of(op.pool_offset) != op.device:
            raise AssertionError(
                f"placement bug: offset {op.pool_offset} not on device "
                f"{op.device}")
        self.pool[op.pool_offset:op.pool_offset + op.size] = data
        self.write_log.append((op.rank, op.pool_offset, op.size))
        self.doorbells.ring(op.doorbell)

    def try_read(self, op: sched.TransferOp, dst: np.ndarray,
                 dtype: np.dtype) -> bool:
        """Attempt the read; returns False if the doorbell is still STALE."""
        assert op.kind is sched.OpKind.READ
        check_fault("read", rank=op.rank, offset=op.pool_offset,
                    size=op.size)
        if not self.doorbells.is_ready(op.doorbell):
            return False
        chunk = self.pool[op.pool_offset:op.pool_offset + op.size]
        view = dst[op.buf_offset:op.buf_offset + op.size]
        if op.reduce:
            acc = view.view(dtype)
            acc += chunk.view(dtype)
        else:
            view[:] = chunk
        return True

    def audit_writes(self) -> None:
        """Assert no two writes overlapped in the pool address space."""
        spans = sorted((off, off + size, rank)
                       for rank, off, size in self.write_log)
        for (s0, e0, r0), (s1, e1, r1) in zip(spans, spans[1:]):
            if s1 < e0:
                raise AssertionError(
                    f"overlapping pool writes: rank {r0} [{s0},{e0}) vs "
                    f"rank {r1} [{s1},{e1})")


def _recv_nbytes(s: sched.Schedule, rank: int) -> int:
    p, n, nr = s.primitive, s.msg_bytes, s.nranks
    if p in ("broadcast", "scatter", "all_reduce", "all_to_all"):
        return n
    if p == "reduce":
        return n  # only meaningful at root
    if p in ("gather", "all_gather"):
        return n * nr
    if p == "reduce_scatter":
        return n // nr
    raise ValueError(p)


def _init_recv(s: sched.Schedule, rank: int, send: np.ndarray,
               recv: np.ndarray) -> None:
    """Local (non-pool) data movement: own contributions."""
    p, n, nr = s.primitive, s.msg_bytes, s.nranks
    seg = n // nr if p in ("reduce_scatter", "all_to_all") else None
    if p == "broadcast" and rank == s.root:
        recv[:] = send[:n]
    elif p == "scatter" and rank == s.root:
        recv[:] = send[rank * n:(rank + 1) * n]
    elif p == "gather" and rank == s.root:
        recv[rank * n:(rank + 1) * n] = send[:n]
    elif p == "reduce" and rank == s.root:
        recv[:] = send[:n]
    elif p == "all_gather":
        recv[rank * n:(rank + 1) * n] = send[:n]
    elif p == "all_reduce":
        recv[:] = send[:n]
    elif p == "reduce_scatter":
        recv[:] = send[rank * seg:(rank + 1) * seg]
    elif p == "all_to_all":
        recv[rank * seg:(rank + 1) * seg] = send[rank * seg:(rank + 1) * seg]


def execute(s: sched.Schedule, send_buffers: np.ndarray,
            dtype: np.dtype = np.dtype(np.float32),
            audit: bool = True) -> np.ndarray:
    """Run the schedule.  ``send_buffers`` is ``(nranks, send_bytes)`` uint8;
    returns ``(nranks, recv_bytes)`` uint8 (ragged sizes zero-padded is not
    needed - all recvs of a primitive share one size)."""
    if send_buffers.dtype != np.uint8:
        raise TypeError("send_buffers must be a uint8 byte view")
    if send_buffers.shape[0] != s.nranks:
        raise ValueError("need one send buffer per rank")

    emu = PoolEmulator(s.layout, s.num_doorbells)
    recv_bytes = _recv_nbytes(s, 0)
    recv = np.zeros((s.nranks, recv_bytes), dtype=np.uint8)
    for r in range(s.nranks):
        _init_recv(s, r, send_buffers[r], recv[r])

    # Index cursors instead of list.pop(0): the emulator used to be
    # quadratic in op count, which dominated large-schedule test time.
    wq = {r: tuple(s.writes[r]) for r in range(s.nranks)}
    rq = {r: tuple(s.reads[r]) for r in range(s.nranks)}
    wi = [0] * s.nranks
    ri = [0] * s.nranks
    # Round-robin one op per stream per iteration: models the write/read
    # stream concurrency of Sec. 4.4.
    stall_rounds = 0
    while any(wi[r] < len(wq[r]) for r in range(s.nranks)) or \
            any(ri[r] < len(rq[r]) for r in range(s.nranks)):
        progressed = False
        for r in range(s.nranks):
            if wi[r] < len(wq[r]):
                emu.write(wq[r][wi[r]], send_buffers[r])
                wi[r] += 1
                progressed = True
        for r in range(s.nranks):
            if ri[r] < len(rq[r]) and \
                    emu.try_read(rq[r][ri[r]], recv[r], dtype):
                ri[r] += 1
                progressed = True
        if not progressed:
            stall_rounds += 1
            if stall_rounds > 2:
                pending = {r: rq[r][ri[r]].data_key
                           for r in range(s.nranks) if ri[r] < len(rq[r])}
                raise RuntimeError(f"doorbell deadlock; waiting on {pending}")
        else:
            stall_rounds = 0
    if audit:
        emu.audit_writes()
    return recv


def run_collective(primitive: str, inputs: np.ndarray, *, root: int = 0,
                   num_devices: int = 6,
                   device_capacity: int = 4 * 1024**2,
                   slicing_factor: int = 4) -> np.ndarray:
    """Convenience wrapper: ``inputs`` is ``(nranks, elems)`` of any numeric
    dtype (for scatter, the root row holds ``nranks*elems``; other rows are
    ignored).  Returns the per-rank results as a 2-D array of the input
    dtype."""
    inputs = np.asarray(inputs)
    nranks = inputs.shape[0]
    itemsize = inputs.dtype.itemsize
    if primitive == "scatter":
        msg_bytes = (inputs.shape[1] // nranks) * itemsize
        send_bytes = inputs.shape[1] * itemsize
    else:
        msg_bytes = inputs.shape[1] * itemsize
        send_bytes = msg_bytes
    s = sched.build(primitive, nranks, msg_bytes, num_devices=num_devices,
                    device_capacity=device_capacity,
                    slicing_factor=slicing_factor, root=root,
                    granularity=itemsize)
    send = np.ascontiguousarray(inputs).view(np.uint8).reshape(
        nranks, send_bytes)
    out = execute(s, send, dtype=inputs.dtype)
    return out.view(inputs.dtype)
