"""TPU-mesh realizations of the CXL-CCL collective schedules.

On a TPU pod there is no shared memory pool; the paper's insight maps onto
ICI as follows (DESIGN.md, "hardware adaptation"):

* Eq. 4's disjoint-device ownership ≙ each rank's shard living in its own
  HBM; the read rotation "start from (rank_id+1) % nranks" (Fig. 6) is
  exactly a ring schedule - at every step all ranks pull a *different*
  peer's chunk, so every ICI link carries traffic every step.  We realize
  it with unrolled ``lax.ppermute`` rounds.
* The slicing-factor chunking of Sec. 4.4 becomes per-chunk ppermute
  rounds: communication of chunk k+1 overlaps the consumer-side compute
  (reduction) of chunk k.  XLA schedules these as async collectives.
* Doorbells are unnecessary: SSA data dependence of the ppermute chain
  enforces the producer->consumer (RAW) ordering the doorbell protects.

Everything here must be called inside ``shard_map`` with the named axis.

The paper-faithful AllReduce reads *all* peers' data and reduces locally
(no partial-result reuse - Sec. 5.2 explains why theirs only reaches 1.05x
on large messages).  ``all_reduce(..., mode='faithful')`` reproduces that;
``mode='two_phase'`` is the beyond-paper reduce_scatter + all_gather
composition (wire bytes 2S(n-1)/n instead of S(n-1) per rank).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax import lax

DEFAULT_CHUNKS = 4


def _ring_perm(n: int, shift: int = 1) -> list[tuple[int, int]]:
    return [(i, (i + shift) % n) for i in range(n)]


# --------------------------------------------------------------------- #
# hierarchical decomposition scaffolds (core.topology)
#
# Tuple axes used to recurse the same flat algorithm per level, so the
# outer (pool-spanning, slow) fabric carried the full payload at every
# level.  These scaffolds implement the level-decomposed schedules -
# the per-level single-axis collectives are injected as callables so the
# Communicator can pick a different backend per fabric level.
# --------------------------------------------------------------------- #

def hierarchical_all_reduce(x: jnp.ndarray, axes, *, rs_fn, ar_fn,
                            ag_fn) -> jnp.ndarray:
    """Level-decomposed AllReduce over ``axes`` (outer level first):

        ReduceScatter innermost..axes[1]  ->  AllReduce over axes[0]
        on the 1/prod(inner) shard        ->  AllGather back out.

    Each byte crosses the outermost (pool-spanning) fabric once at
    1/prod(inner) of the payload, instead of the full payload crossing
    at every level as the flat per-level recursion did.  ``rs_fn`` /
    ``ar_fn`` / ``ag_fn`` are ``(array, axis_name) -> array`` single-
    axis collectives (the Communicator's per-level dispatch).
    """
    axes = tuple(axes)
    inner = axes[1:]
    prod_inner = 1
    for ax in inner:
        prod_inner *= lax.axis_size(ax)
    orig_shape = x.shape
    flat = x.reshape(-1)
    pad = (-flat.shape[0]) % max(1, prod_inner)
    if pad:
        flat = jnp.concatenate([flat, jnp.zeros((pad,), flat.dtype)])
    seg = flat
    for ax in reversed(inner):      # innermost level first
        seg = rs_fn(seg, ax)
    seg = ar_fn(seg, axes[0])       # the only cross-outer traffic
    for ax in inner:                # inverse order back out
        seg = ag_fn(seg, ax)
    if pad:
        seg = seg[:-pad]
    return seg.reshape(orig_shape)


# --------------------------------------------------------------------- #
# grouped / ragged schedules (irregular topologies, core.topology)
#
# An irregular level (mixed per-pod fan-out, e.g. one pod of 4 nodes and
# one of 2) cannot be a regular mesh axis of its own: it lives on ONE
# flat mesh axis of sum(shape) ranks, partitioned into contiguous rank
# groups.  SPMD forbids per-rank shapes, so the ragged decomposition
# never produces uneven shards; instead it composes uniform-shape
# grouped schedules:
#
# * within-group ops are masked ring rounds - every group forms its own
#   ppermute ring, rounds run to max(shape)-1 and each rank masks the
#   rounds beyond its own group size;
# * cross-group traffic moves between per-group sub-roots (the first
#   rank of each group) over the *parent* level's fabric;
# * gathers concatenate padding-free: each rank scatters its shard into
#   a full-size buffer at its global offset, and summing the sub-roots'
#   disjoint-offset buffers IS the concatenation (no padded segments).
# --------------------------------------------------------------------- #

def _group_tables(group_shape) -> tuple:
    """Static per-rank tables for contiguous rank groups: returns
    (n, roots, group size per rank, position-in-group per rank,
    group root per rank)."""
    shape = tuple(int(g) for g in group_shape)
    if not shape or any(g < 1 for g in shape):
        raise ValueError(f"bad group shape {group_shape!r}")
    gsize, gpos, groot, roots = [], [], [], []
    start = 0
    for g in shape:
        roots.append(start)
        for p in range(g):
            gsize.append(g)
            gpos.append(p)
            groot.append(start)
        start += g
    return start, tuple(roots), gsize, gpos, groot


def _group_ring_perm(group_shape) -> list:
    """One ppermute whose cycles are the per-group rings."""
    n, _, gsize, gpos, groot = _group_tables(group_shape)
    return [(r, groot[r] + (gpos[r] + 1) % gsize[r]) for r in range(n)]


def _check_axis(axis_name: str, group_shape) -> int:
    n = lax.axis_size(axis_name)
    want = sum(int(g) for g in group_shape)
    if n != want:
        raise ValueError(
            f"group shape {tuple(group_shape)} spans {want} ranks but "
            f"axis {axis_name!r} has {n}")
    return n


def grouped_all_reduce(x: jnp.ndarray, axis_name: str, group_shape,
                       n_chunks: int = DEFAULT_CHUNKS) -> jnp.ndarray:
    """AllReduce *within* each contiguous rank group of a flat axis.

    Groups may have different sizes (``group_shape=(4, 2)``): rounds
    run to ``max(group_shape) - 1`` on the merged per-group rings and
    each rank stops accumulating after its own group's ``g - 1``
    rounds, so no padding ranks or uneven shards appear.  Every rank
    returns its group's sum.
    """
    _check_axis(axis_name, group_shape)
    shape = tuple(int(g) for g in group_shape)
    if max(shape) == 1:
        return x
    _, _, gsize, _, _ = _group_tables(shape)
    idx = lax.axis_index(axis_name)
    my_g = jnp.asarray(gsize)[idx]
    perm = _group_ring_perm(shape)
    out_chunks = []
    for c in _split_chunks(x, n_chunks):
        acc = c
        cur = c
        for t in range(1, max(shape)):
            cur = lax.ppermute(cur, axis_name, perm)
            acc = acc + jnp.where(t < my_g, cur, jnp.zeros_like(cur))
        out_chunks.append(acc)
    return jnp.concatenate(out_chunks, axis=0) if len(out_chunks) > 1 \
        else out_chunks[0]


def subroot_all_reduce(x: jnp.ndarray, axis_name: str, group_shape,
                       n_chunks: int = DEFAULT_CHUNKS) -> jnp.ndarray:
    """AllReduce *across* the per-group sub-roots (first rank of each
    group); every other rank passes through unchanged.  This is the
    only cross-group traffic of the ragged decomposition - the hop
    that rides the parent level's fabric."""
    n = _check_axis(axis_name, group_shape)
    _, roots, _, _, _ = _group_tables(group_shape)
    n_g = len(roots)
    if n_g == 1:
        return x
    nxt = {roots[i]: roots[(i + 1) % n_g] for i in range(n_g)}
    perm = [(r, nxt.get(r, r)) for r in range(n)]
    idx = lax.axis_index(axis_name)
    is_root = jnp.any(idx == jnp.asarray(roots))
    out_chunks = []
    for c in _split_chunks(x, n_chunks):
        acc = c
        cur = c
        for _ in range(1, n_g):
            cur = lax.ppermute(cur, axis_name, perm)
            acc = acc + jnp.where(is_root, cur, jnp.zeros_like(cur))
        out_chunks.append(acc)
    return jnp.concatenate(out_chunks, axis=0) if len(out_chunks) > 1 \
        else out_chunks[0]


def grouped_broadcast(x: jnp.ndarray, axis_name: str, group_shape,
                      n_chunks: int = DEFAULT_CHUNKS) -> jnp.ndarray:
    """Every rank receives its group sub-root's value (pipelined ring
    forward within each group, like ``broadcast`` with the distance
    measured from the group root)."""
    _check_axis(axis_name, group_shape)
    shape = tuple(int(g) for g in group_shape)
    if max(shape) == 1:
        return x
    _, _, _, gpos, _ = _group_tables(shape)
    idx = lax.axis_index(axis_name)
    dist = jnp.asarray(gpos)[idx]
    perm = _group_ring_perm(shape)
    out_chunks = []
    for c in _split_chunks(x, n_chunks):
        cur = c
        out = jnp.where(dist == 0, c, jnp.zeros_like(c))
        for step in range(1, max(shape)):
            cur = lax.ppermute(cur, axis_name, perm)
            out = jnp.where(dist == step, cur, out)
            cur = jnp.where(dist == step, out, cur)  # forward my copy
        out_chunks.append(out)
    return jnp.concatenate(out_chunks, axis=0) if len(out_chunks) > 1 \
        else out_chunks[0]


def ragged_all_reduce(x: jnp.ndarray, axis_name: str, group_shape,
                      n_chunks: int = DEFAULT_CHUNKS) -> jnp.ndarray:
    """Hierarchical AllReduce over a flat axis with ragged groups:
    within-group AllReduce, sub-root exchange across groups, grouped
    broadcast back out.  Numerically a sum over the whole axis (same
    result as the flat single-axis AllReduce up to summation order)."""
    y = grouped_all_reduce(x, axis_name, group_shape, n_chunks=n_chunks)
    z = subroot_all_reduce(y, axis_name, group_shape, n_chunks=n_chunks)
    return grouped_broadcast(z, axis_name, group_shape, n_chunks=n_chunks)


def ragged_all_gather(x: jnp.ndarray, axis_name: str, group_shape,
                      n_chunks: int = DEFAULT_CHUNKS,
                      cross_chunks: "int | None" = None) -> jnp.ndarray:
    """Padding-free hierarchical all-gather over ragged groups.

    Phase 1 rotates shards within each group, every rank writing each
    received shard into a full-size output buffer at the *global*
    rank-major offset - so after ``g - 1`` rounds each rank holds its
    whole group's block, at the right place, with no padded segments.
    Phase 2 sums the sub-roots' buffers across groups: the blocks sit
    at disjoint offsets, so the sum IS the concatenation.  Phase 3
    fans the assembled buffer back out within each group.  The result
    matches the flat single-axis ``all_gather`` exactly (rank-major
    order along axis 0).  ``cross_chunks`` is the slicing factor of
    the cross-group (sub-root) phase - the hop a per-level plan may
    tune separately; defaults to ``n_chunks``.
    """
    n = _check_axis(axis_name, group_shape)
    shape = tuple(int(g) for g in group_shape)
    if n == 1:
        return x
    if x.ndim == 0:
        raise ValueError("ragged_all_gather needs at least 1-d input")
    _, _, gsize, gpos, groot = _group_tables(shape)
    idx = lax.axis_index(axis_name)
    my_g = jnp.asarray(gsize)[idx]
    my_pos = jnp.asarray(gpos)[idx]
    my_root = jnp.asarray(groot)[idx]
    perm = _group_ring_perm(shape)
    lead = x.shape[0]
    buf = jnp.zeros((n * lead,) + x.shape[1:], x.dtype)
    buf = lax.dynamic_update_slice_in_dim(buf, x, idx * lead, axis=0)
    cur = x
    for t in range(1, max(shape)):
        # after t hops my copy originated t ranks behind me in my group
        cur = lax.ppermute(cur, axis_name, perm)
        src = my_root + jnp.mod(my_pos - t, my_g)
        upd = lax.dynamic_update_slice_in_dim(buf, cur, src * lead,
                                              axis=0)
        buf = jnp.where(t < my_g, upd, buf)
    buf = subroot_all_reduce(buf, axis_name, shape,
                             n_chunks=cross_chunks if cross_chunks
                             is not None else n_chunks)
    return grouped_broadcast(buf, axis_name, shape, n_chunks=n_chunks)


def ragged_reduce_scatter(x: jnp.ndarray, axis_name: str, group_shape,
                          n_chunks: int = DEFAULT_CHUNKS,
                          cross_chunks: "int | None" = None
                          ) -> jnp.ndarray:
    """Padding-free hierarchical reduce-scatter over ragged groups:
    rank r returns ``sum_ranks(x)[r*seg:(r+1)*seg]`` with
    ``seg = lead / sum(shape)`` - exactly the flat single-axis
    ``reduce_scatter`` semantics, decomposed so the cross-group hop
    rides the parent level's fabric.

    Phase 1 reduces within each group (masked rings to
    ``max(shape) - 1`` rounds, so no padding ranks appear); phase 2
    exchanges the group partials across the per-group sub-roots - the
    disjoint-offset complement of ``ragged_all_gather``'s assembly:
    each sub-root's buffer carries its group's partial of *every*
    global segment, and summing them completes every segment at once;
    phase 3 fans the completed buffer back out within each group and
    every rank slices its own rank-major segment (a traced offset -
    uniform shapes, so SPMD never sees an uneven shard).
    ``cross_chunks`` tunes the sub-root hop's slicing factor
    separately; defaults to ``n_chunks``.
    """
    n = _check_axis(axis_name, group_shape)
    shape = tuple(int(g) for g in group_shape)
    if n == 1:
        return x
    if x.shape[0] % n:
        raise ValueError(f"leading dim {x.shape[0]} must divide axis {n}")
    seg = x.shape[0] // n
    idx = lax.axis_index(axis_name)
    part = grouped_all_reduce(x, axis_name, shape, n_chunks=n_chunks)
    full = subroot_all_reduce(part, axis_name, shape,
                              n_chunks=cross_chunks if cross_chunks
                              is not None else n_chunks)
    full = grouped_broadcast(full, axis_name, shape, n_chunks=n_chunks)
    return lax.dynamic_slice_in_dim(full, idx * seg, seg, axis=0)


def ragged_gather(x: jnp.ndarray, axis_name: str, group_shape,
                  root: int = 0,
                  n_chunks: int = DEFAULT_CHUNKS,
                  cross_chunks: "int | None" = None) -> jnp.ndarray:
    """Gather-to-root over ragged groups (rank-major concatenation,
    non-root ranks return zeros), via the padding-free assembly of
    ``ragged_all_gather``."""
    full = ragged_all_gather(x, axis_name, group_shape,
                             n_chunks=n_chunks,
                             cross_chunks=cross_chunks)
    idx = lax.axis_index(axis_name)
    return jnp.where(idx == root, full, jnp.zeros_like(full))


def _split_chunks(x: jnp.ndarray, n_chunks: int) -> list[jnp.ndarray]:
    """Split along axis 0 (the paper's slicing factor).  Falls back to a
    single chunk when the leading dim does not divide."""
    lead = x.shape[0] if x.ndim else 1
    if n_chunks <= 1 or x.ndim == 0 or lead % n_chunks:
        return [x]
    return list(jnp.split(x, n_chunks, axis=0))


def p2p_shift(x: jnp.ndarray, axis_name: str, shift: int = 1,
              n_chunks: int = DEFAULT_CHUNKS) -> jnp.ndarray:
    """Point-to-point ring shift: every rank sends ``x`` to the rank
    ``shift`` ahead on the axis and returns the payload received from
    the rank ``shift`` behind (cyclic).  This is the pipeline-parallel
    activation/grad handoff primitive: the whole payload moves exactly
    one hop, so wire bytes are S per rank per call.

    On the pool this is a write + doorbell commit + consumer read; on
    the TPU mesh both backends lower to per-chunk ``ppermute`` (SSA
    data dependence replaces the doorbell, exactly as for the
    collectives above), with the slicing factor pipelining the
    producer write against the consumer read."""
    n = lax.axis_size(axis_name)
    if n == 1 or shift % n == 0:
        return x
    perm = _ring_perm(n, shift % n)
    moved = [lax.ppermute(c, axis_name, perm)
             for c in _split_chunks(x, n_chunks)]
    return jnp.concatenate(moved, axis=0) if len(moved) > 1 else moved[0]


def all_gather(x: jnp.ndarray, axis_name: str,
               n_chunks: int = DEFAULT_CHUNKS) -> jnp.ndarray:
    """Chunked ring all-gather; returns shards concatenated along axis 0 in
    rank order (``tiled=True`` semantics)."""
    n = lax.axis_size(axis_name)
    if n == 1:
        return x
    idx = lax.axis_index(axis_name)
    perm = _ring_perm(n)
    chunks = _split_chunks(x, n_chunks)
    gathered = []
    for c in chunks:
        out = jnp.zeros((n,) + c.shape, c.dtype)
        out = lax.dynamic_update_index_in_dim(out, c, idx, 0)
        cur = c
        for step in range(1, n):
            # After `step` hops my copy of `cur` originated at idx - step.
            cur = lax.ppermute(cur, axis_name, perm)
            src = (idx - step) % n
            out = lax.dynamic_update_index_in_dim(out, cur, src, 0)
        gathered.append(out)
    # Re-interleave chunk rows back into rank-major order: stack to
    # (chunks, n, lead/chunks, ...), swap to rank-major and flatten -
    # one transpose instead of O(n * chunks) concatenates.
    stacked = jnp.stack(gathered, axis=0)
    lead = x.shape[0] if x.ndim else 1
    return jnp.swapaxes(stacked, 0, 1).reshape((n * lead,)
                                               + x.shape[1:])


def reduce_scatter(x: jnp.ndarray, axis_name: str,
                   n_chunks: int = DEFAULT_CHUNKS) -> jnp.ndarray:
    """Chunked ring reduce-scatter over axis 0 (``scatter_dimension=0``):
    rank r returns ``sum_ranks(x)[r*seg:(r+1)*seg]``."""
    n = lax.axis_size(axis_name)
    if n == 1:
        return x
    if x.shape[0] % n:
        raise ValueError(f"leading dim {x.shape[0]} must divide axis {n}")
    idx = lax.axis_index(axis_name)
    perm = _ring_perm(n)
    segs = jnp.reshape(x, (n, x.shape[0] // n) + x.shape[1:])

    # Partial for segment s starts at rank s+1; after t hops it sits at
    # rank r = s + 1 + t and absorbs that rank's segment s = r - t - 1.
    acc = lax.dynamic_index_in_dim(segs, (idx - 1) % n, 0, keepdims=False)
    acc_chunks = _split_chunks(acc, n_chunks)
    for t in range(1, n):
        local = lax.dynamic_index_in_dim(segs, (idx - t - 1) % n, 0,
                                         keepdims=False)
        local_chunks = _split_chunks(local, n_chunks)
        acc_chunks = [lax.ppermute(a, axis_name, perm) + l
                      for a, l in zip(acc_chunks, local_chunks)]
    return jnp.concatenate(acc_chunks, axis=0) if len(acc_chunks) > 1 \
        else acc_chunks[0]


def all_reduce(x: jnp.ndarray, axis_name: str, *, mode: str = "two_phase",
               n_chunks: int = DEFAULT_CHUNKS) -> jnp.ndarray:
    """AllReduce over the named axis.

    ``faithful``  - the paper's algorithm: gather every peer's full buffer
                    (ring) and reduce locally; wire bytes S(n-1) per rank.
    ``two_phase`` - reduce_scatter + all_gather; wire bytes 2S(n-1)/n.
    """
    n = lax.axis_size(axis_name)
    if n == 1:
        return x
    if mode == "faithful":
        perm = _ring_perm(n)
        chunks = _split_chunks(x, n_chunks)
        out_chunks = []
        for c in chunks:
            acc = c
            cur = c
            for _ in range(1, n):
                cur = lax.ppermute(cur, axis_name, perm)
                acc = acc + cur
            out_chunks.append(acc)
        return jnp.concatenate(out_chunks, axis=0) if len(out_chunks) > 1 \
            else out_chunks[0]
    if mode == "two_phase":
        orig_shape = x.shape
        flat = x.reshape(-1)
        pad = (-flat.shape[0]) % n
        if pad:
            flat = jnp.concatenate([flat, jnp.zeros((pad,), flat.dtype)])
        seg = reduce_scatter(flat, axis_name, n_chunks=n_chunks)
        full = all_gather(seg, axis_name, n_chunks=n_chunks)
        if pad:
            full = full[:-pad]
        return full.reshape(orig_shape)
    raise ValueError(f"unknown all_reduce mode {mode!r}")


def all_to_all(x: jnp.ndarray, axis_name: str,
               n_chunks: int = DEFAULT_CHUNKS) -> jnp.ndarray:
    """Rotation-scheduled all-to-all over axis 0: segment p of the result
    is rank p's segment ``my_rank``.  Mirrors the paper's AllToAll where
    rank r publishes segment ``dest`` starting from ``(r+1) % nranks``:
    rotation ``s`` exchanges data between ranks at ring distance ``s``."""
    n = lax.axis_size(axis_name)
    if n == 1:
        return x
    if x.shape[0] % n:
        raise ValueError(f"leading dim {x.shape[0]} must divide axis {n}")
    idx = lax.axis_index(axis_name)
    segs = jnp.reshape(x, (n, x.shape[0] // n) + x.shape[1:])
    out = jnp.zeros_like(segs)
    own = lax.dynamic_index_in_dim(segs, idx, 0, keepdims=False)
    out = lax.dynamic_update_index_in_dim(out, own, idx, 0)
    for s in range(1, n):
        perm = _ring_perm(n, shift=s)
        # I send my segment for rank (idx+s); I receive from rank (idx-s)
        # its segment destined to me.
        send = lax.dynamic_index_in_dim(segs, (idx + s) % n, 0,
                                        keepdims=False)
        recv_chunks = [lax.ppermute(c, axis_name, perm)
                       for c in _split_chunks(send, n_chunks)]
        recv = jnp.concatenate(recv_chunks, axis=0) \
            if len(recv_chunks) > 1 else recv_chunks[0]
        out = lax.dynamic_update_index_in_dim(out, recv, (idx - s) % n, 0)
    return out.reshape(x.shape)


def broadcast(x: jnp.ndarray, axis_name: str, root: int = 0,
              n_chunks: int = DEFAULT_CHUNKS) -> jnp.ndarray:
    """Pipelined ring broadcast from ``root``; chunks stream hop-by-hop so
    link utilization matches the pool version's chunk overlap."""
    n = lax.axis_size(axis_name)
    if n == 1:
        return x
    idx = lax.axis_index(axis_name)
    dist = (idx - root) % n
    perm = _ring_perm(n)
    out_chunks = []
    for c in _split_chunks(x, n_chunks):
        cur = c
        out = jnp.where(dist == 0, c, jnp.zeros_like(c))
        for step in range(1, n):
            cur = lax.ppermute(cur, axis_name, perm)
            out = jnp.where(dist == step, cur, out)
            cur = jnp.where(dist == step, out, cur)  # forward my copy
        out_chunks.append(out)
    return jnp.concatenate(out_chunks, axis=0) if len(out_chunks) > 1 \
        else out_chunks[0]


def reduce(x: jnp.ndarray, axis_name: str, root: int = 0,
           n_chunks: int = DEFAULT_CHUNKS) -> jnp.ndarray:
    """Ring reduce-to-root; non-root ranks return zeros."""
    n = lax.axis_size(axis_name)
    if n == 1:
        return x
    idx = lax.axis_index(axis_name)
    total = all_reduce(x, axis_name, mode="two_phase", n_chunks=n_chunks)
    return jnp.where(idx == root, total, jnp.zeros_like(total))


def gather(x: jnp.ndarray, axis_name: str, root: int = 0,
           n_chunks: int = DEFAULT_CHUNKS) -> jnp.ndarray:
    """Gather-to-root (rank order along axis 0); non-root ranks zeros."""
    n = lax.axis_size(axis_name)
    idx = lax.axis_index(axis_name)
    full = all_gather(x, axis_name, n_chunks=n_chunks)
    return jnp.where(idx == root, full, jnp.zeros_like(full))


def scatter(x: jnp.ndarray, axis_name: str, root: int = 0,
            n_chunks: int = DEFAULT_CHUNKS) -> jnp.ndarray:
    """Scatter from root: rank r receives segment r of root's axis-0."""
    n = lax.axis_size(axis_name)
    if n == 1:
        return x
    if x.shape[0] % n:
        raise ValueError(f"leading dim {x.shape[0]} must divide axis {n}")
    idx = lax.axis_index(axis_name)
    rooted = broadcast(x, axis_name, root=root, n_chunks=n_chunks)
    segs = jnp.reshape(rooted, (n, x.shape[0] // n) + x.shape[1:])
    return lax.dynamic_index_in_dim(segs, idx, 0, keepdims=False)
