"""Communication/compute overlap subsystem: gradient bucketing and fused
FSDP gathers.

The training hot path used to issue one collective per parameter leaf
(alpha-dominated small messages) and to serialize every FSDP AllGather
against the matmul consuming it.  This module supplies the two fused
counterparts, mirroring the paper's Sec. 4.4 chunked-stream pipelining at
the framework level ("Collective Communication for 100k+ GPUs" calls the
same structure gradient bucketing):

* **Bucketing** (`assign_buckets` + `pack`/`unpack`): coalesce same-dtype
  leaves that want the *same* collective (same mesh axes) into a small
  number of flat fused buffers, NCCL-style size-capped, in deterministic
  leaf (pytree-flatten) order.  `bucketed_sync_grads` then issues one
  AllReduce per bucket instead of one per leaf, and
  `make_gather_fn(..., bucket_bytes>0)` issues one FSDP AllGather per
  bucket per scan row whose AD transpose is the matching fused
  ReduceScatter.
* **Prefetch**: `models.model._run_groups` consumes these gathers with an
  explicit double-buffered carry (prefetch depth 1): layer ``l+1``'s
  AllGather is issued in the same scan body that computes layer ``l``, so
  XLA can schedule it as an async collective behind the matmuls.  Those
  prefetched gathers run under ``ledger.hidden()`` so the trace-time
  ledger splits wire bytes into exposed vs hidden.

Packing/unpacking is pure data movement (ravel + concatenate + slice), so
fused collectives are numerically equivalent to the per-leaf path: an
AllGather is bitwise identical, and a bucketed ring AllReduce sums ranks
in the same per-element order as the per-leaf one.

This module is mesh-layer generic: it never imports ``repro.models`` -
partition specs are handed in by the caller (``models.sharding``).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Optional, Sequence, Union

import jax
import jax.numpy as jnp
from jax import lax
from jax.sharding import PartitionSpec as P

from repro.core import ledger

MiB = 1024 ** 2

# NCCL's default fused-gradient-buffer cap is 25 MB; same default here.
DEFAULT_BUCKET_BYTES = 25 * MiB


@jax.tree_util.register_pytree_node_class
class StackedShards:
    """A gathered FSDP weight kept in rank-major stacked form (n, Ks, N)
    instead of concatenated (n*Ks, N).

    The fused-gather path (``make_gather_fn(..., fuse=True)``) returns
    matmul weights this way so the consuming layer can stream the shard
    stack straight through ``kernels.ops.fused_dense`` - the all_gather
    fused into the matmul's prologue - without ever materializing the
    concatenated weight.  ``models.layers.dense`` dispatches on this
    type; everything else treats it as an opaque pytree node (one array
    child, so grads/optimizer state never see it - it only exists
    inside the per-row gathered params)."""

    def __init__(self, shards):
        self.shards = shards

    def tree_flatten(self):
        return (self.shards,), None

    @classmethod
    def tree_unflatten(cls, aux, children):
        return cls(children[0])

    def __repr__(self):
        shp = getattr(self.shards, "shape", None)
        return f"StackedShards(shape={shp})"


# Matmul weights the fused all_gather+matmul kernel may consume: 2-D,
# dp-sharded on dim 0 (the contraction dim of the ``x @ w`` that eats
# them).  Everything else (norm scales, embeddings, biases) gathers on
# the ordinary concatenated path.
FUSABLE_PARAMS = frozenset({"wq", "wk", "wv", "wo", "wg", "wu", "wd"})


# --------------------------------------------------------------------- #
# bucket assignment (shape-only, deterministic)
# --------------------------------------------------------------------- #

@dataclasses.dataclass(frozen=True)
class Slot:
    """One leaf's position inside a fused flat buffer."""

    index: int          # position in the caller's flat leaf list
    offset: int         # element offset into the bucket buffer
    size: int           # element count
    shape: tuple        # shape to restore on unpack


@dataclasses.dataclass(frozen=True)
class Bucket:
    key: tuple          # (group_key, dtype_name)
    slots: tuple        # tuple[Slot, ...] in deterministic leaf order
    elems: int          # total element count of the fused buffer


def assign_buckets(entries: Sequence[tuple],
                   cap_bytes: Optional[int]) -> list:
    """Greedy size-capped bucket assignment.

    ``entries`` is a sequence of ``(index, shape, dtype, group_key)`` in
    deterministic leaf order (pytree flatten order - jax sorts dict
    keys).  Leaves are grouped by ``(group_key, dtype)`` and each group
    is split into buckets of at most ``cap_bytes`` (a single leaf larger
    than the cap gets its own bucket, like NCCL's oversize buckets).
    ``cap_bytes=None`` fuses each group into ONE bucket (torch-FSDP's
    FlatParameter-per-module analog, the right granularity for per-row
    param gathers); ``cap_bytes <= 0`` degenerates to one bucket per
    leaf (the per-leaf baseline expressed in the same code path).
    """
    groups: dict = {}
    order: list = []
    for index, shape, dtype, group_key in entries:
        dt = jnp.dtype(dtype)
        key = (group_key, dt.name)
        if key not in groups:
            groups[key] = []
            order.append(key)
        groups[key].append((index, tuple(shape), dt))
    buckets: list = []
    for key in order:
        slots: list = []
        elems = 0
        nbytes = 0
        for index, shape, dt in groups[key]:
            size = 1
            for d in shape:
                size *= int(d)
            leaf_bytes = size * dt.itemsize
            if slots and cap_bytes is not None and (
                    cap_bytes <= 0 or nbytes + leaf_bytes > cap_bytes):
                buckets.append(Bucket(key=key, slots=tuple(slots),
                                      elems=elems))
                slots, elems, nbytes = [], 0, 0
            slots.append(Slot(index=index, offset=elems, size=size,
                              shape=shape))
            elems += size
            nbytes += leaf_bytes
        if slots:
            buckets.append(Bucket(key=key, slots=tuple(slots),
                                  elems=elems))
    return buckets


def pack(bucket: Bucket, leaves: Sequence) -> jnp.ndarray:
    """Fuse the bucket's leaves into one flat 1-D buffer."""
    parts = [jnp.ravel(leaves[s.index]) for s in bucket.slots]
    return parts[0] if len(parts) == 1 else jnp.concatenate(parts)


def unpack(bucket: Bucket, flat: jnp.ndarray) -> list:
    """Inverse of ``pack``: [(index, leaf)] restored to slot shapes."""
    return [(s.index, flat[s.offset:s.offset + s.size].reshape(s.shape))
            for s in bucket.slots]


# --------------------------------------------------------------------- #
# spec helpers (kept local: core must not import repro.models)
# --------------------------------------------------------------------- #

def _axis_dim(spec: P, axes) -> Optional[int]:
    """Dim of ``spec`` sharded over ``axes`` (str or tuple), else None."""
    target = tuple(axes) if isinstance(axes, (tuple, list)) else (axes,)
    for i, s in enumerate(spec):
        if s == axes or s == target or (isinstance(s, str)
                                        and s in target):
            return i
    return None


def _spec_axes(spec: P) -> set:
    flat = set()
    for s in spec:
        if s is None:
            continue
        for a in (s if isinstance(s, tuple) else (s,)):
            flat.add(a)
    return flat


def _axes_tuple(axis) -> tuple:
    if axis is None:
        return ()
    return tuple(axis) if isinstance(axis, (tuple, list)) else (axis,)


def _flat_with_specs(tree: Any, specs: Any) -> tuple:
    """(leaves, spec_leaves, treedef) in matching flatten order."""
    leaves, treedef = jax.tree.flatten(tree)
    spec_leaves = treedef.flatten_up_to(specs)
    return leaves, spec_leaves, treedef


# --------------------------------------------------------------------- #
# bucketed gradient sync (fused AllReduce of replicated-leaf grads)
# --------------------------------------------------------------------- #

def bucketed_sync_grads(grads: Any, specs: Any, pc, dp_axis,
                        bucket_bytes: int = DEFAULT_BUCKET_BYTES) -> Any:
    """Fused version of ``models.sharding.sync_grads``.

    Leaves replicated over an axis accumulate only their local grad
    contribution and need an explicit AllReduce over that axis (FSDP
    leaves get their sum through the gather's AD transpose; TP-sharded
    leaves are complete locally).  Here leaves needing the *same*
    AllReduce (same missing axes, same dtype) are coalesced into
    size-capped flat buffers so the sync issues a handful of large
    collectives instead of one per leaf.
    """
    dp = _axes_tuple(dp_axis)
    tp = pc.tp_axis
    leaves, spec_leaves, treedef = _flat_with_specs(grads, specs)

    entries = []
    for i, (g, spec) in enumerate(zip(leaves, spec_leaves)):
        flat_axes = _spec_axes(spec)
        # dp levels first (outermost), tp innermost - matching
        # sharding.sync_grads so the fused and per-leaf paths issue the
        # identical (possibly topology-decomposed) AllReduce
        missing = []
        if dp and not any(a in flat_axes for a in dp):
            missing.extend(dp)
        if tp is not None and tp not in flat_axes:
            missing.append(tp)
        if missing:
            entries.append((i, g.shape, g.dtype, tuple(missing)))

    out = list(leaves)
    for bucket in assign_buckets(entries, bucket_bytes):
        missing = bucket.key[0]
        flat = pack(bucket, leaves)
        flat = pc.comm.all_reduce(
            flat, missing[0] if len(missing) == 1 else tuple(missing))
        for index, leaf in unpack(bucket, flat):
            out[index] = leaf
    return treedef.unflatten(out)


# --------------------------------------------------------------------- #
# bucketed FSDP gather (fused AllGather; AD transposes to fused RS)
# --------------------------------------------------------------------- #

def _leaf_names(tree: Any) -> list:
    """The last path component (dict key name) of every leaf, in the
    same order ``jax.tree.flatten`` yields them."""
    flat, _ = jax.tree_util.tree_flatten_with_path(tree)
    names = []
    for path, _leaf in flat:
        last = path[-1] if path else None
        names.append(getattr(last, "key", None))
    return names


def make_gather_fn(all_row_specs: dict, pc, dp_axis,
                   bucket_bytes: Optional[int] = None,
                   fuse: bool = False):
    """Returns ``gather(group_key, row_params) -> gathered params``.

    Every leaf whose spec shards a dim over the dp axis is moved to
    dim 0, raveled, and fused with its same-dtype neighbours into flat
    buffers; one AllGather per bucket then replaces one per leaf.
    Rank-major blocks of the gathered buffer are sliced back per leaf
    with static reshapes (no data-dependent work), so autodiff
    transposes the whole thing into the matching fused ReduceScatter on
    the gradient - FSDP's communication pattern at bucket granularity.

    The default ``bucket_bytes=None`` fuses a whole row's same-dtype
    leaves into one buffer (torch-FSDP's per-module FlatParameter);
    a positive cap splits NCCL-style, and ``<= 0`` reproduces the
    per-leaf schedule through the same code path.

    ``fuse=True`` routes the 2-D matmul weights (``FUSABLE_PARAMS``,
    dp-sharded on their contraction dim) through the fused
    all_gather+matmul path: they bucket separately, gather inside a
    ``ledger.fused()`` region (booking their wire bytes into the fused
    split), and come back as :class:`StackedShards` - the rank-major
    (n, Ks, N) stack ``models.layers.dense`` streams through
    ``kernels.ops.fused_dense`` instead of a concatenated array.  The
    slicing back to per-leaf stacks is static reshapes only, so the AD
    transpose is the identical fused ReduceScatter.
    """
    def gather(group_key: str, row_params):
        specs = all_row_specs[group_key]
        leaves, spec_leaves, treedef = _flat_with_specs(row_params, specs)
        names = _leaf_names(row_params) if fuse else [None] * len(leaves)

        n_total = 1
        for ax in _axes_tuple(dp_axis):
            n_total *= lax.axis_size(ax)

        moved: dict = {}
        dims: dict = {}
        entries = []
        fused_ix = set()
        for i, (x, spec) in enumerate(zip(leaves, spec_leaves)):
            dim = _axis_dim(spec, dp_axis)
            if dim is None:
                continue
            m = jnp.moveaxis(x, dim, 0)
            moved[i] = m
            dims[i] = dim
            fusable = (fuse and names[i] in FUSABLE_PARAMS
                       and dim == 0 and m.ndim == 2)
            if fusable:
                fused_ix.add(i)
            entries.append((i, m.shape, m.dtype,
                            ("fused",) if fusable else ()))

        out = list(leaves)
        src = [moved.get(i, x) for i, x in enumerate(leaves)]
        for bucket in assign_buckets(entries, bucket_bytes):
            is_fused = bucket.key[0] == ("fused",)
            flat = pack(bucket, src)
            with ledger.fused(is_fused):
                full = pc.comm.all_gather(flat, dp_axis)
            blocks = full.reshape(n_total, bucket.elems)
            for s in bucket.slots:
                seg = blocks[:, s.offset:s.offset + s.size]
                m = seg.reshape((n_total,) + s.shape)
                if s.index in fused_ix:
                    # keep the rank-major shard stack: the consuming
                    # matmul streams it without concatenation
                    out[s.index] = StackedShards(m)
                    continue
                m = m.reshape((n_total * s.shape[0],) + s.shape[1:])
                out[s.index] = jnp.moveaxis(m, 0, dims[s.index])
        return treedef.unflatten(out)
    return gather
