"""Software interleaving across CXL devices (paper Sec. 4.3, Eq. 1-4).

The pool has no hardware cache-line interleaving, so CXL-CCL places data
explicitly.  Two placement policies:

* ``RoundRobin`` (type 1, ``1->N`` / ``N->1`` collectives): the root's data
  blocks are striped round-robin across ALL devices (Eq. 1-3) so readers can
  pull from distinct devices in parallel.
* ``RankPartitioned`` (type 2, ``N->N`` collectives): each rank owns a
  mutually-exclusive device range, ``device_per_rank = ND / nranks`` (Eq. 4),
  eliminating concurrent writes to the same device; readers rotate their
  start offset ``(rank_id + 1) % nranks`` away from the writers.

All functions are pure integer math so the same code serves the functional
pool emulation, the event-driven simulator and trace-time schedule
generation for the shard_map backend.
"""
from __future__ import annotations

import dataclasses
from typing import Literal

PlacementKind = Literal["round_robin", "rank_partitioned"]


@dataclasses.dataclass(frozen=True)
class Placement:
    """Resolved location of one data block inside the pool."""

    device_index: int      # which CXL device
    device_block_id: int   # logical block index within that device
    device_location: int   # byte offset within the unified pool address space
    doorbell_index: int    # index of this block's doorbell entry


@dataclasses.dataclass(frozen=True)
class PoolLayout:
    """Static layout parameters shared by all ranks of a communicator."""

    num_devices: int               # ND
    device_capacity: int           # DS (bytes)
    doorbell_region: int           # DB_offset: bytes reserved for doorbells
    block_size: int                # bytes per data block (chunk)

    def __post_init__(self) -> None:
        if self.num_devices <= 0:
            raise ValueError("num_devices must be positive")
        if self.block_size <= 0:
            raise ValueError("block_size must be positive")
        if self.doorbell_region < 0:
            raise ValueError("doorbell_region must be non-negative")
        per_dev = self.device_capacity - self.doorbell_region
        if per_dev <= 0:
            raise ValueError("doorbell region exceeds device capacity")

    @property
    def blocks_per_device(self) -> int:
        return (self.device_capacity - self.doorbell_region) // self.block_size


def round_robin(layout: PoolLayout, data_id: int) -> Placement:
    """Eq. 1-3: stripe block ``data_id`` round-robin across all devices."""
    nd = layout.num_devices
    device_index = data_id % nd                     # Eq. 1
    device_block_id = data_id // nd                 # Eq. 2
    if device_block_id >= layout.blocks_per_device:
        raise ValueError(
            f"data_id {data_id} overflows device {device_index} "
            f"({layout.blocks_per_device} blocks per device)")
    device_location = (                             # Eq. 3
        layout.doorbell_region
        + device_block_id * layout.block_size
        + device_index * layout.device_capacity)
    return Placement(device_index, device_block_id, device_location,
                     doorbell_index=data_id)


def rank_partitioned(layout: PoolLayout, rank_id: int, nranks: int,
                     data_id: int) -> Placement:
    """Eq. 4: confine rank ``rank_id`` to its own mutually-exclusive devices.

    ``data_id`` here indexes blocks *within the rank's own send buffer*; the
    doorbell index is globally unique per (rank, block).
    """
    nd = layout.num_devices
    if nranks <= 0:
        raise ValueError("nranks must be positive")
    device_per_rank = max(1, nd // nranks)          # Eq. 4
    first_device = (rank_id * device_per_rank) % nd
    device_index = (first_device + data_id % device_per_rank) % nd
    # When nranks > ND, several ranks share a device; give each sharer a
    # disjoint block stripe so writes never collide (still pure index math).
    num_sharers = -(-nranks * device_per_rank // nd)   # ceil
    share_slot = (rank_id * device_per_rank) // nd
    device_block_id = (data_id // device_per_rank) * num_sharers + share_slot
    if device_block_id >= layout.blocks_per_device:
        raise ValueError(
            f"data_id {data_id} overflows rank {rank_id} partition")
    device_location = (
        layout.doorbell_region
        + device_block_id * layout.block_size
        + device_index * layout.device_capacity)
    # Doorbell slot: disjoint per-rank stripe, compacted by the schedule
    # builder which knows the static writes-per-rank bound.
    doorbell_index = data_id
    return Placement(device_index, device_block_id, device_location,
                     doorbell_index=doorbell_index)


def publish_order(rank_id: int, nranks: int) -> list[int]:
    """Deterministic publication order (Sec. 4.3): start from
    ``(rank_id + 1) % nranks`` then continue round-robin.  Used both for the
    write phase (segment destinations) and the read phase (producer order) so
    concurrent ranks fan out across distinct devices."""
    return [(rank_id + 1 + i) % nranks for i in range(nranks)]
