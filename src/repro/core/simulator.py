"""Event-driven performance simulator for pool-mediated collectives.

Reproduces the paper's evaluation methodology: the authors themselves use an
emulator for the scalability study (Sec. 5.1, "Scalability test"), with the
same two modeling assumptions we implement here:

* concurrent requests targeting the same CXL device share its bandwidth
  uniformly (Observation 2) - realized as max-min fair water-filling over
  per-(device, direction) and per-(server, direction) capacity constraints;
* requests to different devices are independent.

On top of that we model the constants measured in Sec. 3 (Fig. 3, Table 1):
20 GB/s per device and per server direction (single GPU DMA engine per
direction, Observation 1), 658 ns pool access latency, per-cudaMemcpyAsync
software overhead, doorbell flush + poll cost, and degraded per-direction
throughput when a device serves reads and writes simultaneously.

Execution model: each rank runs a writeStream and a readStream (Sec. 4.4).
Streams issue their ops in order; a read op additionally blocks until its
chunk's doorbell has been rung by the producer's completed write.  The
optional global phase barrier reproduces the non-overlapped baselines
(CXL-CCL-Naive / the strawman of Fig. 7).
"""
from __future__ import annotations

import dataclasses
import math
from typing import Optional

from repro.core import schedule as sched
from repro.core.hw import CXL_POOL, CXLPoolConfig


@dataclasses.dataclass
class SimOptions:
    pool: CXLPoolConfig = CXL_POOL
    phase_barrier: bool = False     # global write->read barrier (no overlap)
    track_timeline: bool = False


@dataclasses.dataclass
class SimResult:
    total_time: float                       # seconds, max over ranks
    rank_finish: dict[int, float]
    bytes_moved: int
    num_ops: int
    timeline: Optional[list] = None

    @property
    def algbw(self) -> float:
        """bytes moved through the pool / total time."""
        return self.bytes_moved / self.total_time if self.total_time else 0.0


class _Xfer:
    __slots__ = ("op", "remaining", "rate", "active", "done", "start",
                 "finish", "ready_time")

    def __init__(self, op: sched.TransferOp):
        self.op = op
        self.remaining = float(op.size)
        self.rate = 0.0
        self.active = False
        self.done = False
        self.start = None
        self.finish = None
        self.ready_time = None  # earliest legal activation time


def _allocate_rates(active: list[_Xfer], pool: CXLPoolConfig) -> None:
    """Max-min fair allocation subject to device and server caps."""
    if not active:
        return
    # Constraint keys: ('dev', device, dir) and ('srv', rank, dir).
    members: dict[tuple, list[_Xfer]] = {}
    dirs_per_device: dict[int, set[str]] = {}
    for t in active:
        d = "w" if t.op.kind is sched.OpKind.WRITE else "r"
        members.setdefault(("dev", t.op.device, d), []).append(t)
        members.setdefault(("srv", t.op.rank, d), []).append(t)
        dirs_per_device.setdefault(t.op.device, set()).add(d)

    caps: dict[tuple, float] = {}
    for key in members:
        kind = key[0]
        if kind == "dev":
            dev = key[1]
            eff = pool.bidir_efficiency if len(
                dirs_per_device[dev]) == 2 else 1.0
            caps[key] = pool.device_bw * eff
        else:
            caps[key] = pool.server_bw

    unfrozen = set(id(t) for t in active)
    by_id = {id(t): t for t in active}
    while unfrozen:
        # Most-constrained bottleneck first (water-filling).
        best_share, best_key = math.inf, None
        for key, mem in members.items():
            live = [t for t in mem if id(t) in unfrozen]
            if not live:
                continue
            share = caps[key] / len(live)
            if share < best_share:
                best_share, best_key = share, key
        if best_key is None:
            break
        for t in list(members[best_key]):
            if id(t) in unfrozen:
                t.rate = best_share
                unfrozen.discard(id(t))
                # charge this rate against the transfer's other constraints
                d = "w" if t.op.kind is sched.OpKind.WRITE else "r"
                for key in (("dev", t.op.device, d), ("srv", t.op.rank, d)):
                    if key != best_key:
                        caps[key] = max(0.0, caps[key] - best_share)


def simulate(s: sched.Schedule, options: SimOptions | None = None
             ) -> SimResult:
    opt = options or SimOptions()
    pool = opt.pool

    xfers: list[_Xfer] = []
    streams: dict[tuple, list[_Xfer]] = {}   # (rank, 'W'|'R') -> queue
    for r in range(s.nranks):
        wq = [_Xfer(op) for op in s.writes[r]]
        rq = [_Xfer(op) for op in s.reads[r]]
        streams[(r, "W")] = wq
        streams[(r, "R")] = rq
        xfers.extend(wq)
        xfers.extend(rq)
    if not xfers:
        return SimResult(0.0, {r: 0.0 for r in range(s.nranks)}, 0, 0)

    doorbell_ready: dict[tuple, float] = {}   # data_key -> time
    stream_free: dict[tuple, float] = {k: 0.0 for k in streams}
    stream_busy: dict[tuple, bool] = {k: False for k in streams}
    writes_pending = sum(len(s.writes[r]) for r in range(s.nranks))
    # for phase_barrier mode; trivially satisfied when there are no writes
    barrier_time: Optional[float] = 0.0 if writes_pending == 0 else None

    now = 0.0
    timeline: list = [] if opt.track_timeline else None
    active: list[_Xfer] = []

    def head_ready_time(key: tuple) -> Optional[float]:
        """Earliest time the stream head may become active, or None."""
        q = streams[key]
        if not q or stream_busy[key]:
            return None
        t = q[0]
        base = stream_free[key]
        if t.op.kind is sched.OpKind.READ:
            if opt.phase_barrier:
                if barrier_time is None:
                    return None
                base = max(base, barrier_time)
            db = doorbell_ready.get(t.op.data_key)
            if db is None:
                return None  # doorbell not rung yet
            # Poll quantization: the consumer sleeps between polls
            # (Listing 3), so it observes READY one poll interval late on
            # average; plus the cache-line invalidate + re-read.
            base = max(base, db + pool.poll_interval)
        # Issue overhead occupies the stream before the DMA engages.
        return base + pool.memcpy_overhead

    # Event loop.
    guard = 0
    while True:
        guard += 1
        if guard > 1_000_000:
            raise RuntimeError("simulator event-loop runaway")
        # Activate any eligible stream heads.
        changed = False
        for key, q in streams.items():
            while q:
                rt = head_ready_time(key)
                if rt is None or rt > now:
                    break
                t = q.pop(0)
                t.active = True
                t.start = now
                t.ready_time = rt
                stream_busy[key] = True
                active.append(t)
                changed = True
                break  # only one active op per stream
        if changed or active:
            _allocate_rates(active, pool)

        if not active:
            # Jump to the next activation time.
            nexts = [head_ready_time(k) for k in streams]
            nexts = [t for t in nexts if t is not None]
            if not nexts:
                if any(streams.values()):
                    stuck = {k: streams[k][0].op.data_key
                             for k in streams if streams[k]}
                    raise RuntimeError(
                        f"simulator deadlock; blocked streams: {stuck}")
                break  # all queues drained
            now = min(nexts)
            continue

        # Next completion among active transfers vs. next activation.
        dt_complete = min(t.remaining / t.rate if t.rate > 0 else math.inf
                          for t in active)
        candidates = [now + dt_complete]
        for k in streams:
            rt = head_ready_time(k)
            if rt is not None and rt > now:
                candidates.append(rt)
        t_next = min(candidates)
        dt = t_next - now
        for t in active:
            t.remaining -= t.rate * dt
        now = t_next

        # Retire completed transfers.  Sub-byte residue counts as done
        # (repeated rate*dt subtraction leaves float dust on GB transfers).
        still = []
        for t in active:
            if t.remaining <= 1e-3:
                t.done = True
                t.finish = now
                key = (t.op.rank,
                       "W" if t.op.kind is sched.OpKind.WRITE else "R")
                stream_free[key] = now
                stream_busy[key] = False
                if t.op.kind is sched.OpKind.WRITE:
                    doorbell_ready[t.op.data_key] = (
                        now + pool.doorbell_latency)
                    writes_pending -= 1
                    if writes_pending == 0:
                        barrier_time = now + pool.doorbell_latency
                if timeline is not None:
                    timeline.append((t.op.rank, t.op.kind.value,
                                     t.op.data_key, t.start, now))
            else:
                still.append(t)
        active = still

    rank_finish = {r: 0.0 for r in range(s.nranks)}
    total_bytes = 0
    for t in xfers:
        if t.finish is not None:
            rank_finish[t.op.rank] = max(rank_finish[t.op.rank], t.finish)
        total_bytes += t.op.size
    return SimResult(total_time=max(rank_finish.values(), default=0.0),
                     rank_finish=rank_finish, bytes_moved=total_bytes,
                     num_ops=len(xfers), timeline=timeline)


# ---------------------------------------------------------------------------
# CXL-CCL implementation variants (Sec. 5.1 "Baseline")
# ---------------------------------------------------------------------------

def run_variant(variant: str, primitive: str, nranks: int, msg_bytes: int,
                *, num_devices: int = 6,
                device_capacity: int = 128 * 1024**3,
                slicing_factor: int = 4, root: int = 0,
                pool: CXLPoolConfig = CXL_POOL) -> SimResult:
    """Simulate one of the paper's three implementations.

    * ``all``       - interleaving + fine-grained chunking + overlap
    * ``aggregate`` - interleaving at data-block granularity, no overlap
    * ``naive``     - sequential pool allocation, no interleave, no overlap

    ``msg_bytes`` is padded up to a multiple of ``nranks`` for the
    segmented primitives (timing-negligible, mirrors NCCL's own padding).
    """
    if primitive in ("reduce_scatter", "all_to_all") and \
            msg_bytes % nranks:
        msg_bytes += nranks - msg_bytes % nranks
    if variant == "all":
        s = sched.build(primitive, nranks, msg_bytes,
                        num_devices=num_devices,
                        device_capacity=device_capacity,
                        slicing_factor=slicing_factor, root=root)
        return simulate(s, SimOptions(pool=pool))
    if variant == "aggregate":
        s = sched.build(primitive, nranks, msg_bytes,
                        num_devices=num_devices,
                        device_capacity=device_capacity,
                        slicing_factor=1, root=root)
        return simulate(s, SimOptions(pool=pool, phase_barrier=True))
    if variant == "naive":
        s = sched.build(primitive, nranks, msg_bytes,
                        num_devices=num_devices,
                        device_capacity=device_capacity,
                        slicing_factor=1, root=root, placement="naive")
        return simulate(s, SimOptions(pool=pool, phase_barrier=True))
    raise ValueError(f"unknown variant {variant!r}")
