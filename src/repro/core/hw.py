"""Hardware constants for the CXL pool testbed (paper Sec. 5.1) and the TPU
v5e target used for the roofline analysis.

The CXL-side numbers are taken directly from the paper's characterization
(Fig. 3, Table 1, Sec. 2.2): a TITAN-II CXL 2.0 switch fronting six Micron
CZ120 cards (PCIe/CXL Gen5 x8 each), three H100 nodes on Gen5 x16 links.
"""
from __future__ import annotations

import dataclasses

GiB = 1024**3
MiB = 1024**2
KiB = 1024


@dataclasses.dataclass(frozen=True)
class CXLPoolConfig:
    """The paper's shared memory pool (Sec. 2.2, 5.1, Fig. 3)."""

    num_devices: int = 6                 # Micron CZ120 cards
    device_capacity: int = 128 * GiB     # per card -> 768 GB pool
    # Fig. 3a: sustained bandwidth saturates ~20 GB/s for >=1 MB transfers,
    # limited by the card's Gen5 x8 link (Observation 1).
    device_bw: float = 20e9              # per direction, bytes/s
    # Observation 1: the GPU's single DMA engine per direction caps each
    # *server* at the same ~20 GB/s per direction even across devices.
    server_bw: float = 20e9              # per direction, bytes/s
    # When one device serves reads and writes simultaneously the effective
    # per-direction bandwidth degrades (Fig. 3b/3c show contention effects).
    bidir_efficiency: float = 0.75
    # Table 1: 64B pool access latency (MLC) = 658 ns vs 214 ns local DRAM.
    access_latency: float = 658e-9       # seconds
    dram_latency: float = 214e-9
    # Fig. 3a ramp: small transfers are latency/overhead bound.  We model a
    # fixed per-cudaMemcpyAsync software overhead; the paper attributes the
    # small-message losses (ReduceScatter/Scatter/AllToAll < ~64 MB) to
    # "software overheads such as cudaMemcpy invocation and synchronization".
    memcpy_overhead: float = 8e-6        # seconds per issued copy
    # Doorbell cost: flush + re-read across the switch (2 pool accesses) plus
    # a short poll sleep (Listing 3 sleeps between polls).
    doorbell_latency: float = 2 * 658e-9
    poll_interval: float = 1e-6
    switch_bw: float = 2e12              # 2 TB/s max switching bandwidth

    @property
    def pool_capacity(self) -> int:
        return self.num_devices * self.device_capacity


@dataclasses.dataclass(frozen=True)
class InfiniBandConfig:
    """200 Gb/s InfiniBand baseline (paper Sec. 5.1)."""

    link_bw: float = 200e9 / 8           # 25 GB/s line rate
    efficiency: float = 0.88             # protocol + copy-RDMA pipeline
    # Per-RDMA-message overhead: the copy-RDMA pipeline needs GPU<->CPU
    # synchronization at every stage (Sec. 4.1, Fig. 4).
    message_overhead: float = 6e-6
    latency: float = 2e-6                # end-to-end small-message latency

    @property
    def effective_bw(self) -> float:
        return self.link_bw * self.efficiency


@dataclasses.dataclass(frozen=True)
class ICIConfig:
    """Intra-node / intra-pod ring interconnect (TPU-ICI-class links).

    Used by ``core.topology`` for the innermost fabric level: collectives
    there never touch the pool or the NIC, they ride the chip-to-chip
    ring.  Defaults follow the TPU v5e target below (one usable link per
    ring direction)."""

    link_bw: float = 50e9                # bytes/s per link direction
    efficiency: float = 0.95             # protocol framing
    message_overhead: float = 1e-6       # per-hop issue overhead
    latency: float = 0.5e-6              # hop latency

    @property
    def effective_bw(self) -> float:
        return self.link_bw * self.efficiency


@dataclasses.dataclass(frozen=True)
class TPUConfig:
    """TPU v5e-class target for the dry-run roofline (task spec constants)."""

    peak_flops_bf16: float = 197e12      # FLOP/s per chip
    hbm_bw: float = 819e9                # bytes/s per chip
    ici_bw: float = 50e9                 # bytes/s per link
    ici_links: int = 4                   # usable links per chip on a 2D torus
    hbm_capacity: int = 16 * GiB


@dataclasses.dataclass(frozen=True)
class CostConfig:
    """Sec. 5.5: interconnect hardware cost."""

    ib_switch_cost: float = 16_000.0     # $ for a 200 Gbps IB switch
    cxl_switch_cost: float = 5_800.0     # $ for the CXL switch

    @property
    def cost_ratio(self) -> float:
        return self.ib_switch_cost / self.cxl_switch_cost  # 2.75x


CXL_POOL = CXLPoolConfig()
INFINIBAND = InfiniBandConfig()
ICI = ICIConfig()
TPU_V5E = TPUConfig()
COST = CostConfig()
