"""Analytical alpha-beta model of the RDMA-over-InfiniBand baseline.

The paper's baseline is NCCL 2.28.3 over one 200 Gb/s IB NIC per node,
using the copy-RDMA pipeline of Fig. 4 (GPU buffer -> FIFO -> RDMA -> FIFO
-> GPU buffer, with CPU-mediated stage handover).  We model each primitive
with the standard alpha-beta cost of the algorithm NCCL uses at this scale,
plus a per-primitive efficiency factor that captures how well the
copy-RDMA pipeline drives the NIC for that traffic pattern.

The efficiency factors are *calibrated* against the paper's measured
speedups (Sec. 5.2, averaged 1 MB - 4 GB at 3 nodes); they are the only
free parameters in the whole model and are reported in EXPERIMENTS.md.
Ring-friendly N->N primitives sustain a large fraction of line rate;
rooted primitives (which NCCL lowers to p2p send/recv chains or trees over
a single NIC) sustain less - consistent with the paper finding its largest
wins exactly there (Gather 1.94x, Broadcast 1.84x, Reduce 1.70x).
"""
from __future__ import annotations

import dataclasses
import math

from repro.core.hw import INFINIBAND, InfiniBandConfig

# Per-primitive (sustained fraction of the 22 GB/s effective line rate,
# per-step pipeline latency).  Calibrated against the paper's measured mean
# speedups and range endpoints at 3 nodes (Sec. 5.2, 1 MB - 4 GB sweep);
# these are the only free parameters of the whole model and the calibration
# procedure is tests/test_paper_claims.py + benchmarks/fig9_collectives.py.
#
# The pattern that emerges from the calibration is physically sensible for
# the paper's testbed (one 200 Gb/s NIC per node, PCIe-staged copy-RDMA
# pipeline, DDIO disabled): ring N->N primitives with large per-step
# messages sustain 50-60% of line rate with small per-step latency, while
# primitives whose NCCL lowering reduces per step or serializes p2p chains
# (all_reduce, broadcast, reduce, gather) carry ~100-220 us per stage -
# exactly where the paper reports its largest wins.
EFFICIENCY: dict[str, float] = {
    "all_reduce": 0.475,
    "all_gather": 0.550,
    "reduce_scatter": 0.350,
    "all_to_all": 0.300,
    "broadcast": 0.275,
    "reduce": 0.325,
    "gather": 0.525,
    "scatter": 0.700,
}

ALPHA: dict[str, float] = {
    "all_reduce": 93.4e-6,
    "all_gather": 8.6e-6,
    "reduce_scatter": 5.0e-6,
    "all_to_all": 5.0e-6,
    "broadcast": 104.1e-6,
    "reduce": 104.1e-6,
    "gather": 222.3e-6,
    "scatter": 5.0e-6,
}


@dataclasses.dataclass(frozen=True)
class IBEstimate:
    primitive: str
    nranks: int
    msg_bytes: int
    time: float


def _pipelined_chain(bytes_: float, hops: int, bw: float,
                     alpha: float) -> float:
    """Optimal-chunk pipelined transfer through ``hops`` sequential links
    (ring broadcast/reduce): T(c) = (c + hops - 1) * (S/(c*bw) + alpha),
    minimized over the chunk count c."""
    if hops <= 0:
        return 0.0
    c_opt = max(1.0, math.sqrt((hops - 1) * bytes_ / (bw * alpha))
                if alpha > 0 else 1.0)
    return (c_opt + hops - 1) * (bytes_ / (c_opt * bw) + alpha)


def estimate(primitive: str, nranks: int, msg_bytes: int,
             ib: InfiniBandConfig = INFINIBAND) -> IBEstimate:
    """Predicted NCCL-over-IB completion time.  ``msg_bytes`` is Table 2's
    per-rank N (for scatter the root holds N*nranks)."""
    n = nranks
    s = float(msg_bytes)
    a = ALPHA[primitive]

    if n == 1:
        return IBEstimate(primitive, n, msg_bytes, 0.0)

    def bw(step_bytes: float) -> float:
        return ib.effective_bw * EFFICIENCY[primitive]

    if primitive == "all_reduce":
        # ring: 2(n-1) steps of S/n each, 2S(n-1)/n wire bytes per rank
        step = s / n
        t = 2 * (n - 1) * (a + step / bw(step))
    elif primitive == "all_gather":
        t = (n - 1) * (a + s / bw(s))
    elif primitive == "reduce_scatter":
        step = s / n
        t = (n - 1) * (a + step / bw(step))
    elif primitive == "all_to_all":
        # n-1 p2p exchanges of S/n each; NIC serializes egress
        step = s / n
        t = (n - 1) * (a + step / bw(step))
    elif primitive in ("broadcast", "reduce"):
        t = _pipelined_chain(s, n - 1, bw(s), a)
    elif primitive == "gather":
        # incast: root's NIC ingests (n-1) segments (p2p chain)
        t = (n - 1) * (a + s / bw(s))
    elif primitive == "scatter":
        # root egress of (n-1) segments
        t = (n - 1) * (a + s / bw(s))
    else:
        raise ValueError(primitive)
    return IBEstimate(primitive, n, msg_bytes, t + ib.latency)
