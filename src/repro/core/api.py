"""Communicator: the framework-facing API of the CXL-CCL reproduction.

Every collective in the training/serving stack goes through a
``Communicator`` so the backend is swappable:

* ``ring`` - ``jax.lax`` built-ins (what XLA/NCCL would do; the baseline).
* ``cxl``  - the paper's schedules realized as chunked ppermute rounds
             (``core.mesh_collectives``), with the slicing factor and the
             faithful-vs-two-phase AllReduce both selectable.
* ``auto`` - per-call selection from an autotuning ``Plan``
             (``repro.tuner``): each (primitive, message size, axis size)
             resolves, at trace time, to the predicted-fastest
             (backend, slicing_factor, allreduce_mode) under the offline
             cost model, and the ledger records the decision taken.

Axes may be a single name or a tuple (e.g. ``("pod", "data")`` for the
multi-pod FSDP axis); tuple axes are handled hierarchically, innermost
axis first - on the real cluster that is "within the rack-scale CXL pool
first, across pods second", matching the paper's expectation that one pool
spans a small number of nodes (Sec. 5.3).  Under ``auto`` each level of
the hierarchy is tuned independently (the axis sizes differ).
"""
from __future__ import annotations

import dataclasses
from typing import TYPE_CHECKING, Optional, Sequence, Union

import jax
import jax.numpy as jnp
from jax import lax

from repro.core import ledger
from repro.core import mesh_collectives as mc

if TYPE_CHECKING:                     # avoid import cycle at runtime
    from repro.tuner.plan import Plan

AxisSpec = Union[str, Sequence[str]]

BACKENDS = ("ring", "cxl", "auto")


def _axes(axis: AxisSpec) -> tuple[str, ...]:
    return (axis,) if isinstance(axis, str) else tuple(axis)


@dataclasses.dataclass(frozen=True)
class Communicator:
    backend: str = "ring"
    slicing_factor: int = mc.DEFAULT_CHUNKS
    allreduce_mode: str = "two_phase"   # 'faithful' reproduces Sec. 5.2
    # Autotuning plan for backend='auto'; falls back to the process-wide
    # active plan (repro.tuner.runtime) when None.  Excluded from
    # eq/hash: the plan only steers trace-time dispatch.
    plan: Optional["Plan"] = dataclasses.field(
        default=None, compare=False, repr=False)

    def __post_init__(self):
        if self.backend not in BACKENDS:
            raise ValueError(f"backend must be one of {BACKENDS}")
        if self.allreduce_mode not in ("faithful", "two_phase"):
            raise ValueError("allreduce_mode: 'faithful' or 'two_phase'")
        if not isinstance(self.slicing_factor, int) or \
                isinstance(self.slicing_factor, bool) or \
                self.slicing_factor < 1:
            raise ValueError(
                f"slicing_factor must be an integer >= 1, got "
                f"{self.slicing_factor!r}")

    # -- plan resolution --------------------------------------------------

    def _choice(self, primitive: str, msg_bytes: int,
                n: int) -> tuple[str, int, str, bool]:
        """Resolve (backend, slicing_factor, allreduce_mode, overlap) for
        one collective call.  Static under ``jit`` (sizes and axis sizes
        are trace-time constants), so this costs nothing at run time.
        ``overlap`` is True when an overlap-aware plan tuned this cell
        against the compute it expects to hide behind; the ledger then
        books the wire bytes as hidden rather than exposed."""
        if self.backend != "auto":
            return (self.backend, self.slicing_factor,
                    self.allreduce_mode, False)
        plan = self.plan
        if plan is None:
            from repro.tuner import runtime as tuner_runtime
            plan = tuner_runtime.ensure_default_plan()
        ch = plan.lookup(primitive, msg_bytes, n)
        if ch is None:     # primitive absent from the plan: ring baseline
            backend, factor, mode, overlap = (
                "ring", self.slicing_factor, self.allreduce_mode, False)
        else:
            backend, factor, mode, overlap = (
                ch.backend, ch.slicing_factor, ch.allreduce_mode,
                ch.overlap)
        ledger.record_choice(primitive, msg_bytes, n, backend, factor,
                             mode, overlap=overlap)
        return backend, factor, mode, overlap

    # -- N->N primitives (the FSDP / TP / MoE hot path) ------------------

    def all_reduce(self, x: jnp.ndarray, axis: AxisSpec) -> jnp.ndarray:
        s = ledger.nbytes(x)
        if self.backend == "ring":
            # single fused psum over the whole (possibly tuple) axis: one
            # reduction order, matching XLA's own lowering exactly
            for ax in _axes(axis):
                n = lax.axis_size(ax)
                ledger.record("all_reduce", 2 * s * (n - 1) / n)
            return lax.psum(x, axis if isinstance(axis, str)
                            else tuple(axis))
        out = x
        for ax in _axes(axis):  # innermost (pool-local) axis first
            n = lax.axis_size(ax)
            backend, factor, mode, ov = self._choice("all_reduce", s, n)
            wire = s * (n - 1) if mode == "faithful" and \
                backend == "cxl" else 2 * s * (n - 1) / n
            ledger.record("all_reduce", wire,
                          hidden=True if ov else None)
            if backend == "ring":
                out = lax.psum(out, ax)
            else:
                out = mc.all_reduce(out, ax, mode=mode, n_chunks=factor)
        return out

    def all_gather(self, x: jnp.ndarray, axis: AxisSpec) -> jnp.ndarray:
        """Tiled gather along axis 0, rank-major over the (possibly
        hierarchical) axis spec: outer axis index is most significant."""
        axes = _axes(axis)
        out = x
        # Inner (minor, pool-local) axis first; the outer gather then
        # stacks whole pool-level blocks, matching P((outer, inner)) layout.
        for ax in reversed(axes):
            n = lax.axis_size(ax)
            s = ledger.nbytes(out)
            backend, factor, _, ov = self._choice("all_gather", s, n)
            ledger.record("all_gather", s * (n - 1),
                          hidden=True if ov else None)
            if backend == "ring":
                out = lax.all_gather(out, ax, tiled=True)
            else:
                out = mc.all_gather(out, ax, n_chunks=factor)
        return out

    def reduce_scatter(self, x: jnp.ndarray, axis: AxisSpec) -> jnp.ndarray:
        """Reduce-scatter along axis 0, the inverse layout of all_gather
        (outer axis most significant)."""
        axes = _axes(axis)
        out = x
        for ax in axes:  # outer axis first: inverse of gather
            n = lax.axis_size(ax)
            s = ledger.nbytes(out)
            backend, factor, _, ov = self._choice("reduce_scatter", s, n)
            ledger.record("reduce_scatter", s * (n - 1) / n,
                          hidden=True if ov else None)
            if backend == "ring":
                out = lax.psum_scatter(out, ax, scatter_dimension=0,
                                       tiled=True)
            else:
                out = mc.reduce_scatter(out, ax, n_chunks=factor)
        return out

    def all_to_all(self, x: jnp.ndarray, axis: AxisSpec) -> jnp.ndarray:
        axes = _axes(axis)
        if len(axes) != 1:
            raise NotImplementedError("all_to_all is single-axis")
        ax = axes[0]
        n_ = lax.axis_size(ax)
        s = ledger.nbytes(x)
        backend, factor, _, ov = self._choice("all_to_all", s, n_)
        ledger.record("all_to_all", s * (n_ - 1) / n_,
                      hidden=True if ov else None)
        if backend == "ring":
            n = n_
            if x.shape[0] % n:
                raise ValueError("leading dim must divide axis size")
            segs = x.reshape((n, x.shape[0] // n) + x.shape[1:])
            out = lax.all_to_all(segs, ax, split_axis=0, concat_axis=0,
                                 tiled=False)
            return out.reshape(x.shape)
        return mc.all_to_all(x, ax, n_chunks=factor)

    # -- rooted primitives ------------------------------------------------

    def broadcast(self, x: jnp.ndarray, axis: AxisSpec,
                  root: int = 0) -> jnp.ndarray:
        axes = _axes(axis)
        if len(axes) != 1:
            raise NotImplementedError("broadcast is single-axis")
        ax = axes[0]
        n_ = lax.axis_size(ax)
        backend, factor, _, ov = self._choice("broadcast",
                                              ledger.nbytes(x), n_)
        ledger.record("broadcast", ledger.nbytes(x),
                      hidden=True if ov else None)
        if backend == "ring":
            idx = lax.axis_index(ax)
            masked = jnp.where(idx == root, x, jnp.zeros_like(x))
            return lax.psum(masked, ax)
        return mc.broadcast(x, ax, root=root, n_chunks=factor)

    def reduce(self, x: jnp.ndarray, axis: AxisSpec,
               root: int = 0) -> jnp.ndarray:
        axes = _axes(axis)
        if len(axes) != 1:
            raise NotImplementedError("reduce is single-axis")
        ax = axes[0]
        n_ = lax.axis_size(ax)
        s = ledger.nbytes(x)
        backend, factor, _, ov = self._choice("reduce", s, n_)
        ledger.record("reduce", 2 * s * (n_ - 1) / n_,
                      hidden=True if ov else None)
        if backend == "ring":
            idx = lax.axis_index(ax)
            total = lax.psum(x, ax)
            return jnp.where(idx == root, total, jnp.zeros_like(total))
        return mc.reduce(x, ax, root=root, n_chunks=factor)

    def gather(self, x: jnp.ndarray, axis: AxisSpec,
               root: int = 0) -> jnp.ndarray:
        axes = _axes(axis)
        if len(axes) != 1:
            raise NotImplementedError("gather is single-axis")
        ax = axes[0]
        n_ = lax.axis_size(ax)
        s = ledger.nbytes(x)
        backend, factor, _, ov = self._choice("gather", s, n_)
        ledger.record("gather", s * (n_ - 1),
                      hidden=True if ov else None)
        if backend == "ring":
            idx = lax.axis_index(ax)
            full = lax.all_gather(x, ax, tiled=True)
            return jnp.where(idx == root, full, jnp.zeros_like(full))
        return mc.gather(x, ax, root=root, n_chunks=factor)

    def scatter(self, x: jnp.ndarray, axis: AxisSpec,
                root: int = 0) -> jnp.ndarray:
        axes = _axes(axis)
        if len(axes) != 1:
            raise NotImplementedError("scatter is single-axis")
        ax = axes[0]
        n_ = lax.axis_size(ax)
        s = ledger.nbytes(x)
        backend, factor, _, ov = self._choice("scatter", s, n_)
        # root pushes every segment but its own: s*(n-1)/n wire bytes
        ledger.record("scatter", s * (n_ - 1) / n_,
                      hidden=True if ov else None)
        if backend == "ring":
            n = n_
            idx = lax.axis_index(ax)
            rooted = self.broadcast(x, ax, root=root)
            segs = rooted.reshape((n, x.shape[0] // n) + x.shape[1:])
            return lax.dynamic_index_in_dim(segs, idx, 0, keepdims=False)
        return mc.scatter(x, ax, root=root, n_chunks=factor)


def make_communicator(backend: str = "ring", *, slicing_factor: int = 4,
                      allreduce_mode: str = "two_phase",
                      plan: Optional["Plan"] = None) -> Communicator:
    return Communicator(backend=backend, slicing_factor=slicing_factor,
                        allreduce_mode=allreduce_mode, plan=plan)
