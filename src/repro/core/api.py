"""Communicator: the framework-facing API of the CXL-CCL reproduction.

Every collective in the training/serving stack goes through a
``Communicator`` so the backend is swappable:

* ``ring`` - ``jax.lax`` built-ins (what XLA/NCCL would do; the baseline).
* ``cxl``  - the paper's schedules realized as chunked ppermute rounds
             (``core.mesh_collectives``), with the slicing factor and the
             faithful-vs-two-phase AllReduce both selectable.

Axes may be a single name or a tuple (e.g. ``("pod", "data")`` for the
multi-pod FSDP axis); tuple axes are handled hierarchically, innermost
axis first - on the real cluster that is "within the rack-scale CXL pool
first, across pods second", matching the paper's expectation that one pool
spans a small number of nodes (Sec. 5.3).
"""
from __future__ import annotations

import dataclasses
from typing import Sequence, Union

import jax
import jax.numpy as jnp
from jax import lax

from repro.core import ledger
from repro.core import mesh_collectives as mc

AxisSpec = Union[str, Sequence[str]]

BACKENDS = ("ring", "cxl")


def _axes(axis: AxisSpec) -> tuple[str, ...]:
    return (axis,) if isinstance(axis, str) else tuple(axis)


@dataclasses.dataclass(frozen=True)
class Communicator:
    backend: str = "ring"
    slicing_factor: int = mc.DEFAULT_CHUNKS
    allreduce_mode: str = "two_phase"   # 'faithful' reproduces Sec. 5.2

    def __post_init__(self):
        if self.backend not in BACKENDS:
            raise ValueError(f"backend must be one of {BACKENDS}")
        if self.allreduce_mode not in ("faithful", "two_phase"):
            raise ValueError("allreduce_mode: 'faithful' or 'two_phase'")

    # -- N->N primitives (the FSDP / TP / MoE hot path) ------------------

    def all_reduce(self, x: jnp.ndarray, axis: AxisSpec) -> jnp.ndarray:
        s = ledger.nbytes(x)
        for ax in _axes(axis):
            n = lax.axis_size(ax)
            wire = s * (n - 1) if self.allreduce_mode == "faithful" and \
                self.backend == "cxl" else 2 * s * (n - 1) / n
            ledger.record("all_reduce", wire)
        if self.backend == "ring":
            return lax.psum(x, axis if isinstance(axis, str)
                            else tuple(axis))
        out = x
        for ax in _axes(axis):  # innermost (pool-local) axis first
            out = mc.all_reduce(out, ax, mode=self.allreduce_mode,
                                n_chunks=self.slicing_factor)
        return out

    def all_gather(self, x: jnp.ndarray, axis: AxisSpec) -> jnp.ndarray:
        """Tiled gather along axis 0, rank-major over the (possibly
        hierarchical) axis spec: outer axis index is most significant."""
        axes = _axes(axis)
        out = x
        # Inner (minor, pool-local) axis first; the outer gather then
        # stacks whole pool-level blocks, matching P((outer, inner)) layout.
        for ax in reversed(axes):
            n = lax.axis_size(ax)
            ledger.record("all_gather", ledger.nbytes(out) * (n - 1))
            if self.backend == "ring":
                out = lax.all_gather(out, ax, tiled=True)
            else:
                out = mc.all_gather(out, ax,
                                    n_chunks=self.slicing_factor)
        return out

    def reduce_scatter(self, x: jnp.ndarray, axis: AxisSpec) -> jnp.ndarray:
        """Reduce-scatter along axis 0, the inverse layout of all_gather
        (outer axis most significant)."""
        axes = _axes(axis)
        out = x
        for ax in axes:  # outer axis first: inverse of gather
            n = lax.axis_size(ax)
            ledger.record("reduce_scatter",
                          ledger.nbytes(out) * (n - 1) / n)
            if self.backend == "ring":
                out = lax.psum_scatter(out, ax, scatter_dimension=0,
                                       tiled=True)
            else:
                out = mc.reduce_scatter(out, ax,
                                        n_chunks=self.slicing_factor)
        return out

    def all_to_all(self, x: jnp.ndarray, axis: AxisSpec) -> jnp.ndarray:
        axes = _axes(axis)
        if len(axes) != 1:
            raise NotImplementedError("all_to_all is single-axis")
        ax = axes[0]
        n_ = lax.axis_size(ax)
        ledger.record("all_to_all", ledger.nbytes(x) * (n_ - 1) / n_)
        if self.backend == "ring":
            n = lax.axis_size(ax)
            if x.shape[0] % n:
                raise ValueError("leading dim must divide axis size")
            segs = x.reshape((n, x.shape[0] // n) + x.shape[1:])
            out = lax.all_to_all(segs, ax, split_axis=0, concat_axis=0,
                                 tiled=False)
            return out.reshape(x.shape)
        return mc.all_to_all(x, ax, n_chunks=self.slicing_factor)

    # -- rooted primitives ------------------------------------------------

    def broadcast(self, x: jnp.ndarray, axis: AxisSpec,
                  root: int = 0) -> jnp.ndarray:
        axes = _axes(axis)
        if len(axes) != 1:
            raise NotImplementedError("broadcast is single-axis")
        ax = axes[0]
        ledger.record("broadcast", ledger.nbytes(x))
        if self.backend == "ring":
            idx = lax.axis_index(ax)
            masked = jnp.where(idx == root, x, jnp.zeros_like(x))
            return lax.psum(masked, ax)
        return mc.broadcast(x, ax, root=root, n_chunks=self.slicing_factor)

    def reduce(self, x: jnp.ndarray, axis: AxisSpec,
               root: int = 0) -> jnp.ndarray:
        axes = _axes(axis)
        if len(axes) != 1:
            raise NotImplementedError("reduce is single-axis")
        ax = axes[0]
        n_ = lax.axis_size(ax)
        ledger.record("reduce", 2 * ledger.nbytes(x) * (n_ - 1) / n_)
        if self.backend == "ring":
            idx = lax.axis_index(ax)
            total = lax.psum(x, ax)
            return jnp.where(idx == root, total, jnp.zeros_like(total))
        return mc.reduce(x, ax, root=root, n_chunks=self.slicing_factor)

    def gather(self, x: jnp.ndarray, axis: AxisSpec,
               root: int = 0) -> jnp.ndarray:
        axes = _axes(axis)
        if len(axes) != 1:
            raise NotImplementedError("gather is single-axis")
        ax = axes[0]
        n_ = lax.axis_size(ax)
        ledger.record("gather", ledger.nbytes(x) * (n_ - 1))
        if self.backend == "ring":
            idx = lax.axis_index(ax)
            full = lax.all_gather(x, ax, tiled=True)
            return jnp.where(idx == root, full, jnp.zeros_like(full))
        return mc.gather(x, ax, root=root, n_chunks=self.slicing_factor)

    def scatter(self, x: jnp.ndarray, axis: AxisSpec,
                root: int = 0) -> jnp.ndarray:
        axes = _axes(axis)
        if len(axes) != 1:
            raise NotImplementedError("scatter is single-axis")
        ax = axes[0]
        if self.backend == "ring":
            n = lax.axis_size(ax)
            idx = lax.axis_index(ax)
            rooted = self.broadcast(x, ax, root=root)
            segs = rooted.reshape((n, x.shape[0] // n) + x.shape[1:])
            return lax.dynamic_index_in_dim(segs, idx, 0, keepdims=False)
        return mc.scatter(x, ax, root=root, n_chunks=self.slicing_factor)


def make_communicator(backend: str = "ring", *, slicing_factor: int = 4,
                      allreduce_mode: str = "two_phase") -> Communicator:
    return Communicator(backend=backend, slicing_factor=slicing_factor,
                        allreduce_mode=allreduce_mode)
