"""Communicator: the framework-facing API of the CXL-CCL reproduction.

Every collective in the training/serving stack goes through a
``Communicator`` so the backend is swappable:

* ``ring`` - ``jax.lax`` built-ins (what XLA/NCCL would do; the baseline).
* ``cxl``  - the paper's schedules realized as chunked ppermute rounds
             (``core.mesh_collectives``), with the slicing factor and the
             faithful-vs-two-phase AllReduce both selectable.
* ``auto`` - per-call selection from an autotuning ``Plan``
             (``repro.tuner``): each (primitive, message size, axis size)
             resolves, at trace time, to the predicted-fastest
             (backend, slicing_factor, allreduce_mode) under the offline
             cost model, and the ledger records the decision taken.

Axes may be a single name or a tuple (e.g. ``("pod", "node", "gpu")``),
ordered outermost level first - rank-major, matching the repo's layout
convention.  Tuple axes decompose *hierarchically* against the active
``core.topology.Topology`` (explicit ``topology=`` field, else the
process-wide active topology, else the one embedded in an ``auto``
plan's metadata):

* AllReduce = ReduceScatter down the inner levels, AllReduce across the
  outermost level on the 1/prod(inner) shard, AllGather back out - each
  byte crosses the slow pool-spanning fabric once instead of the full
  payload crossing at every level;
* Broadcast = Scatter within the root's inner group, Broadcast of the
  1/prod(inner) pieces across the outer level, AllGather within every
  inner group (per-level roots derived from the flat rank-major root);
* Gather/Scatter/Reduce recurse with per-level roots so only one level
  carries cross-pool traffic.

Under ``auto``, every level resolves independently against the plan
cell keyed by (primitive, size, axis size, level, fabric fingerprint),
and the ledger attributes wire bytes to the level/fabric that carries
them.  Without a topology, tuple axes fall back to the flat per-level
recursion for ``ring`` (a single fused ``psum``) and to the same
hierarchical decomposition - untagged - for ``cxl``/``auto``.

**Irregular (ragged) levels**: a topology level with a grouped shape
vector (``Level(shape=(4, 2))`` - mixed per-pod fan-out) lives on one
*flat* mesh axis of ``sum(shape)`` ranks.  AllReduce / ReduceScatter /
AllGather / Gather over such an axis decompose into the grouped
schedules of ``core.mesh_collectives`` (within-group masked rings on
this level's fabric, a per-pod sub-root exchange on the *parent*
level's fabric, padding-free assembly), with the ledger attributing
the cross-group bytes to the parent level.  In particular the ragged
``reduce_scatter`` keeps the hierarchical decomposition for ragged
FSDP grad-sync and the two-phase AllReduce - there is no flat
fallback left for RS/AR.  The grouped schedules are ppermute programs
regardless of the resolved backend (``lax.psum`` cannot reduce over a
subgroup of a named axis), so on ragged levels the plan's choice
steers the slicing factor and the audit, not the lowering.  The
primitives that still run the flat single-axis path on a ragged axis
(all_to_all, broadcast, reduce, scatter) are numerically correct but
hierarchy-blind, and every such call books an explicit
``ledger.record_fallback`` event - never a silent degradation.

**Point-to-point** (pipeline parallelism): ``send``/``recv`` move one
full payload one ring hop along an axis - the stage-boundary
activation/grad handoff of ``training.pipeline``.  Two backends: ``cxl``
writes the payload to the pool and commits it with a doorbell ring
(``core/doorbell.py``; the consumer invalidates, polls and reads), and
``ring`` is the plain NIC/ICI transfer.  On the TPU mesh both lower to
``lax.ppermute`` - data dependence of the permute chain enforces the
RAW ordering the doorbell protects, so (as with the ragged schedules)
the plan's per-(size bucket, level) ``p2p`` cell steers the slicing
factor and the audit, not the lowering.  Wire bytes are S per rank per
hop, attributed to the level/fabric that carries them.

**Fused kernels**: plan cells carry a ``fused`` knob (format v5) - the
tuner's prediction that the collective's epilogue/prologue compute is
worth folding into the transfer (``kernels.fused_collectives``).  The
resolved flag rides the audit trail and the ledger's fused-byte split;
the training stack acts on it through ``TrainConfig.fuse_kernels``
(the FSDP gather feeds ``layers.dense`` rank-major shard stacks that
``kernels.ops.fused_dense`` consumes).
"""
from __future__ import annotations

import dataclasses
from typing import TYPE_CHECKING, Optional, Sequence, Union

import jax.numpy as jnp
from jax import lax

from repro.core import ledger
from repro.core import mesh_collectives as mc
from repro.core import topology as topo_mod

if TYPE_CHECKING:                     # avoid import cycle at runtime
    from repro.tuner.plan import Plan

AxisSpec = Union[str, Sequence[str]]

BACKENDS = ("ring", "cxl", "auto")


def _axes(axis: AxisSpec) -> tuple[str, ...]:
    return (axis,) if isinstance(axis, str) else tuple(axis)


@dataclasses.dataclass(frozen=True)
class Communicator:
    backend: str = "ring"
    slicing_factor: int = mc.DEFAULT_CHUNKS
    allreduce_mode: str = "two_phase"   # 'faithful' reproduces Sec. 5.2
    # Autotuning plan for backend='auto'; falls back to the process-wide
    # active plan (repro.tuner.runtime) when None.  Excluded from
    # eq/hash: the plan only steers trace-time dispatch.
    plan: Optional["Plan"] = dataclasses.field(
        default=None, compare=False, repr=False)
    # Cluster topology for hierarchical decomposition of tuple axes;
    # falls back to the process-wide active topology
    # (core.topology.set_active_topology), then to the topology embedded
    # in the plan's metadata.  Part of eq/hash: it changes the traced
    # collective structure, not just which plan cell resolves.
    topology: Optional[topo_mod.Topology] = None

    def __post_init__(self):
        if self.backend not in BACKENDS:
            raise ValueError(f"backend must be one of {BACKENDS}")
        if self.allreduce_mode not in ("faithful", "two_phase"):
            raise ValueError("allreduce_mode: 'faithful' or 'two_phase'")
        if not isinstance(self.slicing_factor, int) or \
                isinstance(self.slicing_factor, bool) or \
                self.slicing_factor < 1:
            raise ValueError(
                f"slicing_factor must be an integer >= 1, got "
                f"{self.slicing_factor!r}")

    # -- topology / plan resolution ---------------------------------------

    def _topo(self) -> Optional[topo_mod.Topology]:
        if self.topology is not None:
            return self.topology
        active = topo_mod.get_active_topology()
        if active is not None:
            return active
        if self.backend == "auto" and self.plan is not None:
            return self.plan.topology()
        return None

    def _choice(self, primitive: str, msg_bytes: int, n: int,
                topo: Optional[topo_mod.Topology] = None,
                ax: Optional[str] = None
                ) -> tuple[str, int, str, bool, bool]:
        """Resolve (backend, slicing_factor, allreduce_mode, overlap,
        fused) for one collective call at one topology level.  Static
        under ``jit`` (sizes and axis sizes are trace-time constants),
        so this costs nothing at run time.  ``overlap`` is True when an
        overlap-aware plan tuned this cell against the compute it
        expects to hide behind; the ledger then books the wire bytes as
        hidden.  ``fused`` is True when the plan priced the cell with
        its epilogue/prologue compute folded into a fused kernel
        (``kernels.fused_collectives``); the flag rides the audit trail
        and tags the wire bytes into the ledger's fused split."""
        if self.backend != "auto":
            return (self.backend, self.slicing_factor,
                    self.allreduce_mode, False, False)
        plan = self.plan
        epoch = None
        if plan is None:
            # Resolve against the epoch-versioned registry: a hot-swap
            # (tuner.online) replaces the active plan between steps and
            # the next trace picks the new one up here, with the epoch
            # stamped into the audit so runs can attribute every
            # decision to the plan generation that made it.
            from repro.tuner import runtime as tuner_runtime
            plan, epoch = tuner_runtime.get_active_plan_versioned()
            if plan is None:
                plan = tuner_runtime.ensure_default_plan(topology=topo)
                epoch = tuner_runtime.plan_epoch()
        level = topo.level_for(ax) if (topo is not None and ax) else None
        lkey = topo.level_key(ax) if level is not None else None
        ch = plan.lookup(primitive, msg_bytes, n, level=lkey)
        if ch is None:     # primitive absent from the plan: ring baseline
            backend, factor, mode, overlap, fz = (
                "ring", self.slicing_factor, self.allreduce_mode, False,
                False)
            pred = base = 0.0
        else:
            backend, factor, mode, overlap = (
                ch.backend, ch.slicing_factor, ch.allreduce_mode,
                ch.overlap)
            fz = bool(getattr(ch, "fused", False))
            # measured-over-oracle: a refined (v4) plan cell's measured
            # EWMA is a better per-launch estimate than the oracle, so
            # the audit (and everything downstream of it: step-time
            # apportioning, dry-run deltas) prices with it - gated by
            # the sample threshold the refreshing tuner recorded, so a
            # below-threshold sample persisted for warm-start does not
            # override the oracle here
            ms = (plan.meta.get("online") or {}).get("min_samples", 1)
            pred, base = ch.effective_time(ms), ch.baseline_time
        if level is not None and backend not in level.backends():
            # a flat (level-agnostic) cell can resolve under a topology
            # via the lookup fallback, but the pool schedule does not
            # exist off the pool: never drive an ib/ici level with it
            backend = "ring"
        ledger.record_choice(
            primitive, msg_bytes, n, backend, factor, mode,
            overlap=overlap, fused=fz,
            level=ax if level is not None else None,
            fabric=level.fabric if level is not None else None,
            predicted_time=pred, baseline_time=base, plan_epoch=epoch)
        return backend, factor, mode, overlap, fz

    def _rec(self, kind: str, wire: float, ov: bool,
             topo: Optional[topo_mod.Topology], ax: str,
             fz: bool = False) -> None:
        level = topo.level_for(ax) if topo is not None else None
        ledger.record(kind, wire, hidden=True if ov else None,
                      fused=True if fz else None,
                      level=ax if level is not None else None,
                      fabric=level.fabric if level is not None else None)

    # -- ragged (grouped-level) dispatch ----------------------------------

    @staticmethod
    def _grouped_level(topo: Optional[topo_mod.Topology], ax: str):
        """The Level for ``ax`` when it declares more than one rank
        group (the irregular-topology case), else None."""
        if topo is None:
            return None
        lv = topo.level_for(ax)
        return lv if lv is not None and lv.grouped else None

    @staticmethod
    def _cross_axis(topo: topo_mod.Topology, ax: str) -> str:
        """The level whose fabric carries a ragged axis's cross-group
        (sub-root) traffic: the parent level, or the level itself when
        it is outermost."""
        parent = topo.parent_of(ax)
        return parent.axis if parent is not None else ax

    def _ar_ragged(self, x: jnp.ndarray, ax: str,
                   topo: topo_mod.Topology, level) -> jnp.ndarray:
        shape = level.shape
        s = ledger.nbytes(x)
        max_g, n_g = max(shape), len(shape)
        pax = self._cross_axis(topo, ax)
        _, f_in, _, ov_in, _ = self._choice("all_reduce", s, max_g,
                                            topo, ax)
        _, f_x, _, ov_x, _ = self._choice("all_reduce", s, n_g, topo,
                                          pax)
        # within-group masked ring reads every peer's buffer (faithful
        # schedule): s*(g-1) on this level's fabric; the sub-root
        # exchange and fan-out ride the parent fabric / group rings.
        self._rec("all_reduce", s * (max_g - 1), ov_in, topo, ax)
        self._rec("all_reduce", s * (n_g - 1), ov_x, topo, pax)
        self._rec("broadcast", float(s), ov_in, topo, ax)
        y = mc.grouped_all_reduce(x, ax, shape, n_chunks=f_in)
        z = mc.subroot_all_reduce(y, ax, shape, n_chunks=f_x)
        return mc.grouped_broadcast(z, ax, shape, n_chunks=f_in)

    def _ag_ragged(self, x: jnp.ndarray, ax: str,
                   topo: topo_mod.Topology, level) -> jnp.ndarray:
        shape = level.shape
        s = ledger.nbytes(x)
        max_g, n_g, n = max(shape), len(shape), sum(shape)
        pax = self._cross_axis(topo, ax)
        _, f_in, _, ov_in, fz = self._choice("all_gather", s, max_g,
                                             topo, ax)
        _, f_x, _, ov_x, _ = self._choice("all_gather", s * max_g, n_g,
                                          topo, pax)
        self._rec("all_gather", s * (max_g - 1), ov_in, topo, ax, fz)
        self._rec("all_gather", s * n * (n_g - 1), ov_x, topo, pax, fz)
        self._rec("broadcast", float(s * n), ov_in, topo, ax)
        return mc.ragged_all_gather(x, ax, shape, n_chunks=f_in,
                                    cross_chunks=f_x)

    def _gather_ragged(self, x: jnp.ndarray, ax: str, root: int,
                       topo: topo_mod.Topology, level) -> jnp.ndarray:
        shape = level.shape
        s = ledger.nbytes(x)
        max_g, n_g, n = max(shape), len(shape), sum(shape)
        pax = self._cross_axis(topo, ax)
        _, f_in, _, ov_in, _ = self._choice("gather", s, max_g, topo,
                                            ax)
        _, f_x, _, ov_x, _ = self._choice("gather", s * max_g, n_g,
                                          topo, pax)
        self._rec("gather", s * (max_g - 1), ov_in, topo, ax)
        self._rec("gather", s * n * (n_g - 1), ov_x, topo, pax)
        return mc.ragged_gather(x, ax, shape, root=root, n_chunks=f_in,
                                cross_chunks=f_x)

    def _rs_ragged(self, x: jnp.ndarray, ax: str,
                   topo: topo_mod.Topology, level) -> jnp.ndarray:
        shape = level.shape
        s = ledger.nbytes(x)
        max_g, n_g = max(shape), len(shape)
        pax = self._cross_axis(topo, ax)
        _, f_in, _, ov_in, fz = self._choice("reduce_scatter", s, max_g,
                                             topo, ax)
        _, f_x, _, ov_x, _ = self._choice("reduce_scatter", s, n_g,
                                          topo, pax)
        # hierarchical padding-free RS: within-group masked rings sum the
        # full partial buffer on this level's fabric (s*(max_g-1)), the
        # sub-root exchange completes every segment across groups on the
        # parent fabric (s*(n_g-1)), and the fan-out + traced-offset
        # slice rides the group rings again (s).  No rank ever pads to a
        # power-of-two group or falls back to the flat schedule.
        self._rec("reduce_scatter", s * (max_g - 1), ov_in, topo, ax,
                  fz)
        self._rec("reduce_scatter", s * (n_g - 1), ov_x, topo, pax, fz)
        self._rec("broadcast", float(s), ov_in, topo, ax)
        return mc.ragged_reduce_scatter(x, ax, shape, n_chunks=f_in,
                                        cross_chunks=f_x)

    def _ar_axis(self, x: jnp.ndarray, ax: str,
                 topo: Optional[topo_mod.Topology]) -> jnp.ndarray:
        lv = self._grouped_level(topo, ax)
        if lv is not None:
            return self._ar_ragged(x, ax, topo, lv)
        return self._ar_level(x, ax, topo)

    def _rs_axis(self, x: jnp.ndarray, ax: str,
                 topo: Optional[topo_mod.Topology]) -> jnp.ndarray:
        lv = self._grouped_level(topo, ax)
        if lv is not None:
            return self._rs_ragged(x, ax, topo, lv)
        return self._rs_level(x, ax, topo)

    def _ag_axis(self, x: jnp.ndarray, ax: str,
                 topo: Optional[topo_mod.Topology]) -> jnp.ndarray:
        lv = self._grouped_level(topo, ax)
        if lv is not None:
            return self._ag_ragged(x, ax, topo, lv)
        return self._ag_level(x, ax, topo)

    # -- per-level single-axis dispatchers --------------------------------

    def _ar_level(self, x: jnp.ndarray, ax: str,
                  topo: Optional[topo_mod.Topology]) -> jnp.ndarray:
        n = lax.axis_size(ax)
        s = ledger.nbytes(x)
        backend, factor, mode, ov, _ = self._choice("all_reduce", s, n,
                                                    topo, ax)
        wire = s * (n - 1) if mode == "faithful" and backend == "cxl" \
            else 2 * s * (n - 1) / n
        self._rec("all_reduce", wire, ov, topo, ax)
        if backend == "ring":
            return lax.psum(x, ax)
        return mc.all_reduce(x, ax, mode=mode, n_chunks=factor)

    def _rs_level(self, x: jnp.ndarray, ax: str,
                  topo: Optional[topo_mod.Topology]) -> jnp.ndarray:
        n = lax.axis_size(ax)
        s = ledger.nbytes(x)
        backend, factor, _, ov, fz = self._choice("reduce_scatter", s,
                                                  n, topo, ax)
        self._rec("reduce_scatter", s * (n - 1) / n, ov, topo, ax, fz)
        if backend == "ring":
            return lax.psum_scatter(x, ax, scatter_dimension=0,
                                    tiled=True)
        return mc.reduce_scatter(x, ax, n_chunks=factor)

    def _ag_level(self, x: jnp.ndarray, ax: str,
                  topo: Optional[topo_mod.Topology]) -> jnp.ndarray:
        n = lax.axis_size(ax)
        s = ledger.nbytes(x)
        backend, factor, _, ov, fz = self._choice("all_gather", s, n,
                                                  topo, ax)
        self._rec("all_gather", s * (n - 1), ov, topo, ax, fz)
        if backend == "ring":
            return lax.all_gather(x, ax, tiled=True)
        return mc.all_gather(x, ax, n_chunks=factor)

    def _broadcast_level(self, x: jnp.ndarray, ax: str, root: int,
                         topo: Optional[topo_mod.Topology]) -> jnp.ndarray:
        n = lax.axis_size(ax)
        if n == 1:
            return x
        lv = self._grouped_level(topo, ax)
        if lv is not None:
            ledger.record_fallback("broadcast", level=ax,
                                   fabric=lv.fabric)
        s = ledger.nbytes(x)
        backend, factor, _, ov, _ = self._choice("broadcast", s, n,
                                                 topo, ax)
        self._rec("broadcast", float(s), ov, topo, ax)
        if backend == "ring":
            idx = lax.axis_index(ax)
            masked = jnp.where(idx == root, x, jnp.zeros_like(x))
            return lax.psum(masked, ax)
        return mc.broadcast(x, ax, root=root, n_chunks=factor)

    def _reduce_level(self, x: jnp.ndarray, ax: str, root: int,
                      topo: Optional[topo_mod.Topology]) -> jnp.ndarray:
        n = lax.axis_size(ax)
        if n == 1:
            return x
        lv = self._grouped_level(topo, ax)
        if lv is not None:
            ledger.record_fallback("reduce", level=ax,
                                   fabric=lv.fabric)
        s = ledger.nbytes(x)
        backend, factor, _, ov, _ = self._choice("reduce", s, n, topo,
                                                 ax)
        self._rec("reduce", 2 * s * (n - 1) / n, ov, topo, ax)
        if backend == "ring":
            idx = lax.axis_index(ax)
            total = lax.psum(x, ax)
            return jnp.where(idx == root, total, jnp.zeros_like(total))
        return mc.reduce(x, ax, root=root, n_chunks=factor)

    def _gather_level(self, x: jnp.ndarray, ax: str, root: int,
                      topo: Optional[topo_mod.Topology]) -> jnp.ndarray:
        n = lax.axis_size(ax)
        if n == 1:
            return x
        lv = self._grouped_level(topo, ax)
        if lv is not None:
            # only reachable as the outer level of a tuple-axis gather;
            # the single-axis path dispatches to _gather_ragged
            ledger.record_fallback("gather", level=ax,
                                   fabric=lv.fabric)
        s = ledger.nbytes(x)
        backend, factor, _, ov, _ = self._choice("gather", s, n, topo,
                                                 ax)
        self._rec("gather", s * (n - 1), ov, topo, ax)
        if backend == "ring":
            idx = lax.axis_index(ax)
            full = lax.all_gather(x, ax, tiled=True)
            return jnp.where(idx == root, full, jnp.zeros_like(full))
        return mc.gather(x, ax, root=root, n_chunks=factor)

    def _scatter_level(self, x: jnp.ndarray, ax: str, root: int,
                       topo: Optional[topo_mod.Topology]) -> jnp.ndarray:
        n = lax.axis_size(ax)
        if n == 1:
            return x
        lv = self._grouped_level(topo, ax)
        if lv is not None:
            ledger.record_fallback("scatter", level=ax,
                                   fabric=lv.fabric)
        s = ledger.nbytes(x)
        backend, factor, _, ov, _ = self._choice("scatter", s, n, topo,
                                                 ax)
        # root pushes every segment but its own: s*(n-1)/n wire bytes
        self._rec("scatter", s * (n - 1) / n, ov, topo, ax)
        if backend == "ring":
            # masked-psum broadcast inlined so the ledger books the op
            # once as 'scatter' (delegating to _broadcast_level would
            # double-count the payload as 'broadcast')
            idx = lax.axis_index(ax)
            masked = jnp.where(idx == root, x, jnp.zeros_like(x))
            rooted = lax.psum(masked, ax)
            segs = rooted.reshape((n, x.shape[0] // n) + x.shape[1:])
            return lax.dynamic_index_in_dim(segs, idx, 0, keepdims=False)
        return mc.scatter(x, ax, root=root, n_chunks=factor)

    # -- N->N primitives (the FSDP / TP / MoE hot path) ------------------

    def all_reduce(self, x: jnp.ndarray, axis: AxisSpec) -> jnp.ndarray:
        axes = _axes(axis)
        topo = self._topo()
        if len(axes) == 1:
            return self._ar_axis(x, axes[0], topo)
        hier = topo is not None and topo.covers(axes)
        if self.backend == "ring" and not hier:
            # single fused psum over the whole tuple axis: one reduction
            # order, matching XLA's own lowering exactly
            s = ledger.nbytes(x)
            for ax in axes:
                n = lax.axis_size(ax)
                self._rec("all_reduce", 2 * s * (n - 1) / n, False, topo,
                          ax)
            return lax.psum(x, tuple(axes))
        # hierarchical decomposition: RS down the inner levels, AR across
        # the outermost on the shard, AG back out
        return mc.hierarchical_all_reduce(
            x, axes,
            rs_fn=lambda z, ax: self._rs_axis(z, ax, topo),
            ar_fn=lambda z, ax: self._ar_axis(z, ax, topo),
            ag_fn=lambda z, ax: self._ag_axis(z, ax, topo))

    def all_gather(self, x: jnp.ndarray, axis: AxisSpec) -> jnp.ndarray:
        """Tiled gather along axis 0, rank-major over the (possibly
        hierarchical) axis spec: outer axis index is most significant."""
        axes = _axes(axis)
        topo = self._topo()
        out = x
        # Inner (minor, pool-local) axis first; the outer gather then
        # stacks whole pool-level blocks, matching P((outer, inner))
        # layout.  Payload grows level by level, so this order is also
        # the hierarchy-optimal one: the outer fabric carries each byte
        # exactly once.
        for ax in reversed(axes):
            out = self._ag_axis(out, ax, topo)
        return out

    def reduce_scatter(self, x: jnp.ndarray, axis: AxisSpec) -> jnp.ndarray:
        """Reduce-scatter along axis 0, the inverse layout of all_gather
        (outer axis most significant).  Outer level first: the payload
        shrinks by each level's size before the next fabric sees it."""
        axes = _axes(axis)
        topo = self._topo()
        out = x
        for ax in axes:  # outer axis first: inverse of gather
            out = self._rs_axis(out, ax, topo)
        return out

    def all_to_all(self, x: jnp.ndarray, axis: AxisSpec) -> jnp.ndarray:
        axes = _axes(axis)
        if len(axes) != 1:
            raise NotImplementedError("all_to_all is single-axis")
        ax = axes[0]
        topo = self._topo()
        lv = self._grouped_level(topo, ax)
        if lv is not None:
            ledger.record_fallback("all_to_all", level=ax,
                                   fabric=lv.fabric)
        n_ = lax.axis_size(ax)
        s = ledger.nbytes(x)
        backend, factor, _, ov, _ = self._choice("all_to_all", s, n_,
                                                 topo, ax)
        self._rec("all_to_all", s * (n_ - 1) / n_, ov, topo, ax)
        if backend == "ring":
            n = n_
            if x.shape[0] % n:
                raise ValueError("leading dim must divide axis size")
            segs = x.reshape((n, x.shape[0] // n) + x.shape[1:])
            out = lax.all_to_all(segs, ax, split_axis=0, concat_axis=0,
                                 tiled=False)
            return out.reshape(x.shape)
        return mc.all_to_all(x, ax, n_chunks=factor)

    # -- point-to-point (pipeline stage boundaries) -----------------------

    def send(self, x: jnp.ndarray, axis: AxisSpec, *,
             shift: int = 1) -> jnp.ndarray:
        """Ring point-to-point handoff: every rank sends ``x`` to the
        rank ``shift`` ahead on ``axis`` and returns the payload it
        received from the rank ``shift`` behind (cyclic).  SPMD-
        symmetric - all ranks call it, which is exactly the pipeline
        pattern (stage s pushes activations to s+1 while receiving
        from s-1).  The resolved ``p2p`` plan cell picks the transport:
        ``cxl`` is the pool write + doorbell commit + consumer read,
        ``ring`` the direct NIC/ICI hop; both move S wire bytes per
        rank, booked against the level/fabric that carries them."""
        axes = _axes(axis)
        if len(axes) != 1:
            raise NotImplementedError("send/recv are single-axis")
        ax = axes[0]
        topo = self._topo()
        n = lax.axis_size(ax)
        if n == 1 or shift % n == 0:
            return x
        s = ledger.nbytes(x)
        backend, factor, _, ov, _ = self._choice("p2p", s, n, topo, ax)
        self._rec("p2p", float(s), ov, topo, ax)
        if backend == "ring":
            return lax.ppermute(x, ax, mc._ring_perm(n, shift % n))
        return mc.p2p_shift(x, ax, shift=shift, n_chunks=factor)

    def recv(self, x: jnp.ndarray, axis: AxisSpec, *,
             shift: int = 1) -> jnp.ndarray:
        """The reverse hop of :meth:`send`: every rank sends ``x`` to
        the rank ``shift`` *behind* and returns the payload received
        from the rank ``shift`` ahead - the backward-pass gradient
        handoff (stage s pushes grads to s-1)."""
        return self.send(x, axis, shift=-shift)

    # -- rooted primitives ------------------------------------------------
    # Tuple axes decompose with per-level roots derived from the flat
    # rank-major ``root`` index, so cross-pool traffic moves each byte
    # once (see the module docstring).

    @staticmethod
    def _split_root(axes: tuple, root: int) -> tuple:
        """Split a flat rank-major root index at the outermost level:
        (inner axes, prod(inner sizes), outer root, inner root)."""
        rest = axes[1:]
        prod_rest = 1
        for a in rest:
            prod_rest *= lax.axis_size(a)
        r_out, r_rest = divmod(root, prod_rest)
        return rest, prod_rest, r_out, r_rest

    def broadcast(self, x: jnp.ndarray, axis: AxisSpec,
                  root: int = 0) -> jnp.ndarray:
        axes = _axes(axis)
        topo = self._topo()
        if len(axes) == 1:
            return self._broadcast_level(x, axes[0], root, topo)
        rest, prod_rest, r_out, r_rest = self._split_root(axes, root)
        lead = x.shape[0] if x.ndim else 1
        if x.ndim >= 1 and prod_rest > 1 and lead % prod_rest == 0:
            # scatter within the root's inner group, broadcast the
            # 1/prod(inner) pieces across the outer fabric, allgather
            # within every inner group: the outer level carries s/prod
            # per rank instead of the full payload.
            piece = self.scatter(x, rest, root=r_rest)
            piece = self._broadcast_level(piece, axes[0], r_out, topo)
            return self.all_gather(piece, rest)
        # indivisible payload: per-level root chain (outer first)
        out = self._broadcast_level(x, axes[0], r_out, topo)
        return self.broadcast(out, rest, root=r_rest)

    def reduce(self, x: jnp.ndarray, axis: AxisSpec,
               root: int = 0) -> jnp.ndarray:
        axes = _axes(axis)
        topo = self._topo()
        if len(axes) == 1:
            return self._reduce_level(x, axes[0], root, topo)
        rest, _, r_out, r_rest = self._split_root(axes, root)
        # reduce within each inner group first, then across the outer
        # level: only already-reduced partials cross the slow fabric
        part = self.reduce(x, rest, root=r_rest)
        return self._reduce_level(part, axes[0], r_out, topo)

    def gather(self, x: jnp.ndarray, axis: AxisSpec,
               root: int = 0) -> jnp.ndarray:
        axes = _axes(axis)
        topo = self._topo()
        if len(axes) == 1:
            lv = self._grouped_level(topo, axes[0])
            if lv is not None:
                return self._gather_ragged(x, axes[0], root, topo, lv)
            return self._gather_level(x, axes[0], root, topo)
        rest, _, r_out, r_rest = self._split_root(axes, root)
        # gather each inner group's block at its local root, then gather
        # whole blocks across the outer level (rank-major layout)
        blk = self.gather(x, rest, root=r_rest)
        return self._gather_level(blk, axes[0], r_out, topo)

    def scatter(self, x: jnp.ndarray, axis: AxisSpec,
                root: int = 0) -> jnp.ndarray:
        axes = _axes(axis)
        topo = self._topo()
        if len(axes) == 1:
            return self._scatter_level(x, axes[0], root, topo)
        rest, _, r_out, r_rest = self._split_root(axes, root)
        # outer scatter moves whole inner-group blocks once across the
        # slow fabric; the inner levels fan the block out locally
        blk = self._scatter_level(x, axes[0], r_out, topo)
        return self.scatter(blk, rest, root=r_rest)


def make_communicator(backend: str = "ring", *, slicing_factor: int = 4,
                      allreduce_mode: str = "two_phase",
                      plan: Optional["Plan"] = None,
                      topology: Optional[topo_mod.Topology] = None
                      ) -> Communicator:
    return Communicator(backend=backend, slicing_factor=slicing_factor,
                        allreduce_mode=allreduce_mode, plan=plan,
                        topology=topology)
