"""Fine-grained data chunking (paper Sec. 4.4).

A buffer is split into ``slicing_factor`` chunks, each with its own doorbell,
so a producer's publication of chunk *k* overlaps consumers' retrieval of
chunk *k-1*.  The sensitivity study (Fig. 11) finds 4-8 chunks best; a single
chunk serializes producer and consumer and is worst.
"""
from __future__ import annotations

import dataclasses

DEFAULT_SLICING_FACTOR = 4
# Below this size further slicing only adds cudaMemcpy/doorbell overhead
# (paper Sec. 5.2, ReduceScatter discussion of the small-message regime).
MIN_CHUNK_BYTES = 64 * 1024


@dataclasses.dataclass(frozen=True)
class Chunk:
    index: int        # chunk index within the buffer [0, n_chunks)
    offset: int       # byte offset within the buffer
    size: int         # bytes


def effective_chunks(total_bytes: int, slicing_factor: int) -> int:
    """Clamp the slicing factor so chunks never shrink below
    ``MIN_CHUNK_BYTES`` (avoids the overhead-dominated regime)."""
    if total_bytes <= 0:
        return 1
    max_useful = max(1, total_bytes // MIN_CHUNK_BYTES)
    return max(1, min(slicing_factor, max_useful))


def split(total_bytes: int, slicing_factor: int, clamp: bool = True,
          granularity: int = 1) -> list[Chunk]:
    """Split ``total_bytes`` into chunks.  The last chunk absorbs the
    remainder so sizes always sum exactly to ``total_bytes``.  All chunk
    boundaries are aligned to ``granularity`` bytes (e.g. the element size
    when the buffer is a typed array)."""
    if total_bytes % granularity:
        raise ValueError(
            f"total_bytes {total_bytes} not a multiple of granularity "
            f"{granularity}")
    n = effective_chunks(total_bytes, slicing_factor) if clamp else max(
        1, slicing_factor)
    base = (total_bytes // n) // granularity * granularity
    if base == 0:
        n, base = 1, total_bytes
    chunks = []
    offset = 0
    for i in range(n):
        size = base if i < n - 1 else total_bytes - offset
        chunks.append(Chunk(index=i, offset=offset, size=size))
        offset += size
    return chunks
