"""Doorbell synchronization (paper Sec. 4.5, Fig. 8, Listing 3).

Each data chunk has a dedicated semaphore ("doorbell") living in the pool.
Only the chunk's *owner* (producer) may update it: STALE -> READY after the
write completes, followed by an explicit flush so other sockets observe the
change.  Consumers spin: read doorbell; if STALE, invalidate the cached line
and re-read after a short sleep.

Doorbell *addresses* are derived by pure index calculation against a
pre-allocated doorbell region (no allocator, no metadata) - that is the
paper's "lightweight, index-calculation-based" locking mechanism.

This module provides the host-side (Python) state machine used by the
functional pool emulation and the event-driven simulator.  The TPU mesh
backend needs no doorbells: data dependence of the ppermute chain enforces
the same RAW ordering (see DESIGN.md, hardware adaptation).
"""
from __future__ import annotations

import dataclasses
import enum

DOORBELL_BYTES = 64  # one cache line per doorbell


class DoorbellState(enum.IntEnum):
    STALE = 0
    READY = 1


@dataclasses.dataclass
class DoorbellRegion:
    """Pre-allocated doorbell buffer at the base of the pool address space.

    ``capacity`` is the number of doorbell entries.  The region occupies
    ``capacity * DOORBELL_BYTES`` bytes (= ``DB_offset`` in Eq. 3).
    """

    capacity: int
    _states: list[int] = dataclasses.field(default_factory=list)
    # Telemetry for tests / the simulator.
    rings: int = 0
    polls: int = 0
    flushes: int = 0

    def __post_init__(self) -> None:
        if self.capacity <= 0:
            raise ValueError("doorbell capacity must be positive")
        self._states = [DoorbellState.STALE] * self.capacity

    @property
    def region_bytes(self) -> int:
        return self.capacity * DOORBELL_BYTES

    def address(self, index: int) -> int:
        """Index-calculated doorbell address (no metadata lookup)."""
        self._check(index)
        return index * DOORBELL_BYTES

    def ring(self, index: int) -> None:
        """Producer: mark READY and flush (Listing 3 lines 5-7)."""
        self._check(index)
        self._states[index] = DoorbellState.READY
        self.rings += 1
        self.flushes += 1  # explicit flush for cross-socket visibility

    def is_ready(self, index: int) -> bool:
        """Consumer poll: invalidate + re-read (Listing 3 lines 9-13)."""
        self._check(index)
        self.polls += 1
        self.flushes += 1  # cache-line invalidation before the re-read
        return self._states[index] == DoorbellState.READY

    def reset(self, index: int) -> None:
        """Owner resets the doorbell for buffer reuse between collectives."""
        self._check(index)
        self._states[index] = DoorbellState.STALE

    def reset_all(self) -> None:
        for i in range(self.capacity):
            self._states[i] = DoorbellState.STALE

    def _check(self, index: int) -> None:
        if not 0 <= index < self.capacity:
            raise IndexError(
                f"doorbell index {index} out of range [0, {self.capacity})")


@dataclasses.dataclass
class RefcountRegion:
    """Shared-ownership words in pool memory, doorbell-style.

    The pooled KV prefix cache (``repro.serving.kvcache``) needs a
    cross-engine reference count per pooled entry: how many engines
    currently hold the entry's blocks live.  Each count is a single
    word at the index-calculated address ``i * DOORBELL_BYTES`` in a
    dedicated region after the doorbells - the same allocator-free
    addressing as ``DoorbellRegion``, and the same store+flush /
    invalidate+re-read discipline (every update flushes so other
    sockets observe it; every read invalidates first).

    Updates route through the pool fault shim
    (``core.pool.check_fault``) so injected pool faults surface exactly
    where the real pool store would fail.  A refcount word is only
    meaningful once the entry's *commit* doorbell rang: publishers
    write payload blocks, set the count, then ring - readers that poll
    a STALE doorbell never trust the count.
    """

    capacity: int
    _counts: list[int] = dataclasses.field(default_factory=list)
    # Telemetry, doorbell-style.
    updates: int = 0
    polls: int = 0
    flushes: int = 0

    def __post_init__(self) -> None:
        if self.capacity <= 0:
            raise ValueError("refcount capacity must be positive")
        self._counts = [0] * self.capacity

    @property
    def region_bytes(self) -> int:
        return self.capacity * DOORBELL_BYTES

    def address(self, index: int) -> int:
        """Index-calculated refcount word address."""
        self._check(index)
        return index * DOORBELL_BYTES

    def acquire(self, index: int, rank: int = 0) -> int:
        """Increment and flush; returns the new count."""
        return self._update(index, +1, rank)

    def release(self, index: int, rank: int = 0) -> int:
        """Decrement and flush; returns the new count (>= 0 enforced:
        a double release is a protocol bug, not a no-op)."""
        if self._counts[index] <= 0:
            raise ValueError(
                f"refcount word {index} released below zero")
        return self._update(index, -1, rank)

    def read(self, index: int) -> int:
        """Invalidate + re-read one count word."""
        self._check(index)
        self.polls += 1
        self.flushes += 1
        return self._counts[index]

    def reset(self, index: int) -> None:
        """Owner resets the word when the entry's blocks are reclaimed."""
        self._check(index)
        self._counts[index] = 0

    def _update(self, index: int, delta: int, rank: int) -> int:
        self._check(index)
        from repro.core import pool as _pool  # late: pool imports us
        _pool.check_fault("refcount", rank=rank, index=index,
                          offset=self.address(index))
        self._counts[index] += delta
        self.updates += 1
        self.flushes += 1
        return self._counts[index]

    def _check(self, index: int) -> None:
        if not 0 <= index < self.capacity:
            raise IndexError(
                f"refcount index {index} out of range "
                f"[0, {self.capacity})")


@dataclasses.dataclass
class HeartbeatRegion:
    """Per-rank liveness words in pool memory, reusing the doorbell
    protocol.

    Rank ``r``'s heartbeat is a single word at the index-calculated
    address ``r * DOORBELL_BYTES`` in a dedicated region after the
    doorbells — same allocator-free addressing as ``DoorbellRegion``.
    A live rank overwrites its word with the current step index once
    per step and flushes (a producer "ring"); the failure monitor polls
    every word (invalidate + re-read, a consumer poll) and treats a
    word that has stopped advancing as a missing rank.

    Pulses route through the pool fault hook (``core.pool.check_fault``)
    so injected rank deaths and pool faults surface exactly where a
    real pool store would fail: a dead rank's pulse raises
    ``PoolAccessError`` and its word goes stale on its own.
    """

    nranks: int
    _words: list[int] = dataclasses.field(default_factory=list)
    # Telemetry, doorbell-style.
    pulses: int = 0
    polls: int = 0
    flushes: int = 0

    def __post_init__(self) -> None:
        if self.nranks <= 0:
            raise ValueError("heartbeat region needs at least one rank")
        self._words = [-1] * self.nranks  # -1: never pulsed

    @property
    def region_bytes(self) -> int:
        return self.nranks * DOORBELL_BYTES

    def address(self, rank: int) -> int:
        """Index-calculated heartbeat address for ``rank``."""
        self._check(rank)
        return rank * DOORBELL_BYTES

    def pulse(self, rank: int, step: int) -> None:
        """Rank ``rank`` publishes liveness for ``step`` (store + flush).

        Raises ``PoolAccessError`` if a fault hook decides this rank's
        pool store fails (rank death, transient pool fault)."""
        self._check(rank)
        from repro.core import pool as _pool  # late: pool imports us
        _pool.check_fault("heartbeat", rank=rank, step=step,
                          offset=self.address(rank))
        self._words[rank] = step
        self.pulses += 1
        self.flushes += 1

    def read(self, rank: int) -> int:
        """Monitor poll: invalidate + re-read one liveness word."""
        self._check(rank)
        self.polls += 1
        self.flushes += 1
        return self._words[rank]

    def read_all(self) -> tuple[int, ...]:
        return tuple(self.read(r) for r in range(self.nranks))

    def stale_ranks(self, step: int, timeout_steps: int) -> list[int]:
        """Ranks whose word is more than ``timeout_steps`` behind
        ``step`` (or never pulsed)."""
        return [r for r in range(self.nranks)
                if step - self.read(r) > timeout_steps]

    def _check(self, rank: int) -> None:
        if not 0 <= rank < self.nranks:
            raise IndexError(
                f"heartbeat rank {rank} out of range [0, {self.nranks})")
