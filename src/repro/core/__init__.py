# The paper's primary contribution — implement the SYSTEM here
# (scheduler, optimizer, data path, serving loop, etc.) in the
# host framework. Add sibling subpackages for substrates.

# Compat: the codebase targets the modern `jax.shard_map(..., check_vma=)`
# entry point; older jax (<= 0.4.x) only ships
# `jax.experimental.shard_map.shard_map(..., check_rep=)`.  Install an
# equivalent alias so every call site works on both.
import jax as _jax

if not hasattr(_jax, "shard_map"):
    from jax.experimental.shard_map import shard_map as _shard_map

    def _compat_shard_map(f, *, mesh, in_specs, out_specs, check_vma=True,
                          **kw):
        return _shard_map(f, mesh=mesh, in_specs=in_specs,
                          out_specs=out_specs, check_rep=check_vma, **kw)

    _jax.shard_map = _compat_shard_map

if not hasattr(_jax.lax, "axis_size"):
    from jax.lax import psum as _psum

    def _axis_size(axis_name):
        # psum of 1 over the axis folds to the (static) axis size at
        # trace time - the old-jax spelling of lax.axis_size.
        return _psum(1, axis_name)

    _jax.lax.axis_size = _axis_size

del _jax
