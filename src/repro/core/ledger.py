"""Trace-time collective-bytes ledger.

XLA's ``cost_analysis``/HLO text count a ``lax.scan`` body ONCE, so any
per-layer or per-microbatch collective is undercounted by its trip count
in the compiled artifact.  The Communicator therefore records the wire
bytes of every collective *at trace time* (shapes are static), and the
model/trainer wrap scanned regions in ``ledger.scale(trip_count)`` so the
ledger accumulates the true per-step totals.  The dry-run snapshots the
ledger after ``.lower()`` (tracing is enough - nothing must execute).

Wire-byte convention (per chip, ring algorithms over an axis of size n,
local payload s bytes):
    all_gather      s * (n-1)
    reduce_scatter  s * (n-1) / n
    all_reduce      2 * s * (n-1) / n   (faithful mode: s * (n-1))
    all_to_all      s * (n-1) / n
    broadcast       s                    (pipelined forward)
    scatter         s * (n-1) / n        (root's outgoing segments)
    p2p             s                    (one full-payload ring hop:
                                          the pipeline stage handoff)

Overlap accounting: collectives issued inside a ``ledger.hidden()``
region (the double-buffered FSDP prefetch, or an ``auto`` plan cell
tuned as overlapped) book their bytes as *hidden* - expected to be
scheduled behind compute - while everything else books as *exposed*.
Orthogonally, a ``ledger.fused()`` region marks collectives whose
epilogue/prologue compute runs inside a fused kernel
(``kernels.fused_collectives``); those bytes additionally book into a
*fused* split so the hidden-vs-exposed totals can be decomposed by
fusion coverage.  Primitives that degrade to a hierarchy-blind flat
schedule on a ragged axis book an explicit ``record_fallback`` event -
degradations are audited, never silent.
``counts`` is the number of distinct collective call *sites*;
``collective_calls`` additionally multiplies by the ambient scale, i.e.
the true number of collectives launched per step.

Timing capture (online re-tuning): unlike everything above, wall times
are a *run-time* signal.  ``record_timing`` (or the ``timed`` context
manager around an eagerly dispatched collective) books one measured
sample tagged with the full plan-cell identity - primitive, message
size, nranks, the (backend, slicing_factor, allreduce_mode) actually
taken, and the topology level/fabric - and ``timing_cells`` aggregates
the samples per cell key so ``tuner.online`` can fold them back into
the plan as a measured cost.  Samples are stamped with the ambient
``scale()`` multiplier (``calls``, like ``record_choice``) so a timing
captured inside a scanned region is weighted by its true trip count
when folded into EWMAs; knobs the caller does not know stay ``None``
and aggregate under an explicit ``?`` key instead of polluting a real
candidate's mean.  ``add_timing_hook`` registers an observer (the
``repro.obs`` flight recorder) called once per sample.
"""
from __future__ import annotations

import contextlib
import time
from collections import defaultdict

_BYTES: dict = defaultdict(float)
_EXPOSED: dict = defaultdict(float)
_HIDDEN: dict = defaultdict(float)
_FUSED: dict = defaultdict(float)   # bytes whose epilogue/prologue fused
_COUNTS: dict = defaultdict(int)
_CALLS: dict = defaultdict(float)   # trip-count-scaled launch count
# Per-(level axis, fabric) wire bytes: "<axis>/<fabric>" -> kind -> bytes.
# Populated when the Communicator decomposes against a Topology, so a
# dry-run can attribute traffic to the fabric that actually carries it.
_LEVEL_BYTES: dict = defaultdict(lambda: defaultdict(float))
_MULT: list = [1.0]
_HIDDEN_CTX: list = [False]
_FUSED_CTX: list = [False]
_CHOICES: list = []   # autotuner decisions, for benchmark audit
_FALLBACKS: list = []  # explicit flat-on-ragged degradation events
_TIMINGS: list = []   # measured wall-time samples (online re-tuning)
# Observers called once per timing sample (repro.obs flight recorder).
# Deliberately NOT cleared by reset(): hooks are a process-lifetime
# registration, while reset() runs at every re-trace boundary.
_TIMING_HOOKS: list = []


def reset() -> None:
    _BYTES.clear()
    _EXPOSED.clear()
    _HIDDEN.clear()
    _FUSED.clear()
    _COUNTS.clear()
    _CALLS.clear()
    _LEVEL_BYTES.clear()
    _MULT[:] = [1.0]
    _HIDDEN_CTX[:] = [False]
    _FUSED_CTX[:] = [False]
    _CHOICES.clear()
    _FALLBACKS.clear()
    _TIMINGS.clear()


@contextlib.contextmanager
def scale(mult: float):
    """Everything recorded inside runs ``mult`` times at run time."""
    _MULT.append(_MULT[-1] * mult)
    try:
        yield
    finally:
        _MULT.pop()


@contextlib.contextmanager
def hidden(flag: bool = True):
    """Collectives recorded inside are overlap-hidden behind compute."""
    _HIDDEN_CTX.append(flag)
    try:
        yield
    finally:
        _HIDDEN_CTX.pop()


def in_hidden_region() -> bool:
    return _HIDDEN_CTX[-1]


@contextlib.contextmanager
def fused(flag: bool = True):
    """Collectives recorded inside feed a fused collective+compute
    kernel (``kernels.fused_collectives``): their epilogue/prologue
    compute rides the transfer instead of a separate HBM round-trip.
    The bytes additionally book into the fused split (orthogonal to
    hidden/exposed) so dry-runs can report how much of the wire
    traffic fusion covered."""
    _FUSED_CTX.append(flag)
    try:
        yield
    finally:
        _FUSED_CTX.pop()


def in_fused_region() -> bool:
    return _FUSED_CTX[-1]


def record(kind: str, wire_bytes: float, *,
           hidden: "bool | None" = None, fused: "bool | None" = None,
           level: "str | None" = None,
           fabric: "str | None" = None) -> None:
    """``hidden=None`` defers to the ambient ``ledger.hidden()`` region;
    ``fused=None`` likewise defers to ``ledger.fused()``.
    ``level``/``fabric`` attribute the bytes to a topology level (the
    mesh axis name and the fabric kind that carries the traffic)."""
    h = _HIDDEN_CTX[-1] if hidden is None else hidden
    fz = _FUSED_CTX[-1] if fused is None else fused
    m = _MULT[-1]
    _BYTES[kind] += wire_bytes * m
    (_HIDDEN if h else _EXPOSED)[kind] += wire_bytes * m
    if fz:
        _FUSED[kind] += wire_bytes * m
    _COUNTS[kind] += 1
    _CALLS[kind] += m
    if level is not None:
        _LEVEL_BYTES[f"{level}/{fabric or '?'}"][kind] += wire_bytes * m


def record_fallback(primitive: str, *, level: "str | None" = None,
                    fabric: "str | None" = None,
                    reason: str = "flat_on_ragged") -> None:
    """Audit one explicit degradation event: a primitive that ran a
    hierarchy-blind (flat single-axis) schedule on an axis that
    declares ragged groups.  ReduceScatter/AllReduce/AllGather/Gather
    have grouped schedules and never book one of these; the remaining
    primitives do, so a dry-run (or test) can assert exactly which
    calls degraded instead of discovering it from byte totals."""
    _FALLBACKS.append({"primitive": primitive, "level": level,
                       "fabric": fabric, "reason": reason,
                       "calls": float(_MULT[-1])})


def record_choice(primitive: str, msg_bytes: int, nranks: int,
                  backend: str, slicing_factor: int, mode: str,
                  overlap: bool = False, fused: bool = False,
                  level: "str | None" = None,
                  fabric: "str | None" = None,
                  predicted_time: float = 0.0,
                  baseline_time: float = 0.0,
                  plan_epoch: "int | None" = None) -> None:
    """Audit trail of ``backend='auto'`` decisions (trace time, like
    ``record``): which concrete (backend, knobs) each collective got,
    which topology level it ran at, and the cost model's predicted /
    best-fixed-knob times for the cell (what the plan-aware dry-run
    turns into per-level step-time deltas).  ``plan_epoch`` is the
    version of the active-plan registry the decision was resolved
    against (None for an explicitly attached plan), so hot-swap runs
    can tell which plan generation drove each call."""
    _CHOICES.append({"primitive": primitive, "msg_bytes": int(msg_bytes),
                     "nranks": int(nranks), "backend": backend,
                     "slicing_factor": int(slicing_factor),
                     "allreduce_mode": mode, "overlap": bool(overlap),
                     "fused": bool(fused),
                     "level": level, "fabric": fabric,
                     "predicted_time": float(predicted_time),
                     "baseline_time": float(baseline_time),
                     "plan_epoch": plan_epoch,
                     "calls": float(_MULT[-1])})


# -- measured wall-time capture (online re-tuning) -------------------------

def add_timing_hook(hook) -> None:
    """Register ``hook(sample_dict)`` to observe every timing sample as
    it is recorded (the ``repro.obs`` flight recorder attaches here).
    Hooks survive ``reset()``; detach with ``remove_timing_hook``."""
    if hook not in _TIMING_HOOKS:
        _TIMING_HOOKS.append(hook)


def remove_timing_hook(hook) -> None:
    if hook in _TIMING_HOOKS:
        _TIMING_HOOKS.remove(hook)


def record_timing(primitive: str, msg_bytes: int, nranks: int,
                  backend: str, seconds: float, *,
                  slicing_factor: "int | None" = None,
                  allreduce_mode: "str | None" = None,
                  level: "str | None" = None,
                  fabric: "str | None" = None,
                  calls: "float | None" = None) -> None:
    """Book one measured wall-time sample for a dispatched collective,
    tagged with everything ``tuner.online`` needs to aggregate it into
    a plan cell: the cell identity (primitive, size, nranks, level) and
    the candidate actually executed (backend + knobs).  Knobs the
    caller does not know stay ``None`` (aggregated under an explicit
    ``?`` key, never pooled into a real candidate's mean).  ``calls``
    defaults to the ambient ``scale()`` multiplier, so a sample from a
    scanned region carries its true per-step trip count."""
    t = {"primitive": primitive, "msg_bytes": int(msg_bytes),
         "nranks": int(nranks), "backend": backend,
         "slicing_factor": (None if slicing_factor is None
                            else int(slicing_factor)),
         "allreduce_mode": allreduce_mode,
         "level": level, "fabric": fabric,
         "seconds": float(seconds),
         "calls": float(_MULT[-1] if calls is None else calls)}
    _TIMINGS.append(t)
    for hook in _TIMING_HOOKS:
        hook(t)


@contextlib.contextmanager
def timed(primitive: str, msg_bytes: int, nranks: int, backend: str, *,
          slicing_factor: "int | None" = None,
          allreduce_mode: "str | None" = None,
          level: "str | None" = None, fabric: "str | None" = None):
    """Time an eagerly executed region and book it as one sample.  The
    caller is responsible for making the region synchronous (e.g.
    ``jax.block_until_ready`` on the collective's result) - the ledger
    only measures wall time between entry and exit."""
    t0 = time.perf_counter()
    try:
        yield
    finally:
        record_timing(primitive, msg_bytes, nranks, backend,
                      time.perf_counter() - t0,
                      slicing_factor=slicing_factor,
                      allreduce_mode=allreduce_mode,
                      level=level, fabric=fabric)


def clear_timings() -> None:
    """Drop the measured timing samples only (trace-time state stays).
    Long-running loops call this after folding a step's samples into
    the tuner/metrics so the sample list stays O(one step)."""
    _TIMINGS.clear()


def timing_cells() -> dict:
    """Diagnostic aggregation of the timing samples, keyed per
    (plan cell, executed candidate): ``"<primitive>/b<log2 bucket>/
    n<nranks>[/<level>]@<backend>:<factor>:<allreduce mode>"``
    -> sample count + total/mean seconds.  The candidate key carries
    the full knob tuple so two modes of the same backend never pool
    into one mean; knobs the sample does not carry key as a literal
    ``?`` (an unknown-knob sample must never contaminate a tuned
    candidate's mean).  This is a snapshot *readout* (dry-runs,
    debugging); ``tuner.online`` consumes the raw
    ``snapshot()["timings"]`` list, which keeps per-sample order for
    the EWMA."""
    cells: dict = {}
    for t in _TIMINGS:
        bucket = max(1, int(t["msg_bytes"])).bit_length() - 1
        key = f"{t['primitive']}/b{bucket}/n{t['nranks']}"
        if t.get("level") is not None:
            key += f"/{t['level']}"
        sf = t.get("slicing_factor")
        mode = t.get("allreduce_mode")
        key += f"@{t['backend']}:{'?' if sf is None else sf}" \
               f":{'?' if mode is None else mode}"
        c = cells.setdefault(key, {"samples": 0, "seconds_total": 0.0,
                                   "backend": t["backend"]})
        c["samples"] += 1
        c["seconds_total"] += t["seconds"]
        c["mean_seconds"] = c["seconds_total"] / c["samples"]
    return cells


def snapshot() -> dict:
    return {"wire_bytes": dict(_BYTES), "counts": dict(_COUNTS),
            "total_wire_bytes": float(sum(_BYTES.values())),
            "exposed_bytes": dict(_EXPOSED),
            "hidden_bytes": dict(_HIDDEN),
            "fused_bytes": dict(_FUSED),
            "total_exposed_bytes": float(sum(_EXPOSED.values())),
            "total_hidden_bytes": float(sum(_HIDDEN.values())),
            "total_fused_bytes": float(sum(_FUSED.values())),
            "collective_calls": dict(_CALLS),
            "total_collective_calls": float(sum(_CALLS.values())),
            "level_wire_bytes": {k: dict(v)
                                 for k, v in _LEVEL_BYTES.items()},
            "auto_choices": list(_CHOICES),
            "fallbacks": list(_FALLBACKS),
            "timings": list(_TIMINGS),
            "timing_cells": timing_cells()}


def nbytes(x) -> int:
    size = 1
    for d in x.shape:
        size *= int(d)
    return size * x.dtype.itemsize
