"""Trace-time collective-bytes ledger.

XLA's ``cost_analysis``/HLO text count a ``lax.scan`` body ONCE, so any
per-layer or per-microbatch collective is undercounted by its trip count
in the compiled artifact.  The Communicator therefore records the wire
bytes of every collective *at trace time* (shapes are static), and the
model/trainer wrap scanned regions in ``ledger.scale(trip_count)`` so the
ledger accumulates the true per-step totals.  The dry-run snapshots the
ledger after ``.lower()`` (tracing is enough - nothing must execute).

Wire-byte convention (per chip, ring algorithms over an axis of size n,
local payload s bytes):
    all_gather      s * (n-1)
    reduce_scatter  s * (n-1) / n
    all_reduce      2 * s * (n-1) / n   (faithful mode: s * (n-1))
    all_to_all      s * (n-1) / n
    broadcast       s                    (pipelined forward)
"""
from __future__ import annotations

import contextlib
from collections import defaultdict

_BYTES: dict = defaultdict(float)
_COUNTS: dict = defaultdict(int)
_MULT: list = [1.0]
_CHOICES: list = []   # autotuner decisions, for benchmark audit


def reset() -> None:
    _BYTES.clear()
    _COUNTS.clear()
    _MULT[:] = [1.0]
    _CHOICES.clear()


@contextlib.contextmanager
def scale(mult: float):
    """Everything recorded inside runs ``mult`` times at run time."""
    _MULT.append(_MULT[-1] * mult)
    try:
        yield
    finally:
        _MULT.pop()


def record(kind: str, wire_bytes: float) -> None:
    _BYTES[kind] += wire_bytes * _MULT[-1]
    _COUNTS[kind] += 1


def record_choice(primitive: str, msg_bytes: int, nranks: int,
                  backend: str, slicing_factor: int, mode: str) -> None:
    """Audit trail of ``backend='auto'`` decisions (trace time, like
    ``record``): which concrete (backend, knobs) each collective got."""
    _CHOICES.append({"primitive": primitive, "msg_bytes": int(msg_bytes),
                     "nranks": int(nranks), "backend": backend,
                     "slicing_factor": int(slicing_factor),
                     "allreduce_mode": mode})


def snapshot() -> dict:
    return {"wire_bytes": dict(_BYTES), "counts": dict(_COUNTS),
            "total_wire_bytes": float(sum(_BYTES.values())),
            "auto_choices": list(_CHOICES)}


def nbytes(x) -> int:
    size = 1
    for d in x.shape:
        size *= int(d)
    return size * x.dtype.itemsize
