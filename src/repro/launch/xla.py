"""XLA flag presets applied before the first jax import.

``--xla-overlap`` on the launchers (train / serve / dryrun) folds the
standard comm/compute-overlap compiler flags - async collectives, the
latency-hiding scheduler, the high-priority async stream, plus the
Triton fusion knobs - into ``XLA_FLAGS``.  XLA reads the variable once
at backend init, so the launchers call ``apply_overlap_preset`` from a
module-top hook that runs *before* their ``import jax``; this module
must therefore never import jax itself.

Merge semantics: flags the user already pinned in an external
``XLA_FLAGS`` env var win over the preset (with a warning naming each
conflict), so an operator's explicit tuning is never silently
overridden; preset flags absent from the env var are appended.  The
preset only applies when a CUDA jaxlib is importable: XLA's env-var
flag parser *aborts the process* on flags the build does not know, so
on the CPU-only container the launcher accepts ``--xla-overlap`` (same
flag surface as a real cluster) but skips the merge with a warning.
"""
from __future__ import annotations

import importlib.util
import os
import sys
import warnings

# The standard overlap preset for GPU clusters: async collectives +
# latency-hiding scheduler move every collective the scheduler can
# prove independent onto the (highest-priority) async stream, and the
# Triton knobs keep the fused epilogues of kernels.fused_collectives
# from being broken back apart by the fallback GEMM emitter.
OVERLAP_FLAGS = (
    "--xla_gpu_enable_triton_softmax_fusion=true",
    "--xla_gpu_triton_gemm_any=True",
    "--xla_gpu_enable_async_collectives=true",
    "--xla_gpu_enable_latency_hiding_scheduler=true",
    "--xla_gpu_enable_highest_priority_async_stream=true",
)

FLAG_NAME = "--xla-overlap"


def _flag_key(flag: str) -> str:
    return flag.split("=", 1)[0]


def _gpu_jaxlib() -> bool:
    """Whether a CUDA jaxlib/plugin is importable - the only builds
    whose flag parser knows the ``--xla_gpu_*`` options.  Checked
    without importing jax (which would lock XLA_FLAGS)."""
    for mod in ("jax_cuda12_plugin", "jax_cuda13_plugin",
                "jax_plugins.xla_cuda12", "jaxlib.cuda_extension"):
        try:
            if importlib.util.find_spec(mod) is not None:
                return True
        except (ImportError, ValueError):
            continue
    return False


def apply_overlap_preset(argv=None, *, force=False) -> bool:
    """Merge ``OVERLAP_FLAGS`` into ``os.environ['XLA_FLAGS']`` when
    ``--xla-overlap`` is present in ``argv`` (default ``sys.argv``).

    Returns True when the preset was applied.  Flags already set in the
    env var keep their value (a warning names each conflict); jax
    already being imported also warns, since XLA has then locked its
    options and the merge cannot take effect this process.  Without a
    CUDA jaxlib the merge is skipped entirely (warning): XLA aborts on
    unknown flags, so shipping GPU options to a CPU build would kill
    the launcher at init.  ``force=True`` bypasses that gate (tests).
    """
    argv = sys.argv[1:] if argv is None else list(argv)
    if FLAG_NAME not in argv:
        return False
    if not force and not _gpu_jaxlib():
        warnings.warn(
            f"{FLAG_NAME}: no CUDA jaxlib detected; this build's flag "
            "parser aborts on the GPU overlap flags, so the preset is "
            "skipped", stacklevel=2)
        return False
    if "jax" in sys.modules:
        warnings.warn(
            f"{FLAG_NAME}: jax is already imported; XLA_FLAGS changes "
            "no longer take effect in this process", stacklevel=2)
    existing = os.environ.get("XLA_FLAGS", "").split()
    have = {_flag_key(f): f for f in existing}
    merged = list(existing)
    for flag in OVERLAP_FLAGS:
        key = _flag_key(flag)
        if key in have:
            if have[key] != flag:
                warnings.warn(
                    f"{FLAG_NAME}: XLA_FLAGS already sets "
                    f"{have[key]!r}; keeping it over the preset's "
                    f"{flag!r}", stacklevel=2)
            continue
        merged.append(flag)
    os.environ["XLA_FLAGS"] = " ".join(merged)
    return True


def add_argument(parser) -> None:
    """Document the flag in a launcher's argparse parser.  The actual
    effect happens in ``apply_overlap_preset`` before jax is imported -
    argparse only supplies ``--help`` text and rejects typos."""
    parser.add_argument(
        FLAG_NAME, action="store_true",
        help="fold the XLA comm/compute-overlap compiler flags (async "
             "collectives, latency-hiding scheduler, high-priority "
             "async stream, Triton fusions) into XLA_FLAGS before jax "
             "initializes; flags pinned in an external XLA_FLAGS env "
             "var win over the preset")
