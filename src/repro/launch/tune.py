"""Offline autotuning CLI: sweep the cost model, persist a Plan.

Usage:
  PYTHONPATH=src python -m repro.launch.tune --out plan.json
  PYTHONPATH=src python -m repro.launch.tune --smoke   # coarse, fast
  PYTHONPATH=src python -m repro.launch.tune \
      --primitives all_reduce all_gather --nranks 3 6 12 \
      --sizes-mib 1 16 256 4096 --factors 1 4 16 --out plan.json

Without ``--out`` the plan lands in the fingerprint-keyed cache
(``repro.tuner.default_plan_path``) where ``backend='auto'`` finds it
automatically.  Feed the saved path to ``repro.launch.train --backend
auto --plan ...`` or ``repro.launch.serve --plan ...``.
"""
from __future__ import annotations

import argparse
import collections
import time

from repro.core.hw import MiB
from repro.core.schedule import PRIMITIVES
from repro import tuner


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default=None,
                    help="plan JSON path (default: the plan cache)")
    ap.add_argument("--smoke", action="store_true",
                    help="coarse grid (seconds instead of minutes)")
    ap.add_argument("--primitives", nargs="+", choices=PRIMITIVES,
                    default=None)
    ap.add_argument("--sizes-mib", type=int, nargs="+", default=None)
    ap.add_argument("--nranks", type=int, nargs="+", default=None)
    ap.add_argument("--factors", type=int, nargs="+", default=None)
    ap.add_argument("--overlap-compute-us", type=float, default=0.0,
                    help="overlappable compute window per collective "
                         "(microseconds); > 0 tunes by exposed time "
                         "max(0, comm - window) and marks cells "
                         "overlap=True")
    ap.add_argument("--quiet", action="store_true")
    args = ap.parse_args()

    base = tuner.SMOKE_GRID if args.smoke else tuner.DEFAULT_GRID
    grid = tuner.TuneGrid(
        primitives=tuple(args.primitives) if args.primitives
        else base.primitives,
        sizes=tuple(m * MiB for m in args.sizes_mib) if args.sizes_mib
        else base.sizes,
        nranks=tuple(args.nranks) if args.nranks else base.nranks,
        slicing_factors=tuple(args.factors) if args.factors
        else base.slicing_factors)

    progress = None if args.quiet else (lambda msg: print(f"  {msg}"))
    t0 = time.time()
    plan = tuner.generate_plan(
        grid, overlap_compute=args.overlap_compute_us * 1e-6,
        progress=progress)
    dt = time.time() - t0

    out = args.out or tuner.default_plan_path()
    tuner.save_plan(plan, out)

    by_backend = collections.Counter(
        c.backend for c in plan.entries.values())
    gains = [c.baseline_time / c.predicted_time
             for c in plan.entries.values() if c.predicted_time > 0]
    print(f"tuned {len(plan.entries)} cells in {dt:.1f}s "
          f"-> {out}")
    print(f"  fingerprint {plan.fingerprint}")
    print(f"  choices: {dict(by_backend)}")
    if gains:
        print(f"  predicted gain vs best fixed knobs: "
              f"mean {sum(gains) / len(gains):.3f}x, "
              f"max {max(gains):.3f}x")


if __name__ == "__main__":
    main()
