"""Offline autotuning CLI: sweep the cost model, persist a Plan.

Usage:
  PYTHONPATH=src python -m repro.launch.tune --out plan.json
  PYTHONPATH=src python -m repro.launch.tune --smoke   # coarse, fast
  PYTHONPATH=src python -m repro.launch.tune \
      --primitives all_reduce all_gather --nranks 3 6 12 \
      --sizes-mib 1 16 256 4096 --factors 1 4 16 --out plan.json
  PYTHONPATH=src python -m repro.launch.tune \
      --topology "pod:ib,node:cxl,gpu:ici" --out plan.json
  PYTHONPATH=src python -m repro.launch.tune --topology topo.json \
      --overlap-from-dryrun experiments/dryrun --out plan.json
  PYTHONPATH=src python -m repro.launch.tune \
      --measurements experiments/timings --out plan.json   # v4 fold

``--topology`` accepts the compact ``axis:fabric,...`` string
(outermost level first) or a JSON file with per-level fabric config
overrides (see ``core.topology``); the sweep then tunes every level
against its own fabric and embeds the topology in the plan, so feeding
the plan to ``--backend auto`` launchers activates hierarchical
decomposition automatically.  Axis names must match the mesh axes the
consuming launcher builds (``pod``/``data``/``model`` for the
production mesh) - the launchers warn when a mesh axis has no level.  ``--overlap-from-dryrun`` derives
per-primitive overlap windows from dry-run roofline records instead of
the constant ``--overlap-compute-us`` window.

Without ``--out`` the plan lands in the fingerprint-keyed cache
(``repro.tuner.default_plan_path``) where ``backend='auto'`` finds it
automatically.  Feed the saved path to ``repro.launch.train --backend
auto --plan ...`` or ``repro.launch.serve --plan ...``.
"""
from __future__ import annotations

import argparse
import collections
import glob
import json
import os
import time

from repro.core.hw import MiB
from repro.core.schedule import PRIMITIVES
from repro.core.topology import parse_topology
from repro import tuner


def load_dryrun_records(path: str) -> list:
    """Load dry-run JSON records from a directory, glob, or file."""
    if os.path.isdir(path):
        files = sorted(glob.glob(os.path.join(path, "*.json")))
    elif any(c in path for c in "*?["):
        files = sorted(glob.glob(path))
    else:
        files = [path]
    records = []
    for f in files:
        try:
            with open(f) as fh:
                records.append(json.load(fh))
        except (OSError, ValueError):
            continue
    return records


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default=None,
                    help="plan JSON path (default: the plan cache)")
    ap.add_argument("--smoke", action="store_true",
                    help="coarse grid (seconds instead of minutes)")
    ap.add_argument("--primitives", nargs="+", choices=PRIMITIVES,
                    default=None)
    ap.add_argument("--sizes-mib", type=int, nargs="+", default=None)
    ap.add_argument("--nranks", type=int, nargs="+", default=None)
    ap.add_argument("--factors", type=int, nargs="+", default=None)
    ap.add_argument("--topology", default=None,
                    help="'axis:fabric,...' spec (outermost first; "
                         "fabrics: cxl|ib|ici) or a topology JSON file; "
                         "tunes each level against its own fabric")
    ap.add_argument("--overlap-compute-us", type=float, default=0.0,
                    help="overlappable compute window per collective "
                         "(microseconds); > 0 tunes by exposed time "
                         "max(0, comm - window) and marks cells "
                         "overlap=True")
    ap.add_argument("--overlap-from-dryrun", default=None,
                    help="directory/glob of dry-run JSON records; "
                         "derives per-primitive overlap windows from "
                         "their roofline + ledger data (replaces the "
                         "constant --overlap-compute-us window)")
    ap.add_argument("--measurements", default=None,
                    help="directory/glob/file of ledger timing records "
                         "(snapshot()['timings'], e.g. a train run's "
                         "--plan-out sidecar or a persisted snapshot); "
                         "folds the measured per-cell wall times into "
                         "the swept plan (tuner.online), emitting a "
                         "format-v4 plan whose measured cells override "
                         "the oracle")
    ap.add_argument("--placement-report", default=None, metavar="ARCH",
                    help="with --topology: rank the mesh-axis -> "
                         "fabric-level assignments for this arch's "
                         "analytic collective mix (tuner.placement), "
                         "print the table, and embed the ranked "
                         "PlacementPlan in the plan metadata "
                         "(Plan.placement())")
    ap.add_argument("--placement-axes", default=None,
                    help="logical axis degrees for the report, "
                         "'name=size,...' (default: derived from the "
                         "declared level sizes - innermost placeable "
                         "level is the model axis, the rest multiply "
                         "into the data axis)")
    ap.add_argument("--kv-block-bytes", type=int, nargs="+",
                    default=None, metavar="BYTES",
                    help="also tune kv_block cache-placement cells "
                         "(serving eviction: CXL pool round-trip vs "
                         "prefill recompute) at these KV-image sizes; "
                         "consumed by repro.serving ServeEngine via "
                         "--plan; requires --kv-arch to price the "
                         "recompute arm")
    ap.add_argument("--kv-arch", default=None, metavar="ARCH",
                    help="architecture whose cache footprint and "
                         "active parameter count price the kv_block "
                         "recompute arm")
    ap.add_argument("--quiet", action="store_true")
    args = ap.parse_args()
    if args.kv_block_bytes and not args.kv_arch:
        ap.error("--kv-block-bytes requires --kv-arch")

    base = tuner.SMOKE_GRID if args.smoke else tuner.DEFAULT_GRID
    grid = tuner.TuneGrid(
        primitives=tuple(args.primitives) if args.primitives
        else base.primitives,
        sizes=tuple(m * MiB for m in args.sizes_mib) if args.sizes_mib
        else base.sizes,
        nranks=tuple(args.nranks) if args.nranks else base.nranks,
        slicing_factors=tuple(args.factors) if args.factors
        else base.slicing_factors)

    topology = parse_topology(args.topology) if args.topology else None

    overlap = args.overlap_compute_us * 1e-6
    if args.overlap_from_dryrun:
        if args.overlap_compute_us:
            ap.error("--overlap-from-dryrun and --overlap-compute-us "
                     "are mutually exclusive")
        records = load_dryrun_records(args.overlap_from_dryrun)
        overlap = tuner.overlap_windows_from_dryrun(records)
        got = {p: f"{w * 1e6:.1f}us"
               for p, w in overlap.per_primitive.items()}
        print(f"overlap windows from {len(records)} dry-run records: "
              f"{got}")

    progress = None if args.quiet else (lambda msg: print(f"  {msg}"))
    t0 = time.time()
    plan = tuner.generate_plan(grid, topology=topology,
                               overlap_compute=overlap,
                               progress=progress)
    if args.measurements:
        timings = []
        for rec in load_dryrun_records(args.measurements):
            # accept either a bare timing list or any record carrying a
            # ledger snapshot (top-level or under "ledger")
            if isinstance(rec, list):
                timings.extend(rec)
            elif isinstance(rec, dict):
                timings.extend(rec.get("timings")
                               or (rec.get("ledger") or {}).get(
                                   "timings") or [])
        plan = tuner.fold_measurements(plan, timings)
        measured = sum(c.sample_count > 0
                       for c in plan.entries.values())
        print(f"folded {len(timings)} measured samples into "
              f"{measured} cells")
    if args.kv_block_bytes:
        # Serving-tier cells: same Plan, primitive "kv_block", priced
        # by the shared CXL cost constants.  ServeEngine's eviction
        # path looks these up before falling back to the live oracle.
        import jax.numpy as jnp

        from repro.configs import get_config
        from repro.models.pcontext import UNSHARDED
        from repro.serving import kvcache
        kcfg = get_config(args.kv_arch, smoke=args.smoke)
        layout = kvcache.CacheLayout(kcfg, UNSHARDED, 1, 128,
                                     jnp.dtype("float32"))
        per_tok = max(1, layout.bytes_for(64) // 64)
        picks = collections.Counter()
        for nbytes in args.kv_block_bytes:
            ntok = max(1, nbytes // per_tok)
            choice = kvcache.price_kv_block(
                nbytes, 2.0 * kcfg.active_param_count() * ntok)
            plan.add("kv_block", nbytes, 1, choice)
            picks[choice.backend] += 1
        print(f"  kv_block ({args.kv_arch}, "
              f"{per_tok} B/token): {dict(picks)} over "
              f"{len(args.kv_block_bytes)} sizes")
    if args.placement_report:
        if topology is None:
            ap.error("--placement-report requires --topology")
        from repro.configs import get_config
        cfg = get_config(args.placement_report)
        if args.placement_axes:
            axes = {k: int(v) for k, v in
                    (p.split("=") for p in
                     args.placement_axes.split(","))}
        else:
            lvs = topology.levels
            placeable = [lv for i, lv in enumerate(lvs)
                         if not (i + 1 < len(lvs)
                                 and lvs[i + 1].grouped)]
            sizes = [lv.size for lv in placeable]
            if any(s is None for s in sizes):
                ap.error("--placement-report needs --placement-axes "
                         "when topology level sizes are undeclared")
            data = 1
            for s in sizes[:-1]:
                data *= s
            axes = {"data": data, "model": sizes[-1]}
        mix = tuner.CollectiveMix.for_model(cfg, axes)
        pplan = tuner.plan_placement(mix, topology)
        print(tuner.format_report(pplan))
        plan.meta["placement"] = pplan.to_json()
    dt = time.time() - t0

    out = args.out or tuner.default_plan_path(topology=topology)
    tuner.save_plan(plan, out)

    by_backend = collections.Counter(
        c.backend for c in plan.entries.values())
    gains = [c.baseline_time / c.predicted_time
             for c in plan.entries.values() if c.predicted_time > 0]
    print(f"tuned {len(plan.entries)} cells in {dt:.1f}s "
          f"-> {out}")
    print(f"  fingerprint {plan.fingerprint}")
    print(f"  choices: {dict(by_backend)}")
    if topology is not None:
        for lv in topology.levels:
            lkey = topology.level_key(lv.axis)
            mix = collections.Counter(
                c.backend for k, c in plan.entries.items()
                if len(k) == 4 and k[3] == lkey)
            print(f"  level {lv.axis} ({lv.fabric}, "
                  f"{lv.fingerprint()}): {dict(mix)}")
    if gains:
        print(f"  predicted gain vs best fixed knobs: "
              f"mean {sum(gains) / len(gains):.3f}x, "
              f"max {max(gains):.3f}x")


if __name__ == "__main__":
    main()
