import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
# The two lines above MUST run before any jax import: jax locks the device
# count on first init.  REPRO_DRYRUN_DEVICES overrides for local testing.
if os.environ.get("REPRO_DRYRUN_DEVICES"):
    os.environ["XLA_FLAGS"] = ("--xla_force_host_platform_device_count="
                               + os.environ["REPRO_DRYRUN_DEVICES"])
# --xla-overlap merges the overlap preset into the flags just set; it
# shares the same must-precede-jax constraint, hence the odd placement.
from repro.launch import xla
xla.apply_overlap_preset()

"""Multi-pod dry-run driver.

For every (architecture x input shape x mesh) combination this lowers and
compiles the real step function - train_step for ``train_4k``, prefill
for ``prefill_32k``, serve_step (one token against a KV cache) for
``decode_32k`` / ``long_500k`` - against ShapeDtypeStruct inputs (no
allocation), prints ``memory_analysis()`` / ``cost_analysis()``, parses
per-chip collective wire bytes out of the compiled HLO, and writes a JSON
record for the roofline analysis (EXPERIMENTS.md Sec. Roofline).

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch llama3.2-1b \
      --shape train_4k [--multi-pod|--both-meshes] \
      [--backend ring|cxl] [--mesh-shape DPxTP] [--out DIR]
  PYTHONPATH=src python -m repro.launch.dryrun --arch all --shape all \
      --both-meshes
"""
import argparse
import dataclasses
import json
import re
import time
import traceback

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from repro.configs import ARCH_IDS, get_config
from repro.core.api import Communicator
from repro.launch.mesh import dp_axes, make_production_mesh
from repro.models import model, sharding
from repro.models.config import ModelConfig
from repro.models.pcontext import ParallelContext, UNSHARDED
from repro.optim import AdamWState
from repro.training.train_loop import (TrainConfig, make_gather_fn,
                                       make_train_step)

SHAPES = {
    "train_4k": dict(seq_len=4096, global_batch=256, kind="train"),
    "prefill_32k": dict(seq_len=32768, global_batch=32, kind="prefill"),
    "decode_32k": dict(seq_len=32768, global_batch=128, kind="decode"),
    "long_500k": dict(seq_len=524288, global_batch=1, kind="decode"),
}

DTYPE_BYTES = {"f64": 8, "f32": 4, "f16": 2, "bf16": 2, "s64": 8,
               "s32": 4, "u64": 8, "u32": 4, "s16": 2, "u16": 2,
               "s8": 1, "u8": 1, "pred": 1, "c64": 8, "c128": 16}

_COLL_RE = re.compile(
    r"=\s+(\w+)\[([\d,]*)\][^\s]*\s+"
    r"(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
    r"[\w-]*\(")
_GROUPS_RE = re.compile(r"replica_groups=\{?\{([\d,]+)\}")
_GROUPS_IOTA_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")


def _result_bytes(dtype: str, dims: str) -> int:
    n = 1
    for d in dims.split(","):
        if d:
            n *= int(d)
    return n * DTYPE_BYTES.get(dtype, 4)


def parse_collectives(hlo: str) -> dict:
    """Per-chip wire bytes by collective type, from the partitioned HLO.

    Result-shape bytes are converted to wire bytes with the standard ring
    cost for the op's group size n (parsed from replica_groups)."""
    out = {"all-gather": 0.0, "all-reduce": 0.0, "reduce-scatter": 0.0,
           "all-to-all": 0.0, "collective-permute": 0.0}
    counts = dict.fromkeys(out, 0)
    for line in hlo.splitlines():
        m = _COLL_RE.search(line)
        if not m:
            continue
        dtype, dims, op = m.groups()
        rb = _result_bytes(dtype, dims)
        n = None
        g = _GROUPS_RE.search(line)
        if g:
            n = len(g.group(1).split(","))
        else:
            g2 = _GROUPS_IOTA_RE.search(line)
            if g2:
                n = int(g2.group(2))
        if not n or n < 2:
            if op == "collective-permute":
                n = 2  # permute always moves the full payload
            else:
                continue
        if op == "all-gather":
            wire = rb * (n - 1) / n
        elif op == "all-reduce":
            wire = rb * 2 * (n - 1) / n
        elif op == "reduce-scatter":
            wire = rb * (n - 1)
        elif op == "all-to-all":
            wire = rb * (n - 1) / n
        else:  # collective-permute
            wire = float(rb)
        out[op] += wire
        counts[op] += 1
    return {"wire_bytes": out, "counts": counts,
            "total_wire_bytes": sum(out.values())}


# --------------------------------------------------------------------- #
# input builders
# --------------------------------------------------------------------- #

def _sds(shape, dtype):
    return jax.ShapeDtypeStruct(shape, dtype)


def batch_sds(cfg: ModelConfig, batch: int, seq: int) -> tuple:
    """(batch dict of SDS, specs dict).  Text length shrinks by the
    frontend prefix for decoder-only stub-frontend models."""
    text = seq - (cfg.frontend_tokens
                  if cfg.frontend != "text" and cfg.encoder is None
                  else 0)
    b = {"tokens": _sds((batch, text), jnp.int32),
         "labels": _sds((batch, text), jnp.int32)}
    if cfg.frontend == "vision_stub" and cfg.encoder is None:
        b["frontend"] = _sds((batch, cfg.frontend_tokens,
                              cfg.frontend_dim), jnp.bfloat16)
    if cfg.encoder is not None:
        b["source"] = _sds((batch, cfg.encoder.source_len,
                            cfg.frontend_dim or cfg.d_model), jnp.bfloat16)
    return b


def has_attention(cfg: ModelConfig) -> bool:
    return any(ch in "ae" for ch in cfg.layer_pattern)


def plan_report(ledger_snap: dict) -> dict:
    """Plan-aware dry-run report (ROADMAP item): condense the auto-plan
    audit trail (``ledger.snapshot()["auto_choices"]``) into the
    per-shape backend mix and the predicted step-time delta of the
    tuned choices vs the best fixed knobs, split per topology level.

    Choice records carry the cost model's predicted/baseline times and
    the ambient trip-count scale at the call site, so the deltas are
    per-step (not per-call-site) estimates.  Times are those of the
    nearest tuned plan cell (log2-bucketed sizes, nearest nranks), so
    absolute seconds are approximate when a call site falls outside the
    tuned grid; the delta's sign and per-level split remain exact."""
    choices = ledger_snap.get("auto_choices") or []
    by_backend: dict = {}
    by_prim: dict = {}
    by_level: dict = {}
    predicted = baseline = 0.0
    for ch in choices:
        calls = float(ch.get("calls", 1.0))
        key = ch["backend"]
        by_backend[key] = by_backend.get(key, 0.0) + calls
        prim = by_prim.setdefault(ch["primitive"], {})
        prim[key] = prim.get(key, 0.0) + calls
        lvl = f"{ch.get('level') or 'flat'}/{ch.get('fabric') or '?'}"
        rec = by_level.setdefault(
            lvl, {"calls": 0.0, "predicted_s": 0.0, "baseline_s": 0.0})
        rec["calls"] += calls
        rec["predicted_s"] += ch.get("predicted_time", 0.0) * calls
        rec["baseline_s"] += ch.get("baseline_time", 0.0) * calls
        predicted += ch.get("predicted_time", 0.0) * calls
        baseline += ch.get("baseline_time", 0.0) * calls
    for rec in by_level.values():
        rec["delta_s"] = rec["baseline_s"] - rec["predicted_s"]
    return {
        "backend_mix": by_backend,
        "backend_mix_by_primitive": by_prim,
        "per_level": by_level,
        "predicted_comm_s": predicted,
        "baseline_comm_s": baseline,
        "predicted_step_delta_s": baseline - predicted,
    }


def decode_window(cfg: ModelConfig, shape_name: str):
    """long_500k uses the sliding-window ring buffer for attention rows
    (SSM rows are O(1) regardless) - see DESIGN.md Arch-applicability."""
    if shape_name == "long_500k" and has_attention(cfg):
        return cfg.sliding_window
    return None


def cache_specs(cfg: ModelConfig, cache_tree, dp, batch_sharded: bool,
                tp: int):
    """PartitionSpecs for a decode cache pytree (global shapes): KV cache
    sequence dim and SSM channel dims shard over 'model'; batch over dp.
    Cross-attention KV shards heads over 'model' when divisible (matching
    the prefill-produced layout), else replicates."""
    from jax.tree_util import DictKey, SequenceKey, tree_map_with_path
    bax = dp if batch_sharded else None
    v = cfg.ssm.version if cfg.ssm else 0
    cross_head_ax = "model" if cfg.kv_sharded(tp) else None

    def spec(path, leaf):
        names = [k.key for k in path if isinstance(k, DictKey)]
        name = names[-1]
        r = len(leaf.shape)
        if name in ("k", "v"):
            base = P(bax, "model", None, None)
            return P(*( (None,) * (r - 4) + tuple(base)))
        if name in ("ck", "cv"):
            return P(*((None,) * (r - 4)
                       + (bax, None, cross_head_ax, None)))
        if name == "conv":
            return P(*((None,) * (r - 3) + (bax, None, "model")))
        if name == "conv_bc":
            return P(*((None,) * (r - 3) + (bax, None, None)))
        if name == "ssm":
            base_rank = 3 if v == 1 else 4
            base = (bax, "model") + (None,) * (base_rank - 2)
            return P(*((None,) * (r - base_rank) + base))
        raise ValueError(f"unknown cache leaf {name}")
    return tree_map_with_path(spec, cache_tree)


# --------------------------------------------------------------------- #
# per-shape lowering
# --------------------------------------------------------------------- #

def build_lowerable(arch: str, shape_name: str, mesh, backend: str,
                    allreduce_mode: str = "two_phase",
                    bucket_mb: float = 25.0, prefetch: int = 1):
    """Returns (fn_to_lower, example_args) - fn is already jit+shard_map
    wrapped; args are ShapeDtypeStructs."""
    cfg = get_config(arch)
    info = SHAPES[shape_name]
    seq, gbatch, kind = (info["seq_len"], info["global_batch"],
                         info["kind"])
    tp = mesh.shape["model"]
    dp = dp_axes(mesh)
    dp_spec = dp if len(dp) > 1 else dp[0]
    dp_size = int(np.prod([mesh.shape[a] for a in dp]))
    sharding.set_mesh_sizes({a: mesh.shape[a] for a in mesh.axis_names})
    comm = Communicator(backend=backend, allreduce_mode=allreduce_mode)
    pc = ParallelContext(tp_axis="model", dp_axis=dp_spec, tp=tp,
                         comm=comm)

    abstract = model.abstract_params(cfg, tp=tp, dtype=jnp.bfloat16)

    if kind == "train":
        pspecs = sharding.param_specs(abstract, cfg, dp_axis=dp_spec,
                                      fsdp=True)
        rspecs = sharding.row_specs(pspecs)
        local_b = gbatch // dp_size
        mb = max(1, local_b // 2)   # microbatch of 2 sequences per chip
        tcfg = TrainConfig(remat=True, microbatches=mb, backend=backend,
                           clip_norm=None, bucket_mb=bucket_mb,
                           prefetch=prefetch)
        # bucketed FSDP gathers + double-buffered prefetch (core.overlap)
        # - the production schedule; --bucket-mb 0 --prefetch 0 restore
        # the per-leaf serialized baseline for A/B dry-runs.
        gather = make_gather_fn(tcfg, rspecs, pc, dp_spec)
        inner = make_train_step(cfg, tcfg, pc, gather_fn=gather,
                                param_spec_tree=pspecs, dp_axis=dp_spec)
        batch = batch_sds(cfg, gbatch, seq)
        bspecs = {k: P(dp_spec) for k in batch}
        opt = AdamWState(
            step=_sds((), jnp.int32),
            mu=jax.tree.map(lambda x: _sds(x.shape, jnp.float32),
                            abstract),
            nu=jax.tree.map(lambda x: _sds(x.shape, jnp.float32),
                            abstract))
        ospecs = AdamWState(step=P(), mu=pspecs, nu=pspecs)
        mspecs = {"loss": P(), "lr": P(), "grad_norm": P(), "xent": P(),
                  "aux": P()}
        fn = jax.jit(jax.shard_map(
            inner, mesh=mesh, in_specs=(pspecs, ospecs, bspecs),
            out_specs=(pspecs, ospecs, mspecs), check_vma=False))
        return fn, (abstract, opt, batch), cfg

    pspecs = sharding.param_specs(abstract, cfg, dp_axis=dp_spec,
                                  fsdp=False)  # inference: TP-resident
    if kind == "prefill":
        batch = batch_sds(cfg, gbatch, seq)
        bspecs = {k: P(dp_spec) for k in batch}
        cd = jnp.bfloat16

        def prefill_fn(p, b):
            return model.prefill(p, b, cfg, pc, max_seq=seq,
                                 cache_dtype=cd)
        # global cache shapes: init_cache is params-free (avoids tp
        # padding skew), and prefill emits the same structure/layout
        cache_abs = jax.eval_shape(
            lambda: model.init_cache(cfg, UNSHARDED, gbatch, seq,
                                     cache_dtype=cd))
        cspecs = cache_specs(cfg, cache_abs, dp_spec, batch_sharded=True, tp=tp)
        logit_spec = P(dp_spec, None, "model")
        fn = jax.jit(jax.shard_map(
            prefill_fn, mesh=mesh, in_specs=(pspecs, bspecs),
            out_specs=(logit_spec, cspecs), check_vma=False))
        return fn, (abstract, batch), cfg

    # decode kinds
    window = decode_window(cfg, shape_name)
    batch_sharded = gbatch >= dp_size and gbatch % dp_size == 0
    cd = jnp.bfloat16
    cache_abs = jax.eval_shape(
        lambda: model.init_cache(cfg, UNSHARDED, gbatch, seq,
                                 cache_dtype=cd, window=window))
    cspecs = cache_specs(cfg, cache_abs, dp_spec,
                         batch_sharded=batch_sharded, tp=tp)
    tok = _sds((gbatch, 1), jnp.int32)
    tok_spec = P(dp_spec if batch_sharded else None, None)
    pos = _sds((), jnp.int32)

    def serve_fn(p, c, t, pos):
        return model.decode_step(p, c, t, pos, cfg, pc, window=window)
    logit_spec = P(dp_spec if batch_sharded else None, None, None)
    fn = jax.jit(jax.shard_map(
        serve_fn, mesh=mesh,
        in_specs=(pspecs, cspecs, tok_spec, P()),
        out_specs=(logit_spec, cspecs), check_vma=False))
    return fn, (abstract, cache_abs, tok, pos), cfg


def run_one(arch: str, shape_name: str, multi_pod: bool, backend: str,
            out_dir: str, mesh_shape: str = None,
            allreduce_mode: str = "two_phase",
            bucket_mb: float = 25.0, prefetch: int = 1,
            placement: str = None) -> dict:
    """``mesh_shape``: 'DPxTP' logical re-factorization of the single pod
    (same 256 chips) - the §Perf mesh-reshape experiments.
    ``placement``: 'auto' or a placement JSON; with an active topology
    the mesh is built from the planned axis->level assignment
    (``tuner.placement``) and the ranked report lands in the record."""
    mesh_name = ("pod" + mesh_shape) if mesh_shape else (
        "pod2x16x16" if multi_pod else "pod16x16")
    rec = {"arch": arch, "shape": shape_name, "mesh": mesh_name,
           "backend": backend, "allreduce_mode": allreduce_mode,
           "bucket_mb": bucket_mb, "prefetch": prefetch,
           "status": "error"}
    t0 = time.time()
    try:
        if placement:
            from repro import tuner
            from repro.core.topology import get_active_topology
            from repro.launch.mesh import make_placed_mesh
            topo = get_active_topology()
            if topo is None or not mesh_shape:
                raise ValueError("--placement needs --topology and "
                                 "--mesh-shape DPxTP")
            dp_, tp_ = (int(x) for x in mesh_shape.split("x"))
            info = SHAPES[shape_name]
            mix = tuner.CollectiveMix.for_model(
                get_config(arch), {"data": dp_, "model": tp_},
                seq=info["seq_len"],
                batch_per_rank=max(1, info["global_batch"] // dp_))
            pplan = tuner.plan_placement(mix, topo) \
                if placement == "auto" else \
                tuner.load_placement(placement)
            chosen = pplan.best_with_unsplit(("model",))
            rec["placement"] = {
                "chosen": chosen.to_json(),
                "candidates": len(pplan.ranked),
                "meta": pplan.meta}
            print(tuner.format_report(pplan, chosen=chosen))
            mesh = make_placed_mesh(chosen, mix, topo)
        elif mesh_shape:
            dp_, tp_ = (int(x) for x in mesh_shape.split("x"))
            mesh = jax.make_mesh((dp_, tp_), ("data", "model"))
        elif os.environ.get("REPRO_DRYRUN_DEVICES"):
            # reduced mesh for plumbing tests (REPRO_DRYRUN_DEVICES=8)
            mesh = jax.make_mesh((2, 2, 2), ("pod", "data", "model")) \
                if multi_pod else jax.make_mesh((2, 2),
                                                ("data", "model"))
        else:
            mesh = make_production_mesh(multi_pod=multi_pod)
        from repro.core.topology import get_active_topology, \
            warn_uncovered
        if get_active_topology() is not None:
            warn_uncovered(get_active_topology(), mesh)
        fn, args, cfg = build_lowerable(arch, shape_name, mesh, backend,
                                        allreduce_mode=allreduce_mode,
                                        bucket_mb=bucket_mb,
                                        prefetch=prefetch)
        from repro.core import ledger
        ledger.reset()
        lowered = fn.lower(*args)
        # trace-time wire-byte ledger: exact per-step collective bytes
        # including scan trip counts, microbatch loops, remat replays and
        # AD transposes (the HLO parse below counts scan bodies ONCE -
        # see EXPERIMENTS.md "scan undercount").
        rec["ledger"] = ledger.snapshot()
        if backend == "auto":
            rec["plan_report"] = plan_report(rec["ledger"])
        rec["lower_s"] = round(time.time() - t0, 1)
        t1 = time.time()
        compiled = lowered.compile()
        rec["compile_s"] = round(time.time() - t1, 1)
        mem = compiled.memory_analysis()
        rec["memory"] = {
            k: int(getattr(mem, k)) for k in
            ("argument_size_in_bytes", "output_size_in_bytes",
             "temp_size_in_bytes", "generated_code_size_in_bytes")
            if hasattr(mem, k)}
        ca = compiled.cost_analysis()
        if isinstance(ca, (list, tuple)):
            ca = ca[0]
        rec["cost"] = {k: float(v) for k, v in dict(ca).items()
                       if isinstance(v, (int, float))}
        rec["collectives"] = parse_collectives(compiled.as_text())
        rec["params"] = int(cfg.param_count(tp=mesh.shape["model"]))
        rec["active_params"] = int(
            cfg.active_param_count(tp=mesh.shape["model"]))
        rec["status"] = "ok"
        print(f"[dryrun] {arch} {shape_name} {mesh_name} {backend}: OK "
              f"(lower {rec['lower_s']}s, compile {rec['compile_s']}s)")
        print(f"  memory: {rec['memory']}")
        flops = rec["cost"].get("flops", 0.0)
        print(f"  flops/chip: {flops:.3e}  wire bytes/chip: "
              f"{rec['collectives']['total_wire_bytes']:.3e}")
        lvl_bytes = rec["ledger"].get("level_wire_bytes") or {}
        for lvl, kinds in sorted(lvl_bytes.items()):
            print(f"  level {lvl}: {sum(kinds.values()):.3e} "
                  f"ledger wire bytes")
        if "plan_report" in rec:
            pr = rec["plan_report"]
            print(f"  plan: backend mix {pr['backend_mix']}, predicted "
                  f"step-time delta vs best fixed "
                  f"{pr['predicted_step_delta_s']:.3e}s")
            for lvl, r in sorted(pr["per_level"].items()):
                print(f"    {lvl}: {r['calls']:.0f} calls, "
                      f"delta {r['delta_s']:.3e}s")
    except Exception as e:  # noqa: BLE001 - record and continue
        rec["error"] = f"{type(e).__name__}: {e}"
        rec["traceback"] = traceback.format_exc()[-2000:]
        print(f"[dryrun] {arch} {shape_name} {mesh_name} {backend}: "
              f"FAIL {rec['error']}")
    if out_dir:
        os.makedirs(out_dir, exist_ok=True)
        suffix = "" if allreduce_mode == "two_phase" else \
            f"_{allreduce_mode}"
        fname = (f"{arch}_{shape_name}_{mesh_name}_{backend}"
                 f"{suffix}.json")
        with open(os.path.join(out_dir, fname), "w") as f:
            json.dump(rec, f, indent=1)
    return rec


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ARCH_IDS + ["all"], default="all")
    ap.add_argument("--shape", choices=list(SHAPES) + ["all"],
                    default="all")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--backend", choices=["ring", "cxl", "auto"],
                    default="ring")
    ap.add_argument("--plan", default=None,
                    help="autotuning plan for --backend auto; the "
                         "per-collective decisions land in the record's "
                         "ledger.auto_choices and the condensed "
                         "plan_report")
    ap.add_argument("--topology", default=None,
                    help="'axis:fabric,...' spec or topology JSON: "
                         "decompose tuple-axis collectives per level "
                         "and split ledger wire bytes per fabric")
    ap.add_argument("--mesh-shape", default=None,
                    help="DPxTP single-pod logical mesh override")
    ap.add_argument("--placement", default=None,
                    help="'auto' or a saved placement JSON: build the "
                         "mesh from the planned axis->level assignment "
                         "(tuner.placement; needs --topology and "
                         "--mesh-shape) and record the ranked report")
    ap.add_argument("--allreduce-mode", default="two_phase",
                    choices=["two_phase", "faithful"])
    ap.add_argument("--bucket-mb", type=float, default=25.0,
                    help="grad-sync bucket cap for the train shape; "
                         "> 0 also row-fuses the FSDP gathers "
                         "(0 = per-leaf collectives)")
    ap.add_argument("--prefetch", type=int, default=1, choices=[0, 1],
                    help="FSDP AllGather prefetch depth for the train "
                         "shape (0 = serialized baseline)")
    ap.add_argument("--out", default="experiments/dryrun")
    ap.add_argument("--metrics-out", default=None,
                    help="export each run's ledger-derived metrics as "
                         "JSON-lines here (repro.obs schema, one "
                         "sample per line tagged with the run id) "
                         "plus a Prometheus rendering of the last "
                         "run's registry at <base>.prom")
    xla.add_argument(ap)
    args = ap.parse_args()

    if args.topology:
        from repro.core.topology import parse_topology, \
            set_active_topology
        set_active_topology(parse_topology(args.topology))
    if args.plan:
        from repro.core.hw import CXL_POOL, INFINIBAND
        from repro.tuner import activate_plan_file
        activate_plan_file(args.plan, pool=CXL_POOL, ib=INFINIBAND)
    archs = ARCH_IDS if args.arch == "all" else [args.arch]
    shapes = list(SHAPES) if args.shape == "all" else [args.shape]
    meshes = [False, True] if args.both_meshes else [args.multi_pod]
    failures = 0
    mf = open(args.metrics_out, "w") if args.metrics_out else None
    last_reg = None
    for arch in archs:
        for shape in shapes:
            for mp in meshes:
                rec = run_one(arch, shape, mp, args.backend, args.out,
                              mesh_shape=args.mesh_shape,
                              allreduce_mode=args.allreduce_mode,
                              bucket_mb=args.bucket_mb,
                              prefetch=args.prefetch,
                              placement=args.placement)
                failures += rec["status"] != "ok"
                if mf is not None and rec.get("ledger"):
                    from repro.obs import MetricsRegistry, from_ledger
                    reg = MetricsRegistry()
                    from_ledger(reg, rec["ledger"])
                    run_id = f"{arch}/{shape}" + (
                        "/multi_pod" if mp else "")
                    for m in reg.metrics():
                        for name, key, v in m.samples():
                            mf.write(json.dumps(
                                {"kind": "metric", "run": run_id,
                                 "name": name, "type": m.kind,
                                 "labels": dict(key), "value": v},
                                sort_keys=True) + "\n")
                    last_reg = reg
    if mf is not None:
        mf.close()
        if last_reg is not None:
            prom = os.path.splitext(args.metrics_out)[0] + ".prom"
            with open(prom, "w") as f:
                f.write(last_reg.to_prometheus())
            print(f"[dryrun] metrics: {args.metrics_out} (+ {prom})")
    print(f"[dryrun] done; {failures} failures")
    raise SystemExit(1 if failures else 0)


if __name__ == "__main__":
    main()
