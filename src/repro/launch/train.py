"""Production training launcher.

On a real TPU cluster this process runs per host (jax.distributed); on
this CPU container it drives the same code over forced host devices.

Usage:
  PYTHONPATH=src python -m repro.launch.train --arch llama3.2-1b \
      --steps 100 --backend cxl [--multi-pod] [--smoke]
  XLA_FLAGS=--xla_force_host_platform_device_count=8 \
      PYTHONPATH=src python -m repro.launch.train --arch yi-6b --smoke \
      --mesh 2x4 --steps 20
  XLA_FLAGS=--xla_force_host_platform_device_count=8 \
      PYTHONPATH=src python -m repro.launch.train --arch yi-6b --smoke \
      --pp-stages 2 --microbatches 4 --pp-schedule 1f1b --batch 16
  PYTHONPATH=src python -m repro.launch.train --arch llama3.2-1b \
      --smoke --backend auto --plan plan.json --online-retune \
      --retune-interval 10 --plan-out refined.json
  PYTHONPATH=src python -m repro.launch.train --arch llama3.2-1b \
      --smoke --backend auto --online-retune --timing-source emulator \
      --metrics-out run.jsonl --trace-out run.trace.json

Observability (repro.obs): ``--metrics-out`` streams step/retune/health
events as JSON-lines and dumps the final metric registry (+ a
Prometheus rendering next to it); ``--trace-out`` keeps a flight
recorder of the last ``--trace-steps`` steps and writes a Chrome trace
openable in Perfetto.  ``--timing-source`` picks where measured
per-collective times come from: ``step`` (apportion the step wall time
over the trace-time profile - the pre-obs behavior), ``emulator`` (the
device-free oracle-driven ``obs.StepEmulator``; ``--emu-degrade``
injects link slowdowns), or ``profiler`` (parse ``jax.profiler``
traces; falls back to ``step`` if the build emits none).  With
``--online-retune``, emulator/profiler sources feed the tuner
*candidate-level* measurements instead of step-time apportioning.

Resilience (repro.resilience): ``--fault-plan`` injects a seeded fault
schedule (rank deaths / link degrades / pool-error windows) through
the emulator degrade hooks and the pool fault shim; ``--resilience``
runs the closed detect -> re-plan -> resume loop around it —
heartbeat/health monitoring each step, an automatic survivor or
failover re-plan hot-swapped on confirmation, and a warm rollback to
the newest pool-resident snapshot (``--pool-ckpt-interval``).
``--ewma-decay``/``--explore-eps`` let the online tuner walk back to
calibrated oracle predictions after a fault heals (see
docs/RESILIENCE.md).
"""
from __future__ import annotations

import argparse
import contextlib
import time

from repro.launch import xla
xla.apply_overlap_preset()   # --xla-overlap: must precede the jax import

import jax
import jax.numpy as jnp

from repro.configs import ARCH_IDS, get_config
from repro.data.pipeline import SyntheticTokens
from repro.launch.mesh import dp_axes, make_production_mesh
from repro.models import model
from repro.optim import adamw_init
from repro.training import checkpoint
from repro.training.train_loop import (TrainConfig,
                                       make_sharded_train_step)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ARCH_IDS, required=True)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--backend", choices=["ring", "cxl", "auto"],
                    default="ring")
    ap.add_argument("--plan", default=None,
                    help="autotuning plan JSON (see repro.launch.tune); "
                         "used by --backend auto; a topology plan also "
                         "activates hierarchical decomposition")
    ap.add_argument("--topology", default=None,
                    help="'axis:fabric,...' spec or topology JSON file: "
                         "tuple-axis collectives decompose per level "
                         "(default: the plan's embedded topology, if "
                         "any)")
    ap.add_argument("--online-retune", action="store_true",
                    help="feed measured step times back into the plan "
                         "(per-cell EWMA, tuner.online) and hot-swap "
                         "the refreshed plan between steps; requires "
                         "--backend auto")
    ap.add_argument("--retune-interval", type=int, default=10,
                    help="steps between plan refresh + hot-swap "
                         "under --online-retune")
    ap.add_argument("--plan-out", default=None,
                    help="persist the measurement-refined plan "
                         "(format v4) here at the end of the run")
    ap.add_argument("--slicing-factor", type=int, default=4)
    ap.add_argument("--allreduce-mode", default="two_phase",
                    choices=["two_phase", "faithful"])
    ap.add_argument("--microbatches", type=int, default=1,
                    help="gradient-accumulation splits; with "
                         "--pp-stages > 1 this is the pipeline "
                         "microbatch count M (bubble fraction "
                         "(S-1)/(M+S-1) under 1F1B)")
    ap.add_argument("--pp-stages", type=int, default=1,
                    help="pipeline stages: > 1 trains on a "
                         "(stage, data) mesh with the microbatch "
                         "pipeline (training.pipeline); activation/"
                         "grad handoffs ride the tuned p2p plan cells "
                         "(cxl pool write + doorbell commit vs direct "
                         "IB hop)")
    ap.add_argument("--pp-schedule", default="1f1b",
                    choices=["1f1b", "interleaved"],
                    help="pipeline schedule driving bubble accounting "
                         "and realizability validation (interleaved "
                         "needs microbatches %% stages == 0)")
    ap.add_argument("--pp-chunks", type=int, default=2,
                    help="model chunks per stage under --pp-schedule "
                         "interleaved")
    ap.add_argument("--bucket-mb", type=float, default=25.0,
                    help="grad-sync AllReduce bucket cap in MiB; any "
                         "value > 0 also row-fuses the FSDP gathers "
                         "(0 = per-leaf collectives)")
    ap.add_argument("--prefetch", type=int, default=1, choices=[0, 1],
                    help="FSDP AllGather prefetch depth "
                         "(0 = serialized gather-then-compute)")
    ap.add_argument("--fuse-kernels", action="store_true",
                    help="fuse the FSDP AllGather into the consuming "
                         "matmuls (kernels.fused_collectives); needs "
                         "the bucketed gather path (--bucket-mb > 0)")
    xla.add_argument(ap)
    ap.add_argument("--mesh", default=None,
                    help="DPxTP, e.g. 2x4; default: production mesh")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--placement", default=None,
                    help="'auto' (plan the mesh-axis -> fabric-level "
                         "assignment from the model's analytic "
                         "collective mix, tuner.placement) or a saved "
                         "placement JSON; needs an active topology "
                         "(--topology or a topology plan) and --mesh "
                         "for the DP/TP degrees.  Applies the best "
                         "assignment that keeps the TP axis unsplit")
    ap.add_argument("--placement-from-dryrun", default=None,
                    help="dry-run JSON record (launch.dryrun --backend "
                         "auto): build the placement CollectiveMix "
                         "from its recorded auto_choices audit "
                         "(CollectiveMix.from_dryrun) instead of the "
                         "analytic per-model mix; needs --placement")
    ap.add_argument("--ckpt", default=None)
    ap.add_argument("--metrics-out", default=None,
                    help="write step/retune/health events + final "
                         "metric registry as JSON-lines here (and a "
                         "Prometheus text rendering to <base>.prom)")
    ap.add_argument("--trace-out", default=None,
                    help="flight-recorder Chrome trace JSON (last "
                         "--trace-steps steps; open in Perfetto)")
    ap.add_argument("--trace-steps", type=int, default=32,
                    help="flight-recorder ring capacity in steps")
    ap.add_argument("--timing-source", default="step",
                    choices=["step", "emulator", "profiler"],
                    help="measured-time source: 'step' apportions step "
                         "wall time over the profile; 'emulator' / "
                         "'profiler' produce per-collective samples "
                         "(requires --backend auto)")
    ap.add_argument("--emu-degrade", default=None,
                    help="'key=factor,...' slowdowns for the emulator "
                         "timing source; keys are level axes ('node'), "
                         "fabric kinds ('cxl'), backend-qualified "
                         "'node@cxl', or '*'")
    ap.add_argument("--resilience", action="store_true",
                    help="run the detect -> re-plan -> resume loop "
                         "(repro.resilience): heartbeat + link-health "
                         "monitoring each step; on a confirmed rank "
                         "death or persistent cxl degrade, hot-swap a "
                         "survivor/failover re-plan and roll back to "
                         "the newest pool snapshot")
    ap.add_argument("--fault-plan", default=None,
                    help="seeded fault schedule, e.g. "
                         "'rank_death@12:rank=5;link_degrade@10-18:"
                         "link=node@cxl,factor=4;pool_error@5-7:"
                         "rate=0.5' (repro.resilience.FaultPlan)")
    ap.add_argument("--pool-ckpt-interval", type=int, default=0,
                    help="steps between pool-resident snapshots "
                         "(training.checkpoint.PoolCheckpointStore); "
                         "0 disables; the resume half of --resilience "
                         "rolls back to the newest committed snapshot")
    ap.add_argument("--ewma-decay", type=float, default=0.0,
                    help="per-refresh decay of the online tuner's "
                         "measured EWMAs (and calibration) toward the "
                         "oracle, so post-fault costs un-learn "
                         "(requires --online-retune)")
    ap.add_argument("--explore-eps", type=float, default=0.0,
                    help="epsilon-greedy re-exploration of measured "
                         "plan cells at refresh (requires "
                         "--online-retune)")
    args = ap.parse_args()
    if args.online_retune and args.backend != "auto":
        ap.error("--online-retune requires --backend auto")
    if (args.ewma_decay or args.explore_eps) and not args.online_retune:
        ap.error("--ewma-decay/--explore-eps tune the online tuner; "
                 "add --online-retune")
    if args.timing_source != "step" and args.backend != "auto":
        ap.error("--timing-source emulator/profiler needs the "
                 "--backend auto audit to key samples to plan cells")
    if args.placement_from_dryrun and not args.placement:
        ap.error("--placement-from-dryrun feeds the placement "
                 "planner; add --placement auto")
    if args.pp_stages > 1:
        for on, flag in ((args.online_retune, "--online-retune"),
                         (args.resilience, "--resilience"),
                         (args.fault_plan, "--fault-plan"),
                         (args.placement, "--placement"),
                         (args.timing_source != "step",
                          "--timing-source emulator/profiler")):
            if on:
                ap.error(f"{flag} is not supported with "
                         f"--pp-stages > 1 (plain pipeline training "
                         f"path only)")

    from repro.core.topology import (get_active_topology, parse_topology,
                                     set_active_topology, warn_uncovered)
    if args.topology:
        set_active_topology(parse_topology(args.topology))
    if args.plan:
        # one shared activation path with dryrun: fingerprint-checks the
        # plan, activates it process-wide, and activates (or warns about
        # a mismatch with) its embedded topology
        from repro.core.hw import CXL_POOL, INFINIBAND
        from repro.tuner import activate_plan_file
        activate_plan_file(args.plan, pool=CXL_POOL, ib=INFINIBAND)
    cfg = get_config(args.arch, smoke=args.smoke)
    if args.pp_stages > 1:
        ndev = jax.device_count()
        pp = args.pp_stages
        if ndev % pp:
            ap.error(f"--pp-stages {pp} does not divide "
                     f"{ndev} devices")
        dpsz = ndev // pp
        if args.batch % (dpsz * args.microbatches):
            ap.error(f"--batch {args.batch} must split over "
                     f"{dpsz} data ranks x {args.microbatches} "
                     f"microbatches")
        mesh = jax.make_mesh((pp, dpsz), ("stage", "data"))
    elif args.placement:
        from repro import tuner
        from repro.launch.mesh import make_placed_mesh
        topo = get_active_topology()
        if topo is None:
            ap.error("--placement requires an active topology "
                     "(--topology or a topology plan)")
        if not args.mesh:
            ap.error("--placement requires --mesh DPxTP for the "
                     "logical axis degrees")
        dp, tp = (int(x) for x in args.mesh.split("x"))
        if args.placement_from_dryrun:
            import json
            with open(args.placement_from_dryrun) as f:
                record = json.load(f)
            mix = tuner.CollectiveMix.from_dryrun(
                record, {"data": dp, "model": tp})
        else:
            mix = tuner.CollectiveMix.for_model(
                cfg, {"data": dp, "model": tp}, seq=args.seq,
                batch_per_rank=max(1, args.batch // max(1, dp)))
        pplan = tuner.plan_placement(mix, topo) \
            if args.placement == "auto" \
            else tuner.load_placement(args.placement)
        chosen = pplan.best_with_unsplit(("model",))
        print(tuner.format_report(pplan, chosen=chosen))
        mesh = make_placed_mesh(chosen, mix, topo)
    elif args.mesh:
        dp, tp = (int(x) for x in args.mesh.split("x"))
        mesh = jax.make_mesh((dp, tp), ("data", "model"))
    else:
        mesh = make_production_mesh(multi_pod=args.multi_pod)
    if get_active_topology() is not None:
        warn_uncovered(get_active_topology(), mesh)
    tcfg = TrainConfig(lr=args.lr, warmup=min(20, args.steps // 5),
                       total_steps=args.steps, backend=args.backend,
                       slicing_factor=args.slicing_factor,
                       allreduce_mode=args.allreduce_mode,
                       microbatches=args.microbatches, clip_norm=None,
                       # plan already activated process-wide above;
                       # backend='auto' resolves it via the registry
                       plan_path=None, bucket_mb=args.bucket_mb,
                       prefetch=args.prefetch,
                       fuse_kernels=args.fuse_kernels)
    from repro.core import ledger
    ledger.reset()
    if args.pp_stages > 1:
        from repro.training.pipeline import (bubble_fraction,
                                             make_sharded_pipeline_step)
        step, pspecs, bspecs, pc = make_sharded_pipeline_step(
            cfg, tcfg, mesh, n_microbatches=args.microbatches,
            schedule=args.pp_schedule, n_chunks=args.pp_chunks)
        tp = 1
        bub = bubble_fraction(args.pp_stages, args.microbatches,
                              args.pp_schedule, args.pp_chunks)
        print(f"pipeline: {args.pp_stages} stages x "
              f"{dict(mesh.shape)['data']} dp, "
              f"{args.microbatches} microbatches, "
              f"schedule {args.pp_schedule}, "
              f"bubble fraction {bub:.3f}")
    else:
        step, pspecs, bspecs, pc = make_sharded_train_step(
            cfg, tcfg, mesh, dp_axis=dp_axes(mesh))
        tp = mesh.shape["model"]
    params = model.init_params(jax.random.key(0), cfg, tp=tp,
                               dtype=jnp.float32)
    opt = adamw_init(params)
    data = iter(SyntheticTokens(cfg, batch=args.batch, seq=args.seq))

    online = None
    if args.online_retune:
        from repro import tuner
        base = tuner.ensure_default_plan(
            topology=get_active_topology())
        online = tuner.OnlineTuner(
            base, retune_interval=args.retune_interval,
            decay=args.ewma_decay, explore_eps=args.explore_eps)
        print(f"online re-tuning: interval {args.retune_interval} "
              f"steps, plan epoch {tuner.plan_epoch()}")

    obs_sess = None
    if args.metrics_out or args.trace_out:
        from repro.obs import ObsSession
        obs_sess = ObsSession(metrics_out=args.metrics_out,
                              trace_out=args.trace_out,
                              trace_steps=args.trace_steps)
    emu = None
    if args.timing_source == "emulator":
        from repro.obs import StepEmulator
        degrade = {}
        for part in (args.emu_degrade or "").split(","):
            if part.strip():
                k, _, v = part.partition("=")
                degrade[k.strip()] = float(v)
        emu = StepEmulator(topology=get_active_topology(),
                           noise_std=0.02, seed=0, degrade=degrade)
    prof_dir, prof_failures = None, 0
    if args.timing_source == "profiler":
        import tempfile
        prof_dir = tempfile.mkdtemp(prefix="repro-prof-")
    # profile/emulator/profiler sources all need the trace-time audit
    want_profile = (online is not None
                    or args.timing_source != "step"
                    or (obs_sess is not None
                        and args.backend == "auto"))

    fault_plan = None
    if args.fault_plan:
        from repro.resilience import FaultPlan
        fault_plan = FaultPlan.parse(args.fault_plan)
        fault_plan.install()        # pool fault hook: deaths + errors
        print(f"fault plan: {fault_plan.describe()}")
    resil = None
    if args.resilience:
        from repro.resilience import (FailureMonitor,
                                      ResilienceController)
        monitor = FailureMonitor(int(mesh.devices.size))
        resil = ResilienceController(monitor)
        print(f"resilience: monitoring {monitor.nranks} ranks "
              f"(heartbeat timeout {monitor.heartbeat_timeout}, "
              f"patience {monitor.patience})")
    pool_store = None
    if args.pool_ckpt_interval > 0:
        import numpy as np
        from repro.training.checkpoint import PoolCheckpointStore
        state_bytes = sum(
            np.asarray(l).nbytes
            for l in jax.tree.leaves({"params": params, "opt": opt}))
        # two slots, each big enough for image + header slack
        pool_store = PoolCheckpointStore(
            capacity_bytes=2 * (state_bytes + (1 << 20)) + 4096)
        print(f"pool checkpoints: every {args.pool_ckpt_interval} "
              f"steps, {pool_store.slot_bytes} B/slot")

    print(f"training {cfg.name} on mesh {dict(mesh.shape)} "
          f"backend={args.backend}")
    t0 = time.time()
    profile = None       # trace-time auto_choices of the compiled step
    for i, batch in zip(range(args.steps), data):
        if fault_plan is not None:
            for ev in fault_plan.begin_step(i, emulator=emu):
                print(f"step {i:5d} fault injected: {ev.describe()}")
        batch = {k: jnp.asarray(v) for k, v in batch.items()}
        ts = time.perf_counter()
        step_timings = None
        with (obs_sess.step_span(i) if obs_sess is not None
              else contextlib.nullcontext()):
            prof_cm = contextlib.nullcontext()
            if prof_dir is not None and profile is not None \
                    and prof_failures < 2:
                prof_cm = jax.profiler.trace(prof_dir)
            with prof_cm:
                params, opt, metrics = step(params, opt, batch)
                if want_profile or obs_sess is not None:
                    jax.block_until_ready(metrics["loss"])
            dt = time.perf_counter() - ts
            compiled_this_step = False
            if want_profile and profile is None:
                # the step traced during this call: its audit is the
                # per-step collective profile every later step reruns
                profile = ledger.snapshot()["auto_choices"]
                compiled_this_step = True
            if profile is not None and not compiled_this_step:
                if emu is not None:
                    # books each sample into the ledger, which feeds
                    # the flight recorder via the timing hook
                    step_timings = emu.step_timings(profile)
                elif prof_dir is not None:
                    from repro.obs import profiled_timings
                    step_timings = profiled_timings(prof_dir, profile,
                                                    book=True)
                    if not step_timings:
                        prof_failures += 1
                        if prof_failures == 2:
                            print("warning: no parseable profiler "
                                  "traces; falling back to step-time "
                                  "apportioning")
            if online is not None and profile is not None \
                    and not compiled_this_step:
                if step_timings:
                    # candidate-level feedback: every sample carries
                    # its own plan-cell identity + executed knobs
                    online.observe_timings(step_timings)
                else:
                    # skip the compile step's wall time; every cached
                    # step apportions its measured time over the
                    # profile
                    online.observe_step(dt, profile)
            if online is not None:
                prev = online.plan
                refreshed = online.maybe_retune(i)
                if refreshed is not None:
                    swapped = tuner.choices_changed(prev, refreshed)
                    if obs_sess is not None:
                        obs_sess.on_retune(
                            epoch=tuner.plan_epoch(), swapped=swapped,
                            regret_s=online.measured_regret(),
                            measured_cells=sum(
                                st.samples > 0
                                for st in online.stats.values()))
                    if online.calibration:
                        from repro.obs import calibration_drift
                        for d in calibration_drift(
                                online.calibration_export()):
                            print(f"step {i:5d} calibration drift: "
                                  f"{d['backend']}@{d['level']} "
                                  f"measures {d['scale']}x the oracle "
                                  f"- {d['recommendation']}")
                    if swapped:
                        # hot-swap: the registry already serves the
                        # refreshed plan (epoch bumped); re-trace the
                        # step so auto resolution picks it up at the
                        # next step boundary
                        ledger.reset()
                        profile = None
                        step, pspecs, bspecs, pc = \
                            make_sharded_train_step(
                                cfg, tcfg, mesh, dp_axis=dp_axes(mesh))
                        print(f"step {i:5d} plan hot-swap -> epoch "
                              f"{tuner.plan_epoch()} (choices changed)")
        if obs_sess is not None:
            obs_sess.on_step(i, time.perf_counter() - ts,
                             timings=step_timings)
        if pool_store is not None \
                and i % args.pool_ckpt_interval == 0:
            from repro.core.pool import PoolAccessError
            try:
                rep = pool_store.snapshot(
                    i, {"params": params, "opt": opt})
                if rep["retries"]:
                    print(f"step {i:5d} pool snapshot committed "
                          f"after {rep['retries']} retried faults")
            except PoolAccessError as e:
                # persists past the retry budget: the previous
                # committed snapshot stays restorable
                if resil is not None:
                    resil.monitor.record_pool_error(i)
                print(f"step {i:5d} pool snapshot failed: {e}")
        if resil is not None:
            rp = resil.step(i, timings=step_timings)
            if rp is not None:
                # resume: roll the survivors back to the newest
                # committed pool snapshot (warm rejoin) and re-trace
                # the step so auto resolution sees the new plan and
                # topology.  The forced-host mesh keeps its devices;
                # a true mesh shrink is exercised in
                # tests/_mesh_runner.py.
                snap = pool_store.latest() \
                    if pool_store is not None else None
                if snap is not None:
                    like = {"params": params, "opt": opt}
                    restored, _ = pool_store.restore(like)
                    params, opt = restored["params"], restored["opt"]
                    print(f"step {i:5d} resume: rolled back to pool "
                          f"snapshot step {snap} "
                          f"({i - snap} steps of rollback)")
                ledger.reset()
                profile = None
                step, pspecs, bspecs, pc = make_sharded_train_step(
                    cfg, tcfg, mesh, dp_axis=dp_axes(mesh))
                if online is not None:
                    # restart measured feedback from the recovery plan
                    from repro import tuner
                    online = tuner.OnlineTuner(
                        rp.plan, retune_interval=args.retune_interval,
                        decay=args.ewma_decay,
                        explore_eps=args.explore_eps)
        ledger.clear_timings()    # folded; keep the list O(one step)
        if i % 10 == 0 or i == args.steps - 1:
            print(f"step {i:5d} loss {float(metrics['loss']):.4f} "
                  f"({time.time() - t0:.1f}s)")
    if online is not None and args.plan_out:
        from repro.tuner import save_plan
        refined = online.refresh()
        save_plan(refined, args.plan_out)
        measured = sum(st.samples > 0 for st in online.stats.values())
        print(f"saved refined plan (v4, {len(refined.entries)} cells, "
              f"{measured} measured candidates) -> {args.plan_out}")
    if obs_sess is not None:
        obs_sess.finalize(snapshot=ledger.snapshot(),
                          extra={"steps": int(args.steps),
                                 "wall_s": time.time() - t0,
                                 "timing_source": args.timing_source})
    if prof_dir is not None:
        import shutil
        shutil.rmtree(prof_dir, ignore_errors=True)
    if fault_plan is not None:
        fault_plan.uninstall()
        print(f"faults injected: {len(fault_plan.injected)}")
    if resil is not None:
        rep = resil.report()
        print(f"resilience: {rep['replans']} re-plan(s), "
              f"dead ranks {rep['monitor']['dead_ranks']}, "
              f"degraded links {rep['monitor']['degraded_links']}")
    if args.ckpt:
        checkpoint.save(args.ckpt, args.steps, {"params": params})
        print(f"saved {args.ckpt}/step_{args.steps:08d}")


if __name__ == "__main__":
    main()
