"""Production training launcher.

On a real TPU cluster this process runs per host (jax.distributed); on
this CPU container it drives the same code over forced host devices.

Usage:
  PYTHONPATH=src python -m repro.launch.train --arch llama3.2-1b \
      --steps 100 --backend cxl [--multi-pod] [--smoke]
  XLA_FLAGS=--xla_force_host_platform_device_count=8 \
      PYTHONPATH=src python -m repro.launch.train --arch yi-6b --smoke \
      --mesh 2x4 --steps 20
  PYTHONPATH=src python -m repro.launch.train --arch llama3.2-1b \
      --smoke --backend auto --plan plan.json --online-retune \
      --retune-interval 10 --plan-out refined.json
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp

from repro.configs import ARCH_IDS, get_config
from repro.data.pipeline import SyntheticTokens
from repro.launch.mesh import dp_axes, make_production_mesh
from repro.models import model
from repro.optim import adamw_init
from repro.training import checkpoint
from repro.training.train_loop import (TrainConfig,
                                       make_sharded_train_step)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ARCH_IDS, required=True)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--backend", choices=["ring", "cxl", "auto"],
                    default="ring")
    ap.add_argument("--plan", default=None,
                    help="autotuning plan JSON (see repro.launch.tune); "
                         "used by --backend auto; a topology plan also "
                         "activates hierarchical decomposition")
    ap.add_argument("--topology", default=None,
                    help="'axis:fabric,...' spec or topology JSON file: "
                         "tuple-axis collectives decompose per level "
                         "(default: the plan's embedded topology, if "
                         "any)")
    ap.add_argument("--online-retune", action="store_true",
                    help="feed measured step times back into the plan "
                         "(per-cell EWMA, tuner.online) and hot-swap "
                         "the refreshed plan between steps; requires "
                         "--backend auto")
    ap.add_argument("--retune-interval", type=int, default=10,
                    help="steps between plan refresh + hot-swap "
                         "under --online-retune")
    ap.add_argument("--plan-out", default=None,
                    help="persist the measurement-refined plan "
                         "(format v4) here at the end of the run")
    ap.add_argument("--slicing-factor", type=int, default=4)
    ap.add_argument("--allreduce-mode", default="two_phase",
                    choices=["two_phase", "faithful"])
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--bucket-mb", type=float, default=25.0,
                    help="grad-sync AllReduce bucket cap in MiB; any "
                         "value > 0 also row-fuses the FSDP gathers "
                         "(0 = per-leaf collectives)")
    ap.add_argument("--prefetch", type=int, default=1, choices=[0, 1],
                    help="FSDP AllGather prefetch depth "
                         "(0 = serialized gather-then-compute)")
    ap.add_argument("--mesh", default=None,
                    help="DPxTP, e.g. 2x4; default: production mesh")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--placement", default=None,
                    help="'auto' (plan the mesh-axis -> fabric-level "
                         "assignment from the model's analytic "
                         "collective mix, tuner.placement) or a saved "
                         "placement JSON; needs an active topology "
                         "(--topology or a topology plan) and --mesh "
                         "for the DP/TP degrees.  Applies the best "
                         "assignment that keeps the TP axis unsplit")
    ap.add_argument("--ckpt", default=None)
    args = ap.parse_args()
    if args.online_retune and args.backend != "auto":
        ap.error("--online-retune requires --backend auto")

    from repro.core.topology import (get_active_topology, parse_topology,
                                     set_active_topology, warn_uncovered)
    if args.topology:
        set_active_topology(parse_topology(args.topology))
    if args.plan:
        # one shared activation path with dryrun: fingerprint-checks the
        # plan, activates it process-wide, and activates (or warns about
        # a mismatch with) its embedded topology
        from repro.core.hw import CXL_POOL, INFINIBAND
        from repro.tuner import activate_plan_file
        activate_plan_file(args.plan, pool=CXL_POOL, ib=INFINIBAND)
    cfg = get_config(args.arch, smoke=args.smoke)
    if args.placement:
        from repro import tuner
        from repro.launch.mesh import make_placed_mesh
        topo = get_active_topology()
        if topo is None:
            ap.error("--placement requires an active topology "
                     "(--topology or a topology plan)")
        if not args.mesh:
            ap.error("--placement requires --mesh DPxTP for the "
                     "logical axis degrees")
        dp, tp = (int(x) for x in args.mesh.split("x"))
        mix = tuner.CollectiveMix.for_model(
            cfg, {"data": dp, "model": tp}, seq=args.seq,
            batch_per_rank=max(1, args.batch // max(1, dp)))
        pplan = tuner.plan_placement(mix, topo) \
            if args.placement == "auto" \
            else tuner.load_placement(args.placement)
        chosen = pplan.best_with_unsplit(("model",))
        print(tuner.format_report(pplan, chosen=chosen))
        mesh = make_placed_mesh(chosen, mix, topo)
    elif args.mesh:
        dp, tp = (int(x) for x in args.mesh.split("x"))
        mesh = jax.make_mesh((dp, tp), ("data", "model"))
    else:
        mesh = make_production_mesh(multi_pod=args.multi_pod)
    if get_active_topology() is not None:
        warn_uncovered(get_active_topology(), mesh)
    tcfg = TrainConfig(lr=args.lr, warmup=min(20, args.steps // 5),
                       total_steps=args.steps, backend=args.backend,
                       slicing_factor=args.slicing_factor,
                       allreduce_mode=args.allreduce_mode,
                       microbatches=args.microbatches, clip_norm=None,
                       # plan already activated process-wide above;
                       # backend='auto' resolves it via the registry
                       plan_path=None, bucket_mb=args.bucket_mb,
                       prefetch=args.prefetch)
    from repro.core import ledger
    ledger.reset()
    step, pspecs, bspecs, pc = make_sharded_train_step(
        cfg, tcfg, mesh, dp_axis=dp_axes(mesh))
    tp = mesh.shape["model"]
    params = model.init_params(jax.random.key(0), cfg, tp=tp,
                               dtype=jnp.float32)
    opt = adamw_init(params)
    data = iter(SyntheticTokens(cfg, batch=args.batch, seq=args.seq))

    online = None
    if args.online_retune:
        from repro import tuner
        base = tuner.ensure_default_plan(
            topology=get_active_topology())
        online = tuner.OnlineTuner(
            base, retune_interval=args.retune_interval)
        print(f"online re-tuning: interval {args.retune_interval} "
              f"steps, plan epoch {tuner.plan_epoch()}")

    print(f"training {cfg.name} on mesh {dict(mesh.shape)} "
          f"backend={args.backend}")
    t0 = time.time()
    profile = None       # trace-time auto_choices of the compiled step
    for i, batch in zip(range(args.steps), data):
        batch = {k: jnp.asarray(v) for k, v in batch.items()}
        ts = time.perf_counter()
        params, opt, metrics = step(params, opt, batch)
        if online is not None:
            jax.block_until_ready(metrics["loss"])
            dt = time.perf_counter() - ts
            if profile is None:
                # the step traced during this call: its audit is the
                # per-step collective profile every later step reruns
                profile = ledger.snapshot()["auto_choices"]
            else:
                # skip the compile step's wall time; every cached step
                # apportions its measured time over the profile
                online.observe_step(dt, profile)
            prev = online.plan
            refreshed = online.maybe_retune(i)
            if refreshed is not None and \
                    tuner.choices_changed(prev, refreshed):
                # hot-swap: the registry already serves the refreshed
                # plan (epoch bumped); re-trace the step so auto
                # resolution picks it up at the next step boundary
                ledger.reset()
                profile = None
                step, pspecs, bspecs, pc = make_sharded_train_step(
                    cfg, tcfg, mesh, dp_axis=dp_axes(mesh))
                print(f"step {i:5d} plan hot-swap -> epoch "
                      f"{tuner.plan_epoch()} (choices changed)")
        if i % 10 == 0 or i == args.steps - 1:
            print(f"step {i:5d} loss {float(metrics['loss']):.4f} "
                  f"({time.time() - t0:.1f}s)")
    if online is not None and args.plan_out:
        from repro.tuner import save_plan
        refined = online.refresh()
        save_plan(refined, args.plan_out)
        measured = sum(st.samples > 0 for st in online.stats.values())
        print(f"saved refined plan (v4, {len(refined.entries)} cells, "
              f"{measured} measured candidates) -> {args.plan_out}")
    if args.ckpt:
        checkpoint.save(args.ckpt, args.steps, {"params": params})
        print(f"saved {args.ckpt}/step_{args.steps:08d}")


if __name__ == "__main__":
    main()
