"""Production training launcher.

On a real TPU cluster this process runs per host (jax.distributed); on
this CPU container it drives the same code over forced host devices.

Usage:
  PYTHONPATH=src python -m repro.launch.train --arch llama3.2-1b \
      --steps 100 --backend cxl [--multi-pod] [--smoke]
  XLA_FLAGS=--xla_force_host_platform_device_count=8 \
      PYTHONPATH=src python -m repro.launch.train --arch yi-6b --smoke \
      --mesh 2x4 --steps 20
  PYTHONPATH=src python -m repro.launch.train --arch llama3.2-1b \
      --smoke --backend auto --plan plan.json --online-retune \
      --retune-interval 10 --plan-out refined.json
  PYTHONPATH=src python -m repro.launch.train --arch llama3.2-1b \
      --smoke --backend auto --online-retune --timing-source emulator \
      --metrics-out run.jsonl --trace-out run.trace.json

Observability (repro.obs): ``--metrics-out`` streams step/retune/health
events as JSON-lines and dumps the final metric registry (+ a
Prometheus rendering next to it); ``--trace-out`` keeps a flight
recorder of the last ``--trace-steps`` steps and writes a Chrome trace
openable in Perfetto.  ``--timing-source`` picks where measured
per-collective times come from: ``step`` (apportion the step wall time
over the trace-time profile - the pre-obs behavior), ``emulator`` (the
device-free oracle-driven ``obs.StepEmulator``; ``--emu-degrade``
injects link slowdowns), or ``profiler`` (parse ``jax.profiler``
traces; falls back to ``step`` if the build emits none).  With
``--online-retune``, emulator/profiler sources feed the tuner
*candidate-level* measurements instead of step-time apportioning.
"""
from __future__ import annotations

import argparse
import contextlib
import time

import jax
import jax.numpy as jnp

from repro.configs import ARCH_IDS, get_config
from repro.data.pipeline import SyntheticTokens
from repro.launch.mesh import dp_axes, make_production_mesh
from repro.models import model
from repro.optim import adamw_init
from repro.training import checkpoint
from repro.training.train_loop import (TrainConfig,
                                       make_sharded_train_step)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ARCH_IDS, required=True)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--backend", choices=["ring", "cxl", "auto"],
                    default="ring")
    ap.add_argument("--plan", default=None,
                    help="autotuning plan JSON (see repro.launch.tune); "
                         "used by --backend auto; a topology plan also "
                         "activates hierarchical decomposition")
    ap.add_argument("--topology", default=None,
                    help="'axis:fabric,...' spec or topology JSON file: "
                         "tuple-axis collectives decompose per level "
                         "(default: the plan's embedded topology, if "
                         "any)")
    ap.add_argument("--online-retune", action="store_true",
                    help="feed measured step times back into the plan "
                         "(per-cell EWMA, tuner.online) and hot-swap "
                         "the refreshed plan between steps; requires "
                         "--backend auto")
    ap.add_argument("--retune-interval", type=int, default=10,
                    help="steps between plan refresh + hot-swap "
                         "under --online-retune")
    ap.add_argument("--plan-out", default=None,
                    help="persist the measurement-refined plan "
                         "(format v4) here at the end of the run")
    ap.add_argument("--slicing-factor", type=int, default=4)
    ap.add_argument("--allreduce-mode", default="two_phase",
                    choices=["two_phase", "faithful"])
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--bucket-mb", type=float, default=25.0,
                    help="grad-sync AllReduce bucket cap in MiB; any "
                         "value > 0 also row-fuses the FSDP gathers "
                         "(0 = per-leaf collectives)")
    ap.add_argument("--prefetch", type=int, default=1, choices=[0, 1],
                    help="FSDP AllGather prefetch depth "
                         "(0 = serialized gather-then-compute)")
    ap.add_argument("--mesh", default=None,
                    help="DPxTP, e.g. 2x4; default: production mesh")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--placement", default=None,
                    help="'auto' (plan the mesh-axis -> fabric-level "
                         "assignment from the model's analytic "
                         "collective mix, tuner.placement) or a saved "
                         "placement JSON; needs an active topology "
                         "(--topology or a topology plan) and --mesh "
                         "for the DP/TP degrees.  Applies the best "
                         "assignment that keeps the TP axis unsplit")
    ap.add_argument("--ckpt", default=None)
    ap.add_argument("--metrics-out", default=None,
                    help="write step/retune/health events + final "
                         "metric registry as JSON-lines here (and a "
                         "Prometheus text rendering to <base>.prom)")
    ap.add_argument("--trace-out", default=None,
                    help="flight-recorder Chrome trace JSON (last "
                         "--trace-steps steps; open in Perfetto)")
    ap.add_argument("--trace-steps", type=int, default=32,
                    help="flight-recorder ring capacity in steps")
    ap.add_argument("--timing-source", default="step",
                    choices=["step", "emulator", "profiler"],
                    help="measured-time source: 'step' apportions step "
                         "wall time over the profile; 'emulator' / "
                         "'profiler' produce per-collective samples "
                         "(requires --backend auto)")
    ap.add_argument("--emu-degrade", default=None,
                    help="'key=factor,...' slowdowns for the emulator "
                         "timing source; keys are level axes ('node'), "
                         "fabric kinds ('cxl'), or '*'")
    args = ap.parse_args()
    if args.online_retune and args.backend != "auto":
        ap.error("--online-retune requires --backend auto")
    if args.timing_source != "step" and args.backend != "auto":
        ap.error("--timing-source emulator/profiler needs the "
                 "--backend auto audit to key samples to plan cells")

    from repro.core.topology import (get_active_topology, parse_topology,
                                     set_active_topology, warn_uncovered)
    if args.topology:
        set_active_topology(parse_topology(args.topology))
    if args.plan:
        # one shared activation path with dryrun: fingerprint-checks the
        # plan, activates it process-wide, and activates (or warns about
        # a mismatch with) its embedded topology
        from repro.core.hw import CXL_POOL, INFINIBAND
        from repro.tuner import activate_plan_file
        activate_plan_file(args.plan, pool=CXL_POOL, ib=INFINIBAND)
    cfg = get_config(args.arch, smoke=args.smoke)
    if args.placement:
        from repro import tuner
        from repro.launch.mesh import make_placed_mesh
        topo = get_active_topology()
        if topo is None:
            ap.error("--placement requires an active topology "
                     "(--topology or a topology plan)")
        if not args.mesh:
            ap.error("--placement requires --mesh DPxTP for the "
                     "logical axis degrees")
        dp, tp = (int(x) for x in args.mesh.split("x"))
        mix = tuner.CollectiveMix.for_model(
            cfg, {"data": dp, "model": tp}, seq=args.seq,
            batch_per_rank=max(1, args.batch // max(1, dp)))
        pplan = tuner.plan_placement(mix, topo) \
            if args.placement == "auto" \
            else tuner.load_placement(args.placement)
        chosen = pplan.best_with_unsplit(("model",))
        print(tuner.format_report(pplan, chosen=chosen))
        mesh = make_placed_mesh(chosen, mix, topo)
    elif args.mesh:
        dp, tp = (int(x) for x in args.mesh.split("x"))
        mesh = jax.make_mesh((dp, tp), ("data", "model"))
    else:
        mesh = make_production_mesh(multi_pod=args.multi_pod)
    if get_active_topology() is not None:
        warn_uncovered(get_active_topology(), mesh)
    tcfg = TrainConfig(lr=args.lr, warmup=min(20, args.steps // 5),
                       total_steps=args.steps, backend=args.backend,
                       slicing_factor=args.slicing_factor,
                       allreduce_mode=args.allreduce_mode,
                       microbatches=args.microbatches, clip_norm=None,
                       # plan already activated process-wide above;
                       # backend='auto' resolves it via the registry
                       plan_path=None, bucket_mb=args.bucket_mb,
                       prefetch=args.prefetch)
    from repro.core import ledger
    ledger.reset()
    step, pspecs, bspecs, pc = make_sharded_train_step(
        cfg, tcfg, mesh, dp_axis=dp_axes(mesh))
    tp = mesh.shape["model"]
    params = model.init_params(jax.random.key(0), cfg, tp=tp,
                               dtype=jnp.float32)
    opt = adamw_init(params)
    data = iter(SyntheticTokens(cfg, batch=args.batch, seq=args.seq))

    online = None
    if args.online_retune:
        from repro import tuner
        base = tuner.ensure_default_plan(
            topology=get_active_topology())
        online = tuner.OnlineTuner(
            base, retune_interval=args.retune_interval)
        print(f"online re-tuning: interval {args.retune_interval} "
              f"steps, plan epoch {tuner.plan_epoch()}")

    obs_sess = None
    if args.metrics_out or args.trace_out:
        from repro.obs import ObsSession
        obs_sess = ObsSession(metrics_out=args.metrics_out,
                              trace_out=args.trace_out,
                              trace_steps=args.trace_steps)
    emu = None
    if args.timing_source == "emulator":
        from repro.obs import StepEmulator
        degrade = {}
        for part in (args.emu_degrade or "").split(","):
            if part.strip():
                k, _, v = part.partition("=")
                degrade[k.strip()] = float(v)
        emu = StepEmulator(topology=get_active_topology(),
                           noise_std=0.02, seed=0, degrade=degrade)
    prof_dir, prof_failures = None, 0
    if args.timing_source == "profiler":
        import tempfile
        prof_dir = tempfile.mkdtemp(prefix="repro-prof-")
    # profile/emulator/profiler sources all need the trace-time audit
    want_profile = (online is not None
                    or args.timing_source != "step"
                    or (obs_sess is not None
                        and args.backend == "auto"))

    print(f"training {cfg.name} on mesh {dict(mesh.shape)} "
          f"backend={args.backend}")
    t0 = time.time()
    profile = None       # trace-time auto_choices of the compiled step
    for i, batch in zip(range(args.steps), data):
        batch = {k: jnp.asarray(v) for k, v in batch.items()}
        ts = time.perf_counter()
        step_timings = None
        with (obs_sess.step_span(i) if obs_sess is not None
              else contextlib.nullcontext()):
            prof_cm = contextlib.nullcontext()
            if prof_dir is not None and profile is not None \
                    and prof_failures < 2:
                prof_cm = jax.profiler.trace(prof_dir)
            with prof_cm:
                params, opt, metrics = step(params, opt, batch)
                if want_profile or obs_sess is not None:
                    jax.block_until_ready(metrics["loss"])
            dt = time.perf_counter() - ts
            compiled_this_step = False
            if want_profile and profile is None:
                # the step traced during this call: its audit is the
                # per-step collective profile every later step reruns
                profile = ledger.snapshot()["auto_choices"]
                compiled_this_step = True
            if profile is not None and not compiled_this_step:
                if emu is not None:
                    # books each sample into the ledger, which feeds
                    # the flight recorder via the timing hook
                    step_timings = emu.step_timings(profile)
                elif prof_dir is not None:
                    from repro.obs import profiled_timings
                    step_timings = profiled_timings(prof_dir, profile,
                                                    book=True)
                    if not step_timings:
                        prof_failures += 1
                        if prof_failures == 2:
                            print("warning: no parseable profiler "
                                  "traces; falling back to step-time "
                                  "apportioning")
            if online is not None and profile is not None \
                    and not compiled_this_step:
                if step_timings:
                    # candidate-level feedback: every sample carries
                    # its own plan-cell identity + executed knobs
                    online.observe_timings(step_timings)
                else:
                    # skip the compile step's wall time; every cached
                    # step apportions its measured time over the
                    # profile
                    online.observe_step(dt, profile)
            if online is not None:
                prev = online.plan
                refreshed = online.maybe_retune(i)
                if refreshed is not None:
                    swapped = tuner.choices_changed(prev, refreshed)
                    if obs_sess is not None:
                        obs_sess.on_retune(
                            epoch=tuner.plan_epoch(), swapped=swapped,
                            regret_s=online.measured_regret(),
                            measured_cells=sum(
                                st.samples > 0
                                for st in online.stats.values()))
                    if online.calibration:
                        from repro.obs import calibration_drift
                        for d in calibration_drift(
                                online.calibration_export()):
                            print(f"step {i:5d} calibration drift: "
                                  f"{d['backend']}@{d['level']} "
                                  f"measures {d['scale']}x the oracle "
                                  f"- {d['recommendation']}")
                    if swapped:
                        # hot-swap: the registry already serves the
                        # refreshed plan (epoch bumped); re-trace the
                        # step so auto resolution picks it up at the
                        # next step boundary
                        ledger.reset()
                        profile = None
                        step, pspecs, bspecs, pc = \
                            make_sharded_train_step(
                                cfg, tcfg, mesh, dp_axis=dp_axes(mesh))
                        print(f"step {i:5d} plan hot-swap -> epoch "
                              f"{tuner.plan_epoch()} (choices changed)")
        if obs_sess is not None:
            obs_sess.on_step(i, time.perf_counter() - ts,
                             timings=step_timings)
        ledger.clear_timings()    # folded; keep the list O(one step)
        if i % 10 == 0 or i == args.steps - 1:
            print(f"step {i:5d} loss {float(metrics['loss']):.4f} "
                  f"({time.time() - t0:.1f}s)")
    if online is not None and args.plan_out:
        from repro.tuner import save_plan
        refined = online.refresh()
        save_plan(refined, args.plan_out)
        measured = sum(st.samples > 0 for st in online.stats.values())
        print(f"saved refined plan (v4, {len(refined.entries)} cells, "
              f"{measured} measured candidates) -> {args.plan_out}")
    if obs_sess is not None:
        obs_sess.finalize(snapshot=ledger.snapshot(),
                          extra={"steps": int(args.steps),
                                 "wall_s": time.time() - t0,
                                 "timing_source": args.timing_source})
    if prof_dir is not None:
        import shutil
        shutil.rmtree(prof_dir, ignore_errors=True)
    if args.ckpt:
        checkpoint.save(args.ckpt, args.steps, {"params": params})
        print(f"saved {args.ckpt}/step_{args.steps:08d}")


if __name__ == "__main__":
    main()
