"""Step-time breakdown report from an observability metrics stream.

Reads the JSON-lines file written by ``--metrics-out`` (train / serve /
dryrun, see ``repro.obs.session``) and prints a human summary: step
wall-time statistics, where measured collective time went (by
primitive/backend and by (level, fabric) link), retune/hot-swap
activity, and any link-health transitions.  Optionally cross-checks a
flight-recorder trace (``--trace``) for its retained steps and
anomalies.

Usage:
  PYTHONPATH=src python -m repro.launch.report run.jsonl \
      [--trace run.trace.json] [--top 8]
"""
from __future__ import annotations

import argparse
import json


def load_events(path: str) -> list:
    events = []
    with open(path) as f:
        for line in f:
            line = line.strip()
            if line:
                events.append(json.loads(line))
    return events


def _pct(sorted_vals: list, q: float) -> float:
    if not sorted_vals:
        return 0.0
    i = min(len(sorted_vals) - 1, int(q * (len(sorted_vals) - 1)))
    return sorted_vals[i]


def summarize(events: list, top: int = 8) -> str:
    steps = [e for e in events if e.get("kind") == "step"]
    retunes = [e for e in events if e.get("kind") == "retune"]
    health = [e for e in events if e.get("kind") == "health"]
    diags = [e for e in events if e.get("kind") == "diag"]
    metrics = [e for e in events if e.get("kind") == "metric"]
    summary = next((e for e in events if e.get("kind") == "summary"),
                   {})
    out = []
    walls = sorted(e["wall_s"] for e in steps)
    if steps:
        # the first step usually includes compilation; report it apart
        cached = sorted(e["wall_s"] for e in steps[1:]) or walls
        out.append(f"steps: {len(steps)}  "
                   f"wall p50 {_pct(cached, 0.5):.4f}s  "
                   f"p90 {_pct(cached, 0.9):.4f}s  "
                   f"max {cached[-1]:.4f}s  "
                   f"(first step {steps[0]['wall_s']:.2f}s, "
                   f"incl. compile)")
        samples = sum(e.get("timing_samples", 0) for e in steps)
        out.append(f"measured collective samples: {samples}")

    # measured collective seconds by (primitive, backend, level), from
    # the histogram _sum samples of the final registry dump
    coll = [(m["labels"], m["value"]) for m in metrics
            if m["name"] == "repro_collective_seconds_sum"]
    if coll:
        coll.sort(key=lambda kv: -kv[1])
        total = sum(v for _, v in coll) or 1.0
        out.append("collective time by cell "
                   "(primitive@backend [level]):")
        for lab, v in coll[:top]:
            out.append(f"  {lab.get('primitive')}@{lab.get('backend')}"
                       f" [{lab.get('level')}]  {v:.6f}s "
                       f"({100.0 * v / total:.1f}%)")
        if len(coll) > top:
            out.append(f"  ... {len(coll) - top} more cells")

    busy = [(m["labels"], m["value"]) for m in metrics
            if m["name"] == "repro_level_busy_seconds_total"]
    if busy:
        out.append("busy seconds by link (level/fabric):")
        for lab, v in sorted(busy, key=lambda kv: -kv[1]):
            out.append(f"  {lab.get('level')}/{lab.get('fabric')}  "
                       f"{v:.6f}s")

    if retunes:
        swaps = sum(1 for e in retunes if e.get("swapped"))
        last = retunes[-1]
        out.append(f"retunes: {len(retunes)} boundaries, {swaps} hot "
                   f"swaps, final epoch {last.get('epoch')}, "
                   f"measured regret "
                   f"{last.get('regret_s', 0.0):.6f}s")
    for e in health:
        out.append(f"health: link {e.get('link')} {e.get('event')} at "
                   f"step {e.get('step')} "
                   f"(slowdown {e.get('slowdown')}x)")
    if diags:
        out.append(f"diagnostics: {len(diags)}")
        for e in diags[:top]:
            out.append(f"  [{e.get('source')}] {e.get('msg')}")
        if len(diags) > top:
            out.append(f"  ... {len(diags) - top} more")
    degraded = summary.get("degraded_links")
    out.append(f"degraded links at exit: {degraded or 'none'}")

    # serving-trace summary (serve --trace poisson writes these into
    # the final summary event and exports repro_serve_* gauges)
    if "req_per_s" in summary:
        out.append(f"serving: {summary['req_per_s']:.2f} req/s over "
                   f"{summary.get('requests')} requests  "
                   f"latency p50 "
                   f"{summary.get('latency_p50_s', 0.0):.3f}s  "
                   f"p99 {summary.get('latency_p99_s', 0.0):.3f}s")
    serve = sorted((m["name"], m["value"]) for m in metrics
                   if m["name"].startswith("repro_serve_"))
    if serve:
        out.append("serving counters at exit:")
        for name, v in serve:
            out.append(f"  {name[len('repro_serve_'):]}  {v:g}")

    wire = {tuple(sorted(m["labels"].items())): m["value"]
            for m in metrics if m["name"] == "repro_wire_bytes"}
    if wire:
        total = sum(wire.values())
        out.append(f"trace-time wire bytes/step: {total:.3e} "
                   f"({len(wire)} collective kinds)")
    return "\n".join(out)


def summarize_trace(path: str) -> str:
    with open(path) as f:
        doc = json.load(f)
    meta = doc.get("metadata", {})
    n_coll = sum(1 for e in doc.get("traceEvents", [])
                 if e.get("cat") == "collective")
    lines = [f"flight recorder: steps retained "
             f"{meta.get('steps_retained')}, {n_coll} collective "
             f"slices"]
    for a in meta.get("anomalies", []):
        lines.append(f"  anomaly @ {a['ts']:.3f}s: {a['reason']}")
    return "\n".join(lines)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("metrics", help="JSON-lines file from --metrics-out")
    ap.add_argument("--trace", default=None,
                    help="flight-recorder JSON from --trace-out")
    ap.add_argument("--top", type=int, default=8,
                    help="cells to list in the collective breakdown")
    args = ap.parse_args()
    print(summarize(load_events(args.metrics), top=args.top))
    if args.trace:
        print(summarize_trace(args.trace))


if __name__ == "__main__":
    main()
