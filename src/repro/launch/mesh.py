"""Production mesh builders.

Defined as FUNCTIONS (not module constants) so importing this module
never touches jax device state.  The production target is TPU v5e:
one pod = 16x16 = 256 chips, multi-pod = 2 x 256 = 512.
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh(tp: int = 1, dp: int = 1):
    """Small mesh for local/CI runs on forced host devices."""
    return jax.make_mesh((dp, tp), ("data", "model"))


def dp_axes(mesh) -> tuple:
    """The data-parallel axis spec for a mesh (hierarchical when the pod
    axis exists)."""
    return ("pod", "data") if "pod" in mesh.axis_names else ("data",)
