"""Production mesh builders.

Defined as FUNCTIONS (not module constants) so importing this module
never touches jax device state.  The production target is TPU v5e:
one pod = 16x16 = 256 chips, multi-pod = 2 x 256 = 512.

With ``--placement auto`` the launchers instead derive the mesh from a
``tuner.placement.Placement``: one mesh axis per assigned level run
(ordered outermost level first), logical names for single-level axes,
level names + a ``models.sharding`` axis alias for split axes
(``make_placed_mesh``).
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh(tp: int = 1, dp: int = 1):
    """Small mesh for local/CI runs on forced host devices."""
    return jax.make_mesh((dp, tp), ("data", "model"))


def make_placed_mesh(placement, mix, topology):
    """Build the mesh a placement chose and activate everything it
    needs: the axis aliases for split logical axes
    (``sharding.set_axis_aliases``) and the placed (relabeled)
    topology as the process-wide active one.  Returns the mesh."""
    from repro.core.topology import set_active_topology
    from repro.models import sharding
    from repro.tuner.placement import mesh_spec, placed_topology
    shape, names, aliases = mesh_spec(placement, mix, topology)
    mesh = jax.make_mesh(shape, names)
    sharding.set_axis_aliases(aliases)
    set_active_topology(placed_topology(placement, topology))
    return mesh


def dp_axes(mesh) -> tuple:
    """The data-parallel axis spec for a mesh: the placement alias for
    ``data`` when one is installed, else hierarchical ``(pod, data)``
    when the pod axis exists."""
    from repro.models import sharding
    data = sharding.resolve_axis("data")
    if isinstance(data, tuple):
        return data
    return ("pod", "data") if "pod" in mesh.axis_names else (data,)
