"""Serving launcher: batched generation against any zoo architecture.

Usage:
  PYTHONPATH=src python -m repro.launch.serve --arch llama3.2-1b --smoke \
      --batch 4 --new-tokens 16 [--window 64]
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import ARCH_IDS, get_config
from repro.models import model
from repro.serving import ServeConfig, ServeEngine


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ARCH_IDS, required=True)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--new-tokens", type=int, default=16)
    ap.add_argument("--temperature", type=float, default=0.0)
    ap.add_argument("--window", type=int, default=None)
    ap.add_argument("--plan", default=None,
                    help="autotuning plan JSON (repro.launch.tune); "
                         "switches the engine's Communicator to "
                         "backend='auto' (takes effect when serving "
                         "sharded, i.e. with a tp>1 ParallelContext)")
    ap.add_argument("--ckpt", default=None)
    ap.add_argument("--ckpt-step", type=int, default=None)
    args = ap.parse_args()

    cfg = get_config(args.arch, smoke=args.smoke)
    params = model.init_params(jax.random.key(0), cfg, tp=1,
                               dtype=jnp.float32)
    if args.ckpt:
        from repro.training import checkpoint
        step = args.ckpt_step or checkpoint.latest_step(args.ckpt)
        params = checkpoint.restore(args.ckpt, step,
                                    {"params": params})["params"]
        print(f"restored {args.ckpt} step {step}")
    eng = ServeEngine(cfg, params, ServeConfig(
        max_seq=args.prompt_len + args.new_tokens + 8,
        window=args.window, temperature=args.temperature,
        plan_path=args.plan))
    rng = np.random.default_rng(0)
    batch = {"tokens": jnp.asarray(rng.integers(
        0, cfg.vocab_size, (args.batch, args.prompt_len)))}
    if cfg.frontend == "vision_stub" and cfg.encoder is None:
        batch["frontend"] = jnp.asarray(rng.standard_normal(
            (args.batch, cfg.frontend_tokens, cfg.frontend_dim)),
            jnp.float32)
    if cfg.encoder is not None:
        batch["source"] = jnp.asarray(rng.standard_normal(
            (args.batch, cfg.encoder.source_len, cfg.frontend_dim)),
            jnp.float32)
    t0 = time.time()
    out = eng.generate(batch, max_new_tokens=args.new_tokens)
    dt = time.time() - t0
    print(f"{cfg.name}: {out.shape} in {dt:.2f}s "
          f"({args.batch * args.new_tokens / dt:.1f} tok/s)")
    print(out[: min(2, args.batch)].tolist())


if __name__ == "__main__":
    main()
