"""Serving launcher: request-level generation against any zoo arch.

Two modes:

* default - the pre-PR-9 fixed-batch path (one ``generate`` call per
  round, reported as tok/s); still the --online-retune vehicle.
* ``--trace poisson`` - an open-loop request trace: ``--requests``
  arrivals drawn from a Poisson process (``--arrival-rate`` requests
  per decode step) are submitted against the continuous-batching
  engine and reported as req/s + latency percentiles.
  ``--prompt-reuse`` draws that fraction of prompts from a shared
  prefix, exercising the CXL-pooled prefix cache (prefix sharing is
  auto-enabled when reuse > 0).

Usage:
  PYTHONPATH=src python -m repro.launch.serve --arch llama3.2-1b --smoke \
      --batch 4 --new-tokens 16 [--window 64]
  PYTHONPATH=src python -m repro.launch.serve --arch llama3.2-1b --smoke \
      --trace poisson --requests 24 --arrival-rate 0.5 \
      --prompt-reuse 0.6 --decode-slots 4
"""
from __future__ import annotations

import argparse
import time

from repro.launch import xla
xla.apply_overlap_preset()   # --xla-overlap: must precede the jax import

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import ARCH_IDS, get_config
from repro.models import model
from repro.serving import (Request, SamplingParams, ServeConfig,
                           ServeEngine)


def _pct(sorted_vals: list, q: float) -> float:
    if not sorted_vals:
        return 0.0
    i = min(len(sorted_vals) - 1, int(q * (len(sorted_vals) - 1)))
    return sorted_vals[i]


def run_trace(eng: ServeEngine, cfg, args, obs_sess) -> None:
    """Open-loop Poisson request trace against the live engine."""
    rng = np.random.default_rng(args.seed)
    bt = args.kv_block_tokens
    prefix_len = args.prefix_len
    if prefix_len is None:
        # longest block-aligned prefix that still leaves a suffix
        prefix_len = max(bt, (args.prompt_len - 1) // bt * bt)
    prefix_len = min(prefix_len, args.prompt_len - 1)
    shared = rng.integers(0, cfg.vocab_size, prefix_len)
    arrivals = np.cumsum(rng.exponential(
        1.0 / args.arrival_rate, args.requests))   # in decode steps
    reqs = []
    for i in range(args.requests):
        if rng.random() < args.prompt_reuse:
            toks = np.concatenate([shared, rng.integers(
                0, cfg.vocab_size, args.prompt_len - prefix_len)])
        else:
            toks = rng.integers(0, cfg.vocab_size, args.prompt_len)
        reqs.append(Request(
            id=f"req{i}", tokens=toks,
            sampling=SamplingParams(temperature=args.temperature,
                                    seed=args.seed + i),
            max_new_tokens=args.new_tokens))
    t0 = time.time()
    born, done = {}, {}
    step, nxt = 0, 0
    while nxt < len(reqs) or not eng.sched.idle:
        if (eng.sched.idle and nxt < len(reqs)
                and arrivals[nxt] > step):
            step = int(np.ceil(arrivals[nxt]))   # skip the idle gap
        while nxt < len(reqs) and arrivals[nxt] <= step:
            eng.submit(reqs[nxt])
            born[reqs[nxt].id] = time.time()
            nxt += 1
        ts = time.time()
        eng.step()
        dt = time.time() - ts
        step += 1
        for rid, (status, _fresh) in eng.poll().items():
            if status == "finished" and rid not in done:
                done[rid] = time.time()
        if obs_sess is not None:
            obs_sess.on_step(step, dt, extra={
                "inflight": eng.sched.inflight})
    wall = time.time() - t0
    lats = sorted(done[r] - born[r] for r in done)
    toks = len(done) * args.new_tokens
    c = eng.counters
    print(f"{cfg.name}: trace poisson  {len(done)} requests in "
          f"{wall:.2f}s ({len(done) / wall:.2f} req/s, "
          f"{toks / wall:.1f} tok/s)")
    print(f"  latency p50 {_pct(lats, 0.5):.3f}s  "
          f"p99 {_pct(lats, 0.99):.3f}s  "
          f"decode steps {c['decode_steps']}  "
          f"prefills {c['prefills']}")
    print(f"  prefix hits {c['prefix_hits']} "
          f"({c['prefix_hit_tokens']} tokens pooled)  "
          f"evictions {c['evictions']}  restores {c['restores']}  "
          f"replays {c['replays']}  "
          f"preemptions {eng.sched.preemption_count}")
    if obs_sess is not None:
        from repro.core import ledger as _ledger
        obs_sess.finalize(snapshot=_ledger.snapshot(), extra={
            "requests": len(done), "wall_s": wall,
            "req_per_s": len(done) / wall,
            "latency_p50_s": _pct(lats, 0.5),
            "latency_p99_s": _pct(lats, 0.99), **eng.stats()})


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ARCH_IDS, required=True)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--new-tokens", type=int, default=16)
    ap.add_argument("--temperature", type=float, default=0.0)
    ap.add_argument("--window", type=int, default=None)
    ap.add_argument("--trace", choices=["poisson"], default=None,
                    help="request-trace mode: submit --requests "
                         "Poisson arrivals through submit/step/poll "
                         "and report req/s + latency percentiles "
                         "instead of the fixed-batch rounds")
    ap.add_argument("--requests", type=int, default=16,
                    help="trace mode: number of requests")
    ap.add_argument("--arrival-rate", type=float, default=0.5,
                    help="trace mode: mean arrivals per decode step")
    ap.add_argument("--prompt-reuse", type=float, default=0.0,
                    help="trace mode: fraction of prompts sharing a "
                         "common prefix (> 0 auto-enables "
                         "--prefix-sharing)")
    ap.add_argument("--prefix-len", type=int, default=None,
                    help="shared-prefix tokens for --prompt-reuse "
                         "(default: longest block-aligned prefix)")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--decode-slots", type=int, default=4,
                    help="dense decode lanes (engine batch)")
    ap.add_argument("--kv-block-tokens", type=int, default=16,
                    help="tokens per paged HBM KV block")
    ap.add_argument("--hbm-budget-blocks", type=int, default=None,
                    help="HBM KV block budget (default: enough for "
                         "every slot at max_seq; lower it to force "
                         "eviction to the pool)")
    ap.add_argument("--pool-budget-mib", type=int, default=64,
                    help="CXL pool budget for evictions + pooled "
                         "prefixes (MiB)")
    ap.add_argument("--scheduler", choices=["continuous", "static"],
                    default="continuous",
                    help="'static' is the batch-synchronous baseline "
                         "(admits only when the engine drained)")
    ap.add_argument("--kv-placement",
                    choices=["auto", "pool", "recompute"],
                    default="auto",
                    help="eviction placement: 'auto' prices the pool "
                         "round-trip vs recompute (kv_block plan "
                         "cell / live oracle)")
    ap.add_argument("--prefix-sharing", action="store_true",
                    help="publish complete prompt blocks to the "
                         "pooled prefix store and restore them for "
                         "later matching prompts")
    ap.add_argument("--plan", default=None,
                    help="autotuning plan JSON (repro.launch.tune); "
                         "switches the engine's Communicator to "
                         "backend='auto' (takes effect when serving "
                         "sharded, i.e. with a tp>1 ParallelContext)")
    ap.add_argument("--online-retune", action="store_true",
                    help="treat every generate round as a step: fold "
                         "its measured wall time back into the plan "
                         "and hot-swap at --retune-interval round "
                         "boundaries; requires --plan (and, like "
                         "--plan itself, only folds measurements when "
                         "serving sharded: an unsharded tp=1 engine "
                         "issues no collectives to measure)")
    ap.add_argument("--retune-interval", type=int, default=4,
                    help="generate rounds between plan refresh + "
                         "hot-swap under --online-retune")
    ap.add_argument("--rounds", type=int, default=None,
                    help="number of generate rounds (default 1; "
                         "2 x retune-interval under --online-retune)")
    ap.add_argument("--plan-out", default=None,
                    help="persist the measurement-refined plan "
                         "(format v4) here at the end of the run")
    ap.add_argument("--topology", default=None,
                    help="'axis:fabric[:shape],...' spec or topology "
                         "JSON file to activate for this process")
    ap.add_argument("--placement", default=None,
                    help="'auto' or a saved placement JSON: rank the "
                         "mesh-axis -> fabric-level assignments for "
                         "this arch (tuner.placement), print the "
                         "report, and activate the placed topology + "
                         "axis aliases (takes effect when serving "
                         "sharded); needs a topology")
    ap.add_argument("--placement-axes", default="data=2,model=4",
                    help="logical axis degrees for --placement, "
                         "'name=size,...'")
    ap.add_argument("--ckpt", default=None)
    ap.add_argument("--ckpt-step", type=int, default=None)
    ap.add_argument("--metrics-out", default=None,
                    help="write per-round/retune events + the final "
                         "metric registry as JSON-lines here (and a "
                         "Prometheus rendering to <base>.prom); see "
                         "repro.obs")
    xla.add_argument(ap)
    args = ap.parse_args()
    if args.online_retune and not args.plan:
        ap.error("--online-retune requires --plan")
    if args.trace and args.online_retune:
        ap.error("--trace and --online-retune are mutually exclusive "
                 "(retune is driven by fixed-batch rounds)")

    cfg = get_config(args.arch, smoke=args.smoke)
    if args.topology:
        from repro.core.topology import (parse_topology,
                                         set_active_topology)
        set_active_topology(parse_topology(args.topology))
    if args.placement:
        from repro import tuner
        from repro.core.topology import (get_active_topology,
                                         set_active_topology)
        from repro.models import sharding
        topo = get_active_topology()
        if topo is None:
            ap.error("--placement requires --topology")
        axes = {k: int(v) for k, v in
                (p.split("=") for p in args.placement_axes.split(","))}
        mix = tuner.CollectiveMix.for_model(cfg, axes,
                                            seq=args.prompt_len
                                            + args.new_tokens)
        pplan = tuner.plan_placement(mix, topo) \
            if args.placement == "auto" \
            else tuner.load_placement(args.placement)
        chosen = pplan.best_with_unsplit(("model",))
        print(tuner.format_report(pplan, chosen=chosen))
        _, _, aliases = tuner.mesh_spec(chosen, mix, topo)
        sharding.set_axis_aliases(aliases)
        set_active_topology(tuner.placed_topology(chosen, topo))
    params = model.init_params(jax.random.key(0), cfg, tp=1,
                               dtype=jnp.float32)
    if args.ckpt:
        from repro.training import checkpoint
        step = args.ckpt_step or checkpoint.latest_step(args.ckpt)
        params = checkpoint.restore(args.ckpt, step,
                                    {"params": params})["params"]
        print(f"restored {args.ckpt} step {step}")
    obs_sess = None
    if args.metrics_out:
        from repro.obs import ObsSession
        obs_sess = ObsSession(metrics_out=args.metrics_out)
    scfg = ServeConfig(
        max_seq=args.prompt_len + args.new_tokens + 8,
        window=args.window, temperature=args.temperature,
        plan_path=args.plan, decode_slots=args.decode_slots,
        kv_block_tokens=args.kv_block_tokens,
        hbm_budget_blocks=args.hbm_budget_blocks,
        pool_budget_bytes=args.pool_budget_mib << 20,
        scheduler=args.scheduler, kv_placement=args.kv_placement,
        prefix_sharing=(args.prefix_sharing
                        or args.prompt_reuse > 0.0))
    eng = ServeEngine(cfg, params, scfg, obs=obs_sess)
    if args.trace:
        run_trace(eng, cfg, args, obs_sess)
        return
    rng = np.random.default_rng(0)
    batch = {"tokens": jnp.asarray(rng.integers(
        0, cfg.vocab_size, (args.batch, args.prompt_len)))}
    if cfg.frontend == "vision_stub" and cfg.encoder is None:
        batch["frontend"] = jnp.asarray(rng.standard_normal(
            (args.batch, cfg.frontend_tokens, cfg.frontend_dim)),
            jnp.float32)
    if cfg.encoder is not None:
        batch["source"] = jnp.asarray(rng.standard_normal(
            (args.batch, cfg.encoder.source_len, cfg.frontend_dim)),
            jnp.float32)
    online = None
    if args.online_retune:
        import dataclasses as _dc

        from repro import tuner
        from repro.core import ledger
        from repro.core.hw import CXL_POOL, INFINIBAND
        online = tuner.OnlineTuner(
            tuner.load_plan(args.plan, pool=CXL_POOL, ib=INFINIBAND),
            retune_interval=args.retune_interval)
        # the refreshed plan lives in a file so rebuilt engines load it
        live_path = args.plan_out or (args.plan + ".refined.json")
    rounds = args.rounds if args.rounds is not None else (
        2 * args.retune_interval if args.online_retune else 1)
    out = None
    if online is not None:
        ledger.reset()
    profile = None   # trace-time auto_choices of the compiled engine
    for r in range(rounds):
        t0 = time.time()
        out = eng.generate(batch, max_new_tokens=args.new_tokens)
        dt = time.time() - t0
        print(f"{cfg.name}: {out.shape} in {dt:.2f}s "
              f"({args.batch * args.new_tokens / dt:.1f} tok/s)")
        if obs_sess is not None:
            obs_sess.on_step(r, dt, extra={
                "tok_per_s": args.batch * args.new_tokens / dt})
        if online is None:
            continue
        if profile is None:
            # the engine traced during this round: its audit is the
            # per-round collective profile cached rounds rerun (the
            # round's wall time includes compilation, so skip it)
            profile = ledger.snapshot()["auto_choices"]
            if not profile:
                msg = ("--online-retune: the engine issued no auto "
                       "collectives (unsharded tp=1 engines have "
                       "nothing to measure) - rounds will run but "
                       "the plan cannot change")
                if obs_sess is not None:
                    obs_sess.diag("serve", msg)
                else:
                    print(f"[serve] {msg}")
        else:
            online.observe_step(dt, profile)
        prev = online.plan
        refreshed = online.maybe_retune(r)
        if refreshed is not None:
            tuner.save_plan(refreshed, live_path)
            if obs_sess is not None:
                obs_sess.on_retune(
                    epoch=tuner.plan_epoch(),
                    swapped=tuner.choices_changed(prev, refreshed),
                    regret_s=online.measured_regret())
            if tuner.choices_changed(prev, refreshed):
                # hot-swap between rounds: rebuild the engine against
                # the refreshed plan (its jitted prefill/decode must
                # re-trace to pick up the new resolution)
                eng = ServeEngine(cfg, params, _dc.replace(
                    scfg, plan_path=live_path), obs=obs_sess)
                ledger.reset()
                profile = None
                print(f"round {r}: plan hot-swap -> {live_path}")
    if online is not None and args.plan_out:
        refined = online.refresh()
        from repro.tuner import save_plan
        save_plan(refined, args.plan_out)
        print(f"saved refined plan (v4) -> {args.plan_out}")
    if obs_sess is not None:
        from repro.core import ledger as _ledger
        obs_sess.finalize(snapshot=_ledger.snapshot(),
                          extra={"rounds": rounds})
    print(out[: min(2, args.batch)].tolist())


if __name__ == "__main__":
    main()
