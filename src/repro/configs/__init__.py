"""Architecture registry: the 10 assigned configs + the paper's case-study
model, selectable via ``--arch <id>``.

Each ``src/repro/configs/<id>.py`` module exports ``CONFIG`` (the exact
published dimensions, cited) and ``SMOKE`` (a reduced same-family variant:
<=2-4 layers, d_model <= 512, <= 4 experts) used by the per-arch CPU smoke
tests.  Full configs are exercised only through the dry-run
(ShapeDtypeStruct, no allocation).
"""
from __future__ import annotations

import importlib

from repro.models.config import ModelConfig

_MODULES = {
    "zamba2-1.2b": "zamba2_1p2b",
    "phi-3-vision-4.2b": "phi3_vision_4p2b",
    "arctic-480b": "arctic_480b",
    "whisper-tiny": "whisper_tiny",
    "granite-moe-3b-a800m": "granite_moe_3b",
    "falcon-mamba-7b": "falcon_mamba_7b",
    "deepseek-coder-33b": "deepseek_coder_33b",
    "yi-6b": "yi_6b",
    "phi3-medium-14b": "phi3_medium_14b",
    "llama3.2-1b": "llama3p2_1b",
    # paper Sec. 5.5 case-study model
    "llama3-8b": "llama3_8b",
}

ARCH_IDS = [k for k in _MODULES if k != "llama3-8b"]
ALL_IDS = list(_MODULES)


def get_config(arch_id: str, smoke: bool = False) -> ModelConfig:
    if arch_id not in _MODULES:
        raise KeyError(f"unknown arch {arch_id!r}; known: {ALL_IDS}")
    mod = importlib.import_module(f"repro.configs.{_MODULES[arch_id]}")
    return mod.SMOKE if smoke else mod.CONFIG
