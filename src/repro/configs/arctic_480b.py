"""Snowflake Arctic (480B): dense-MoE hybrid - 128 experts top-2 routed in
parallel with a dense residual MLP [hf:Snowflake/snowflake-arctic-base]."""
from repro.models.config import ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="arctic-480b", family="moe",
    n_layers=35, d_model=7168, n_heads=56, n_kv_heads=8, d_ff=4864,
    vocab_size=32000,
    layer_pattern="e" * 35,
    moe=MoEConfig(num_experts=128, top_k=2, expert_d_ff=4864,
                  dense_residual_d_ff=4864),
    source="hf:Snowflake/snowflake-arctic-base",
)

SMOKE = ModelConfig(
    name="arctic-smoke", family="moe",
    n_layers=2, d_model=256, n_heads=4, n_kv_heads=2, d_ff=512,
    vocab_size=512,
    layer_pattern="ee",
    moe=MoEConfig(num_experts=4, top_k=2, expert_d_ff=128,
                  dense_residual_d_ff=128),
    source="reduced arctic family",
)
