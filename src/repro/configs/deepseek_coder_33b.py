"""DeepSeek-Coder-33B: llama-arch dense GQA [arXiv:2401.14196]."""
from repro.models.config import ModelConfig, dense_pattern

CONFIG = ModelConfig(
    name="deepseek-coder-33b", family="dense",
    n_layers=62, d_model=7168, n_heads=56, n_kv_heads=8, d_ff=19200,
    vocab_size=32256,
    layer_pattern=dense_pattern(62),
    rope_theta=100_000.0,
    source="arXiv:2401.14196",
)

SMOKE = ModelConfig(
    name="deepseek-coder-smoke", family="dense",
    n_layers=2, d_model=256, n_heads=8, n_kv_heads=2, d_ff=512,
    vocab_size=512,
    layer_pattern=dense_pattern(2),
    source="reduced deepseek family",
)
