"""Whisper-tiny: 4-layer encoder-decoder; the mel-spectrogram + conv
frontend is a stub supplying 1500 frame embeddings [arXiv:2212.04356].
RoPE replaces whisper's learned positions (documented adaptation)."""
from repro.models.config import EncoderConfig, ModelConfig, dense_pattern

CONFIG = ModelConfig(
    name="whisper-tiny", family="audio",
    n_layers=4, d_model=384, n_heads=6, n_kv_heads=6, d_ff=1536,
    vocab_size=51865,
    layer_pattern=dense_pattern(4),
    encoder=EncoderConfig(n_layers=4, source_len=1500),
    frontend="audio_stub", frontend_tokens=1500, frontend_dim=384,
    source="arXiv:2212.04356",
)

SMOKE = ModelConfig(
    name="whisper-smoke", family="audio",
    n_layers=2, d_model=128, n_heads=4, n_kv_heads=4, d_ff=256,
    vocab_size=512,
    layer_pattern=dense_pattern(2),
    encoder=EncoderConfig(n_layers=2, source_len=64),
    frontend="audio_stub", frontend_tokens=64, frontend_dim=128,
    source="reduced whisper family",
)
