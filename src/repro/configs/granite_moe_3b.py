"""IBM Granite 3.0 MoE 3B (active 800M): 40 experts top-8, small expert
FFNs [hf:ibm-granite/granite-3.0-1b-a400m-base family]."""
from repro.models.config import ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="granite-moe-3b-a800m", family="moe",
    n_layers=32, d_model=1536, n_heads=24, n_kv_heads=8, d_ff=512,
    vocab_size=49155,
    layer_pattern="e" * 32,
    moe=MoEConfig(num_experts=40, top_k=8, expert_d_ff=512),
    source="hf:ibm-granite/granite-3.0-1b-a400m-base",
)

SMOKE = ModelConfig(
    name="granite-moe-smoke", family="moe",
    n_layers=2, d_model=256, n_heads=4, n_kv_heads=2, d_ff=128,
    vocab_size=512,
    layer_pattern="ee",
    moe=MoEConfig(num_experts=4, top_k=2, expert_d_ff=128),
    source="reduced granite family",
)
