"""Yi-6B: llama-arch dense with aggressive GQA (kv=4) [arXiv:2403.04652]."""
from repro.models.config import ModelConfig, dense_pattern

CONFIG = ModelConfig(
    name="yi-6b", family="dense",
    n_layers=32, d_model=4096, n_heads=32, n_kv_heads=4, d_ff=11008,
    vocab_size=64000,
    layer_pattern=dense_pattern(32),
    rope_theta=5_000_000.0,
    source="arXiv:2403.04652",
)

SMOKE = ModelConfig(
    name="yi-smoke", family="dense",
    n_layers=2, d_model=256, n_heads=8, n_kv_heads=2, d_ff=512,
    vocab_size=512,
    layer_pattern=dense_pattern(2),
    source="reduced yi family",
)
