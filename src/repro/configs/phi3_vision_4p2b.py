"""Phi-3-vision-128k-instruct: phi3-mini decoder + CLIP ViT-L/14-336
vision tower (stubbed: 576 patch embeddings of dim 1024)
[hf:microsoft/Phi-3-vision-128k-instruct]."""
from repro.models.config import ModelConfig, dense_pattern

CONFIG = ModelConfig(
    name="phi-3-vision-4.2b", family="vlm",
    n_layers=32, d_model=3072, n_heads=32, n_kv_heads=32, d_ff=8192,
    vocab_size=32064,
    layer_pattern=dense_pattern(32),
    frontend="vision_stub", frontend_tokens=576, frontend_dim=1024,
    source="hf:microsoft/Phi-3-vision-128k-instruct",
)

SMOKE = ModelConfig(
    name="phi3-vision-smoke", family="vlm",
    n_layers=2, d_model=256, n_heads=4, n_kv_heads=4, d_ff=512,
    vocab_size=512,
    layer_pattern=dense_pattern(2),
    frontend="vision_stub", frontend_tokens=16, frontend_dim=64,
    source="reduced phi3-vision family",
)
