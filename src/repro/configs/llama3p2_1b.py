"""Llama-3.2-1B: small llama3 dense GQA, tied embeddings
[hf:meta-llama/Llama-3.2-1B]."""
from repro.models.config import ModelConfig, dense_pattern

CONFIG = ModelConfig(
    name="llama3.2-1b", family="dense",
    n_layers=16, d_model=2048, n_heads=32, n_kv_heads=8, d_ff=8192,
    vocab_size=128256,
    layer_pattern=dense_pattern(16),
    rope_theta=500_000.0, tie_embeddings=True,
    source="hf:meta-llama/Llama-3.2-1B",
)

SMOKE = ModelConfig(
    name="llama3.2-smoke", family="dense",
    n_layers=2, d_model=256, n_heads=8, n_kv_heads=2, d_ff=512,
    vocab_size=512,
    layer_pattern=dense_pattern(2),
    tie_embeddings=True,
    source="reduced llama3 family",
)
