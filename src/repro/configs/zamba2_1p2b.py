"""Zamba2-1.2B: Mamba-2 backbone with shared attention blocks
[arXiv:2411.15242]."""
from repro.models.config import (ModelConfig, SSMConfig, hybrid_pattern)

CONFIG = ModelConfig(
    name="zamba2-1.2b", family="hybrid",
    n_layers=38, d_model=2048, n_heads=32, n_kv_heads=32, d_ff=8192,
    vocab_size=32000,
    layer_pattern=hybrid_pattern(38, attn_every=6, offset=5),
    ssm=SSMConfig(d_state=64, d_conv=4, expand=2, headdim=64, version=2),
    shared_attention=True,
    source="arXiv:2411.15242",
)

SMOKE = ModelConfig(
    name="zamba2-smoke", family="hybrid",
    n_layers=4, d_model=256, n_heads=4, n_kv_heads=4, d_ff=512,
    vocab_size=512,
    layer_pattern="22a2",
    ssm=SSMConfig(d_state=16, d_conv=4, expand=2, headdim=64, version=2),
    shared_attention=True,
    source="reduced zamba2 family",
)
