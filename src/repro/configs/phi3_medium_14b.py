"""Phi-3-medium-14B: RoPE SwiGLU GQA dense [arXiv:2404.14219]."""
from repro.models.config import ModelConfig, dense_pattern

CONFIG = ModelConfig(
    name="phi3-medium-14b", family="dense",
    n_layers=40, d_model=5120, n_heads=40, n_kv_heads=10, d_ff=17920,
    vocab_size=100352,
    layer_pattern=dense_pattern(40),
    source="arXiv:2404.14219",
)

SMOKE = ModelConfig(
    name="phi3-medium-smoke", family="dense",
    n_layers=2, d_model=256, n_heads=8, n_kv_heads=2, d_ff=512,
    vocab_size=512,
    layer_pattern=dense_pattern(2),
    source="reduced phi3 family",
)
