"""Llama-3-8B: the paper's FSDP training case-study model (Sec. 5.5)."""
from repro.models.config import ModelConfig, dense_pattern

CONFIG = ModelConfig(
    name="llama3-8b", family="dense",
    n_layers=32, d_model=4096, n_heads=32, n_kv_heads=8, d_ff=14336,
    vocab_size=128256,
    layer_pattern=dense_pattern(32),
    rope_theta=500_000.0,
    source="paper Sec. 5.5 / hf:meta-llama/Meta-Llama-3-8B",
)

SMOKE = ModelConfig(
    name="llama3-8b-smoke", family="dense",
    n_layers=2, d_model=256, n_heads=8, n_kv_heads=2, d_ff=512,
    vocab_size=512,
    layer_pattern=dense_pattern(2),
    source="reduced llama3 family",
)
