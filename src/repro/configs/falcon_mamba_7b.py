"""Falcon-Mamba-7B: pure Mamba-1, attention-free [arXiv:2410.05355]."""
from repro.models.config import ModelConfig, SSMConfig, ssm_pattern

CONFIG = ModelConfig(
    name="falcon-mamba-7b", family="ssm",
    n_layers=64, d_model=4096, n_heads=0, n_kv_heads=0, d_ff=0,
    vocab_size=65024,
    layer_pattern=ssm_pattern(64, version=1),
    ssm=SSMConfig(d_state=16, d_conv=4, expand=2, version=1),
    source="arXiv:2410.05355",
)

SMOKE = ModelConfig(
    name="falcon-mamba-smoke", family="ssm",
    n_layers=2, d_model=256, n_heads=0, n_kv_heads=0, d_ff=0,
    vocab_size=512,
    layer_pattern=ssm_pattern(2, version=1),
    ssm=SSMConfig(d_state=16, d_conv=4, expand=2, version=1),
    source="reduced falcon-mamba family",
)
