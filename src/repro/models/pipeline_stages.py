"""Pipeline-parallel stage partitioning of the layer stack.

A pipeline stage owns a contiguous slice of ``cfg.layer_pattern``:
stage 0 additionally runs the embedding, the last stage the final norm
+ logits + loss.  Two consumers:

* **Cost modelling / placement** - ``partition_stages`` balances any
  pattern (dense, MoE, SSM, hybrid) into contiguous slices so
  ``tuner.placement`` and ``benchmarks/pipeline.py`` can price a
  PP x TP x FSDP assignment for every zoo architecture.
* **SPMD execution** (``training.pipeline``) - the stacked layer
  params keep their single ``g0`` pytree and are *sharded over the
  stage mesh axis on the leading layer dim* (``stage_param_specs``),
  so inside ``shard_map`` every stage rank holds its slab and runs the
  same scanned body.  This path requires a uniform stack
  (``uniform_stage_rows``): one scan group, no shared attention, no
  encoder/frontend prefix, rows divisible by stages - the layer axis
  must shard evenly for all ranks to execute one program.

Embedding and final norm are replicated across the stage axis (the
embedding is consumed at both pipeline ends via weight tying); their
gradients are summed over the stage axis by
``training.pipeline.sync_stage_grads``.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
from jax import lax
from jax.sharding import PartitionSpec as P

from repro.core import ledger
from repro.models import blocks
from repro.models.config import ModelConfig
from repro.models.pcontext import ParallelContext

Params = dict


@dataclasses.dataclass(frozen=True)
class StageSlice:
    """One pipeline stage's contiguous slice of the layer pattern."""
    index: int
    start: int            # first layer row (inclusive)
    stop: int             # past-the-end row
    pattern: str          # the rows this stage executes

    @property
    def count(self) -> int:
        return self.stop - self.start


def partition_stages(cfg: ModelConfig, n_stages: int) -> list[StageSlice]:
    """Balanced contiguous split of ``cfg.layer_pattern``: every stage
    gets ``floor(L/S)`` rows and the first ``L mod S`` stages one extra
    (the last stage already carries the logits/loss epilogue, so the
    remainder is front-loaded)."""
    n_rows = len(cfg.layer_pattern)
    if n_stages < 1:
        raise ValueError("n_stages must be >= 1")
    if n_stages > n_rows:
        raise ValueError(f"{n_stages} stages > {n_rows} layer rows")
    base, extra = divmod(n_rows, n_stages)
    out, start = [], 0
    for s in range(n_stages):
        cnt = base + (1 if s < extra else 0)
        out.append(StageSlice(s, start, start + cnt,
                              cfg.layer_pattern[start:start + cnt]))
        start += cnt
    return out


def uniform_stage_rows(cfg: ModelConfig, n_stages: int) -> int:
    """Rows per stage for the SPMD execution path, validating that the
    stack is uniform enough to shard the stacked layer axis evenly.
    Heterogeneous patterns still partition for cost modelling
    (``partition_stages``); executing them would need per-stage
    programs, which the single-controller SPMD step cannot express."""
    if cfg.encoder is not None or cfg.frontend != "text":
        raise NotImplementedError(
            "pipeline execution supports decoder-only text models")
    groups = blocks.scan_groups(cfg)
    if len(groups) != 1 or groups[0].shared:
        raise NotImplementedError(
            "pipeline execution needs a uniform layer stack (one scan "
            f"group); {cfg.name!r} has pattern {cfg.layer_pattern!r}")
    if n_stages < 1 or groups[0].count % n_stages:
        raise ValueError(
            f"{groups[0].count} layers not divisible by {n_stages} stages")
    return groups[0].count // n_stages


def stage_param_specs(abstract: Params, stage_axis: str,
                      base: Params | None = None) -> Params:
    """PartitionSpecs sharding the stacked layer axis over the stage
    mesh axis: each stage rank holds its contiguous slab of rows.
    Embedding/final-norm (and any frontend leaves) stay replicated
    across stages.  ``base`` composes an existing spec tree (e.g. FSDP
    over a data axis): the stage axis replaces the layer-dim entry of
    layer-stacked leaves and all other leaves keep their base spec."""
    specs: Params = {}
    for k, sub in abstract.items():
        if k.startswith("g"):
            if base is not None:
                specs[k] = jax.tree.map(
                    lambda b: P(stage_axis, *tuple(b)[1:]), base[k])
            else:
                specs[k] = jax.tree.map(lambda x: P(stage_axis), sub)
        elif base is not None:
            specs[k] = jax.tree.map(lambda b: P(*tuple(b)), base[k])
        else:
            specs[k] = jax.tree.map(lambda x: P(), sub)
    return specs


def stage_forward(slab: Params, h: jnp.ndarray, cfg: ModelConfig,
                  pc: ParallelContext, positions: jnp.ndarray,
                  remat: bool = True):
    """Run this rank's slab of layer rows (leading axis = local rows)
    with the same scanned body as ``model._run_groups``.  Returns
    (h, aux_sum)."""
    kind = cfg.layer_pattern[0]

    def body(carry, p):
        out, aux = blocks.row_forward(p, carry, kind, cfg, pc, positions)
        return out, aux

    if remat:
        body = jax.checkpoint(body)
    rows = jax.tree.leaves(slab)[0].shape[0]
    with ledger.scale(rows):
        h, auxs = lax.scan(body, h, slab)
    return h, jnp.sum(auxs)
