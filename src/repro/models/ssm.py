"""Mamba-1 (S6 selective scan) and Mamba-2 (SSD) blocks.

Channel-parallel over the tp axis: ``d_inner`` (and for Mamba-2 the head
dim grouping) is sharded; the recurrent scan is independent per channel so
no collective is needed inside the scan.  The only cross-channel coupling
is Mamba-1's ``x_proj`` (B/C/dt are functions of the full d_inner), which
is a row-parallel matmul -> one tp AllReduce, and the out_proj (row
parallel -> one tp AllReduce).

The scan itself is a first-order linear recurrence
``h_t = a_t * h_{t-1} + b_t`` evaluated with ``jax.lax.associative_scan``
(log-depth, TPU friendly) for training/prefill, and a single fused update
for decode.  ``kernels/ssm_scan`` provides the Pallas version of the same
contraction for the TPU hot path.

Decode state per block: (conv_state (B, d_conv-1, d_in_local),
ssm_state (B, ..., d_state)) - O(1) in context length, which is what makes
``long_500k`` native for the SSM/hybrid architectures.
"""
from __future__ import annotations

import math
from typing import Optional

import jax
import jax.numpy as jnp

from repro.models import layers
from repro.models.config import ModelConfig
from repro.models.pcontext import ParallelContext

Params = dict


def _dt_rank(cfg: ModelConfig) -> int:
    return cfg.ssm.dt_rank or math.ceil(cfg.d_model / 16)


def linear_scan(a: jnp.ndarray, b: jnp.ndarray,
                h0: Optional[jnp.ndarray] = None):
    """h_t = a_t * h_{t-1} + b_t along axis 1 (seq).  Returns all h_t and
    the final state."""
    if h0 is not None:
        b = b.at[:, 0].add(a[:, 0] * h0)

    def combine(x, y):
        a1, b1 = x
        a2, b2 = y
        return a1 * a2, a2 * b1 + b2

    a_all, h_all = jax.lax.associative_scan(combine, (a, b), axis=1)
    return h_all, h_all[:, -1]


def causal_conv1d(x: jnp.ndarray, w: jnp.ndarray,
                  state: Optional[jnp.ndarray] = None):
    """Depthwise causal conv.  x: (B, L, C); w: (C, K).  ``state`` is the
    trailing K-1 inputs from the previous segment (decode).  Returns
    (y, new_state)."""
    b, l, c = x.shape
    k = w.shape[-1]
    if state is None:
        state = jnp.zeros((b, k - 1, c), x.dtype)
    xp = jnp.concatenate([state, x], axis=1)          # (B, L+K-1, C)
    idx = jnp.arange(l)[:, None] + jnp.arange(k)[None, :]
    windows = xp[:, idx, :]                           # (B, L, K, C)
    y = jnp.einsum("blkc,ck->blc", windows, w)
    new_state = xp[:, -(k - 1):, :] if k > 1 else state
    return y, new_state


# ======================================================================== #
# Mamba-1
# ======================================================================== #

def init_mamba1(key, cfg: ModelConfig, tp: int, dtype) -> Params:
    """GLOBAL shapes; the inner (channel) dim is tp-sharded at run time.
    ``in_proj`` is stored as separate x/z tensors so column sharding stays
    well-defined."""
    s = cfg.ssm
    d_in = s.expand * cfg.d_model
    r = _dt_rank(cfg)
    ks = jax.random.split(key, 7)
    return {
        "in_x": layers._dense_init(ks[0], (cfg.d_model, d_in),
                                   cfg.d_model, dtype),
        "in_z": layers._dense_init(ks[5], (cfg.d_model, d_in),
                                   cfg.d_model, dtype),
        "conv_w": (jax.random.normal(ks[1], (d_in, s.d_conv)) /
                   math.sqrt(s.d_conv)).astype(dtype),
        # x_proj is row-parallel (input d_in sharded) -> tp AllReduce
        "x_proj": layers._dense_init(ks[2], (d_in, r + 2 * s.d_state),
                                     d_in, dtype),
        "dt_proj": layers._dense_init(ks[3], (r, d_in), r, dtype),
        "dt_bias": jnp.zeros((d_in,), jnp.float32),
        "A_log": jnp.log(jnp.tile(
            jnp.arange(1, s.d_state + 1, dtype=jnp.float32)[None, :],
            (d_in, 1))),
        "D": jnp.ones((d_in,), jnp.float32),
        "out_proj": layers._dense_init(ks[4], (d_in, cfg.d_model), d_in,
                                       dtype),
    }


def mamba1_forward(params: Params, x: jnp.ndarray, cfg: ModelConfig,
                   pc: ParallelContext, state: Optional[tuple] = None,
                   return_state: bool = False):
    """x: (B, L, d_model).  state: (conv_state, ssm_state) for decode
    continuation."""
    s = cfg.ssm
    b, l, _ = x.shape
    r = _dt_rank(cfg)

    xin = x @ params["in_x"]                      # (B, L, d_loc)
    z = x @ params["in_z"]
    conv_state = state[0] if state is not None else None
    xc, new_conv = causal_conv1d(xin, params["conv_w"], conv_state)
    xc = jax.nn.silu(xc)

    # B, C, dt from the full inner activation (row-parallel + AllReduce)
    proj = pc.tp_all_reduce(xc @ params["x_proj"])  # (B, L, r+2N)
    dt_in, Bmat, Cmat = jnp.split(proj, [r, r + s.d_state], axis=-1)
    dt = jax.nn.softplus(dt_in @ params["dt_proj"]
                         + params["dt_bias"])       # (B, L, d_loc)

    A = -jnp.exp(params["A_log"])                   # (d_loc, N)
    dt32 = dt.astype(jnp.float32)
    xc32 = xc.astype(jnp.float32)
    a = jnp.exp(dt32[..., None] * A[None, None])    # (B, L, d_loc, N)
    bu = (dt32 * xc32)[..., None] * \
        Bmat.astype(jnp.float32)[:, :, None, :]     # (B, L, d_loc, N)
    h0 = state[1] if state is not None else None
    h_all, h_last = linear_scan(a, bu, h0)
    y = jnp.einsum("bldn,bln->bld", h_all,
                   Cmat.astype(jnp.float32))        # (B, L, d_loc)
    y = y + params["D"][None, None] * xc32
    y = (y.astype(x.dtype)) * jax.nn.silu(z)
    out = pc.tp_all_reduce(y @ params["out_proj"])
    if return_state:
        return out, (new_conv, h_last)
    return out


def mamba1_decode(params: Params, x: jnp.ndarray, state: tuple,
                  cfg: ModelConfig, pc: ParallelContext):
    """Single-token decode; x: (B, 1, d_model)."""
    return mamba1_forward(params, x, cfg, pc, state=state,
                          return_state=True)


# ======================================================================== #
# Mamba-2 (SSD, scalar A per head)
# ======================================================================== #

def init_mamba2(key, cfg: ModelConfig, tp: int, dtype) -> Params:
    """GLOBAL shapes.  x/z/dt projections are channel/head-sharded; the
    B/C projections and their conv are replicated (B/C are shared across
    heads in SSD, so sharding them would change the model)."""
    s = cfg.ssm
    d_in = s.expand * cfg.d_model
    nh = d_in // s.headdim
    ks = jax.random.split(key, 7)
    return {
        "in_x": layers._dense_init(ks[0], (cfg.d_model, d_in),
                                   cfg.d_model, dtype),
        "in_z": layers._dense_init(ks[1], (cfg.d_model, d_in),
                                   cfg.d_model, dtype),
        "in_bc": layers._dense_init(ks[2], (cfg.d_model, 2 * s.d_state),
                                    cfg.d_model, dtype),
        "in_dt": layers._dense_init(ks[3], (cfg.d_model, nh),
                                    cfg.d_model, dtype),
        "conv_x": (jax.random.normal(ks[4], (d_in, s.d_conv)) /
                   math.sqrt(s.d_conv)).astype(dtype),
        "conv_bc": (jax.random.normal(ks[5], (2 * s.d_state, s.d_conv)) /
                    math.sqrt(s.d_conv)).astype(jnp.float32),
        "A_log": jnp.zeros((nh,), jnp.float32),
        "D": jnp.ones((nh,), jnp.float32),
        "norm": jnp.ones((d_in,), jnp.float32),
        "out_proj": layers._dense_init(ks[6], (d_in, cfg.d_model), d_in,
                                       dtype),
    }


def mamba2_forward(params: Params, x: jnp.ndarray, cfg: ModelConfig,
                   pc: ParallelContext, state: Optional[tuple] = None,
                   return_state: bool = False):
    s = cfg.ssm
    b, l, _ = x.shape
    d_loc = params["out_proj"].shape[0]
    nh = d_loc // s.headdim

    xin = x @ params["in_x"]
    z = x @ params["in_z"]
    bc = (x @ params["in_bc"]).astype(jnp.float32)
    dt = x @ params["in_dt"]
    # state is (conv_x, conv_bc, ssm): the x-conv state is channel-sharded
    # over tp while the B/C-conv state is replicated, so they are separate
    # cache entries (cf. cache_specs).
    cs_x = state[0] if state is not None else None
    cs_bc = state[1] if state is not None else None
    xconv, new_conv_x = causal_conv1d(xin, params["conv_x"], cs_x)
    bcconv, new_conv_bc = causal_conv1d(bc, params["conv_bc"], cs_bc)
    xin = jax.nn.silu(xconv)
    bcconv = jax.nn.silu(bcconv)
    Bmat, Cmat = jnp.split(bcconv, 2, axis=-1)

    dt = jax.nn.softplus(dt.astype(jnp.float32))           # (B, L, nh)
    A = -jnp.exp(params["A_log"])                          # (nh,)
    xh = xin.reshape(b, l, nh, s.headdim).astype(jnp.float32)
    # h_t (B, L, nh, headdim, N): a_t scalar per head
    a = jnp.exp(dt * A[None, None])                        # (B, L, nh)
    bu = (dt[..., None] * xh)[..., None] * \
        Bmat.astype(jnp.float32)[:, :, None, None, :]
    h0 = state[2] if state is not None else None
    h_all, h_last = linear_scan(a[..., None, None], bu, h0)
    y = jnp.einsum("blhdn,bln->blhd", h_all, Cmat.astype(jnp.float32))
    y = y + params["D"][None, None, :, None] * xh
    y = y.reshape(b, l, d_loc).astype(x.dtype)
    y = layers.rms_norm(y * jax.nn.silu(z), params["norm"], cfg.norm_eps)
    out = pc.tp_all_reduce(y @ params["out_proj"])
    if return_state:
        return out, (new_conv_x, new_conv_bc, h_last)
    return out


def mamba2_decode(params: Params, x: jnp.ndarray, state: tuple,
                  cfg: ModelConfig, pc: ParallelContext):
    return mamba2_forward(params, x, cfg, pc, state=state,
                          return_state=True)


def mamba_state_shapes(cfg: ModelConfig, tp: int, batch: int,
                       version: int) -> tuple:
    """Abstract decode-state shapes: v1 -> (conv, ssm); v2 ->
    (conv_x, conv_bc, ssm).  The x-conv/ssm dims are tp-sharded."""
    s = cfg.ssm
    d_loc = s.expand * cfg.d_model // tp
    if version == 1:
        return ((batch, s.d_conv - 1, d_loc),
                (batch, d_loc, s.d_state))
    nh = d_loc // s.headdim
    return ((batch, s.d_conv - 1, d_loc),
            (batch, s.d_conv - 1, 2 * s.d_state),
            (batch, nh, s.headdim, s.d_state))
