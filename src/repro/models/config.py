"""Model configuration for the architecture zoo.

A ``ModelConfig`` fully describes one architecture: the layer pattern (one
char per layer row: ``a`` = attention + dense SwiGLU FFN, ``e`` =
attention + MoE FFN, ``1`` = Mamba-1 block, ``2`` = Mamba-2 block), the
transformer dimensions, and the modality frontend.

Tensor-parallel padding: head counts and expert counts that do not divide
the model axis are padded with inert (zero-initialized, masked) units;
``padded_heads``/``padded_experts`` report the padded sizes for a given tp
so the roofline's useful-FLOPs ratio can account for the waste.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Optional


def pad_to(x: int, m: int) -> int:
    return (x + m - 1) // m * m


@dataclasses.dataclass(frozen=True)
class MoEConfig:
    num_experts: int
    top_k: int
    expert_d_ff: int
    # dense residual MLP alongside the MoE branch (Snowflake Arctic)
    dense_residual_d_ff: int = 0
    capacity_factor: float = 1.25
    router_aux_weight: float = 0.01

    def padded_experts(self, tp: int) -> int:
        return pad_to(self.num_experts, tp)


@dataclasses.dataclass(frozen=True)
class SSMConfig:
    d_state: int = 16
    d_conv: int = 4
    expand: int = 2
    headdim: int = 64          # mamba2 only
    dt_rank: int = 0           # mamba1: ceil(d_model/16) when 0
    version: int = 1           # 1 = Mamba-1 (S6), 2 = Mamba-2 (SSD)


@dataclasses.dataclass(frozen=True)
class EncoderConfig:
    """Encoder stack for enc-dec models (whisper) - same dims as decoder
    unless overridden.  ``source_len`` is the (stub) frontend's output
    sequence length (audio frames / vision patches)."""
    n_layers: int
    source_len: int


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                    # dense | moe | ssm | hybrid | vlm | audio
    n_layers: int
    d_model: int
    n_heads: int                   # query heads (0 for attention-free)
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    layer_pattern: str             # one char per layer row, len == n_layers
    moe: Optional[MoEConfig] = None
    ssm: Optional[SSMConfig] = None
    encoder: Optional[EncoderConfig] = None
    # hybrids: the shared attention block (Zamba2) is one param set reused
    # at every 'a' position in the pattern
    shared_attention: bool = False
    frontend: str = "text"         # text | vision_stub | audio_stub
    frontend_tokens: int = 0       # patches / frames consumed by the stub
    frontend_dim: int = 0          # stub embedding dim (0 -> d_model)
    rope_theta: float = 10_000.0
    norm_eps: float = 1e-5
    tie_embeddings: bool = False
    sliding_window: int = 8192     # used by long-context decode
    source: str = ""               # citation for the config values

    def __post_init__(self):
        if len(self.layer_pattern) != self.n_layers:
            raise ValueError(
                f"{self.name}: layer_pattern length "
                f"{len(self.layer_pattern)} != n_layers {self.n_layers}")

    @property
    def head_dim(self) -> int:
        if self.n_heads == 0:
            return 0
        return self.d_model // self.n_heads

    def padded_heads(self, tp: int) -> int:
        return pad_to(self.n_heads, tp) if self.n_heads else 0

    def padded_kv_heads(self, tp: int) -> int:
        """KV heads are sharded when divisible, replicated otherwise."""
        if self.n_kv_heads == 0:
            return 0
        return self.n_kv_heads if self.n_kv_heads % tp == 0 else \
            self.n_kv_heads

    def kv_sharded(self, tp: int) -> bool:
        return self.n_kv_heads > 0 and self.n_kv_heads % tp == 0

    def padded_vocab(self, tp: int) -> int:
        return pad_to(self.vocab_size, tp)

    # ---- parameter / FLOP accounting (for the roofline) ------------------

    def param_count(self, tp: int = 1) -> int:
        """Total parameter count (with tp padding).  MoE counts all
        experts; ``active_param_count`` counts routed-active only."""
        return _count_params(self, tp, active_only=False)

    def active_param_count(self, tp: int = 1) -> int:
        return _count_params(self, tp, active_only=True)


def _attn_params(cfg: ModelConfig, tp: int) -> int:
    hq = cfg.padded_heads(tp)
    hkv = cfg.padded_kv_heads(tp)
    hd = cfg.head_dim
    return cfg.d_model * hq * hd + 2 * cfg.d_model * hkv * hd \
        + hq * hd * cfg.d_model


def _ffn_params(cfg: ModelConfig) -> int:
    return 3 * cfg.d_model * cfg.d_ff  # SwiGLU: gate, up, down


def _moe_params(cfg: ModelConfig, tp: int, active_only: bool) -> int:
    m = cfg.moe
    n_e = m.top_k if active_only else m.padded_experts(tp)
    p = n_e * 3 * cfg.d_model * m.expert_d_ff
    p += cfg.d_model * m.padded_experts(tp)  # router
    if m.dense_residual_d_ff:
        p += 3 * cfg.d_model * m.dense_residual_d_ff
    return p


def _ssm_params(cfg: ModelConfig) -> int:
    s = cfg.ssm
    d_in = s.expand * cfg.d_model
    if s.version == 1:
        dt_rank = s.dt_rank or math.ceil(cfg.d_model / 16)
        return (cfg.d_model * 2 * d_in            # in_proj (x, z)
                + d_in * s.d_conv                 # depthwise conv
                + d_in * (dt_rank + 2 * s.d_state)  # x_proj
                + dt_rank * d_in                  # dt_proj
                + d_in * s.d_state                # A_log
                + d_in                            # D
                + d_in * cfg.d_model)             # out_proj
    n_heads = d_in // s.headdim
    return (cfg.d_model * (2 * d_in + 2 * s.d_state + n_heads)  # in_proj
            + (d_in + 2 * s.d_state) * s.d_conv
            + n_heads * 2                        # A_log, D (per head)
            + d_in                               # norm
            + d_in * cfg.d_model)                # out_proj


def _count_params(cfg: ModelConfig, tp: int, active_only: bool) -> int:
    total = cfg.padded_vocab(tp) * cfg.d_model          # embedding
    if not cfg.tie_embeddings:
        total += cfg.padded_vocab(tp) * cfg.d_model     # lm head
    shared_attn_counted = False
    for ch in cfg.layer_pattern:
        if ch == "a":
            if cfg.shared_attention:
                if not shared_attn_counted:
                    total += _attn_params(cfg, tp) + _ffn_params(cfg)
                    shared_attn_counted = True
            else:
                total += _attn_params(cfg, tp) + _ffn_params(cfg)
        elif ch == "e":
            total += _attn_params(cfg, tp) + _moe_params(cfg, tp,
                                                         active_only)
        elif ch in "12":
            total += _ssm_params(cfg)
        else:
            raise ValueError(f"unknown layer kind {ch!r}")
    if cfg.encoder:
        # encoder rows: attention + FFN per layer (whisper-style)
        total += cfg.encoder.n_layers * (_attn_params(cfg, tp)
                                         + _ffn_params(cfg))
        # decoder cross-attention per 'a' row
        total += cfg.layer_pattern.count("a") * _attn_params(cfg, tp)
    return total


# Standard decoder row patterns -------------------------------------------

def dense_pattern(n_layers: int) -> str:
    return "a" * n_layers


def ssm_pattern(n_layers: int, version: int) -> str:
    return ("1" if version == 1 else "2") * n_layers


def hybrid_pattern(n_layers: int, attn_every: int, offset: int = 5) -> str:
    """Mamba2 rows with shared attention rows interleaved (Zamba2)."""
    rows = []
    for i in range(n_layers):
        rows.append("a" if (i % attn_every) == offset else "2")
    return "".join(rows)
