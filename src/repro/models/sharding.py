"""Parameter sharding specs: tensor parallel over ``model``, FSDP over the
data axis (the paper's Sec. 5.5 case study: FSDP AllGather before use,
ReduceScatter on grads - both through the CXL-CCL Communicator).

``param_specs`` walks the param pytree by path and assigns:

* TP dim (over ``model``): Megatron column/row rules per leaf name;
* FSDP dim (over the dp axis, possibly hierarchical ``(pod, data)``):
  the largest remaining dim that divides dp, for leaves above a size
  threshold.  Small leaves (norms, biases, conv kernels) stay replicated,
  like torch-FSDP's ``min_num_params``.

Stacked scan-group params carry a leading layer dim which is never
sharded.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Optional, Union

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P
from jax.tree_util import DictKey, tree_map_with_path

from repro.models.pcontext import ParallelContext

FSDP_MIN_SIZE = 65536

# -- axis-name indirection (tuner.placement) -------------------------------
# Model code and the spec tables below speak *logical* axis names
# ("model", "data").  A placement may bind a logical axis to
# differently-named mesh axes - in particular, split one logical axis
# across adjacent fabric levels, each a mesh axis of its own.  The
# alias registry maps logical -> mesh axes at spec-construction time so
# a placement can relabel the mesh without touching model code.

_AXIS_ALIASES: dict = {}


def set_axis_aliases(aliases: dict) -> None:
    """Install the placement's logical->mesh axis map, e.g.
    ``{"data": ("pod", "node")}``.  Values are a mesh axis name or a
    tuple of them (outermost first, the rank-major convention)."""
    _AXIS_ALIASES.clear()
    _AXIS_ALIASES.update(aliases)


def clear_axis_aliases() -> None:
    _AXIS_ALIASES.clear()


def resolve_axis(axis):
    """Map a logical axis spec (name or tuple of names) through the
    alias registry, flattening tuple-valued aliases.  Unaliased names
    pass through, so callers can resolve unconditionally."""
    if axis is None:
        return None
    if isinstance(axis, (tuple, list)):
        out: list = []
        for a in axis:
            r = _AXIS_ALIASES.get(a, a)
            out.extend(r) if isinstance(r, (tuple, list)) else \
                out.append(r)
        return tuple(out)
    r = _AXIS_ALIASES.get(axis, axis)
    return tuple(r) if isinstance(r, (tuple, list)) else r

# leaf name -> dim sharded over the model axis (None = replicated)
TP_DIM = {
    "wq": 1, "wk": 1, "wv": 1, "wo": 0,
    "wg": None, "wu": None, "wd": None,    # resolved by rank below
    "tok": 0, "head": 1,
    "router": None,
    "in_x": 1, "in_z": 1, "in_dt": 1, "in_bc": None,
    "conv_w": 0, "conv_x": 0, "conv_bc": None,
    "x_proj": 0, "dt_proj": 1, "dt_bias": 0,
    "A_log": 0, "D": 0, "norm": 0,
    "out_proj": 0,
    "norm1": None, "norm2": None, "norm_x": None,
    "final_norm": None, "enc_norm": None,
    "enc_proj": None, "front_proj": None,
}


def _path_names(path) -> list[str]:
    return [k.key if isinstance(k, DictKey) else str(k) for k in path]


def _tp_dim(names: list[str], rank: int, stacked: bool) -> Optional[int]:
    leaf = names[-1]
    parent = names[-2] if len(names) > 1 else ""
    base_rank = rank - (1 if stacked else 0)
    if leaf in ("wg", "wu", "wd"):
        if parent == "moe" or base_rank == 3:
            # expert-stacked MoE weights: expert-parallel on dim 0
            return 0
        # dense FFN: column for wg/wu, row for wd
        return 1 if leaf in ("wg", "wu") else 0
    if leaf in ("wk", "wv") and parent in ("attn", "xattn"):
        return 1  # may be overridden to replicated by kv_sharded=False
    d = TP_DIM.get(leaf, None)
    return d


def param_specs(params: Any, cfg, *, model_axis: str = "model",
                dp_axis: Union[str, tuple, None] = None,
                fsdp: bool = True) -> Any:
    """PartitionSpec pytree matching ``params`` (arrays or
    ShapeDtypeStructs).  Axis names resolve through the placement
    alias registry (``set_axis_aliases``) first, so specs built with
    the logical names land on the mesh axes the placement chose."""
    model_axis = resolve_axis(model_axis)
    dp_axis = resolve_axis(dp_axis)

    def spec_for(path, leaf) -> P:
        names = _path_names(path)
        shape = leaf.shape
        rank = len(shape)
        stacked = _is_stacked(names, rank)
        tp_d = _tp_dim(names, rank, stacked)
        if tp_d is not None and stacked:
            tp_d += 1
        if tp_d is not None and not cfg.kv_sharded(_infer_tp()) and \
                names[-1] in ("wk", "wv"):
            tp_d = None
        dims: list = [None] * rank
        if tp_d is not None:
            dims[tp_d] = model_axis
        # encoder / frontend projections are used outside the FSDP-gather
        # hook (tiny stacks) - keep them dp-replicated
        no_fsdp = any(n in ("encoder", "enc_proj", "front_proj")
                      for n in names)
        if fsdp and dp_axis is not None and not no_fsdp \
                and leaf.size >= FSDP_MIN_SIZE:
            start = 1 if stacked else 0
            for i in range(start, rank):
                if dims[i] is None and shape[i] % _dp_size() == 0:
                    dims[i] = dp_axis
                    break
        return P(*dims)

    def _infer_tp() -> int:
        if isinstance(model_axis, tuple):
            n = 1
            for a in model_axis:
                n *= _MESH_SIZES.get(a, 1)
            return n
        return _MESH_SIZES.get(model_axis, 1)

    def _dp_size() -> int:
        if isinstance(dp_axis, str):
            return _MESH_SIZES.get(dp_axis, 1)
        n = 1
        for a in dp_axis:
            n *= _MESH_SIZES.get(a, 1)
        return n

    return tree_map_with_path(spec_for, params)


# Axis sizes for spec construction; set by callers before building specs
# (kept module-level so spec building can stay a pure tree walk).
_MESH_SIZES: dict[str, int] = {}


def set_mesh_sizes(sizes: dict[str, int]) -> None:
    _MESH_SIZES.clear()
    _MESH_SIZES.update(sizes)


def _is_stacked(names: list[str], rank: int) -> bool:
    """Group entries 'g<i>' hold layer-stacked params; 'encoder' too."""
    for n in names[:-1]:
        if n == "encoder" or (n.startswith("g") and n[1:].isdigit()):
            return True
    return False


def row_specs(specs: Any) -> Any:
    """Drop the leading (layer) dim of stacked specs: specs for a single
    scan-row param slice, used for the in-scan FSDP gather."""
    def drop(path, spec):
        names = _path_names(path)
        if _is_stacked(names, 0) and len(spec) > 0:
            return P(*spec[1:])
        return spec
    return tree_map_with_path(drop, specs)


def _has_axis(spec: P, axes) -> Optional[int]:
    target = axes if isinstance(axes, (tuple, list)) else (axes,)
    for i, s in enumerate(spec):
        if s == axes or s == tuple(target) or (
                isinstance(s, str) and s in target):
            return i
    return None


def sync_grads(grads: Any, specs: Any, pc: ParallelContext,
               dp_axis: Union[str, tuple, None]) -> Any:
    """Sum gradients of replicated parameters across the mesh axes they
    are replicated over.

    * FSDP-sharded leaves already receive their cross-dp sum through the
      AD transpose of the gather (ReduceScatter);
    * TP-sharded leaves' grads are complete locally;
    * leaves replicated over an axis accumulate only their local
      contribution and need an explicit AllReduce over that axis
      (Megatron's layernorm-grad sync, generalized).

    This is the per-leaf reference path (one collective per leaf); the
    production trainer uses ``core.overlap.bucketed_sync_grads``, which
    fuses same-(dtype, axes) leaves into size-capped flat buffers and is
    numerically equivalent (tests/_mesh_runner.py asserts bitwise
    equality for fp32 under the ring backend).
    """
    dp = tuple(dp_axis) if isinstance(dp_axis, (tuple, list)) else \
        ((dp_axis,) if dp_axis else ())
    tp = pc.tp_axis

    def fix(path, g):
        spec = specs
        for k in path:
            spec = spec[k.key if isinstance(k, DictKey) else k.idx]
        flat_axes = set()
        for s in spec:
            if s is None:
                continue
            for a in (s if isinstance(s, tuple) else (s,)):
                flat_axes.add(a)
        # dp levels first (outermost), tp innermost: one tuple-axis
        # AllReduce so the Communicator can decompose hierarchically
        # against the active topology instead of syncing per level
        missing = []
        if dp and not any(a in flat_axes for a in dp):
            missing.extend(dp)
        if tp is not None and tp not in flat_axes:
            missing.append(tp)
        if missing:
            g = pc.comm.all_reduce(
                g, missing[0] if len(missing) == 1 else tuple(missing))
        return g

    return tree_map_with_path(fix, grads)


def fsdp_gather_fn(all_row_specs: dict, pc: ParallelContext,
                   dp_axis: Union[str, tuple]):
    """Returns gather(group_key, row_params) -> gathered params.

    AllGather (via the CXL-CCL Communicator) every leaf whose spec shards
    a dim over the dp axis; autodiff transposes it into the matching
    ReduceScatter on the gradient - exactly FSDP's communication pattern.

    Per-leaf reference path; the production trainer uses
    ``core.overlap.make_gather_fn`` (same contract, fused size-capped
    buckets: one AllGather per bucket instead of one per leaf).
    """
    def gather(group_key: str, row_params):
        specs = all_row_specs[group_key]

        def g(path, x):
            spec = specs
            for k in path:
                spec = spec[k.key if isinstance(k, DictKey) else k.idx]
            dim = _has_axis(spec, dp_axis)
            if dim is None:
                return x
            moved = jnp.moveaxis(x, dim, 0)
            full = pc.comm.all_gather(moved, dp_axis)
            return jnp.moveaxis(full, 0, dim)

        return tree_map_with_path(g, row_params)
    return gather
