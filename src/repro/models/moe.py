"""Mixture-of-Experts layer with expert parallelism over the tp axis.

Experts are sharded across the model axis (padded to a multiple of tp,
padding experts masked with -inf router logits).  Token routing uses the
paper's AllToAll primitive - the collective the paper identifies with MoE
("Architectures like MoE further introduce all-to-all communication to
route and aggregate token batches across distributed expert layers").

Dispatch is capacity-based and sort-free:

1. router -> top-k experts per token;
2. position-in-expert via cumsum over the one-hot assignment; tokens
   beyond the per-expert capacity are dropped (standard Switch behavior);
3. scatter into an (experts, capacity, d) buffer, AllToAll over tp so each
   shard receives the buffers of its local experts from every peer;
4. local expert FFNs (SwiGLU), AllToAll back, weighted combine.

With ``pc.tp == 1`` the same code runs unsharded (smoke tests).
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.models import layers
from repro.models.config import ModelConfig
from repro.models.pcontext import ParallelContext

Params = dict


def init_moe(key, cfg: ModelConfig, tp: int, dtype) -> Params:
    """GLOBAL shapes: experts padded to a multiple of tp and stacked on
    the leading (expert-parallel) dim; the router stays replicated and
    masks padded experts with -inf."""
    m = cfg.moe
    e_pad = m.padded_experts(tp)
    ks = jax.random.split(key, 5)
    p = {
        "router": layers._dense_init(ks[0], (cfg.d_model, e_pad),
                                     cfg.d_model, jnp.float32),
        "wg": layers._dense_init(ks[1], (e_pad, cfg.d_model,
                                         m.expert_d_ff), cfg.d_model,
                                 dtype),
        "wu": layers._dense_init(ks[2], (e_pad, cfg.d_model,
                                         m.expert_d_ff), cfg.d_model,
                                 dtype),
        "wd": layers._dense_init(ks[3], (e_pad, m.expert_d_ff,
                                         cfg.d_model), m.expert_d_ff,
                                 dtype),
    }
    if m.dense_residual_d_ff:
        p["dense"] = layers.init_ffn(ks[4], cfg.d_model,
                                     m.dense_residual_d_ff, dtype)
    return p


def moe_forward(params: Params, x: jnp.ndarray, cfg: ModelConfig,
                pc: ParallelContext,
                capacity: Optional[int] = None,
                shard_tokens: bool = True):
    """x: (B, L, d).  Returns (out, aux_loss).

    ``shard_tokens`` (§Perf H1): inside a tp row the activations are
    replicated, so dispatching the full token set from every shard
    duplicates the expert GEMMs and the AllToAll payload tp times.  When
    enabled (and tokens divide tp), each shard routes a DISJOINT token
    slice and the combined outputs are re-assembled with one tp
    AllGather - expert FLOPs and a2a wire drop by ~tp at the cost of one
    (t, d) gather per layer."""
    m = cfg.moe
    b, l, d = x.shape
    t_full = b * l
    # local expert count from the (possibly shard_map-split) weight shape
    e_local = params["wg"].shape[0]
    e_pad = e_local * pc.tp
    k = m.top_k

    xt_full = x.reshape(t_full, d)
    sharded = shard_tokens and pc.tp > 1 and t_full % pc.tp == 0 \
        and t_full >= pc.tp
    if sharded:
        t = t_full // pc.tp
        start = pc.tp_index() * t
        xt = jax.lax.dynamic_slice_in_dim(xt_full, start, t, axis=0)
    else:
        t = t_full
        xt = xt_full
    logits = (xt.astype(jnp.float32) @ params["router"])
    if e_pad != m.num_experts:
        pad_mask = jnp.arange(e_pad) >= m.num_experts
        logits = jnp.where(pad_mask[None, :], -jnp.inf, logits)
    probs = jax.nn.softmax(logits, axis=-1)
    top_w, top_e = jax.lax.top_k(probs, k)                # (t, k)
    top_w = top_w / jnp.maximum(top_w.sum(-1, keepdims=True), 1e-9)

    # load-balance auxiliary loss (Switch-style)
    me = jnp.mean(probs, axis=0)
    ce = jnp.mean(jax.nn.one_hot(top_e[:, 0], e_pad), axis=0)
    aux = m.num_experts * jnp.sum(me * ce) * m.router_aux_weight

    if capacity is None:
        capacity = max(1, int(t * k * m.capacity_factor) // e_pad)
    # position of each (token, slot) within its expert queue
    onehot = jax.nn.one_hot(top_e, e_pad, dtype=jnp.int32)  # (t, k, E)
    flat_oh = onehot.reshape(t * k, e_pad)
    pos = jnp.cumsum(flat_oh, axis=0) - flat_oh             # (t*k, E)
    pos_in_e = jnp.sum(pos * flat_oh, axis=-1)              # (t*k,)
    e_flat = top_e.reshape(t * k)
    w_flat = top_w.reshape(t * k)
    keep = pos_in_e < capacity

    # scatter tokens into (E, capacity, d)
    slot = e_flat * capacity + jnp.minimum(pos_in_e, capacity - 1)
    buf = jnp.zeros((e_pad * capacity, d), x.dtype)
    src = jnp.repeat(xt, k, axis=0) * keep[:, None].astype(x.dtype)
    buf = buf.at[slot].add(src)
    buf = buf.reshape(e_pad, capacity, d)

    if pc.tp > 1:
        # (E, C, d) -> exchange so shard s receives buffers for experts
        # [s*e_local, (s+1)*e_local) from every peer.
        recv = pc.tp_all_to_all(buf.reshape(e_pad * capacity, d))
        # recv rows: (tp segments) x (e_local*capacity) from each peer;
        # peer p's segment holds ITS tokens for MY experts.
        recv = recv.reshape(pc.tp, e_local, capacity, d)
        expert_in = jnp.moveaxis(recv, 0, 1).reshape(
            e_local, pc.tp * capacity, d)
    else:
        expert_in = buf  # (E, C, d)

    # local expert SwiGLU (batched over experts)
    h = jax.nn.silu(jnp.einsum("ecd,edf->ecf", expert_in, params["wg"])) \
        * jnp.einsum("ecd,edf->ecf", expert_in, params["wu"])
    expert_out = jnp.einsum("ecf,efd->ecd", h, params["wd"])

    if pc.tp > 1:
        back = jnp.moveaxis(
            expert_out.reshape(e_local, pc.tp, capacity, d), 1, 0)
        back = pc.tp_all_to_all(
            back.reshape(pc.tp * e_local * capacity, d))
        out_buf = back.reshape(e_pad, capacity, d)
    else:
        out_buf = expert_out

    # gather + weighted combine
    flat_out = out_buf.reshape(e_pad * capacity, d)
    tok_out = flat_out[slot] * (w_flat * keep)[:, None].astype(x.dtype)
    combined = tok_out.reshape(t, k, d).sum(axis=1)
    if sharded:
        combined = pc.comm.all_gather(combined, pc.tp_axis)
    out = combined.reshape(b, l, d)

    if "dense" in params:  # Arctic: dense residual MLP in parallel
        out = out + layers.ffn_forward(params["dense"], x, pc)
    return out, aux
