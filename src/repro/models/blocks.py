"""Decoder rows and scan-group assembly.

A "row" is one entry of ``cfg.layer_pattern``:

* ``a`` - pre-norm attention + pre-norm SwiGLU FFN (plus cross-attention
  when the model has an encoder);
* ``e`` - pre-norm attention + pre-norm MoE FFN;
* ``1``/``2`` - pre-norm Mamba block.

Consecutive rows of the same kind are stacked (params get a leading layer
axis) and executed with ``lax.scan`` so the compiled HLO contains one body
per kind regardless of depth - essential to keep 512-device dry-run
compiles tractable.  Rows marked shared (Zamba2's shared attention block)
hold a single param set applied at every occurrence.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Optional

import jax
import jax.numpy as jnp

from repro.models import layers, moe, ssm
from repro.models.config import ModelConfig
from repro.models.pcontext import ParallelContext

Params = dict


@dataclasses.dataclass(frozen=True)
class Group:
    kind: str          # 'a' | 'e' | '1' | '2'
    count: int
    shared: bool = False   # single param set reused `count` times


def scan_groups(cfg: ModelConfig) -> list[Group]:
    """Groups in pattern order; consecutive same-kind rows merge into one
    scanned group.  Shared-attention rows (Zamba2) become ``shared=True``
    groups which all reference the single ``shared_a`` param set."""
    groups: list[Group] = []
    for ch in cfg.layer_pattern:
        shared = ch == "a" and cfg.shared_attention
        if groups and groups[-1].kind == ch \
                and groups[-1].shared == shared:
            groups[-1] = Group(ch, groups[-1].count + 1, shared)
        else:
            groups.append(Group(ch, 1, shared))
    return groups


# ----------------------------------------------------------------------- #
# row init / forward
# ----------------------------------------------------------------------- #

def init_row(key, kind: str, cfg: ModelConfig, tp: int, dtype,
             cross: bool = False) -> Params:
    ks = jax.random.split(key, 4)
    if kind == "a":
        p = {"norm1": jnp.ones((cfg.d_model,), jnp.float32),
             "attn": layers.init_attention(ks[0], cfg, tp, dtype),
             "norm2": jnp.ones((cfg.d_model,), jnp.float32),
             "ffn": layers.init_ffn(ks[1], cfg.d_model, cfg.d_ff, dtype)}
        if cross:
            p["norm_x"] = jnp.ones((cfg.d_model,), jnp.float32)
            p["xattn"] = layers.init_attention(ks[2], cfg, tp, dtype,
                                               cross=True)
        return p
    if kind == "e":
        return {"norm1": jnp.ones((cfg.d_model,), jnp.float32),
                "attn": layers.init_attention(ks[0], cfg, tp, dtype),
                "norm2": jnp.ones((cfg.d_model,), jnp.float32),
                "moe": moe.init_moe(ks[1], cfg, tp, dtype)}
    if kind == "1":
        return {"norm1": jnp.ones((cfg.d_model,), jnp.float32),
                "mamba": ssm.init_mamba1(ks[0], cfg, tp, dtype)}
    if kind == "2":
        return {"norm1": jnp.ones((cfg.d_model,), jnp.float32),
                "mamba": ssm.init_mamba2(ks[0], cfg, tp, dtype)}
    raise ValueError(kind)


def row_forward(p: Params, h: jnp.ndarray, kind: str, cfg: ModelConfig,
                pc: ParallelContext, positions: jnp.ndarray,
                encoder_out: Optional[jnp.ndarray] = None,
                causal: bool = True,
                window: Optional[int] = None):
    """Full-sequence forward for one row.  Returns (h, aux_loss)."""
    aux = jnp.float32(0.0)
    if kind in ("a", "e"):
        attn_in = layers.rms_norm(h, p["norm1"], cfg.norm_eps)
        h = h + layers.attention_forward(p["attn"], attn_in, cfg, pc,
                                         positions, causal=causal,
                                         window=window)
        if "xattn" in p and encoder_out is not None:
            x_in = layers.rms_norm(h, p["norm_x"], cfg.norm_eps)
            h = h + layers.attention_forward(p["xattn"], x_in, cfg, pc,
                                             positions, causal=False,
                                             kv_source=encoder_out)
        ff_in = layers.rms_norm(h, p["norm2"], cfg.norm_eps)
        if kind == "a":
            h = h + layers.ffn_forward(p["ffn"], ff_in, pc)
        else:
            out, aux = moe.moe_forward(p["moe"], ff_in, cfg, pc)
            h = h + out
    else:
        m_in = layers.rms_norm(h, p["norm1"], cfg.norm_eps)
        fwd = ssm.mamba1_forward if kind == "1" else ssm.mamba2_forward
        h = h + fwd(p["mamba"], m_in, cfg, pc)
    return h, aux


def row_prefill(p: Params, h: jnp.ndarray, kind: str, cfg: ModelConfig,
                pc: ParallelContext, positions: jnp.ndarray,
                max_seq: int, cache_dtype,
                encoder_out: Optional[jnp.ndarray] = None,
                window: Optional[int] = None):
    """Full-sequence forward that also emits this row's decode cache.
    Returns (h, aux, cache)."""
    aux = jnp.float32(0.0)
    if kind in ("a", "e"):
        attn_in = layers.rms_norm(h, p["norm1"], cfg.norm_eps)
        out, (k, v) = layers.attention_forward(
            p["attn"], attn_in, cfg, pc, positions, causal=True,
            window=window, return_kv=True)
        h = h + out
        cache = _kv_to_cache(k, v, cfg, pc, max_seq, cache_dtype)
        if "xattn" in p and encoder_out is not None:
            x_in = layers.rms_norm(h, p["norm_x"], cfg.norm_eps)
            xout, (ck, cv) = layers.attention_forward(
                p["xattn"], x_in, cfg, pc, positions, causal=False,
                kv_source=encoder_out, return_kv=True)
            h = h + xout
            cache["ck"] = ck.astype(cache_dtype)
            cache["cv"] = cv.astype(cache_dtype)
        ff_in = layers.rms_norm(h, p["norm2"], cfg.norm_eps)
        if kind == "a":
            h = h + layers.ffn_forward(p["ffn"], ff_in, pc)
        else:
            out, aux = moe.moe_forward(p["moe"], ff_in, cfg, pc)
            h = h + out
        return h, aux, cache
    m_in = layers.rms_norm(h, p["norm1"], cfg.norm_eps)
    if kind == "1":
        out, (conv, st) = ssm.mamba1_forward(p["mamba"], m_in, cfg, pc,
                                             return_state=True)
        return h + out, aux, {"conv": conv, "ssm": st}
    out, (cx, cbc, st) = ssm.mamba2_forward(p["mamba"], m_in, cfg, pc,
                                            return_state=True)
    return h + out, aux, {"conv": cx, "conv_bc": cbc, "ssm": st}


def _kv_to_cache(k: jnp.ndarray, v: jnp.ndarray, cfg: ModelConfig,
                 pc: ParallelContext, max_seq: int, cache_dtype) -> dict:
    """(B, L, n_kv_local, hd) head-layout -> flash-decoding cache layout:
    (B, S_local, n_kv_full, hd), sequence sharded over tp."""
    d = layers.attn_dims(cfg, pc.tp)
    b, l = k.shape[0], k.shape[1]
    if pc.tp > 1 and d.kv_sharded:
        k = layers._gather_heads(k, pc)
        v = layers._gather_heads(v, pc)
    if pc.tp > 1:
        s_local = max_seq // pc.tp
        start = pc.tp_index() * s_local
        # my sequence slice (prefill length L == global cache len for the
        # assigned shapes; shorter prefills zero-pad)
        k = jax.lax.dynamic_slice_in_dim(k, start, s_local, axis=1)
        v = jax.lax.dynamic_slice_in_dim(v, start, s_local, axis=1)
    else:
        s_local = max_seq
        if l < max_seq:
            pad = max_seq - l
            k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
            v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
    return {"k": k.astype(cache_dtype), "v": v.astype(cache_dtype)}


def row_decode(p: Params, h: jnp.ndarray, kind: str, cache: dict,
               pos: jnp.ndarray, cfg: ModelConfig, pc: ParallelContext,
               window: Optional[int] = None):
    """Single-token decode for one row.  ``cache`` layouts:
    attention: {'k','v'} (+{'ck','cv'} cross KV); mamba: {'conv','ssm'}.
    Returns (h, new_cache)."""
    if kind in ("a", "e"):
        attn_in = layers.rms_norm(h, p["norm1"], cfg.norm_eps)
        kv_write = pos % window if window is not None else pos
        out, ck, cv = layers.decode_attention(
            p["attn"], attn_in, cache["k"], cache["v"], pos, cfg, pc,
            window=window, kv_write_pos=kv_write)
        h = h + out
        new_cache = dict(cache, k=ck, v=cv)
        if "xattn" in p and "ck" in cache:
            x_in = layers.rms_norm(h, p["norm_x"], cfg.norm_eps)
            h = h + _cross_decode(p["xattn"], x_in, cache["ck"],
                                  cache["cv"], cfg, pc)
        ff_in = layers.rms_norm(h, p["norm2"], cfg.norm_eps)
        if kind == "a":
            h = h + layers.ffn_forward(p["ffn"], ff_in, pc)
        else:
            # decode is drop-free: worst case every assignment lands on
            # one expert, so capacity = tokens * top_k (tiny at decode)
            cap = ff_in.shape[0] * ff_in.shape[1] * cfg.moe.top_k
            out, _ = moe.moe_forward(p["moe"], ff_in, cfg, pc,
                                     capacity=cap)
            h = h + out
        return h, new_cache
    m_in = layers.rms_norm(h, p["norm1"], cfg.norm_eps)
    if kind == "1":
        out, (conv, st) = ssm.mamba1_decode(
            p["mamba"], m_in, (cache["conv"], cache["ssm"]), cfg, pc)
        return h + out, dict(cache, conv=conv, ssm=st)
    out, (cx, cbc, st) = ssm.mamba2_decode(
        p["mamba"], m_in,
        (cache["conv"], cache["conv_bc"], cache["ssm"]), cfg, pc)
    return h + out, dict(cache, conv=cx, conv_bc=cbc, ssm=st)


def _cross_decode(p: Params, x: jnp.ndarray, ck: jnp.ndarray,
                  cv: jnp.ndarray, cfg: ModelConfig,
                  pc: ParallelContext) -> jnp.ndarray:
    """Cross-attention against precomputed encoder KV (B, S_enc, n_kv, hd)
    - local kv heads, full encoder sequence (encoder KV is small)."""
    d = layers.attn_dims(cfg, pc.tp)
    b = x.shape[0]
    q = (x @ p["wq"]).reshape(b, 1, d.n_q, d.head_dim)
    kk, vv = layers.select_kv(ck, cv, d, cfg, pc)
    out = layers.attention_scores(q, kk, vv, causal=False)
    out = out.reshape(b, 1, d.n_q * d.head_dim) @ p["wo"]
    return pc.tp_all_reduce(out)


def row_cache_init(kind: str, cfg: ModelConfig, pc: ParallelContext,
                   batch: int, max_seq: int, dtype,
                   cross_len: int = 0) -> dict:
    """Zero-initialized decode cache for one row.  The KV cache sequence
    dim is sharded over tp (flash-decoding layout)."""
    if kind in ("a", "e"):
        d = layers.attn_dims(cfg, pc.tp)
        n_kv_full = d.n_kv * pc.tp if d.kv_sharded else d.n_kv
        s_local = max_seq // max(pc.tp, 1) if pc.tp > 1 else max_seq
        c = {"k": jnp.zeros((batch, s_local, n_kv_full, d.head_dim),
                            dtype),
             "v": jnp.zeros((batch, s_local, n_kv_full, d.head_dim),
                            dtype)}
        if cross_len:
            c["ck"] = jnp.zeros((batch, cross_len, d.n_kv, d.head_dim),
                                dtype)
            c["cv"] = jnp.zeros((batch, cross_len, d.n_kv, d.head_dim),
                                dtype)
        return c
    if kind == "1":
        conv_s, ssm_s = ssm.mamba_state_shapes(cfg, max(pc.tp, 1), batch,
                                               1)
        return {"conv": jnp.zeros(conv_s, dtype),
                "ssm": jnp.zeros(ssm_s, jnp.float32)}
    cx_s, cbc_s, ssm_s = ssm.mamba_state_shapes(cfg, max(pc.tp, 1),
                                                batch, 2)
    return {"conv": jnp.zeros(cx_s, dtype),
            "conv_bc": jnp.zeros(cbc_s, jnp.float32),
            "ssm": jnp.zeros(ssm_s, jnp.float32)}
