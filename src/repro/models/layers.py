"""Core layers: RMSNorm, RoPE, GQA attention (train/prefill/decode),
SwiGLU FFN, embeddings, sharded-vocab cross-entropy.

Tensor-parallel convention (Megatron-style, inside shard_map):

* column-parallel weights hold their *local* out-features slice; the
  matmul needs no collective;
* row-parallel weights hold their local in-features slice; the partial
  product is summed with ``pc.tp_all_reduce`` (CXL-CCL AllReduce);
* Q heads are padded to a multiple of tp (zero weights, numerically
  inert); KV heads are sharded when divisible by tp, else replicated
  (GQA KV is small).

Decode attention is flash-decoding style: the KV cache is sharded over the
tp axis on the *sequence* dim; each shard computes a partial softmax
(m, l, o) and the combine is two tp AllReduces.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Optional

import jax
import jax.numpy as jnp
from jax import lax

from repro.core.overlap import StackedShards
from repro.models.config import ModelConfig
from repro.models.pcontext import ParallelContext

Params = dict


def dense(x: jnp.ndarray, w) -> jnp.ndarray:
    """``x @ w`` where ``w`` may be a rank-major ``StackedShards`` stack
    from the fused FSDP gather path (``TrainConfig.fuse_kernels``): the
    stack streams through the fused all_gather+matmul kernel
    (``kernels.ops.fused_dense`` - shard k+1 prefetched while shard k
    multiplies) instead of being concatenated first.  Plain arrays take
    the ordinary matmul, so the unfused/serving paths are unchanged."""
    if isinstance(w, StackedShards):
        from repro.kernels import ops
        return ops.fused_dense(x, w.shards)
    return x @ w


# ---------------------------------------------------------------------- #
# initialization helpers
# ---------------------------------------------------------------------- #

def _dense_init(key, shape, in_dim, dtype):
    scale = 1.0 / math.sqrt(in_dim)
    return (jax.random.normal(key, shape) * scale).astype(dtype)


def rms_norm(x: jnp.ndarray, scale: jnp.ndarray,
             eps: float = 1e-5) -> jnp.ndarray:
    dt = x.dtype
    x = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    return ((x * jax.lax.rsqrt(var + eps)) * scale).astype(dt)


# ---------------------------------------------------------------------- #
# rotary position embeddings
# ---------------------------------------------------------------------- #

def rope_frequencies(head_dim: int, theta: float) -> jnp.ndarray:
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2,
                                       dtype=jnp.float32) / head_dim))


def apply_rope(x: jnp.ndarray, positions: jnp.ndarray,
               theta: float) -> jnp.ndarray:
    """x: (..., seq, heads, head_dim); positions: (..., seq)."""
    hd = x.shape[-1]
    freqs = rope_frequencies(hd, theta)                    # (hd/2,)
    angles = positions[..., :, None].astype(jnp.float32) * freqs
    cos = jnp.cos(angles)[..., None, :]                    # (..., s, 1, hd/2)
    sin = jnp.sin(angles)[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin,
                           x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------- #
# attention
# ---------------------------------------------------------------------- #

@dataclasses.dataclass(frozen=True)
class AttnDims:
    """Local (per-tp-shard) attention dimensions."""
    n_q: int          # local query heads (after padding / tp split)
    n_kv: int         # local kv heads (sharded) or full kv heads (repl.)
    head_dim: int
    kv_sharded: bool


def attn_dims(cfg: ModelConfig, tp: int) -> AttnDims:
    if cfg.kv_sharded(tp) and cfg.padded_heads(tp) != cfg.n_heads:
        # sharded kv + padded q would misalign shard-local GQA grouping
        raise ValueError(
            f"{cfg.name}: q-head padding with sharded kv unsupported")
    return AttnDims(n_q=cfg.padded_heads(tp) // tp,
                    n_kv=(cfg.n_kv_heads // tp if cfg.kv_sharded(tp)
                          else cfg.n_kv_heads),
                    head_dim=cfg.head_dim,
                    kv_sharded=cfg.kv_sharded(tp))


def init_attention(key, cfg: ModelConfig, tp: int, dtype,
                   cross: bool = False) -> Params:
    """GLOBAL param shapes (shard_map splits them per param_specs).
    Q heads padded to a multiple of tp; padded head weights zeroed so the
    padding is numerically inert under any tp."""
    dm = cfg.d_model
    hd = cfg.head_dim
    hq_pad = cfg.padded_heads(tp)
    n_kv = cfg.n_kv_heads
    real = cfg.n_heads * hd
    ks = jax.random.split(key, 4)
    wq = _dense_init(ks[0], (dm, hq_pad * hd), dm, dtype)
    wo = _dense_init(ks[3], (hq_pad * hd, dm), cfg.n_heads * hd, dtype)
    if hq_pad != cfg.n_heads:
        wq = wq.at[:, real:].set(0.0)
        wo = wo.at[real:, :].set(0.0)
    return {
        "wq": wq,
        "wk": _dense_init(ks[1], (dm, n_kv * hd), dm, dtype),
        "wv": _dense_init(ks[2], (dm, n_kv * hd), dm, dtype),
        "wo": wo,
    }


def _repeat_kv(k: jnp.ndarray, n_rep: int) -> jnp.ndarray:
    if n_rep == 1:
        return k
    return jnp.repeat(k, n_rep, axis=2)


def select_kv(k: jnp.ndarray, v: jnp.ndarray, d: "AttnDims",
              cfg: ModelConfig, pc: ParallelContext):
    """Map each *local* query head to its GQA kv head.

    With sharded kv heads, shard-local grouping is aligned (guarded in
    attn_dims).  With replicated kv the mapping must use the GLOBAL query
    index and the *unpadded* group size - padded q heads clip to the last
    kv head (they are numerically inert via zero wo rows)."""
    if d.kv_sharded:
        rep = d.n_q // d.n_kv
        return _repeat_kv(k, rep), _repeat_kv(v, rep)
    g = max(1, cfg.n_heads // max(cfg.n_kv_heads, 1))
    q_glob = pc.tp_index() * d.n_q + jnp.arange(d.n_q)
    kv_idx = jnp.clip(q_glob // g, 0, d.n_kv - 1)
    return jnp.take(k, kv_idx, axis=2), jnp.take(v, kv_idx, axis=2)


def select_kv_global(k: jnp.ndarray, v: jnp.ndarray, hq_full: int,
                     cfg: ModelConfig):
    """Same mapping for the decode path where all q heads are gathered:
    hq_full may include padding; k/v hold all kv heads."""
    n_kv = k.shape[2]
    g = max(1, cfg.n_heads // max(cfg.n_kv_heads, 1))
    kv_idx = jnp.clip(jnp.arange(hq_full) // g, 0, n_kv - 1)
    return jnp.take(k, kv_idx, axis=2), jnp.take(v, kv_idx, axis=2)


FLASH_THRESHOLD = 1024  # sequences this long use blocked attention


def attention_scores(q, k, v, causal: bool, window: Optional[int] = None,
                     q_offset: int = 0):
    """Attention.  q: (B,Lq,H,hd), k/v: (B,Lk,H,hd).  Long sequences
    dispatch to the blocked flash path (O(L) memory fwd+bwd)."""
    if q.shape[1] >= FLASH_THRESHOLD and k.shape[1] >= FLASH_THRESHOLD:
        from repro.models.flash import flash_attention
        return flash_attention(q, k, v, causal, window, q_offset)
    hd = q.shape[-1]
    logits = jnp.einsum("bqhd,bkhd->bhqk", q, k,
                        preferred_element_type=jnp.float32)
    logits = logits / math.sqrt(hd)
    lq, lk = q.shape[1], k.shape[1]
    if causal:
        qpos = jnp.arange(lq)[:, None] + q_offset
        kpos = jnp.arange(lk)[None, :]
        mask = kpos <= qpos
        if window is not None:
            mask &= kpos > qpos - window
        logits = jnp.where(mask[None, None], logits, -jnp.inf)
    probs = jax.nn.softmax(logits, axis=-1)
    return jnp.einsum("bhqk,bkhd->bqhd", probs.astype(v.dtype), v)


def attention_forward(params: Params, x: jnp.ndarray, cfg: ModelConfig,
                      pc: ParallelContext, positions: jnp.ndarray,
                      causal: bool = True,
                      window: Optional[int] = None,
                      kv_source: Optional[jnp.ndarray] = None,
                      return_kv: bool = False):
    """Full-sequence attention (training / prefill / encoder).

    ``kv_source`` switches to cross-attention (keys/values from encoder
    output, no causal mask, no rope on kv positions beyond arange).
    The output is row-parallel-reduced over tp.
    """
    d = attn_dims(cfg, pc.tp)
    b, l, _ = x.shape
    q = dense(x, params["wq"]).reshape(b, l, d.n_q, d.head_dim)
    src = x if kv_source is None else kv_source
    lk = src.shape[1]
    k = dense(src, params["wk"]).reshape(b, lk, d.n_kv, d.head_dim)
    v = dense(src, params["wv"]).reshape(b, lk, d.n_kv, d.head_dim)
    if kv_source is None:
        q = apply_rope(q, positions, cfg.rope_theta)
        k = apply_rope(k, positions[..., :lk] if positions.shape[-1] >= lk
                       else jnp.arange(lk), cfg.rope_theta)
    kk, vv = select_kv(k, v, d, cfg, pc)
    out = attention_scores(q, kk, vv, causal=causal and kv_source is None,
                           window=window)
    out = dense(out.reshape(b, l, d.n_q * d.head_dim), params["wo"])
    out = pc.tp_all_reduce(out)
    if return_kv:
        return out, (k, v)
    return out


# -- decode path --------------------------------------------------------- #

def decode_attention(params: Params, x: jnp.ndarray, cache_k, cache_v,
                     pos: jnp.ndarray, cfg: ModelConfig,
                     pc: ParallelContext,
                     window: Optional[int] = None,
                     kv_write_pos: Optional[jnp.ndarray] = None):
    """One-token decode with a sequence-sharded KV cache.

    x: (B, 1, d_model).  cache_{k,v}: (B, S_local, n_kv, hd) - the local
    slice of a cache whose *global* sequence length is S_local * tp (tp
    sharded) or S_local (unsharded).  ``pos``: the global position being
    written - a scalar int32 (whole batch at one position, the static
    batch-synchronous path) or a ``(B,)`` int32 vector (per-slot
    positions, the continuous-batching engine where every decode slot
    carries its own request).  For a ring-buffer window cache the
    caller passes ``kv_write_pos`` = pos % window (same rank as
    ``pos``).

    Returns (attn_out (B,1,d_model), new_cache_k, new_cache_v).
    """
    d = attn_dims(cfg, pc.tp)
    b = x.shape[0]
    s_local = cache_k.shape[1]
    tp_idx = pc.tp_index()
    vec = jnp.ndim(pos) > 0   # per-slot positions (trace-time static)

    q = dense(x, params["wq"]).reshape(b, 1, d.n_q, d.head_dim)
    rope_pos = pos.reshape(b, 1) if vec else pos[None].reshape(1,)
    q = apply_rope(q, rope_pos, cfg.rope_theta)
    # KV for the new token: computed on every shard (redundant but tiny),
    # using the *full* kv-head projection when kv is replicated; when kv
    # is head-sharded we gather the heads so the seq-sharded cache holds
    # all kv heads.
    k_new = dense(x, params["wk"]).reshape(b, 1, d.n_kv, d.head_dim)
    v_new = dense(x, params["wv"]).reshape(b, 1, d.n_kv, d.head_dim)
    k_new = apply_rope(k_new, rope_pos, cfg.rope_theta)
    if d.kv_sharded and pc.tp > 1:
        # (B,1,n_kv_local,hd) -> all heads: gather over tp along head dim
        k_new = _gather_heads(k_new, pc)
        v_new = _gather_heads(v_new, pc)
    n_kv_full = k_new.shape[2]

    write = kv_write_pos if kv_write_pos is not None else pos
    # Which shard owns this cache slot?
    owner = (write // s_local) if pc.tp > 1 else jnp.int32(0)
    local_off = write % s_local
    sel = (owner == tp_idx) | (pc.tp == 1)
    if vec:
        # Per-slot write offsets: a dynamic_update_slice cannot take a
        # batch of offsets, so the write is a one-hot select over the
        # local sequence axis (O(S) lanes, exact - only the hit slot of
        # a selected batch row changes).
        hit = jnp.arange(s_local)[None, :] == local_off[:, None]
        sel_b = jnp.broadcast_to(sel, (b,))  # scalar True when tp == 1
        mask4 = (hit & sel_b[:, None])[..., None, None]     # (B,S,1,1)
        cache_k = jnp.where(mask4, k_new.astype(cache_k.dtype), cache_k)
        cache_v = jnp.where(mask4, v_new.astype(cache_v.dtype), cache_v)
    else:
        upd_k = lax.dynamic_update_slice(
            cache_k, k_new.astype(cache_k.dtype),
            (0, local_off.astype(jnp.int32), 0, 0))
        upd_v = lax.dynamic_update_slice(
            cache_v, v_new.astype(cache_v.dtype),
            (0, local_off.astype(jnp.int32), 0, 0))
        cache_k = jnp.where(sel, upd_k, cache_k)
        cache_v = jnp.where(sel, upd_v, cache_v)

    # Partial attention over the local sequence slice, all q heads.
    q_full = _gather_heads(q, pc) if pc.tp > 1 else q   # (B,1,Hq_full,hd)
    hq_full = q_full.shape[2]
    kk, vv = select_kv_global(cache_k, cache_v, hq_full, cfg)
    logits = jnp.einsum("bqhd,bkhd->bhqk", q_full, kk,
                        preferred_element_type=jnp.float32)
    logits = logits / math.sqrt(d.head_dim)
    # mask invalid cache slots: global slot index of local slot j
    base = tp_idx * s_local if pc.tp > 1 else 0
    slot_pos = base + jnp.arange(s_local)
    sp = slot_pos[None, None, None, :]
    pv = pos.reshape(b, 1, 1, 1) if vec else pos
    if window is not None:
        # ring buffer: before the buffer wraps (pos < window) only slots
        # <= pos hold data; afterwards every slot is live.
        valid = (sp <= pv) | (pv >= window)
    else:
        valid = sp <= pv
    logits = jnp.where(valid, logits, -jnp.inf)
    m = jnp.max(logits, axis=-1)                          # (B,H,1)
    m_glob = pc.tp_psum_max(m)
    p = jnp.exp(logits - m_glob[..., None])
    p = jnp.where(valid, p, 0.0)
    l_part = jnp.sum(p, axis=-1)                          # (B,H,1)
    o_part = jnp.einsum("bhqk,bkhd->bqhd", p.astype(vv.dtype), vv)
    l_glob = pc.tp_all_reduce(l_part)
    o_glob = pc.tp_all_reduce(o_part.astype(jnp.float32))
    out_full = o_glob / jnp.maximum(
        l_glob, 1e-20).transpose(0, 2, 1)[..., None]      # (B,1,H,hd)
    # Row-parallel output projection: my shard's q-head slice only.
    if pc.tp > 1:
        my = pc.tp_index()
        out_local = lax.dynamic_slice_in_dim(out_full, my * d.n_q, d.n_q,
                                             axis=2)
    else:
        out_local = out_full
    out = dense(out_local.astype(x.dtype).reshape(b, 1,
                                                  d.n_q * d.head_dim),
                params["wo"])
    out = pc.tp_all_reduce(out)
    return out, cache_k, cache_v


def _gather_heads(x: jnp.ndarray, pc: ParallelContext) -> jnp.ndarray:
    """(B, L, h_local, hd) -> (B, L, h_local*tp, hd) via tp all-gather."""
    if pc.tp_axis is None or pc.tp == 1:
        return x
    moved = jnp.moveaxis(x, 2, 0)          # (h, B, L, hd)
    gathered = pc.comm.all_gather(moved, pc.tp_axis)
    return jnp.moveaxis(gathered, 0, 2)


# ---------------------------------------------------------------------- #
# SwiGLU FFN
# ---------------------------------------------------------------------- #

def init_ffn(key, d_model: int, d_ff_local: int, dtype) -> Params:
    ks = jax.random.split(key, 3)
    return {
        "wg": _dense_init(ks[0], (d_model, d_ff_local), d_model, dtype),
        "wu": _dense_init(ks[1], (d_model, d_ff_local), d_model, dtype),
        "wd": _dense_init(ks[2], (d_ff_local, d_model), d_ff_local, dtype),
    }


def ffn_forward(params: Params, x: jnp.ndarray,
                pc: ParallelContext) -> jnp.ndarray:
    h = jax.nn.silu(dense(x, params["wg"])) * dense(x, params["wu"])
    out = dense(h, params["wd"])
    return pc.tp_all_reduce(out)


# ---------------------------------------------------------------------- #
# embeddings + sharded-vocab cross entropy
# ---------------------------------------------------------------------- #

def init_embedding(key, cfg: ModelConfig, tp: int, dtype) -> Params:
    """GLOBAL shapes; vocab padded to a multiple of tp (padded ids are
    masked out of the softmax in sharded_xent)."""
    v_pad = cfg.padded_vocab(tp)
    k1, k2 = jax.random.split(key)
    p = {"tok": (jax.random.normal(k1, (v_pad, cfg.d_model)) *
                 0.02).astype(dtype)}
    if not cfg.tie_embeddings:
        p["head"] = _dense_init(k2, (cfg.d_model, v_pad), cfg.d_model,
                                dtype)
    return p


def embed_tokens(params: Params, tokens: jnp.ndarray, cfg: ModelConfig,
                 pc: ParallelContext) -> jnp.ndarray:
    """Vocab-sharded embedding lookup: each shard contributes its slice,
    summed over tp (one AllReduce)."""
    v_local = params["tok"].shape[0]
    if pc.tp > 1:
        start = pc.tp_index() * v_local
        local_ids = tokens - start
        in_range = (local_ids >= 0) & (local_ids < v_local)
        local_ids = jnp.clip(local_ids, 0, v_local - 1)
        emb = params["tok"][local_ids]
        emb = jnp.where(in_range[..., None], emb, 0.0)
        return pc.tp_all_reduce(emb)
    return params["tok"][tokens]


def lm_logits(params: Params, h: jnp.ndarray, cfg: ModelConfig,
              pc: ParallelContext) -> jnp.ndarray:
    """Returns *local* vocab-slice logits (B, L, V/tp)."""
    w = params.get("head")
    if w is None:
        w = params["tok"].T
    return h @ w


def sharded_xent(logits_local: jnp.ndarray, labels: jnp.ndarray,
                 pc: ParallelContext,
                 mask: Optional[jnp.ndarray] = None,
                 vocab_size: Optional[int] = None) -> jnp.ndarray:
    """Cross-entropy over a vocab-sharded logits tensor.

    logits_local: (B, L, V_local); labels: (B, L) global ids.
    Three tp collectives: max, sum-exp, label-logit.  ``vocab_size``
    excludes padded vocabulary ids from the softmax.
    """
    v_local = logits_local.shape[-1]
    logits_local = logits_local.astype(jnp.float32)
    if vocab_size is not None:
        gid = pc.tp_index() * v_local + jnp.arange(v_local)
        logits_local = jnp.where(gid[None, None, :] < vocab_size,
                                 logits_local, -jnp.inf)
    # stop_gradient: the max is a constant offset of logsumexp, so
    # gradients are exact without it (and pmax has no AD rule - tp_max
    # is the gather-based differentiable-path variant).
    m = jax.lax.stop_gradient(
        pc.tp_max(jnp.max(logits_local, axis=-1)))
    z = jnp.sum(jnp.exp(logits_local - m[..., None]), axis=-1)
    z = pc.tp_all_reduce(z)
    start = pc.tp_index() * v_local
    local_ids = labels - start
    in_range = (local_ids >= 0) & (local_ids < v_local)
    safe = jnp.clip(local_ids, 0, v_local - 1)
    lab = jnp.take_along_axis(logits_local, safe[..., None],
                              axis=-1)[..., 0]
    lab = jnp.where(in_range, lab, 0.0)
    lab = pc.tp_all_reduce(lab)
    nll = jnp.log(z) + m - lab
    if mask is not None:
        nll = nll * mask
        return jnp.sum(nll) / jnp.maximum(jnp.sum(mask), 1.0)
    return jnp.mean(nll)
