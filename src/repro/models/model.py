"""Top-level language model: init, train forward, prefill, decode.

Handles all six architecture families through ``cfg.layer_pattern`` +
frontend switches:

* decoder-only text (dense / MoE / SSM / hybrid);
* decoder-only with a stub modality frontend (VLM: projected patch
  embeddings prepended to the token sequence);
* encoder-decoder (audio: stub frame embeddings -> bidirectional encoder,
  causal decoder with cross-attention).

Layer rows are executed per scan group with ``lax.scan`` over stacked
params (one compiled body per kind).  ``remat=True`` wraps each row in
``jax.checkpoint`` for training-memory control.
"""
from __future__ import annotations

import functools
from typing import Any, Optional

import jax
import jax.numpy as jnp

from repro.core import ledger
from repro.models import blocks, layers
from repro.models.config import ModelConfig
from repro.models.pcontext import ParallelContext

Params = dict


# ----------------------------------------------------------------------- #
# init
# ----------------------------------------------------------------------- #

def init_params(key, cfg: ModelConfig, tp: int = 1,
                dtype=jnp.float32) -> Params:
    keys = jax.random.split(key, 8)
    params: Params = {
        "embed": layers.init_embedding(keys[0], cfg, tp, dtype),
        "final_norm": jnp.ones((cfg.d_model,), jnp.float32),
    }
    groups = blocks.scan_groups(cfg)
    cross = cfg.encoder is not None
    gkeys = jax.random.split(keys[1], len(groups))
    shared_done = False
    for gi, g in enumerate(groups):
        if g.shared:
            if not shared_done:
                params["shared_a"] = blocks.init_row(
                    gkeys[gi], "a", cfg, tp, dtype, cross=cross)
                shared_done = True
            continue
        # groups are ALWAYS layer-stacked (count-1 groups get a leading
        # dim of 1) so the 'g<i>' key uniformly means "stacked"
        rk = jax.random.split(gkeys[gi], g.count)
        params[f"g{gi}"] = jax.vmap(
            lambda k: blocks.init_row(k, g.kind, cfg, tp, dtype,
                                      cross=cross))(rk)
    if cfg.encoder is not None:
        ek = jax.random.split(keys[2], cfg.encoder.n_layers)
        params["encoder"] = jax.vmap(
            lambda k: blocks.init_row(k, "a", cfg, tp, dtype))(ek)
        params["enc_norm"] = jnp.ones((cfg.d_model,), jnp.float32)
        fd = cfg.frontend_dim or cfg.d_model
        params["enc_proj"] = layers._dense_init(
            keys[3], (fd, cfg.d_model), fd, dtype)
    elif cfg.frontend != "text":
        fd = cfg.frontend_dim or cfg.d_model
        params["front_proj"] = layers._dense_init(
            keys[3], (fd, cfg.d_model), fd, dtype)
    return params


def abstract_params(cfg: ModelConfig, tp: int = 1, dtype=jnp.float32):
    """ShapeDtypeStruct pytree with the same structure as init_params -
    used by the dry-run (no allocation)."""
    return jax.eval_shape(
        lambda k: init_params(k, cfg, tp, dtype),
        jax.random.key(0))


# ----------------------------------------------------------------------- #
# shared plumbing
# ----------------------------------------------------------------------- #

def _encode(params: Params, source: jnp.ndarray, cfg: ModelConfig,
            pc: ParallelContext) -> jnp.ndarray:
    """Stub-frontend frames -> encoder stack (bidirectional)."""
    h = source @ params["enc_proj"]
    positions = jnp.arange(h.shape[1])
    def body(carry, p):
        out, _ = blocks.row_forward(p, carry, "a", cfg, pc, positions,
                                    causal=False)
        return out, None
    with ledger.scale(cfg.encoder.n_layers):
        h, _ = jax.lax.scan(body, h, params["encoder"])
    return layers.rms_norm(h, params["enc_norm"], cfg.norm_eps)


def _embed_inputs(params: Params, batch: dict, cfg: ModelConfig,
                  pc: ParallelContext):
    """Returns (h, n_prefix, encoder_out)."""
    tokens = batch["tokens"]
    h = layers.embed_tokens(params["embed"], tokens, cfg, pc)
    encoder_out = None
    n_prefix = 0
    if cfg.encoder is not None:
        encoder_out = _encode(params, batch["source"], cfg, pc)
    elif cfg.frontend != "text":
        front = batch["frontend"] @ params["front_proj"]
        h = jnp.concatenate([front.astype(h.dtype), h], axis=1)
        n_prefix = front.shape[1]
    return h, n_prefix, encoder_out


def _run_groups(params: Params, h, cfg, pc, positions, encoder_out,
                remat: bool, window=None, gather_fn=None,
                prefetch: int = 0):
    """``prefetch >= 1`` enables the double-buffered FSDP prefetch: each
    scan body issues layer ``l+1``'s param AllGather (carried explicitly)
    alongside layer ``l``'s compute, so XLA schedules the gather as an
    async collective hidden behind the matmuls.  The prefetched gathers
    run under ``ledger.hidden()`` (the prologue gather stays exposed)."""
    groups = blocks.scan_groups(cfg)
    aux_total = jnp.float32(0.0)
    prefetching = gather_fn is not None and prefetch >= 1

    def make_body(kind, group_key):
        def body(carry, p):
            if gather_fn is not None:
                p = gather_fn(group_key, p)   # FSDP: gather row params
            out, aux = blocks.row_forward(p, carry, kind, cfg, pc,
                                          positions,
                                          encoder_out=encoder_out,
                                          window=window)
            return out, aux
        return jax.checkpoint(body) if remat else body

    def make_prefetch_body(kind, group_key):
        """carry = (h, gathered params of the layer to compute now);
        xs = raw (sharded) params of the NEXT layer."""
        def body(carry, p_next):
            hh, p_cur = carry
            with ledger.hidden():
                p_pre = gather_fn(group_key, p_next)
            out, aux = blocks.row_forward(p_cur, hh, kind, cfg, pc,
                                          positions,
                                          encoder_out=encoder_out,
                                          window=window)
            return (out, p_pre), aux
        return jax.checkpoint(body) if remat else body

    def make_consume(kind):
        """Epilogue: compute one row from already-gathered params."""
        def body(carry, p):
            out, aux = blocks.row_forward(p, carry, kind, cfg, pc,
                                          positions,
                                          encoder_out=encoder_out,
                                          window=window)
            return out, aux
        return jax.checkpoint(body) if remat else body

    for gi, g in enumerate(groups):
        if g.shared:
            if prefetching:
                # one param set reused count x: gather it ONCE instead of
                # per occurrence (count x fewer AllGathers; the AD
                # transpose fuses the count ReduceScatters into one)
                sp = gather_fn("shared_a", params["shared_a"])
                body = make_consume("a")
                for _ in range(g.count):
                    h, aux = body(h, sp)
                    aux_total += aux
            else:
                body = make_body("a", "shared_a")
                for _ in range(g.count):
                    h, aux = body(h, params["shared_a"])
                    aux_total += aux
        elif prefetching:
            stacked = params[f"g{gi}"]
            first = jax.tree.map(lambda x: x[0], stacked)
            gathered = gather_fn(f"g{gi}", first)    # exposed prologue
            if g.count > 1:
                rest = jax.tree.map(lambda x: x[1:], stacked)
                # trace-time ledger: the prefetch body runs count-1 x;
                # the prologue gather and epilogue row run once each, so
                # totals match the non-prefetched schedule exactly.
                with ledger.scale(g.count - 1):
                    (h, gathered), auxs = jax.lax.scan(
                        make_prefetch_body(g.kind, f"g{gi}"),
                        (h, gathered), rest)
                aux_total += jnp.sum(auxs)
            h, aux_last = make_consume(g.kind)(h, gathered)
            aux_total += aux_last
        else:
            # trace-time collective ledger: the scan body runs count x
            with ledger.scale(g.count):
                h, auxs = jax.lax.scan(make_body(g.kind, f"g{gi}"), h,
                                       params[f"g{gi}"])
            aux_total += jnp.sum(auxs)
    return h, aux_total


# ----------------------------------------------------------------------- #
# training forward
# ----------------------------------------------------------------------- #

def loss_fn(params: Params, batch: dict, cfg: ModelConfig,
            pc: ParallelContext, remat: bool = True,
            window: Optional[int] = None, gather_fn=None,
            prefetch: int = 0):
    """batch: tokens (B, L_text), labels (B, L_text), optional
    frontend/source.  ``gather_fn(group_key, row_params)`` is the FSDP
    hook (sharding.fsdp_gather_fn / core.overlap.make_gather_fn);
    ``prefetch >= 1`` double-buffers it (see _run_groups).  Returns
    (loss, aux_dict)."""
    if gather_fn is not None:
        # embed is used at both ends of the step: gather once up front.
        params = dict(params, embed=gather_fn("embed", params["embed"]))
    h, n_prefix, encoder_out = _embed_inputs(params, batch, cfg, pc)
    positions = jnp.arange(h.shape[1])
    h, aux = _run_groups(params, h, cfg, pc, positions, encoder_out,
                         remat=remat, window=window, gather_fn=gather_fn,
                         prefetch=prefetch)
    h = layers.rms_norm(h, params["final_norm"], cfg.norm_eps)
    if n_prefix:
        h = h[:, n_prefix:]
    logits = layers.lm_logits(params["embed"], h, cfg, pc)
    xent = layers.sharded_xent(logits, batch["labels"], pc,
                               mask=batch.get("loss_mask"),
                               vocab_size=cfg.vocab_size)
    return xent + aux, {"xent": xent, "aux": aux}


# ----------------------------------------------------------------------- #
# serving: prefill + decode
# ----------------------------------------------------------------------- #

def prefill(params: Params, batch: dict, cfg: ModelConfig,
            pc: ParallelContext, max_seq: int,
            cache_dtype=jnp.bfloat16, window: Optional[int] = None):
    """Full-sequence forward producing last-position logits + decode
    cache (a list aligned with scan groups)."""
    h, n_prefix, encoder_out = _embed_inputs(params, batch, cfg, pc)
    positions = jnp.arange(h.shape[1])
    groups = blocks.scan_groups(cfg)
    caches: list = []

    def make_body(kind):
        def body(carry, p):
            out, aux, cache = blocks.row_prefill(
                p, carry, kind, cfg, pc, positions, max_seq, cache_dtype,
                encoder_out=encoder_out, window=window)
            return out, cache
        return body

    for gi, g in enumerate(groups):
        if g.shared:
            body = make_body("a")
            gc = []
            for _ in range(g.count):
                h, cache = body(h, params["shared_a"])
                gc.append(cache)
            caches.append(gc)
        else:
            with ledger.scale(g.count):
                h, cache = jax.lax.scan(make_body(g.kind), h,
                                        params[f"g{gi}"])
            caches.append(cache)
    h = layers.rms_norm(h, params["final_norm"], cfg.norm_eps)
    logits = layers.lm_logits(params["embed"], h[:, -1:], cfg, pc)
    return logits, caches


def init_cache(cfg: ModelConfig, pc: ParallelContext, batch: int,
               max_seq: int, cache_dtype=jnp.bfloat16,
               window: Optional[int] = None):
    """Zero cache for decode-from-scratch (the dry-run decode shapes)."""
    eff_seq = min(max_seq, window) if window else max_seq
    cross_len = cfg.encoder.source_len if cfg.encoder else 0
    groups = blocks.scan_groups(cfg)
    caches = []
    for g in groups:
        one = lambda: blocks.row_cache_init(g.kind, cfg, pc, batch,
                                            eff_seq, cache_dtype,
                                            cross_len=cross_len)
        if g.shared:
            caches.append([one() for _ in range(g.count)])
        else:
            caches.append(jax.tree.map(
                lambda *xs: jnp.stack(xs), *[one() for _ in
                                             range(g.count)]))
    return caches


def decode_step(params: Params, caches: list, tokens: jnp.ndarray,
                pos: jnp.ndarray, cfg: ModelConfig, pc: ParallelContext,
                window: Optional[int] = None):
    """One decode step.  tokens: (B, 1) int32; pos: the global position
    being decoded - scalar int32 (whole batch in lockstep) or (B,)
    int32 (per-slot positions for the continuous-batching engine; see
    ``layers.decode_attention``).  Returns (logits (B, 1, V_padded),
    new_caches)."""
    h = layers.embed_tokens(params["embed"], tokens, cfg, pc)
    groups = blocks.scan_groups(cfg)
    new_caches = []
    for gi, g in enumerate(groups):
        def body(carry, pc_pair):
            p, cache = pc_pair
            out, nc = blocks.row_decode(p, carry, g.kind, cache, pos,
                                        cfg, pc, window=window)
            return out, nc
        if g.shared:
            gc = []
            for cache in caches[gi]:
                h, nc = body(h, (params["shared_a"], cache))
                gc.append(nc)
            new_caches.append(gc)
        else:
            with ledger.scale(g.count):
                h, nc = jax.lax.scan(body, h,
                                     (params[f"g{gi}"], caches[gi]))
            new_caches.append(nc)
    h = layers.rms_norm(h, params["final_norm"], cfg.norm_eps)
    logits_local = layers.lm_logits(params["embed"], h, cfg, pc)
    if pc.tp > 1:
        moved = jnp.moveaxis(logits_local, -1, 0)
        logits = jnp.moveaxis(pc.comm.all_gather(moved, pc.tp_axis), 0, -1)
    else:
        logits = logits_local
    return logits[..., :cfg.vocab_size], new_caches
